"""AOT compile path: lower L2 (JAX model + L1 Pallas kernels) to HLO text.

Emits one .hlo.txt per executable plus artifacts/manifest.json describing
argument/result layouts so the Rust runtime can load and drive them blind.

HLO *text* (not serialized HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids, so text round-trips cleanly. See /opt/xla-example/README.md.

Usage:
    python -m compile.aot --out-dir ../artifacts [--large]
"""

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

LEARNING_RATE = {"tiny": 1e-2, "e2e": 3e-3, "e2e-100m": 1e-3}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple for stable ABI)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype="float32"):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def _arg_entry(shape, dtype):
    return {"shape": list(shape), "dtype": str(dtype)}


def lower_init(cfg: M.ModelConfig):
    def fn(seed):
        return tuple(M.init_params(cfg, seed))

    lowered = jax.jit(fn).lower(_spec((), "uint32"))
    args = [_arg_entry((), "uint32")]
    outs = [_arg_entry(s, "float32") for _, s in cfg.param_specs()]
    return to_hlo_text(lowered), args, outs


def lower_train_step(cfg: M.ModelConfig, lr: float):
    n = len(cfg.param_specs())

    def fn(*flat):
        state = list(flat[: 3 * n])
        step = flat[3 * n]
        tokens = flat[3 * n + 1]
        loss, new_state, new_step = M.train_step(cfg, lr, state, step, tokens)
        return tuple([loss] + new_state + [new_step])

    state_specs = [_spec(s) for _, s in cfg.param_specs()] * 3
    step_spec = _spec((), "int32")
    tok_spec = _spec((cfg.batch, cfg.seq_len + 1), "int32")
    lowered = jax.jit(fn).lower(*state_specs, step_spec, tok_spec)
    args = (
        [_arg_entry(s, "float32") for _, s in cfg.param_specs()] * 3
        + [_arg_entry((), "int32"), _arg_entry((cfg.batch, cfg.seq_len + 1), "int32")]
    )
    outs = (
        [_arg_entry((), "float32")]
        + [_arg_entry(s, "float32") for _, s in cfg.param_specs()] * 3
        + [_arg_entry((), "int32")]
    )
    return to_hlo_text(lowered), args, outs


def lower_fwd(cfg: M.ModelConfig, use_pallas: bool):
    def fn(*flat):
        params = list(flat[:-1])
        tokens = flat[-1]
        return (M.forward(cfg, params, tokens, use_pallas=use_pallas),)

    param_specs = [_spec(s) for _, s in cfg.param_specs()]
    tok_spec = _spec((cfg.batch, cfg.seq_len), "int32")
    lowered = jax.jit(fn).lower(*param_specs, tok_spec)
    args = [_arg_entry(s, "float32") for _, s in cfg.param_specs()] + [
        _arg_entry((cfg.batch, cfg.seq_len), "int32")
    ]
    outs = [_arg_entry((cfg.batch, cfg.seq_len, cfg.vocab), "float32")]
    return to_hlo_text(lowered), args, outs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--large", action="store_true", help="also emit the ~100M-param e2e config")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"artifacts": {}, "configs": {}}
    jobs = [
        ("init_tiny", lambda: lower_init(M.CONFIGS["tiny"])),
        ("fwd_ref_tiny", lambda: lower_fwd(M.CONFIGS["tiny"], use_pallas=False)),
        ("fwd_pallas_tiny", lambda: lower_fwd(M.CONFIGS["tiny"], use_pallas=True)),
        ("train_step_tiny", lambda: lower_train_step(M.CONFIGS["tiny"], LEARNING_RATE["tiny"])),
        ("init_e2e", lambda: lower_init(M.CONFIGS["e2e"])),
        ("train_step_e2e", lambda: lower_train_step(M.CONFIGS["e2e"], LEARNING_RATE["e2e"])),
    ]
    if args.large:
        jobs += [
            ("init_e2e-100m", lambda: lower_init(M.CONFIGS["e2e-100m"])),
            (
                "train_step_e2e-100m",
                lambda: lower_train_step(M.CONFIGS["e2e-100m"], LEARNING_RATE["e2e-100m"]),
            ),
        ]

    for name, job in jobs:
        t0 = time.time()
        text, arg_specs, out_specs = job()
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "args": arg_specs,
            "outputs": out_specs,
        }
        print(f"lowered {name}: {len(text)} chars in {time.time() - t0:.1f}s")

    for cname, cfg in M.CONFIGS.items():
        if cname == "e2e-100m" and not args.large:
            continue
        manifest["configs"][cname] = {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff,
            "seq_len": cfg.seq_len,
            "batch": cfg.batch,
            "n_param_arrays": len(cfg.param_specs()),
            "n_params": int(cfg.n_params()),
            "lr": LEARNING_RATE[cname],
            "param_names": [n for n, _ in cfg.param_specs()],
        }

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest with {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
