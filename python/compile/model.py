"""L2: decoder-only transformer LM in JAX (build-time only).

Forward, cross-entropy loss, backward, and a fused AdamW train step. The
attention layer can run through the L1 Pallas flash-attention kernel
(`use_pallas=True`) or the pure-jnp oracle; both lower to the same HLO
artifact format consumed by the Rust runtime.

Parameters are a flat, deterministically-ordered list of arrays so the Rust
side can thread `(params, opt_m, opt_v)` through repeated `train_step`
executions without understanding the pytree structure. The ordering is
recorded in artifacts/manifest.json by aot.py.
"""

import dataclasses
import functools
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.attention import flash_attention


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Decoder-only transformer configuration (Llama-style, RoPE + SwiGLU)."""

    vocab: int = 256
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 1024  # SwiGLU hidden size
    seq_len: int = 128
    batch: int = 8
    rope_theta: float = 10000.0
    eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_specs(self) -> List[Tuple[str, Tuple[int, ...]]]:
        """Flat (name, shape) list; the canonical parameter ordering."""
        specs: List[Tuple[str, Tuple[int, ...]]] = [("embed", (self.vocab, self.d_model))]
        for i in range(self.n_layers):
            specs += [
                (f"layer{i}.attn_norm", (self.d_model,)),
                (f"layer{i}.wq", (self.d_model, self.d_model)),
                (f"layer{i}.wk", (self.d_model, self.d_model)),
                (f"layer{i}.wv", (self.d_model, self.d_model)),
                (f"layer{i}.wo", (self.d_model, self.d_model)),
                (f"layer{i}.mlp_norm", (self.d_model,)),
                (f"layer{i}.w_gate", (self.d_model, self.d_ff)),
                (f"layer{i}.w_up", (self.d_model, self.d_ff)),
                (f"layer{i}.w_down", (self.d_ff, self.d_model)),
            ]
        specs += [("final_norm", (self.d_model,)), ("lm_head", (self.d_model, self.vocab))]
        return specs

    def n_params(self) -> int:
        return sum(int(jnp.prod(jnp.asarray(s))) for _, s in self.param_specs())


def init_params(cfg: ModelConfig, seed: jax.Array) -> List[jax.Array]:
    """Initialize the flat parameter list from a scalar uint32 seed.

    Scaled-normal init for matrices, ones for norm gains. Lowered to its own
    HLO artifact so the Rust trainer can materialize parameters on-device.
    """
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in cfg.param_specs():
        key, sub = jax.random.split(key)
        if len(shape) == 1:  # norm gain
            params.append(jnp.ones(shape, jnp.float32))
        else:
            fan_in = shape[0]
            std = 1.0 / jnp.sqrt(jnp.asarray(fan_in, jnp.float32))
            params.append(jax.random.normal(sub, shape, jnp.float32) * std)
    return params


def _rope(x: jax.Array, theta: float) -> jax.Array:
    """Rotary position embedding. x: [heads, seq, head_dim]."""
    _, seq, d = x.shape
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = jnp.arange(seq, dtype=jnp.float32)[:, None] * freqs[None, :]  # [seq, half]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def forward(
    cfg: ModelConfig, params: List[jax.Array], tokens: jax.Array, use_pallas: bool = False
) -> jax.Array:
    """Forward pass. tokens: [batch, seq] int32 -> logits [batch, seq, vocab]."""
    specs = cfg.param_specs()
    p = {name: arr for (name, _), arr in zip(specs, params)}
    x = p["embed"][tokens]  # [b, s, d]

    attn = flash_attention if use_pallas else ref.attention_ref

    def block(x, i):
        h = ref.rmsnorm_ref(x, p[f"layer{i}.attn_norm"], cfg.eps)
        b, s, d = h.shape
        nh, hd = cfg.n_heads, cfg.head_dim

        def heads_of(w):
            y = h @ w  # [b, s, d]
            return y.reshape(b, s, nh, hd).transpose(0, 2, 1, 3)  # [b, nh, s, hd]

        q, k, v = heads_of(p[f"layer{i}.wq"]), heads_of(p[f"layer{i}.wk"]), heads_of(p[f"layer{i}.wv"])
        q = jax.vmap(lambda t: _rope(t, cfg.rope_theta))(q)
        k = jax.vmap(lambda t: _rope(t, cfg.rope_theta))(k)
        o = jax.vmap(lambda qq, kk, vv: attn(qq, kk, vv))(q, k, v)  # [b, nh, s, hd]
        o = o.transpose(0, 2, 1, 3).reshape(b, s, d) @ p[f"layer{i}.wo"]
        x = x + o
        h2 = ref.rmsnorm_ref(x, p[f"layer{i}.mlp_norm"], cfg.eps)
        x = x + ref.swiglu_ref(
            h2, p[f"layer{i}.w_gate"], p[f"layer{i}.w_up"], p[f"layer{i}.w_down"]
        )
        return x

    for i in range(cfg.n_layers):
        x = block(x, i)
    x = ref.rmsnorm_ref(x, p["final_norm"], cfg.eps)
    return x @ p["lm_head"]


def loss_fn(
    cfg: ModelConfig, params: List[jax.Array], tokens: jax.Array, use_pallas: bool = False
) -> jax.Array:
    """Next-token cross-entropy. tokens: [batch, seq_len + 1] int32."""
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    logits = forward(cfg, params, inp, use_pallas)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# AdamW train step (flat-state layout: params ++ m ++ v, plus step counter)
# ---------------------------------------------------------------------------

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.95, 1e-8
WEIGHT_DECAY = 0.01


def train_step(
    cfg: ModelConfig,
    lr: float,
    state: List[jax.Array],
    step: jax.Array,
    tokens: jax.Array,
) -> Tuple[jax.Array, List[jax.Array], jax.Array]:
    """One fused fwd+bwd+AdamW update.

    state = flat [params..., m..., v...] (3 * n_params arrays).
    Returns (loss, new_state, new_step); the Rust trainer threads outputs
    back into inputs each step.
    """
    n = len(cfg.param_specs())
    assert len(state) == 3 * n, f"state len {len(state)} != 3*{n}"
    params, m, v = state[:n], state[n : 2 * n], state[2 * n :]

    loss, grads = jax.value_and_grad(lambda ps: loss_fn(cfg, ps, tokens))(params)

    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - ADAM_B1 ** t
    bc2 = 1.0 - ADAM_B2 ** t
    new_params, new_m, new_v = [], [], []
    for pi, mi, vi, gi, (name, _) in zip(params, m, v, grads, cfg.param_specs()):
        mi2 = ADAM_B1 * mi + (1.0 - ADAM_B1) * gi
        vi2 = ADAM_B2 * vi + (1.0 - ADAM_B2) * jnp.square(gi)
        update = (mi2 / bc1) / (jnp.sqrt(vi2 / bc2) + ADAM_EPS)
        decay = 0.0 if pi.ndim == 1 else WEIGHT_DECAY  # no decay on norm gains
        new_params.append(pi - lr * (update + decay * pi))
        new_m.append(mi2)
        new_v.append(vi2)
    return loss, new_params + new_m + new_v, step + 1


def zeros_like_params(cfg: ModelConfig) -> List[jax.Array]:
    return [jnp.zeros(shape, jnp.float32) for _, shape in cfg.param_specs()]


# Named configurations used by aot.py / the Rust trainer.
CONFIGS = {
    # Pallas-vs-ref numerics check (small so interpret-mode is fast).
    "tiny": ModelConfig(vocab=64, d_model=64, n_layers=2, n_heads=2, d_ff=128, seq_len=64, batch=2),
    # E2E training default (~13M params), a few hundred steps on CPU PJRT.
    "e2e": ModelConfig(vocab=512, d_model=320, n_layers=6, n_heads=5, d_ff=896, seq_len=128, batch=8),
    # ~100M-parameter config for the full-scale E2E run (slower per step).
    "e2e-100m": ModelConfig(
        vocab=4096, d_model=768, n_layers=12, n_heads=12, d_ff=2048, seq_len=256, batch=4
    ),
}
