"""L1: blocked causal flash attention as a Pallas kernel.

This is the compute hot-spot of the paper's Attention-AllReduce partition
(the "FlashAttention" box in Figure 3). The kernel uses the online-softmax
formulation: the grid tiles the query sequence, and each program streams
key/value blocks from HBM through VMEM, maintaining running max / sum /
accumulator state.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's CUDA
kernel tiles for shared memory and warps; here BlockSpec expresses the
HBM<->VMEM schedule, and block sizes are MXU-friendly multiples. The kernel
MUST run with interpret=True in this environment — real-TPU lowering emits
a Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 64
DEFAULT_BLOCK_K = 64
NEG_INF = -1e30


def _flash_attention_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, causal: bool, scale: float):
    """One grid program: one query block vs. all (visible) key blocks.

    Refs (VMEM blocks):
      q_ref: [block_q, d]    -- this program's query tile
      k_ref: [seq_k, d]      -- full keys (streamed in block_k chunks below)
      v_ref: [seq_k, d]      -- full values
      o_ref: [block_q, d]    -- output tile
    """
    block_q = q_ref.shape[0]
    seq_k = k_ref.shape[0]
    d = q_ref.shape[1]
    q_blk_idx = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale

    # Running online-softmax state.
    m0 = jnp.full((block_q,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((block_q,), dtype=jnp.float32)
    acc0 = jnp.zeros((block_q, d), dtype=jnp.float32)

    num_k_blocks = pl.cdiv(seq_k, block_k)
    if causal:
        # Skip key blocks strictly after the last query row of this tile.
        last_q_row = (q_blk_idx + 1) * block_q - 1
        num_visible = pl.cdiv(last_q_row + 1, block_k)
        num_visible = jnp.minimum(num_visible, num_k_blocks)
    else:
        num_visible = num_k_blocks

    def body(kb, carry):
        m_i, l_i, acc = carry
        k_blk = pl.load(k_ref, (pl.dslice(kb * block_k, block_k), slice(None))).astype(jnp.float32)
        v_blk = pl.load(v_ref, (pl.dslice(kb * block_k, block_k), slice(None))).astype(jnp.float32)
        s = q @ k_blk.T  # [block_q, block_k]
        if causal:
            q_rows = q_blk_idx * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            k_cols = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            s = jnp.where(q_rows >= k_cols, s, NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_i - m_new)
        l_new = l_i * alpha + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + p @ v_blk
        return m_new, l_new, acc_new

    m_f, l_f, acc_f = jax.lax.fori_loop(0, num_visible, body, (m0, l0, acc0))
    # Rows with no visible keys (cannot happen for causal self-attention,
    # but guard the division anyway).
    l_safe = jnp.where(l_f == 0.0, 1.0, l_f)
    o_ref[...] = (acc_f / l_safe[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
) -> jax.Array:
    """Blocked causal attention. q, k, v: [heads, seq, head_dim].

    Grid: (heads, seq_q / block_q). Each program holds one query tile in
    VMEM and streams keys/values.
    """
    assert q.ndim == 3, f"expected [heads, seq, d], got {q.shape}"
    heads, seq_q, d = q.shape
    seq_k = k.shape[1]
    block_q = min(block_q, seq_q)
    block_k = min(block_k, seq_k)
    assert seq_q % block_q == 0 and seq_k % block_k == 0, (
        f"seq ({seq_q},{seq_k}) must be divisible by blocks ({block_q},{block_k})"
    )
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(
        _flash_attention_kernel, block_k=block_k, causal=causal, scale=scale
    )
    return pl.pallas_call(
        kernel,
        grid=(heads, seq_q // block_q),
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda h, i: (h, i, 0)),
            pl.BlockSpec((None, seq_k, d), lambda h, i: (h, 0, 0)),
            pl.BlockSpec((None, seq_k, d), lambda h, i: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls.
    )(q, k, v)
