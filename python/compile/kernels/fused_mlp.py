"""L1: fused RMSNorm + tiled matmul as a Pallas kernel.

The paper's MLP-AllReduce partition schedules a Norm kernel followed by a
Linear kernel (Figure 3). Fusing the (memory-bound) norm into the
(compute-bound) matmul's first pass removes one full HBM round-trip of the
activation tensor -- the same static-energy argument the paper makes for
grouping short memory-bound computations (Section 4.5).

Grid tiles rows x output-columns; each program re-normalizes its row tile
(d is small enough that a full row fits in VMEM) and multiplies with one
weight column tile.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_M = 64
DEFAULT_BLOCK_N = 128


def _fused_rmsnorm_matmul_kernel(x_ref, gamma_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # [bm, d] -- full feature dim per row
    gamma = gamma_ref[...].astype(jnp.float32)  # [d]
    w = w_ref[...].astype(jnp.float32)  # [d, bn]
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    normed = x * jax.lax.rsqrt(ms + eps) * gamma[None, :]
    o_ref[...] = (normed @ w).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_m", "block_n"))
def fused_rmsnorm_matmul(
    x: jax.Array,
    gamma: jax.Array,
    w: jax.Array,
    eps: float = 1e-5,
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
) -> jax.Array:
    """rmsnorm(x, gamma) @ w with x: [m, d], gamma: [d], w: [d, n]."""
    m, d = x.shape
    d2, n = w.shape
    assert d == d2 and gamma.shape == (d,)
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    assert m % block_m == 0 and n % block_n == 0, (
        f"dims ({m},{n}) must be divisible by blocks ({block_m},{block_n})"
    )
    kernel = functools.partial(_fused_rmsnorm_matmul_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(m // block_m, n // block_n),
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d,), lambda i, j: (0,)),
            pl.BlockSpec((d, block_n), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls.
    )(x, gamma, w)
