"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness signal).

Every Pallas kernel in this package has an exact (up to float tolerance)
counterpart here. pytest (python/tests/) sweeps shapes/dtypes with
hypothesis and asserts allclose between the kernel and its oracle; the
same oracles back the `fwd_ref` AOT artifact that the Rust integration
tests compare against `fwd_pallas`.
"""

import jax
import jax.numpy as jnp


def rmsnorm_ref(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm over the last axis: x * rsqrt(mean(x^2) + eps) * gamma."""
    ms = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(ms + eps) * gamma).astype(x.dtype)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True) -> jax.Array:
    """Scaled dot-product attention oracle.

    q, k, v: [..., seq, head_dim]; returns the same shape as q.
    """
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    logits = jnp.einsum("...qd,...kd->...qk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if causal:
        sq, sk = logits.shape[-2], logits.shape[-1]
        mask = jnp.tril(jnp.ones((sq, sk), dtype=bool), k=sk - sq)
        logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("...qk,...kd->...qd", probs, v.astype(jnp.float32)).astype(q.dtype)


def fused_rmsnorm_matmul_ref(
    x: jax.Array, gamma: jax.Array, w: jax.Array, eps: float = 1e-5
) -> jax.Array:
    """Oracle for the fused RMSNorm + matmul kernel: rmsnorm(x, gamma) @ w."""
    return (rmsnorm_ref(x, gamma, eps).astype(jnp.float32) @ w.astype(jnp.float32)).astype(
        x.dtype
    )


def swiglu_ref(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    """SwiGLU MLP oracle: (silu(x @ w_gate) * (x @ w_up)) @ w_down."""
    xf = x.astype(jnp.float32)
    out = (jax.nn.silu(xf @ w_gate.astype(jnp.float32)) * (xf @ w_up.astype(jnp.float32))) @ w_down.astype(jnp.float32)
    return out.astype(x.dtype)
