"""L2 correctness: model shapes, loss behaviour, train-step convergence,
pallas/ref forward agreement, and AOT artifact integrity."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


@pytest.fixture(scope="module")
def tiny():
    return M.CONFIGS["tiny"]


@pytest.fixture(scope="module")
def tiny_params(tiny):
    return M.init_params(tiny, jnp.uint32(0))


def synthetic_tokens(cfg, seed=0, n_extra=1):
    """Learnable synthetic stream: affine next-token map + noise (mirrors
    the Rust trainer's corpus generator)."""
    rng = np.random.default_rng(seed)
    b, s = cfg.batch, cfg.seq_len + n_extra - 1
    toks = np.zeros((b, s + 1), dtype=np.int32)
    toks[:, 0] = rng.integers(0, cfg.vocab, size=b)
    for t in range(s):
        toks[:, t + 1] = (toks[:, t] * 31 + 17) % cfg.vocab
    return jnp.asarray(toks)


class TestInit:
    def test_param_count_matches_specs(self, tiny, tiny_params):
        assert len(tiny_params) == len(tiny.param_specs())
        for arr, (_, shape) in zip(tiny_params, tiny.param_specs()):
            assert arr.shape == shape

    def test_norm_gains_are_ones(self, tiny, tiny_params):
        for arr, (name, _) in zip(tiny_params, tiny.param_specs()):
            if "norm" in name:
                np.testing.assert_allclose(arr, jnp.ones_like(arr))

    def test_deterministic(self, tiny):
        p1 = M.init_params(tiny, jnp.uint32(7))
        p2 = M.init_params(tiny, jnp.uint32(7))
        for a, b in zip(p1, p2):
            np.testing.assert_array_equal(a, b)

    def test_seed_changes_params(self, tiny):
        p1 = M.init_params(tiny, jnp.uint32(1))
        p2 = M.init_params(tiny, jnp.uint32(2))
        assert any(not np.array_equal(a, b) for a, b in zip(p1, p2))


class TestForward:
    def test_logits_shape(self, tiny, tiny_params):
        toks = synthetic_tokens(tiny)[:, : tiny.seq_len]
        logits = M.forward(tiny, tiny_params, toks)
        assert logits.shape == (tiny.batch, tiny.seq_len, tiny.vocab)

    def test_causality(self, tiny, tiny_params):
        """Changing a future token must not change earlier logits."""
        toks = synthetic_tokens(tiny)[:, : tiny.seq_len]
        l1 = M.forward(tiny, tiny_params, toks)
        toks2 = toks.at[:, -1].set((toks[:, -1] + 1) % tiny.vocab)
        l2 = M.forward(tiny, tiny_params, toks2)
        np.testing.assert_allclose(l1[:, :-1], l2[:, :-1], atol=1e-5, rtol=1e-4)

    def test_pallas_matches_ref_forward(self, tiny, tiny_params):
        """The L1-kernel forward must agree with the oracle forward — the
        same equivalence the Rust integration test checks on HLO artifacts."""
        toks = synthetic_tokens(tiny)[:, : tiny.seq_len]
        l_ref = M.forward(tiny, tiny_params, toks, use_pallas=False)
        l_pal = M.forward(tiny, tiny_params, toks, use_pallas=True)
        np.testing.assert_allclose(l_ref, l_pal, atol=5e-4, rtol=5e-4)

    def test_initial_loss_near_uniform(self, tiny, tiny_params):
        toks = synthetic_tokens(tiny)
        loss = M.loss_fn(tiny, tiny_params, toks)
        assert abs(float(loss) - np.log(tiny.vocab)) < 0.7


class TestTrainStep:
    def test_loss_decreases(self, tiny):
        params = M.init_params(tiny, jnp.uint32(0))
        state = params + M.zeros_like_params(tiny) + M.zeros_like_params(tiny)
        step = jnp.int32(0)
        fn = jax.jit(lambda st, sp, tk: M.train_step(tiny, 1e-2, st, sp, tk))
        losses = []
        for i in range(40):
            toks = synthetic_tokens(tiny, seed=i)
            loss, state, step = fn(state, step, toks)
            losses.append(float(loss))
        # The trajectory is noisy step-to-step; compare a tail average.
        tail = sum(losses[-5:]) / 5.0
        assert tail < losses[0] * 0.5, f"no convergence: {losses[0]} -> tail {tail} ({losses})"

    def test_step_counter_increments(self, tiny):
        params = M.init_params(tiny, jnp.uint32(0))
        state = params + M.zeros_like_params(tiny) + M.zeros_like_params(tiny)
        _, _, step = M.train_step(tiny, 1e-2, state, jnp.int32(3), synthetic_tokens(tiny))
        assert int(step) == 4

    def test_state_layout_stable(self, tiny):
        n = len(tiny.param_specs())
        params = M.init_params(tiny, jnp.uint32(0))
        state = params + M.zeros_like_params(tiny) + M.zeros_like_params(tiny)
        loss, new_state, _ = M.train_step(tiny, 1e-2, state, jnp.int32(0), synthetic_tokens(tiny))
        assert len(new_state) == 3 * n
        for a, b in zip(state, new_state):
            assert a.shape == b.shape


class TestArtifacts:
    """Integrity of the AOT outputs consumed by the Rust runtime."""

    ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

    @pytest.fixture(scope="class")
    def manifest(self):
        path = os.path.join(self.ART, "manifest.json")
        if not os.path.exists(path):
            pytest.skip("artifacts not built (run `make artifacts`)")
        with open(path) as f:
            return json.load(f)

    def test_all_artifacts_exist(self, manifest):
        for name, art in manifest["artifacts"].items():
            assert os.path.exists(os.path.join(self.ART, art["file"])), name

    def test_hlo_text_is_parseable_header(self, manifest):
        for name, art in manifest["artifacts"].items():
            with open(os.path.join(self.ART, art["file"])) as f:
                head = f.read(200)
            assert "HloModule" in head, f"{name} missing HloModule header"

    def test_train_step_arg_layout(self, manifest):
        cfg = manifest["configs"]["tiny"]
        art = manifest["artifacts"]["train_step_tiny"]
        n = cfg["n_param_arrays"]
        assert len(art["args"]) == 3 * n + 2
        assert len(art["outputs"]) == 3 * n + 2
        assert art["args"][-1]["shape"] == [cfg["batch"], cfg["seq_len"] + 1]

    def test_manifest_param_names_match_model(self, manifest):
        cfg = M.CONFIGS["tiny"]
        assert manifest["configs"]["tiny"]["param_names"] == [n for n, _ in cfg.param_specs()]
