"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

Hypothesis sweeps shapes/dtypes/block sizes; assert_allclose against the
oracle is the core correctness signal for the kernels that end up inside
the AOT HLO artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import flash_attention
from compile.kernels.fused_mlp import fused_rmsnorm_matmul

jax.config.update("jax_enable_x64", False)


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

class TestFlashAttention:
    @pytest.mark.parametrize("heads", [1, 2, 4])
    @pytest.mark.parametrize("seq", [64, 128])
    @pytest.mark.parametrize("d", [16, 32, 64])
    def test_matches_ref_causal(self, heads, seq, d):
        q, k, v = (rand(i, (heads, seq, d)) for i in range(3))
        out = flash_attention(q, k, v, causal=True)
        exp = ref.attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(out, exp, atol=2e-5, rtol=2e-5)

    @pytest.mark.parametrize("seq", [64, 128])
    def test_matches_ref_noncausal(self, seq):
        q, k, v = (rand(i + 10, (2, seq, 32)) for i in range(3))
        out = flash_attention(q, k, v, causal=False)
        exp = ref.attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(out, exp, atol=2e-5, rtol=2e-5)

    @pytest.mark.parametrize("block_q,block_k", [(16, 16), (32, 64), (64, 32), (128, 128)])
    def test_block_size_invariance(self, block_q, block_k):
        q, k, v = (rand(i + 20, (2, 128, 32)) for i in range(3))
        out = flash_attention(q, k, v, block_q=block_q, block_k=block_k)
        exp = ref.attention_ref(q, k, v)
        np.testing.assert_allclose(out, exp, atol=2e-5, rtol=2e-5)

    def test_causal_masks_future(self):
        """Perturbing future keys/values must not change earlier outputs."""
        q, k, v = (rand(i + 30, (1, 64, 16)) for i in range(3))
        out1 = flash_attention(q, k, v)
        k2 = k.at[:, 48:, :].set(99.0)
        v2 = v.at[:, 48:, :].set(-99.0)
        out2 = flash_attention(q, k2, v2)
        np.testing.assert_allclose(out1[:, :48], out2[:, :48], atol=1e-6)

    def test_first_row_attends_only_self(self):
        q, k, v = (rand(i + 40, (1, 64, 16)) for i in range(3))
        out = flash_attention(q, k, v)
        np.testing.assert_allclose(out[:, 0, :], v[:, 0, :], atol=1e-5, rtol=1e-5)

    def test_large_logit_stability(self):
        """Online softmax must survive large logits without overflow."""
        q = rand(50, (1, 64, 16), scale=30.0)
        k = rand(51, (1, 64, 16), scale=30.0)
        v = rand(52, (1, 64, 16))
        out = flash_attention(q, k, v)
        assert bool(jnp.all(jnp.isfinite(out)))
        exp = ref.attention_ref(q, k, v)
        np.testing.assert_allclose(out, exp, atol=1e-4, rtol=1e-4)

    @settings(max_examples=20, deadline=None)
    @given(
        heads=st.integers(1, 3),
        seq_pow=st.integers(4, 7),  # 16..128
        d=st.sampled_from([8, 16, 32]),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_shape_sweep(self, heads, seq_pow, d, seed):
        seq = 2 ** seq_pow
        q, k, v = (rand(seed + i, (heads, seq, d)) for i in range(3))
        bq = min(32, seq)
        out = flash_attention(q, k, v, block_q=bq, block_k=bq)
        exp = ref.attention_ref(q, k, v)
        np.testing.assert_allclose(out, exp, atol=3e-5, rtol=3e-5)

    def test_bfloat16(self):
        q, k, v = (rand(i + 60, (2, 64, 32), dtype=jnp.bfloat16) for i in range(3))
        out = flash_attention(q, k, v)
        exp = ref.attention_ref(q, k, v)
        assert out.dtype == jnp.bfloat16
        np.testing.assert_allclose(
            out.astype(jnp.float32), exp.astype(jnp.float32), atol=3e-2, rtol=3e-2
        )

    def test_rejects_bad_rank(self):
        with pytest.raises(AssertionError):
            flash_attention(jnp.zeros((64, 16)), jnp.zeros((64, 16)), jnp.zeros((64, 16)))


# ---------------------------------------------------------------------------
# fused rmsnorm + matmul
# ---------------------------------------------------------------------------

class TestFusedRmsnormMatmul:
    @pytest.mark.parametrize("m,d,n", [(64, 96, 256), (128, 64, 128), (64, 320, 512)])
    def test_matches_ref(self, m, d, n):
        x, g, w = rand(1, (m, d)), rand(2, (d,)), rand(3, (d, n))
        out = fused_rmsnorm_matmul(x, g, w)
        exp = ref.fused_rmsnorm_matmul_ref(x, g, w)
        np.testing.assert_allclose(out, exp, atol=1e-4, rtol=1e-4)

    @pytest.mark.parametrize("block_m,block_n", [(16, 32), (64, 64), (32, 128)])
    def test_block_size_invariance(self, block_m, block_n):
        x, g, w = rand(4, (64, 96)), rand(5, (96,)), rand(6, (96, 128))
        out = fused_rmsnorm_matmul(x, g, w, block_m=block_m, block_n=block_n)
        exp = ref.fused_rmsnorm_matmul_ref(x, g, w)
        np.testing.assert_allclose(out, exp, atol=1e-4, rtol=1e-4)

    def test_norm_scale_invariance(self):
        """RMSNorm output is invariant to uniform scaling of the input row."""
        x, g, w = rand(7, (32, 64)), rand(8, (64,)), rand(9, (64, 64))
        out1 = fused_rmsnorm_matmul(x, g, w)
        out2 = fused_rmsnorm_matmul(x * 7.5, g, w)
        np.testing.assert_allclose(out1, out2, atol=1e-3, rtol=1e-3)

    @settings(max_examples=20, deadline=None)
    @given(
        m=st.sampled_from([16, 32, 64]),
        d=st.sampled_from([32, 64, 128]),
        n=st.sampled_from([32, 64, 128, 256]),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_shape_sweep(self, m, d, n, seed):
        x, g, w = rand(seed, (m, d)), rand(seed + 1, (d,)), rand(seed + 2, (d, n))
        out = fused_rmsnorm_matmul(x, g, w, block_m=min(16, m), block_n=min(32, n))
        exp = ref.fused_rmsnorm_matmul_ref(x, g, w)
        np.testing.assert_allclose(out, exp, atol=1e-4, rtol=1e-4)

    def test_gamma_zero_gives_zero(self):
        x = rand(10, (32, 64))
        w = rand(11, (64, 32))
        out = fused_rmsnorm_matmul(x, jnp.zeros((64,)), w)
        np.testing.assert_allclose(out, jnp.zeros((32, 32)), atol=1e-7)


# ---------------------------------------------------------------------------
# oracle self-consistency
# ---------------------------------------------------------------------------

class TestOracles:
    def test_rmsnorm_unit_rms(self):
        x = rand(20, (16, 64), scale=3.0)
        y = ref.rmsnorm_ref(x, jnp.ones((64,)))
        rms = jnp.sqrt(jnp.mean(jnp.square(y), axis=-1))
        np.testing.assert_allclose(rms, jnp.ones(16), atol=1e-3, rtol=1e-3)

    def test_attention_rows_are_convex_combos(self):
        """Non-causal attention output rows lie in the convex hull of v rows."""
        q, k = rand(21, (1, 32, 16)), rand(22, (1, 32, 16))
        v = jnp.ones((1, 32, 16))
        out = ref.attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(out, jnp.ones_like(out), atol=1e-5)

    def test_swiglu_zero_input(self):
        wg, wu, wd = rand(23, (64, 128)), rand(24, (64, 128)), rand(25, (128, 64))
        out = ref.swiglu_ref(jnp.zeros((8, 64)), wg, wu, wd)
        np.testing.assert_allclose(out, jnp.zeros((8, 64)), atol=1e-7)
