//! Strategy-layer acceptance tests.
//!
//! * Parity: the default `mbo` strategy through the trait + engine is
//!   byte-identical across cold/warm/double runs (the refactor's
//!   load-bearing constraint).
//! * Racing quality: `halving` reaches ≥ 95% of the exhaustive oracle's
//!   dominated hypervolume on a small partition space while charging
//!   strictly fewer simulated profiling seconds than the multi-pass MBO.
//! * Isolation: different strategies never alias each other's `MboCache`
//!   entries.

use kareus::compose::optimize_all_partitions_with;
use kareus::engine::EngineConfig;
use kareus::frontier::{Frontier, Point};
use kareus::mbo::{
    exhaustive, optimize_partition, optimize_partition_with, HalvingParams, MboParams, MboResult,
    Pass, StrategyKind,
};
use kareus::paper::workloads::strategy_ablation_partition;
use kareus::partition::{Partition, SizeClass};
use kareus::profiler::{Profiler, ProfilerConfig};
use kareus::sim::gpu::GpuSpec;
use kareus::util::hash::fnv1a_str;

/// The pinned strategy-ablation partition (shared with `paper --exp
/// strategies`): medium size class, exactly 18 freqs × 10 SM choices × 2
/// viable launch timings = 360 candidates — small enough for the
/// exhaustive oracle, structured enough that search order matters.
fn small_partition() -> Partition {
    strategy_ablation_partition()
}

fn run_kind(kind: StrategyKind, seed: u64) -> MboResult {
    let gpu = GpuSpec::a100();
    let part = small_partition();
    let mut params = MboParams::for_class(part.size_class());
    params.seed = seed;
    let strategy = kind.build(params).expect("defaults validate");
    let mut prof = Profiler::new(gpu, ProfilerConfig::default(), seed);
    optimize_partition_with(strategy.as_ref(), &mut prof, &part, 8)
}

/// Exact bit-level signature of a result.
fn bits(r: &MboResult) -> (Vec<(u64, u64, usize)>, usize, u64) {
    let f = &r.frontier;
    (
        f.points().iter().map(|p| (p.time.to_bits(), p.energy.to_bits(), p.tag)).collect(),
        r.evaluated.len(),
        r.profiling_cost_s.to_bits(),
    )
}

/// Noise-free re-evaluation of a result's frontier schedules (the shared
/// definition also used by the published ablation table).
fn true_frontier(gpu: &GpuSpec, part: &Partition, r: &MboResult) -> Frontier {
    exhaustive::true_frontier(gpu, part, r)
}

#[test]
fn partition_space_is_the_intended_small_case() {
    let part = small_partition();
    assert_eq!(part.size_class(), SizeClass::Medium);
    let space = kareus::mbo::space::candidate_space(&GpuSpec::a100(), &part, 8);
    assert_eq!(space.len(), 360, "test geometry drifted; racing cost bounds assume 360");
}

#[test]
fn default_strategy_double_run_is_byte_identical() {
    // The CI strategy-parity smoke: two cold runs of the default `mbo`
    // strategy must agree bit-for-bit, and the engine path must agree
    // with the legacy `optimize_partition` entry point for the engine's
    // derived per-partition seed.
    let a = run_kind(StrategyKind::MultiPass, 2026);
    let b = run_kind(StrategyKind::MultiPass, 2026);
    assert_eq!(bits(&a), bits(&b));

    let gpu = GpuSpec::a100();
    let part = small_partition();
    let engine = EngineConfig::sequential();
    let seed = 17u64;
    let results = optimize_all_partitions_with(seed, &gpu, &[part.clone()], 8, &engine);
    let via_engine = results.get(&part.ptype).expect("partition optimized");
    let derived = seed ^ fnv1a_str(&part.ptype);
    let mut params = MboParams::for_class(part.size_class());
    params.seed = derived;
    let mut prof = Profiler::new(gpu, ProfilerConfig::default(), derived);
    let legacy = optimize_partition(&mut prof, &part, 8, &params);
    assert_eq!(bits(via_engine), bits(&legacy), "engine trait dispatch diverged from legacy path");
}

#[test]
fn halving_near_oracle_hv_at_lower_profiling_cost() {
    let gpu = GpuSpec::a100();
    let part = small_partition();
    let mbo = run_kind(StrategyKind::MultiPass, 2026);
    let halving = run_kind(StrategyKind::Halving(HalvingParams::default()), 2026);

    // Racing must be strictly cheaper in simulated profiling seconds —
    // screening probes included in its bill.
    assert!(
        halving.profiling_cost_s < mbo.profiling_cost_s,
        "halving {} s vs mbo {} s",
        halving.profiling_cost_s,
        mbo.profiling_cost_s
    );
    // Its full-fidelity measurement count is the survivor quota.
    assert_eq!(halving.evaluated.len(), HalvingParams::default().survivors);
    assert!(halving.evaluated.iter().all(|e| e.pass == Pass::Racing));

    // …and still reach ≥ 95% of the exhaustive oracle's dominated HV
    // (judged on noise-free re-evaluation of the selected schedules).
    let oracle = exhaustive::exhaustive_frontier(&gpu, &part, 8);
    let halving_true = true_frontier(&gpu, &part, &halving);
    let mut all: Vec<Point> = oracle.points().to_vec();
    all.extend(halving_true.points().iter().copied());
    let rref = Frontier::reference_of(&all);
    let hv_oracle = oracle.hypervolume(rref);
    let hv_halving = halving_true.hypervolume(rref);
    assert!(
        hv_halving >= 0.95 * hv_oracle,
        "halving hv {hv_halving} vs oracle {hv_oracle} ({:.3})",
        hv_halving / hv_oracle
    );
}

#[test]
fn exhaustive_strategy_measures_every_candidate() {
    let r = run_kind(StrategyKind::Exhaustive, 7);
    assert_eq!(r.evaluated.len(), r.n_candidates);
    assert_eq!(r.n_candidates, 360);
    assert!(r.frontier.len() >= 3);
    // Full coverage, no duplicates: every evaluated schedule is distinct.
    let distinct: std::collections::HashSet<_> = r.evaluated.iter().map(|e| e.sched).collect();
    assert_eq!(distinct.len(), r.n_candidates);
}

#[test]
fn random_search_respects_measurement_budget() {
    let r = run_kind(StrategyKind::Random, 5);
    let params = MboParams::for_class(small_partition().size_class());
    let budget = params.n_init + params.b_max * params.batch_k;
    assert_eq!(r.evaluated.len(), budget.min(360));
    assert!(r.evaluated.iter().all(|e| e.pass == Pass::Init));
    assert!(!r.frontier.is_empty());
    // Random is cheaper than exhaustive but not free.
    assert!(r.profiling_cost_s > 0.0);
}

#[test]
fn strategies_never_alias_cache_entries() {
    let gpu = GpuSpec::a100();
    let part = small_partition();
    let parts = [part.clone()];
    let engine = EngineConfig::sequential();
    let a = optimize_all_partitions_with(7, &gpu, &parts, 8, &engine);
    assert_eq!(engine.mbo_cache.len(), 1);

    // Same shared caches, different strategy: must occupy a second slot.
    let engine_h = engine.clone().with_strategy(StrategyKind::Halving(HalvingParams::default()));
    let b = optimize_all_partitions_with(7, &gpu, &parts, 8, &engine_h);
    assert_eq!(engine.mbo_cache.len(), 2, "strategies aliased one cache entry");
    assert_ne!(
        bits(a.get(&part.ptype).unwrap()),
        bits(b.get(&part.ptype).unwrap()),
        "mbo and halving produced identical bits — suspicious aliasing"
    );

    // Warm replays of each strategy stay byte-identical.
    let a2 = optimize_all_partitions_with(7, &gpu, &parts, 8, &engine);
    let b2 = optimize_all_partitions_with(7, &gpu, &parts, 8, &engine_h);
    assert_eq!(engine.mbo_cache.len(), 2);
    assert_eq!(bits(a.get(&part.ptype).unwrap()), bits(a2.get(&part.ptype).unwrap()));
    assert_eq!(bits(b.get(&part.ptype).unwrap()), bits(b2.get(&part.ptype).unwrap()));
}
