//! Strategy-layer acceptance tests.
//!
//! * Parity: the default `mbo` strategy through the trait + engine is
//!   byte-identical across cold/warm/double runs (the refactor's
//!   load-bearing constraint).
//! * Racing quality: `halving` reaches ≥ 95% of the exhaustive oracle's
//!   dominated hypervolume on a small partition space while charging
//!   strictly fewer simulated profiling seconds than the multi-pass MBO.
//! * Isolation: different strategies never alias each other's `MboCache`
//!   entries.

use kareus::compose::optimize_all_partitions_with;
use kareus::engine::EngineConfig;
use kareus::frontier::{Frontier, Point};
use kareus::mbo::{
    exhaustive, optimize_partition, optimize_partition_warm, optimize_partition_with,
    EvalContext, HalvingParams, MboParams, MboResult, Pass, StrategyKind,
};
use kareus::paper::workloads::strategy_ablation_partition;
use kareus::partition::{Partition, SizeClass};
use kareus::profiler::{Profiler, ProfilerConfig};
use kareus::sim::gpu::GpuSpec;
use kareus::util::hash::fnv1a_str;

/// The pinned strategy-ablation partition (shared with `paper --exp
/// strategies`): medium size class, exactly 18 freqs × 10 SM choices × 2
/// viable launch timings = 360 candidates — small enough for the
/// exhaustive oracle, structured enough that search order matters.
fn small_partition() -> Partition {
    strategy_ablation_partition()
}

fn run_kind(kind: StrategyKind, seed: u64) -> MboResult {
    let gpu = GpuSpec::a100();
    let part = small_partition();
    let mut params = MboParams::for_class(part.size_class());
    params.seed = seed;
    let strategy = kind.build(params).expect("defaults validate");
    let mut prof = Profiler::new(gpu, ProfilerConfig::default(), seed);
    optimize_partition_with(strategy.as_ref(), &mut prof, &part, 8)
}

/// Exact bit-level signature of a result.
fn bits(r: &MboResult) -> (Vec<(u64, u64, usize)>, usize, u64) {
    let f = &r.frontier;
    (
        f.points().iter().map(|p| (p.time.to_bits(), p.energy.to_bits(), p.tag)).collect(),
        r.evaluated.len(),
        r.profiling_cost_s.to_bits(),
    )
}

/// Noise-free re-evaluation of a result's frontier schedules (the shared
/// definition also used by the published ablation table).
fn true_frontier(gpu: &GpuSpec, part: &Partition, r: &MboResult) -> Frontier {
    exhaustive::true_frontier(gpu, part, r)
}

#[test]
fn partition_space_is_the_intended_small_case() {
    let part = small_partition();
    assert_eq!(part.size_class(), SizeClass::Medium);
    let space = kareus::mbo::space::candidate_space(&GpuSpec::a100(), &part, 8);
    assert_eq!(space.len(), 360, "test geometry drifted; racing cost bounds assume 360");
}

#[test]
fn default_strategy_double_run_is_byte_identical() {
    // The CI strategy-parity smoke: two cold runs of the default `mbo`
    // strategy must agree bit-for-bit, and the engine path must agree
    // with the legacy `optimize_partition` entry point for the engine's
    // derived per-partition seed.
    let a = run_kind(StrategyKind::MultiPass, 2026);
    let b = run_kind(StrategyKind::MultiPass, 2026);
    assert_eq!(bits(&a), bits(&b));

    let gpu = GpuSpec::a100();
    let part = small_partition();
    let engine = EngineConfig::sequential();
    let seed = 17u64;
    let results = optimize_all_partitions_with(seed, &gpu, &[part.clone()], 8, &engine);
    let via_engine = results.get(&part.ptype).expect("partition optimized");
    let derived = seed ^ fnv1a_str(&part.ptype);
    let mut params = MboParams::for_class(part.size_class());
    params.seed = derived;
    let mut prof = Profiler::new(gpu, ProfilerConfig::default(), derived);
    let legacy = optimize_partition(&mut prof, &part, 8, &params);
    assert_eq!(bits(via_engine), bits(&legacy), "engine trait dispatch diverged from legacy path");
}

/// The racer's exact simulated profiling bill, replayed from the ladder
/// arithmetic at test time: per-measurement cost is schedule-independent
/// (`setup + cooldown + warmup + window`, scaled by fidelity for probes),
/// and `pareto_survivors` returns exactly `keep` candidates for finite
/// probes — so the bill is a pure function of (n, HalvingParams, config),
/// not of noise. Mirrors `SuccessiveHalving::optimize`'s pool/fidelity
/// schedule, including the 1/2 screening-fidelity cap.
fn expected_halving_cost(n: usize, hp: &HalvingParams, cfg: &ProfilerConfig) -> f64 {
    const MAX_SCREEN_FIDELITY: f64 = 0.5;
    let full = cfg.setup_s + cfg.cooldown_s + cfg.warmup_s + cfg.window_s;
    let mut cost = 0.0;
    let mut alive = n;
    if n > hp.survivors {
        let mut fidelity = hp.base_fidelity.min(MAX_SCREEN_FIDELITY);
        while alive > hp.survivors {
            cost += alive as f64 * full * fidelity.clamp(0.01, 1.0);
            alive = (alive / hp.eta).max(hp.survivors);
            fidelity = (fidelity * hp.eta as f64).min(MAX_SCREEN_FIDELITY);
        }
    }
    cost + alive as f64 * full
}

#[test]
fn halving_near_oracle_hv_at_lower_profiling_cost() {
    let gpu = GpuSpec::a100();
    let part = small_partition();
    let mbo = run_kind(StrategyKind::MultiPass, 2026);
    let hp = HalvingParams::default();
    let halving = run_kind(StrategyKind::Halving(hp), 2026);

    // Cost margins are computed at test time, not hand-derived: the
    // racer's bill must equal the ladder arithmetic exactly, and must be
    // strictly cheaper than whatever the multi-pass MBO actually spent on
    // this run — screening probes included in the racer's bill.
    let expected = expected_halving_cost(360, &hp, &ProfilerConfig::default());
    assert!(
        (halving.profiling_cost_s - expected).abs() <= 1e-6 * expected,
        "halving billed {} s, ladder arithmetic predicts {expected} s",
        halving.profiling_cost_s
    );
    assert!(
        halving.profiling_cost_s < mbo.profiling_cost_s,
        "halving {} s vs mbo {} s",
        halving.profiling_cost_s,
        mbo.profiling_cost_s
    );
    // Its full-fidelity measurement count is the survivor quota (the
    // dedup bitmap can only shrink it below the quota, never above).
    assert!(halving.evaluated.len() <= hp.survivors && !halving.evaluated.is_empty());
    assert!(halving.evaluated.iter().all(|e| e.pass == Pass::Racing));

    // Quality margin, also computed from the exhaustive oracle at test
    // time instead of a pinned "95%": the racer judged candidates through
    // probes at the screening fidelity, so its selection is at worst as
    // good as an oracle frontier whose every point is degraded by the
    // *measured* probe-noise scale δ on this exact partition. δ is taken
    // as the worst relative probe deviation over the oracle frontier's
    // own schedules.
    let oracle = exhaustive::exhaustive_frontier(&gpu, &part, 8);
    let mut prof = Profiler::new(gpu.clone(), ProfilerConfig::default(), 77);
    let mut ctx = EvalContext::new(&mut prof, &part, 8);
    let mut delta = 0.0f64;
    for p in oracle.points() {
        let m = ctx.probe(p.tag, hp.base_fidelity);
        delta = delta.max((m.time_s - p.time).abs() / p.time);
        delta = delta.max((m.energy_j - p.energy).abs() / p.energy);
    }
    assert!(delta > 0.0 && delta < 1.0, "probe-noise scale {delta} out of range");

    let halving_true = true_frontier(&gpu, &part, &halving);
    let mut all: Vec<Point> = oracle.points().to_vec();
    all.extend(halving_true.points().iter().copied());
    let rref = Frontier::reference_of(&all);
    let degraded = Frontier::from_points(
        oracle
            .points()
            .iter()
            .map(|p| Point::new(p.time * (1.0 + delta), p.energy * (1.0 + delta), p.tag))
            .collect(),
    );
    let hv_oracle = oracle.hypervolume(rref);
    let hv_floor = degraded.hypervolume(rref);
    let hv_halving = halving_true.hypervolume(rref);
    assert!(hv_floor > 0.0 && hv_floor < hv_oracle, "degenerate noise floor {hv_floor}");
    assert!(
        hv_halving >= hv_floor,
        "halving hv {hv_halving} under the δ={delta:.3} noise floor {hv_floor} \
         (oracle {hv_oracle}, ratio {:.3})",
        hv_halving / hv_oracle
    );
}

#[test]
fn racing_never_measures_a_candidate_twice_at_full_fidelity() {
    // Regression for the double-measure path: the final full-fidelity
    // loop must consult the chosen-candidate bitmap, so no candidate is
    // ever measured at full fidelity twice — neither cold (survivor-pool
    // underflow) nor when the context was warm-started from a prior
    // search that already measured some survivors.
    let cold = run_kind(StrategyKind::Halving(HalvingParams::default()), 99);
    let distinct: std::collections::HashSet<_> =
        cold.evaluated.iter().map(|e| e.sched).collect();
    assert_eq!(distinct.len(), cold.evaluated.len(), "cold racer double-measured a candidate");

    let gpu = GpuSpec::a100();
    let part = small_partition();
    let mut params = MboParams::for_class(part.size_class());
    params.seed = 99;
    let strategy =
        StrategyKind::Halving(HalvingParams::default()).build(params).expect("defaults validate");
    let mut prof = Profiler::new(gpu, ProfilerConfig::default(), 100);
    let warm = optimize_partition_warm(strategy.as_ref(), &mut prof, &part, 8, &cold);
    let distinct: std::collections::HashSet<_> =
        warm.evaluated.iter().map(|e| e.sched).collect();
    assert_eq!(
        distinct.len(),
        warm.evaluated.len(),
        "warm-started racer measured a chosen candidate again at full fidelity"
    );
    // The carried-over survivors are skipped, so the warm continuation
    // can never bill more than the cold ladder.
    assert!(warm.profiling_cost_s <= cold.profiling_cost_s + 1e-9);
}

#[test]
fn warm_started_mbo_bills_measurably_fewer_measurements() {
    // The replanning runtime's warm-start contract at the strategy level:
    // continuing a search from a prior result skips the whole initial
    // design, so the new bill is bounded by the batch budget alone.
    let cold = run_kind(StrategyKind::MultiPass, 2026);
    let gpu = GpuSpec::a100();
    let part = small_partition();
    let mut params = MboParams::for_class(part.size_class());
    params.seed = 2026;
    let strategy = StrategyKind::MultiPass.build(params).expect("defaults validate");
    let mut prof = Profiler::new(gpu, ProfilerConfig::default(), 4040);
    let warm = optimize_partition_warm(strategy.as_ref(), &mut prof, &part, 8, &cold);
    let new_measurements = warm.evaluated.len() - cold.evaluated.len();
    assert!(
        new_measurements < cold.evaluated.len(),
        "warm continuation re-measured as much as the cold run ({new_measurements})"
    );
    assert!(
        warm.profiling_cost_s < 0.75 * cold.profiling_cost_s,
        "warm billed {} s vs cold {} s",
        warm.profiling_cost_s,
        cold.profiling_cost_s
    );
    // And never re-measures a carried-over candidate.
    let distinct: std::collections::HashSet<_> =
        warm.evaluated.iter().map(|e| e.sched).collect();
    assert_eq!(distinct.len(), warm.evaluated.len());
}

#[test]
fn exhaustive_strategy_measures_every_candidate() {
    let r = run_kind(StrategyKind::Exhaustive, 7);
    assert_eq!(r.evaluated.len(), r.n_candidates);
    assert_eq!(r.n_candidates, 360);
    assert!(r.frontier.len() >= 3);
    // Full coverage, no duplicates: every evaluated schedule is distinct.
    let distinct: std::collections::HashSet<_> = r.evaluated.iter().map(|e| e.sched).collect();
    assert_eq!(distinct.len(), r.n_candidates);
}

#[test]
fn random_search_respects_measurement_budget() {
    let r = run_kind(StrategyKind::Random, 5);
    let params = MboParams::for_class(small_partition().size_class());
    let budget = params.n_init + params.b_max * params.batch_k;
    assert_eq!(r.evaluated.len(), budget.min(360));
    assert!(r.evaluated.iter().all(|e| e.pass == Pass::Init));
    assert!(!r.frontier.is_empty());
    // Random is cheaper than exhaustive but not free.
    assert!(r.profiling_cost_s > 0.0);
}

#[test]
fn strategies_never_alias_cache_entries() {
    let gpu = GpuSpec::a100();
    let part = small_partition();
    let parts = [part.clone()];
    let engine = EngineConfig::sequential();
    let a = optimize_all_partitions_with(7, &gpu, &parts, 8, &engine);
    assert_eq!(engine.mbo_cache.len(), 1);

    // Same shared caches, different strategy: must occupy a second slot.
    let engine_h = engine.clone().with_strategy(StrategyKind::Halving(HalvingParams::default()));
    let b = optimize_all_partitions_with(7, &gpu, &parts, 8, &engine_h);
    assert_eq!(engine.mbo_cache.len(), 2, "strategies aliased one cache entry");
    assert_ne!(
        bits(a.get(&part.ptype).unwrap()),
        bits(b.get(&part.ptype).unwrap()),
        "mbo and halving produced identical bits — suspicious aliasing"
    );

    // Warm replays of each strategy stay byte-identical.
    let a2 = optimize_all_partitions_with(7, &gpu, &parts, 8, &engine);
    let b2 = optimize_all_partitions_with(7, &gpu, &parts, 8, &engine_h);
    assert_eq!(engine.mbo_cache.len(), 2);
    assert_eq!(bits(a.get(&part.ptype).unwrap()), bits(a2.get(&part.ptype).unwrap()));
    assert_eq!(bits(b.get(&part.ptype).unwrap()), bits(b2.get(&part.ptype).unwrap()));
}
