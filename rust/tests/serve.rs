//! Integration tests for the plan-serving daemon (`kareus::serve`): the
//! acceptance properties from the serve PR — wire plans byte-identical to
//! direct engine calls, cache hits that never re-enter the optimizer,
//! typed errors for malformed requests, graceful shutdown that drains
//! in-flight work, and deterministic loadgen reports.

use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use kareus::baselines::run_system_with;
use kareus::cluster::parse_job_spec;
use kareus::coordinator::{Coordinator, Target};
use kareus::engine::EngineConfig;
use kareus::serve::{
    run_loadgen, send_shutdown, ErrorCode, LoadgenConfig, PlanService, ServeConfig, ServeOptions,
    ServeRequest, ServeResponse, Server, MAX_REQUEST_LINE,
};
use kareus::util::json::Json;

/// Cheapest real job in the matrix: Megatron baseline, one frequency
/// sweep, no nanobatch search.
const JOB: &str = "a100:qwen1.7b:tp8pp2:megatron";

fn start(
    max_inflight: usize,
    threads: usize,
) -> (String, Arc<PlanService>, std::thread::JoinHandle<()>) {
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        threads,
        opts: ServeOptions { max_inflight, ..ServeOptions::default() },
    };
    let server = Server::bind(EngineConfig::sequential(), &cfg, |_| {}).expect("bind");
    let addr = server.local_addr().to_string();
    let service = server.service();
    let handle = std::thread::spawn(move || server.run().expect("serve run"));
    (addr, service, handle)
}

fn plan_line(job: &str, seed: u64) -> String {
    ServeRequest::Plan { job: job.to_string(), target: "max".to_string(), seed, strategy: None }
        .to_json()
        .dump()
}

/// One request over a fresh connection; returns the decoded response.
fn roundtrip(addr: &str, line: &str) -> ServeResponse {
    let stream = TcpStream::connect(addr).expect("connect");
    let mut writer = stream.try_clone().expect("clone");
    writer.write_all(format!("{line}\n").as_bytes()).expect("send");
    writer.flush().expect("flush");
    let mut reply = String::new();
    BufReader::new(stream).read_line(&mut reply).expect("read");
    ServeResponse::from_json(&Json::parse(reply.trim_end()).expect("response is JSON"))
        .expect("response decodes")
}

/// The same pipeline the server's miss path runs, executed directly.
fn direct_deployment_bytes(job: &str, seed: u64) -> String {
    let parsed = parse_job_spec(job, 8, 4096, 8, seed).expect("job spec");
    let sc = parsed.scenario;
    let engine = EngineConfig::sequential();
    let result = run_system_with(&sc.gpu, &sc.cfg, sc.system, sc.seed, &engine);
    let coord = Coordinator::new(sc.gpu.clone(), sc.cfg).with_engine(engine);
    let dep = coord.select(&result, Target::MaxThroughput).expect("feasible");
    dep.to_json().dump()
}

#[test]
fn concurrent_clients_get_byte_identical_plans_to_a_direct_engine_call() {
    let (addr, service, handle) = start(2, 4);
    let line = plan_line(JOB, 41);

    // Four clients race the same request; the server must coalesce them
    // onto one optimization.
    let clients: Vec<_> = (0..4)
        .map(|_| {
            let addr = addr.clone();
            let line = line.clone();
            std::thread::spawn(move || roundtrip(&addr, &line))
        })
        .collect();
    let responses: Vec<ServeResponse> =
        clients.into_iter().map(|c| c.join().expect("client")).collect();

    let expected = direct_deployment_bytes(JOB, 41);
    for resp in &responses {
        assert!(resp.is_ok(), "{resp:?}");
        let result = resp.result.as_ref().expect("ok responses carry a result");
        assert_eq!(
            result.get("deployment").expect("plan payload has a deployment").dump(),
            expected,
            "served plan differs from the direct engine call"
        );
        assert_eq!(result.get("job").and_then(Json::as_str), Some(JOB));
    }
    // Coalescing makes the split deterministic: one owner, three waiters.
    assert_eq!((service.misses(), service.hits()), (1, 3));

    send_shutdown(&addr).expect("shutdown");
    handle.join().expect("server thread");
}

#[test]
fn repeated_request_is_answered_from_the_cache() {
    let (addr, service, handle) = start(2, 2);
    let line = plan_line(JOB, 42);

    // One persistent connection, same request twice.
    let stream = TcpStream::connect(&addr).expect("connect");
    let mut writer = stream.try_clone().expect("clone");
    let mut reader = BufReader::new(stream);
    let mut ask = || {
        writer.write_all(format!("{line}\n").as_bytes()).expect("send");
        writer.flush().expect("flush");
        let mut reply = String::new();
        reader.read_line(&mut reply).expect("read");
        ServeResponse::from_json(&Json::parse(reply.trim_end()).unwrap()).unwrap()
    };
    let first = ask();
    assert!(first.is_ok());
    assert_eq!(first.cache_hit, Some(false));
    assert_eq!((service.misses(), service.hits()), (1, 0));

    let second = ask();
    assert!(second.is_ok());
    assert_eq!(second.cache_hit, Some(true), "repeat must be served from the plan cache");
    assert_eq!((service.misses(), service.hits()), (1, 1), "hit counter must increment");
    assert_eq!(
        first.result.unwrap().dump(),
        second.result.unwrap().dump(),
        "hit and miss paths must serve identical bytes"
    );

    send_shutdown(&addr).expect("shutdown");
    handle.join().expect("server thread");
}

#[test]
fn malformed_requests_get_typed_error_responses() {
    let (addr, _service, handle) = start(2, 2);

    // Garbage, wrong schema, unknown type, bad job spec: typed, no hang.
    let cases = [
        ("this is not json", ErrorCode::Parse),
        ("{\"serve\":\"nope\",\"version\":1,\"type\":\"plan\"}", ErrorCode::BadRequest),
        ("{\"serve\":\"kareus_serve\",\"version\":1,\"type\":\"frobnicate\"}", ErrorCode::BadRequest),
        (
            "{\"serve\":\"kareus_serve\",\"version\":1,\"type\":\"plan\",\"job\":\"not-a-job\"}",
            ErrorCode::BadRequest,
        ),
    ];
    for (line, want) in cases {
        let resp = roundtrip(&addr, line);
        assert_eq!(resp.status, "error", "{line}");
        assert_eq!(resp.code, Some(want), "{line}");
        assert!(resp.message.is_some(), "{line}");
    }

    // An oversized line gets a typed parse error, then the connection
    // closes (no way to resynchronize the stream).
    let stream = TcpStream::connect(&addr).expect("connect");
    let mut writer = stream.try_clone().expect("clone");
    let huge = "x".repeat(MAX_REQUEST_LINE + 1024);
    writer.write_all(huge.as_bytes()).expect("send");
    writer.write_all(b"\n").expect("send");
    writer.flush().expect("flush");
    let mut reader = BufReader::new(stream);
    let mut reply = String::new();
    reader.read_line(&mut reply).expect("read");
    let resp = ServeResponse::from_json(&Json::parse(reply.trim_end()).unwrap()).unwrap();
    assert_eq!(resp.code, Some(ErrorCode::Parse));
    assert!(resp.message.unwrap().contains("cap"));
    let mut rest = String::new();
    assert_eq!(reader.read_line(&mut rest).expect("eof"), 0, "connection must close");

    // A truncated request (EOF before the newline) is surfaced as a
    // typed parse error rather than silently dropped.
    let stream = TcpStream::connect(&addr).expect("connect");
    let mut writer = stream.try_clone().expect("clone");
    writer.write_all(b"{\"serve\":\"kareus_serve\",\"ver").expect("send");
    writer.flush().expect("flush");
    stream.shutdown(Shutdown::Write).expect("half-close");
    let mut reply = String::new();
    BufReader::new(stream).read_line(&mut reply).expect("read");
    let resp = ServeResponse::from_json(&Json::parse(reply.trim_end()).unwrap()).unwrap();
    assert_eq!(resp.status, "error");
    assert_eq!(resp.code, Some(ErrorCode::Parse));

    send_shutdown(&addr).expect("shutdown");
    handle.join().expect("server thread");
}

#[test]
fn graceful_shutdown_drains_in_flight_requests() {
    let (addr, service, handle) = start(2, 4);

    // Client A starts an expensive miss...
    let stream = TcpStream::connect(&addr).expect("connect");
    let mut writer = stream.try_clone().expect("clone");
    writer.write_all(format!("{}\n", plan_line(JOB, 43)).as_bytes()).expect("send");
    writer.flush().expect("flush");

    // ...wait until the optimizer actually owns it (miss counted)...
    let deadline = Instant::now() + Duration::from_secs(30);
    while service.misses() == 0 {
        assert!(Instant::now() < deadline, "optimization never started");
        std::thread::sleep(Duration::from_millis(10));
    }

    // ...then client B asks the server to shut down.
    send_shutdown(&addr).expect("shutdown");
    handle.join().expect("server drains before exiting");

    // A's in-flight request completed with a full response even though
    // the server exited: drain, not abort.
    let mut reply = String::new();
    BufReader::new(stream).read_line(&mut reply).expect("read");
    let resp = ServeResponse::from_json(&Json::parse(reply.trim_end()).unwrap()).unwrap();
    assert!(resp.is_ok(), "in-flight request must complete: {resp:?}");
    assert_eq!(resp.cache_hit, Some(false));
}

#[test]
fn zero_admission_returns_typed_busy_over_the_wire() {
    let (addr, service, handle) = start(0, 2);
    let resp = roundtrip(&addr, &plan_line(JOB, 44));
    assert_eq!(resp.status, "busy");
    assert_eq!(resp.code, Some(ErrorCode::Busy));
    assert_eq!((service.misses(), service.hits()), (0, 0), "busy path must not touch caches");
    send_shutdown(&addr).expect("shutdown");
    handle.join().expect("server thread");
}

#[test]
fn loadgen_deterministic_reports_are_byte_identical_and_check_clean() {
    let mut cold_reports = Vec::new();
    for _ in 0..2 {
        // Fresh server per run: same cold caches, same request mix.
        let (addr, _service, handle) = start(2, 4);
        let cfg = LoadgenConfig {
            addr: addr.clone(),
            requests: 4,
            concurrency: 2,
            jobs: vec![JOB.to_string()],
            target: "max".to_string(),
            seed: 45,
            deterministic: true,
        };
        let report = run_loadgen(&cfg).expect("loadgen");
        cold_reports.push((report.try_dump().expect("report dumps"), addr, handle, cfg));
    }
    let a = cold_reports[0].0.clone();
    let b = cold_reports[1].0.clone();
    assert_eq!(a, b, "deterministic loadgen reports must be byte-identical across runs");

    // Cold split: 1 distinct key → 1 miss, everything else coalesced/cached.
    let cold = Json::parse(&a).unwrap();
    assert_eq!(cold.get("ok").and_then(Json::as_f64), Some(4.0));
    assert_eq!(cold.get("misses").and_then(Json::as_f64), Some(1.0));
    assert_eq!(cold.get("hits").and_then(Json::as_f64), Some(3.0));
    assert_eq!(cold.get("hit_rate").and_then(Json::as_f64), Some(0.75));
    assert_eq!(cold.get("wall_s"), Some(&Json::Null), "deterministic mode nulls wall fields");
    assert_eq!(cold.get("addr"), Some(&Json::Null));

    // A second wave against a warm server hits on every request.
    let (_, addr, handle, cfg) = cold_reports.pop().unwrap();
    let warm = run_loadgen(&cfg).expect("warm loadgen");
    assert_eq!(warm.get("hits").and_then(Json::as_f64), Some(4.0));
    assert_eq!(warm.get("misses").and_then(Json::as_f64), Some(0.0));
    assert_eq!(warm.get("hit_rate").and_then(Json::as_f64), Some(1.0));

    // Both reports pass the static verifier with zero diagnostics.
    for report in [a.as_str(), warm.try_dump().unwrap().as_str()] {
        let checked = kareus::check::check_text(report, "loadgen", None);
        assert_eq!(checked.kind, "loadgen_report");
        assert!(checked.diagnostics.is_empty(), "{}", checked.to_text());
    }

    send_shutdown(&addr).expect("shutdown");
    handle.join().expect("server thread");
    // The first run's server is still listening; stop it too.
    let (_, addr, handle, _) = cold_reports.pop().unwrap();
    send_shutdown(&addr).expect("shutdown first server");
    handle.join().expect("first server thread");
}
