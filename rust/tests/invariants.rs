//! Property-based invariant tests (mini-proptest over the in-house PRNG —
//! proptest is unavailable offline). Each property samples hundreds of
//! random inputs and asserts a structural invariant of the simulator,
//! frontier algebra, scheduler, or composition layers.

use kareus::frontier::{Frontier, Point};
use kareus::partition::Partition;
use kareus::pipeline::{greedy_fill, simulate_1f1b, stage_order, StageMenu};
use kareus::profiler::Profiler;
use kareus::sim::exec::{execute_partition, LaunchAt, Schedule};
use kareus::sim::gpu::GpuSpec;
use kareus::sim::kernel::{Kernel, KernelKind};
use kareus::util::rng::Rng;

fn random_partition(rng: &mut Rng) -> Partition {
    let n = 1 + rng.below(5);
    let comps = (0..n)
        .map(|i| {
            if rng.f64() < 0.4 {
                Kernel::comp(format!("mem{i}"), KernelKind::Norm, 1e8, 5e8 + rng.f64() * 4e9)
            } else {
                Kernel::comp(
                    format!("comp{i}"),
                    KernelKind::Linear,
                    5e10 + rng.f64() * 8e11,
                    1e9 + rng.f64() * 2e9,
                )
            }
        })
        .collect();
    let comm = if rng.f64() < 0.85 {
        Some(Kernel::comm("ar", KernelKind::AllReduce, 5e7 + rng.f64() * 3e9))
    } else {
        None
    };
    Partition { ptype: "prop".into(), comps, comm, count: 1 }
}

fn random_schedule(rng: &mut Rng, n_comps: usize) -> Schedule {
    Schedule::uniform(
        1 + rng.below(30) as u32,
        LaunchAt::WithComp(rng.below(n_comps)),
        900 + 30 * rng.below(18) as u32,
    )
}

// ---------------------------------------------------------------------
// Simulator invariants
// ---------------------------------------------------------------------

#[test]
fn prop_exec_results_physical() {
    let gpu = GpuSpec::a100();
    let mut rng = Rng::new(1);
    for _ in 0..300 {
        let part = random_partition(&mut rng);
        let sched = random_schedule(&mut rng, part.comps.len());
        let r =
            execute_partition(&gpu, &part.comps, part.comm.as_ref(), &sched, 30.0, Some(gpu.tdp_w));
        assert!(r.time_s.is_finite() && r.time_s > 0.0);
        assert!(r.dyn_j >= 0.0 && r.static_j > 0.0);
        assert!(r.exposed_comm_s <= r.time_s + 1e-12);
        assert!(r.avg_freq_mhz <= sched.freq_mhz as f64 + 1e-9);
        // The controller throttles the *clock*; memory/interconnect power
        // is not frequency-gated, and the Jensen oscillation mixture can
        // transiently exceed the limit — allow a 10% excursion.
        assert!(r.peak_power_w <= gpu.tdp_w * 1.10 + 1.0, "peak {}", r.peak_power_w);
        // Static energy = static power × time exactly (fixed temp).
        let ps = gpu.static_power(30.0);
        assert!((r.static_j - ps * r.time_s).abs() < 1e-6 * r.static_j.max(1.0));
    }
}

#[test]
fn prop_overlap_bounded_by_resource_envelopes() {
    // An overlap schedule can be *much* slower than sequential when the
    // comm kernel is SM-starved (that is Figure 3a's pathology!), but it
    // is always bounded above by "each stream at its own allocated rate,
    // serialized", and below by the best single-resource lower bound.
    let gpu = GpuSpec::a100();
    let mut rng = Rng::new(2);
    for _ in 0..200 {
        let part = random_partition(&mut rng);
        let sched = random_schedule(&mut rng, part.comps.len());
        let r = execute_partition(&gpu, &part.comps, part.comm.as_ref(), &sched, 30.0, None);
        // Upper bound: compute stream with its reduced SMs, then comm at
        // its own SM-limited rate, fully serialized, + launch overheads.
        let comp_sms = gpu.n_sms - sched.comm_sms;
        let t_comp_slow: f64 = part
            .comps
            .iter()
            .map(|k| {
                let t_flops = k.flops / gpu.flop_rate(comp_sms, sched.freq_mhz);
                t_flops.max(k.bytes / (gpu.mem_bw * 0.3))
            })
            .sum();
        let t_comm_slow = part
            .comm
            .as_ref()
            .map(|c| c.comm_bytes / gpu.comm_bw(sched.comm_sms) + c.bytes / (gpu.mem_bw * 0.3))
            .unwrap_or(0.0);
        let upper = t_comp_slow + t_comm_slow + 1e-4;
        assert!(r.time_s <= upper, "overlap {} > envelope {}", r.time_s, upper);
        // Lower bound: compute-stream work at full SMs, and comm at link.
        let t_comp: f64 = part
            .comps
            .iter()
            .map(|k| (k.flops / gpu.flop_rate(gpu.n_sms, sched.freq_mhz)).max(k.bytes / gpu.mem_bw))
            .sum();
        let t_comm = part.comm.as_ref().map(|c| c.comm_bytes / gpu.link_bw).unwrap_or(0.0);
        assert!(r.time_s >= t_comp.max(t_comm) * 0.999, "{} < {}", r.time_s, t_comp.max(t_comm));
    }
}

#[test]
fn prop_dynamic_energy_monotone_in_frequency() {
    let gpu = GpuSpec::a100();
    let mut rng = Rng::new(3);
    for _ in 0..100 {
        let part = random_partition(&mut rng);
        let mk = |f: u32| {
            execute_partition(
                &gpu,
                &part.comps,
                part.comm.as_ref(),
                &Schedule::uniform(12, LaunchAt::WithComp(0), f),
                30.0,
                None,
            )
        };
        let lo = mk(900);
        let hi = mk(1410);
        assert!(lo.dyn_j <= hi.dyn_j * 1.001, "dyn {} vs {}", lo.dyn_j, hi.dyn_j);
        // Note: total TIME is deliberately NOT asserted monotone — §3.2.3:
        // lowering frequency reduces the compute stream's HBM demand, so
        // an overlapped comm kernel can run *faster* at lower clocks. Only
        // the compute-only case must slow down.
        let lo_solo = execute_partition(
            &gpu,
            &part.comps,
            None,
            &Schedule::uniform(0, LaunchAt::WithComp(0), 900),
            30.0,
            None,
        );
        let hi_solo = execute_partition(
            &gpu,
            &part.comps,
            None,
            &Schedule::uniform(0, LaunchAt::WithComp(0), 1410),
            30.0,
            None,
        );
        assert!(lo_solo.time_s >= hi_solo.time_s * 0.999);
    }
}

// ---------------------------------------------------------------------
// Frontier algebra invariants
// ---------------------------------------------------------------------

#[test]
fn prop_frontier_no_point_dominates_another() {
    let mut rng = Rng::new(4);
    for _ in 0..200 {
        let pts: Vec<Point> = (0..50)
            .map(|i| Point::new(rng.range_f64(1.0, 10.0), rng.range_f64(1.0, 10.0), i))
            .collect();
        let f = Frontier::from_points(pts.clone());
        for a in f.points() {
            for b in f.points() {
                if a.tag != b.tag {
                    assert!(!a.dominates(b), "{a:?} dominates {b:?}");
                }
            }
        }
        // Every input point is dominated-or-equal by some frontier point.
        for p in &pts {
            assert!(f
                .points()
                .iter()
                .any(|q| q.dominates(p) || (q.time == p.time && q.energy == p.energy)));
        }
    }
}

#[test]
fn prop_incremental_insert_equals_batch_build() {
    let mut rng = Rng::new(5);
    for _ in 0..100 {
        let pts: Vec<Point> = (0..40)
            .map(|i| Point::new(rng.range_f64(0.0, 5.0), rng.range_f64(0.0, 5.0), i))
            .collect();
        let batch = Frontier::from_points(pts.clone());
        let mut inc = Frontier::new();
        for p in pts {
            inc.insert(p);
        }
        let a: Vec<(f64, f64)> = batch.points().iter().map(|p| (p.time, p.energy)).collect();
        let b: Vec<(f64, f64)> = inc.points().iter().map(|p| (p.time, p.energy)).collect();
        assert_eq!(a, b);
    }
}

#[test]
fn prop_hypervolume_monotone_and_bounded() {
    let mut rng = Rng::new(6);
    for _ in 0..100 {
        let pts: Vec<Point> = (0..20)
            .map(|i| Point::new(rng.range_f64(0.1, 1.0), rng.range_f64(0.1, 1.0), i))
            .collect();
        let f = Frontier::from_points(pts);
        let r = (2.0, 2.0);
        let hv = f.hypervolume(r);
        assert!(hv >= 0.0 && hv <= 4.0);
        // Adding any point never decreases HV.
        let mut f2 = f.clone();
        f2.insert(Point::new(rng.range_f64(0.1, 1.0), rng.range_f64(0.1, 1.0), 99));
        assert!(f2.hypervolume(r) >= hv - 1e-12);
    }
}

// ---------------------------------------------------------------------
// Pipeline invariants
// ---------------------------------------------------------------------

fn random_menu(rng: &mut Rng, n_pts: usize) -> StageMenu {
    use kareus::compose::{MbFrontier, MbPoint, MicrobatchPlan};
    let mk = |rng: &mut Rng| {
        let mut t = rng.range_f64(0.5, 1.0);
        let mut e = rng.range_f64(200.0, 400.0);
        let pts: Vec<MbPoint> = (0..n_pts)
            .map(|_| {
                t += rng.range_f64(0.01, 0.2);
                e -= rng.range_f64(5.0, 40.0);
                MbPoint {
                    time_s: t,
                    total_j: e.max(1.0),
                    dyn_j: e.max(1.0) * 0.7,
                    plan: MicrobatchPlan {
                        freq_mhz: 1410,
                        configs: Default::default(),
                        sequential: true,
                    },
                }
            })
            .collect();
        MbFrontier::from_points(pts)
    };
    let f = mk(rng);
    let b = mk(rng);
    StageMenu::from_frontiers(&f, &b)
}

#[test]
fn prop_1f1b_makespan_lower_bounds() {
    let mut rng = Rng::new(7);
    for _ in 0..100 {
        let n_stages = 2 + rng.below(4);
        let n_mb = 2 + rng.below(8);
        let menus: Vec<StageMenu> = (0..n_stages).map(|_| random_menu(&mut rng, 4)).collect();
        let choice = vec![vec![0usize; 2 * n_mb]; n_stages];
        let (t, busy) = simulate_1f1b(&menus, &choice, n_mb);
        // Makespan ≥ any single stage's busy time, and ≥ the pipeline-fill
        // lower bound (first fwd chain + last bwd chain).
        for b in &busy {
            assert!(t >= *b - 1e-9, "makespan {t} < busy {b}");
        }
        assert!(t > 0.0);
    }
}

#[test]
fn prop_stage_order_valid_1f1b() {
    let mut rng = Rng::new(8);
    for _ in 0..200 {
        let n_stages = 1 + rng.below(8);
        let n_mb = 1 + rng.below(16);
        for s in 0..n_stages {
            let order = stage_order(s, n_stages, n_mb);
            assert_eq!(order.len(), 2 * n_mb);
            // fwd i precedes bwd i on the same stage; fwds in order.
            let pos = |mb: usize, bwd: bool| {
                order.iter().position(|t| t.mb == mb && t.is_bwd == bwd).unwrap()
            };
            for mb in 0..n_mb {
                assert!(pos(mb, false) < pos(mb, true));
                if mb > 0 {
                    assert!(pos(mb - 1, false) < pos(mb, false));
                    assert!(pos(mb - 1, true) < pos(mb, true));
                }
            }
        }
    }
}

#[test]
fn prop_greedy_fill_respects_deadline_and_improves() {
    let mut rng = Rng::new(9);
    for _ in 0..40 {
        let n_stages = 2 + rng.below(3);
        let n_mb = 2 + rng.below(6);
        let menus: Vec<StageMenu> = (0..n_stages).map(|_| random_menu(&mut rng, 5)).collect();
        let tight = greedy_fill(&menus, n_mb, 90.0, 0.0);
        let deadline = tight.time_s * rng.range_f64(1.05, 1.6);
        let plan = greedy_fill(&menus, n_mb, 90.0, deadline);
        assert!(plan.time_s <= deadline * (1.0 + 1e-6), "{} > {}", plan.time_s, deadline);
        assert!(plan.total_j <= tight.total_j + 1e-9);
        assert!(plan.bubble_s >= -1e-9);
    }
}

// ---------------------------------------------------------------------
// Profiler invariant: measurement tracks ground truth
// ---------------------------------------------------------------------

#[test]
fn prop_profiler_tracks_truth_within_tolerance() {
    let gpu = GpuSpec::a100();
    let mut rng = Rng::new(10);
    let mut prof = Profiler::new(gpu.clone(), Default::default(), 11);
    for _ in 0..15 {
        let part = random_partition(&mut rng);
        let sched = random_schedule(&mut rng, part.comps.len());
        let m = prof.measure(&part, &sched);
        let truth = Profiler::true_eval(&gpu, &part, &sched);
        assert!((m.time_s - truth.time_s).abs() / truth.time_s < 0.05);
        // Profiled energy runs at load temperature (leakage above the
        // reference-temperature truth) plus counter quantization noise.
        assert!(
            (m.energy_j - truth.energy_j).abs() / truth.energy_j < 0.20,
            "energy {} vs truth {}",
            m.energy_j,
            truth.energy_j
        );
    }
}
