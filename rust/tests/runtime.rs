//! Online-replanning acceptance tests.
//!
//! * Under the pinned mid-run scenario (straggler + cap drop) the
//!   drift-triggered replanner's total (time, energy) strictly dominates
//!   the static plan and lands within 5% of the oracle-replan reference —
//!   the same comparison `kareus paper --exp replanning` prints.
//! * Warm-started replans bill measurably fewer backend measurements
//!   than a cold re-optimization (shared `MboCache`/`MeasureCache`).
//! * The typed `RevisionLog` JSON is byte-deterministic and round-trips.

use kareus::baselines::System;
use kareus::engine::EngineConfig;
use kareus::plan::{ReplanTrigger, RevisionLog};
use kareus::runtime::{
    replanning_scenario, run_replanning_comparison, LoopConfig, ReplanPolicy, ReplanningComparison,
    TrainingLoop,
};
use kareus::sim::gpu::GpuSpec;
use kareus::util::json::Json;
use kareus::workload::{ModelSpec, Parallelism, TrainConfig};

use std::sync::OnceLock;

fn cfg() -> TrainConfig {
    TrainConfig {
        model: ModelSpec::qwen3_1_7b(),
        par: Parallelism::new(8, 1, 2),
        microbatch: 8,
        seq_len: 4096,
        n_microbatches: 8,
        dtype_bytes: 2,
    }
}

const SYSTEM: System = System::MegatronPerseus;
const N_ITERS: u64 = 300;
const SEED: u64 = 11;

/// Shared fixture: one engine (so later runs replay the first run's
/// caches warm), the pinned scenario, and all three policy runs.
fn fixture() -> &'static (EngineConfig, LoopConfig, ReplanningComparison) {
    static FIX: OnceLock<(EngineConfig, LoopConfig, ReplanningComparison)> = OnceLock::new();
    FIX.get_or_init(|| {
        let gpu = GpuSpec::a100();
        // The scenario probe runs on a throwaway engine so the
        // comparison's first (static) run genuinely cold-starts the
        // shared caches — that cold bill is the warm-replan reference.
        let probe_engine = EngineConfig::default();
        let scenario = replanning_scenario(&gpu, &cfg(), SYSTEM, &probe_engine, N_ITERS, SEED)
            .expect("scenario builds");
        let engine = EngineConfig::default();
        let cmp = run_replanning_comparison(&gpu, &cfg(), SYSTEM, &engine, &scenario)
            .expect("comparison runs");
        (engine, scenario, cmp)
    })
}

#[test]
fn drift_replanner_dominates_static_and_matches_oracle() {
    let (_, _, cmp) = fixture();
    let (st, dr, or) = (&cmp.static_run, &cmp.drift_run, &cmp.oracle_run);

    // The stale static plan gets board-throttled once the cap drops; the
    // reactive policies re-select an in-cap point at the boundary and are
    // never throttled.
    assert!(st.throttled_iters > 0, "scenario cap never bound the static plan");
    assert_eq!(dr.throttled_iters, 0, "drift policy ran a throttled (out-of-cap) plan");
    assert_eq!(or.throttled_iters, 0);

    // Strict Pareto domination of the static plan in run totals.
    assert!(
        dr.total_time_s < st.total_time_s,
        "drift {} s not faster than static {} s",
        dr.total_time_s,
        st.total_time_s
    );
    assert!(
        dr.total_energy_j < st.total_energy_j,
        "drift {} J not cheaper than static {} J",
        dr.total_energy_j,
        st.total_energy_j
    );

    // Within 5% of the oracle-replan reference on both totals.
    let within = |a: f64, b: f64| (a - b).abs() <= 0.05 * b;
    assert!(
        within(dr.total_time_s, or.total_time_s),
        "drift time {} vs oracle {}",
        dr.total_time_s,
        or.total_time_s
    );
    assert!(
        within(dr.total_energy_j, or.total_energy_j),
        "drift energy {} vs oracle {}",
        dr.total_energy_j,
        or.total_energy_j
    );

    // Revision accounting: static never replans; the drift policy fires
    // both a cap-boundary re-selection and at least one monitor-triggered
    // replan; the oracle reacts at its injected boundaries.
    assert_eq!(st.replans, 0);
    assert_eq!(st.revisions.revisions.len(), 1);
    assert_eq!(st.revisions.revisions[0].trigger, ReplanTrigger::Initial);
    assert!(dr.replans >= 2, "drift policy replanned only {} times", dr.replans);
    let triggers: Vec<ReplanTrigger> =
        dr.revisions.revisions.iter().map(|r| r.trigger).collect();
    assert!(triggers.contains(&ReplanTrigger::CapBoundary), "{triggers:?}");
    assert!(triggers.contains(&ReplanTrigger::Drift), "{triggers:?}");
    assert!(or.replans >= 2);
    assert!(or
        .revisions
        .revisions
        .iter()
        .any(|r| r.trigger == ReplanTrigger::Oracle));
}

#[test]
fn warm_replans_bill_measurably_fewer_measurements_than_cold() {
    let (_, _, cmp) = fixture();
    // The static run cold-started the shared caches: its initial
    // optimization is the cold-re-optimization reference.
    let cold = cmp.static_run.revisions.revisions[0].measurements_billed;
    assert!(cold > 0, "cold optimization must consult the backend");
    // Every drift-policy revision — including its initial plan, which ran
    // on the already-warm engine — replays from the caches.
    for r in &cmp.drift_run.revisions.revisions {
        assert!(
            r.measurements_billed < cold,
            "revision {} ({}): billed {} not below cold {}",
            r.revision,
            r.trigger.as_str(),
            r.measurements_billed,
            cold
        );
    }
    // Monitor-triggered replans re-run the optimizer end to end and still
    // bill zero: pure cache replay.
    let drift_replans: Vec<_> = cmp
        .drift_run
        .revisions
        .revisions
        .iter()
        .filter(|r| r.trigger == ReplanTrigger::Drift)
        .collect();
    assert!(!drift_replans.is_empty());
    assert!(drift_replans.iter().all(|r| r.measurements_billed == 0));
    assert!(cmp.drift_run.measurements_billed < cold);
}

#[test]
fn revision_log_is_byte_deterministic_and_roundtrips() {
    let (engine, scenario, cmp) = fixture();
    // A fresh drift run on the same (warm) engine must reproduce the
    // fixture's drift run byte-for-byte — cache hits are bit-identical
    // replays, and the log schema carries no wall-clock state.
    let again = TrainingLoop::new(GpuSpec::a100(), cfg(), SYSTEM, engine.clone())
        .with_loop_config(LoopConfig { policy: ReplanPolicy::Drift, ..scenario.clone() })
        .run()
        .expect("rerun");
    let (a, b) = (cmp.drift_run.revisions.to_json().dump(), again.revisions.to_json().dump());
    assert_eq!(a, b, "two identical drift runs dumped different revision logs");
    assert_eq!(
        cmp.drift_run.to_json().dump(),
        again.to_json().dump(),
        "summary JSON diverged across identical runs"
    );

    let back = RevisionLog::from_json(&Json::parse(&a).unwrap()).unwrap();
    assert_eq!(back, cmp.drift_run.revisions, "RevisionLog JSON round-trip diverged");
    assert_eq!(back.to_json().dump(), a, "re-dump after round-trip diverged");

    // Schema spot checks: every revision carries a deployable typed plan.
    let parsed = Json::parse(&a).unwrap();
    assert_eq!(parsed.get("log").unwrap().as_str(), Some("kareus_revisions"));
    for r in &back.revisions {
        assert_eq!(
            r.plan.n_slots(),
            cfg().par.pp as usize * 2 * cfg().n_microbatches as usize,
            "revision {} plan slot count",
            r.revision
        );
    }
}

#[test]
fn static_policy_without_events_matches_plan_exactly_at_reference_temp() {
    // Sanity anchor for the observation model: no drift, no cap, and a
    // run long enough to warm the die — totals exceed the plan only
    // through thermal leakage, and monotonically so.
    let gpu = GpuSpec::a100();
    let engine = EngineConfig::default();
    let lc = LoopConfig {
        n_iters: 50,
        policy: ReplanPolicy::Static,
        seed: SEED,
        ..Default::default()
    };
    let run = TrainingLoop::new(gpu, cfg(), SYSTEM, engine)
        .with_loop_config(lc)
        .run()
        .expect("runs");
    let planned = &run.revisions.revisions[0];
    // Time is exact: nothing stretches it without drift or throttling.
    let expected_time = planned.iter_time_s * 50.0;
    assert!(
        (run.total_time_s - expected_time).abs() < 1e-9 * expected_time,
        "time {} vs planned {expected_time}",
        run.total_time_s
    );
    // Energy is bounded below by the plan (leakage only adds) and the die
    // ends warmer than ambient.
    assert!(run.total_energy_j >= planned.iter_energy_j * 50.0 - 1e-9);
    assert!(run.final_temp_c > 25.0);
    assert_eq!(run.replans, 0);
    assert!(!run.revisions.revisions.is_empty());
}
