//! Backend acceptance tests: a sweep recorded through `TraceBackend`
//! replays to byte-identical BENCH JSON with the simulator disabled, the
//! shared `MeasureCache` sees the identical probe sequence in both runs,
//! and an explicit `SimBackend` engine is bit-identical to the default
//! path.

use std::sync::Arc;

use kareus::backend::{ExecutionBackend, SimBackend, TraceBackend};
use kareus::baselines::System;
use kareus::engine::{run_sweep, scenario_matrix, sweep_json, EngineConfig, Scenario};
use kareus::sim::gpu::GpuSpec;
use kareus::workload::{ModelSpec, Parallelism};

/// A small but multi-system scenario matrix: sequential-model and
/// overlapped-model paths both exercise the backend seam, without the
/// cost of a full Kareus MBO run (covered by `tests/engine.rs`).
fn scenarios() -> Vec<Scenario> {
    scenario_matrix(
        &[GpuSpec::a100()],
        &[ModelSpec::qwen3_1_7b()],
        &[Parallelism::new(8, 1, 2)],
        &[System::MegatronPerseus, System::Nanobatching],
        8,
        4096,
        8,
        11,
    )
}

fn frontier_bits(outcomes: &[kareus::engine::ScenarioOutcome]) -> Vec<Vec<(u64, u64)>> {
    outcomes
        .iter()
        .map(|o| {
            o.result
                .frontier
                .points()
                .iter()
                .map(|p| (p.time.to_bits(), p.energy.to_bits()))
                .collect()
        })
        .collect()
}

#[test]
fn trace_record_then_replay_reproduces_sweep_bytes() {
    let path = std::env::temp_dir()
        .join(format!("kareus_sweep_trace_{}.json", std::process::id()));
    let _ = std::fs::remove_file(&path);

    // Reference: the plain simulator engine.
    let engine_sim = EngineConfig::new().with_threads(1).with_backend(Arc::new(SimBackend));
    let out_sim = run_sweep(scenarios(), &engine_sim, |_| {});

    // Record: trace wraps the simulator and must not perturb results.
    let rec = Arc::new(TraceBackend::open(&path).unwrap());
    assert!(!rec.is_replay() && rec.caps().live);
    let engine_rec = EngineConfig::new().with_threads(1).with_backend(rec.clone());
    let out_rec = run_sweep(scenarios(), &engine_rec, |_| {});
    assert_eq!(
        frontier_bits(&out_sim),
        frontier_bits(&out_rec),
        "recording through the trace backend changed results"
    );
    let json_rec = sweep_json(&out_rec, &engine_rec, true).dump();
    rec.save().unwrap();
    assert!(!rec.is_empty(), "record run captured no measurements");
    let hits_rec = engine_rec.measure_cache.hits();
    let misses_rec = engine_rec.measure_cache.misses();
    assert!(misses_rec > 0, "record run never reached the backend");
    assert!(hits_rec > 0, "shared cache never hit during the record run");

    // Replay: answered exclusively from the trace (no live measurement
    // path exists in replay mode — a miss would panic, not simulate).
    let rep = Arc::new(TraceBackend::open(&path).unwrap());
    assert!(rep.is_replay());
    assert!(!rep.caps().live, "replay backend must not claim live measurement");
    let engine_rep = EngineConfig::new().with_threads(1).with_backend(rep.clone());
    let out_rep = run_sweep(scenarios(), &engine_rep, |_| {});
    let json_rep = sweep_json(&out_rep, &engine_rep, true).dump();
    assert_eq!(json_rec, json_rep, "trace replay diverged from the recorded sweep JSON");
    assert!(rep.replayed() > 0);

    // The memo cache sits above the backend: both runs issue the identical
    // probe sequence, so the hit/miss counters replay exactly, and every
    // replay-run miss was served from the trace.
    assert_eq!(hits_rec, engine_rep.measure_cache.hits(), "cache hit pattern diverged");
    assert_eq!(misses_rec, engine_rep.measure_cache.misses(), "cache miss pattern diverged");
    assert_eq!(rep.replayed(), misses_rec, "replay served probes outside the cache-miss path");

    let _ = std::fs::remove_file(&path);
}

#[test]
fn explicit_sim_backend_matches_default_engine() {
    // The default engine and an explicitly-constructed SimBackend engine
    // are the same measurement source.
    let default_engine = EngineConfig::new().with_threads(1);
    let explicit = EngineConfig::new().with_threads(1).with_backend(Arc::new(SimBackend));
    assert_eq!(default_engine.backend.fingerprint(), explicit.backend.fingerprint());
    let a = run_sweep(scenarios(), &default_engine, |_| {});
    let b = run_sweep(scenarios(), &explicit, |_| {});
    assert_eq!(frontier_bits(&a), frontier_bits(&b));
}
