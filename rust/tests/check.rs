//! Integration tests for the static artifact verifier: every diagnostic
//! code has a seeded negative fixture that must trip it, clean fixtures
//! must stay clean, double runs must be byte-identical, and the CLI exit
//! codes must follow the 0/1/2 convention.

use std::path::PathBuf;

use kareus::check::{check_file, Code, Severity};
use kareus::sim::gpu::GpuSpec;
use kareus::util::json::Json;

fn fixture(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name)
}

/// (fixture, the code it was seeded to trip). The fixture trips at least
/// that code; error-ness follows the code's own severity.
const SEEDED: &[(&str, Code)] = &[
    ("plan_k001_slot_count.json", Code::K001),
    ("plan_k002_slot_order.json", Code::K002),
    ("plan_k003_freq_range.json", Code::K003),
    ("plan_k004_off_grid.json", Code::K004),
    ("plan_k005_sm_oversub.json", Code::K005),
    ("plan_k006_seq_conflict.json", Code::K006),
    ("plan_k007_negative_bubble.json", Code::K007),
    ("cluster_k008_unknown_gpu.json", Code::K008),
    ("cluster_k010_over_cap.json", Code::K010),
    ("cluster_k011_sum_mismatch.json", Code::K011),
    ("cluster_k012_timeline.json", Code::K012),
    ("cluster_k013_missing_job.json", Code::K013),
    ("cluster_k014_bad_index.json", Code::K014),
    ("cluster_k015_stats_mismatch.json", Code::K015),
    ("cluster_k016_menu_order.json", Code::K016),
    ("revisions_k020_counter.json", Code::K020),
    ("revisions_k021_time_travel.json", Code::K021),
    ("revisions_k022_first_trigger.json", Code::K022),
    ("revisions_k023_cap_null.json", Code::K023),
    ("revisions_k024_over_cap.json", Code::K024),
    ("revisions_k030_version.json", Code::K030),
    ("trace_k030_version.json", Code::K030),
    ("trace_k031_bad_key.json", Code::K031),
    ("trace_k032_bad_entry.json", Code::K032),
    ("trace_k033_dup_key.json", Code::K033),
    ("trace_k034_freq_exceeds.json", Code::K034),
    ("sweep_k041_bad_point.json", Code::K041),
    ("sweep_k042_not_pareto.json", Code::K042),
    ("summary_k050_missing_field.json", Code::K050),
    ("summary_k051_replan_count.json", Code::K051),
    ("loadgen_k060_missing_field.json", Code::K060),
    ("loadgen_k061_counter_mismatch.json", Code::K061),
    ("loadgen_k062_percentile_order.json", Code::K062),
    ("loadgen_k063_mixed_nulling.json", Code::K063),
    ("plan_k070_mem_off_grid.json", Code::K070),
    ("trace_k071_uniform_transitions.json", Code::K071),
    ("plan_k072_mem_above_core.json", Code::K072),
    ("bench_k080_missing_field.json", Code::K080),
    ("bench_k081_mixed_nulling.json", Code::K081),
    ("bench_k082_median_lt_min.json", Code::K082),
    ("unknown_k000.json", Code::K000),
];

const CLEAN: &[&str] = &[
    "plan_ok.json",
    "plan_kernel_ok.json",
    "cluster_ok.json",
    "revisions_ok.json",
    "trace_ok.json",
    "trace_kernel_ok.json",
    "sweep_ok.json",
    "summary_ok.json",
    "loadgen_ok.json",
    "bench_ok.json",
];

fn gpu_for(name: &str) -> Option<GpuSpec> {
    // Plan and revision fixtures target the A100 range; cluster plans
    // name their GPU per job and the rest need none.
    if name.starts_with("plan_") || name.starts_with("revisions_") {
        Some(GpuSpec::a100())
    } else {
        None
    }
}

#[test]
fn every_seeded_fixture_trips_its_code() {
    for (name, code) in SEEDED {
        let report = check_file(&fixture(name), gpu_for(name).as_ref()).unwrap();
        let codes: Vec<Code> = report.diagnostics.iter().map(|x| x.code).collect();
        assert!(codes.contains(code), "{name}: expected {:?} in {codes:?}", code);
        if code.severity() == Severity::Error {
            assert!(report.has_errors(), "{name}: {code:?} is an error code");
        } else {
            // Warn-seeded fixtures are otherwise valid documents.
            assert!(!report.has_errors(), "{name}: {}", report.to_text());
        }
    }
}

#[test]
fn seeded_codes_cover_at_least_ten_distinct() {
    let mut distinct: Vec<&str> = SEEDED.iter().map(|(_, c)| c.as_str()).collect();
    distinct.sort();
    distinct.dedup();
    assert!(distinct.len() >= 10, "only {} distinct codes seeded", distinct.len());
}

#[test]
fn clean_fixtures_have_no_diagnostics() {
    for name in CLEAN {
        let report = check_file(&fixture(name), gpu_for(name).as_ref()).unwrap();
        assert!(report.diagnostics.is_empty(), "{name}:\n{}", report.to_text());
    }
}

#[test]
fn reports_are_byte_identical_across_runs() {
    for (name, _) in SEEDED {
        let a = check_file(&fixture(name), gpu_for(name).as_ref()).unwrap();
        let b = check_file(&fixture(name), gpu_for(name).as_ref()).unwrap();
        assert_eq!(a.to_text(), b.to_text(), "{name}: text report not deterministic");
        assert_eq!(
            a.to_json().try_dump().unwrap(),
            b.to_json().try_dump().unwrap(),
            "{name}: json report not deterministic"
        );
    }
}

fn run_check(args: &[&str]) -> (i32, String, String) {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_kareus"))
        .arg("check")
        .args(args)
        .output()
        .expect("spawn kareus check");
    (
        out.status.code().unwrap_or(-1),
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

#[test]
fn cli_exit_codes_follow_convention() {
    let ok = fixture("plan_ok.json");
    let bad = fixture("cluster_k010_over_cap.json");
    let warn_only = fixture("cluster_k015_stats_mismatch.json");

    let (code, stdout, _) = run_check(&[ok.to_str().unwrap(), "--gpu", "a100"]);
    assert_eq!(code, 0, "clean artifact must exit 0:\n{stdout}");
    assert!(stdout.contains("0 error(s), 0 warning(s)"), "{stdout}");

    let (code, stdout, _) = run_check(&[bad.to_str().unwrap()]);
    assert_eq!(code, 1, "artifact with errors must exit 1");
    assert!(stdout.contains("K010"), "{stdout}");

    let (code, _, _) = run_check(&[warn_only.to_str().unwrap()]);
    assert_eq!(code, 0, "warnings alone must not fail the check");

    let (code, _, _) = run_check(&[]);
    assert_eq!(code, 2, "missing file argument is a usage error");
    let (code, _, _) = run_check(&["/nonexistent/definitely_missing.json"]);
    assert_eq!(code, 2, "unreadable file is an IO error");
    let (code, _, _) = run_check(&[ok.to_str().unwrap(), "--gpu", "tpu9"]);
    assert_eq!(code, 2, "unknown gpu is a usage error");
}

#[test]
fn cli_json_report_parses_and_is_deterministic() {
    let bad = fixture("revisions_k020_counter.json");
    let (code, a, _) = run_check(&[bad.to_str().unwrap(), "--format", "json"]);
    assert_eq!(code, 1);
    let (_, b, _) = run_check(&[bad.to_str().unwrap(), "--format", "json"]);
    assert_eq!(a, b, "json report not byte-identical across runs");
    let doc = Json::parse(a.trim()).expect("report must be valid JSON");
    assert_eq!(doc.get("check").and_then(Json::as_str), Some("kareus_check"));
    assert_eq!(doc.get("kind").and_then(Json::as_str), Some("revision_log"));
    let diags = doc.get("diagnostics").and_then(Json::as_arr).unwrap();
    assert!(diags
        .iter()
        .any(|x| x.get("code").and_then(Json::as_str) == Some("K020")));
}
