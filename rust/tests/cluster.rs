//! Cluster-scheduler acceptance tests: under a binding datacenter cap the
//! water-filling allocator must stay within the cap in every schedule
//! segment, beat (or match) the uniform equal-share baseline's aggregate
//! throughput, survive degenerate jobs without panicking, and emit
//! byte-deterministic `ClusterPlan` JSON.

use std::sync::OnceLock;

use kareus::baselines::{uniform_cap_allocation, System, SystemResult};
use kareus::cluster::{
    allocate, demand_range, job_menu, optimize_jobs, parse_job_spec, plan_cluster, CapSegment,
    ClusterJob, ClusterPlan, JobFrontier, JobMenu, PowerCapSchedule,
};
use kareus::engine::{EngineConfig, Scenario};
use kareus::frontier::Frontier;
use kareus::sim::gpu::GpuSpec;
use kareus::util::json::Json;
use kareus::workload::{ModelSpec, Parallelism, TrainConfig};

/// Three heterogeneous 16-GPU jobs (cheap M+P system: multi-point
/// frontiers without MBO cost). Optimized once, shared across tests.
fn fronts() -> &'static [JobFrontier] {
    static FRONTS: OnceLock<Vec<JobFrontier>> = OnceLock::new();
    FRONTS.get_or_init(|| {
        let jobs: Vec<ClusterJob> = [
            "a100:qwen1.7b:tp8pp2:m+p",
            "a100:llama3b:cp2tp4pp2:m+p",
            "v100:qwen1.7b:tp8pp2:m+p",
        ]
        .iter()
        .map(|spec| parse_job_spec(spec, 8, 4096, 8, 11).expect("valid job spec"))
        .collect();
        optimize_jobs(&jobs, &EngineConfig::default(), |_| {})
    })
}

fn menus() -> Vec<JobMenu> {
    fronts().iter().map(job_menu).collect()
}

#[test]
fn binding_cap_respected_and_beats_uniform() {
    let menus = menus();
    let (peak, floor) = demand_range(&menus);
    assert!(floor < peak, "frontiers must span a power range ({floor} .. {peak})");
    for frac in [0.75, 0.5, 0.25] {
        let cap = floor + frac * (peak - floor);
        let wf = allocate(&menus, cap);
        assert!(wf.feasible, "cap {cap} above the floor must be feasible");
        assert!(
            wf.total_power_w <= cap * (1.0 + 1e-9),
            "allocation {} exceeds cap {cap}",
            wf.total_power_w
        );
        let uni = uniform_cap_allocation(&menus, cap);
        assert!(
            wf.tokens_per_s >= uni.tokens_per_s * (1.0 - 1e-12),
            "water-filling {} below uniform {} at cap {cap}",
            wf.tokens_per_s,
            uni.tokens_per_s
        );
    }
    // Unconstrained cap: everything runs at max throughput.
    let loose = allocate(&menus, peak * 2.0);
    assert!(loose.selection.iter().all(|s| *s == Some(0)));
}

#[test]
fn cap_schedule_boundary_reallocates_without_reoptimizing() {
    let menus = menus();
    let (peak, floor) = demand_range(&menus);
    let hi = peak * 1.05; // non-binding day cap
    let lo = floor + 0.3 * (peak - floor); // binding night cap
    let schedule = PowerCapSchedule::piecewise(vec![
        CapSegment { start_s: 0.0, cap_w: hi },
        CapSegment { start_s: 3600.0, cap_w: lo },
    ])
    .unwrap();
    assert_eq!(schedule.cap_at(3599.9), hi);
    assert_eq!(schedule.cap_at(3600.0), lo);

    let plan = plan_cluster(fronts(), &schedule, |_| {});
    assert!(plan.feasible());
    assert_eq!(plan.slices.len(), 2);
    for sl in &plan.slices {
        assert!(
            sl.total_power_w <= sl.cap_w * (1.0 + 1e-9),
            "slice at {} s draws {} W over its {} W cap",
            sl.start_s,
            sl.total_power_w,
            sl.cap_w
        );
        assert_eq!(sl.assignments.len(), plan.jobs.len());
        for a in &sl.assignments {
            // Each assignment carries a deployable typed plan with one
            // slot per (stage, microbatch, direction).
            let cfg = &fronts()[a.job].job.scenario.cfg;
            assert_eq!(
                a.plan.n_slots(),
                cfg.par.pp as usize * 2 * cfg.n_microbatches as usize,
                "job {} slot count",
                a.job
            );
        }
    }
    // The binding segment must move at least one job down-frontier and
    // cannot raise aggregate throughput.
    let day = &plan.slices[0];
    let night = &plan.slices[1];
    assert!(night.tokens_per_s <= day.tokens_per_s * (1.0 + 1e-12));
    assert!(
        day.assignments
            .iter()
            .zip(&night.assignments)
            .any(|(d, n)| d.point != n.point),
        "cap drop did not change any operating point"
    );
    assert!(day.assignments.iter().all(|a| a.point == 0), "non-binding day cap must run fast");
}

#[test]
fn cluster_plan_json_is_deterministic_and_roundtrips() {
    let menus = menus();
    let (peak, floor) = demand_range(&menus);
    let schedule = PowerCapSchedule::piecewise(vec![
        CapSegment { start_s: 0.0, cap_w: peak * 1.05 },
        CapSegment { start_s: 3600.0, cap_w: floor + 0.3 * (peak - floor) },
    ])
    .unwrap();
    let a = plan_cluster(fronts(), &schedule, |_| {});
    let b = plan_cluster(fronts(), &schedule, |_| {});
    let (da, db) = (a.to_json().dump(), b.to_json().dump());
    assert_eq!(da, db, "two identical planning runs must dump identical bytes");

    let back = ClusterPlan::from_json(&Json::parse(&da).unwrap()).unwrap();
    assert_eq!(back, a, "ClusterPlan JSON round-trip diverged");
    assert_eq!(back.to_json().dump(), da, "re-dump after round-trip diverged");

    // Schema spot checks.
    let parsed = Json::parse(&da).unwrap();
    assert_eq!(parsed.get("plan").unwrap().as_str(), Some("kareus_cluster"));
    assert_eq!(parsed.get("jobs").unwrap().as_arr().unwrap().len(), 3);
    assert_eq!(parsed.get("slices").unwrap().as_arr().unwrap().len(), 2);
}

#[test]
fn empty_frontier_job_skipped_with_warning() {
    let real = fronts()[0].clone();
    let degenerate = JobFrontier {
        job: ClusterJob::new(Scenario {
            gpu: GpuSpec::a100(),
            cfg: TrainConfig {
                model: ModelSpec::qwen3_1_7b(),
                par: Parallelism::new(8, 1, 2),
                microbatch: 8,
                seq_len: 4096,
                n_microbatches: 8,
                dtype_bytes: 2,
            },
            system: System::Kareus,
            seed: 0,
        }),
        result: SystemResult {
            system: System::Kareus,
            frontier: Frontier::new(),
            plans: Vec::new(),
            menus: Vec::new(),
            mbo_profiling_s: 0.0,
            tflops_per_gpu: f64::NAN,
        },
    };
    let both = vec![real, degenerate];
    let mut warnings = Vec::new();
    let plan = plan_cluster(&both, &PowerCapSchedule::constant(1e9), |w| {
        warnings.push(w.to_string())
    });
    assert_eq!(warnings.len(), 1, "exactly one skip warning expected: {warnings:?}");
    assert!(warnings[0].contains("empty frontier"), "{warnings:?}");
    assert!(!plan.jobs[0].skipped && plan.jobs[1].skipped);
    assert!(plan.feasible());
    assert_eq!(plan.slices[0].assignments.len(), 1, "skipped job must get no assignment");
    assert_eq!(plan.slices[0].assignments[0].job, 0);
}

#[test]
fn cluster_cli_stdout_is_pure_json() {
    // The CI double-run smoke `cmp`s the CLI's stdout byte-for-byte, so
    // progress lines and warnings must never leak into it: stdout is the
    // ClusterPlan JSON and nothing else, stderr carries the rest. A
    // binding second segment plus a below-minimum third exercises both
    // the normal and the warning-adjacent paths.
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_kareus"))
        .args([
            "cluster",
            "--jobs",
            "a100:qwen1.7b:tp8pp2:m+p",
            "--caps",
            "0:1000000,3600:100",
            "--threads",
            "1",
        ])
        .output()
        .expect("kareus binary runs");
    // Exit code 1 = infeasible segment (the 100 W one), by contract.
    assert_eq!(out.status.code(), Some(1), "expected the infeasible-segment exit code");
    let stdout = String::from_utf8(out.stdout).expect("stdout is UTF-8");
    let parsed = Json::parse(stdout.trim_end_matches('\n'))
        .unwrap_or_else(|e| panic!("stdout is not pure JSON ({e}):\n{stdout}"));
    let plan = ClusterPlan::from_json(&parsed).expect("stdout decodes as a ClusterPlan");
    assert_eq!(plan.slices.len(), 2);
    assert!(!plan.slices[1].feasible);
    // Progress and warnings went to stderr instead.
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("optimizing"), "progress missing from stderr: {stderr}");
    assert!(stderr.contains("warning"), "infeasible-cap warning missing from stderr: {stderr}");
}

#[test]
fn cap_below_cluster_minimum_pins_min_power_not_panics() {
    let menus = menus();
    let (_, floor) = demand_range(&menus);
    let plan = plan_cluster(fronts(), &PowerCapSchedule::constant(floor * 0.5), |_| {});
    assert!(!plan.feasible());
    let sl = &plan.slices[0];
    assert!(!sl.feasible);
    // Pinned at minimum power: the selection equals each menu's min-power
    // point and the (unavoidable) draw equals the cluster floor.
    for a in &sl.assignments {
        assert_eq!(Some(a.point), menus[a.job].min_power_point());
    }
    assert!((sl.total_power_w - floor).abs() <= floor * 1e-9);
}
