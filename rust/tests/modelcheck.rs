//! Exhaustive model-checking harnesses (`--features modelcheck`).
//!
//! Each harness hands the [`Explorer`] a model closure built entirely on
//! the `util::sync` shims and asserts a protocol property over *every*
//! interleaving within the preemption bound (≥ 2 everywhere here; every
//! harness also asserts the exploration was not capped, so a pass means
//! the bounded space was genuinely exhausted):
//!
//! - the serve coalescing protocol: exactly one owner per key, waiters
//!   observe the owner's published value (typed errors included), and an
//!   owner dying unpublished poisons — never strands — its waiters;
//! - the worker pool's drain-then-join shutdown: every queued job runs,
//!   no deadlock, under 1–2 workers;
//! - the daemon's shutdown accept-race, as an abstract flag + wake-channel
//!   model of the accept loop;
//! - replay fixtures: the two seeded bugs in `modelcheck::demos` are
//!   re-detected from their committed schedules, and exploration reports
//!   are byte-identical across double runs.

#![cfg(feature = "modelcheck")]

use std::sync::Arc;

use kareus::modelcheck::{demos, schedule_from_json, Config, Explorer, FailureKind, Report};
use kareus::serve::coalesce::{Claim, CoalescingCache, Fill};
use kareus::util::json::Json;
use kareus::util::pool::WorkerPool;
use kareus::util::sync::{channel, spawn, SyncAtomicBool, SyncAtomicUsize};

/// Bound used by every harness: per the CHESS observation most real bugs
/// need ≤ 2 preemptions, and the acceptance bar for this suite is ≥ 2.
const BOUND: u32 = 2;

fn explorer() -> Explorer {
    Explorer::new(Config { max_preemptions: BOUND, max_schedules: 500_000, prune: true })
}

/// An exploration that must pass: no failure, and the space was actually
/// exhausted within the schedule cap.
fn assert_clean(report: &Report, what: &str) {
    assert!(!report.capped, "{what}: exploration hit the schedule cap");
    if let Some(f) = &report.failure {
        panic!(
            "{what}: {} under schedule {:?}\n  {}\n  trace: {:?}",
            f.kind.as_str(),
            f.schedule,
            f.message,
            f.trace
        );
    }
    assert!(report.schedules >= 2, "{what}: expected a real interleaving space");
}

// ---------------------------------------------------------------------------
// Serve coalescing protocol
// ---------------------------------------------------------------------------

#[test]
fn coalescing_has_exactly_one_owner_and_waiters_see_its_value() {
    let report = explorer().explore(|| {
        let cache = Arc::new(CoalescingCache::<u32>::new());
        let owners = Arc::new(SyncAtomicUsize::new(0));
        let mk = |cache: &Arc<CoalescingCache<u32>>, owners: &Arc<SyncAtomicUsize>| {
            let cache = Arc::clone(cache);
            let owners = Arc::clone(owners);
            spawn(move || match cache.claim("k", || true) {
                Claim::Owner(g) => {
                    owners.fetch_add(1);
                    g.fill(42);
                }
                Claim::Waiter(slot) => match slot.wait() {
                    Fill::Value(v) => assert_eq!(v, 42, "waiter saw a foreign value"),
                    Fill::Poisoned(m) => panic!("live owner must never poison: {m}"),
                },
                Claim::Refused => panic!("admission granted yet claim refused"),
            })
        };
        let a = mk(&cache, &owners);
        let b = mk(&cache, &owners);
        a.join().expect("requester a");
        b.join().expect("requester b");
        assert_eq!(owners.load(), 1, "exactly one requester may compute");
        assert_eq!(cache.len(), 1, "the filled slot stays cached");
        // A late claim coalesces onto the resolved slot, never recomputes.
        match cache.claim("k", || panic!("resolved key consulted admission")) {
            Claim::Waiter(slot) => assert_eq!(slot.wait(), Fill::Value(42)),
            _ => panic!("late claim must coalesce"),
        }
    });
    assert_clean(&report, "coalescing owner/waiter");
}

#[test]
fn coalescing_negative_cache_is_poison_free() {
    // An owner that *publishes* a typed error is a deterministic, cacheable
    // outcome: waiters must see exactly that value — never Poisoned — and
    // the entry must stay cached, in every interleaving.
    let report = explorer().explore(|| {
        let cache = Arc::new(CoalescingCache::<i64>::new());
        let c2 = Arc::clone(&cache);
        let waiter = spawn(move || match c2.claim("k", || true) {
            Claim::Owner(g) => g.fill(-1), // this thread won the race: publish
            Claim::Waiter(slot) => match slot.wait() {
                Fill::Value(v) => assert_eq!(v, -1),
                Fill::Poisoned(m) => panic!("typed error fill must not poison: {m}"),
            },
            Claim::Refused => panic!("unexpected refusal"),
        });
        match cache.claim("k", || true) {
            // -1 stands in for a typed deterministic failure payload.
            Claim::Owner(g) => g.fill(-1),
            Claim::Waiter(slot) => assert_eq!(slot.wait(), Fill::Value(-1)),
            Claim::Refused => panic!("unexpected refusal"),
        }
        waiter.join().expect("waiter");
        assert_eq!(cache.len(), 1, "deterministic failures stay negatively cached");
    });
    assert_clean(&report, "negative cache");
}

#[test]
fn coalescing_owner_death_never_strands_waiters() {
    // The owner dies without publishing. In every interleaving the other
    // requester either coalesced first (→ observes a typed Poisoned, no
    // hang — a strand would surface as lost-wakeup) or claimed after the
    // eviction (→ becomes the new owner and publishes).
    let report = explorer().explore(|| {
        let cache = Arc::new(CoalescingCache::<u32>::new());
        let c2 = Arc::clone(&cache);
        let other = spawn(move || match c2.claim("k", || true) {
            Claim::Owner(g) => g.fill(7),
            Claim::Waiter(slot) => match slot.wait() {
                Fill::Poisoned(m) => assert!(m.contains("died before publishing"), "{m}"),
                // The first owner never publishes, so a value can only
                // come from this thread's own re-claim — not this arm.
                Fill::Value(v) => panic!("dead owner published {v}?"),
            },
            Claim::Refused => panic!("unexpected refusal"),
        });
        if let Claim::Owner(g) = cache.claim("k", || true) {
            drop(g); // die unpublished: poison + evict
        }
        other.join().expect("surviving requester");
    });
    assert_clean(&report, "owner death");
}

// ---------------------------------------------------------------------------
// Worker pool shutdown
// ---------------------------------------------------------------------------

#[test]
fn pool_shutdown_drains_every_job_one_worker() {
    let report = explorer().explore(|| {
        let ran = Arc::new(SyncAtomicUsize::new(0));
        let pool = WorkerPool::new(1);
        for _ in 0..2 {
            let ran = Arc::clone(&ran);
            pool.execute(move || {
                ran.fetch_add(1);
            });
        }
        drop(pool); // shutdown: drain queued jobs, then join
        assert_eq!(ran.load(), 2, "shutdown must drain, not abort");
    });
    assert_clean(&report, "pool drain (1 worker, 2 jobs)");
}

#[test]
fn pool_shutdown_drains_with_two_workers() {
    // Two workers contend on the shared receiver mutex; one may be parked
    // in the channel condvar while the other holds the receiver lock. The
    // drain property (job runs, both workers join, no lost wakeup on the
    // close notification) must hold in every interleaving.
    let report = explorer().explore(|| {
        let ran = Arc::new(SyncAtomicUsize::new(0));
        let pool = WorkerPool::new(2);
        let r2 = Arc::clone(&ran);
        pool.execute(move || {
            r2.fetch_add(1);
        });
        drop(pool);
        assert_eq!(ran.load(), 1);
    });
    assert_clean(&report, "pool drain (2 workers, 1 job)");
}

// ---------------------------------------------------------------------------
// Serve shutdown accept-race (abstract model of Server::run)
// ---------------------------------------------------------------------------

#[test]
fn accept_loop_terminates_under_shutdown_race() {
    // Abstract model of the daemon's shutdown: the acceptor re-checks a
    // flag between blocking accepts (modeled as channel recvs); the
    // shutdown path sets the flag, sends one wake (the real code's
    // self-connect), and closes the channel. Termination in every
    // interleaving means no ordering of flag-store / wake / park can
    // strand the acceptor — the exact race the self-connect poke exists
    // to close.
    let report = explorer().explore(|| {
        let shutting_down = Arc::new(SyncAtomicBool::new(false));
        let (tx, rx) = channel::<()>();
        let flag = Arc::clone(&shutting_down);
        let acceptor = spawn(move || {
            let mut served = 0u32;
            loop {
                if flag.load() {
                    break;
                }
                match rx.recv() {
                    Ok(()) => served += 1, // one "connection" handled
                    Err(_) => break,       // listener closed
                }
            }
            served
        });
        shutting_down.store(true);
        let _ = tx.send(()); // wake a parked acceptor (self-connect poke)
        drop(tx); // close the listener
        let served = acceptor.join().expect("acceptor");
        assert!(served <= 1, "at most the wake poke is ever served");
    });
    assert_clean(&report, "accept-race shutdown");
}

// ---------------------------------------------------------------------------
// Seeded-bug fixtures: replay + byte determinism
// ---------------------------------------------------------------------------

fn load_fixture(name: &str) -> (FailureKind, Vec<usize>) {
    let path =
        format!("{}/tests/fixtures/modelcheck/{name}.json", env!("CARGO_MANIFEST_DIR"));
    let text = std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {path}: {e}"));
    let j = Json::parse(&text).unwrap_or_else(|e| panic!("parse {path}: {e}"));
    let kind = j
        .get("kind")
        .and_then(|v| v.as_str())
        .and_then(FailureKind::parse)
        .unwrap_or_else(|| panic!("{path}: bad or missing kind"));
    let schedule = schedule_from_json(&j).unwrap_or_else(|| panic!("{path}: bad schedule"));
    (kind, schedule)
}

#[test]
fn double_lock_fixture_replays_to_the_same_bug() {
    let (kind, schedule) = load_fixture("double_lock");
    assert_eq!(kind, FailureKind::DoubleLock);
    let report = Explorer::new(Config::default()).replay(&schedule, demos::double_lock);
    let f = report.failure.expect("fixture schedule must re-detect the seeded bug");
    assert_eq!(f.kind, FailureKind::DoubleLock, "{}", f.message);
    assert_eq!(f.schedule, schedule, "replay must fail at the recorded point");
}

#[test]
fn lost_wakeup_fixture_replays_to_the_same_bug() {
    let (kind, schedule) = load_fixture("lost_wakeup");
    assert_eq!(kind, FailureKind::LostWakeup);
    let report = Explorer::new(Config::default()).replay(&schedule, demos::lost_wakeup);
    let f = report.failure.expect("fixture schedule must re-detect the seeded bug");
    assert_eq!(f.kind, FailureKind::LostWakeup, "{}", f.message);
    assert_eq!(f.schedule, schedule, "replay must fail at the recorded point");
}

#[test]
fn seeded_bugs_are_found_by_exploration_with_replayable_reports() {
    // Exploration (not just replay) finds both seeded bugs, and the
    // schedule it reports is itself a working reproducer.
    for (name, model, want) in [
        ("double_lock", demos::double_lock as fn(), FailureKind::DoubleLock),
        ("lost_wakeup", demos::lost_wakeup as fn(), FailureKind::LostWakeup),
    ] {
        let report = explorer().explore(model);
        let f = report.failure.unwrap_or_else(|| panic!("{name}: bug not found"));
        assert_eq!(f.kind, want, "{name}: {}", f.message);
        let replay = Explorer::new(Config::default()).replay(&f.schedule, model);
        assert_eq!(
            replay.failure.map(|f| f.kind),
            Some(want),
            "{name}: reported schedule must reproduce"
        );
    }
}

#[test]
fn reports_are_byte_identical_across_double_runs() {
    for (name, model) in [
        ("double_lock", demos::double_lock as fn()),
        ("lost_wakeup", demos::lost_wakeup as fn()),
        ("wakeup_correct", demos::wakeup_correct as fn()),
    ] {
        let a = explorer().explore(model).dump();
        let b = explorer().explore(model).dump();
        assert_eq!(a, b, "{name}: exploration must be deterministic");
    }
}

#[test]
fn correct_wakeup_protocol_is_clean_under_the_same_bound() {
    // The fixed variant of the seeded lost-wakeup bug: same shape, the
    // signaler holds the mutex across set-and-notify. The checker that
    // flags the broken version must pass this one.
    let report = explorer().explore(demos::wakeup_correct);
    assert_clean(&report, "wakeup_correct");
}
