//! Differential + property test layer for kernel-level DVFS.
//!
//! The kernel-DVFS axis is opt-in: with `FreqGranularity::Partition`
//! every layer — candidate census, MBO cache keys, optimizer output,
//! sweep JSON — must be byte-identical to the pre-kernel-DVFS build, and
//! a uniform per-kernel assignment with zero transition cost must match
//! partition-level results exactly. The property section drives random
//! partitions/schedules through the in-house PRNG (proptest is
//! unavailable offline) and pins the structural invariants of the new
//! axis: census product arithmetic, grid membership, transition-count
//! accounting, and monotonicity in the transition-energy penalty.

use kareus::baselines::System;
use kareus::engine::{run_sweep, scenario_matrix, sweep_json, EngineConfig, MboCache};
use kareus::frontier::Frontier;
use kareus::mbo::space::{self, FreqGranularity};
use kareus::mbo::{
    exhaustive, optimize_partition, optimize_partition_with_granularity, MboParams, MboResult,
    MultiPassMbo,
};
use kareus::partition::Partition;
use kareus::profiler::{Profiler, ProfilerConfig};
use kareus::sim::exec::{execute_partition, KernelFreqs, LaunchAt, Schedule};
use kareus::sim::gpu::GpuSpec;
use kareus::sim::kernel::{Kernel, KernelKind};
use kareus::util::hash::Fnv64;
use kareus::util::rng::Rng;
use kareus::workload::{ModelSpec, Parallelism};

fn attn_partition() -> Partition {
    Partition {
        ptype: "fwd/attn".into(),
        comps: vec![
            Kernel::comp("Norm", KernelKind::Norm, 1e8, 8e8),
            Kernel::comp("Linear1", KernelKind::Linear, 5e11, 2.5e9),
            Kernel::comp("Flash", KernelKind::FlashAttention, 3e11, 1e9),
            Kernel::comp("Linear2", KernelKind::Linear, 5e11, 2.5e9),
        ],
        comm: Some(Kernel::comm("AR", KernelKind::AllReduce, 5e8)),
        count: 28,
    }
}

fn random_partition(rng: &mut Rng) -> Partition {
    let n = 1 + rng.below(5);
    let comps = (0..n)
        .map(|i| {
            if rng.f64() < 0.4 {
                Kernel::comp(format!("mem{i}"), KernelKind::Norm, 1e8, 5e8 + rng.f64() * 4e9)
            } else {
                Kernel::comp(
                    format!("comp{i}"),
                    KernelKind::Linear,
                    5e10 + rng.f64() * 8e11,
                    1e9 + rng.f64() * 2e9,
                )
            }
        })
        .collect();
    let comm = if rng.f64() < 0.85 {
        Some(Kernel::comm("ar", KernelKind::AllReduce, 5e7 + rng.f64() * 3e9))
    } else {
        None
    };
    Partition { ptype: "prop".into(), comps, comm, count: 1 }
}

/// A random per-kernel-class schedule whose frequencies come from the
/// same grids the candidate space enumerates.
fn random_per_class_schedule(gpu: &GpuSpec, rng: &mut Rng, n_comps: usize) -> Schedule {
    let compute = 900 + 30 * rng.below(18) as u32;
    let mem_grid = gpu.memory_class_freqs();
    let memory = mem_grid[rng.below(mem_grid.len())];
    Schedule {
        comm_sms: 1 + rng.below(30) as u32,
        launch: LaunchAt::WithComp(rng.below(n_comps)),
        freq_mhz: compute,
        kernel_freqs: KernelFreqs::PerClass { compute_mhz: compute, memory_mhz: memory },
    }
}

// ---------------------------------------------------------------------
// Differential parity: Partition granularity is byte-identical to the
// pre-kernel-DVFS build at every layer.
// ---------------------------------------------------------------------

#[test]
fn partition_candidate_space_matches_legacy_enumeration() {
    let gpu = GpuSpec::a100();
    for part in [attn_partition(), {
        let mut p = attn_partition();
        p.comm = None;
        p
    }] {
        let legacy = space::candidate_space(&gpu, &part, 8);
        let explicit = space::candidate_space_with(&gpu, &part, 8, FreqGranularity::Partition);
        assert_eq!(legacy, explicit, "{}: same schedules in the same order", part.ptype);
        for s in &legacy {
            assert_eq!(s.kernel_freqs, KernelFreqs::Uniform);
        }
    }
}

#[test]
fn kernel_space_is_partition_space_times_memory_grid() {
    let gpu = GpuSpec::a100();
    let part = attn_partition();
    let p = space::candidate_space_with(&gpu, &part, 8, FreqGranularity::Partition);
    let k = space::candidate_space_with(&gpu, &part, 8, FreqGranularity::KernelClass);
    assert_eq!(k.len(), p.len() * gpu.memory_class_freqs().len());
    // Projecting away the memory axis recovers exactly the legacy space.
    let sort_key = |s: &Schedule| (s.freq_mhz, s.comm_sms, format!("{:?}", s.launch));
    let mut projected: Vec<Schedule> = k
        .iter()
        .map(|s| Schedule::uniform(s.comm_sms, s.launch, s.freq_mhz))
        .collect();
    projected.sort_by_key(sort_key);
    projected.dedup();
    let mut legacy = p.clone();
    legacy.sort_by_key(sort_key);
    legacy.dedup();
    assert_eq!(projected, legacy);
}

#[test]
fn partition_mbo_cache_key_matches_pre_kernel_dvfs_hash() {
    // The cache key folds the granularity in only when it differs from
    // Partition, so partition-level keys hash byte-identically to builds
    // that predate the axis. Recompute the legacy hash by hand.
    let gpu = GpuSpec::a100();
    let part = attn_partition();
    let params = MboParams::for_class(part.size_class());
    let prof = ProfilerConfig::default();
    let (backend_fp, strategy_fp) = (0x1234_5678_9abc_def0u64, 0x0fed_cba9_8765_4321u64);
    let legacy = {
        let mut h = Fnv64::new();
        h.write_u64(backend_fp)
            .write_u64(strategy_fp)
            .write_u64(gpu.fingerprint())
            .write_u64(part.fingerprint())
            .write_u64(8)
            .write_u64(params.n_init as u64)
            .write_u64(params.b_max as u64)
            .write_u64(params.batch_k as u64)
            .write_f64(params.pass_fracs[0])
            .write_f64(params.pass_fracs[1])
            .write_f64(params.pass_fracs[2])
            .write_u64(params.ensemble_size as u64)
            .write_f64(params.bootstrap_fraction)
            .write_u64(params.r_window as u64)
            .write_f64(params.eps)
            .write_u64(params.seed)
            .write_f64(prof.window_s)
            .write_f64(prof.cooldown_s)
            .write_f64(prof.warmup_s)
            .write_f64(prof.setup_s);
        h.finish()
    };
    let key = |g: FreqGranularity| {
        MboCache::key(backend_fp, strategy_fp, &gpu, &part, 8, &params, &prof, g)
    };
    assert_eq!(key(FreqGranularity::Partition), legacy);
    assert_ne!(key(FreqGranularity::KernelClass), legacy, "kernel keys must not alias");
}

fn result_bits(r: &MboResult) -> (Vec<(u64, u64, usize)>, Vec<Schedule>, u64) {
    (
        r.frontier
            .points()
            .iter()
            .map(|p| (p.time.to_bits(), p.energy.to_bits(), p.tag))
            .collect(),
        r.evaluated.iter().map(|e| e.sched).collect(),
        r.profiling_cost_s.to_bits(),
    )
}

#[test]
fn partition_granularity_optimizer_output_is_byte_identical() {
    let gpu = GpuSpec::a100();
    let part = attn_partition();
    let mut params = MboParams::for_class(part.size_class());
    params.seed = 11;
    let mut prof_a = Profiler::new(gpu.clone(), ProfilerConfig::default(), 11);
    let legacy = optimize_partition(&mut prof_a, &part, 8, &params);
    let strategy = MultiPassMbo::new(params).expect("valid params");
    let mut prof_b = Profiler::new(gpu, ProfilerConfig::default(), 11);
    let explicit = optimize_partition_with_granularity(
        &strategy,
        &mut prof_b,
        &part,
        8,
        FreqGranularity::Partition,
    );
    assert_eq!(result_bits(&legacy), result_bits(&explicit));
}

#[test]
fn sweep_json_carries_granularity_key_only_when_kernel_level() {
    let scenarios = || {
        scenario_matrix(
            &[GpuSpec::a100()],
            &[ModelSpec::qwen3_1_7b()],
            &[Parallelism::new(8, 1, 2)],
            &[System::MegatronPerseus],
            8,
            4096,
            8,
            5,
        )
    };
    // `deterministic = true` nulls the wall-clock timing fields — anything
    // else would never be byte-identical across two separate sweeps.
    let dump_with = |engine: &EngineConfig| {
        let outcomes = run_sweep(scenarios(), engine, |_| {});
        sweep_json(&outcomes, engine, true).dump()
    };
    let default_engine = dump_with(&EngineConfig::new());
    let explicit_partition =
        dump_with(&EngineConfig::new().with_freq_granularity(FreqGranularity::Partition));
    assert_eq!(
        default_engine, explicit_partition,
        "partition-level sweep JSON must be byte-identical to the legacy dump"
    );
    assert!(!default_engine.contains("freq_granularity"));
    let kernel =
        dump_with(&EngineConfig::new().with_freq_granularity(FreqGranularity::KernelClass));
    assert!(kernel.contains("\"freq_granularity\":\"kernel\""), "{kernel}");
}

#[test]
fn zero_cost_kernel_frontier_contains_partition_frontier() {
    // With the transition cost zeroed, every partition-level operating
    // point is a diagonal per-class candidate that executes bit-identically
    // — so the kernel-level frontier must weakly dominate every
    // partition-level frontier point, exactly.
    let mut gpu = GpuSpec::a100();
    gpu.freq_switch_s = 0.0;
    gpu.freq_switch_j = 0.0;
    let part = attn_partition();
    let pf = exhaustive::exhaustive_frontier_with(&gpu, &part, 8, FreqGranularity::Partition);
    let kf = exhaustive::exhaustive_frontier_with(&gpu, &part, 8, FreqGranularity::KernelClass);
    assert!(!pf.is_empty() && !kf.is_empty());
    for pp in pf.points() {
        assert!(
            kf.points().iter().any(|kp| kp.time <= pp.time && kp.energy <= pp.energy),
            "partition point ({}, {}) not weakly dominated",
            pp.time,
            pp.energy
        );
    }
    let rref = Frontier::reference_of(
        &pf.points().iter().chain(kf.points()).copied().collect::<Vec<_>>(),
    );
    assert!(kf.hypervolume(rref) >= pf.hypervolume(rref) - 1e-12);
}

#[test]
fn kernel_level_strictly_dominates_on_the_pinned_membound_scenario() {
    // The acceptance scenario: the paper ablation's memory-heavy fused
    // partition, where per-class downclocking must beat every uniform
    // assignment despite paying real transition costs.
    let out = kareus::paper::run_experiment("kernel-dvfs").expect("registered experiment");
    assert!(
        out.contains("fwd/fused (memory-heavy): strictly-dominates=yes"),
        "kernel-level DVFS must strictly improve the membound frontier:\n{out}"
    );
    assert!(out.contains("fwd/mlp (compute-heavy): strictly-dominates="), "{out}");
}

// ---------------------------------------------------------------------
// Property tests (seeded in-house PRNG; no external proptest dep).
// ---------------------------------------------------------------------

#[test]
fn prop_candidate_counts_follow_census_product() {
    let gpu = GpuSpec::a100();
    let n_mem = gpu.memory_class_freqs().len();
    let mut rng = Rng::new(0xDF5);
    for _ in 0..60 {
        let part = random_partition(&mut rng);
        let p = space::candidate_space_with(&gpu, &part, 8, FreqGranularity::Partition);
        let k = space::candidate_space_with(&gpu, &part, 8, FreqGranularity::KernelClass);
        assert_eq!(p, space::candidate_space(&gpu, &part, 8));
        assert_eq!(k.len(), p.len() * n_mem, "census product violated for {part:?}");
    }
}

#[test]
fn prop_kernel_space_frequencies_stay_on_the_gpu_grid() {
    let mut rng = Rng::new(0xDF6);
    for gpu in [GpuSpec::a100(), GpuSpec::h100(), GpuSpec::v100()] {
        for _ in 0..20 {
            let part = random_partition(&mut rng);
            for s in space::candidate_space_with(&gpu, &part, 8, FreqGranularity::KernelClass) {
                let KernelFreqs::PerClass { compute_mhz, memory_mhz } = s.kernel_freqs else {
                    panic!("kernel-class space emitted a uniform schedule");
                };
                assert_eq!(compute_mhz, s.freq_mhz, "compute class is pinned to the base");
                for f in [compute_mhz, memory_mhz] {
                    assert!(f >= gpu.f_min_mhz && f <= gpu.f_max_mhz, "{}: {f}", gpu.name);
                    assert_eq!((f - gpu.f_min_mhz) % gpu.f_stride_mhz, 0, "{}: {f}", gpu.name);
                }
            }
        }
    }
}

/// The transition count the executor must charge for a sequential
/// schedule: the stream enters at the base (= compute) frequency and
/// switches whenever the next computation kernel's class frequency
/// differs from the current one. Comm kernels never switch.
fn expected_transitions(part: &Partition, sched: &Schedule) -> u32 {
    let mut cur = sched.freq_mhz;
    let mut n = 0;
    for k in &part.comps {
        let f = sched.freq_for(k.kind.class());
        if f != cur {
            n += 1;
            cur = f;
        }
    }
    n
}

#[test]
fn prop_transition_count_zero_iff_adjacent_kernels_share_frequency() {
    let gpu = GpuSpec::a100();
    let mut rng = Rng::new(0xDF7);
    let mut saw_transitions = false;
    for _ in 0..200 {
        let part = random_partition(&mut rng);
        let mut sched = random_per_class_schedule(&gpu, &mut rng, part.comps.len());
        sched.comm_sms = 0;
        sched.launch = LaunchAt::Sequential;
        let r = execute_partition(&gpu, &part.comps, None, &sched, 30.0, None);
        let expected = expected_transitions(&part, &sched);
        assert_eq!(r.freq_transitions, expected, "{part:?} under {sched:?}");
        let all_shared =
            part.comps.iter().all(|k| sched.freq_for(k.kind.class()) == sched.freq_mhz);
        assert_eq!(expected == 0, all_shared);
        saw_transitions |= expected > 0;
    }
    assert!(saw_transitions, "sampler never produced a frequency split");
}

#[test]
fn prop_total_energy_monotone_in_transition_energy_penalty() {
    let mut rng = Rng::new(0xDF8);
    for _ in 0..100 {
        let part = random_partition(&mut rng);
        let sched = {
            let mut s = random_per_class_schedule(&GpuSpec::a100(), &mut rng, part.comps.len());
            s.comm_sms = 0;
            s.launch = LaunchAt::Sequential;
            s
        };
        let mut prev = f64::NEG_INFINITY;
        for switch_j in [0.0, 1e-3, 5e-3, 5e-2, 0.5] {
            let mut gpu = GpuSpec::a100();
            gpu.freq_switch_j = switch_j;
            let r = execute_partition(&gpu, &part.comps, None, &sched, 30.0, None);
            assert!(
                r.total_j() >= prev - 1e-12,
                "energy decreased when the switch penalty grew to {switch_j}"
            );
            prev = r.total_j();
        }
    }
}

#[test]
fn prop_diagonal_per_class_schedules_match_uniform_bitwise() {
    let gpu = GpuSpec::a100();
    let mut rng = Rng::new(0xDF9);
    for _ in 0..100 {
        let part = random_partition(&mut rng);
        let f = 900 + 30 * rng.below(18) as u32;
        let sms = 1 + rng.below(30) as u32;
        let launch = LaunchAt::WithComp(rng.below(part.comps.len()));
        let uni = Schedule::uniform(sms, launch, f);
        let diag = Schedule {
            comm_sms: sms,
            launch,
            freq_mhz: f,
            kernel_freqs: KernelFreqs::PerClass { compute_mhz: f, memory_mhz: f },
        };
        let a =
            execute_partition(&gpu, &part.comps, part.comm.as_ref(), &uni, 30.0, Some(gpu.tdp_w));
        let b =
            execute_partition(&gpu, &part.comps, part.comm.as_ref(), &diag, 30.0, Some(gpu.tdp_w));
        assert_eq!(a.time_s.to_bits(), b.time_s.to_bits());
        assert_eq!(a.dyn_j.to_bits(), b.dyn_j.to_bits());
        assert_eq!(a.static_j.to_bits(), b.static_j.to_bits());
        assert_eq!(a.freq_transitions, 0);
        assert_eq!(b.freq_transitions, 0);
    }
}
