//! Cross-layer integration tests: PJRT runtime ↔ AOT artifacts ↔ L1/L2
//! numerics, and the coordinator's optimize→select→deploy path.
//!
//! Tests that need `artifacts/` skip (with a message) when it hasn't been
//! built — run `make artifacts` first for full coverage.

use std::path::PathBuf;

use kareus::baselines::System;
use kareus::coordinator::{Coordinator, Target};
use kareus::runtime::Runtime;
use kareus::sim::gpu::GpuSpec;
use kareus::trainer::{synthetic_tokens, Trainer};
use kareus::util::rng::Rng;
use kareus::workload::{ModelSpec, Parallelism, TrainConfig};

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

/// The core L1 correctness signal at the Rust level: the Pallas-kernel
/// forward and the pure-jnp oracle forward, both lowered to HLO and
/// executed through PJRT, must agree on the same inputs.
#[test]
fn pallas_and_ref_artifacts_agree_through_pjrt() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let mut rt = Runtime::new(artifacts_dir()).unwrap();
    let info = rt.manifest.configs.get("tiny").unwrap().clone();

    // Materialize parameters with the init artifact.
    let params = rt.execute("init_tiny", &[xla::Literal::scalar(3u32)]).unwrap();
    assert_eq!(params.len(), info.n_param_arrays);

    // Same tokens for both forwards.
    let mut rng = Rng::new(9);
    let toks = synthetic_tokens(&mut rng, info.batch, info.seq_len, info.vocab);
    let tok_lit = xla::Literal::vec1(&toks)
        .reshape(&[info.batch as i64, info.seq_len as i64])
        .unwrap();

    let mut args: Vec<xla::Literal> = params.clone();
    args.push(tok_lit);
    let logits_ref = rt.execute("fwd_ref_tiny", &args).unwrap();
    let logits_pal = rt.execute("fwd_pallas_tiny", &args).unwrap();

    let a = logits_ref[0].to_vec::<f32>().unwrap();
    let b = logits_pal[0].to_vec::<f32>().unwrap();
    assert_eq!(a.len(), b.len());
    assert_eq!(a.len(), info.batch * info.seq_len * info.vocab);
    let mut max_err = 0.0f32;
    for (x, y) in a.iter().zip(&b) {
        max_err = max_err.max((x - y).abs());
    }
    assert!(max_err < 5e-3, "pallas vs ref max err {max_err}");
}

#[test]
fn train_step_reduces_loss_and_threads_state() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let rt = Runtime::new(artifacts_dir()).unwrap();
    let mut tr = Trainer::new(rt, "tiny", 1).unwrap();
    let n_state = tr.n_state();
    let mut losses = Vec::new();
    for _ in 0..25 {
        losses.push(tr.step().unwrap());
    }
    assert_eq!(tr.n_state(), n_state, "state layout must be stable");
    let head = losses[0];
    let tail: f32 = losses[losses.len() - 5..].iter().sum::<f32>() / 5.0;
    assert!(tail < head, "loss did not decrease: {head} -> {tail} ({losses:?})");
}

#[test]
fn runtime_rejects_wrong_arity() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let mut rt = Runtime::new(artifacts_dir()).unwrap();
    match rt.execute("init_tiny", &[]) {
        Ok(_) => panic!("wrong arity accepted"),
        Err(err) => assert!(format!("{err:#}").contains("expected"), "{err:#}"),
    }
}

#[test]
fn coordinator_full_path_megatron_perseus() {
    // Optimizer-only path (no artifacts needed): optimize, select under
    // three targets, emit a plan JSON.
    let cfg = TrainConfig {
        model: ModelSpec::llama32_3b(),
        par: Parallelism::new(8, 1, 2),
        microbatch: 8,
        seq_len: 4096,
        n_microbatches: 8,
        dtype_bytes: 2,
    };
    let coord = Coordinator::new(GpuSpec::a100(), cfg);
    let r = coord.optimize(System::MegatronPerseus, 5);
    let fast = coord.select(&r, Target::MaxThroughput).unwrap();
    let relaxed = coord.select(&r, Target::Deadline(fast.iter_time_s * 1.5)).unwrap();
    assert!(relaxed.iter_energy_j < fast.iter_energy_j);
    let json = coord.plan_json(&r, &relaxed).dump();
    assert!(json.contains("frontier"));
}

#[test]
fn kareus_beats_megatron_on_both_axes_qwen_tp8() {
    // The headline end-to-end claim on the Table 3 flagship row.
    let cfg = TrainConfig {
        model: ModelSpec::qwen3_1_7b(),
        par: Parallelism::new(8, 1, 2),
        microbatch: 8,
        seq_len: 4096,
        n_microbatches: 8,
        dtype_bytes: 2,
    };
    let gpu = GpuSpec::a100();
    let coord = Coordinator::new(gpu, cfg);
    let m = coord.optimize(System::Megatron, 11);
    let k = coord.optimize(System::Kareus, 11);
    let mp = m.frontier.min_time().unwrap();
    let kp = k.frontier.min_time().unwrap();
    assert!(kp.time < mp.time * 0.95, "time: kareus {} vs megatron {}", kp.time, mp.time);
    assert!(kp.energy < mp.energy * 0.95, "energy: kareus {} vs megatron {}", kp.energy, mp.energy);
}
