//! Engine acceptance tests: the parallel multi-partition MBO engine must
//! produce *byte-identical* frontiers to the sequential path for a fixed
//! seed (thread count, cache warmth, and worker scheduling must never leak
//! into results), and the sweep must fan the pipeline over GPU × model
//! scenarios with machine-readable JSON output.

use std::collections::BTreeMap;

use kareus::baselines::System;
use kareus::compose::optimize_all_partitions_with;
use kareus::coordinator::Coordinator;
use kareus::engine::{run_sweep, scenario_matrix, sweep_json, EngineConfig, Scenario};
use kareus::frontier::Frontier;
use kareus::mbo::MboResult;
use kareus::partition::{detect_partitions, Partition};
use kareus::sim::gpu::GpuSpec;
use kareus::util::json::Json;
use kareus::workload::{build_nanobatch_pass, Dir, ModelSpec, Parallelism, TrainConfig};

fn qwen_cfg() -> TrainConfig {
    TrainConfig {
        model: ModelSpec::qwen3_1_7b(),
        par: Parallelism::new(8, 1, 2),
        microbatch: 8,
        seq_len: 4096,
        n_microbatches: 8,
        dtype_bytes: 2,
    }
}

fn all_partitions(gpu: &GpuSpec, cfg: &TrainConfig) -> Vec<Partition> {
    let fwd = build_nanobatch_pass(cfg, Dir::Fwd, false, false);
    let bwd = build_nanobatch_pass(cfg, Dir::Bwd, false, false);
    let mut parts = detect_partitions(gpu, &fwd, true);
    parts.extend(detect_partitions(gpu, &bwd, true));
    parts
}

/// Exact bit-level signature of a frontier.
fn frontier_bits(f: &Frontier) -> Vec<(u64, u64, usize)> {
    f.points().iter().map(|p| (p.time.to_bits(), p.energy.to_bits(), p.tag)).collect()
}

/// Exact bit-level signature of a full per-type MBO result set.
type MboBits = Vec<(String, Vec<(u64, u64, usize)>, Vec<u64>, usize)>;
fn mbo_bits(results: &BTreeMap<String, MboResult>) -> MboBits {
    results
        .iter()
        .map(|(ptype, r)| {
            (
                ptype.clone(),
                frontier_bits(&r.frontier),
                r.hv_history.iter().map(|h| h.to_bits()).collect(),
                r.evaluated.len(),
            )
        })
        .collect()
}

#[test]
fn parallel_engine_matches_sequential_bitwise() {
    let gpu = GpuSpec::a100();
    let cfg = qwen_cfg();
    let parts = all_partitions(&gpu, &cfg);
    assert!(parts.len() >= 3, "expected several partition types, got {}", parts.len());
    let comm_group = cfg.par.tp * cfg.par.cp;

    let sequential = EngineConfig::sequential();
    let threaded = EngineConfig::new().with_threads(8);
    let seq = optimize_all_partitions_with(17, &gpu, &parts, comm_group, &sequential);
    let par = optimize_all_partitions_with(17, &gpu, &parts, comm_group, &threaded);
    assert_eq!(mbo_bits(&seq), mbo_bits(&par), "thread count leaked into MBO results");
}

#[test]
fn warm_cache_replay_is_bitwise_identical() {
    let gpu = GpuSpec::a100();
    let cfg = qwen_cfg();
    let parts = all_partitions(&gpu, &cfg);
    let comm_group = cfg.par.tp * cfg.par.cp;

    let engine = EngineConfig::new();
    let cold = optimize_all_partitions_with(23, &gpu, &parts, comm_group, &engine);
    assert!(!engine.mbo_cache.is_empty(), "MBO memoization never populated");
    let warm = optimize_all_partitions_with(23, &gpu, &parts, comm_group, &engine);
    assert_eq!(mbo_bits(&cold), mbo_bits(&warm), "cache warmth leaked into MBO results");

    // A *different* seed must not be served from the cache.
    let other = optimize_all_partitions_with(24, &gpu, &parts, comm_group, &engine);
    assert_ne!(mbo_bits(&cold), mbo_bits(&other), "distinct seeds must diverge");
}

#[test]
fn parallel_coordinator_frontier_byte_identical_to_sequential() {
    // The end-to-end acceptance check: the full coordinator pipeline
    // (partition detection → parallel MBO → microbatch frontiers → 1F1B
    // composition) is byte-identical across engine configurations.
    let gpu = GpuSpec::a100();
    let cfg = qwen_cfg();
    let sequential = Coordinator::new(gpu.clone(), cfg).with_engine(EngineConfig::sequential());
    let parallel = Coordinator::new(gpu, cfg).with_engine(EngineConfig::new());
    let a = sequential.optimize(System::Kareus, 31);
    let b = parallel.optimize(System::Kareus, 31);
    assert_eq!(
        frontier_bits(&a.frontier),
        frontier_bits(&b.frontier),
        "parallel coordinator diverged from sequential"
    );
    assert_eq!(a.mbo_profiling_s.to_bits(), b.mbo_profiling_s.to_bits());
    assert_eq!(a.tflops_per_gpu.to_bits(), b.tflops_per_gpu.to_bits());
}

#[test]
fn hammered_shared_caches_stay_byte_identical_to_sequential() {
    // The serve daemon's steady state: many threads hammer one engine
    // whose MboCache/MeasureCache keys overlap (same partitions, two
    // interleaved seeds). Whatever the interleaving, every thread must
    // get results byte-identical to a cold sequential run of its seed.
    let gpu = GpuSpec::a100();
    let cfg = qwen_cfg();
    let parts = all_partitions(&gpu, &cfg);
    let comm_group = cfg.par.tp * cfg.par.cp;

    let expected: Vec<MboBits> = [51u64, 52]
        .iter()
        .map(|&seed| {
            let engine = EngineConfig::sequential();
            mbo_bits(&optimize_all_partitions_with(seed, &gpu, &parts, comm_group, &engine))
        })
        .collect();

    let shared = EngineConfig::new().with_threads(2);
    let hammers: Vec<_> = (0..6)
        .map(|i| {
            let seed = [51u64, 52][i % 2];
            let gpu = gpu.clone();
            let parts = parts.clone();
            let engine = shared.clone(); // shares caches with every thread
            std::thread::spawn(move || {
                (i, mbo_bits(&optimize_all_partitions_with(seed, &gpu, &parts, comm_group, &engine)))
            })
        })
        .collect();
    for h in hammers {
        let (i, bits) = h.join().expect("hammer thread");
        assert_eq!(
            bits,
            expected[i % 2],
            "thread {i} diverged from the sequential result under cache contention"
        );
    }
    assert!(!shared.mbo_cache.is_empty(), "hammer never populated the shared cache");
    assert!(shared.mbo_cache.hits() > 0, "overlapping keys never hit the shared cache");
}

#[test]
fn sweep_covers_gpu_model_matrix_and_emits_json() {
    // Three GPU×model scenarios through the pipeline; cheap systems keep
    // the test fast (the kareus path is covered by the coordinator test).
    let scenarios: Vec<Scenario> = vec![
        scenario_matrix(
            &[GpuSpec::a100(), GpuSpec::h100()],
            &[ModelSpec::qwen3_1_7b()],
            &[Parallelism::new(8, 1, 2)],
            &[System::MegatronPerseus],
            8,
            4096,
            8,
            5,
        ),
        scenario_matrix(
            &[GpuSpec::v100()],
            &[ModelSpec::llama32_3b()],
            &[Parallelism::new(8, 1, 2)],
            &[System::Megatron],
            8,
            4096,
            8,
            5,
        ),
    ]
    .into_iter()
    .flatten()
    .collect();
    assert_eq!(scenarios.len(), 3);

    let engine = EngineConfig::new();
    let mut lines = Vec::new();
    let outcomes = run_sweep(scenarios, &engine, |l| lines.push(l.to_string()));
    assert_eq!(outcomes.len(), 3);
    assert!(lines.len() >= 3, "sweep reported no progress");
    for o in &outcomes {
        assert!(!o.result.frontier.is_empty(), "{}: empty frontier", o.scenario.label());
        assert!(o.result.tflops_per_gpu > 0.0);
    }
    // Faster GPU, same workload, same system ⇒ faster iterations.
    let t_a100 = outcomes[0].result.frontier.min_time().unwrap().time;
    let t_h100 = outcomes[1].result.frontier.min_time().unwrap().time;
    assert!(t_h100 < t_a100, "H100 ({t_h100}s) should beat A100 ({t_a100}s)");

    // The JSON dump round-trips and carries the full schema.
    let dump = sweep_json(&outcomes, &engine, false).dump();
    let parsed = Json::parse(&dump).unwrap();
    assert_eq!(parsed.get("bench").unwrap().as_str(), Some("kareus_sweep"));
    let scen = parsed.get("scenarios").unwrap().as_arr().unwrap();
    assert_eq!(scen.len(), 3);
    for sc in scen {
        assert!(sc.get("frontier").unwrap().as_arr().unwrap().len() >= 1);
        for key in ["gpu", "model", "parallelism", "system"] {
            assert!(sc.get(key).unwrap().as_str().is_some(), "missing {key}");
        }
        assert!(sc.get("min_iter_time_s").unwrap().as_f64().unwrap() > 0.0);
    }
    assert!(parsed.get("cache").unwrap().get("exec_misses").unwrap().as_f64().unwrap() >= 0.0);
}
