//! srclint: source-level determinism and hygiene lint.
//!
//! The compiler cannot enforce the crate's operational discipline — byte-
//! deterministic artifacts, stdout reserved for artifact JSON, seeded
//! randomness, `unsafe` confined to the FFI boundary. This harness walks
//! `src/**` and enforces those rules with plain substring matching (no
//! external deps), so it runs everywhere `cargo test` runs.
//!
//! Vetted exceptions live in `tests/lint_allowlist.txt`, one
//! `rule path` pair per line (paths relative to `src/`). Allowlist
//! entries that no longer match anything fail the lint too, so the list
//! can only shrink.
//!
//! Scope: comment lines and everything from a column-0 `#[cfg(test)]`
//! line onward (the crate's trailing-test-mod convention) are skipped.

use std::fs;
use std::path::{Path, PathBuf};

struct Rule {
    id: &'static str,
    /// A line violates the rule when it contains any of these...
    needles: &'static [&'static str],
    /// ...and (when non-empty) at least one of these too.
    also: &'static [&'static str],
    /// Path suffixes the rule never applies to. `dir/` prefixes match the
    /// whole directory.
    exempt: &'static [&'static str],
    why: &'static str,
}

const RULES: &[Rule] = &[
    Rule {
        id: "stdout",
        needles: &["println!", "print!", "eprintln!", "eprint!"],
        also: &[],
        exempt: &["main.rs"],
        why: "stdout is reserved for artifact JSON and stderr for the CLI's own progress; \
              library modules report through return values",
    },
    Rule {
        id: "wallclock",
        needles: &["Instant", "SystemTime"],
        also: &[],
        exempt: &["util/bench.rs"],
        why: "wall-clock reads make output non-deterministic; confine them to util::bench",
    },
    Rule {
        id: "hash-collections",
        needles: &["HashMap", "HashSet"],
        also: &[],
        exempt: &["util/"],
        why: "std hash iteration order is randomized per process; anything that can feed \
              emitted output must use BTreeMap/BTreeSet",
    },
    Rule {
        id: "randomness",
        needles: &["thread_rng", "rand::", "RandomState", "getrandom"],
        also: &[],
        exempt: &["util/rng.rs"],
        why: "all randomness flows through the seeded util::rng so runs replay bit-identically",
    },
    Rule {
        id: "unsafe",
        needles: &["unsafe"],
        also: &[],
        exempt: &["runtime/pjrt.rs", "xla/"],
        why: "unsafe stays confined to the PJRT FFI boundary",
    },
    Rule {
        id: "sync-primitives",
        needles: &["std::sync::"],
        also: &["Mutex", "Condvar", "atomic", "mpsc", "RwLock", "Barrier", "OnceLock"],
        exempt: &["util/sync.rs", "modelcheck/"],
        why: "locks, condvars, atomics, and channels flow through the util::sync shims so \
              `--features modelcheck` can model-check every interleaving; raw std::sync \
              primitives are invisible to the explorer (std::sync::Arc is fine — it has no \
              scheduling-visible operations)",
    },
    Rule {
        id: "debug-fmt-json",
        needles: &["{:?}"],
        also: &["Json", ".dump("],
        exempt: &[],
        why: "Debug formatting is not JSON (floats, enums); emit through util::json",
    },
];

fn collect(dir: &Path, out: &mut Vec<PathBuf>) {
    let mut entries: Vec<PathBuf> =
        fs::read_dir(dir).unwrap().map(|e| e.unwrap().path()).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect(&p, out);
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
}

fn exempted(rel: &str, exempt: &[&str]) -> bool {
    exempt.iter().any(|e| rel == *e || (e.ends_with('/') && rel.starts_with(e)))
}

#[test]
fn srclint() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let src = root.join("src");
    let mut files = Vec::new();
    collect(&src, &mut files);
    assert!(!files.is_empty(), "no sources under {}", src.display());

    let allow_path = root.join("tests/lint_allowlist.txt");
    let allow_text = fs::read_to_string(&allow_path)
        .unwrap_or_else(|e| panic!("read {}: {e}", allow_path.display()));
    let mut allow: Vec<(String, String)> = Vec::new();
    for line in allow_text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        let rule = it.next().unwrap().to_string();
        let path = it
            .next()
            .unwrap_or_else(|| panic!("allowlist line needs 'rule path': {line}"))
            .to_string();
        assert!(
            RULES.iter().any(|r| r.id == rule),
            "allowlist names unknown rule '{rule}' (known: {:?})",
            RULES.iter().map(|r| r.id).collect::<Vec<_>>()
        );
        allow.push((rule, path));
    }
    let mut used = vec![false; allow.len()];

    let mut violations: Vec<String> = Vec::new();
    for f in &files {
        let rel = f.strip_prefix(&src).unwrap().to_string_lossy().replace('\\', "/");
        let text = fs::read_to_string(f).unwrap();
        let mut in_tests = false;
        for (ln, line) in text.lines().enumerate() {
            if line.starts_with("#[cfg(test)]") {
                in_tests = true;
            }
            if in_tests || line.trim_start().starts_with("//") {
                continue;
            }
            for rule in RULES {
                if exempted(&rel, rule.exempt) {
                    continue;
                }
                let hit = rule.needles.iter().any(|n| line.contains(n))
                    && (rule.also.is_empty() || rule.also.iter().any(|n| line.contains(n)));
                if !hit {
                    continue;
                }
                if let Some(i) =
                    allow.iter().position(|(r, p)| r == rule.id && p == rel.as_str())
                {
                    used[i] = true;
                    continue;
                }
                violations.push(format!(
                    "[{}] {rel}:{}: {}\n    rule: {}",
                    rule.id,
                    ln + 1,
                    line.trim(),
                    rule.why
                ));
            }
        }
    }

    let stale: Vec<String> = allow
        .iter()
        .zip(&used)
        .filter(|(_, u)| !**u)
        .map(|((r, p), _)| format!("{r} {p}"))
        .collect();
    assert!(
        violations.is_empty() && stale.is_empty(),
        "srclint failed.\n\n{} violation(s):\n{}\n\n{} stale allowlist entrie(s) (remove from \
         tests/lint_allowlist.txt):\n{}\n",
        violations.len(),
        violations.join("\n"),
        stale.len(),
        stale.join("\n")
    );
}
