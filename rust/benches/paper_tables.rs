//! End-to-end benches: one per paper table/figure (deliverable (d)).
//! Each regenerates the experiment and reports wall time. Subset with
//! KAREUS_BENCH=table1,fig3 (comma-separated ids); default runs the
//! fast set; KAREUS_BENCH=all runs everything including the emulation.

use std::time::Instant;

fn main() {
    let sel = std::env::var("KAREUS_BENCH").unwrap_or_else(|_| "fast".to_string());
    let fast: &[&str] = &["table1", "fig3", "fig7", "table8", "fig12", "appA", "appB", "mbo-stats"];
    let all = kareus::paper::ALL_EXPERIMENTS;
    let ids: Vec<&str> = match sel.as_str() {
        "fast" => fast.to_vec(),
        "all" => all.to_vec(),
        s => s
            .split(',')
            .map(|x| x.trim())
            .filter(|x| !x.is_empty())
            .map(|x| {
                // leak to 'static lifetime for uniform handling
                Box::leak(x.to_string().into_boxed_str()) as &str
            })
            .collect(),
    };
    println!("== kareus paper-table benches (KAREUS_BENCH={sel}) ==");
    for id in ids {
        let t0 = Instant::now();
        match kareus::paper::run_experiment(id) {
            Some(out) => {
                let dt = t0.elapsed().as_secs_f64();
                let first = out.lines().next().unwrap_or("");
                println!("{id:12} {dt:8.2}s   {first}");
            }
            None => println!("{id:12} unknown experiment id"),
        }
    }
}
