//! Microbenchmarks of the optimizer stack's hot paths — the §Perf
//! targets in EXPERIMENTS.md. Run via `cargo bench --bench hot_paths`.

use kareus::compose::optimize_all_partitions_with;
use kareus::engine::EngineConfig;
use kareus::frontier::{Frontier, Point};
use kareus::mbo::{optimize_partition_with, space, HalvingParams, MboParams, StrategyKind};
use kareus::partition::{detect_partitions, Partition};
use kareus::pipeline::{greedy_fill, simulate_1f1b, StageMenu};
use kareus::profiler::Profiler;
use kareus::serve::{PlanService, ServeOptions, ServeRequest};
use kareus::sim::exec::{execute_partition, LaunchAt, Schedule};
use kareus::sim::gpu::GpuSpec;
use kareus::surrogate::{Gbdt, GbdtParams};
use kareus::util::bench::bench;
use kareus::util::pool::default_threads;
use kareus::util::rng::Rng;
use kareus::workload::{build_nanobatch_pass, Dir, ModelSpec, Parallelism, TrainConfig};

fn test_partition() -> (GpuSpec, Partition) {
    let gpu = GpuSpec::a100();
    let cfg = TrainConfig {
        model: ModelSpec::qwen3_1_7b(),
        par: Parallelism::new(8, 1, 2),
        microbatch: 8,
        seq_len: 4096,
        n_microbatches: 8,
        dtype_bytes: 2,
    };
    let w = build_nanobatch_pass(&cfg, Dir::Fwd, false, false);
    let parts = detect_partitions(&gpu, &w, true);
    (gpu, parts[0].clone())
}

fn main() {
    println!("== kareus hot-path benchmarks ==");
    let (gpu, part) = test_partition();
    let sched = Schedule::uniform(12, LaunchAt::WithComp(1), 1200);

    // 1. The schedule executor — called ~10^5–10^6 times per MBO sweep.
    bench("sim::execute_partition (overlap)", 0.5, || {
        std::hint::black_box(execute_partition(
            &gpu,
            &part.comps,
            part.comm.as_ref(),
            &sched,
            30.0,
            Some(gpu.tdp_w),
        ));
    });
    bench("sim::execute_partition (sequential)", 0.5, || {
        std::hint::black_box(execute_partition(
            &gpu,
            &part.comps,
            part.comm.as_ref(),
            &Schedule::sequential(1200),
            30.0,
            Some(gpu.tdp_w),
        ));
    });

    // 2. Candidate-space enumeration.
    bench("mbo::candidate_space", 0.3, || {
        std::hint::black_box(space::candidate_space(&gpu, &part, 8));
    });

    // 3. GBDT surrogate training (Appendix C hyperparameters) + predict.
    let mut rng = Rng::new(1);
    let x: Vec<Vec<f64>> = (0..150)
        .map(|_| vec![rng.range_f64(900.0, 1410.0), rng.below(30) as f64, rng.below(5) as f64])
        .collect();
    let y: Vec<f64> = x.iter().map(|v| 1000.0 / v[0] + (v[1] - 12.0).abs()).collect();
    bench("surrogate::Gbdt::fit (150 pts, 100 rounds)", 1.0, || {
        std::hint::black_box(Gbdt::fit(&x, &y, &GbdtParams::default()));
    });
    let model = Gbdt::fit(&x, &y, &GbdtParams::default());
    bench("surrogate::Gbdt::predict x1000", 0.3, || {
        let mut acc = 0.0;
        for xi in &x {
            acc += model.predict(xi);
        }
        std::hint::black_box(acc);
    });

    // 4. Hypervolume / HVI over a realistic frontier.
    let pts: Vec<Point> =
        (0..64).map(|i| Point::new(1.0 + i as f64 * 0.05, 100.0 - i as f64, i)).collect();
    let front = Frontier::from_points(pts);
    bench("frontier::hvi x1000", 0.3, || {
        let mut acc = 0.0;
        for i in 0..1000 {
            acc += front.hvi((1.5 + (i % 50) as f64 * 0.01, 80.0), (10.0, 200.0));
        }
        std::hint::black_box(acc);
    });

    // 5. 1F1B simulation + Perseus greedy at testbed and emulation scale.
    let menu_pts: Vec<(f64, f64, f64)> =
        (0..18).map(|i| (0.1 + 0.004 * i as f64, 60.0 - 1.2 * i as f64, 40.0 - i as f64)).collect();
    let mk_menu = || {
        let f = kareus::compose::MbFrontier::from_points(
            menu_pts
                .iter()
                .map(|&(t, e, d)| kareus::compose::MbPoint {
                    time_s: t,
                    total_j: e,
                    dyn_j: d,
                    plan: kareus::compose::MicrobatchPlan {
                        freq_mhz: 1410,
                        configs: Default::default(),
                        sequential: true,
                    },
                })
                .collect(),
        );
        StageMenu::from_frontiers(&f, &f)
    };
    let menus2: Vec<StageMenu> = (0..2).map(|_| mk_menu()).collect();
    let choice2 = vec![vec![0usize; 16]; 2];
    bench("pipeline::simulate_1f1b (2 stages, 8 µb)", 0.3, || {
        std::hint::black_box(simulate_1f1b(&menus2, &choice2, 8));
    });
    let menus10: Vec<StageMenu> = (0..10).map(|_| mk_menu()).collect();
    let choice10 = vec![vec![0usize; 256]; 10];
    bench("pipeline::simulate_1f1b (10 stages, 128 µb)", 0.5, || {
        std::hint::black_box(simulate_1f1b(&menus10, &choice10, 128));
    });
    bench("pipeline::greedy_fill (2 stages, 8 µb)", 1.0, || {
        std::hint::black_box(greedy_fill(&menus2, 8, 90.0, 2.0));
    });
    bench("pipeline::greedy_fill (10 stages, 128 µb)", 3.0, || {
        std::hint::black_box(greedy_fill(&menus10, 128, 90.0, 60.0));
    });

    // 6. Profiler measurement (thermal + meter simulation).
    let mut prof = Profiler::new(gpu.clone(), Default::default(), 7);
    bench("profiler::measure (5s window sim)", 1.0, || {
        std::hint::black_box(prof.measure(&part, &sched));
    });

    // 7. Multi-partition MBO engine: sequential vs parallel vs warm-cache
    //    replay (§5.1/§6.6 — per-partition optimizations fan out across
    //    workers; identical candidates are simulated once).
    let cfg = TrainConfig {
        model: ModelSpec::qwen3_1_7b(),
        par: Parallelism::new(8, 1, 2),
        microbatch: 8,
        seq_len: 4096,
        n_microbatches: 8,
        dtype_bytes: 2,
    };
    let fwd = build_nanobatch_pass(&cfg, Dir::Fwd, false, false);
    let bwd = build_nanobatch_pass(&cfg, Dir::Bwd, false, false);
    let mut parts = detect_partitions(&gpu, &fwd, true);
    parts.extend(detect_partitions(&gpu, &bwd, true));
    let comm_group = cfg.par.tp * cfg.par.cp;
    let time_once = |label: &str, engine: &EngineConfig| -> f64 {
        let t0 = std::time::Instant::now();
        std::hint::black_box(optimize_all_partitions_with(42, &gpu, &parts, comm_group, engine));
        let dt = t0.elapsed().as_secs_f64();
        println!("{label:55} {dt:8.3} s");
        dt
    };
    let n = default_threads();
    println!("-- engine: {} partition types, {} workers available --", parts.len(), n);
    let t_seq =
        time_once("engine::optimize_all_partitions (sequential)", &EngineConfig::sequential());
    let par_engine = EngineConfig::new();
    let t_par = time_once(
        &format!("engine::optimize_all_partitions (parallel ×{n})"),
        &par_engine,
    );
    let t_warm = time_once("engine::optimize_all_partitions (warm-cache replay)", &par_engine);
    println!(
        "engine speedup: parallel {:.2}x, warm replay {:.0}x",
        t_seq / t_par.max(1e-9),
        t_seq / t_warm.max(1e-9)
    );

    // 8. Plan service request paths: the serve daemon's hit path (plan
    //    cache + response serialization, the steady state) vs its miss
    //    path (one full optimization). The gap is the daemon's reason to
    //    exist — pin both so a regression in either is visible.
    let svc = PlanService::new(EngineConfig::new(), ServeOptions::default());
    let plan_req = ServeRequest::Plan {
        job: "a100:qwen1.7b:tp8pp2:megatron".to_string(),
        target: "max".to_string(),
        seed: 42,
        strategy: None,
    }
    .to_json()
    .dump();
    let t0 = std::time::Instant::now();
    let (first, _) = svc.process_line(&plan_req);
    assert!(first.is_ok(), "bench miss path failed: {first:?}");
    println!("{:55} {:8.3} s", "serve::process_line (miss: full optimization)", t0.elapsed().as_secs_f64());
    bench("serve::process_line (hit: warm plan cache)", 0.3, || {
        std::hint::black_box(svc.process_line(&plan_req));
    });
    let stats_req = ServeRequest::Stats { deterministic: true }.to_json().dump();
    bench("serve::process_line (stats)", 0.3, || {
        std::hint::black_box(svc.process_line(&stats_req));
    });

    // 9. Search strategies on one partition: wall time + simulated
    //    profiling seconds per strategy (the racing strategy's win is the
    //    simulated bill; its wall time also drops with the probe count).
    let n_cands = space::candidate_space(&gpu, &part, 8).len();
    println!("-- strategies: one partition, {n_cands} candidates --");
    for kind in [
        StrategyKind::MultiPass,
        StrategyKind::Halving(HalvingParams::default()),
        StrategyKind::Random,
    ] {
        let mut params = MboParams::for_class(part.size_class());
        params.seed = 42;
        let strategy = kind.build(params).expect("defaults validate");
        let mut prof = Profiler::new(gpu.clone(), Default::default(), 42);
        let t0 = std::time::Instant::now();
        let r = optimize_partition_with(strategy.as_ref(), &mut prof, &part, 8);
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "mbo::strategy {:10} {:8.3} s wall   {:8.0} GPU·s simulated   {:3} measured",
            kind.name(),
            dt,
            r.profiling_cost_s,
            r.evaluated.len()
        );
    }
}
