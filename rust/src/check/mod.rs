//! Static artifact verifier — the diagnostics engine behind `kareus check`
//! (data-flow step ⑧).
//!
//! Every artifact the system emits or consumes — frequency plans, cluster
//! plans, revision logs, execution traces, sweep reports, replan summaries
//! — carries invariants no type system enforces: schedules must not
//! oversubscribe SMs, frequencies must sit inside the `GpuSpec` range,
//! per-slice power must stay under the cap, timelines must be monotonic.
//! This module turns each invariant into a pass that produces
//! [`Diagnostic`]s with stable codes (`K001`, `K010`, …) so violations can
//! be asserted in tests, grepped in CI, and documented once.
//!
//! Reports are byte-deterministic: diagnostics are emitted in document
//! order, messages contain no timestamps or addresses, and the JSON form
//! goes through [`util::json`](crate::util::json) (sorted object keys).
//!
//! The same passes run as debug-mode assertions at the construction seams
//! (`plan::FrequencyPlan::from_iteration`, `cluster::plan_cluster`,
//! `backend::TraceBackend::replay`) via [`assert_no_errors`], so a corrupt
//! artifact trips close to where it was built rather than where it is
//! consumed.

use std::collections::BTreeSet;

use crate::backend::{TRACE_SCHEMA, TRACE_VERSION};
use crate::compose::MicrobatchPlan;
use crate::plan::{FrequencyPlan, ReplanTrigger, RevisionLog, REVISION_SCHEMA, REVISION_VERSION};
use crate::sim::exec::{KernelFreqs, LaunchAt};
use crate::sim::gpu::GpuSpec;
use crate::util::json::{arr, num, obj, s, Json};

/// A launch anchor beyond any plausible kernel count in one microbatch.
const MAX_LAUNCH_INDEX: usize = 4096;
/// Absolute comm-SM ceiling applied when the GPU is unknown.
const ABS_MAX_SMS: u32 = 1024;
/// Relative tolerance for recomputed aggregates (sums replay the emitter's
/// own iteration order, so they should match to the bit; the slack only
/// absorbs decimal round-trips from hand-edited artifacts).
const REL_TOL: f64 = 1e-9;

// ---------------------------------------------------------------------------
// Diagnostics
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Warn,
    Error,
}

impl Severity {
    pub fn as_str(self) -> &'static str {
        match self {
            Severity::Warn => "warn",
            Severity::Error => "error",
        }
    }
}

/// Stable diagnostic codes. Codes are append-only: a released code never
/// changes meaning or severity, so tests and CI greps stay valid.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Code {
    K000,
    K001,
    K002,
    K003,
    K004,
    K005,
    K006,
    K007,
    K008,
    K010,
    K011,
    K012,
    K013,
    K014,
    K015,
    K016,
    K020,
    K021,
    K022,
    K023,
    K024,
    K030,
    K031,
    K032,
    K033,
    K034,
    K041,
    K042,
    K050,
    K051,
    K060,
    K061,
    K062,
    K063,
    K070,
    K071,
    K072,
    K080,
    K081,
    K082,
}

impl Code {
    pub const ALL: [Code; 40] = [
        Code::K000,
        Code::K001,
        Code::K002,
        Code::K003,
        Code::K004,
        Code::K005,
        Code::K006,
        Code::K007,
        Code::K008,
        Code::K010,
        Code::K011,
        Code::K012,
        Code::K013,
        Code::K014,
        Code::K015,
        Code::K016,
        Code::K020,
        Code::K021,
        Code::K022,
        Code::K023,
        Code::K024,
        Code::K030,
        Code::K031,
        Code::K032,
        Code::K033,
        Code::K034,
        Code::K041,
        Code::K042,
        Code::K050,
        Code::K051,
        Code::K060,
        Code::K061,
        Code::K062,
        Code::K063,
        Code::K070,
        Code::K071,
        Code::K072,
        Code::K080,
        Code::K081,
        Code::K082,
    ];

    pub fn as_str(self) -> &'static str {
        match self {
            Code::K000 => "K000",
            Code::K001 => "K001",
            Code::K002 => "K002",
            Code::K003 => "K003",
            Code::K004 => "K004",
            Code::K005 => "K005",
            Code::K006 => "K006",
            Code::K007 => "K007",
            Code::K008 => "K008",
            Code::K010 => "K010",
            Code::K011 => "K011",
            Code::K012 => "K012",
            Code::K013 => "K013",
            Code::K014 => "K014",
            Code::K015 => "K015",
            Code::K016 => "K016",
            Code::K020 => "K020",
            Code::K021 => "K021",
            Code::K022 => "K022",
            Code::K023 => "K023",
            Code::K024 => "K024",
            Code::K030 => "K030",
            Code::K031 => "K031",
            Code::K032 => "K032",
            Code::K033 => "K033",
            Code::K034 => "K034",
            Code::K041 => "K041",
            Code::K042 => "K042",
            Code::K050 => "K050",
            Code::K051 => "K051",
            Code::K060 => "K060",
            Code::K061 => "K061",
            Code::K062 => "K062",
            Code::K063 => "K063",
            Code::K070 => "K070",
            Code::K071 => "K071",
            Code::K072 => "K072",
            Code::K080 => "K080",
            Code::K081 => "K081",
            Code::K082 => "K082",
        }
    }

    pub fn severity(self) -> Severity {
        match self {
            Code::K004
            | Code::K008
            | Code::K015
            | Code::K016
            | Code::K024
            | Code::K033
            | Code::K042
            | Code::K063
            | Code::K072
            | Code::K082 => Severity::Warn,
            _ => Severity::Error,
        }
    }

    /// One-line description of what the code means (the README table).
    pub fn summary(self) -> &'static str {
        match self {
            Code::K000 => "unrecognized or undecodable artifact",
            Code::K001 => "slot count does not match n_stages x n_microbatches x 2",
            Code::K002 => "slots out of canonical (stage, microbatch, fwd/bwd) order",
            Code::K003 => "frequency outside the GPU's supported range",
            Code::K004 => "frequency off the GPU's supported step grid",
            Code::K005 => "communication SM allocation oversubscribes the GPU",
            Code::K006 => "launch/sequential inconsistency in a microbatch plan",
            Code::K007 => "non-finite or out-of-range numeric field",
            Code::K008 => "unknown GPU name; range checks skipped",
            Code::K010 => "feasible slice draws more power than its cap",
            Code::K011 => "recorded aggregate disagrees with recomputation from parts",
            Code::K012 => "slice timeline inconsistent with the cap schedule",
            Code::K013 => "job coverage violation in a slice",
            Code::K014 => "assignment job/point index out of range",
            Code::K015 => "assignment stats disagree with the referenced menu point",
            Code::K016 => "job menu not ascending in time / descending in power",
            Code::K020 => "revision counters not contiguous from 0",
            Code::K021 => "iteration/time ordering violation in a revision sequence",
            Code::K022 => "initial-revision invariant violated",
            Code::K023 => "cap-triggered revision missing its cap value",
            Code::K024 => "revision predicts per-GPU draw above its active cap",
            Code::K030 => "artifact schema version mismatch",
            Code::K031 => "malformed trace key",
            Code::K032 => "invalid trace entry value",
            Code::K033 => "duplicate JSON object key (parser keeps the last)",
            Code::K034 => "trace average frequency exceeds the requested frequency",
            Code::K041 => "invalid sweep scenario or frontier value",
            Code::K042 => "sweep frontier not Pareto-ordered",
            Code::K050 => "replan summary missing or invalid required field",
            Code::K051 => "replan summary counters disagree with its revision list",
            Code::K060 => "loadgen report missing or invalid required field",
            Code::K061 => "loadgen report counters inconsistent",
            Code::K062 => "loadgen report p50 latency exceeds p99",
            Code::K063 => "loadgen report mixes null and non-null wall-clock fields",
            Code::K070 => "per-kernel class frequency outside the GPU's range or step grid",
            Code::K071 => "frequency-transition count inconsistent with the schedule key",
            Code::K072 => "per-kernel memory frequency above its slot's core frequency",
            Code::K080 => "bench report missing or invalid required field",
            Code::K081 => "bench report wall-field nulling inconsistent with its mode",
            Code::K082 => "bench report median latency below its minimum",
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct Diagnostic {
    pub code: Code,
    /// Dotted path into the artifact (`slices[2].assignments[0].power_w`);
    /// empty when the diagnostic applies to the document as a whole.
    pub path: String,
    pub message: String,
}

fn d(code: Code, path: impl Into<String>, message: impl Into<String>) -> Diagnostic {
    Diagnostic { code, path: path.into(), message: message.into() }
}

pub fn has_errors(diags: &[Diagnostic]) -> bool {
    diags.iter().any(|x| x.code.severity() == Severity::Error)
}

/// Panic with every error-level diagnostic. Debug-mode construction seams
/// call this right after building an artifact.
pub fn assert_no_errors(what: &str, diags: &[Diagnostic]) {
    if has_errors(diags) {
        let lines: Vec<String> = diags
            .iter()
            .filter(|x| x.code.severity() == Severity::Error)
            .map(|x| format!("  {} {}: {}", x.code.as_str(), x.path, x.message))
            .collect();
        panic!("{what} failed self-check:\n{}", lines.join("\n"));
    }
}

// ---------------------------------------------------------------------------
// Reports
// ---------------------------------------------------------------------------

/// Which artifact schema a document was recognized as.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    FrequencyPlan,
    ClusterPlan,
    RevisionLog,
    ExecTrace,
    Sweep,
    ReplanSummary,
    LoadgenReport,
    BenchReport,
}

impl ArtifactKind {
    pub fn as_str(self) -> &'static str {
        match self {
            ArtifactKind::FrequencyPlan => "frequency_plan",
            ArtifactKind::ClusterPlan => "cluster_plan",
            ArtifactKind::RevisionLog => "revision_log",
            ArtifactKind::ExecTrace => "exec_trace",
            ArtifactKind::Sweep => "sweep",
            ArtifactKind::ReplanSummary => "replan_summary",
            ArtifactKind::LoadgenReport => "loadgen_report",
            ArtifactKind::BenchReport => "bench_report",
        }
    }
}

/// Identify an artifact from its schema tag. Frequency plans carry no tag
/// and are recognized structurally, so tagged kinds are tried first.
pub fn infer_kind(j: &Json) -> Option<ArtifactKind> {
    let tag = |key: &str| j.get(key).and_then(Json::as_str);
    if tag("plan") == Some("kareus_cluster") {
        return Some(ArtifactKind::ClusterPlan);
    }
    if tag("log") == Some(REVISION_SCHEMA) {
        return Some(ArtifactKind::RevisionLog);
    }
    if tag("trace") == Some(TRACE_SCHEMA) {
        return Some(ArtifactKind::ExecTrace);
    }
    if tag("bench") == Some("kareus_sweep") {
        return Some(ArtifactKind::Sweep);
    }
    if tag("bench") == Some("kareus_bench") {
        return Some(ArtifactKind::BenchReport);
    }
    if tag("summary") == Some("kareus_replan_run") {
        return Some(ArtifactKind::ReplanSummary);
    }
    if tag("report") == Some("kareus_loadgen") {
        return Some(ArtifactKind::LoadgenReport);
    }
    if j.get("slots").is_some() && j.get("n_stages").is_some() {
        return Some(ArtifactKind::FrequencyPlan);
    }
    None
}

/// The result of checking one document.
#[derive(Clone, Debug)]
pub struct Report {
    pub source: String,
    /// `ArtifactKind::as_str()` or `"unknown"`.
    pub kind: &'static str,
    pub diagnostics: Vec<Diagnostic>,
}

impl Report {
    pub fn errors(&self) -> usize {
        self.diagnostics.iter().filter(|x| x.code.severity() == Severity::Error).count()
    }

    pub fn warnings(&self) -> usize {
        self.diagnostics.iter().filter(|x| x.code.severity() == Severity::Warn).count()
    }

    pub fn has_errors(&self) -> bool {
        self.errors() > 0
    }

    /// Human-readable report. Byte-deterministic for a given document.
    pub fn to_text(&self) -> String {
        let mut out = format!("{}: {}\n", self.source, self.kind);
        for x in &self.diagnostics {
            let path = if x.path.is_empty() { "-" } else { x.path.as_str() };
            out.push_str(&format!(
                "{} {:5} {}: {}\n",
                x.code.as_str(),
                x.code.severity().as_str(),
                path,
                x.message
            ));
        }
        out.push_str(&format!("{} error(s), {} warning(s)\n", self.errors(), self.warnings()));
        out
    }

    /// Machine-readable report (sorted keys, so byte-deterministic).
    pub fn to_json(&self) -> Json {
        let diags: Vec<Json> = self
            .diagnostics
            .iter()
            .map(|x| {
                obj(vec![
                    ("code", s(x.code.as_str())),
                    ("message", s(&x.message)),
                    ("path", s(&x.path)),
                    ("severity", s(x.code.severity().as_str())),
                ])
            })
            .collect();
        obj(vec![
            ("check", s("kareus_check")),
            ("version", num(1.0)),
            ("source", s(&self.source)),
            ("kind", s(self.kind)),
            ("errors", num(self.errors() as f64)),
            ("warnings", num(self.warnings() as f64)),
            ("diagnostics", arr(diags)),
        ])
    }
}

/// Check a raw JSON document: parse, identify, and run the matching pass.
/// `gpu` supplies range context for artifacts that do not name their GPU
/// (frequency plans, revision logs); cluster plans name one per job.
pub fn check_text(raw: &str, source: &str, gpu: Option<&GpuSpec>) -> Report {
    let mut report = Report { source: source.to_string(), kind: "unknown", diagnostics: Vec::new() };
    let j = match Json::parse(raw) {
        Ok(j) => j,
        Err(e) => {
            report.diagnostics.push(d(Code::K000, "", format!("not valid JSON: {e}")));
            return report;
        }
    };
    let Some(kind) = infer_kind(&j) else {
        report.diagnostics.push(d(
            Code::K000,
            "",
            "no recognizable schema tag (expected a kareus plan, cluster plan, revision log, \
             trace, sweep, replan summary, loadgen report, or bench report)",
        ));
        return report;
    };
    report.kind = kind.as_str();
    for k in duplicate_object_keys(raw) {
        report.diagnostics.push(d(
            Code::K033,
            "",
            format!("duplicate object key \"{k}\" (the parser keeps the last occurrence)"),
        ));
    }
    let mut diags = match kind {
        ArtifactKind::FrequencyPlan => match FrequencyPlan::from_json(&j) {
            Ok(p) => check_frequency_plan(&p, gpu),
            Err(e) => vec![d(Code::K000, "", format!("frequency plan does not decode: {e}"))],
        },
        ArtifactKind::ClusterPlan => check_cluster_json(&j),
        ArtifactKind::RevisionLog => {
            let v = j.get("version").and_then(Json::as_f64);
            if v != Some(REVISION_VERSION as f64) {
                vec![d(
                    Code::K030,
                    "version",
                    format!(
                        "revision log version {} unsupported (expected {REVISION_VERSION})",
                        fmt_opt(v)
                    ),
                )]
            } else {
                match RevisionLog::from_json(&j) {
                    Ok(log) => check_revision_log(&log, gpu),
                    Err(e) => vec![d(Code::K000, "", format!("revision log does not decode: {e}"))],
                }
            }
        }
        ArtifactKind::ExecTrace => check_trace_json(&j),
        ArtifactKind::Sweep => check_sweep_json(&j),
        ArtifactKind::ReplanSummary => check_summary_json(&j),
        ArtifactKind::LoadgenReport => check_loadgen_json(&j),
        ArtifactKind::BenchReport => check_bench_json(&j),
    };
    report.diagnostics.append(&mut diags);
    report
}

/// Check a file on disk. IO failure is an `Err` (CLI exit 2), not a
/// diagnostic.
pub fn check_file(path: &std::path::Path, gpu: Option<&GpuSpec>) -> Result<Report, String> {
    let raw =
        std::fs::read_to_string(path).map_err(|e| format!("read {}: {e}", path.display()))?;
    Ok(check_text(&raw, &path.display().to_string(), gpu))
}

/// Resolve a GPU by CLI short name (`a100`) or by the full device name
/// cluster-plan jobs record (`A100-SXM4-40GB`).
pub fn resolve_gpu(name: &str) -> Option<GpuSpec> {
    GpuSpec::by_name(name).or_else(|| {
        [GpuSpec::a100(), GpuSpec::h100(), GpuSpec::v100()]
            .into_iter()
            .find(|g| g.name == name)
    })
}

fn fmt_opt(v: Option<f64>) -> String {
    match v {
        Some(x) => format!("{x}"),
        None => "missing".to_string(),
    }
}

fn close(a: f64, b: f64) -> bool {
    (a - b).abs() <= REL_TOL * a.abs().max(b.abs()).max(1.0)
}

fn dir(bwd: bool) -> &'static str {
    if bwd {
        "bwd"
    } else {
        "fwd"
    }
}

// ---------------------------------------------------------------------------
// Frequency plans (K001-K008)
// ---------------------------------------------------------------------------

pub fn check_frequency_plan(p: &FrequencyPlan, gpu: Option<&GpuSpec>) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    plan_pass(p, gpu, "", &mut out);
    out
}

/// Shared pass over one frequency plan. `prefix` is empty or ends with
/// `.` so embedded plans (cluster assignments, revisions) report full
/// paths.
fn plan_pass(p: &FrequencyPlan, gpu: Option<&GpuSpec>, prefix: &str, out: &mut Vec<Diagnostic>) {
    if !p.bubble_s.is_finite() || p.bubble_s < 0.0 {
        out.push(d(
            Code::K007,
            format!("{prefix}bubble_s"),
            format!("bubble_s {} must be finite and >= 0", p.bubble_s),
        ));
    }
    let want = p.n_stages as usize * p.n_microbatches as usize * 2;
    if p.slots.len() != want {
        out.push(d(
            Code::K001,
            format!("{prefix}slots"),
            format!(
                "{} slots, but n_stages x n_microbatches x 2 = {} (every stage runs one fwd \
                 and one bwd per microbatch)",
                p.slots.len(),
                want
            ),
        ));
    } else if p.n_microbatches > 0 {
        let nmb = p.n_microbatches as usize;
        for (i, slot) in p.slots.iter().enumerate() {
            let stage = (i / (2 * nmb)) as u32;
            let mb = ((i / 2) % nmb) as u32;
            let bwd = i % 2 == 1;
            if slot.stage != stage || slot.mb != mb || slot.bwd != bwd {
                out.push(d(
                    Code::K002,
                    format!("{prefix}slots[{i}]"),
                    format!(
                        "slot is (stage {}, mb {}, {}); canonical stage-major order expects \
                         (stage {stage}, mb {mb}, {})",
                        slot.stage,
                        slot.mb,
                        dir(slot.bwd),
                        dir(bwd)
                    ),
                ));
                break; // later slots are shifted noise once one is out of place
            }
        }
    }
    for (i, slot) in p.slots.iter().enumerate() {
        mb_plan_pass(&slot.plan, gpu, &format!("{prefix}slots[{i}].plan"), out);
    }
}

fn mb_plan_pass(mp: &MicrobatchPlan, gpu: Option<&GpuSpec>, path: &str, out: &mut Vec<Diagnostic>) {
    check_freq(mp.freq_mhz, gpu, &format!("{path}.freq_mhz"), out);
    if mp.sequential && !mp.configs.is_empty() {
        out.push(d(
            Code::K006,
            path,
            format!(
                "sequential plan carries {} per-partition configs (sequential plans must have \
                 none)",
                mp.configs.len()
            ),
        ));
    }
    for (name, sc) in &mp.configs {
        let cpath = format!("{path}.configs[{name}]");
        if sc.freq_mhz != mp.freq_mhz {
            out.push(d(
                Code::K006,
                format!("{cpath}.freq_mhz"),
                format!(
                    "config frequency {} MHz disagrees with the plan frequency {} MHz",
                    sc.freq_mhz, mp.freq_mhz
                ),
            ));
        }
        match sc.launch {
            LaunchAt::Sequential => out.push(d(
                Code::K006,
                format!("{cpath}.launch"),
                "overlapped config uses the sequential launch mode; sequential execution must \
                 set the plan flag and drop configs",
            )),
            LaunchAt::WithComp(i) if i >= MAX_LAUNCH_INDEX => out.push(d(
                Code::K006,
                format!("{cpath}.launch"),
                format!(
                    "launch anchor c{i} exceeds any plausible kernel count (limit \
                     {MAX_LAUNCH_INDEX})"
                ),
            )),
            LaunchAt::WithComp(_) => {}
        }
        match gpu {
            Some(g) if sc.comm_sms >= g.n_sms => out.push(d(
                Code::K005,
                format!("{cpath}.sms"),
                format!(
                    "{} comm SMs leaves no compute SMs on {} ({} SMs total)",
                    sc.comm_sms, g.name, g.n_sms
                ),
            )),
            None if sc.comm_sms >= ABS_MAX_SMS => out.push(d(
                Code::K005,
                format!("{cpath}.sms"),
                format!(
                    "{} comm SMs exceeds any known GPU (no GPU given; absolute limit \
                     {ABS_MAX_SMS})",
                    sc.comm_sms
                ),
            )),
            _ => {}
        }
        if let KernelFreqs::PerClass { memory_mhz, .. } = sc.kernel_freqs {
            let mpath = format!("{cpath}.memory_mhz");
            // Unlike core frequencies (K003 range error / K004 grid warn),
            // memory-class assignments only ever come off the enumerated
            // hardware grid, so any off-grid value means corruption: one
            // error code covers range and grid.
            if let Some(g) = gpu {
                if memory_mhz < g.f_min_mhz
                    || memory_mhz > g.f_max_mhz
                    || (memory_mhz - g.f_min_mhz) % g.f_stride_mhz != 0
                {
                    out.push(d(
                        Code::K070,
                        &mpath,
                        format!(
                            "memory-class frequency {memory_mhz} MHz is outside {}'s \
                             [{}, {}] MHz range or off its {}-MHz step grid",
                            g.name, g.f_min_mhz, g.f_max_mhz, g.f_stride_mhz
                        ),
                    ));
                }
            }
            if memory_mhz > sc.freq_mhz {
                out.push(d(
                    Code::K072,
                    &mpath,
                    format!(
                        "memory-class frequency {memory_mhz} MHz exceeds the slot's core \
                         frequency {} MHz (raising the memory class past the core only \
                         wastes energy; likely a corrupted or hand-edited plan)",
                        sc.freq_mhz
                    ),
                ));
            }
        }
    }
}

fn check_freq(f_mhz: u32, gpu: Option<&GpuSpec>, path: &str, out: &mut Vec<Diagnostic>) {
    let Some(g) = gpu else { return };
    if f_mhz < g.f_min_mhz || f_mhz > g.f_max_mhz {
        out.push(d(
            Code::K003,
            path,
            format!(
                "{f_mhz} MHz outside the [{}, {}] MHz range supported by {}",
                g.f_min_mhz, g.f_max_mhz, g.name
            ),
        ));
    } else if (f_mhz - g.f_min_mhz) % g.f_stride_mhz != 0 {
        out.push(d(
            Code::K004,
            path,
            format!(
                "{f_mhz} MHz is not on {}'s {}-MHz step grid starting at {} MHz",
                g.name, g.f_stride_mhz, g.f_min_mhz
            ),
        ));
    }
}

// ---------------------------------------------------------------------------
// Cluster plans (K010-K016)
// ---------------------------------------------------------------------------

/// Checked against the raw document (not the typed decoder) so corrupt
/// timelines that the typed constructor would reject still get precise
/// diagnostics instead of a blanket decode failure.
pub fn check_cluster_json(j: &Json) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    if j.get("version").and_then(Json::as_f64) != Some(1.0) {
        out.push(d(
            Code::K030,
            "version",
            format!(
                "cluster plan version {} unsupported (expected 1)",
                fmt_opt(j.get("version").and_then(Json::as_f64))
            ),
        ));
        return out;
    }

    // Cap timeline: starts at 0, strictly ascending, finite positive caps.
    let mut cap_segs: Vec<(f64, f64)> = Vec::new();
    let mut segs_ok = false;
    match j.get("cap_schedule").and_then(Json::as_arr) {
        None => out.push(d(Code::K012, "cap_schedule", "missing or not an array")),
        Some(segs) => {
            segs_ok = true;
            for (i, seg) in segs.iter().enumerate() {
                let start = seg.get("start_s").and_then(Json::as_f64);
                let cap = seg.get("cap_w").and_then(Json::as_f64);
                match (start, cap) {
                    (Some(t), Some(w)) if t.is_finite() && t >= 0.0 && w.is_finite() && w > 0.0 => {
                        cap_segs.push((t, w))
                    }
                    _ => {
                        segs_ok = false;
                        out.push(d(
                            Code::K012,
                            format!("cap_schedule[{i}]"),
                            "segment needs finite start_s >= 0 and finite cap_w > 0",
                        ));
                    }
                }
            }
            if let Some(&(t0, _)) = cap_segs.first() {
                if t0 != 0.0 {
                    out.push(d(
                        Code::K012,
                        "cap_schedule[0].start_s",
                        format!("timeline starts at {t0} s; the first segment must start at 0"),
                    ));
                }
            }
            for w in cap_segs.windows(2) {
                if w[1].0 <= w[0].0 {
                    out.push(d(
                        Code::K012,
                        "cap_schedule",
                        format!(
                            "segment starts must be strictly ascending ({} s then {} s)",
                            w[0].0, w[1].0
                        ),
                    ));
                    break;
                }
            }
        }
    }

    // Jobs: GPU resolution, menu shape and Pareto order, skipped flag.
    struct JobInfo {
        skipped: bool,
        menu: Vec<(f64, f64, f64)>,
        menu_ok: bool,
        gpu: Option<GpuSpec>,
    }
    let mut jobs: Vec<JobInfo> = Vec::new();
    match j.get("jobs").and_then(Json::as_arr) {
        None => out.push(d(Code::K013, "jobs", "missing or not an array")),
        Some(list) => {
            for (ji, jj) in list.iter().enumerate() {
                let label = jj.get("label").and_then(Json::as_str).unwrap_or("?").to_string();
                let gpu_name = jj.get("gpu").and_then(Json::as_str).unwrap_or("");
                let gpu = resolve_gpu(gpu_name);
                if gpu.is_none() {
                    out.push(d(
                        Code::K008,
                        format!("jobs[{ji}].gpu"),
                        format!(
                            "unknown GPU '{gpu_name}'; frequency and SM range checks skipped \
                             for job '{label}'"
                        ),
                    ));
                }
                let skipped = jj.get("skipped").and_then(Json::as_bool).unwrap_or(false);
                let mut menu = Vec::new();
                let mut menu_ok = true;
                match jj.get("menu").and_then(Json::as_arr) {
                    None => {
                        menu_ok = false;
                        out.push(d(
                            Code::K007,
                            format!("jobs[{ji}].menu"),
                            "missing or not an array",
                        ));
                    }
                    Some(pts) => {
                        for (pi, pt) in pts.iter().enumerate() {
                            let p = pt.as_arr().unwrap_or(&[]);
                            let t = p.first().and_then(Json::as_f64).unwrap_or(f64::NAN);
                            let e = p.get(1).and_then(Json::as_f64).unwrap_or(f64::NAN);
                            let w = p.get(2).and_then(Json::as_f64).unwrap_or(f64::NAN);
                            if p.len() != 3
                                || !t.is_finite()
                                || t <= 0.0
                                || !e.is_finite()
                                || e < 0.0
                                || !w.is_finite()
                                || w <= 0.0
                            {
                                menu_ok = false;
                                out.push(d(
                                    Code::K007,
                                    format!("jobs[{ji}].menu[{pi}]"),
                                    "menu point must be [iter_time_s > 0, iter_energy_j >= 0, \
                                     power_w > 0], all finite",
                                ));
                            } else {
                                menu.push((t, e, w));
                            }
                        }
                    }
                }
                if menu_ok {
                    for w2 in menu.windows(2) {
                        if w2[1].0 <= w2[0].0 || w2[1].2 >= w2[0].2 {
                            out.push(d(
                                Code::K016,
                                format!("jobs[{ji}].menu"),
                                format!(
                                    "menu for '{label}' must be strictly ascending in time and \
                                     strictly descending in power"
                                ),
                            ));
                            break;
                        }
                    }
                    if skipped != menu.is_empty() {
                        out.push(d(
                            Code::K013,
                            format!("jobs[{ji}].skipped"),
                            format!(
                                "job '{label}': skipped={skipped} but its menu has {} points \
                                 (skipped must mean an empty menu)",
                                menu.len()
                            ),
                        ));
                    }
                }
                jobs.push(JobInfo { skipped, menu, menu_ok, gpu });
            }
        }
    }

    // Slices: 1:1 with cap segments, power sums, coverage, embedded plans.
    match j.get("slices").and_then(Json::as_arr) {
        None => out.push(d(Code::K012, "slices", "missing or not an array")),
        Some(slices) => {
            if segs_ok && slices.len() != cap_segs.len() {
                out.push(d(
                    Code::K012,
                    "slices",
                    format!(
                        "{} slices but {} cap segments (slices must be 1:1 with segments)",
                        slices.len(),
                        cap_segs.len()
                    ),
                ));
            }
            for (si, sl) in slices.iter().enumerate() {
                let path = format!("slices[{si}]");
                let start = sl.get("start_s").and_then(Json::as_f64).unwrap_or(f64::NAN);
                let cap = sl.get("cap_w").and_then(Json::as_f64).unwrap_or(f64::NAN);
                let feasible = sl.get("feasible").and_then(Json::as_bool).unwrap_or(true);
                let total = sl.get("total_power_w").and_then(Json::as_f64).unwrap_or(f64::NAN);
                let tokens = sl.get("tokens_per_s").and_then(Json::as_f64).unwrap_or(f64::NAN);
                if segs_ok {
                    if let Some(&(seg_t, seg_w)) = cap_segs.get(si) {
                        if start != seg_t || cap != seg_w {
                            out.push(d(
                                Code::K012,
                                &path,
                                format!(
                                    "slice (start {start} s, cap {cap} W) disagrees with cap \
                                     segment {si} (start {seg_t} s, cap {seg_w} W)"
                                ),
                            ));
                        }
                    }
                }
                if !total.is_finite() || total < 0.0 {
                    out.push(d(
                        Code::K007,
                        format!("{path}.total_power_w"),
                        "must be finite and >= 0",
                    ));
                    continue;
                }
                if !tokens.is_finite() || tokens < 0.0 {
                    out.push(d(
                        Code::K007,
                        format!("{path}.tokens_per_s"),
                        "must be finite and >= 0",
                    ));
                }
                let Some(asgs) = sl.get("assignments").and_then(Json::as_arr) else {
                    out.push(d(
                        Code::K013,
                        format!("{path}.assignments"),
                        "missing or not an array",
                    ));
                    continue;
                };
                let mut covered: Vec<u32> = vec![0; jobs.len()];
                let mut sum_w = 0.0;
                for (ai, a) in asgs.iter().enumerate() {
                    let apath = format!("{path}.assignments[{ai}]");
                    let aw = a.get("power_w").and_then(Json::as_f64).unwrap_or(f64::NAN);
                    let at = a.get("iter_time_s").and_then(Json::as_f64).unwrap_or(f64::NAN);
                    let ae = a.get("iter_energy_j").and_then(Json::as_f64).unwrap_or(f64::NAN);
                    if !aw.is_finite() || aw < 0.0 || !at.is_finite() || at <= 0.0 || !ae.is_finite() || ae < 0.0 {
                        out.push(d(
                            Code::K007,
                            &apath,
                            "assignment stats must be finite (power_w >= 0, iter_time_s > 0, \
                             iter_energy_j >= 0)",
                        ));
                    } else {
                        sum_w += aw;
                    }
                    let Some(ji) = a.get("job").and_then(Json::as_usize) else {
                        out.push(d(Code::K014, format!("{apath}.job"), "missing job index"));
                        continue;
                    };
                    if ji >= jobs.len() {
                        out.push(d(
                            Code::K014,
                            format!("{apath}.job"),
                            format!("job index {ji} out of range ({} jobs)", jobs.len()),
                        ));
                        continue;
                    }
                    covered[ji] += 1;
                    let job = &jobs[ji];
                    if job.skipped {
                        out.push(d(
                            Code::K013,
                            &apath,
                            format!("job {ji} is skipped but assigned in this slice"),
                        ));
                    }
                    if let Some(pi) = a.get("point").and_then(Json::as_usize) {
                        if job.menu_ok {
                            if pi >= job.menu.len() {
                                out.push(d(
                                    Code::K014,
                                    format!("{apath}.point"),
                                    format!(
                                        "point index {pi} out of range (menu has {} points)",
                                        job.menu.len()
                                    ),
                                ));
                            } else {
                                let (mt, me, mw) = job.menu[pi];
                                if !close(at, mt) || !close(ae, me) || !close(aw, mw) {
                                    out.push(d(
                                        Code::K015,
                                        &apath,
                                        format!(
                                            "assignment stats (t {at}, e {ae}, p {aw}) disagree \
                                             with menu point {pi} (t {mt}, e {me}, p {mw})"
                                        ),
                                    ));
                                }
                            }
                        }
                    } else {
                        out.push(d(Code::K014, format!("{apath}.point"), "missing point index"));
                    }
                    if let Some(pj) = a.get("plan") {
                        match FrequencyPlan::from_json(pj) {
                            Ok(p) => {
                                plan_pass(&p, job.gpu.as_ref(), &format!("{apath}.plan."), &mut out)
                            }
                            Err(e) => out.push(d(
                                Code::K000,
                                format!("{apath}.plan"),
                                format!("embedded frequency plan does not decode: {e}"),
                            )),
                        }
                    }
                }
                for (ji, job) in jobs.iter().enumerate() {
                    if job.skipped || !job.menu_ok {
                        continue;
                    }
                    match covered[ji] {
                        0 => out.push(d(
                            Code::K013,
                            &path,
                            format!("job {ji} has no assignment in this slice"),
                        )),
                        1 => {}
                        n => out.push(d(
                            Code::K013,
                            &path,
                            format!("job {ji} assigned {n} times in this slice"),
                        )),
                    }
                }
                if feasible && cap.is_finite() && total > cap * (1.0 + 1e-8) {
                    out.push(d(
                        Code::K010,
                        format!("{path}.total_power_w"),
                        format!("feasible slice draws {total} W, above its {cap} W cap"),
                    ));
                }
                if !close(total, sum_w) {
                    out.push(d(
                        Code::K011,
                        format!("{path}.total_power_w"),
                        format!("recorded {total} W but the assignments sum to {sum_w} W"),
                    ));
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Revision logs (K020-K024)
// ---------------------------------------------------------------------------

pub fn check_revision_log(log: &RevisionLog, gpu: Option<&GpuSpec>) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    if log.revisions.is_empty() {
        out.push(d(
            Code::K022,
            "revisions",
            "log has no revisions; the initial deployment must be recorded as revision 0",
        ));
        return out;
    }
    for (i, r) in log.revisions.iter().enumerate() {
        let path = format!("revisions[{i}]");
        if r.revision as usize != i {
            out.push(d(
                Code::K020,
                format!("{path}.revision"),
                format!(
                    "revision counter {} at position {i}; counters must run 0, 1, 2, ...",
                    r.revision
                ),
            ));
        }
        if !r.sim_time_s.is_finite() || r.sim_time_s < 0.0 {
            out.push(d(Code::K007, format!("{path}.sim_time_s"), "must be finite and >= 0"));
        }
        if !r.iter_time_s.is_finite() || r.iter_time_s <= 0.0 {
            out.push(d(Code::K007, format!("{path}.iter_time_s"), "must be finite and > 0"));
        }
        if !r.iter_energy_j.is_finite() || r.iter_energy_j < 0.0 {
            out.push(d(Code::K007, format!("{path}.iter_energy_j"), "must be finite and >= 0"));
        }
        if i == 0 {
            if r.trigger != ReplanTrigger::Initial {
                out.push(d(
                    Code::K022,
                    format!("{path}.trigger"),
                    format!(
                        "first revision triggered by '{}'; the first entry must be the \
                         'initial' deployment",
                        r.trigger.as_str()
                    ),
                ));
            }
            if r.at_iter != 0 {
                out.push(d(
                    Code::K022,
                    format!("{path}.at_iter"),
                    format!("initial revision deployed at iteration {}; must be 0", r.at_iter),
                ));
            }
        } else if r.trigger == ReplanTrigger::Initial {
            out.push(d(
                Code::K022,
                format!("{path}.trigger"),
                "'initial' trigger on a non-first revision",
            ));
        }
        if r.trigger == ReplanTrigger::CapBoundary && r.cap_w.is_none() {
            out.push(d(
                Code::K023,
                format!("{path}.cap_w"),
                "cap-triggered revision records no cap value (cause without effect)",
            ));
        }
        if let Some(c) = r.cap_w {
            if !c.is_finite() || c <= 0.0 {
                out.push(d(Code::K007, format!("{path}.cap_w"), "must be finite and > 0"));
            } else if r.iter_time_s > 0.0 && r.iter_energy_j / r.iter_time_s > c * 1.05 {
                out.push(d(
                    Code::K024,
                    &path,
                    format!(
                        "predicted draw {:.1} W exceeds the {c} W cap by more than 5%",
                        r.iter_energy_j / r.iter_time_s
                    ),
                ));
            }
        }
        plan_pass(&r.plan, gpu, &format!("{path}.plan."), &mut out);
    }
    for i in 1..log.revisions.len() {
        let (a, b) = (&log.revisions[i - 1], &log.revisions[i]);
        if b.at_iter < a.at_iter {
            out.push(d(
                Code::K021,
                format!("revisions[{i}].at_iter"),
                format!("iteration {} is before the previous revision's {}", b.at_iter, a.at_iter),
            ));
        }
        if b.sim_time_s < a.sim_time_s {
            out.push(d(
                Code::K021,
                format!("revisions[{i}].sim_time_s"),
                format!("time {} s is before the previous revision's {} s", b.sim_time_s, a.sim_time_s),
            ));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Execution traces (K030-K034)
// ---------------------------------------------------------------------------

pub fn check_trace_json(j: &Json) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    if j.get("trace").and_then(Json::as_str) != Some(TRACE_SCHEMA) {
        out.push(d(Code::K000, "trace", "missing kareus_exec_trace schema tag"));
        return out;
    }
    if j.get("version").and_then(Json::as_f64) != Some(TRACE_VERSION as f64) {
        out.push(d(
            Code::K030,
            "version",
            format!(
                "trace version {} unsupported (expected {TRACE_VERSION})",
                fmt_opt(j.get("version").and_then(Json::as_f64))
            ),
        ));
        return out;
    }
    let Some(entries) = j.get("entries").and_then(Json::as_obj) else {
        out.push(d(Code::K000, "entries", "missing or not an object"));
        return out;
    };
    for (key, val) in entries {
        let path = format!("entries[{key}]");
        let key_info = match parse_trace_key(key) {
            Ok(info) => Some(info),
            Err(why) => {
                out.push(d(Code::K031, &path, why));
                None
            }
        };
        let mut field = |name: &str, strictly_positive: bool| -> Option<f64> {
            match val.get(name).and_then(Json::as_f64) {
                Some(x) if x.is_finite() && (x > 0.0 || (!strictly_positive && x >= 0.0)) => {
                    Some(x)
                }
                _ => {
                    out.push(d(
                        Code::K032,
                        format!("{path}.{name}"),
                        format!(
                            "must be finite and {}",
                            if strictly_positive { "> 0" } else { ">= 0" }
                        ),
                    ));
                    None
                }
            }
        };
        let _ = field("time_s", true);
        let _ = field("dyn_j", false);
        let _ = field("static_j", false);
        let _ = field("exposed_comm_s", false);
        let avg = field("avg_freq_mhz", true);
        let _ = field("peak_power_w", false);
        drop(field);
        // `freq_transitions` is optional (zero-transition entries omit it),
        // but when present it must be a count and consistent with the key:
        // a uniform-frequency schedule can never switch mid-partition.
        let transitions = match val.get("freq_transitions") {
            None => None,
            Some(v) => match v.as_f64() {
                Some(x) if x.is_finite() && x >= 0.0 && x.fract() == 0.0 => Some(x),
                _ => {
                    out.push(d(
                        Code::K032,
                        format!("{path}.freq_transitions"),
                        "must be a finite non-negative integer",
                    ));
                    None
                }
            },
        };
        if let (Some((f, mem)), Some(a)) = (key_info, avg) {
            let bound = mem.map_or(f, |m| f.max(m));
            if a > bound * (1.0 + REL_TOL) {
                out.push(d(
                    Code::K034,
                    format!("{path}.avg_freq_mhz"),
                    format!(
                        "average frequency {a} MHz exceeds the requested {bound} MHz \
                         (throttling can only lower it)"
                    ),
                ));
            }
        }
        if let (Some((_, mem)), Some(n)) = (key_info, transitions) {
            if mem.is_none() && n > 0.0 {
                out.push(d(
                    Code::K071,
                    format!("{path}.freq_transitions"),
                    format!(
                        "{n} frequency transition(s) recorded for a uniform-frequency key \
                         (uniform schedules never switch mid-partition)"
                    ),
                ));
            }
        }
    }
    out
}

/// Validate one trace key (`fp|sms:launch:freq|temp_bits|limit_bits`,
/// where `freq` is `<mhz>` for uniform schedules or `<mhz>m<mem_mhz>` for
/// per-kernel-class splits) and return the requested core frequency plus
/// the memory-class frequency when the key carries a split.
fn parse_trace_key(key: &str) -> Result<(f64, Option<f64>), String> {
    let parts: Vec<&str> = key.split('|').collect();
    if parts.len() != 4 {
        return Err(format!(
            "key has {} '|'-separated parts, expected 4 (fp|sms:launch:freq|temp|limit)",
            parts.len()
        ));
    }
    let hex16 = |text: &str, what: &str| -> Result<u64, String> {
        if text.len() != 16 {
            return Err(format!("{what} field '{text}' must be 16 hex digits"));
        }
        u64::from_str_radix(text, 16).map_err(|_| format!("{what} field '{text}' must be 16 hex digits"))
    };
    hex16(parts[0], "fingerprint")?;
    let temp = hex16(parts[2], "temperature")?;
    if !f64::from_bits(temp).is_finite() {
        return Err("temperature bits decode to a non-finite value".to_string());
    }
    let limit = hex16(parts[3], "power-limit")?;
    if limit != u64::MAX {
        let w = f64::from_bits(limit);
        if !w.is_finite() || w <= 0.0 {
            return Err("power-limit bits decode to a non-positive or non-finite value".to_string());
        }
    }
    let mid: Vec<&str> = parts[1].split(':').collect();
    if mid.len() != 3 {
        return Err(format!("schedule field '{}' must be sms:launch:freq", parts[1]));
    }
    mid[0]
        .parse::<u32>()
        .map_err(|_| format!("comm-SM count '{}' is not an integer", mid[0]))?;
    if mid[1] != "seq" {
        let idx = mid[1]
            .strip_prefix('c')
            .ok_or_else(|| format!("launch '{}' must be 'seq' or 'c<i>'", mid[1]))?;
        idx.parse::<u32>().map_err(|_| format!("launch '{}' must be 'seq' or 'c<i>'", mid[1]))?;
    }
    let parse_freq = |text: &str| -> Result<f64, String> {
        let f: u32 =
            text.parse().map_err(|_| format!("frequency '{text}' is not an integer"))?;
        if f == 0 {
            return Err("frequency must be > 0".to_string());
        }
        Ok(f as f64)
    };
    match mid[2].split_once('m') {
        None => Ok((parse_freq(mid[2])?, None)),
        Some((core, mem)) => Ok((parse_freq(core)?, Some(parse_freq(mem)?))),
    }
}

// ---------------------------------------------------------------------------
// Sweep reports (K041-K042)
// ---------------------------------------------------------------------------

pub fn check_sweep_json(j: &Json) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    if j.get("version").and_then(Json::as_f64) != Some(1.0) {
        out.push(d(
            Code::K030,
            "version",
            format!(
                "sweep version {} unsupported (expected 1)",
                fmt_opt(j.get("version").and_then(Json::as_f64))
            ),
        ));
        return out;
    }
    let Some(scenarios) = j.get("scenarios").and_then(Json::as_arr) else {
        out.push(d(Code::K041, "scenarios", "missing or not an array"));
        return out;
    };
    for (i, sc) in scenarios.iter().enumerate() {
        let path = format!("scenarios[{i}]");
        let Some(front) = sc.get("frontier").and_then(Json::as_arr) else {
            out.push(d(Code::K041, format!("{path}.frontier"), "missing or not an array"));
            continue;
        };
        let mut pts = Vec::new();
        let mut ok = true;
        for (pi, pt) in front.iter().enumerate() {
            let p = pt.as_arr().unwrap_or(&[]);
            let t = p.first().and_then(Json::as_f64).unwrap_or(f64::NAN);
            let e = p.get(1).and_then(Json::as_f64).unwrap_or(f64::NAN);
            if p.len() != 2 || !t.is_finite() || t <= 0.0 || !e.is_finite() || e < 0.0 {
                ok = false;
                out.push(d(
                    Code::K041,
                    format!("{path}.frontier[{pi}]"),
                    "frontier point must be [iter_time_s > 0, iter_energy_j >= 0], finite",
                ));
            } else {
                pts.push((t, e));
            }
        }
        if !ok {
            continue;
        }
        for w in pts.windows(2) {
            if w[1].0 <= w[0].0 || w[1].1 >= w[0].1 {
                out.push(d(
                    Code::K042,
                    format!("{path}.frontier"),
                    "frontier must be strictly ascending in time and strictly descending in \
                     energy (dominated points filtered)",
                ));
                break;
            }
        }
        if let (Some(min_t), Some(&(t0, _))) =
            (sc.get("min_iter_time_s").and_then(Json::as_f64), pts.first())
        {
            if min_t.is_finite() && !close(min_t, t0) {
                out.push(d(
                    Code::K042,
                    format!("{path}.min_iter_time_s"),
                    format!("{min_t} disagrees with the frontier's fastest point {t0}"),
                ));
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Replan summaries (K050-K051)
// ---------------------------------------------------------------------------

pub fn check_summary_json(j: &Json) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for key in ["n_iters", "total_time_s", "total_energy_j", "deadline_s", "replans", "measurements_billed"]
    {
        match j.get(key).and_then(Json::as_f64) {
            Some(x) if x.is_finite() && x >= 0.0 => {}
            _ => out.push(d(
                Code::K050,
                key,
                "required field missing or not a finite non-negative number",
            )),
        }
    }
    let Some(revs) = j.get("revisions").and_then(Json::as_arr) else {
        out.push(d(Code::K050, "revisions", "missing or not an array"));
        return out;
    };
    if revs.is_empty() {
        out.push(d(Code::K050, "revisions", "summary records no revisions (need the initial one)"));
        return out;
    }
    let mut prev_iter = -1.0;
    for (i, r) in revs.iter().enumerate() {
        let path = format!("revisions[{i}]");
        match r.get("revision").and_then(Json::as_f64) {
            Some(x) if x == i as f64 => {}
            v => out.push(d(
                Code::K020,
                format!("{path}.revision"),
                format!("revision counter {} at position {i}; counters must run 0, 1, 2, ...", fmt_opt(v)),
            )),
        }
        let at = r.get("at_iter").and_then(Json::as_f64).unwrap_or(f64::NAN);
        if !at.is_finite() || at < 0.0 {
            out.push(d(Code::K050, format!("{path}.at_iter"), "missing or negative"));
        } else {
            if at < prev_iter {
                out.push(d(
                    Code::K021,
                    format!("{path}.at_iter"),
                    format!("iteration {at} is before the previous revision's {prev_iter}"),
                ));
            }
            prev_iter = at;
        }
    }
    if let Some(replans) = j.get("replans").and_then(Json::as_f64) {
        let want = (revs.len() - 1) as f64;
        if replans != want {
            out.push(d(
                Code::K051,
                "replans",
                format!(
                    "summary records {replans} replans but lists {} revisions (expected {} = \
                     revisions - 1, the initial deployment is not a replan)",
                    revs.len(),
                    want
                ),
            ));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Loadgen reports (K060-K063)
// ---------------------------------------------------------------------------

/// Verify a `kareus_loadgen` report (`kareus loadgen` output):
/// counter presence and non-negativity (K060), counter identities
/// `ok + busy + errors = requests` and `hits + misses = ok` (K061),
/// percentile ordering `p50 <= p99` (K062), and consistent
/// deterministic-mode nulling of the wall-clock fields (K063, warn).
pub fn check_loadgen_json(j: &Json) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    if j.get("version").and_then(Json::as_f64) != Some(1.0) {
        out.push(d(
            Code::K030,
            "version",
            format!(
                "loadgen report version {} unsupported (expected 1)",
                fmt_opt(j.get("version").and_then(Json::as_f64))
            ),
        ));
        return out;
    }
    // Required counters: finite non-negative integers.
    let mut counter = |key: &str| -> Option<f64> {
        match j.get(key).and_then(Json::as_f64) {
            Some(x) if x.is_finite() && x >= 0.0 && x.fract() == 0.0 => Some(x),
            _ => {
                out.push(d(
                    Code::K060,
                    key,
                    "required counter missing or not a non-negative integer",
                ));
                None
            }
        }
    };
    let requests = counter("requests");
    let concurrency = counter("concurrency");
    let ok = counter("ok");
    let errors = counter("errors");
    let busy = counter("busy");
    let hits = counter("hits");
    let misses = counter("misses");
    if requests == Some(0.0) {
        out.push(d(Code::K060, "requests", "report covers zero requests"));
    }
    if concurrency == Some(0.0) {
        out.push(d(Code::K060, "concurrency", "concurrency must be >= 1"));
    }
    match j.get("jobs").and_then(Json::as_arr) {
        Some(jobs) if !jobs.is_empty() => {
            for (i, job) in jobs.iter().enumerate() {
                if job.as_str().is_none() {
                    out.push(d(Code::K060, format!("jobs[{i}]"), "job spec must be a string"));
                }
            }
        }
        _ => out.push(d(Code::K060, "jobs", "missing, not an array, or empty")),
    }
    if j.get("target").and_then(Json::as_str).is_none() {
        out.push(d(Code::K060, "target", "missing or not a string"));
    }
    // Counter identities: every request resolves exactly one way, and
    // every ok plan response came from the cache either warm or cold.
    if let (Some(requests), Some(ok), Some(errors), Some(busy)) = (requests, ok, errors, busy) {
        if ok + busy + errors != requests {
            out.push(d(
                Code::K061,
                "requests",
                format!("ok {ok} + busy {busy} + errors {errors} != requests {requests}"),
            ));
        }
    }
    if let (Some(ok), Some(hits), Some(misses)) = (ok, hits, misses) {
        if hits + misses != ok {
            out.push(d(
                Code::K061,
                "hits",
                format!("hits {hits} + misses {misses} != ok {ok}"),
            ));
        }
        match j.get("hit_rate") {
            Some(Json::Null) | None => {
                if hits + misses > 0.0 {
                    out.push(d(
                        Code::K061,
                        "hit_rate",
                        "null although the cache answered at least one request",
                    ));
                }
            }
            Some(v) => match v.as_f64() {
                Some(r) if hits + misses > 0.0 => {
                    let want = hits / (hits + misses);
                    if !close(r, want) {
                        out.push(d(
                            Code::K061,
                            "hit_rate",
                            format!("{r} disagrees with hits/(hits+misses) = {want}"),
                        ));
                    }
                }
                Some(_) => out.push(d(
                    Code::K061,
                    "hit_rate",
                    "non-null although the cache answered no requests",
                )),
                None => out.push(d(Code::K060, "hit_rate", "must be a number or null")),
            },
        }
    }
    // Wall-clock fields: each is null (deterministic mode) or a finite
    // non-negative number, and the nulling must be all-or-nothing.
    let latency = j.get("latency");
    if latency.is_none() {
        out.push(d(Code::K060, "latency", "missing latency object"));
    }
    let mut nulled = 0usize;
    let mut live = 0usize;
    let mut wall = |path: String, v: Option<&Json>| -> Option<f64> {
        match v {
            None => {
                out.push(d(Code::K060, path, "missing wall-clock field (use null, not absence)"));
                None
            }
            Some(Json::Null) => {
                nulled += 1;
                None
            }
            Some(x) => match x.as_f64() {
                Some(f) if f.is_finite() && f >= 0.0 => {
                    live += 1;
                    Some(f)
                }
                _ => {
                    out.push(d(Code::K060, path, "must be null or a finite non-negative number"));
                    None
                }
            },
        }
    };
    let p50 = wall("latency.p50_ms".into(), latency.and_then(|l| l.get("p50_ms")));
    let p99 = wall("latency.p99_ms".into(), latency.and_then(|l| l.get("p99_ms")));
    for key in ["mean_ms", "min_ms", "max_ms"] {
        wall(format!("latency.{key}"), latency.and_then(|l| l.get(key)));
    }
    for key in ["requests_per_s", "wall_s"] {
        wall(key.to_string(), j.get(key));
    }
    // addr is wall-ish provenance (ephemeral ports): null or a string.
    match j.get("addr") {
        None => out.push(d(Code::K060, "addr", "missing (use null in deterministic mode)")),
        Some(Json::Null) => nulled += 1,
        Some(v) if v.as_str().is_some() => live += 1,
        Some(_) => out.push(d(Code::K060, "addr", "must be null or a string")),
    }
    if let (Some(p50), Some(p99)) = (p50, p99) {
        if p50 > p99 {
            out.push(d(
                Code::K062,
                "latency.p50_ms",
                format!("p50 {p50} ms exceeds p99 {p99} ms"),
            ));
        }
    }
    if nulled > 0 && live > 0 {
        out.push(d(
            Code::K063,
            "",
            format!(
                "{nulled} wall-clock field(s) are null but {live} are not — deterministic-mode \
                 nulling must cover all of them or none"
            ),
        ));
    }
    out
}

// ---------------------------------------------------------------------------
// Bench reports (K080-K082)
// ---------------------------------------------------------------------------

/// Verify a `kareus_bench` report (`kareus bench` output): required-field
/// shape (K080), the deterministic-mode contract that the `deterministic`
/// flag and the wall fields — per-entry `iters`/`min_ns`/`median_ns`/
/// `mean_ns` and top-level `wall_s` — agree, all null or all populated
/// (K081), and per-entry `median_ns >= min_ns` (K082, warn).
pub fn check_bench_json(j: &Json) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    if j.get("version").and_then(Json::as_f64) != Some(1.0) {
        out.push(d(
            Code::K030,
            "version",
            format!(
                "bench report version {} unsupported (expected 1)",
                fmt_opt(j.get("version").and_then(Json::as_f64))
            ),
        ));
        return out;
    }
    let Some(deterministic) = j.get("deterministic").and_then(Json::as_bool) else {
        out.push(d(Code::K080, "deterministic", "missing or not a boolean"));
        return out;
    };
    let Some(entries) = j.get("entries").and_then(Json::as_obj) else {
        out.push(d(Code::K080, "entries", "missing or not an object"));
        return out;
    };
    if entries.is_empty() {
        out.push(d(Code::K080, "entries", "bench report covers zero entries"));
    }
    // Wall fields are null in deterministic mode and populated otherwise;
    // mixing within one report breaks the byte-for-byte CI diff contract.
    let mut nulled = 0usize;
    let mut live = 0usize;
    for (name, e) in entries {
        let path = format!("entries.{name}");
        match e.get("counters").and_then(Json::as_obj) {
            Some(counters) => {
                for (k, v) in counters {
                    match v.as_f64() {
                        Some(x) if x.is_finite() && x >= 0.0 && x.fract() == 0.0 => {}
                        _ => out.push(d(
                            Code::K080,
                            format!("{path}.counters.{k}"),
                            "counter must be a non-negative integer",
                        )),
                    }
                }
            }
            None => out.push(d(Code::K080, format!("{path}.counters"), "missing or not an object")),
        }
        let mut wall = |key: &str| -> Option<f64> {
            match e.get(key) {
                None => {
                    out.push(d(
                        Code::K080,
                        format!("{path}.{key}"),
                        "missing wall-clock field (use null, not absence)",
                    ));
                    None
                }
                Some(Json::Null) => {
                    nulled += 1;
                    None
                }
                Some(x) => match x.as_f64() {
                    Some(f) if f.is_finite() && f >= 0.0 => {
                        live += 1;
                        Some(f)
                    }
                    _ => {
                        out.push(d(
                            Code::K080,
                            format!("{path}.{key}"),
                            "must be null or a finite non-negative number",
                        ));
                        None
                    }
                },
            }
        };
        let iters = wall("iters");
        let min = wall("min_ns");
        let median = wall("median_ns");
        wall("mean_ns");
        if let Some(i) = iters {
            if i.fract() != 0.0 {
                out.push(d(Code::K080, format!("{path}.iters"), "must be an integer"));
            }
        }
        if let (Some(min), Some(median)) = (min, median) {
            if median < min {
                out.push(d(
                    Code::K082,
                    format!("{path}.median_ns"),
                    format!("median {median} ns is below min {min} ns"),
                ));
            }
        }
    }
    match j.get("wall_s") {
        None => {
            out.push(d(Code::K080, "wall_s", "missing wall-clock field (use null, not absence)"))
        }
        Some(Json::Null) => nulled += 1,
        Some(x) => match x.as_f64() {
            Some(f) if f.is_finite() && f >= 0.0 => live += 1,
            _ => out.push(d(Code::K080, "wall_s", "must be null or a finite non-negative number")),
        },
    }
    if deterministic && live > 0 {
        out.push(d(
            Code::K081,
            "deterministic",
            format!(
                "{live} wall-clock field(s) populated in a deterministic report — \
                 deterministic mode must null all of them"
            ),
        ));
    } else if !deterministic && nulled > 0 && live > 0 {
        out.push(d(
            Code::K081,
            "",
            format!(
                "{nulled} wall-clock field(s) are null but {live} are not — a timed report \
                 must populate all of them"
            ),
        ));
    } else if !deterministic && live == 0 && nulled > 0 {
        out.push(d(
            Code::K081,
            "deterministic",
            "every wall-clock field is null but the report claims deterministic = false",
        ));
    }
    out
}

// ---------------------------------------------------------------------------
// Duplicate-key scan (K033)
// ---------------------------------------------------------------------------

/// Scan well-formed JSON text for duplicate object keys. The parser's
/// `BTreeMap` silently keeps the last occurrence, so duplicates can only
/// be seen at the raw-text level. Keys are compared as raw (still-escaped)
/// text; the emitter escapes deterministically, so that is exact for any
/// artifact this crate wrote.
pub fn duplicate_object_keys(raw: &str) -> Vec<String> {
    enum Ctx {
        Obj(BTreeSet<String>),
        Arr,
    }
    let b = raw.as_bytes();
    let mut stack: Vec<Ctx> = Vec::new();
    let mut dups = Vec::new();
    let mut expect_key = false;
    let mut i = 0;
    while i < b.len() {
        match b[i] {
            b'{' => {
                stack.push(Ctx::Obj(BTreeSet::new()));
                expect_key = true;
                i += 1;
            }
            b'}' | b']' => {
                stack.pop();
                expect_key = false;
                i += 1;
            }
            b'[' => {
                stack.push(Ctx::Arr);
                expect_key = false;
                i += 1;
            }
            b',' => {
                expect_key = matches!(stack.last(), Some(Ctx::Obj(_)));
                i += 1;
            }
            b':' => {
                expect_key = false;
                i += 1;
            }
            b'"' => {
                let start = i + 1;
                let mut j = start;
                while j < b.len() {
                    match b[j] {
                        b'\\' => j += 2,
                        b'"' => break,
                        _ => j += 1,
                    }
                }
                if expect_key {
                    let key = String::from_utf8_lossy(&b[start..j.min(b.len())]).into_owned();
                    if let Some(Ctx::Obj(seen)) = stack.last_mut() {
                        if !seen.insert(key.clone()) {
                            dups.push(key);
                        }
                    }
                    expect_key = false;
                }
                i = (j + 1).min(b.len());
            }
            _ => i += 1,
        }
    }
    dups
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::SlotPlan;
    use crate::sim::exec::Schedule;
    use std::collections::BTreeMap;

    fn tiny_plan(freq: u32, sms: u32) -> FrequencyPlan {
        let mut configs = BTreeMap::new();
        configs.insert(
            "fwd/attn".to_string(),
            Schedule::uniform(sms, LaunchAt::WithComp(1), freq),
        );
        FrequencyPlan {
            n_stages: 1,
            n_microbatches: 1,
            bubble_s: 0.0,
            slots: vec![
                SlotPlan {
                    stage: 0,
                    mb: 0,
                    bwd: false,
                    plan: MicrobatchPlan { freq_mhz: freq, configs, sequential: false },
                },
                SlotPlan {
                    stage: 0,
                    mb: 0,
                    bwd: true,
                    plan: MicrobatchPlan {
                        freq_mhz: 990,
                        configs: BTreeMap::new(),
                        sequential: true,
                    },
                },
            ],
        }
    }

    fn codes(diags: &[Diagnostic]) -> Vec<Code> {
        diags.iter().map(|x| x.code).collect()
    }

    #[test]
    fn valid_plan_is_clean() {
        let g = GpuSpec::a100();
        let diags = check_frequency_plan(&tiny_plan(1410, 12), Some(&g));
        assert!(diags.is_empty(), "{diags:?}");
    }

    #[test]
    fn freq_out_of_range_is_k003() {
        let g = GpuSpec::a100();
        let diags = check_frequency_plan(&tiny_plan(2000, 12), Some(&g));
        assert!(codes(&diags).contains(&Code::K003), "{diags:?}");
        assert!(has_errors(&diags));
    }

    #[test]
    fn off_grid_freq_is_k004_warn_only() {
        let g = GpuSpec::a100();
        let diags = check_frequency_plan(&tiny_plan(1001, 12), Some(&g));
        assert_eq!(codes(&diags), vec![Code::K004]);
        assert!(!has_errors(&diags));
    }

    #[test]
    fn sm_oversubscription_is_k005() {
        let g = GpuSpec::a100();
        let diags = check_frequency_plan(&tiny_plan(1410, 200), Some(&g));
        assert!(codes(&diags).contains(&Code::K005), "{diags:?}");
    }

    #[test]
    fn unknown_gpu_skips_range_checks() {
        let diags = check_frequency_plan(&tiny_plan(2000, 200), None);
        assert!(diags.is_empty(), "{diags:?}");
    }

    #[test]
    fn slot_count_mismatch_is_k001() {
        let mut p = tiny_plan(1410, 12);
        p.slots.pop();
        let diags = check_frequency_plan(&p, Some(&GpuSpec::a100()));
        assert!(codes(&diags).contains(&Code::K001), "{diags:?}");
    }

    #[test]
    fn slot_order_violation_is_k002() {
        let mut p = tiny_plan(1410, 12);
        p.slots.swap(0, 1);
        let diags = check_frequency_plan(&p, Some(&GpuSpec::a100()));
        assert!(codes(&diags).contains(&Code::K002), "{diags:?}");
    }

    #[test]
    fn sequential_with_configs_is_k006() {
        let mut p = tiny_plan(1410, 12);
        p.slots[0].plan.sequential = true;
        let diags = check_frequency_plan(&p, Some(&GpuSpec::a100()));
        assert!(codes(&diags).contains(&Code::K006), "{diags:?}");
    }

    #[test]
    fn trace_key_roundtrip_ok() {
        let key = crate::backend::trace_key(
            0xdeadbeef,
            &Schedule::uniform(12, LaunchAt::WithComp(1), 1410),
            30.0,
            None,
        );
        assert_eq!(parse_trace_key(&key), Ok((1410.0, None)));
        let capped = crate::backend::trace_key(1, &Schedule::sequential(990), 45.5, Some(250.0));
        assert_eq!(parse_trace_key(&capped), Ok((990.0, None)));
        // Per-kernel splits extend the frequency field.
        let mut split = Schedule::uniform(12, LaunchAt::WithComp(1), 1410);
        split.kernel_freqs = KernelFreqs::PerClass { compute_mhz: 1410, memory_mhz: 900 };
        let skey = crate::backend::trace_key(1, &split, 30.0, None);
        assert_eq!(parse_trace_key(&skey), Ok((1410.0, Some(900.0))));
    }

    #[test]
    fn bad_trace_keys_rejected() {
        assert!(parse_trace_key("garbage").is_err());
        assert!(parse_trace_key("0000000000000000|12:tomorrow:1410|0000000000000000|ffffffffffffffff").is_err());
        assert!(parse_trace_key("0000000000000000|12:c1:0|0000000000000000|ffffffffffffffff").is_err());
        assert!(parse_trace_key("xyz|12:c1:1410|0000000000000000|ffffffffffffffff").is_err());
        // NaN temperature bits
        assert!(parse_trace_key("0000000000000000|12:c1:1410|7ff8000000000000|ffffffffffffffff").is_err());
        // Malformed per-kernel frequency splits
        assert!(parse_trace_key("0000000000000000|12:c1:1410m0|0000000000000000|ffffffffffffffff").is_err());
        assert!(parse_trace_key("0000000000000000|12:c1:1410mx|0000000000000000|ffffffffffffffff").is_err());
    }

    #[test]
    fn duplicate_keys_found_in_raw_text() {
        assert_eq!(duplicate_object_keys(r#"{"a":1,"b":2,"a":3}"#), vec!["a".to_string()]);
        // Values and nested scopes must not confuse the scanner.
        assert!(duplicate_object_keys(r#"{"a":"a","b":{"a":1},"c":["a","a"]}"#).is_empty());
        assert!(duplicate_object_keys(r#"{"a":1,"b":{"x":1,"x":2}}"#) == vec!["x".to_string()]);
    }

    #[test]
    fn infer_kind_recognizes_all_tags() {
        let cases = [
            (r#"{"plan":"kareus_cluster"}"#, ArtifactKind::ClusterPlan),
            (r#"{"log":"kareus_revisions"}"#, ArtifactKind::RevisionLog),
            (r#"{"trace":"kareus_exec_trace"}"#, ArtifactKind::ExecTrace),
            (r#"{"bench":"kareus_sweep"}"#, ArtifactKind::Sweep),
            (r#"{"summary":"kareus_replan_run"}"#, ArtifactKind::ReplanSummary),
            (r#"{"report":"kareus_loadgen"}"#, ArtifactKind::LoadgenReport),
            (r#"{"bench":"kareus_bench"}"#, ArtifactKind::BenchReport),
            (r#"{"slots":[],"n_stages":1}"#, ArtifactKind::FrequencyPlan),
        ];
        for (src, want) in cases {
            assert_eq!(infer_kind(&Json::parse(src).unwrap()), Some(want), "{src}");
        }
        assert_eq!(infer_kind(&Json::parse(r#"{"hello":1}"#).unwrap()), None);
    }

    #[test]
    fn unknown_artifact_is_k000() {
        let r = check_text(r#"{"hello":1}"#, "mem", None);
        assert_eq!(r.kind, "unknown");
        assert_eq!(codes(&r.diagnostics), vec![Code::K000]);
    }

    #[test]
    fn report_is_byte_deterministic() {
        let src = tiny_plan(2000, 200);
        let json = src.to_json().dump();
        let a = check_text(&json, "mem", Some(&GpuSpec::a100()));
        let b = check_text(&json, "mem", Some(&GpuSpec::a100()));
        assert_eq!(a.to_text(), b.to_text());
        assert_eq!(a.to_json().dump(), b.to_json().dump());
        assert!(a.has_errors());
    }

    #[test]
    fn severity_partition_is_stable() {
        // Warn codes are a fixed set; everything else is an error.
        let warns: Vec<Code> =
            Code::ALL.iter().copied().filter(|c| c.severity() == Severity::Warn).collect();
        assert_eq!(
            warns,
            vec![
                Code::K004,
                Code::K008,
                Code::K015,
                Code::K016,
                Code::K024,
                Code::K033,
                Code::K042,
                Code::K063,
                Code::K072,
                Code::K082,
            ]
        );
        for c in Code::ALL {
            assert!(c.as_str().starts_with('K'));
            assert!(!c.summary().is_empty());
        }
    }

    fn per_class_plan(freq: u32, memory: u32) -> FrequencyPlan {
        let mut p = tiny_plan(freq, 12);
        let sc = p.slots[0].plan.configs.get_mut("fwd/attn").expect("config present");
        sc.kernel_freqs = KernelFreqs::PerClass { compute_mhz: freq, memory_mhz: memory };
        p
    }

    #[test]
    fn per_kernel_memory_freq_clean_on_grid() {
        let g = GpuSpec::a100();
        let diags = check_frequency_plan(&per_class_plan(1410, 900), Some(&g));
        assert!(diags.is_empty(), "{diags:?}");
    }

    #[test]
    fn per_kernel_memory_freq_off_grid_is_k070() {
        let g = GpuSpec::a100();
        let diags = check_frequency_plan(&per_class_plan(1410, 907), Some(&g));
        assert_eq!(codes(&diags), vec![Code::K070]);
        assert!(has_errors(&diags));
        // Below the supported range trips the same code.
        let low = check_frequency_plan(&per_class_plan(1410, 60), Some(&g));
        assert!(codes(&low).contains(&Code::K070), "{low:?}");
    }

    #[test]
    fn memory_above_core_is_k072_warn() {
        let g = GpuSpec::a100();
        let diags = check_frequency_plan(&per_class_plan(900, 1410), Some(&g));
        assert_eq!(codes(&diags), vec![Code::K072]);
        assert!(!has_errors(&diags));
    }

    #[test]
    fn uniform_key_with_transitions_is_k071() {
        let entry = r#"{"time_s":0.01,"dyn_j":1.0,"static_j":0.5,"exposed_comm_s":0.0,"avg_freq_mhz":1400.0,"throttled":false,"peak_power_w":300.0,"freq_transitions":2}"#;
        let sched = Schedule::uniform(12, LaunchAt::WithComp(1), 1410);
        let uni = crate::backend::trace_key(1, &sched, 30.0, None);
        let raw = format!(
            "{{\"trace\":\"kareus_exec_trace\",\"version\":1,\"entries\":{{\"{uni}\":{entry}}}}}"
        );
        let r = check_text(&raw, "mem", None);
        assert!(codes(&r.diagnostics).contains(&Code::K071), "{:?}", r.diagnostics);
        // The same entry under a per-kernel key is legitimate.
        let mut split = Schedule::uniform(12, LaunchAt::WithComp(1), 1410);
        split.kernel_freqs = KernelFreqs::PerClass { compute_mhz: 1410, memory_mhz: 900 };
        let skey = crate::backend::trace_key(1, &split, 30.0, None);
        let raw2 = format!(
            "{{\"trace\":\"kareus_exec_trace\",\"version\":1,\"entries\":{{\"{skey}\":{entry}}}}}"
        );
        let r2 = check_text(&raw2, "mem", None);
        assert!(r2.diagnostics.is_empty(), "{:?}", r2.diagnostics);
    }

    fn bench_raw(deterministic: bool, entry: &str, wall_s: &str) -> String {
        format!(
            "{{\"bench\":\"kareus_bench\",\"version\":1,\"deterministic\":{deterministic},\
             \"entries\":{{\"exec_overlapped\":{entry}}},\"wall_s\":{wall_s}}}"
        )
    }

    #[test]
    fn bench_deterministic_report_is_clean() {
        let raw = bench_raw(
            true,
            r#"{"counters":{"kernels":3},"iters":null,"min_ns":null,"median_ns":null,"mean_ns":null}"#,
            "null",
        );
        let r = check_text(&raw, "mem", None);
        assert_eq!(r.kind, "bench_report");
        assert!(r.diagnostics.is_empty(), "{:?}", r.diagnostics);
        // The real suite's deterministic report passes its own checker.
        let rep = crate::bench_suite::run(true, 0.0);
        let r2 = check_text(&rep.to_json().dump(), "mem", None);
        assert!(r2.diagnostics.is_empty(), "{:?}", r2.diagnostics);
    }

    #[test]
    fn bench_missing_field_is_k080() {
        // Absent min_ns (wall fields must be explicit nulls) and a
        // fractional counter each trip K080.
        let raw = bench_raw(
            true,
            r#"{"counters":{"kernels":3.5},"iters":null,"median_ns":null,"mean_ns":null}"#,
            "null",
        );
        let r = check_text(&raw, "mem", None);
        assert_eq!(codes(&r.diagnostics), vec![Code::K080, Code::K080]);
        assert!(r.has_errors());
    }

    #[test]
    fn bench_mixed_nulling_is_k081() {
        // Deterministic report with a populated wall field.
        let raw = bench_raw(
            true,
            r#"{"counters":{},"iters":null,"min_ns":12.0,"median_ns":null,"mean_ns":null}"#,
            "null",
        );
        let r = check_text(&raw, "mem", None);
        assert_eq!(codes(&r.diagnostics), vec![Code::K081]);
        // Timed report with everything nulled claims the wrong mode.
        let raw2 = bench_raw(
            false,
            r#"{"counters":{},"iters":null,"min_ns":null,"median_ns":null,"mean_ns":null}"#,
            "null",
        );
        let r2 = check_text(&raw2, "mem", None);
        assert_eq!(codes(&r2.diagnostics), vec![Code::K081]);
    }

    #[test]
    fn bench_median_below_min_is_k082_warn() {
        let raw = bench_raw(
            false,
            r#"{"counters":{"kernels":3},"iters":5,"min_ns":100.0,"median_ns":50.0,"mean_ns":80.0}"#,
            "0.5",
        );
        let r = check_text(&raw, "mem", None);
        assert_eq!(codes(&r.diagnostics), vec![Code::K082]);
        assert!(!r.has_errors());
    }
}
