//! Kareus leader entrypoint.
//!
//! Subcommands:
//!   paper     --exp <id> | --all          regenerate paper tables/figures
//!   optimize  --model <m> --tp --cp --pp --microbatch --seq [--system <s>]
//!             [--strategy mbo|exhaustive|random|halving]
//!             [--freq-granularity partition|kernel]
//!             [--deadline S | --budget J | --power-cap W]
//!   sweep     --gpus a100,h100 --models qwen1.7b,llama3b --pars tp8pp2 …
//!             [--backend sim|trace:<path>]
//!   cluster   --jobs gpu:model:par:system[:replicas],…
//!             --cap W | --caps 0:W1,T2:W2,…  [--backend sim|trace:<path>]
//!   train     --config tiny|e2e --steps N [--artifacts DIR] [--baseline]
//!             [--backend sim|trace:<path>]
//!   train     --replan [--iters N] [--policy static|drift|oracle]
//!             [--slowdown ITER:F,…] [--caps 0:W,T:W] [--drift-pct N]
//!             [--revisions-out FILE]       online replanning runtime
//!   serve     [--addr 127.0.0.1:4500] [--threads N] [--max-inflight N]
//!             [--strategy S] [--backend sim|trace:<path>]
//!                                          long-running plan-serving daemon
//!   loadgen   --addr HOST:PORT [--requests N] [--concurrency C]
//!             [--jobs spec,…] [--target T] [--seed N] [--deterministic]
//!             [--shutdown] [--out FILE]    drive a server, emit a report
//!   check     <file.json> [--gpu a100] [--format text|json]
//!                                          statically verify an artifact
//!   bench     [--deterministic] [--budget-scale X] [--out FILE]
//!                                          hot-path suite, BENCH JSON
//!   census                                 Appendix B space census
//!   list                                   list experiments

use std::sync::Arc;

use kareus::backend::{parse_backend_spec, BackendSpec, TraceBackend};
use kareus::baselines::System;
use kareus::cli::Args;
use kareus::cluster::{optimize_jobs, parse_job_spec, plan_cluster, PowerCapSchedule};
use kareus::coordinator::{Coordinator, Target};
use kareus::engine::{
    parse_model, parse_parallelism, parse_system, run_sweep, scenario_matrix, sweep_json,
    EngineConfig,
};
use kareus::mbo::space::FreqGranularity;
use kareus::mbo::StrategyKind;
use kareus::paper;
use kareus::runtime::{DriftSchedule, LoopConfig, ReplanPolicy, Runtime, TrainingLoop};
use kareus::serve::{run_loadgen, send_shutdown, LoadgenConfig, ServeConfig, ServeOptions, Server};
use kareus::sim::gpu::GpuSpec;
use kareus::workload::{ModelSpec, Parallelism, TrainConfig};

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("kareus: {e}");
            std::process::exit(2);
        }
    };
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let code = match cmd {
        "paper" => cmd_paper(&args),
        "optimize" => cmd_optimize(&args),
        "sweep" => cmd_sweep(&args),
        "cluster" => cmd_cluster(&args),
        "train" => cmd_train(&args),
        "serve" => cmd_serve(&args),
        "loadgen" => cmd_loadgen(&args),
        "check" => cmd_check(&args),
        "bench" => cmd_bench(&args),
        "census" => match paper::run_experiment("appB") {
            // Propagate through the CLI error path instead of unwrapping:
            // a missing built-in experiment is an internal error, not a
            // panic the user has to decode.
            Some(out) => {
                println!("{out}");
                0
            }
            None => {
                eprintln!("internal error: census experiment (appB) is not registered");
                1
            }
        },
        "list" => {
            println!("experiments: {}", paper::ALL_EXPERIMENTS.join(" "));
            0
        }
        _ => {
            eprintln!(
                "kareus — joint dynamic+static energy optimization for large model training\n\
                 usage:\n  kareus paper --exp <id>|--all\n  kareus optimize --model qwen1.7b|llama3b|llama70b \
                 [--tp 8 --cp 1 --pp 2 --microbatch 8 --seq 4096 --nmb 8] [--system kareus] \
                 [--strategy mbo|exhaustive|random|halving] [--freq-granularity partition|kernel] \
                 [--deadline S|--budget J|--power-cap W]\n  kareus sweep [--gpus a100,h100,v100] [--models qwen1.7b,llama3b] \
                 [--pars tp8pp2,cp2tp4pp2] [--systems kareus,n+p] [--microbatch 8 --seq 4096 --nmb 8] \
                 [--seed N] [--threads N] [--strategy S] [--backend sim|trace:FILE] [--out FILE.json]\n  \
                 kareus cluster --jobs gpu:model:par:system[:replicas],… --cap WATTS|--caps 0:W1,T2:W2,… \
                 [--microbatch 8 --seq 4096 --nmb 8] [--seed N] [--threads N] [--strategy S] \
                 [--backend sim|trace:FILE] [--out FILE.json]\n  \
                 kareus train --config tiny|e2e --steps 100 [--artifacts artifacts] [--baseline] \
                 [--strategy S] [--backend sim|trace:FILE]\n  \
                 kareus train --replan [--iters 400] [--system kareus] [--policy static|drift|oracle] \
                 [--slowdown ITER:FACTOR,…] [--cap WATTS|--caps 0:W1,T2:W2,…] [--drift-pct 5] \
                 [--replan-cooldown 20] [--deadline S] [--seed N] [--revisions-out FILE] \
                 [--out FILE] [--strategy S] [--backend sim|trace:FILE]\n  \
                 kareus serve [--addr 127.0.0.1:4500] [--threads N] [--max-inflight 2] \
                 [--microbatch 8 --seq 4096 --nmb 8] [--strategy S] [--backend sim|trace:FILE]\n  \
                 kareus loadgen --addr HOST:PORT [--requests 16] [--concurrency 4] \
                 [--jobs gpu:model:par:system,…] [--target max|deadline:S|budget:J|power-cap:W] \
                 [--seed N] [--deterministic] [--shutdown] [--out FILE.json]\n  \
                 kareus check FILE.json [--gpu a100|h100|v100] [--format text|json]\n  \
                 kareus bench [--deterministic] [--budget-scale X] [--out FILE.json]\n  \
                 kareus census | kareus list\n\
                 \n\
                 --strategy picks the per-partition search (default mbo: the paper's multi-pass MBO;\n\
                 halving: successive-halving racing; exhaustive: measure everything; random: baseline).\n\
                 --freq-granularity kernel adds the per-kernel-class DVFS axis (memory-class\n\
                 frequency searched independently of the compute class; default: partition).\n\
                 --backend trace:FILE records measurements on the first run (FILE absent) and\n\
                 replays them byte-identically, simulator disabled, on later runs (FILE present)."
            );
            if cmd == "help" {
                0
            } else {
                2
            }
        }
    };
    std::process::exit(code);
}

/// Serialize an artifact document, refusing to write non-finite numbers
/// (invalid JSON). Returns the CLI exit code on failure.
fn emit(doc: &kareus::util::json::Json, what: &str) -> Result<String, i32> {
    doc.try_dump().map_err(|e| {
        eprintln!("{what}: {e}");
        1
    })
}

/// `kareus serve`: the long-running plan-serving daemon (data-flow step
/// ⑨). Blocks in the accept loop until a client sends a `shutdown`
/// control request, then drains in-flight work and exits 0.
fn cmd_serve(args: &Args) -> i32 {
    for key in ["addr", "max-inflight"] {
        if args.has_flag(key) {
            eprintln!("--{key} requires a value");
            return 2;
        }
    }
    // --threads feeds both pools: build_engine sizes the per-partition
    // MBO fan-out, ServeConfig sizes the connection workers.
    let (engine, trace) = match build_engine(args) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let cfg = ServeConfig {
        addr: args.get("addr").unwrap_or("127.0.0.1:4500").to_string(),
        threads: args.get_u32("threads", 0) as usize,
        opts: ServeOptions {
            max_inflight: args.get_u32("max-inflight", 2) as usize,
            microbatch: args.get_u32("microbatch", 8),
            seq_len: args.get_u32("seq", 4096),
            n_microbatches: args.get_u32("nmb", 8),
        },
    };
    let server = match Server::bind(engine, &cfg, |line| eprintln!("{line}")) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("kareus serve: bind {}: {e}", cfg.addr);
            return 2;
        }
    };
    if let Err(e) = server.run() {
        eprintln!("kareus serve: {e}");
        return 1;
    }
    // Persist a recording trace only after the daemon drained, so the
    // trace covers every optimization the run admitted.
    if let Err(e) = finish_trace(&trace) {
        eprintln!("{e}");
        return 1;
    }
    0
}

/// `kareus loadgen`: drive a running server with a deterministic request
/// mix and emit the `kareus_loadgen` report (stdout or `--out`).
fn cmd_loadgen(args: &Args) -> i32 {
    for key in ["addr", "requests", "concurrency", "jobs", "target", "seed"] {
        if args.has_flag(key) {
            eprintln!("--{key} requires a value");
            return 2;
        }
    }
    let cfg = LoadgenConfig {
        addr: args.get("addr").unwrap_or("127.0.0.1:4500").to_string(),
        requests: args.get_u32("requests", 16) as usize,
        concurrency: args.get_u32("concurrency", 4) as usize,
        jobs: args.get_list("jobs", "a100:qwen1.7b:tp8pp2:megatron"),
        target: args.get("target").unwrap_or("max").to_string(),
        seed: args.get_u32("seed", 2026) as u64,
        deterministic: args.has_flag("deterministic"),
    };
    // Validate the request mix client-side (usage errors exit 2 before
    // any connection is made; the server would reject them anyway).
    if cfg.requests == 0 {
        eprintln!("--requests must be >= 1");
        return 2;
    }
    for job in &cfg.jobs {
        if let Err(e) = parse_job_spec(job, 8, 4096, 8, cfg.seed) {
            eprintln!("bad job spec '{job}': {e}");
            return 2;
        }
    }
    if let Err(e) = kareus::serve::parse_target(&cfg.target) {
        eprintln!("{e}");
        return 2;
    }
    let report = match run_loadgen(&cfg) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("kareus loadgen: {e}");
            return 1;
        }
    };
    let json = match emit(&report, "emit loadgen report") {
        Ok(j) => j,
        Err(code) => return code,
    };
    match args.get("out") {
        Some(path) => {
            if let Err(e) = std::fs::write(path, &json) {
                eprintln!("write {path}: {e}");
                return 1;
            }
            eprintln!("wrote {path}");
        }
        None => println!("{json}"),
    }
    if args.has_flag("shutdown") {
        if let Err(e) = send_shutdown(&cfg.addr) {
            eprintln!("kareus loadgen: {e}");
            return 1;
        }
        eprintln!("server at {} acknowledged shutdown", cfg.addr);
    }
    0
}

/// `kareus check <file.json>`: statically verify an emitted artifact.
/// Exit 0 when clean (warnings allowed), 1 on errors, 2 on usage/IO.
fn cmd_check(args: &Args) -> i32 {
    let Some(path) = args.positional.get(1) else {
        eprintln!("usage: kareus check <file.json> [--gpu a100|h100|v100] [--format text|json]");
        return 2;
    };
    let gpu = match args.get("gpu") {
        None => None,
        Some(name) => match kareus::check::resolve_gpu(name) {
            Some(g) => Some(g),
            None => {
                eprintln!("unknown gpu '{name}' (a100 | h100 | v100)");
                return 2;
            }
        },
    };
    let format = args.get("format").unwrap_or("text");
    if format != "text" && format != "json" {
        eprintln!("unknown --format '{format}' (text | json)");
        return 2;
    }
    let report = match kareus::check::check_file(std::path::Path::new(path), gpu.as_ref()) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("kareus check: {e}");
            return 2;
        }
    };
    if format == "json" {
        match emit(&report.to_json(), "emit report") {
            Ok(text) => println!("{text}"),
            Err(code) => return code,
        }
    } else {
        print!("{}", report.to_text());
    }
    if report.has_errors() {
        1
    } else {
        0
    }
}

/// `kareus bench`: run the hot-path suite and emit the `kareus_bench`
/// JSON artifact (stdout or `--out`). With `--deterministic` each
/// workload runs exactly once, every wall-clock field is null, and two
/// runs dump byte-identical documents (the CI smoke `cmp`s them);
/// without it, entries carry min/median/mean nanoseconds from the bench
/// harness, scaled by `--budget-scale`.
fn cmd_bench(args: &Args) -> i32 {
    if args.has_flag("budget-scale") {
        eprintln!("--budget-scale requires a value");
        return 2;
    }
    let deterministic = args.has_flag("deterministic");
    let scale = args.get_f64("budget-scale", 1.0);
    if !(scale.is_finite() && scale > 0.0) {
        eprintln!("bad --budget-scale (positive multiplier)");
        return 2;
    }
    eprintln!(
        "benching hot paths ({})",
        if deterministic { "deterministic: counters only" } else { "timed" }
    );
    let report = kareus::bench_suite::run(deterministic, scale);
    let json = match emit(&report.to_json(), "emit bench report") {
        Ok(j) => j,
        Err(code) => return code,
    };
    match args.get("out") {
        Some(path) => {
            if let Err(e) = std::fs::write(path, &json) {
                eprintln!("write {path}: {e}");
                return 1;
            }
            eprintln!("wrote {path}");
        }
        None => println!("{json}"),
    }
    0
}

fn cmd_paper(args: &Args) -> i32 {
    if args.has_flag("all") {
        for id in paper::ALL_EXPERIMENTS {
            println!("================ {id} ================");
            match paper::run_experiment(id) {
                Some(out) => println!("{out}"),
                None => eprintln!("unknown experiment {id}"),
            }
        }
        return 0;
    }
    let Some(id) = args.get("exp") else {
        eprintln!("need --exp <id> or --all; ids: {}", paper::ALL_EXPERIMENTS.join(" "));
        return 2;
    };
    match paper::run_experiment(id) {
        Some(out) => {
            println!("{out}");
            0
        }
        None => {
            eprintln!("unknown experiment {id}; ids: {}", paper::ALL_EXPERIMENTS.join(" "));
            2
        }
    }
}

/// Resolve `--strategy` into the engine's per-partition search strategy
/// (default: the paper's multi-pass MBO).
fn parse_strategy(args: &Args) -> Result<StrategyKind, String> {
    // A bare `--strategy` followed by another option parses as a flag;
    // don't silently fall back to the default search.
    if args.has_flag("strategy") {
        return Err("--strategy requires a value (mbo | exhaustive | random | halving)".into());
    }
    let spec = args.get("strategy").unwrap_or("mbo");
    StrategyKind::parse(spec)
        .ok_or_else(|| format!("unknown strategy '{spec}' (mbo | exhaustive | random | halving)"))
}

/// Resolve `--freq-granularity` into the per-partition frequency axis
/// (default partition: the paper's model; kernel adds the per-class axis).
fn parse_freq_granularity(args: &Args) -> Result<FreqGranularity, String> {
    if args.has_flag("freq-granularity") {
        return Err("--freq-granularity requires a value (partition | kernel)".into());
    }
    let spec = args.get("freq-granularity").unwrap_or("partition");
    FreqGranularity::parse(spec)
        .ok_or_else(|| format!("unknown --freq-granularity '{spec}' (partition | kernel)"))
}

/// Resolve `--backend` + `--threads` + `--strategy` + `--freq-granularity`
/// into an engine, plus the trace handle when a trace backend is active
/// (record mode must be saved afterwards).
fn build_engine(args: &Args) -> Result<(EngineConfig, Option<Arc<TraceBackend>>), String> {
    // A bare `--backend` followed by another option parses as a flag;
    // don't silently fall back to the simulator.
    if args.has_flag("backend") {
        return Err("--backend requires a value (sim | trace:<path>)".to_string());
    }
    let engine = EngineConfig::new()
        .with_threads(args.get_u32("threads", 0) as usize)
        .with_strategy(parse_strategy(args)?)
        .with_freq_granularity(parse_freq_granularity(args)?);
    match parse_backend_spec(args.get("backend").unwrap_or("sim"))? {
        BackendSpec::Sim => Ok((engine, None)),
        BackendSpec::Trace(path) => {
            let trace = Arc::new(
                TraceBackend::open(&path)
                    .map_err(|e| format!("backend trace:{}: {e}", path.display()))?,
            );
            eprintln!(
                "backend: trace:{} ({})",
                path.display(),
                if trace.is_replay() { "replay, simulator disabled" } else { "recording" }
            );
            Ok((engine.with_backend(trace.clone()), Some(trace)))
        }
    }
}

/// Resolve `--cap W` / `--caps 0:W1,T2:W2,…` into a cap schedule (the
/// shared format of `kareus cluster` and `kareus train --replan`).
/// `Ok(None)` when neither flag is given; errors name the offending spec.
fn parse_cap_args(args: &Args) -> Result<Option<PowerCapSchedule>, String> {
    match (args.get("cap"), args.get("caps")) {
        (Some(_), Some(_)) => Err("give either --cap or --caps, not both".to_string()),
        (None, None) => Ok(None),
        (Some(spec), None) | (None, Some(spec)) => PowerCapSchedule::parse(spec)
            .map(Some)
            .map_err(|e| format!("bad cap schedule '{spec}': {e}")),
    }
}

/// Persist a recording trace; replay traces need no save.
fn finish_trace(trace: &Option<Arc<TraceBackend>>) -> Result<(), String> {
    if let Some(t) = trace {
        if t.is_replay() {
            eprintln!("replayed {} measurements from {}", t.replayed(), t.path().display());
        } else {
            t.save().map_err(|e| format!("saving trace {}: {e}", t.path().display()))?;
            eprintln!("recorded {} measurements to {}", t.len(), t.path().display());
        }
    }
    Ok(())
}

fn cmd_optimize(args: &Args) -> i32 {
    let model = match parse_model(args.get("model").unwrap_or("qwen1.7b")) {
        Some(m) => m,
        None => {
            eprintln!("unknown model (qwen1.7b | llama3b | llama70b)");
            return 2;
        }
    };
    let cfg = TrainConfig {
        model,
        par: Parallelism::new(
            args.get_u32("tp", 8),
            args.get_u32("cp", 1),
            args.get_u32("pp", 2),
        ),
        microbatch: args.get_u32("microbatch", 8),
        seq_len: args.get_u32("seq", 4096),
        n_microbatches: args.get_u32("nmb", 8),
        dtype_bytes: 2,
    };
    let system = match parse_system(args.get("system").unwrap_or("kareus")) {
        Some(s) => s,
        None => {
            eprintln!("unknown system");
            return 2;
        }
    };
    let strategy = match parse_strategy(args) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let granularity = match parse_freq_granularity(args) {
        Ok(g) => g,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let coord = Coordinator::new(GpuSpec::a100(), cfg).with_engine(
        EngineConfig::new().with_strategy(strategy).with_freq_granularity(granularity),
    );
    eprintln!(
        "optimizing {} with {} ({} search) ...",
        cfg.label(),
        system.name(),
        strategy.name()
    );
    let result = coord.optimize(system, args.get_u32("seed", 2026) as u64);
    let target = if let Some(d) = args.get("deadline") {
        Target::Deadline(d.parse().unwrap_or(f64::INFINITY))
    } else if let Some(b) = args.get("budget") {
        Target::EnergyBudget(b.parse().unwrap_or(f64::INFINITY))
    } else if let Some(w) = args.get("power-cap") {
        // Average per-GPU watts (energy/time along the frontier). A
        // malformed value must NOT silently become "unconstrained" — that
        // would drop the safety constraint the user asked for.
        match w.parse::<f64>() {
            Ok(v) if v.is_finite() && v > 0.0 => Target::PowerCap(v),
            _ => {
                eprintln!("bad --power-cap '{w}' (positive watts per GPU)");
                return 2;
            }
        }
    } else {
        Target::MaxThroughput
    };
    match coord.select(&result, target) {
        Some(dep) => match emit(&coord.plan_json(&result, &dep), "emit plan") {
            Ok(text) => {
                println!("{text}");
                0
            }
            Err(code) => code,
        },
        None => {
            eprintln!("no frontier point satisfies the target");
            1
        }
    }
}

/// Fan the full frontier pipeline over a GPUs × models × parallelism ×
/// systems matrix and emit machine-readable JSON (BENCH_*.json schema).
fn cmd_sweep(args: &Args) -> i32 {
    // A space after a comma ("--gpus a100, h100") would silently strand
    // "h100" as a positional token and shrink the matrix — reject instead.
    if args.positional.len() > 1 {
        eprintln!(
            "unexpected arguments {:?} — list options take comma-separated values without spaces \
             (e.g. --gpus a100,h100)",
            &args.positional[1..]
        );
        return 2;
    }
    // A list option followed by another option ("--gpus --models …")
    // parses as a bare flag; don't silently run the default matrix.
    // (`--backend` gets the same guard inside build_engine.)
    for key in ["gpus", "models", "pars", "systems"] {
        if args.has_flag(key) {
            eprintln!("--{key} requires a value");
            return 2;
        }
    }
    let mut gpus = Vec::new();
    for name in args.get_list("gpus", "a100,h100,v100") {
        match GpuSpec::by_name(&name) {
            Some(g) => gpus.push(g),
            None => {
                eprintln!("unknown gpu '{name}' (a100 | h100 | v100)");
                return 2;
            }
        }
    }
    let mut models = Vec::new();
    for name in args.get_list("models", "qwen1.7b") {
        match parse_model(&name) {
            Some(m) => models.push(m),
            None => {
                eprintln!("unknown model '{name}' (qwen1.7b | llama3b | llama70b)");
                return 2;
            }
        }
    }
    let mut pars = Vec::new();
    for spec in args.get_list("pars", "tp8pp2") {
        match parse_parallelism(&spec) {
            Some(p) => pars.push(p),
            None => {
                eprintln!("bad parallelism '{spec}' (e.g. tp8pp2, cp2tp4pp2)");
                return 2;
            }
        }
    }
    let mut systems = Vec::new();
    for name in args.get_list("systems", "kareus") {
        match parse_system(&name) {
            Some(s) => systems.push(s),
            None => {
                eprintln!("unknown system '{name}'");
                return 2;
            }
        }
    }

    let scenarios = scenario_matrix(
        &gpus,
        &models,
        &pars,
        &systems,
        args.get_u32("microbatch", 8),
        args.get_u32("seq", 4096),
        args.get_u32("nmb", 8),
        args.get_u32("seed", 2026) as u64,
    );
    if scenarios.is_empty() {
        eprintln!("empty scenario matrix");
        return 2;
    }
    let (engine, trace) = match build_engine(args) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    eprintln!(
        "sweeping {} scenarios ({} gpus × {} models × {} parallelisms × {} systems) \
         on {} workers",
        scenarios.len(),
        gpus.len(),
        models.len(),
        pars.len(),
        systems.len(),
        engine.worker_threads()
    );
    let outcomes = run_sweep(scenarios, &engine, |line| eprintln!("{line}"));
    // Trace runs null the timing-dependent fields so a record run and its
    // replay dump byte-identical JSON.
    let json = match emit(&sweep_json(&outcomes, &engine, trace.is_some()), "emit sweep") {
        Ok(j) => j,
        Err(code) => return code,
    };
    if let Err(e) = finish_trace(&trace) {
        eprintln!("{e}");
        return 1;
    }
    match args.get("out") {
        Some(path) => {
            if let Err(e) = std::fs::write(path, &json) {
                eprintln!("write {path}: {e}");
                return 1;
            }
            eprintln!("wrote {path}");
        }
        None => println!("{json}"),
    }
    0
}

/// Optimize N jobs and allocate a datacenter power-cap timeline across
/// their retained frontiers (deterministic `ClusterPlan` JSON output).
fn cmd_cluster(args: &Args) -> i32 {
    if args.positional.len() > 1 {
        eprintln!(
            "unexpected arguments {:?} — --jobs and --caps take comma-separated values \
             without spaces",
            &args.positional[1..]
        );
        return 2;
    }
    // Guard against `--jobs --cap …`-style bare flags silently running a
    // default (same rationale as cmd_sweep).
    for key in ["jobs", "cap", "caps"] {
        if args.has_flag(key) {
            eprintln!("--{key} requires a value");
            return 2;
        }
    }
    let Some(jobs_spec) = args.get("jobs") else {
        eprintln!(
            "need --jobs gpu:model:par:system[:replicas],… \
             (e.g. a100:qwen1.7b:tp8pp2:m+p,v100:llama3b:cp2tp4pp2:kareus)"
        );
        return 2;
    };
    let microbatch = args.get_u32("microbatch", 8);
    let seq_len = args.get_u32("seq", 4096);
    let nmb = args.get_u32("nmb", 8);
    let seed = args.get_u32("seed", 2026) as u64;
    let mut jobs = Vec::new();
    for spec in jobs_spec.split(',').map(str::trim).filter(|t| !t.is_empty()) {
        match parse_job_spec(spec, microbatch, seq_len, nmb, seed) {
            Ok(j) => jobs.push(j),
            Err(e) => {
                eprintln!("bad job '{spec}': {e}");
                return 2;
            }
        }
    }
    if jobs.is_empty() {
        eprintln!("empty job list");
        return 2;
    }
    let schedule = match parse_cap_args(args) {
        Ok(Some(s)) => s,
        Ok(None) => {
            eprintln!("need --cap WATTS or --caps 0:W1,T2:W2,… (cluster watts)");
            return 2;
        }
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let (engine, trace) = match build_engine(args) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    eprintln!(
        "optimizing {} jobs, then allocating {} cap segment(s) on {} workers",
        jobs.len(),
        schedule.segments().len(),
        engine.worker_threads()
    );
    let fronts = optimize_jobs(&jobs, &engine, |line| eprintln!("{line}"));
    // All measurements happen inside optimize_jobs; persist a recording
    // trace before planning so a degenerate schedule can't discard it.
    if let Err(e) = finish_trace(&trace) {
        eprintln!("{e}");
        return 1;
    }
    let plan = plan_cluster(&fronts, &schedule, |w| eprintln!("warning: {w}"));
    let json = match emit(&plan.to_json(), "emit cluster plan") {
        Ok(j) => j,
        Err(code) => return code,
    };
    match args.get("out") {
        Some(path) => {
            if let Err(e) = std::fs::write(path, &json) {
                eprintln!("write {path}: {e}");
                return 1;
            }
            eprintln!("wrote {path}");
        }
        None => println!("{json}"),
    }
    if plan.feasible() {
        0
    } else {
        eprintln!(
            "warning: cap below the cluster-wide minimum power in at least one segment \
             (jobs pinned at their minimum-power points)"
        );
        1
    }
}

/// `kareus train --replan`: the online replanning runtime — step a
/// simulated training run under injected drift (straggler slowdowns, a
/// per-GPU power-cap timeline, thermal leakage) and replan per the
/// selected policy. Emits a deterministic summary JSON (stdout or
/// `--out`) and, with `--revisions-out`, the full typed
/// `RevisionLog` (byte-deterministic; the CI smoke `cmp`s two runs).
fn cmd_train_replan(args: &Args) -> i32 {
    let value_keys = [
        "caps", "cap", "slowdown", "policy", "revisions-out", "drift-pct", "replan-cooldown",
        "deadline",
    ];
    for key in value_keys {
        if args.has_flag(key) {
            eprintln!("--{key} requires a value");
            return 2;
        }
    }
    let system = match parse_system(args.get("system").unwrap_or("kareus")) {
        Some(s) => s,
        None => {
            eprintln!("unknown system");
            return 2;
        }
    };
    let policy = match ReplanPolicy::parse(args.get("policy").unwrap_or("drift")) {
        Some(p) => p,
        None => {
            eprintln!("unknown policy (static | drift | oracle)");
            return 2;
        }
    };
    let caps = match parse_cap_args(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{e} (per-GPU watts)");
            return 2;
        }
    };
    let drift = match args.get("slowdown") {
        Some(spec) => match DriftSchedule::parse(spec) {
            Ok(d) => d,
            Err(e) => {
                eprintln!("bad slowdown schedule '{spec}': {e}");
                return 2;
            }
        },
        None => DriftSchedule::none(),
    };
    let deadline_s = match args.get("deadline") {
        Some(v) => match v.parse::<f64>() {
            Ok(d) if d.is_finite() && d > 0.0 => Some(d),
            _ => {
                eprintln!("bad --deadline '{v}' (positive seconds)");
                return 2;
            }
        },
        None => None,
    };
    let (engine, trace) = match build_engine(args) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let mut replan_cfg = engine.replan;
    if let Some(v) = args.get("drift-pct") {
        match v.parse::<f64>() {
            Ok(p) => replan_cfg.drift_pct = p,
            Err(_) => {
                eprintln!("bad --drift-pct '{v}' (percent)");
                return 2;
            }
        }
    }
    if let Some(v) = args.get("replan-cooldown") {
        match v.parse::<u64>() {
            Ok(c) => replan_cfg.cooldown_iters = c,
            Err(_) => {
                eprintln!("bad --replan-cooldown '{v}' (iterations)");
                return 2;
            }
        }
    }
    if let Err(e) = replan_cfg.validate() {
        eprintln!("bad replan config: {e}");
        return 2;
    }
    let engine = engine.with_replan(replan_cfg);

    let wl = TrainConfig {
        model: ModelSpec::qwen3_1_7b(),
        par: Parallelism::new(8, 1, 2),
        microbatch: 8,
        seq_len: 4096,
        n_microbatches: 8,
        dtype_bytes: 2,
    };
    let lc = LoopConfig {
        n_iters: args.get_u32("iters", 400) as u64,
        deadline_s,
        deadline_slack: args.get_f64("deadline-slack", 0.02),
        caps,
        drift,
        policy,
        seed: args.get_u32("seed", 2026) as u64,
    };
    eprintln!(
        "replanning run: {} · policy {} · {} iters · drift-pct {}",
        system.name(),
        policy.name(),
        lc.n_iters,
        engine.replan.drift_pct
    );
    let tl = TrainingLoop::new(GpuSpec::a100(), wl, system, engine).with_loop_config(lc);
    let summary = match tl.run() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("replanning run: {e}");
            return 1;
        }
    };
    // All measurements happen inside run(); persist a recording trace
    // before any output can fail.
    if let Err(e) = finish_trace(&trace) {
        eprintln!("{e}");
        return 1;
    }
    if let Some(path) = args.get("revisions-out") {
        let revisions = match emit(&summary.revisions.to_json(), "emit revisions") {
            Ok(j) => j,
            Err(code) => return code,
        };
        if let Err(e) = std::fs::write(path, revisions) {
            eprintln!("write {path}: {e}");
            return 1;
        }
        eprintln!("wrote {path} ({} revisions)", summary.revisions.revisions.len());
    }
    let json = match emit(&summary.to_json(), "emit summary") {
        Ok(j) => j,
        Err(code) => return code,
    };
    match args.get("out") {
        Some(path) => {
            if let Err(e) = std::fs::write(path, &json) {
                eprintln!("write {path}: {e}");
                return 1;
            }
            eprintln!("wrote {path}");
        }
        None => println!("{json}"),
    }
    0
}

fn cmd_train(args: &Args) -> i32 {
    // `--replan` normally parses as a bare flag; tolerate a stray value
    // token after it rather than silently falling through to the PJRT
    // training path.
    if args.has_flag("replan") || args.get("replan").is_some() {
        return cmd_train_replan(args);
    }
    let config = args.get("config").unwrap_or("e2e").to_string();
    let steps = args.get_u32("steps", 100);
    let dir = args.get("artifacts").unwrap_or("artifacts").to_string();
    let seed = args.get_u32("seed", 0) as u64;

    // Phase ①–④: pick the schedule to deploy (Kareus vs Megatron baseline)
    // on a representative workload; the simulated accounting is attached
    // to every training step.
    let wl = TrainConfig {
        model: ModelSpec::qwen3_1_7b(),
        par: Parallelism::new(8, 1, 2),
        microbatch: 8,
        seq_len: 4096,
        n_microbatches: 8,
        dtype_bytes: 2,
    };
    let (engine, trace) = match build_engine(args) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let coord = Coordinator::new(GpuSpec::a100(), wl).with_engine(engine);
    let system = if args.has_flag("baseline") { System::Megatron } else { System::Kareus };
    eprintln!("selecting execution schedule ({}) ...", system.name());
    let result = coord.optimize(system, 2026);
    // All measurements happen inside optimize(); persist a recording trace
    // now so even a failed selection doesn't discard it.
    if let Err(e) = finish_trace(&trace) {
        eprintln!("{e}");
        return 1;
    }
    let Some(dep) = coord.select(&result, Target::MaxThroughput) else {
        eprintln!("optimization produced an empty frontier; nothing to deploy");
        return 1;
    };
    eprintln!(
        "deployed: {} iter {:.3}s {:.0}J ({})",
        dep.system.name(),
        dep.iter_time_s,
        dep.iter_energy_j,
        dep.freq_summary()
    );

    // Phase ⑤: real training through PJRT.
    let rt = match Runtime::new(&dir) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("runtime: {e:#}");
            return 1;
        }
    };
    eprintln!("PJRT platform: {}", rt.platform());
    match coord.deploy_and_train(&dep, rt, &config, steps, seed) {
        Ok(logs) => {
            let first = logs.first().map(|l| l.loss).unwrap_or(f32::NAN);
            let last = logs.last().map(|l| l.loss).unwrap_or(f32::NAN);
            let sim_total_t: f64 = dep.iter_time_s * steps as f64;
            let sim_total_e: f64 = dep.iter_energy_j * steps as f64;
            println!(
                "done: loss {first:.4} -> {last:.4} over {steps} steps; \
                 simulated {sim_total_t:.1}s / {sim_total_e:.0}J per-GPU under {}",
                dep.system.name()
            );
            0
        }
        Err(e) => {
            eprintln!("train: {e:#}");
            1
        }
    }
}
