//! 1F1B pipeline schedule + Perseus-style iteration-frontier composition
//! (§2.2 Figure 1, §4.4 "microbatch frontiers to iteration frontier").
//!
//! Each (stage, direction) has a microbatch frontier (time, energy)
//! choices. The 1F1B dependency DAG determines the critical path; the
//! iteration frontier is traced by sweeping an iteration deadline and
//! greedily moving off-critical-path microbatches down their frontiers
//! (cheaper-but-slower points) while the deadline holds — Perseus's
//! iterative energy-reduction algorithm [15] adapted to our frontier
//! representation. Iteration energy adds the static power of idle bubble
//! time (§4.4).

use crate::compose::{MbFrontier, MicrobatchPlan};
use crate::frontier::{Frontier, Point};

/// One task in the pipeline: (stage, microbatch, direction).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Task {
    pub stage: usize,
    pub mb: usize,
    pub is_bwd: bool,
}

/// The 1F1B task order for one stage (warmup fwds, steady 1F1B, cooldown
/// bwds) — Figure 1's schedule.
pub fn stage_order(stage: usize, n_stages: usize, n_microbatches: usize) -> Vec<Task> {
    let warmup = (n_stages - 1 - stage).min(n_microbatches);
    let mut order = Vec::with_capacity(2 * n_microbatches);
    let mut next_fwd = 0usize;
    let mut next_bwd = 0usize;
    for _ in 0..warmup {
        order.push(Task { stage, mb: next_fwd, is_bwd: false });
        next_fwd += 1;
    }
    while next_bwd < n_microbatches {
        if next_fwd < n_microbatches {
            order.push(Task { stage, mb: next_fwd, is_bwd: false });
            next_fwd += 1;
        }
        order.push(Task { stage, mb: next_bwd, is_bwd: true });
        next_bwd += 1;
        // 1F1B steady state alternates F and B; warmup already issued the
        // lead forwards.
    }
    order
}

/// A frozen choice of operating point for every task.
#[derive(Clone, Debug)]
pub struct IterationPlan {
    /// choice[stage][2*mb + is_bwd] = index into that (stage, dir)
    /// frontier's pareto() list.
    pub choice: Vec<Vec<usize>>,
    pub time_s: f64,
    pub total_j: f64,
    pub dyn_j: f64,
    /// Idle (bubble) time summed over stages, per GPU.
    pub bubble_s: f64,
}

impl IterationPlan {
    /// Average per-GPU power over the iteration (total energy / time, W)
    /// — the quantity the power-cap selectors and the cluster scheduler
    /// budget against.
    pub fn avg_power_w(&self) -> f64 {
        self.total_j / self.time_s
    }
}

/// Per-(stage, dir) Pareto choices: (time, total, dyn) ascending in time,
/// plus the deployed [`MicrobatchPlan`] behind every choice (same order),
/// so a selected operating point can be materialized into a typed
/// [`FrequencyPlan`](crate::plan::FrequencyPlan) instead of a summary
/// string.
#[derive(Clone, Debug)]
pub struct StageMenu {
    pub fwd: Vec<(f64, f64, f64)>,
    pub bwd: Vec<(f64, f64, f64)>,
    /// Plans parallel to `fwd` / `bwd`.
    pub fwd_plans: Vec<MicrobatchPlan>,
    pub bwd_plans: Vec<MicrobatchPlan>,
}

impl StageMenu {
    pub fn from_frontiers(fwd: &MbFrontier, bwd: &MbFrontier) -> Self {
        let take = |f: &MbFrontier| {
            f.pareto().iter().map(|p| (p.time_s, p.total_j, p.dyn_j)).collect::<Vec<_>>()
        };
        let plans = |f: &MbFrontier| {
            f.pareto().iter().map(|p| p.plan.clone()).collect::<Vec<_>>()
        };
        StageMenu { fwd: take(fwd), bwd: take(bwd), fwd_plans: plans(fwd), bwd_plans: plans(bwd) }
    }

    fn menu(&self, is_bwd: bool) -> &[(f64, f64, f64)] {
        if is_bwd {
            &self.bwd
        } else {
            &self.fwd
        }
    }

    /// The deployed microbatch plan behind menu entry `idx` (clamped like
    /// the scheduler's duration lookup).
    pub fn plan(&self, is_bwd: bool, idx: usize) -> &MicrobatchPlan {
        let plans = if is_bwd { &self.bwd_plans } else { &self.fwd_plans };
        &plans[idx.min(plans.len() - 1)]
    }
}

/// Reusable state for [`simulate_1f1b_with`]: the per-stage 1F1B task
/// orders — invariant across the thousands of candidate moves
/// [`greedy_fill`] evaluates, yet previously recomputed per call — plus
/// the event-scheduling vectors, so repeated simulation allocates
/// nothing.
pub struct SimScratch {
    orders: Vec<Vec<Task>>,
    end: Vec<Vec<f64>>,
    ptr: Vec<usize>,
    clock: Vec<f64>,
    busy: Vec<f64>,
}

impl SimScratch {
    pub fn new(n_stages: usize, n_microbatches: usize) -> SimScratch {
        SimScratch {
            orders: (0..n_stages).map(|s| stage_order(s, n_stages, n_microbatches)).collect(),
            end: vec![vec![f64::NAN; 2 * n_microbatches]; n_stages],
            ptr: vec![0; n_stages],
            clock: vec![0.0; n_stages],
            busy: vec![0.0; n_stages],
        }
    }

    /// Per-stage busy time from the most recent simulation.
    pub fn busy(&self) -> &[f64] {
        &self.busy
    }
}

/// Simulate the 1F1B schedule given per-task durations; returns
/// (iteration time, per-stage busy time).
pub fn simulate_1f1b(
    menus: &[StageMenu],
    choice: &[Vec<usize>],
    n_microbatches: usize,
) -> (f64, Vec<f64>) {
    let mut scratch = SimScratch::new(menus.len(), n_microbatches);
    let t = simulate_1f1b_with(menus, choice, n_microbatches, &mut scratch);
    (t, scratch.busy)
}

/// [`simulate_1f1b`] with caller-owned scratch (results independent of
/// its prior contents); per-stage busy time lands in
/// [`SimScratch::busy`]. Returns the iteration makespan.
pub fn simulate_1f1b_with(
    menus: &[StageMenu],
    choice: &[Vec<usize>],
    n_microbatches: usize,
    scratch: &mut SimScratch,
) -> f64 {
    let n_stages = menus.len();
    debug_assert_eq!(scratch.orders.len(), n_stages);
    debug_assert!(scratch.end.iter().all(|row| row.len() == 2 * n_microbatches));
    let dur = |t: &Task| {
        let m = menus[t.stage].menu(t.is_bwd);
        m[choice[t.stage][2 * t.mb + t.is_bwd as usize].min(m.len() - 1)].0
    };
    // end[stage][2*mb + dir]; NaN = not yet scheduled.
    let end = &mut scratch.end;
    for row in end.iter_mut() {
        row.fill(f64::NAN);
    }
    let orders = &scratch.orders;
    // Event-driven list scheduling in topological order: each stage
    // consumes its 1F1B order as soon as cross-stage dependencies resolve.
    let ptr = &mut scratch.ptr;
    ptr.fill(0);
    let clock = &mut scratch.clock;
    clock.fill(0.0);
    let total = n_stages * 2 * n_microbatches;
    let mut scheduled = 0usize;
    while scheduled < total {
        let mut progress = false;
        for s in 0..n_stages {
            while ptr[s] < orders[s].len() {
                let t = &orders[s][ptr[s]];
                let dep = if !t.is_bwd {
                    if s == 0 {
                        Some(0.0)
                    } else {
                        let v = end[s - 1][2 * t.mb];
                        if v.is_nan() {
                            None
                        } else {
                            Some(v)
                        }
                    }
                } else if s == n_stages - 1 {
                    let v = end[s][2 * t.mb];
                    if v.is_nan() {
                        None
                    } else {
                        Some(v)
                    }
                } else {
                    let v = end[s + 1][2 * t.mb + 1];
                    if v.is_nan() {
                        None
                    } else {
                        Some(v)
                    }
                };
                let Some(dep) = dep else { break };
                let start = clock[s].max(dep);
                let e = start + dur(t);
                end[s][2 * t.mb + t.is_bwd as usize] = e;
                clock[s] = e;
                ptr[s] += 1;
                scheduled += 1;
                progress = true;
            }
        }
        assert!(progress, "1F1B schedule deadlocked (inconsistent orders)");
    }
    let mut makespan = 0.0f64;
    scratch.busy.fill(0.0);
    for s in 0..n_stages {
        for t in &orders[s] {
            scratch.busy[s] += dur(t);
        }
        makespan = makespan.max(clock[s]);
    }
    makespan
}

/// Energy of a frozen plan given its already-simulated (makespan, busy):
/// task energies + static power during bubbles. [`greedy_fill`] simulates
/// once per candidate move and feeds the result straight here — the old
/// path re-ran the identical simulation inside its energy helper.
fn plan_energy_from_sim(
    menus: &[StageMenu],
    choice: &[Vec<usize>],
    n_microbatches: usize,
    p_static: f64,
    time: f64,
    busy: &[f64],
) -> (f64, f64, f64, f64) {
    let mut total = 0.0;
    let mut dynamic = 0.0;
    for (s, menu) in menus.iter().enumerate() {
        for mb in 0..n_microbatches {
            for d in 0..2 {
                let m = menu.menu(d == 1);
                let c = m[choice[s][2 * mb + d].min(m.len() - 1)];
                total += c.1;
                dynamic += c.2;
            }
        }
    }
    let bubble: f64 = busy.iter().map(|b| (time - b).max(0.0)).sum();
    total += p_static * bubble;
    (time, total, dynamic, bubble)
}

/// Build the iteration frontier by deadline sweep + greedy slack filling.
///
/// Returns (frontier over per-GPU (time, energy), plans). Energies are per
/// GPU within one pipeline (multiply by TP×CP×PP for cluster totals).
pub fn iteration_frontier(
    menus: &[StageMenu],
    n_microbatches: usize,
    p_static: f64,
    n_deadlines: usize,
) -> (Frontier, Vec<IterationPlan>) {
    let n_stages = menus.len();
    let min_choice = vec![vec![0usize; 2 * n_microbatches]; n_stages];
    let (t_min, _) = simulate_1f1b(menus, &min_choice, n_microbatches);

    // Loosest deadline worth considering: everything at its own
    // energy-minimal point.
    let max_choice: Vec<Vec<usize>> = (0..n_stages)
        .map(|s| {
            (0..2 * n_microbatches)
                .map(|i| {
                    let m = menus[s].menu(i % 2 == 1);
                    argmin_energy(m)
                })
                .collect()
        })
        .collect();
    let (t_max, _) = simulate_1f1b(menus, &max_choice, n_microbatches);

    let mut plans = Vec::new();
    let mut pts = Vec::new();
    for k in 0..n_deadlines.max(2) {
        let deadline =
            t_min + (t_max - t_min).max(0.0) * k as f64 / (n_deadlines - 1).max(1) as f64;
        let plan = greedy_fill(menus, n_microbatches, p_static, deadline);
        pts.push(Point::new(plan.time_s, plan.total_j, plans.len()));
        plans.push(plan);
    }
    (Frontier::from_points(pts), plans)
}

fn argmin_energy(m: &[(f64, f64, f64)]) -> usize {
    let mut best = 0;
    for (i, c) in m.iter().enumerate() {
        if c.1 < m[best].1 {
            best = i;
        }
    }
    best
}

/// Perseus-style greedy: start at min-time everywhere, then repeatedly
/// apply the move (one task → next, slower-but-cheaper frontier point)
/// with the highest task-local energy saving per added second, as long as
/// the 1F1B makespan stays within the deadline.
///
/// Granularity adapts to scale: per-task moves for testbed-sized
/// pipelines; per-(stage, direction) uniform moves for large-scale
/// emulation (10 stages × 128 microbatches), where per-task search would
/// be quadratic in thousands of slots.
pub fn greedy_fill(
    menus: &[StageMenu],
    n_microbatches: usize,
    p_static: f64,
    deadline: f64,
) -> IterationPlan {
    let n_stages = menus.len();
    let mut choice = vec![vec![0usize; 2 * n_microbatches]; n_stages];

    // Move groups: sets of task slots that move together. Testbed-sized
    // pipelines get one group per task (Perseus's per-microbatch control).
    // At emulation scale, the warm-up and cool-down microbatches — the
    // ones with real slack (the paper: bubbles are "normally reduced down
    // to the lowest frequency") — stay individually controllable, and the
    // steady-state middle moves as one block per (stage, direction).
    let per_task = n_stages * 2 * n_microbatches <= 192;
    // Groups are sets of (stage, slot) that move together. Three kinds:
    //  · fine-grained groups (per task, or per warmup/cooldown microbatch
    //    plus a per-stage middle block at emulation scale) absorb *slack*;
    //  · coordinated all-stage groups slow the whole pipeline uniformly —
    //    a single stage slowed alone just creates bubbles on the other
    //    stages (static burn ≥ dynamic savings), the coordinated move is
    //    what trades iteration time for dynamic energy.
    let mut groups: Vec<Vec<(usize, usize)>> = Vec::new();
    for s in 0..n_stages {
        for d in 0..2 {
            if per_task {
                for mb in 0..n_microbatches {
                    groups.push(vec![(s, 2 * mb + d)]);
                }
            } else {
                let edge = n_stages.min(n_microbatches / 2);
                let mut middle = Vec::new();
                for mb in 0..n_microbatches {
                    if mb < edge || mb >= n_microbatches - edge {
                        groups.push(vec![(s, 2 * mb + d)]);
                    } else {
                        middle.push((s, 2 * mb + d));
                    }
                }
                if !middle.is_empty() {
                    groups.push(middle);
                }
            }
        }
    }
    // Coordinated groups: all-forward, all-backward, and everything.
    let all_fwd: Vec<(usize, usize)> = (0..n_stages)
        .flat_map(|s| (0..n_microbatches).map(move |mb| (s, 2 * mb)))
        .collect();
    let all_bwd: Vec<(usize, usize)> = (0..n_stages)
        .flat_map(|s| (0..n_microbatches).map(move |mb| (s, 2 * mb + 1)))
        .collect();
    let mut all: Vec<(usize, usize)> = all_fwd.clone();
    all.extend(all_bwd.iter().copied());
    groups.push(all_fwd);
    groups.push(all_bwd);
    groups.push(all);

    // Max-heap of candidate moves keyed by energy-saved-per-second.
    #[derive(PartialEq)]
    struct Move {
        rate: f64,
        group: usize,
    }
    impl Eq for Move {}
    impl PartialOrd for Move {
        fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
            Some(self.cmp(o))
        }
    }
    impl Ord for Move {
        fn cmp(&self, o: &Self) -> std::cmp::Ordering {
            self.rate.partial_cmp(&o.rate).unwrap_or(std::cmp::Ordering::Equal)
        }
    }

    // Group move value: summed task-energy savings per summed added time
    // over members that can still advance. The true (bubble-coupled)
    // objective is verified before accepting.
    let group_rate = |choice: &Vec<Vec<usize>>, members: &[(usize, usize)]| -> Option<f64> {
        let mut de = 0.0;
        let mut dt = 0.0;
        for &(s, slot) in members {
            let m = menus[s].menu(slot % 2 == 1);
            let cur = choice[s][slot];
            if cur + 1 < m.len() {
                de += m[cur].1 - m[cur + 1].1;
                dt += m[cur + 1].0 - m[cur].0;
            }
        }
        if dt <= 0.0 {
            None
        } else {
            Some(de / dt)
        }
    };

    let mut heap = std::collections::BinaryHeap::new();
    for g in 0..groups.len() {
        if let Some(rate) = group_rate(&choice, &groups[g]) {
            heap.push(Move { rate, group: g });
        }
    }

    // One scratch for the whole fill: the stage orders are computed once,
    // and each candidate move costs exactly one (allocation-free)
    // simulation instead of the two back-to-back identical runs the old
    // simulate-then-plan_energy pair paid.
    let mut scratch = SimScratch::new(n_stages, n_microbatches);
    let t0 = simulate_1f1b_with(menus, &choice, n_microbatches, &mut scratch);
    let (_, mut total_cur, _, _) =
        plan_energy_from_sim(menus, &choice, n_microbatches, p_static, t0, &scratch.busy);
    while let Some(mv) = heap.pop() {
        let members = &groups[mv.group];
        // Advance every member that still has a slower point; remember
        // which actually moved so the revert is exact.
        let mut moved: Vec<(usize, usize)> = Vec::new();
        for &(s, slot) in members {
            let m = menus[s].menu(slot % 2 == 1);
            if choice[s][slot] + 1 < m.len() {
                choice[s][slot] += 1;
                moved.push((s, slot));
            }
        }
        if moved.is_empty() {
            continue;
        }
        let t = simulate_1f1b_with(menus, &choice, n_microbatches, &mut scratch);
        let (_, total_after, _, _) =
            plan_energy_from_sim(menus, &choice, n_microbatches, p_static, t, &scratch.busy);
        // A move must respect the deadline AND reduce true total energy
        // (task savings can be outweighed by static power burned in the
        // bubbles the slowdown creates on other stages).
        if t <= deadline * (1.0 + 1e-9) && total_after < total_cur - 1e-12 {
            total_cur = total_after;
            if let Some(rate) = group_rate(&choice, members) {
                heap.push(Move { rate, group: mv.group });
            }
        } else {
            for (s, slot) in moved {
                choice[s][slot] -= 1; // revert; this group is saturated
            }
        }
    }

    let t_final = simulate_1f1b_with(menus, &choice, n_microbatches, &mut scratch);
    let (time, total, dynamic, bubble) =
        plan_energy_from_sim(menus, &choice, n_microbatches, p_static, t_final, &scratch.busy);
    IterationPlan { choice, time_s: time, total_j: total, dyn_j: dynamic, bubble_s: bubble }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compose::{MbFrontier, MbPoint, MicrobatchPlan};
    use std::collections::BTreeMap;

    fn mb_frontier(points: &[(f64, f64, f64)]) -> MbFrontier {
        MbFrontier::from_points(
            points
                .iter()
                .map(|&(t, e, d)| MbPoint {
                    time_s: t,
                    total_j: e,
                    dyn_j: d,
                    plan: MicrobatchPlan {
                        freq_mhz: 1410,
                        configs: BTreeMap::new(),
                        sequential: true,
                    },
                })
                .collect(),
        )
    }

    fn menus(n_stages: usize) -> Vec<StageMenu> {
        // Realistic proportions: dynamic energy dominates, so slowing a
        // microbatch saves far more than the static power burned in any
        // bubble it creates (90 W × Δt).
        let f = mb_frontier(&[(1.0, 300.0, 250.0), (1.2, 240.0, 185.0), (1.5, 200.0, 140.0)]);
        let b = mb_frontier(&[(2.0, 600.0, 500.0), (2.4, 480.0, 370.0), (3.0, 400.0, 280.0)]);
        (0..n_stages).map(|_| StageMenu::from_frontiers(&f, &b)).collect()
    }

    #[test]
    fn stage_order_is_1f1b() {
        let o = stage_order(0, 2, 4);
        assert_eq!(o.len(), 8);
        // Stage 0 with 2 stages: 1 warmup fwd, then F B F B ...
        assert!(!o[0].is_bwd && !o[1].is_bwd && o[2].is_bwd);
        let o_last = stage_order(1, 2, 4);
        assert!(!o_last[0].is_bwd && o_last[1].is_bwd); // no warmup on last stage
    }

    #[test]
    fn all_tasks_scheduled_once() {
        for s in 0..3 {
            let o = stage_order(s, 3, 5);
            assert_eq!(o.len(), 10);
            let mut seen = std::collections::HashSet::new();
            for t in &o {
                assert!(seen.insert((t.mb, t.is_bwd)));
            }
        }
    }

    #[test]
    fn min_time_schedule_matches_analytic_1f1b() {
        // Uniform durations: makespan = (M + P - 1) * (tf + tb) for 1F1B
        // (approximately; exact for tf == tb).
        let f = mb_frontier(&[(1.0, 1.0, 0.5)]);
        let b = mb_frontier(&[(1.0, 1.0, 0.5)]);
        let m: Vec<StageMenu> = (0..4).map(|_| StageMenu::from_frontiers(&f, &b)).collect();
        let choice = vec![vec![0usize; 2 * 8]; 4];
        let (t, _) = simulate_1f1b(&m, &choice, 8);
        let expected = (8 + 4 - 1) as f64 * 2.0;
        assert!((t - expected).abs() < 1e-6, "t = {t}, expected {expected}");
    }

    #[test]
    fn deeper_pipeline_longer_makespan() {
        let (t2, _) = simulate_1f1b(&menus(2), &vec![vec![0; 12]; 2], 6);
        let (t4, _) = simulate_1f1b(&menus(4), &vec![vec![0; 12]; 4], 6);
        assert!(t4 > t2);
    }

    #[test]
    fn greedy_fill_saves_energy_with_slack() {
        let m = menus(2);
        let tight = greedy_fill(&m, 4, 90.0, 0.0); // impossible deadline -> min time
        let loose = greedy_fill(&m, 4, 90.0, tight.time_s * 1.3);
        assert!(loose.total_j < tight.total_j, "loose {} tight {}", loose.total_j, tight.total_j);
        assert!(loose.time_s <= tight.time_s * 1.3 + 1e-9);
        // Cheaper energy over a longer iteration ⇒ strictly lower draw.
        assert!(loose.avg_power_w() < tight.avg_power_w());
    }

    #[test]
    fn iteration_frontier_is_pareto() {
        let m = menus(2);
        let (f, plans) = iteration_frontier(&m, 4, 90.0, 8);
        assert!(f.len() >= 2, "frontier {}", f.len());
        assert!(!plans.is_empty());
        for w in f.points().windows(2) {
            assert!(w[1].time > w[0].time && w[1].energy < w[0].energy);
        }
    }

    #[test]
    fn sim_scratch_reuse_matches_fresh_bitwise() {
        let m = menus(3);
        let mut scratch = SimScratch::new(3, 4);
        for c in [0usize, 2, 1, 0] {
            let choice = vec![vec![c; 8]; 3];
            let t_reused = simulate_1f1b_with(&m, &choice, 4, &mut scratch);
            let (t_fresh, busy_fresh) = simulate_1f1b(&m, &choice, 4);
            assert_eq!(t_reused.to_bits(), t_fresh.to_bits());
            assert_eq!(scratch.busy().len(), busy_fresh.len());
            for (a, b) in scratch.busy().iter().zip(&busy_fresh) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn bubbles_nonnegative_and_counted() {
        let m = menus(3);
        let plan = greedy_fill(&m, 4, 90.0, 0.0);
        assert!(plan.bubble_s >= 0.0);
        // Warmup/cooldown bubbles must exist in a 3-stage pipeline.
        assert!(plan.bubble_s > 0.0);
    }
}
