//! Deterministic concurrency model checking (data-flow step ⑩).
//!
//! A no-deps, loom-style interleaving explorer for the crate's
//! concurrency layer. Code under test uses the [`crate::util::sync`]
//! shims; when a model closure runs inside an [`Explorer`], every
//! visible operation (lock, unlock, condvar wait/notify, atomic
//! load/store/rmw, spawn, join) is routed through a cooperative
//! scheduler that runs exactly one thread at a time and chooses, at
//! every scheduling point, which thread to run next. The [`Explorer`]
//! then enumerates those choices exhaustively:
//!
//! - **DFS over schedule prefixes**: each execution records, at every
//!   grant, the set of runnable threads and the choice taken; the
//!   explorer backtracks over untried alternatives, re-executing the
//!   (deterministic) model under the new forced prefix.
//! - **Bounded preemption** ([`Config::max_preemptions`]): switching
//!   away from a thread that is still runnable costs one unit of
//!   budget; most real concurrency bugs need very few preemptions
//!   (CHESS's observation), which keeps the search tractable.
//! - **State-hash pruning** ([`Config::prune`]): a state is the FNV-64
//!   of every thread's observation history plus every sync object's
//!   shadow state; once a state has been fully explored with at least
//!   as much remaining budget, re-reaching it cuts the execution short.
//!   Insertion is post-order (only after every alternative under the
//!   state has been explored), which keeps the pruning sound.
//!
//! Detected failures: **deadlock** (no runnable thread, none parked),
//! **lost wakeup** (no runnable thread, at least one parked on a
//! condvar), **double lock** (re-acquiring a held [`crate::util::sync::SyncMutex`]),
//! and **panic** (any model thread panicking, e.g. a failed assertion
//! inside the model). A failure report carries the exact schedule — the
//! sequence of thread ids granted, one per scheduling point — which
//! [`Explorer::replay`] re-executes deterministically; failing
//! schedules are committed as JSON fixtures under
//! `tests/fixtures/modelcheck/`.
//!
//! Everything here is deterministic: thread ids are assigned in spawn
//! order, object ids in construction order, runnable sets are sorted,
//! and exploration order is a pure function of the model. Running the
//! same exploration twice yields byte-identical reports.
//!
//! This module only exists under `--features modelcheck`; see
//! `tests/modelcheck.rs` for the harnesses that model-check the serve
//! coalescing protocol, the worker pool's drain-then-join shutdown, and
//! the daemon's shutdown accept-race.

use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};

use crate::util::hash::Fnv64;
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::sync::AtomicOp;

pub mod demos;

/// Exploration limits and switches.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    /// Preemption budget per execution: switching to another thread
    /// while the current one is still runnable costs one unit.
    pub max_preemptions: u32,
    /// Hard cap on executions; [`Report::capped`] is set if reached.
    pub max_schedules: u64,
    /// Enable state-hash pruning of already-explored suffixes.
    pub prune: bool,
}

impl Default for Config {
    fn default() -> Config {
        Config { max_preemptions: 2, max_schedules: 20_000, prune: true }
    }
}

/// What went wrong in a failing execution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailureKind {
    /// No thread can make progress and none is in a condvar wait.
    Deadlock,
    /// No thread can make progress and at least one is parked on a
    /// condvar — a notify was lost (or never sent).
    LostWakeup,
    /// A thread re-locked a mutex it already holds.
    DoubleLock,
    /// A model thread panicked (failed assertion, explicit panic).
    Panic,
    /// A replayed schedule named a thread that was not runnable at that
    /// point — the fixture does not match the model.
    ReplayDivergence,
}

impl FailureKind {
    /// Stable string form used in reports and fixtures.
    pub fn as_str(&self) -> &'static str {
        match self {
            FailureKind::Deadlock => "deadlock",
            FailureKind::LostWakeup => "lost-wakeup",
            FailureKind::DoubleLock => "double-lock",
            FailureKind::Panic => "panic",
            FailureKind::ReplayDivergence => "replay-divergence",
        }
    }

    /// Inverse of [`FailureKind::as_str`], for reading fixtures.
    pub fn parse(text: &str) -> Option<FailureKind> {
        Some(match text {
            "deadlock" => FailureKind::Deadlock,
            "lost-wakeup" => FailureKind::LostWakeup,
            "double-lock" => FailureKind::DoubleLock,
            "panic" => FailureKind::Panic,
            "replay-divergence" => FailureKind::ReplayDivergence,
            _ => return None,
        })
    }
}

/// A failing execution: kind, human-readable message, and the exact
/// schedule (granted thread id per scheduling point) that reproduces it
/// via [`Explorer::replay`].
#[derive(Clone, Debug)]
pub struct Failure {
    /// Classification of the failure.
    pub kind: FailureKind,
    /// Human-readable description (thread/object ids included).
    pub message: String,
    /// Thread id granted at each scheduling point, in order.
    pub schedule: Vec<usize>,
    /// Per-grant labels ("t1 lock m0", ...) for the same points.
    pub trace: Vec<String>,
}

impl Failure {
    /// Serialize for reports and replay fixtures.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("kind", s(self.kind.as_str())),
            ("message", s(&self.message)),
            ("schedule", arr(self.schedule.iter().map(|&t| num(t as f64)).collect())),
            ("trace", arr(self.trace.iter().map(|t| s(t)).collect())),
        ])
    }
}

/// Outcome of an exploration or replay.
#[derive(Clone, Debug)]
pub struct Report {
    /// Executions run (1 for a replay).
    pub schedules: u64,
    /// Total scheduling points granted across executions.
    pub decisions: u64,
    /// Executions cut short by state-hash pruning.
    pub pruned: u64,
    /// True if [`Config::max_schedules`] stopped the search early.
    pub capped: bool,
    /// First failure found, if any (exploration stops at the first).
    pub failure: Option<Failure>,
}

impl Report {
    /// Serialize; byte-identical across runs of the same exploration.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("schedules", num(self.schedules as f64)),
            ("decisions", num(self.decisions as f64)),
            ("pruned", num(self.pruned as f64)),
            ("capped", Json::Bool(self.capped)),
            ("failure", match &self.failure {
                Some(f) => f.to_json(),
                None => Json::Null,
            }),
        ])
    }

    /// `to_json().dump()` convenience.
    pub fn dump(&self) -> String {
        self.to_json().dump()
    }
}

/// Read a `{"schedule": [...]}` replay fixture (as emitted inside
/// [`Failure::to_json`] or committed under `tests/fixtures/modelcheck/`).
pub fn schedule_from_json(j: &Json) -> Option<Vec<usize>> {
    j.get("schedule")?.as_arr()?.iter().map(|v| v.as_usize()).collect()
}

// ---------------------------------------------------------------------------
// Scheduler state
// ---------------------------------------------------------------------------

/// Panic payload used to unwind controlled threads when an execution
/// aborts (failure found, or suffix pruned). Never escapes the checker.
struct AbortExecution;

/// A visible operation posted by a controlled thread, pending grant.
enum Op {
    /// First scheduling point of every thread, before any user code.
    Begin,
    Lock(u64),
    /// Post-notify mutex re-acquisition (second half of a condvar wait).
    Reacquire(u64),
    Wait { cv: u64, mutex: u64 },
    Notify { cv: u64, all: bool },
    Atomic { obj: u64, op: AtomicOp },
    Spawn(Box<dyn FnOnce() + Send>),
    Join(usize),
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum TState {
    /// Granted and executing user code (at most one thread at a time).
    Running,
    /// Posted an op, waiting for it to be granted.
    Posted,
    /// In a condvar wait, waiting for a notify.
    Parked,
    Finished,
}

struct TRec {
    state: TState,
    op: Option<Op>,
    parked_cv: u64,
    parked_mutex: u64,
    granted: bool,
    op_result: u64,
    /// Rolling FNV-64 over (tag, operand, observed value) of every
    /// granted op — the thread's deterministic observation history.
    history: u64,
}

impl TRec {
    /// A thread that exists but has not yet been allowed to start.
    fn posted_begin() -> TRec {
        TRec {
            state: TState::Posted,
            op: Some(Op::Begin),
            parked_cv: 0,
            parked_mutex: 0,
            granted: false,
            op_result: 0,
            history: 0,
        }
    }
}

/// Shadow state of one sync object (ids are construction order).
enum ObjRec {
    Mutex { owner: Option<usize> },
    Condvar,
    Atomic { value: u64 },
}

struct State {
    threads: Vec<TRec>,
    objs: Vec<ObjRec>,
    aborting: bool,
    failure: Option<Failure>,
    schedule: Vec<usize>,
    trace: Vec<String>,
}

struct Shared {
    mu: Mutex<State>,
    cv: Condvar,
}

impl Shared {
    fn fresh() -> Shared {
        Shared {
            mu: Mutex::new(State {
                threads: vec![TRec::posted_begin()],
                objs: Vec::new(),
                aborting: false,
                failure: None,
                schedule: Vec::new(),
                trace: Vec::new(),
            }),
            cv: Condvar::new(),
        }
    }

    fn record_failure(st: &mut State, kind: FailureKind, message: String) {
        if st.failure.is_none() {
            st.failure = Some(Failure {
                kind,
                message,
                schedule: st.schedule.clone(),
                trace: st.trace.clone(),
            });
        }
        st.aborting = true;
    }
}

// ---------------------------------------------------------------------------
// rt: the hooks util::sync routes through on controlled threads
// ---------------------------------------------------------------------------

/// Runtime face of the scheduler, called by the [`crate::util::sync`]
/// shims. Every function is a no-op (or identity) unless the calling
/// thread is controlled by an active [`Explorer`] execution.
pub(crate) mod rt {
    use super::*;
    use std::cell::RefCell;

    struct Ctx {
        shared: Arc<Shared>,
        tid: usize,
    }

    thread_local! {
        static CTX: RefCell<Option<Ctx>> = RefCell::new(None);
    }

    /// True iff this thread is controlled by an active execution.
    pub(crate) fn active() -> bool {
        CTX.with(|c| c.borrow().is_some())
    }

    fn with_ctx<R>(f: impl FnOnce(&Arc<Shared>, usize) -> R) -> Option<R> {
        CTX.with(|c| {
            let b = c.borrow();
            b.as_ref().map(|ctx| f(&ctx.shared, ctx.tid))
        })
    }

    fn register(o: ObjRec) -> Option<u64> {
        with_ctx(|sh, _tid| {
            let mut st = sh.mu.lock().unwrap_or_else(|e| e.into_inner());
            st.objs.push(o);
            (st.objs.len() - 1) as u64
        })
    }

    pub(crate) fn register_mutex() -> Option<u64> {
        register(ObjRec::Mutex { owner: None })
    }

    pub(crate) fn register_condvar() -> Option<u64> {
        register(ObjRec::Condvar)
    }

    pub(crate) fn register_atomic(init: u64) -> Option<u64> {
        register(ObjRec::Atomic { value: init })
    }

    /// Post `op` and block until the scheduler grants it. Returns the
    /// op's observed value (previous atomic value, spawned tid, 0).
    fn gate(sh: &Arc<Shared>, tid: usize, op: Op) -> u64 {
        // A thread that is already unwinding (user panic, or an
        // AbortExecution teardown) can reach here from drop glue — e.g.
        // a poison-on-drop fill guard taking its slot lock to notify
        // waiters. Never start a second panic inside a destructor:
        // skip the scheduling point and let the shim fall through to
        // its real `std` primitive, whose state is being torn down
        // anyway.
        if std::thread::panicking() {
            return 0;
        }
        let mut st = sh.mu.lock().unwrap_or_else(|e| e.into_inner());
        if st.aborting {
            drop(st);
            std::panic::panic_any(AbortExecution);
        }
        // Double-lock is detectable the moment it is posted: the poster
        // already owns the mutex, so no extension of any schedule could
        // ever grant it.
        if let Op::Lock(m) = &op {
            if let ObjRec::Mutex { owner: Some(o) } = &st.objs[*m as usize] {
                if *o == tid {
                    Shared::record_failure(
                        &mut st,
                        FailureKind::DoubleLock,
                        format!("thread {tid} re-locked mutex m{m} it already holds"),
                    );
                    sh.cv.notify_all();
                    drop(st);
                    std::panic::panic_any(AbortExecution);
                }
            }
        }
        st.threads[tid].op = Some(op);
        st.threads[tid].state = TState::Posted;
        sh.cv.notify_all();
        loop {
            if st.threads[tid].granted {
                break;
            }
            if st.aborting {
                drop(st);
                std::panic::panic_any(AbortExecution);
            }
            st = sh.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        st.threads[tid].granted = false;
        st.threads[tid].op_result
    }

    /// Wait for this thread's `Begin` grant (the op was posted by the
    /// spawner), without posting anything.
    fn await_begin(sh: &Arc<Shared>, tid: usize) {
        let mut st = sh.mu.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if st.threads[tid].granted {
                break;
            }
            if st.aborting {
                drop(st);
                std::panic::panic_any(AbortExecution);
            }
            st = sh.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        st.threads[tid].granted = false;
    }

    pub(crate) fn mutex_lock(id: u64) {
        with_ctx(|sh, tid| {
            gate(sh, tid, Op::Lock(id));
        });
    }

    /// Immediate effect (no scheduling point): releasing a mutex only
    /// enables other threads; any switch it could cause is equivalent
    /// to one at the releasing thread's next posted op. Must never
    /// panic — it runs on guard-drop paths during unwinding.
    pub(crate) fn mutex_unlock(id: u64) {
        with_ctx(|sh, tid| {
            let mut st = sh.mu.lock().unwrap_or_else(|e| e.into_inner());
            if let ObjRec::Mutex { owner } = &mut st.objs[id as usize] {
                if *owner == Some(tid) {
                    *owner = None;
                }
            }
            sh.cv.notify_all();
        });
    }

    /// Two-stage condvar wait: the grant of the `Wait` op releases the
    /// mutex and parks; a later `Notify` re-posts the thread as a
    /// `Reacquire`, whose grant finally returns control here.
    pub(crate) fn condvar_wait(cv: u64, mutex: u64) {
        with_ctx(|sh, tid| {
            gate(sh, tid, Op::Wait { cv, mutex });
        });
    }

    pub(crate) fn condvar_notify(cv: u64, all: bool) {
        with_ctx(|sh, tid| {
            gate(sh, tid, Op::Notify { cv, all });
        });
    }

    /// Apply `op` to the shadow cell at its scheduling point; returns
    /// the previous value.
    pub(crate) fn atomic(id: u64, op: AtomicOp) -> u64 {
        with_ctx(|sh, tid| gate(sh, tid, Op::Atomic { obj: id, op })).unwrap_or(0)
    }

    /// Register and start a controlled thread; returns its model tid.
    pub(crate) fn spawn(f: Box<dyn FnOnce() + Send>) -> u64 {
        with_ctx(|sh, tid| gate(sh, tid, Op::Spawn(f))).unwrap_or(0)
    }

    /// Block until thread `target` finishes (a scheduling point).
    pub(crate) fn join(target: u64) {
        with_ctx(|sh, tid| {
            gate(sh, tid, Op::Join(target as usize));
        });
    }

    /// Body of every controlled OS thread: install the TLS handle, wait
    /// for `Begin`, run the user closure, record panics (aborting the
    /// execution), and mark the thread finished.
    pub(super) fn run_controlled(sh: Arc<Shared>, tid: usize, f: Box<dyn FnOnce() + Send>) {
        CTX.with(|c| {
            *c.borrow_mut() = Some(Ctx { shared: Arc::clone(&sh), tid });
        });
        let result = catch_unwind(AssertUnwindSafe(|| {
            await_begin(&sh, tid);
            f();
        }));
        CTX.with(|c| {
            *c.borrow_mut() = None;
        });
        let mut st = sh.mu.lock().unwrap_or_else(|e| e.into_inner());
        if let Err(payload) = result {
            if !payload.is::<AbortExecution>() {
                let msg = if let Some(m) = payload.downcast_ref::<&str>() {
                    (*m).to_string()
                } else if let Some(m) = payload.downcast_ref::<String>() {
                    m.clone()
                } else {
                    "non-string panic payload".to_string()
                };
                Shared::record_failure(
                    &mut st,
                    FailureKind::Panic,
                    format!("thread {tid} panicked: {msg}"),
                );
            }
        }
        st.threads[tid].state = TState::Finished;
        st.threads[tid].op = None;
        sh.cv.notify_all();
    }
}

// ---------------------------------------------------------------------------
// Grant application
// ---------------------------------------------------------------------------

fn grant_run(t: &mut TRec, result: u64) {
    t.granted = true;
    t.state = TState::Running;
    t.op_result = result;
}

fn update_history(st: &mut State, tid: usize, tag: u64, operand: u64, value: u64) {
    let mut h = Fnv64::new();
    h.write_u64(st.threads[tid].history)
        .write_u64(tag)
        .write_u64(operand)
        .write_u64(value);
    st.threads[tid].history = h.finish();
}

/// Apply the granted op's effect under the state lock. Returns the
/// closure of a newly spawned thread (to be started outside the lock).
fn apply_grant(st: &mut State, tid: usize) -> Option<(usize, Box<dyn FnOnce() + Send>)> {
    let op = st.threads[tid].op.take().expect("granted thread has no posted op");
    let mut spawned = None;
    let label = match op {
        Op::Begin => {
            grant_run(&mut st.threads[tid], 0);
            update_history(st, tid, 1, 0, 0);
            format!("t{tid} begin")
        }
        Op::Lock(m) => {
            if let ObjRec::Mutex { owner } = &mut st.objs[m as usize] {
                *owner = Some(tid);
            }
            grant_run(&mut st.threads[tid], 0);
            update_history(st, tid, 2, m, 0);
            format!("t{tid} lock m{m}")
        }
        Op::Reacquire(m) => {
            if let ObjRec::Mutex { owner } = &mut st.objs[m as usize] {
                *owner = Some(tid);
            }
            grant_run(&mut st.threads[tid], 0);
            update_history(st, tid, 3, m, 0);
            format!("t{tid} reacquire m{m}")
        }
        Op::Wait { cv, mutex } => {
            // Atomically release the mutex and park; the thread stays
            // blocked in its gate until a notify re-posts it as a
            // Reacquire and that gets granted.
            if let ObjRec::Mutex { owner } = &mut st.objs[mutex as usize] {
                if *owner == Some(tid) {
                    *owner = None;
                }
            }
            let t = &mut st.threads[tid];
            t.state = TState::Parked;
            t.parked_cv = cv;
            t.parked_mutex = mutex;
            update_history(st, tid, 4, cv, mutex);
            format!("t{tid} wait cv{cv}")
        }
        Op::Notify { cv, all } => {
            let mut woken: Vec<usize> = Vec::new();
            for w in 0..st.threads.len() {
                if st.threads[w].state == TState::Parked && st.threads[w].parked_cv == cv {
                    woken.push(w);
                    if !all {
                        break;
                    }
                }
            }
            for &w in &woken {
                let mutex = st.threads[w].parked_mutex;
                st.threads[w].state = TState::Posted;
                st.threads[w].op = Some(Op::Reacquire(mutex));
            }
            grant_run(&mut st.threads[tid], woken.len() as u64);
            update_history(st, tid, 5, cv, woken.len() as u64);
            let verb = if all { "notify_all" } else { "notify" };
            if woken.is_empty() {
                format!("t{tid} {verb} cv{cv} (woke none)")
            } else {
                let ids: Vec<String> = woken.iter().map(|w| format!("t{w}")).collect();
                format!("t{tid} {verb} cv{cv} (woke {})", ids.join(","))
            }
        }
        Op::Atomic { obj, op } => {
            let (prev, desc) = match &mut st.objs[obj as usize] {
                ObjRec::Atomic { value } => {
                    let prev = *value;
                    let desc = match op {
                        AtomicOp::Load => format!("load={prev}"),
                        AtomicOp::Store(v) => {
                            *value = v;
                            format!("store {v}")
                        }
                        AtomicOp::FetchAdd(v) => {
                            *value = value.wrapping_add(v);
                            format!("fetch_add {v} (was {prev})")
                        }
                        AtomicOp::FetchSub(v) => {
                            *value = value.wrapping_sub(v);
                            format!("fetch_sub {v} (was {prev})")
                        }
                        AtomicOp::CompareExchange { expect, new } => {
                            if prev == expect {
                                *value = new;
                                format!("cas {expect}->{new} ok")
                            } else {
                                format!("cas {expect}->{new} failed (was {prev})")
                            }
                        }
                    };
                    (prev, desc)
                }
                _ => (0, "atomic on non-atomic object".to_string()),
            };
            grant_run(&mut st.threads[tid], prev);
            update_history(st, tid, 6, obj, prev);
            format!("t{tid} atomic a{obj} {desc}")
        }
        Op::Spawn(f) => {
            let new_tid = st.threads.len();
            st.threads.push(TRec::posted_begin());
            spawned = Some((new_tid, f));
            grant_run(&mut st.threads[tid], new_tid as u64);
            update_history(st, tid, 7, new_tid as u64, 0);
            format!("t{tid} spawn t{new_tid}")
        }
        Op::Join(target) => {
            grant_run(&mut st.threads[tid], 0);
            update_history(st, tid, 8, target as u64, 0);
            format!("t{tid} join t{target}")
        }
    };
    st.schedule.push(tid);
    st.trace.push(label);
    spawned
}

/// Threads whose posted op can be granted right now, ascending by tid.
fn runnable(st: &State) -> Vec<usize> {
    let mut out = Vec::new();
    for (i, t) in st.threads.iter().enumerate() {
        if t.state != TState::Posted {
            continue;
        }
        let ok = match &t.op {
            Some(Op::Lock(m)) | Some(Op::Reacquire(m)) => {
                matches!(&st.objs[*m as usize], ObjRec::Mutex { owner: None })
            }
            Some(Op::Join(target)) => st.threads[*target].state == TState::Finished,
            Some(_) => true,
            None => false,
        };
        if ok {
            out.push(i);
        }
    }
    out
}

/// FNV-64 of the whole quiescent state: per-thread histories (which
/// determine each deterministic thread's continuation) plus every
/// object's shadow state.
fn state_key(st: &State) -> u64 {
    let mut h = Fnv64::new();
    for (i, t) in st.threads.iter().enumerate() {
        let tag = match t.state {
            TState::Posted => 1u64,
            TState::Parked => 2,
            TState::Finished => 3,
            TState::Running => 4,
        };
        h.write_u64(i as u64).write_u64(tag).write_u64(t.history);
        if t.state == TState::Parked {
            h.write_u64(t.parked_cv).write_u64(t.parked_mutex);
        }
    }
    for (i, o) in st.objs.iter().enumerate() {
        h.write_u64(i as u64);
        match o {
            ObjRec::Mutex { owner } => {
                h.write_u64(10).write_u64(owner.map_or(u64::MAX, |t| t as u64));
            }
            ObjRec::Condvar => {
                h.write_u64(11);
            }
            ObjRec::Atomic { value } => {
                h.write_u64(12).write_u64(*value);
            }
        }
    }
    h.finish()
}

// ---------------------------------------------------------------------------
// Explorer
// ---------------------------------------------------------------------------

/// One scheduling point of one execution, as seen by the DFS.
struct Decision {
    runnable: Vec<usize>,
    chosen: usize,
    preempt_before: u32,
    key: u64,
}

struct ExecRun {
    decisions: Vec<Decision>,
    failure: Option<Failure>,
    truncated: bool,
}

#[derive(Default)]
struct Stats {
    schedules: u64,
    decisions: u64,
    pruned: u64,
    capped: bool,
}

type Model = Arc<dyn Fn() + Send + Sync + 'static>;

/// Exhaustive bounded interleaving explorer over a model closure.
///
/// The model must be a *pure function of its observed sync history*:
/// it is re-executed once per explored schedule, so it must not carry
/// state across invocations (construct everything it shares inside the
/// closure) and must not consult anything nondeterministic. Assertions
/// inside the model surface as [`FailureKind::Panic`].
pub struct Explorer {
    cfg: Config,
}

impl Explorer {
    /// Explorer with the given limits.
    pub fn new(cfg: Config) -> Explorer {
        Explorer { cfg }
    }

    /// Explore every schedule of `model` within the preemption bound;
    /// stops at the first failure. Deterministic: the same model and
    /// config always return a byte-identical report.
    pub fn explore<F>(&self, model: F) -> Report
    where
        F: Fn() + Send + Sync + 'static,
    {
        let model: Model = Arc::new(model);
        let mut memo: BTreeMap<u64, u32> = BTreeMap::new();
        let mut stats = Stats::default();
        let failure = self.explore_rec(&model, Vec::new(), &mut memo, &mut stats);
        Report {
            schedules: stats.schedules,
            decisions: stats.decisions,
            pruned: stats.pruned,
            capped: stats.capped,
            failure,
        }
    }

    /// Re-execute `model` under an exact schedule (from a failure
    /// report or fixture). The forced prefix is followed verbatim —
    /// divergence is reported as [`FailureKind::ReplayDivergence`] —
    /// and any remaining suffix runs under the default policy.
    pub fn replay<F>(&self, schedule: &[usize], model: F) -> Report
    where
        F: Fn() + Send + Sync + 'static,
    {
        let model: Model = Arc::new(model);
        let run = self.run_one(&model, schedule, true, None);
        Report {
            schedules: 1,
            decisions: run.decisions.len() as u64,
            pruned: 0,
            capped: false,
            failure: run.failure,
        }
    }

    fn explore_rec(
        &self,
        model: &Model,
        prefix: Vec<usize>,
        memo: &mut BTreeMap<u64, u32>,
        stats: &mut Stats,
    ) -> Option<Failure> {
        if stats.schedules >= self.cfg.max_schedules {
            stats.capped = true;
            return None;
        }
        let run = {
            let memo_ref = if self.cfg.prune { Some(&*memo) } else { None };
            self.run_one(model, &prefix, false, memo_ref)
        };
        stats.schedules += 1;
        stats.decisions += run.decisions.len() as u64;
        if run.truncated {
            stats.pruned += 1;
        }
        if run.failure.is_some() {
            return run.failure;
        }
        // Backtrack: try every untried, budget-feasible alternative at
        // every free (non-forced) scheduling point, deepest first. The
        // memo entry for a point is inserted only after all its
        // alternatives are explored (post-order), so pruning on it is
        // sound.
        for i in (prefix.len()..run.decisions.len()).rev() {
            let prev = if i == 0 { None } else { Some(run.decisions[i - 1].chosen) };
            let runnable = run.decisions[i].runnable.clone();
            let chosen = run.decisions[i].chosen;
            let pb = run.decisions[i].preempt_before;
            let key = run.decisions[i].key;
            for &alt in &runnable {
                if alt == chosen {
                    continue;
                }
                let cost = u32::from(prev.is_some_and(|p| p != alt && runnable.contains(&p)));
                if pb + cost > self.cfg.max_preemptions {
                    continue;
                }
                let mut p2: Vec<usize> =
                    run.decisions[..i].iter().map(|d| d.chosen).collect();
                p2.push(alt);
                if let Some(f) = self.explore_rec(model, p2, memo, stats) {
                    return Some(f);
                }
                if stats.capped {
                    return None;
                }
            }
            if self.cfg.prune {
                let remaining = self.cfg.max_preemptions - pb;
                memo.entry(key).and_modify(|b| *b = (*b).max(remaining)).or_insert(remaining);
            }
        }
        None
    }

    /// Run one execution: start the model as controlled thread 0, then
    /// grant ops one at a time — forced prefix first, then "keep the
    /// current thread running if runnable, else lowest tid".
    fn run_one(
        &self,
        model: &Model,
        forced: &[usize],
        replay: bool,
        memo: Option<&BTreeMap<u64, u32>>,
    ) -> ExecRun {
        let sh = Arc::new(Shared::fresh());
        let mut handles = Vec::new();
        {
            let sh2 = Arc::clone(&sh);
            let m2 = Arc::clone(model);
            handles.push(std::thread::spawn(move || {
                rt::run_controlled(sh2, 0, Box::new(move || m2()))
            }));
        }
        let mut decisions: Vec<Decision> = Vec::new();
        let mut preemptions = 0u32;
        let mut prev: Option<usize> = None;
        let mut truncated = false;
        let mut step = 0usize;
        'sched: loop {
            let mut pending: Option<(usize, Box<dyn FnOnce() + Send>)> = None;
            {
                let mut st = sh.mu.lock().unwrap_or_else(|e| e.into_inner());
                loop {
                    if st.aborting {
                        break 'sched;
                    }
                    if st.threads.iter().all(|t| t.state == TState::Finished) {
                        break 'sched;
                    }
                    let quiescent = st.threads.iter().all(|t| {
                        matches!(t.state, TState::Posted | TState::Parked | TState::Finished)
                    });
                    if !quiescent {
                        st = sh.cv.wait(st).unwrap_or_else(|e| e.into_inner());
                        continue;
                    }
                    let run = runnable(&st);
                    if run.is_empty() {
                        let parked =
                            st.threads.iter().any(|t| t.state == TState::Parked);
                        let kind = if parked {
                            FailureKind::LostWakeup
                        } else {
                            FailureKind::Deadlock
                        };
                        let mut states: Vec<String> = Vec::new();
                        for (i, t) in st.threads.iter().enumerate() {
                            if t.state == TState::Parked {
                                states.push(format!("t{i}=parked(cv{})", t.parked_cv));
                            } else if t.state == TState::Posted {
                                states.push(format!("t{i}=blocked"));
                            }
                        }
                        Shared::record_failure(
                            &mut st,
                            kind,
                            format!("no runnable thread: {}", states.join(", ")),
                        );
                        sh.cv.notify_all();
                        break 'sched;
                    }
                    let pick = if step < forced.len() {
                        let want = forced[step];
                        if !run.contains(&want) {
                            let what = if replay { "replayed schedule" } else { "prefix" };
                            let ids: Vec<String> =
                                run.iter().map(|t| format!("t{t}")).collect();
                            Shared::record_failure(
                                &mut st,
                                FailureKind::ReplayDivergence,
                                format!(
                                    "{what} names t{want} at step {step} but runnable is [{}]",
                                    ids.join(",")
                                ),
                            );
                            sh.cv.notify_all();
                            break 'sched;
                        }
                        want
                    } else if prev.is_some_and(|p| run.contains(&p)) {
                        prev.expect("checked above")
                    } else {
                        run[0]
                    };
                    let key = if replay { 0 } else { state_key(&st) };
                    if !replay && step >= forced.len() {
                        if let Some(m) = memo {
                            if let Some(&b) = m.get(&key) {
                                if b >= self.cfg.max_preemptions - preemptions {
                                    truncated = true;
                                    st.aborting = true;
                                    sh.cv.notify_all();
                                    break 'sched;
                                }
                            }
                        }
                    }
                    decisions.push(Decision {
                        runnable: run.clone(),
                        chosen: pick,
                        preempt_before: preemptions,
                        key,
                    });
                    if prev.is_some_and(|p| p != pick && run.contains(&p)) {
                        preemptions += 1;
                    }
                    pending = apply_grant(&mut st, pick);
                    prev = Some(pick);
                    step += 1;
                    sh.cv.notify_all();
                    break;
                }
            }
            if let Some((tid, f)) = pending.take() {
                let sh2 = Arc::clone(&sh);
                handles.push(std::thread::spawn(move || rt::run_controlled(sh2, tid, f)));
            }
        }
        // Drain: wake everything, let controlled threads unwind, join.
        {
            let mut st = sh.mu.lock().unwrap_or_else(|e| e.into_inner());
            if !st.threads.iter().all(|t| t.state == TState::Finished) {
                st.aborting = true;
                sh.cv.notify_all();
                while !st.threads.iter().all(|t| t.state == TState::Finished) {
                    st = sh.cv.wait(st).unwrap_or_else(|e| e.into_inner());
                }
            }
        }
        for h in handles {
            let _ = h.join();
        }
        let failure = {
            let st = sh.mu.lock().unwrap_or_else(|e| e.into_inner());
            st.failure.clone()
        };
        ExecRun { decisions, failure, truncated }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn double_lock_detected_with_minimal_schedule() {
        let report = Explorer::new(Config::default()).explore(demos::double_lock);
        let f = report.failure.expect("double lock must be found");
        assert_eq!(f.kind, FailureKind::DoubleLock);
        // Every choice on the failing path is forced, so the first
        // execution already hits it with the minimal schedule.
        assert_eq!(f.schedule, vec![0, 0, 1, 1]);
        assert_eq!(report.schedules, 1);
        assert!(!report.capped);
    }

    #[test]
    fn lost_wakeup_detected() {
        let report = Explorer::new(Config::default()).explore(demos::lost_wakeup);
        let f = report.failure.expect("lost wakeup must be found");
        assert_eq!(f.kind, FailureKind::LostWakeup);
        assert!(!report.capped);
        // The reported schedule must replay to the same failure.
        let again = Explorer::new(Config::default()).replay(&f.schedule, demos::lost_wakeup);
        assert_eq!(again.failure.expect("replay refinds it").kind, FailureKind::LostWakeup);
    }

    #[test]
    fn correct_model_passes() {
        let report = Explorer::new(Config::default()).explore(demos::wakeup_correct);
        assert!(report.failure.is_none(), "unexpected: {:#?}", report.failure);
        assert!(!report.capped);
        assert!(report.schedules >= 2, "branching model explores >1 schedule");
    }

    #[test]
    fn replay_divergence_is_typed() {
        let report = Explorer::new(Config::default()).replay(&[5], demos::wakeup_correct);
        assert_eq!(report.failure.expect("diverges").kind, FailureKind::ReplayDivergence);
    }

    #[test]
    fn reports_are_byte_deterministic() {
        let a = Explorer::new(Config::default()).explore(demos::lost_wakeup).dump();
        let b = Explorer::new(Config::default()).explore(demos::lost_wakeup).dump();
        assert_eq!(a, b);
    }

    #[test]
    fn schedule_fixture_roundtrip() {
        let f = Failure {
            kind: FailureKind::DoubleLock,
            message: "m".to_string(),
            schedule: vec![0, 0, 1, 1],
            trace: vec!["t0 begin".to_string()],
        };
        let j = Json::parse(&f.to_json().dump()).expect("parse own dump");
        assert_eq!(schedule_from_json(&j), Some(vec![0, 0, 1, 1]));
        assert_eq!(
            FailureKind::parse(j.get("kind").and_then(|k| k.as_str()).unwrap()),
            Some(FailureKind::DoubleLock)
        );
    }
}
