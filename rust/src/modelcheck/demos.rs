//! Deliberately broken (and one correct) concurrency models used to
//! validate the checker itself.
//!
//! Each function is a complete model closure body: construct shared
//! state inside, spawn controlled threads through
//! [`crate::util::sync::spawn`], and join. The broken ones each seed
//! one classic bug the explorer must detect; their minimal failing
//! schedules are committed as fixtures under
//! `tests/fixtures/modelcheck/` and re-checked by replay tests.

use std::sync::Arc;

use crate::util::sync::{spawn, SyncAtomicBool, SyncCondvar, SyncMutex};

/// Seeded bug: a thread locks the same mutex twice.
///
/// Thread layout: t0 spawns t1; t1 takes `m` and, still holding it,
/// takes it again. Detected at the second acquire's post — no schedule
/// can ever grant it. Minimal failing schedule: `[0, 0, 1, 1]`
/// (t0 begin, t0 spawn, t1 begin, t1 first lock).
pub fn double_lock() {
    let m = Arc::new(SyncMutex::new(0u32));
    let m2 = Arc::clone(&m);
    let t = spawn(move || {
        let _a = m2.lock();
        let _b = m2.lock(); // bug: self-deadlock in a plain mutex
    });
    let _ = t.join();
}

/// Seeded bug: the classic two-thread lost wakeup.
///
/// The waiter checks a flag and then waits; the signaler sets the flag
/// and notifies *without holding the mutex that guards the check*. On
/// schedules where the signaler runs entirely inside the waiter's
/// check-then-wait window, the notify finds nobody parked and the
/// waiter sleeps forever. Minimal failing schedule:
/// `[0, 0, 0, 1, 1, 1, 2, 2, 2, 1]`.
pub fn lost_wakeup() {
    let flag = Arc::new(SyncAtomicBool::new(false));
    let pair = Arc::new((SyncMutex::new(()), SyncCondvar::new()));
    let (f1, p1) = (Arc::clone(&flag), Arc::clone(&pair));
    let waiter = spawn(move || {
        let (m, cv) = &*p1;
        let g = m.lock();
        if !f1.load() {
            // bug: by the time we park, the notify may already be gone
            let _g = cv.wait(g);
        }
    });
    let (f2, p2) = (Arc::clone(&flag), Arc::clone(&pair));
    let signaler = spawn(move || {
        f2.store(true);
        p2.1.notify_one(); // bug: not ordered against the waiter's check
    });
    let _ = waiter.join();
    let _ = signaler.join();
}

/// Correct version of [`lost_wakeup`]: the predicate lives under the
/// mutex and the signaler holds it across set-and-notify, so every
/// interleaving wakes the waiter. The explorer must find no failure.
pub fn wakeup_correct() {
    let pair = Arc::new((SyncMutex::new(false), SyncCondvar::new()));
    let p1 = Arc::clone(&pair);
    let waiter = spawn(move || {
        let (m, cv) = &*p1;
        let mut g = m.lock();
        while !*g {
            g = cv.wait(g);
        }
    });
    let p2 = Arc::clone(&pair);
    let signaler = spawn(move || {
        let (m, cv) = &*p2;
        *m.lock() = true;
        cv.notify_one();
    });
    waiter.join().expect("waiter");
    signaler.join().expect("signaler");
}
