//! End-to-end systems under comparison (§6.1): Megatron-LM, Perseus,
//! Nanobatching, naive combinations, Kareus, and the Table 8 ablations.
//!
//! Each system maps a `TrainConfig` to per-(stage, direction) microbatch
//! frontiers, then composes the 1F1B iteration frontier. All systems share
//! the same simulator physics; they differ exactly in which execution-
//! schedule factors they control:
//!
//! | system            | kernel schedule          | frequency  |
//! |-------------------|--------------------------|------------|
//! | Megatron-LM       | sequential               | max only   |
//! | Megatron + Perseus| sequential               | per-µbatch |
//! | Nanobatching      | fixed default overlap    | max only   |
//! | Nanobatch + Perseus| fixed default overlap   | per-µbatch |
//! | Kareus w/o freq   | MBO (SM alloc + timing)  | max only   |
//! | Kareus            | MBO (SM alloc + timing)  | per-µbatch |

use std::collections::BTreeMap;

use crate::compose::{
    eval_overlapped_microbatch_fp, eval_sequential_microbatch_fp, microbatch_fps,
    microbatch_frontier, sequential_fps, MbFrontier, MbPoint,
};
use crate::engine::EngineConfig;
use crate::frontier::Frontier;
use crate::partition::{detect_partitions, Partition};
use crate::pipeline::{iteration_frontier, IterationPlan, StageMenu};
use crate::sim::exec::{LaunchAt, Schedule};
use crate::sim::gpu::GpuSpec;
use crate::workload::{build_nanobatch_pass, build_pass, Dir, TrainConfig};

/// Nanobatching's default communication kernel configuration (§3.2): NCCL
/// defaults tuned for sequential execution — "may use excessive SMs" — and
/// launch-as-soon-as-possible.
pub const NANO_DEFAULT_SMS: u32 = 20;
pub const NANO_DEFAULT_LAUNCH: LaunchAt = LaunchAt::WithComp(0);

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum System {
    Megatron,
    MegatronPerseus,
    Nanobatching,
    NanobatchingPerseus,
    Kareus,
    /// Table 8 ablation: kernel scheduling only (frequency pinned at max).
    KareusNoFreq,
    /// Table 8 ablation: frequency scaling only (default overlap schedule)
    /// — equivalent to Nanobatching + Perseus.
    KareusNoSched,
    /// Strategy-ablation reference: the full Kareus pipeline with the
    /// per-partition search swapped from multi-pass MBO to uniform random
    /// sampling at the same measurement budget
    /// ([`StrategyKind::Random`](crate::mbo::StrategyKind)).
    KareusRandom,
}

impl System {
    pub fn name(&self) -> &'static str {
        match self {
            System::Megatron => "Megatron-LM",
            System::MegatronPerseus => "Megatron-LM+Perseus",
            System::Nanobatching => "Nanobatching",
            System::NanobatchingPerseus => "Nanobatching+Perseus",
            System::Kareus => "Kareus",
            System::KareusNoFreq => "Kareus w/o frequency",
            System::KareusNoSched => "Kareus w/o kernel schedule",
            System::KareusRandom => "Kareus (random search)",
        }
    }

    /// Inverse of [`name`](Self::name) (plan-file deserialization).
    pub fn by_name(name: &str) -> Option<System> {
        [
            System::Megatron,
            System::MegatronPerseus,
            System::Nanobatching,
            System::NanobatchingPerseus,
            System::Kareus,
            System::KareusNoFreq,
            System::KareusNoSched,
            System::KareusRandom,
        ]
        .into_iter()
        .find(|s| s.name() == name)
    }
}

/// One system's iteration-level result on one workload.
#[derive(Clone, Debug)]
pub struct SystemResult {
    pub system: System,
    /// Per-GPU iteration (time, total energy) frontier.
    pub frontier: Frontier,
    pub plans: Vec<IterationPlan>,
    /// The per-stage menus the plans index into — kept so a selected
    /// operating point can be materialized into a typed
    /// [`FrequencyPlan`](crate::plan::FrequencyPlan).
    pub menus: Vec<StageMenu>,
    /// Simulated MBO profiling overhead (s), Kareus only.
    pub mbo_profiling_s: f64,
    /// Achieved TFLOP/s/GPU at the min-time point (Table 3's last column).
    pub tflops_per_gpu: f64,
}

impl SystemResult {
    /// The max-throughput plan; `None` on an empty frontier (callers must
    /// handle infeasible/degenerate results rather than unwrap blindly).
    pub fn min_time_plan(&self) -> Option<&IterationPlan> {
        Some(&self.plans[self.frontier.min_time()?.tag])
    }
}

/// Per-stage microbatch frontiers for a given execution policy.
fn stage_frontiers<F>(cfg: &TrainConfig, mut make: F) -> Vec<StageMenu>
where
    F: FnMut(bool, bool, Dir) -> MbFrontier,
{
    let pp = cfg.par.pp as usize;
    (0..pp)
        .map(|s| {
            let first = s == 0;
            let last = s == pp - 1;
            StageMenu::from_frontiers(&make(first, last, Dir::Fwd), &make(first, last, Dir::Bwd))
        })
        .collect()
}

/// Deadline-sweep resolution for iteration frontiers. Finer sweeps make
/// iso-time/iso-energy lookups (§6.1 metrics) accurate; emulation-scale
/// pipelines use a slightly coarser grid to bound greedy cost.
fn n_deadlines(cfg: &TrainConfig) -> usize {
    if cfg.par.pp as usize * cfg.n_microbatches as usize > 64 {
        20
    } else {
        24
    }
}

/// Run one system on one workload with default engine settings (auto
/// thread count, fresh caches).
pub fn run_system(gpu: &GpuSpec, cfg: &TrainConfig, system: System, seed: u64) -> SystemResult {
    run_system_with(gpu, cfg, system, seed, &EngineConfig::default())
}

/// Run one system on one workload on a shared optimization engine: the
/// per-partition MBO fans out across the engine's workers and both
/// memoization layers (canonical executions, whole MBO results) are
/// consulted, so repeated workloads — Table 8 ablations, sweep scenarios —
/// replay instead of re-simulating. Byte-identical to the sequential,
/// cache-free path for a fixed seed.
pub fn run_system_with(
    gpu: &GpuSpec,
    cfg: &TrainConfig,
    system: System,
    seed: u64,
    engine: &EngineConfig,
) -> SystemResult {
    let freqs_all = gpu.search_freqs();
    let fmax = gpu.f_max_mhz;
    let mut mbo_profiling_s = 0.0;
    // All measurements flow through the engine's backend + shared cache.
    let m = engine.measurer();

    let menus: Vec<StageMenu> = match system {
        System::Megatron | System::MegatronPerseus => {
            let freqs: Vec<u32> =
                if system == System::Megatron { vec![fmax] } else { freqs_all.clone() };
            stage_frontiers(cfg, |first, last, dir| {
                let w = build_pass(cfg, cfg.tokens_per_gpu(), dir, first, last);
                let fps = sequential_fps(gpu, &w);
                MbFrontier::from_points(
                    freqs
                        .iter()
                        .map(|&f| eval_sequential_microbatch_fp(gpu, &w, Some(&fps), f, m))
                        .collect(),
                )
            })
        }
        System::Nanobatching | System::NanobatchingPerseus | System::KareusNoSched => {
            let freqs: Vec<u32> =
                if system == System::Nanobatching { vec![fmax] } else { freqs_all.clone() };
            stage_frontiers(cfg, |first, last, dir| {
                let w = build_nanobatch_pass(cfg, dir, first, last);
                let parts = detect_partitions(gpu, &w, true);
                let fps = microbatch_fps(gpu, &parts, &w.extra);
                let points: Vec<MbPoint> = freqs
                    .iter()
                    .map(|&f| {
                        let configs = default_configs(&parts, f);
                        eval_overlapped_microbatch_fp(
                            gpu,
                            &parts,
                            Some(&fps),
                            &configs,
                            f,
                            &w.extra,
                            m,
                        )
                    })
                    .collect();
                MbFrontier::from_points(points)
            })
        }
        System::Kareus | System::KareusNoFreq | System::KareusRandom => {
            // One search per partition type (types repeat across stages).
            // The random-search reference rides the identical pipeline
            // with only the strategy swapped; sharing the caches is safe
            // because cache keys fold the strategy fingerprint.
            let engine_random;
            let engine = if system == System::KareusRandom {
                engine_random = engine.clone().with_strategy(crate::mbo::StrategyKind::Random);
                &engine_random
            } else {
                engine
            };
            let comm_group = cfg.par.tp * cfg.par.cp;
            let fwd_w = build_nanobatch_pass(cfg, Dir::Fwd, false, false);
            let bwd_w = build_nanobatch_pass(cfg, Dir::Bwd, false, false);
            let mut parts = detect_partitions(gpu, &fwd_w, true);
            parts.extend(detect_partitions(gpu, &bwd_w, true));
            let mbo =
                crate::compose::optimize_all_partitions_with(seed, gpu, &parts, comm_group, engine);
            // Partitions profile in parallel across GPUs (§6.6), so the
            // charged overhead is the slowest one, not the sum.
            mbo_profiling_s = mbo.values().map(|r| r.profiling_cost_s).fold(0.0f64, f64::max);
            stage_frontiers(cfg, |first, last, dir| {
                let nano_w = build_nanobatch_pass(cfg, dir, first, last);
                let parts = detect_partitions(gpu, &nano_w, true);
                let seq_w = build_pass(cfg, cfg.tokens_per_gpu(), dir, first, last);
                let mut mbf =
                    microbatch_frontier(gpu, &parts, &mbo, &nano_w.extra, Some(&seq_w), m);
                if system == System::KareusNoFreq {
                    let pts: Vec<MbPoint> = mbf
                        .points
                        .into_iter()
                        .filter(|p| p.plan.freq_mhz == fmax)
                        .collect();
                    mbf = MbFrontier::from_points(pts);
                }
                mbf
            })
        }
    };

    let (frontier, plans) =
        iteration_frontier(&menus, cfg.n_microbatches as usize, gpu.static_w, n_deadlines(cfg));

    // Achieved TFLOP/s/GPU at max throughput: model FLOPs / (time · GPUs),
    // counting real math (undo the efficiency derate is unnecessary — we
    // count the analytic model FLOPs like the paper does).
    let t_min = frontier.min_time().map(|p| p.time).unwrap_or(f64::NAN);
    let tflops = analytic_model_flops_per_gpu(cfg) / t_min / 1e12;

    SystemResult { system, frontier, plans, menus, mbo_profiling_s, tflops_per_gpu: tflops }
}

/// The cluster-level reference policy: split the datacenter cap into N
/// equal shares (one per job with a non-empty menu) and let each job
/// independently pick its fastest operating point within its share —
/// what a frontier-oblivious operator does with per-job power limits.
/// Jobs whose *minimum*-power point still exceeds the share are pinned
/// there and the allocation is flagged infeasible.
///
/// Compare against [`cluster::allocate`](crate::cluster::allocate), which
/// pools the cap across jobs along their frontiers (`kareus paper --exp
/// cluster` quantifies the gap).
pub fn uniform_cap_allocation(
    menus: &[crate::cluster::JobMenu],
    cap_w: f64,
) -> crate::cluster::Allocation {
    let active = menus.iter().filter(|m| !m.points.is_empty()).count();
    let share = if active == 0 { 0.0 } else { cap_w / active as f64 };
    let slack = share * 1e-9;
    let mut feasible = true;
    let selection = menus
        .iter()
        .map(|m| {
            if m.points.is_empty() {
                return None;
            }
            // Menus ascend in time and descend in power, so the first
            // point within the share is the fastest one that fits.
            m.points.iter().position(|p| p.power_w <= share + slack).or_else(|| {
                feasible = false;
                m.min_power_point()
            })
        })
        .collect();
    crate::cluster::Allocation::from_selection(menus, selection, feasible)
}

fn default_configs(parts: &[Partition], f: u32) -> BTreeMap<String, Schedule> {
    parts
        .iter()
        .map(|p| {
            (
                p.ptype.clone(),
                Schedule::uniform(NANO_DEFAULT_SMS, NANO_DEFAULT_LAUNCH, f),
            )
        })
        .collect()
}

/// Analytic 6·N·T-style FLOP count per GPU per iteration (fwd + bwd with
/// recompute ≈ 4× fwd), for the achieved-TFLOP/s column.
pub fn analytic_model_flops_per_gpu(cfg: &TrainConfig) -> f64 {
    let m = &cfg.model;
    let d = m.d_model as f64;
    let ff = m.d_ff as f64;
    let kv = (m.n_kv_heads as f64 / m.n_heads as f64) * d;
    let tokens_iter =
        cfg.microbatch as f64 * cfg.seq_len as f64 * cfg.n_microbatches as f64;
    let per_layer_per_token = 2.0 * (d * d + 2.0 * d * kv + d * d + 3.0 * d * ff)
        + 4.0 * cfg.seq_len as f64 * d * 0.5; // attention scores+values, causal
    let fwd = m.n_layers as f64 * per_layer_per_token * tokens_iter
        + 2.0 * tokens_iter * d * m.vocab as f64;
    // fwd + recompute + bwd(2x) = 4x fwd per iteration.
    4.0 * fwd / cfg.par.gpus() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{ModelSpec, Parallelism};

    fn cfg() -> TrainConfig {
        TrainConfig {
            model: ModelSpec::qwen3_1_7b(),
            par: Parallelism::new(8, 1, 2),
            microbatch: 8,
            seq_len: 4096,
            n_microbatches: 8,
            dtype_bytes: 2,
        }
    }

    #[test]
    fn megatron_single_point() {
        let g = GpuSpec::a100();
        let r = run_system(&g, &cfg(), System::Megatron, 0);
        assert_eq!(r.frontier.len(), 1);
        assert!(r.min_time_plan().unwrap().time_s > 0.0);
        assert_eq!(r.menus.len(), cfg().par.pp as usize);
    }

    #[test]
    fn system_names_roundtrip() {
        for sys in [
            System::Megatron,
            System::MegatronPerseus,
            System::Nanobatching,
            System::NanobatchingPerseus,
            System::Kareus,
            System::KareusNoFreq,
            System::KareusNoSched,
            System::KareusRandom,
        ] {
            assert_eq!(System::by_name(sys.name()), Some(sys));
        }
        assert_eq!(System::by_name("nope"), None);
    }

    #[test]
    fn perseus_extends_frontier_without_time_penalty() {
        let g = GpuSpec::a100();
        let m = run_system(&g, &cfg(), System::Megatron, 0);
        let mp = run_system(&g, &cfg(), System::MegatronPerseus, 0);
        assert!(mp.frontier.len() > 1);
        let t_m = m.frontier.min_time().unwrap().time;
        let t_mp = mp.frontier.min_time().unwrap().time;
        // Perseus keeps iteration time ≈ the same (±2%) at max throughput.
        assert!((t_mp - t_m).abs() / t_m < 0.02, "m {t_m} mp {t_mp}");
        // …while saving energy at the same point.
        let e_m = m.frontier.min_time().unwrap().energy;
        let e_mp = mp.frontier.energy_at_deadline(t_m * 1.001).unwrap();
        assert!(e_mp < e_m, "no energy saving: {e_mp} vs {e_m}");
    }

    #[test]
    fn nanobatching_reduces_time_vs_megatron() {
        let g = GpuSpec::a100();
        let m = run_system(&g, &cfg(), System::Megatron, 0);
        let n = run_system(&g, &cfg(), System::Nanobatching, 0);
        let t_m = m.frontier.min_time().unwrap().time;
        let t_n = n.frontier.min_time().unwrap().time;
        assert!(t_n < t_m, "nano {t_n} vs megatron {t_m}");
    }

    #[test]
    fn kareus_dominates_baselines() {
        let g = GpuSpec::a100();
        let c = cfg();
        let k = run_system(&g, &c, System::Kareus, 1);
        let np = run_system(&g, &c, System::NanobatchingPerseus, 1);
        let t_k = k.frontier.min_time().unwrap().time;
        let t_np = np.frontier.min_time().unwrap().time;
        assert!(t_k <= t_np * 1.005, "kareus {t_k} vs n+p {t_np}");
        // Iso-time energy: Kareus at N+P's min-time should cost no more.
        let e_k = k.frontier.energy_at_deadline(t_np).unwrap();
        let e_np = np.frontier.min_time().unwrap().energy;
        assert!(e_k <= e_np * 1.005, "kareus {e_k} vs n+p {e_np}");
        assert!(k.mbo_profiling_s > 0.0);
    }

    #[test]
    fn random_search_reference_runs_full_pipeline() {
        // The strategy-ablation row: same pipeline, random per-partition
        // search. It must produce a real frontier and charge profiling
        // time, and informed MBO must be at least as good at max
        // throughput (small tolerance: both search the same space).
        let g = GpuSpec::a100();
        let c = cfg();
        let r = run_system(&g, &c, System::KareusRandom, 1);
        assert!(r.frontier.len() >= 3, "frontier len {}", r.frontier.len());
        assert!(r.mbo_profiling_s > 0.0);
        let k = run_system(&g, &c, System::Kareus, 1);
        let t_r = r.frontier.min_time().unwrap().time;
        let t_k = k.frontier.min_time().unwrap().time;
        assert!(t_k <= t_r * 1.02, "kareus {t_k} vs random {t_r}");
    }

    #[test]
    fn tflops_in_plausible_range() {
        // Paper Table 1: Megatron-LM achieves ~99 TFLOP/s/GPU on Qwen 1.7B.
        let g = GpuSpec::a100();
        let r = run_system(&g, &cfg(), System::Megatron, 0);
        assert!(
            (40.0..250.0).contains(&r.tflops_per_gpu),
            "tflops {}",
            r.tflops_per_gpu
        );
    }
}
