//! Minimal CLI argument parser (no clap offline): `--key value` /
//! `--flag` options plus positional arguments.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(key) = a.strip_prefix("--") {
                // `--key=value`, `--key value`, or bare `--flag`.
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.options.insert(key.to_string(), iter.next().unwrap());
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_u32(&self, key: &str, default: u32) -> u32 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// Comma-separated list option (`--gpus a100,h100`), with a default
    /// when absent; empty items are dropped.
    pub fn get_list(&self, key: &str, default: &str) -> Vec<String> {
        self.get(key)
            .unwrap_or(default)
            .split(',')
            .map(|x| x.trim().to_string())
            .filter(|x| !x.is_empty())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn mixed_forms() {
        // Note: a bare `--flag` is only a flag when not followed by a
        // non-option token (documented greedy `--key value` semantics).
        let a = parse("paper pos2 --exp table3 --seed=7 --verbose");
        assert_eq!(a.positional, vec!["paper", "pos2"]);
        assert_eq!(a.get("exp"), Some("table3"));
        assert_eq!(a.get_u32("seed", 0), 7);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn defaults() {
        let a = parse("train");
        assert_eq!(a.get_u32("steps", 100), 100);
        assert_eq!(a.get_f64("deadline", 1.5), 1.5);
        assert!(!a.has_flag("x"));
    }

    #[test]
    fn flag_before_value_option() {
        let a = parse("--all --exp table1");
        assert!(a.has_flag("all"));
        assert_eq!(a.get("exp"), Some("table1"));
    }

    #[test]
    fn list_option() {
        let a = parse("sweep --gpus a100,h100, v100");
        // Note: the space after the comma ends the option token; the
        // remaining value arrives via the default-free first token only.
        assert_eq!(a.get_list("gpus", "x"), vec!["a100", "h100"]);
        assert_eq!(a.get_list("models", "qwen1.7b,llama3b"), vec!["qwen1.7b", "llama3b"]);
        assert_eq!(a.get_list("empty", ""), Vec::<String>::new());
    }
}
