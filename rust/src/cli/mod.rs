//! Minimal CLI argument parser (no clap offline): `--key value` /
//! `--flag` options plus positional arguments.
//!
//! Malformed input is a proper `Err`, never a panic: a bare `--` or an
//! empty option name (`--=v`) is rejected with a message the binary can
//! print, and a trailing `--flag` with no following value parses as a
//! flag.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Result<Args, String> {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(key) = a.strip_prefix("--") {
                if key.is_empty() || key.starts_with('=') {
                    return Err(format!("malformed option '{a}': empty option name"));
                }
                // `--key=value`, `--key value`, or bare `--flag`.
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    // The peek guarantees a value token; `ok_or_else`
                    // (rather than `unwrap`) keeps any future iterator
                    // desync an error instead of a panic.
                    let v = iter
                        .next()
                        .ok_or_else(|| format!("option '--{key}' expects a value"))?;
                    out.options.insert(key.to_string(), v);
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_u32(&self, key: &str, default: u32) -> u32 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// Comma-separated list option (`--gpus a100,h100`), with a default
    /// when absent; empty items are dropped.
    pub fn get_list(&self, key: &str, default: &str) -> Vec<String> {
        self.get(key)
            .unwrap_or(default)
            .split(',')
            .map(|x| x.trim().to_string())
            .filter(|x| !x.is_empty())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).expect("well-formed argv")
    }

    #[test]
    fn mixed_forms() {
        // Note: a bare `--flag` is only a flag when not followed by a
        // non-option token (documented greedy `--key value` semantics).
        let a = parse("paper pos2 --exp table3 --seed=7 --verbose");
        assert_eq!(a.positional, vec!["paper", "pos2"]);
        assert_eq!(a.get("exp"), Some("table3"));
        assert_eq!(a.get_u32("seed", 0), 7);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn defaults() {
        let a = parse("train");
        assert_eq!(a.get_u32("steps", 100), 100);
        assert_eq!(a.get_f64("deadline", 1.5), 1.5);
        assert!(!a.has_flag("x"));
    }

    #[test]
    fn flag_before_value_option() {
        let a = parse("--all --exp table1");
        assert!(a.has_flag("all"));
        assert_eq!(a.get("exp"), Some("table1"));
    }

    #[test]
    fn list_option() {
        let a = parse("sweep --gpus a100,h100, v100");
        // Note: the space after the comma ends the option token; the
        // remaining value arrives via the default-free first token only.
        assert_eq!(a.get_list("gpus", "x"), vec!["a100", "h100"]);
        assert_eq!(a.get_list("models", "qwen1.7b,llama3b"), vec!["qwen1.7b", "llama3b"]);
        assert_eq!(a.get_list("empty", ""), Vec::<String>::new());
    }

    #[test]
    fn trailing_option_with_no_value_is_a_flag_not_a_panic() {
        // Regression: `--out` as the final token used to route through an
        // `iter.next().unwrap()`-shaped path; it must parse as a flag.
        let a = parse("sweep --out");
        assert!(a.has_flag("out"));
        assert_eq!(a.get("out"), None);
        // Same when the trailing flag follows a consumed option.
        let a = parse("sweep --seed 7 --verbose");
        assert_eq!(a.get_u32("seed", 0), 7);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn malformed_options_return_errors() {
        assert!(Args::parse(["--".to_string()]).is_err());
        assert!(Args::parse(["--=7".to_string()]).is_err());
        assert!(Args::parse(["ok".to_string(), "--".to_string(), "x".to_string()]).is_err());
        // Well-formed input still parses.
        assert!(Args::parse(["--ok".to_string()]).is_ok());
    }
}
