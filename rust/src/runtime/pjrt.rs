//! PJRT execution runtime: loads the HLO-text artifacts emitted by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//! Python never runs on this path — the Rust binary is self-contained
//! once `make artifacts` has produced `artifacts/`. (The *online
//! replanning* runtime lives in the parent [`runtime`](crate::runtime)
//! module; this file is only the PJRT loader behind phase ⑤.)
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO *text* → HloModuleProto
//! → XlaComputation → compile → execute (the text parser reassigns the
//! 64-bit instruction ids that xla_extension 0.5.1 would reject in
//! serialized protos).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Tensor spec from the manifest.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    fn from_json(j: &Json) -> Result<Self> {
        let shape = j
            .get("shape")
            .and_then(|s| s.as_arr())
            .ok_or_else(|| anyhow!("bad shape"))?
            .iter()
            .map(|v| v.as_usize().unwrap_or(0))
            .collect();
        let dtype =
            j.get("dtype").and_then(|d| d.as_str()).ok_or_else(|| anyhow!("bad dtype"))?.into();
        Ok(TensorSpec { shape, dtype })
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub file: String,
    pub args: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Model config entry from the manifest.
#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub vocab: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub n_param_arrays: usize,
    pub n_params: usize,
    pub lr: f64,
}

#[derive(Debug)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub configs: BTreeMap<String, ModelInfo>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let raw = std::fs::read_to_string(dir.join("manifest.json")).with_context(|| {
            format!("reading {}/manifest.json (run `make artifacts`)", dir.display())
        })?;
        let j = Json::parse(&raw).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let mut artifacts = BTreeMap::new();
        let listed =
            j.get("artifacts").and_then(|a| a.as_obj()).ok_or_else(|| anyhow!("no artifacts"))?;
        for (name, a) in listed {
            let file = a.get("file").and_then(|f| f.as_str()).ok_or_else(|| anyhow!("no file"))?;
            let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
                a.get(key)
                    .and_then(|x| x.as_arr())
                    .ok_or_else(|| anyhow!("no {key}"))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            let spec = ArtifactSpec {
                file: file.into(),
                args: parse_specs("args")?,
                outputs: parse_specs("outputs")?,
            };
            artifacts.insert(name.clone(), spec);
        }
        let mut configs = BTreeMap::new();
        if let Some(cfgs) = j.get("configs").and_then(|c| c.as_obj()) {
            for (name, c) in cfgs {
                let u = |k: &str| c.get(k).and_then(|v| v.as_usize()).unwrap_or(0);
                configs.insert(
                    name.clone(),
                    ModelInfo {
                        vocab: u("vocab"),
                        seq_len: u("seq_len"),
                        batch: u("batch"),
                        n_param_arrays: u("n_param_arrays"),
                        n_params: u("n_params"),
                        lr: c.get("lr").and_then(|v| v.as_f64()).unwrap_or(0.0),
                    },
                );
            }
        }
        Ok(Manifest { artifacts, configs })
    }
}

/// The PJRT runtime: one client, lazily compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    compiled: BTreeMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = artifact_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;
        Ok(Runtime { client, dir, manifest, compiled: BTreeMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (and cache) one artifact.
    pub fn compile(&mut self, name: &str) -> Result<()> {
        if self.compiled.contains_key(name) {
            return Ok(());
        }
        let spec = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}"))?;
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        self.compiled.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact on host literals; returns the un-tupled output
    /// literals (aot.py lowers with return_tuple=True).
    pub fn execute(&mut self, name: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.compile(name)?;
        let spec = &self.manifest.artifacts[name];
        if args.len() != spec.args.len() {
            bail!("{name}: expected {} args, got {}", spec.args.len(), args.len());
        }
        let exe = &self.compiled[name];
        let out = exe.execute::<xla::Literal>(args).map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let lit = out[0][0].to_literal_sync().map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let parts = lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        if parts.len() != spec.outputs.len() {
            bail!("{name}: expected {} outputs, got {}", spec.outputs.len(), parts.len());
        }
        Ok(parts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn manifest_parses() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&artifacts_dir()).unwrap();
        assert!(m.artifacts.contains_key("train_step_tiny"));
        let tiny = &m.configs["tiny"];
        assert!(tiny.n_param_arrays > 0);
        let ts = &m.artifacts["train_step_tiny"];
        assert_eq!(ts.args.len(), 3 * tiny.n_param_arrays + 2);
    }

    #[test]
    fn spec_elements() {
        let s = TensorSpec { shape: vec![2, 3, 4], dtype: "float32".into() };
        assert_eq!(s.elements(), 24);
    }
}
