//! Online replanning runtime (data-flow step ⑦): train under
//! *time-varying* conditions, replanning incrementally when the plan goes
//! stale.
//!
//! Kareus's frontier-pushing schedules are computed once, but both energy
//! terms it optimizes drift during training: static power rises with the
//! die's thermal state ([`sim::thermal`](crate::sim::thermal)), and the
//! effective critical path moves when a straggler slows iterations or the
//! cluster layer changes the power cap mid-run. This module closes the
//! loop:
//!
//! * [`TrainingLoop`] steps iterations against the optimizer's retained
//!   output (frontier + stage menus + typed plans), applying an injected
//!   [`DriftSchedule`] (straggler slowdowns), the live per-GPU
//!   [`PowerCapSchedule`](crate::cluster::PowerCapSchedule), and the
//!   first-order thermal model — so observed iteration (time, energy)
//!   deviates from the plan exactly the way §4.1's "changing
//!   environments" describe.
//! * [`DriftMonitor`] watches the smoothed observed/predicted ratios with
//!   hysteresis (threshold + patience + cooldown, re-baselined after
//!   every replan) and decides when the active
//!   [`FrequencyPlan`](crate::plan::FrequencyPlan) is stale.
//! * Replanning is **incremental**: a cap-segment boundary re-selects
//!   along the retained frontier (no optimizer run at all), and a drift
//!   trigger re-runs the optimizer *warm* — per-partition searches replay
//!   from the engine's [`MboCache`](crate::engine::MboCache) and
//!   canonical executions from the shared
//!   [`MeasureCache`](crate::profiler::MeasureCache), so a replan bills
//!   only true cache misses instead of a cold re-optimization
//!   (`tests/runtime.rs` asserts the gap).
//! * Every plan change is logged as a typed
//!   [`PlanRevision`](crate::plan::PlanRevision); the
//!   [`RevisionLog`](crate::plan::RevisionLog) JSON is byte-deterministic
//!   (the CI replanning smoke `cmp`s two runs).
//!
//! Three [`ReplanPolicy`]s exist so `kareus paper --exp replanning` can
//! quantify the win: `static` (plan once, never react), `drift`
//! (monitor-triggered + cap boundaries), and `oracle` (replans exactly at
//! the injected event boundaries with perfect knowledge — the reference
//! the drift policy must land within 5% of).
//!
//! The PJRT execution runtime (phase ⑤'s artifact loader) lives in
//! [`pjrt`] and is re-exported unchanged.

pub mod pjrt;

pub use pjrt::{ArtifactSpec, Manifest, ModelInfo, Runtime, TensorSpec};

use crate::baselines::{run_system_with, System, SystemResult};
use crate::cluster::PowerCapSchedule;
use crate::engine::{EngineConfig, ReplanConfig};
use crate::frontier::Frontier;
use crate::plan::{FrequencyPlan, PlanRevision, ReplanTrigger, RevisionLog};
use crate::sim::gpu::GpuSpec;
use crate::sim::thermal::{ThermalModel, ThermalState};
use crate::util::json::{arr, num, obj, s, Json};
use crate::workload::TrainConfig;

// ---------------------------------------------------------------------------
// Injected environment drift
// ---------------------------------------------------------------------------

/// One segment of the injected straggler timeline: from iteration
/// `start_iter` until the next segment, every iteration's wall time is
/// multiplied by `slowdown` (1.0 = nominal).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DriftSegment {
    pub start_iter: u64,
    pub slowdown: f64,
}

/// Piecewise-constant straggler-slowdown timeline over iteration index —
/// the injected "changing environment" the replanning experiments run
/// under. Validated like [`PowerCapSchedule`]: strictly ascending starts,
/// finite positive factors; a missing leading segment is implicitly
/// nominal (factor 1.0 from iteration 0).
#[derive(Clone, Debug, PartialEq)]
pub struct DriftSchedule {
    segments: Vec<DriftSegment>,
}

impl DriftSchedule {
    /// No injected drift (factor 1.0 throughout).
    pub fn none() -> Self {
        DriftSchedule { segments: vec![DriftSegment { start_iter: 0, slowdown: 1.0 }] }
    }

    /// Validate and build. A first segment starting after iteration 0 gets
    /// an implicit nominal prefix.
    pub fn piecewise(mut segments: Vec<DriftSegment>) -> Result<Self, String> {
        if segments.is_empty() {
            return Ok(Self::none());
        }
        if segments[0].start_iter > 0 {
            segments.insert(0, DriftSegment { start_iter: 0, slowdown: 1.0 });
        }
        for w in segments.windows(2) {
            if w[1].start_iter <= w[0].start_iter {
                return Err(format!(
                    "drift segment starts must strictly ascend ({} then {})",
                    w[0].start_iter, w[1].start_iter
                ));
            }
        }
        for seg in &segments {
            if !seg.slowdown.is_finite() || seg.slowdown <= 0.0 {
                return Err(format!(
                    "drift segment (iter {}, x{}) must have a finite positive factor",
                    seg.start_iter, seg.slowdown
                ));
            }
        }
        Ok(DriftSchedule { segments })
    }

    /// Parse the CLI format: either a plain factor (`"1.25"` — constant)
    /// or comma-separated `iter:factor` pairs (`"150:1.25,300:1.0"`).
    pub fn parse(spec: &str) -> Result<Self, String> {
        let mut segments = Vec::new();
        for item in spec.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            let (start, factor) = match item.split_once(':') {
                Some((a, b)) => (a, b),
                None => ("0", item),
            };
            let start_iter: u64 =
                start.trim().parse().map_err(|_| format!("bad drift start '{start}'"))?;
            let slowdown: f64 =
                factor.trim().parse().map_err(|_| format!("bad drift factor '{factor}'"))?;
            segments.push(DriftSegment { start_iter, slowdown });
        }
        if segments.is_empty() {
            return Err("empty drift schedule".to_string());
        }
        Self::piecewise(segments)
    }

    pub fn segments(&self) -> &[DriftSegment] {
        &self.segments
    }

    /// The slowdown factor in force at iteration `iter`.
    pub fn factor_at(&self, iter: u64) -> f64 {
        let mut f = self.segments[0].slowdown;
        for seg in &self.segments {
            if seg.start_iter <= iter {
                f = seg.slowdown;
            } else {
                break;
            }
        }
        f
    }

    /// True iff a segment boundary sits exactly at `iter` (> 0) — the
    /// oracle policy's replan instants.
    pub fn is_boundary(&self, iter: u64) -> bool {
        iter > 0 && self.segments.iter().any(|seg| seg.start_iter == iter)
    }
}

// ---------------------------------------------------------------------------
// Drift monitor
// ---------------------------------------------------------------------------

/// Hysteresis-guarded drift detector over the observed/predicted
/// iteration ratios.
///
/// Both ratios (time, energy) are EWMA-smoothed; drift is the relative
/// deviation of the smoothed ratio from its *baseline* — the smoothed
/// value at the last replan — so a replan that absorbs the new conditions
/// re-arms the monitor instead of re-firing forever (the thermal
/// warm-up's leakage growth is the canonical slow drift this absorbs).
/// A trigger needs the deviation to exceed the threshold for `patience`
/// consecutive iterations, with at least `cooldown_iters` since the last
/// replan.
#[derive(Clone, Debug)]
pub struct DriftMonitor {
    cfg: ReplanConfig,
    time_ratio: f64,
    energy_ratio: f64,
    baseline_time: f64,
    baseline_energy: f64,
    streak: u32,
    last_replan_iter: Option<u64>,
}

impl DriftMonitor {
    pub fn new(cfg: ReplanConfig) -> Self {
        DriftMonitor {
            cfg,
            time_ratio: 1.0,
            energy_ratio: 1.0,
            baseline_time: 1.0,
            baseline_energy: 1.0,
            streak: 0,
            last_replan_iter: None,
        }
    }

    /// Fold one iteration's `(predicted, observed)` (time, energy) pair
    /// in; returns true when a replan should fire.
    pub fn observe(&mut self, iter: u64, predicted: (f64, f64), observed: (f64, f64)) -> bool {
        let a = self.cfg.ewma_alpha;
        let rt = observed.0 / predicted.0.max(1e-12);
        let re = observed.1 / predicted.1.max(1e-12);
        self.time_ratio += a * (rt - self.time_ratio);
        self.energy_ratio += a * (re - self.energy_ratio);
        let dev_t = (self.time_ratio / self.baseline_time - 1.0).abs();
        let dev_e = (self.energy_ratio / self.baseline_energy - 1.0).abs();
        if dev_t.max(dev_e) > self.cfg.drift_pct / 100.0 {
            self.streak += 1;
        } else {
            self.streak = 0;
        }
        let cooled = self
            .last_replan_iter
            .is_none_or(|last| iter.saturating_sub(last) >= self.cfg.cooldown_iters);
        self.streak >= self.cfg.patience && cooled
    }

    /// Re-arm after a replan at `iter`: the current smoothed ratios become
    /// the new baseline (hysteresis).
    pub fn rebaseline(&mut self, iter: u64) {
        self.baseline_time = self.time_ratio;
        self.baseline_energy = self.energy_ratio;
        self.streak = 0;
        self.last_replan_iter = Some(iter);
    }

    /// The smoothed observed/predicted *time* ratio — the straggler-factor
    /// estimate re-selection budgets against.
    pub fn slowdown_estimate(&self) -> f64 {
        self.time_ratio.max(1.0)
    }
}

// ---------------------------------------------------------------------------
// Policies and loop configuration
// ---------------------------------------------------------------------------

/// When the runtime re-plans.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReplanPolicy {
    /// Plan once, never react (the stale-plan baseline).
    Static,
    /// React to [`DriftMonitor`] triggers and cap-segment boundaries.
    Drift,
    /// Replan exactly at the injected event boundaries with perfect
    /// knowledge of the new conditions — the reference the drift policy
    /// is measured against.
    Oracle,
}

impl ReplanPolicy {
    pub fn parse(spec: &str) -> Option<ReplanPolicy> {
        match spec {
            "static" => Some(ReplanPolicy::Static),
            "drift" => Some(ReplanPolicy::Drift),
            "oracle" => Some(ReplanPolicy::Oracle),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ReplanPolicy::Static => "static",
            ReplanPolicy::Drift => "drift",
            ReplanPolicy::Oracle => "oracle",
        }
    }
}

/// Configuration of one [`TrainingLoop`] run.
#[derive(Clone, Debug)]
pub struct LoopConfig {
    pub n_iters: u64,
    /// Wall-clock deadline for the whole run (s). `None` derives
    /// `n_iters × t_min × (1 + deadline_slack)` from the initial frontier.
    pub deadline_s: Option<f64>,
    pub deadline_slack: f64,
    /// Per-GPU power-cap timeline over simulated wall-clock (W); `None`
    /// means uncapped.
    pub caps: Option<PowerCapSchedule>,
    /// Injected straggler timeline.
    pub drift: DriftSchedule,
    pub policy: ReplanPolicy,
    pub seed: u64,
}

impl Default for LoopConfig {
    fn default() -> Self {
        LoopConfig {
            n_iters: 400,
            deadline_s: None,
            deadline_slack: 0.02,
            caps: None,
            drift: DriftSchedule::none(),
            policy: ReplanPolicy::Drift,
            seed: 2026,
        }
    }
}

/// Appendix A's Jensen penalty applied when a plan is board-throttled: a
/// plan drawing `s×` the active cap runs at oscillating frequency, which
/// costs more dynamic energy than the average-frequency equivalent.
const THROTTLE_JENSEN: f64 = 0.15;

/// One observed iteration (what the monitor and the totals see).
#[derive(Clone, Copy, Debug)]
pub struct ObservedIter {
    pub time_s: f64,
    pub energy_j: f64,
    pub throttled: bool,
}

/// Physical outcome of running one deployed operating point for one
/// iteration under the current conditions. Policy-independent by
/// construction — it depends only on the deployed point's reference-
/// temperature characteristics and the live (slowdown, cap, temperature):
///
/// * straggler: time × `slowdown`;
/// * cap: a plan whose nominal draw exceeds the cap is throttled — time
///   stretches by the overshoot `s` and dynamic energy pays the Jensen
///   penalty `1 + 0.15·(s − 1)` (Appendix A: fluctuating frequency costs
///   more than its average);
/// * thermal: the static share scales with the stretched duration *and*
///   the leakage factor `static_power(temp) / static_w`.
pub fn observe_iteration(
    gpu: &GpuSpec,
    point_time_s: f64,
    point_energy_j: f64,
    plan_dyn_j: f64,
    slowdown: f64,
    cap_w: Option<f64>,
    temp_c: f64,
) -> ObservedIter {
    let t_p = point_time_s.max(1e-12);
    let dyn_j = plan_dyn_j.clamp(0.0, point_energy_j);
    let stat_j = point_energy_j - dyn_j;
    let p_plan = point_energy_j / t_p;
    let (stretch, jensen, throttled) = match cap_w {
        Some(cap) if p_plan > cap * (1.0 + 1e-9) => {
            let s = p_plan / cap;
            (s, 1.0 + THROTTLE_JENSEN * (s - 1.0), true)
        }
        _ => (1.0, 1.0, false),
    };
    let time_s = t_p * slowdown * stretch;
    let leak = gpu.static_power(temp_c) / gpu.static_w;
    let energy_j = dyn_j * jensen + stat_j * (time_s / t_p) * leak;
    ObservedIter { time_s, energy_j, throttled }
}

/// Select an operating point: minimum energy among frontier points whose
/// time fits `budget_s` and whose average draw fits `cap_w`; falls back
/// to the fastest in-cap point when the budget is infeasible, then to the
/// minimum-power point when even the cap is (mirroring the cluster
/// allocator's pinning rule). `None` only on an empty frontier.
pub fn select_operating_point(
    frontier: &Frontier,
    budget_s: f64,
    cap_w: Option<f64>,
) -> Option<usize> {
    let pts = frontier.points();
    if pts.is_empty() {
        return None;
    }
    let in_cap = |p: &crate::frontier::Point| match cap_w {
        Some(cap) => p.avg_power_w() <= cap * (1.0 + 1e-9),
        None => true,
    };
    // Frontier points ascend in time and descend in energy: the last
    // in-budget feasible point is the energy-minimal one.
    let mut best: Option<usize> = None;
    for (i, p) in pts.iter().enumerate() {
        if in_cap(p) && p.time <= budget_s * (1.0 + 1e-9) {
            best = Some(i);
        }
    }
    if best.is_some() {
        return best;
    }
    // Budget infeasible: fastest point that respects the cap.
    if let Some(i) = pts.iter().position(|p| in_cap(p)) {
        return Some(i);
    }
    // Cap below the frontier's minimum power: pin at minimum power.
    pts.iter()
        .enumerate()
        .min_by(|(_, a), (_, b)| {
            a.avg_power_w().partial_cmp(&b.avg_power_w()).expect("finite frontier powers")
        })
        .map(|(i, _)| i)
}

// ---------------------------------------------------------------------------
// The training loop
// ---------------------------------------------------------------------------

/// Summary of one [`TrainingLoop`] run (JSON is byte-deterministic).
#[derive(Clone, Debug)]
pub struct RunSummary {
    pub system: System,
    pub policy: ReplanPolicy,
    pub n_iters: u64,
    /// Total observed wall-clock (s).
    pub total_time_s: f64,
    /// Total observed per-GPU energy (J).
    pub total_energy_j: f64,
    pub deadline_s: f64,
    pub missed_deadline: bool,
    pub throttled_iters: u64,
    pub final_temp_c: f64,
    /// Plan revisions beyond the initial plan.
    pub replans: u64,
    /// Backend measurements (shared-cache misses) billed across the
    /// initial optimization and every replan.
    pub measurements_billed: u64,
    pub revisions: RevisionLog,
}

impl RunSummary {
    /// Deterministic summary JSON. Revisions appear as metadata only (the
    /// full typed log, plans included, is [`RevisionLog::to_json`]).
    pub fn to_json(&self) -> Json {
        let revs: Vec<Json> = self
            .revisions
            .revisions
            .iter()
            .map(|r| {
                obj(vec![
                    ("revision", num(r.revision as f64)),
                    ("at_iter", num(r.at_iter as f64)),
                    ("trigger", s(r.trigger.as_str())),
                    ("iter_time_s", num(r.iter_time_s)),
                    ("iter_energy_j", num(r.iter_energy_j)),
                    ("measurements_billed", num(r.measurements_billed as f64)),
                ])
            })
            .collect();
        obj(vec![
            ("summary", s("kareus_replan_run")),
            ("system", s(self.system.name())),
            ("policy", s(self.policy.name())),
            ("n_iters", num(self.n_iters as f64)),
            ("total_time_s", num(self.total_time_s)),
            ("total_energy_j", num(self.total_energy_j)),
            ("deadline_s", num(self.deadline_s)),
            ("missed_deadline", Json::Bool(self.missed_deadline)),
            ("throttled_iters", num(self.throttled_iters as f64)),
            ("final_temp_c", num(self.final_temp_c)),
            ("replans", num(self.replans as f64)),
            ("measurements_billed", num(self.measurements_billed as f64)),
            ("revisions", arr(revs)),
        ])
    }
}

/// The online replanning training loop: optimize once, then step
/// `n_iters` iterations under the injected conditions, replanning per the
/// configured [`ReplanPolicy`].
pub struct TrainingLoop {
    pub gpu: GpuSpec,
    pub cfg: TrainConfig,
    pub system: System,
    /// Shared engine: its caches are what make replans warm (a drift
    /// replan re-runs the optimizer and bills only cache misses), and its
    /// [`ReplanConfig`](crate::engine::EngineConfig::replan) parameterizes
    /// the drift monitor.
    pub engine: EngineConfig,
    pub loop_cfg: LoopConfig,
}

/// Mutable per-run state bundled so replans and the iteration loop share
/// one borrow.
struct LoopState {
    result: SystemResult,
    sel: usize,
    revisions: Vec<PlanRevision>,
    billed: u64,
    sim_time_s: f64,
}

impl TrainingLoop {
    pub fn new(gpu: GpuSpec, cfg: TrainConfig, system: System, engine: EngineConfig) -> Self {
        TrainingLoop { gpu, cfg, system, engine, loop_cfg: LoopConfig::default() }
    }

    pub fn with_loop_config(mut self, loop_cfg: LoopConfig) -> Self {
        self.loop_cfg = loop_cfg;
        self
    }

    /// Deadline budget for one iteration given progress and the current
    /// straggler estimate.
    fn iter_budget(&self, deadline_s: f64, st: &LoopState, iters_done: u64, est: f64) -> f64 {
        let remaining = (deadline_s - st.sim_time_s).max(0.0);
        let left = (self.loop_cfg.n_iters - iters_done).max(1) as f64;
        remaining / left / est.max(1.0)
    }

    /// Record a revision for the currently selected point.
    fn log_revision(
        &self,
        st: &mut LoopState,
        at_iter: u64,
        trigger: ReplanTrigger,
        cap_w: Option<f64>,
        slowdown_est: f64,
        billed: u64,
    ) {
        let point = st.result.frontier.points()[st.sel];
        let plan = FrequencyPlan::from_iteration(&st.result.menus, &st.result.plans[point.tag]);
        st.revisions.push(PlanRevision {
            revision: st.revisions.len() as u32,
            at_iter,
            sim_time_s: st.sim_time_s,
            trigger,
            cap_w,
            slowdown_est,
            iter_time_s: point.time,
            iter_energy_j: point.energy,
            measurements_billed: billed,
            plan,
        });
        st.billed += billed;
    }

    /// Full (warm) replan: re-run the optimizer on the shared engine —
    /// per-partition searches replay from the `MboCache`, canonical
    /// executions from the `MeasureCache`, so only true misses are billed
    /// — then re-select under the given budget and cap.
    fn replan(&self, st: &mut LoopState, budget_s: f64, cap_w: Option<f64>) -> u64 {
        let m0 = self.engine.measure_cache.misses();
        let refreshed =
            run_system_with(&self.gpu, &self.cfg, self.system, self.loop_cfg.seed, &self.engine);
        let billed = self.engine.measure_cache.misses() - m0;
        // A refresh can only be adopted if it still has operating points
        // (it always does for deterministic inputs — same seed, same
        // caches — but a stale plan beats no plan).
        if !refreshed.frontier.is_empty() {
            st.result = refreshed;
            if let Some(sel) = select_operating_point(&st.result.frontier, budget_s, cap_w) {
                st.sel = sel;
            }
        }
        billed
    }

    pub fn run(&self) -> Result<RunSummary, String> {
        let lc = &self.loop_cfg;
        let engine = &self.engine;

        // Initial (possibly cold) optimization.
        let m0 = engine.measure_cache.misses();
        let result = run_system_with(&self.gpu, &self.cfg, self.system, lc.seed, engine);
        let initial_billed = engine.measure_cache.misses() - m0;
        let t_min = result
            .frontier
            .min_time()
            .ok_or_else(|| "optimization produced an empty frontier".to_string())?
            .time;
        let nominal_deadline = lc.n_iters as f64 * t_min * (1.0 + lc.deadline_slack);
        let deadline_s = lc.deadline_s.unwrap_or(nominal_deadline);

        let thermal = ThermalModel::default();
        let mut temp: ThermalState = thermal.initial();
        let mut monitor = DriftMonitor::new(engine.replan);
        let mut st =
            LoopState { result, sel: 0, revisions: Vec::new(), billed: 0, sim_time_s: 0.0 };
        let mut active_cap = lc.caps.as_ref().map(|c| c.cap_at(0.0));
        let budget0 = self.iter_budget(deadline_s, &st, 0, 1.0);
        st.sel = select_operating_point(&st.result.frontier, budget0, active_cap)
            .ok_or_else(|| "no selectable operating point".to_string())?;
        self.log_revision(&mut st, 0, ReplanTrigger::Initial, active_cap, 1.0, initial_billed);

        let mut total_energy_j = 0.0;
        let mut throttled_iters = 0u64;

        for iter in 0..lc.n_iters {
            // The cap in force now binds *physically* for every policy;
            // reactive policies additionally re-select at its boundaries
            // (retained frontier only — the optimizer never runs here).
            let cap_now = lc.caps.as_ref().map(|c| c.cap_at(st.sim_time_s));
            if lc.policy != ReplanPolicy::Static && cap_now != active_cap {
                active_cap = cap_now;
                let est = match lc.policy {
                    ReplanPolicy::Oracle => lc.drift.factor_at(iter),
                    _ => monitor.slowdown_estimate(),
                };
                let budget = self.iter_budget(deadline_s, &st, iter, est);
                if let Some(sel) = select_operating_point(&st.result.frontier, budget, cap_now) {
                    st.sel = sel;
                }
                self.log_revision(&mut st, iter, ReplanTrigger::CapBoundary, cap_now, est, 0);
                monitor.rebaseline(iter);
            }
            if lc.policy == ReplanPolicy::Oracle && lc.drift.is_boundary(iter) {
                let est = lc.drift.factor_at(iter);
                let budget = self.iter_budget(deadline_s, &st, iter, est);
                let billed = self.replan(&mut st, budget, active_cap);
                self.log_revision(&mut st, iter, ReplanTrigger::Oracle, active_cap, est, billed);
                monitor.rebaseline(iter);
            }

            let point = st.result.frontier.points()[st.sel];
            let dyn_j = st.result.plans[point.tag].dyn_j;
            let o = observe_iteration(
                &self.gpu,
                point.time,
                point.energy,
                dyn_j,
                lc.drift.factor_at(iter),
                cap_now,
                temp.temp_c,
            );
            thermal.step(&mut temp, o.energy_j / o.time_s.max(1e-12), o.time_s);
            st.sim_time_s += o.time_s;
            total_energy_j += o.energy_j;
            throttled_iters += o.throttled as u64;

            if lc.policy == ReplanPolicy::Drift
                && monitor.observe(iter, (point.time, point.energy), (o.time_s, o.energy_j))
            {
                let est = monitor.slowdown_estimate();
                let budget = self.iter_budget(deadline_s, &st, iter + 1, est);
                let billed = self.replan(&mut st, budget, active_cap);
                self.log_revision(&mut st, iter + 1, ReplanTrigger::Drift, active_cap, est, billed);
                monitor.rebaseline(iter);
            }
        }

        let replans = st.revisions.len() as u64 - 1;
        Ok(RunSummary {
            system: self.system,
            policy: lc.policy,
            n_iters: lc.n_iters,
            total_time_s: st.sim_time_s,
            total_energy_j,
            deadline_s,
            missed_deadline: st.sim_time_s > deadline_s * (1.0 + 1e-9),
            throttled_iters,
            final_temp_c: temp.temp_c,
            replans,
            measurements_billed: st.billed,
            revisions: RevisionLog { revisions: st.revisions },
        })
    }
}

// ---------------------------------------------------------------------------
// The pinned replanning comparison (paper experiment + acceptance tests)
// ---------------------------------------------------------------------------

/// Static vs drift-triggered vs oracle under one injected scenario.
#[derive(Clone, Debug)]
pub struct ReplanningComparison {
    pub static_run: RunSummary,
    pub drift_run: RunSummary,
    pub oracle_run: RunSummary,
}

/// Build the pinned mid-run scenario for `paper --exp replanning` and
/// `tests/runtime.rs`: a ×1.25 straggler from 40% of the run, and a
/// per-GPU cap dropping to 75% of the span between the initial point's
/// draw and the frontier's minimum power at ~60% of the nominal runtime.
/// The deadline carries zero slack, so the initial selection is the
/// max-throughput point and re-selection under the dropped cap is
/// "fastest point that fits" — which a throttled static plan strictly
/// loses to in both time (stretch `s` vs the frontier's ~`s^(1/3)` step)
/// and energy (Jensen penalty vs a cheaper frontier point).
pub fn replanning_scenario(
    gpu: &GpuSpec,
    cfg: &TrainConfig,
    system: System,
    engine: &EngineConfig,
    n_iters: u64,
    seed: u64,
) -> Result<LoopConfig, String> {
    let probe = run_system_with(gpu, cfg, system, seed, engine);
    let fast = probe
        .frontier
        .min_time()
        .ok_or_else(|| "empty frontier in replanning scenario".to_string())?;
    let p_fast = fast.avg_power_w();
    let p_min = probe
        .frontier
        .min_energy()
        .ok_or_else(|| "empty frontier in replanning scenario".to_string())?
        .avg_power_w();
    let cap_lo = p_min + 0.75 * (p_fast - p_min);
    let slow_at = (n_iters * 2) / 5;
    // Boundary in wall-clock: 40% nominal iterations plus 20% slowed ones.
    let t_boundary = slow_at as f64 * fast.time + (n_iters as f64 / 5.0) * 1.25 * fast.time;
    let caps = PowerCapSchedule::piecewise(vec![
        crate::cluster::CapSegment { start_s: 0.0, cap_w: p_fast * 2.0 },
        crate::cluster::CapSegment { start_s: t_boundary, cap_w: cap_lo },
    ])?;
    let drift =
        DriftSchedule::piecewise(vec![DriftSegment { start_iter: slow_at, slowdown: 1.25 }])?;
    Ok(LoopConfig {
        n_iters,
        deadline_s: None,
        deadline_slack: 0.0,
        caps: Some(caps),
        drift,
        policy: ReplanPolicy::Drift,
        seed,
    })
}

/// Run all three policies over one scenario on a shared engine (the
/// static run cold-starts the caches; the drift and oracle runs replay
/// warm — deterministic because cache hits are bit-identical replays).
pub fn run_replanning_comparison(
    gpu: &GpuSpec,
    cfg: &TrainConfig,
    system: System,
    engine: &EngineConfig,
    base: &LoopConfig,
) -> Result<ReplanningComparison, String> {
    let run = |policy: ReplanPolicy| -> Result<RunSummary, String> {
        let lc = LoopConfig { policy, ..base.clone() };
        TrainingLoop::new(gpu.clone(), *cfg, system, engine.clone()).with_loop_config(lc).run()
    };
    Ok(ReplanningComparison {
        static_run: run(ReplanPolicy::Static)?,
        drift_run: run(ReplanPolicy::Drift)?,
        oracle_run: run(ReplanPolicy::Oracle)?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontier::Point;

    #[test]
    fn drift_schedule_parse_and_lookup() {
        let d = DriftSchedule::parse("150:1.25,300:1.0").unwrap();
        assert_eq!(d.segments().len(), 3, "implicit nominal prefix expected");
        assert_eq!(d.factor_at(0), 1.0);
        assert_eq!(d.factor_at(149), 1.0);
        assert_eq!(d.factor_at(150), 1.25);
        assert_eq!(d.factor_at(299), 1.25);
        assert_eq!(d.factor_at(1_000_000), 1.0);
        assert!(d.is_boundary(150) && d.is_boundary(300));
        assert!(!d.is_boundary(0) && !d.is_boundary(151));
        let constant = DriftSchedule::parse("1.4").unwrap();
        assert_eq!(constant.factor_at(7), 1.4);
        assert!(DriftSchedule::parse("").is_err());
        assert!(DriftSchedule::parse("10:0").is_err());
        assert!(DriftSchedule::parse("10:1.2,10:1.3").is_err());
        assert_eq!(DriftSchedule::none().factor_at(123), 1.0);
    }

    #[test]
    fn monitor_fires_on_sustained_drift_with_hysteresis() {
        let cfg = ReplanConfig { drift_pct: 5.0, ewma_alpha: 0.5, patience: 3, cooldown_iters: 4 };
        let mut m = DriftMonitor::new(cfg);
        // Nominal iterations never fire.
        for i in 0..10 {
            assert!(!m.observe(i, (1.0, 100.0), (1.0, 100.0)), "false positive at {i}");
        }
        // A sustained 25% slowdown fires only after `patience` exceedances.
        let mut fired_at = None;
        for i in 10..20 {
            if m.observe(i, (1.0, 100.0), (1.25, 100.0)) {
                fired_at = Some(i);
                break;
            }
        }
        let fired_at = fired_at.expect("sustained drift must fire");
        assert!(fired_at >= 12, "fired at {fired_at}, before the patience window");
        assert!(m.slowdown_estimate() > 1.1);
        // Rebaseline absorbs the new conditions: same observations stop
        // firing (hysteresis), even well past the cooldown.
        m.rebaseline(fired_at);
        for i in fired_at + 1..fired_at + 40 {
            assert!(!m.observe(i, (1.0, 100.0), (1.25, 100.0)), "re-fired at {i} after baseline");
        }
    }

    #[test]
    fn monitor_respects_cooldown() {
        let cfg = ReplanConfig { drift_pct: 5.0, ewma_alpha: 1.0, patience: 1, cooldown_iters: 10 };
        let mut m = DriftMonitor::new(cfg);
        assert!(m.observe(0, (1.0, 1.0), (2.0, 1.0)));
        m.rebaseline(0);
        // A *new* deviation inside the cooldown window stays silenced.
        for i in 1..10 {
            assert!(!m.observe(i, (1.0, 1.0), (4.0, 1.0)), "fired inside cooldown at {i}");
        }
        assert!(m.observe(10, (1.0, 1.0), (4.0, 1.0)), "cooldown expiry must re-arm");
    }

    #[test]
    fn monitor_tracks_thermal_warmup_trace() {
        // The pinned warm-up trace from sim::thermal: as the die warms,
        // leakage inflates observed energy. With a tight threshold the
        // monitor must flag it; with a loose one it must not.
        let gpu = GpuSpec::a100();
        let model = ThermalModel::default();
        let trace = model.warmup_trace(320.0, 0.5, 40);
        let observe_all = |drift_pct: f64| -> bool {
            let cfg = ReplanConfig { drift_pct, ewma_alpha: 0.5, patience: 3, cooldown_iters: 5 };
            let mut m = DriftMonitor::new(cfg);
            let mut fired = false;
            for (i, &t) in trace.iter().enumerate() {
                let leak = gpu.static_power(t) / gpu.static_w;
                // 25% static share at reference temperature.
                let e_obs = 75.0 + 25.0 * leak;
                fired |= m.observe(i as u64, (0.5, 100.0), (0.5, e_obs));
            }
            fired
        };
        assert!(observe_all(1.0), "1% threshold must flag thermal leakage growth");
        assert!(!observe_all(25.0), "25% threshold must ignore it");
    }

    #[test]
    fn selection_obeys_budget_and_cap() {
        // Times 1..4, energies 40,30,20,10 → powers 40,15,6.67,2.5 W.
        let f = Frontier::from_points(vec![
            Point::new(1.0, 40.0, 0),
            Point::new(2.0, 30.0, 1),
            Point::new(3.0, 20.0, 2),
            Point::new(4.0, 10.0, 3),
        ]);
        // Loose budget, no cap: min energy.
        assert_eq!(select_operating_point(&f, 10.0, None), Some(3));
        // Budget admits the first two: pick the cheaper of them.
        assert_eq!(select_operating_point(&f, 2.0, None), Some(1));
        // Budget infeasible: fastest point (in cap).
        assert_eq!(select_operating_point(&f, 0.5, None), Some(0));
        // Cap excludes the fast points.
        assert_eq!(select_operating_point(&f, 0.5, Some(10.0)), Some(2));
        // Cap below minimum power: pinned at the min-power point.
        assert_eq!(select_operating_point(&f, 10.0, Some(1.0)), Some(3));
        assert_eq!(select_operating_point(&Frontier::new(), 1.0, None), None);
    }

    #[test]
    fn observed_iteration_physics() {
        let gpu = GpuSpec::a100();
        // 0.5 s, 150 J total, 100 J dynamic → 300 W nominal draw.
        let base = observe_iteration(&gpu, 0.5, 150.0, 100.0, 1.0, None, gpu.ref_temp_c);
        assert!(!base.throttled);
        assert!((base.time_s - 0.5).abs() < 1e-12);
        assert!((base.energy_j - 150.0).abs() < 1e-9, "baseline must equal the plan");

        // Straggler: time and the static share stretch together.
        let slow = observe_iteration(&gpu, 0.5, 150.0, 100.0, 1.25, None, gpu.ref_temp_c);
        assert!((slow.time_s - 0.625).abs() < 1e-12);
        assert!((slow.energy_j - (100.0 + 50.0 * 1.25)).abs() < 1e-9);

        // Cap throttling: stretch + Jensen penalty, strictly worse than
        // the plan in both coordinates.
        let hot = observe_iteration(&gpu, 0.5, 150.0, 100.0, 1.0, Some(200.0), gpu.ref_temp_c);
        assert!(hot.throttled);
        assert!(hot.time_s > base.time_s && hot.energy_j > base.energy_j);
        // In-cap plans are untouched.
        let cool = observe_iteration(&gpu, 0.5, 150.0, 100.0, 1.0, Some(400.0), gpu.ref_temp_c);
        assert!(!cool.throttled);
        assert_eq!(cool.energy_j.to_bits(), base.energy_j.to_bits());

        // Hot die: leakage inflates only the static share.
        let warm = observe_iteration(&gpu, 0.5, 150.0, 100.0, 1.0, None, 60.0);
        assert!(warm.energy_j > base.energy_j);
        assert_eq!(warm.time_s.to_bits(), base.time_s.to_bits());
    }

    #[test]
    fn policy_parsing() {
        for p in [ReplanPolicy::Static, ReplanPolicy::Drift, ReplanPolicy::Oracle] {
            assert_eq!(ReplanPolicy::parse(p.name()), Some(p));
        }
        assert!(ReplanPolicy::parse("never").is_none());
    }
}
