//! Thermally stable profiler (§5.3, studied in §6.7 / Figure 12).
//!
//! Profiling a candidate schedule = cooldown → warm-up → run the partition
//! repeatedly over a measurement window, reading the (NVML-like, 100 ms
//! quantized) energy counter at window boundaries. The die temperature
//! evolves across candidates: skipping the cooldown biases subsequent
//! measurements upward (leakage grows with temperature), and short windows
//! alias against the counter publication interval — both reproduced by the
//! meter/thermal substrates.

use std::collections::HashMap;
use std::sync::Arc;

use crate::backend::{ExecutionBackend, SimBackend};
use crate::partition::Partition;
use crate::sim::exec::{execute_partition, ExecResult, Schedule};
use crate::sim::gpu::GpuSpec;
use crate::sim::kernel::Kernel;
use crate::sim::meter::EnergyMeter;
use crate::sim::thermal::{ThermalModel, ThermalState};
use crate::util::rng::Rng;
use crate::util::sync::{SyncAtomicU64, SyncMutex};

/// Combined GPU + partition fingerprint: the invariant part of a
/// [`MeasureCache`] key. Callers hoist this out of hot loops (the
/// microbatch Cartesian product probes the cache with the same pair
/// thousands of times).
pub fn combine_fp(gpu_fp: u64, part_fp: u64) -> u64 {
    let mut h = crate::util::hash::Fnv64::new();
    h.write_u64(gpu_fp).write_u64(part_fp);
    h.finish()
}

/// Cache key for one canonical partition execution. Every backend is a
/// pure function of these inputs for a fixed backend identity, so
/// memoizing on them is exactly semantics-preserving: a hit returns
/// bit-identical results to a recompute by the same backend.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
struct ExecKey {
    /// The measurement source's [`ExecutionBackend::fingerprint`] — one
    /// shared cache may serve engines with different backends (cloning an
    /// `EngineConfig` shares the cache, `with_backend` swaps the source),
    /// and results from different sources must never alias.
    backend_fp: u64,
    /// Combined GPU + partition fingerprint (see [`combine_fp`]).
    fp: u64,
    sched: Schedule,
    /// `f64::to_bits` of the die temperature — exact, no quantization.
    temp_bits: u64,
    /// `f64::to_bits` of the power limit; `u64::MAX` (a NaN pattern no
    /// real limit produces) encodes `None`.
    limit_bits: u64,
}

/// Shared memoization of canonical partition executions (§5.1's parallel
/// per-partition optimization shares one measurement store).
///
/// Identical (GPU, partition, schedule, temperature, power-limit)
/// simulations are run once and replayed from the cache everywhere else:
/// across MBO passes re-profiling a repeated workload, across the
/// microbatch-frontier Cartesian product (where a partition's execution
/// depends only on its *own* configuration, not the combo it appears in),
/// and across sweep scenarios sharing a workload. Cloning shares the
/// underlying store; hit/miss counters are lock-free.
#[derive(Clone)]
pub struct MeasureCache {
    inner: Arc<SyncMutex<HashMap<ExecKey, ExecResult>>>,
    hits: Arc<SyncAtomicU64>,
    misses: Arc<SyncAtomicU64>,
}

impl Default for MeasureCache {
    fn default() -> Self {
        MeasureCache {
            inner: Arc::new(SyncMutex::new(HashMap::new())),
            hits: Arc::new(SyncAtomicU64::new(0)),
            misses: Arc::new(SyncAtomicU64::new(0)),
        }
    }
}

/// Entry bound for [`MeasureCache`]: profiler-path keys embed exact die
/// temperatures and rarely repeat, so a long sweep would otherwise grow
/// the shared map without limit. Past the bound, results are still
/// computed (and existing entries still hit) — new ones just aren't stored.
const MAX_CACHE_ENTRIES: usize = 1 << 20;

impl MeasureCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Cache-or-measure through an optional cache: the one shared branch
    /// for the profiler and microbatch-evaluation paths, so keying rules
    /// and the backend call list can't drift apart between them. A cache
    /// miss (or absent cache) consults `backend` exactly once.
    #[allow(clippy::too_many_arguments)]
    pub fn exec_opt(
        backend: &dyn ExecutionBackend,
        cache: Option<&MeasureCache>,
        fp: u64,
        gpu: &GpuSpec,
        comps: &[Kernel],
        comm: Option<&Kernel>,
        sched: &Schedule,
        temp_c: f64,
        power_limit: Option<f64>,
    ) -> ExecResult {
        match cache {
            Some(c) => c.exec(backend, fp, gpu, comps, comm, sched, temp_c, power_limit),
            None => backend.measure_kernels(gpu, fp, comps, comm, sched, temp_c, power_limit),
        }
    }

    /// Measure (or replay) one canonical partition execution through
    /// `backend`. `fp` is the combined GPU+partition fingerprint from
    /// [`combine_fp`] — computed by the caller once per (GPU, partition),
    /// not per probe.
    #[allow(clippy::too_many_arguments)]
    pub fn exec(
        &self,
        backend: &dyn ExecutionBackend,
        fp: u64,
        gpu: &GpuSpec,
        comps: &[Kernel],
        comm: Option<&Kernel>,
        sched: &Schedule,
        temp_c: f64,
        power_limit: Option<f64>,
    ) -> ExecResult {
        let key = ExecKey {
            backend_fp: backend.fingerprint(),
            fp,
            sched: *sched,
            temp_bits: temp_c.to_bits(),
            limit_bits: power_limit.map_or(u64::MAX, f64::to_bits),
        };
        if let Some(r) = self.inner.lock().get(&key) {
            self.hits.fetch_add(1);
            return *r;
        }
        let r = backend.measure_kernels(gpu, fp, comps, comm, sched, temp_c, power_limit);
        self.misses.fetch_add(1);
        let mut map = self.inner.lock();
        if map.len() < MAX_CACHE_ENTRIES {
            map.insert(key, r);
        }
        r
    }

    pub fn len(&self) -> usize {
        self.inner.lock().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn hits(&self) -> u64 {
        self.hits.load()
    }

    pub fn misses(&self) -> u64 {
        self.misses.load()
    }
}

#[derive(Clone, Debug)]
pub struct ProfilerConfig {
    /// Measurement window (paper: 5 s).
    pub window_s: f64,
    /// Cooldown between candidates (paper: 5 s).
    pub cooldown_s: f64,
    /// Warm-up before measuring (runs not counted).
    pub warmup_s: f64,
    /// Fixed per-candidate setup (init + configuration switching).
    pub setup_s: f64,
}

impl Default for ProfilerConfig {
    fn default() -> Self {
        // §5.3: ~13 s per candidate total (init + warm-up + 5 s window +
        // 5 s cooldown).
        ProfilerConfig { window_s: 5.0, cooldown_s: 5.0, warmup_s: 1.0, setup_s: 2.0 }
    }
}

/// One profiling measurement.
#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    /// Mean wall time per partition execution (s).
    pub time_s: f64,
    /// Mean measured total energy per execution (J).
    pub energy_j: f64,
    /// Dynamic component: total − P_static(ref)·time.
    pub dyn_j: f64,
    /// Simulated wall-clock cost of taking this measurement (s) — the MBO
    /// overhead accounting of §6.6 charges this.
    pub profiling_cost_s: f64,
    /// Die temperature when the measurement window started.
    pub temp_at_start_c: f64,
}

/// Stateful profiler: carries thermal state across candidates like a real
/// GPU does.
pub struct Profiler {
    pub gpu: GpuSpec,
    pub thermal: ThermalModel,
    pub state: ThermalState,
    pub config: ProfilerConfig,
    rng: Rng,
    /// The persistent NVML-like counter: like the real driver's, it
    /// integrates continuously (cooldowns and warm-ups included) and is
    /// published on its own 100 ms cadence — measurement windows start at
    /// an arbitrary phase of that cadence, which is exactly what makes
    /// short windows noisy (Figure 12a).
    meter: EnergyMeter,
    /// Total simulated profiling wall-clock (s).
    pub total_cost_s: f64,
    /// Optional shared memoization of the canonical executions; replayed
    /// hits are bit-identical to recomputes, so attaching a cache never
    /// changes measurement values.
    cache: Option<MeasureCache>,
    /// The measurement source behind every canonical execution (default:
    /// the simulator). The thermal/meter substrates stay in the profiler —
    /// a backend only answers "what does this schedule do", the profiler
    /// models *measuring* it on a real, warming die.
    backend: Arc<dyn ExecutionBackend>,
    /// `gpu.fingerprint()`, hoisted — `measure` probes the cache per
    /// candidate and must not rehash the spec every time.
    gpu_fp: u64,
}

impl Profiler {
    pub fn new(gpu: GpuSpec, config: ProfilerConfig, seed: u64) -> Self {
        let thermal = ThermalModel::default();
        let state = thermal.initial();
        let mut rng = Rng::new(seed);
        let mut meter = EnergyMeter::new();
        // Desynchronize the counter phase from the measurement windows.
        meter.advance(gpu.static_w, rng.f64() * 0.1);
        let gpu_fp = gpu.fingerprint();
        Profiler {
            gpu,
            thermal,
            state,
            config,
            rng,
            meter,
            total_cost_s: 0.0,
            cache: None,
            backend: Arc::new(SimBackend),
            gpu_fp,
        }
    }

    /// Attach a shared measurement cache (builder style).
    pub fn with_cache(mut self, cache: MeasureCache) -> Self {
        self.cache = Some(cache);
        self
    }

    /// Swap the measurement source (builder style). All canonical
    /// executions — and nothing else — go through the backend, so a
    /// trace/hardware backend transparently drives the whole MBO stack.
    pub fn with_backend(mut self, backend: Arc<dyn ExecutionBackend>) -> Self {
        self.backend = backend;
        self
    }

    /// Profile one candidate schedule on one partition.
    ///
    /// Perf note (§Perf in EXPERIMENTS.md): a 5 s window covers hundreds
    /// to thousands of partition executions, but the execution result is
    /// temperature-independent except for the *static* power term — so we
    /// run the executor ONCE and replay (dynamic power + temperature-
    /// dependent static power) through the meter/thermal loop per run.
    /// This is semantically identical to re-executing each run and makes
    /// `measure` ~50× cheaper, which dominates MBO wall time.
    pub fn measure(&mut self, part: &Partition, sched: &Schedule) -> Measurement {
        self.measure_fp(part, part.fingerprint(), sched)
    }

    /// Hot-path variant of [`measure`](Self::measure): `part_fp` is the
    /// caller-hoisted `part.fingerprint()`, so an MBO run probing the
    /// cache hundreds of times per partition hashes its kernels once.
    pub fn measure_fp(&mut self, part: &Partition, part_fp: u64, sched: &Schedule) -> Measurement {
        let cfg = self.config.clone();
        // 1. Cooldown (idle at static draw; the counter keeps running).
        self.meter.advance(self.gpu.static_power(self.state.temp_c), cfg.cooldown_s);
        self.thermal.cool(&mut self.state, self.gpu.static_w, cfg.cooldown_s);

        // One canonical execution: time and dynamic energy do not depend
        // on die temperature (only static power does).
        let r = MeasureCache::exec_opt(
            self.backend.as_ref(),
            self.cache.as_ref(),
            combine_fp(self.gpu_fp, part_fp),
            &self.gpu,
            &part.comps,
            part.comm.as_ref(),
            sched,
            self.state.temp_c,
            Some(self.gpu.tdp_w),
        );
        let t_run = r.time_s.max(1e-9);
        let p_dyn = r.dyn_j / t_run;

        // 2. Warm-up runs (heat the die, not measured).
        let mut elapsed = 0.0;
        while elapsed < cfg.warmup_s {
            let p = p_dyn + self.gpu.static_power(self.state.temp_c);
            self.meter.advance(p, t_run);
            self.thermal.step(&mut self.state, p, t_run);
            elapsed += t_run;
        }
        let temp_at_start = self.state.temp_c;

        // 3. Measurement window: replay runs, the counter integrates.
        let start_reading = self.meter.read(&mut self.rng);
        let mut window_elapsed = 0.0;
        let mut runs = 0u64;
        while window_elapsed < cfg.window_s {
            let p = p_dyn + self.gpu.static_power(self.state.temp_c);
            self.meter.advance(p, t_run);
            self.thermal.step(&mut self.state, p, t_run);
            window_elapsed += t_run;
            runs += 1;
            if runs > 2_000_000 {
                break; // degenerate tiny partitions
            }
        }
        let end_reading = self.meter.read(&mut self.rng);
        let energy_j = (end_reading - start_reading).max(0.0) / runs as f64;
        let time_s = window_elapsed / runs as f64;
        let dyn_j = (energy_j - self.gpu.static_w * time_s).max(0.0);

        let cost = cfg.setup_s + cfg.cooldown_s + cfg.warmup_s + cfg.window_s;
        self.total_cost_s += cost;
        Measurement {
            time_s,
            energy_j,
            dyn_j,
            profiling_cost_s: cost,
            temp_at_start_c: temp_at_start,
        }
    }

    /// Noise-free, reference-temperature evaluation — the ground truth the
    /// profiler tries to estimate. Used by tests and the exhaustive oracle.
    /// Deliberately backend-free: ground truth is defined by the simulator
    /// physics, not by whichever measurement source a run is configured
    /// with.
    pub fn true_eval(gpu: &GpuSpec, part: &Partition, sched: &Schedule) -> Measurement {
        let r = execute_partition(
            gpu,
            &part.comps,
            part.comm.as_ref(),
            sched,
            gpu.ref_temp_c,
            Some(gpu.tdp_w),
        );
        Measurement {
            time_s: r.time_s,
            energy_j: r.total_j(),
            dyn_j: r.dyn_j,
            profiling_cost_s: 0.0,
            temp_at_start_c: gpu.ref_temp_c,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::exec::LaunchAt;
    use crate::sim::kernel::{Kernel, KernelKind};

    fn test_partition() -> Partition {
        Partition {
            ptype: "fwd/attn".into(),
            comps: vec![
                Kernel::comp("norm", KernelKind::Norm, 1e8, 8e8),
                Kernel::comp("linear1", KernelKind::Linear, 4e11, 2e9),
                Kernel::comp("linear2", KernelKind::Linear, 4e11, 2e9),
            ],
            comm: Some(Kernel::comm("ar", KernelKind::AllReduce, 4e8)),
            count: 28,
        }
    }

    fn sched() -> Schedule {
        Schedule::uniform(12, LaunchAt::WithComp(1), 1410)
    }

    #[test]
    fn measurement_close_to_truth_with_default_config() {
        let gpu = GpuSpec::a100();
        let mut p = Profiler::new(gpu.clone(), ProfilerConfig::default(), 1);
        let part = test_partition();
        let m = p.measure(&part, &sched());
        let truth = Profiler::true_eval(&gpu, &part, &sched());
        let time_err = (m.time_s - truth.time_s).abs() / truth.time_s;
        let energy_err = (m.energy_j - truth.energy_j).abs() / truth.energy_j;
        assert!(time_err < 0.02, "time err {time_err}");
        // Profiled energy runs hot (warm die > ref temp) but within a few %.
        assert!(energy_err < 0.08, "energy err {energy_err}");
    }

    #[test]
    fn short_window_noisier_than_long() {
        let gpu = GpuSpec::a100();
        let part = test_partition();
        let spread = |window: f64, seed_base: u64| {
            let vals: Vec<f64> = (0..8)
                .map(|i| {
                    let cfg = ProfilerConfig { window_s: window, ..Default::default() };
                    let mut p = Profiler::new(gpu.clone(), cfg, seed_base + i);
                    p.measure(&part, &sched()).energy_j
                })
                .collect();
            crate::util::stats::std_dev(&vals) / crate::util::stats::mean(&vals)
        };
        let short = spread(0.55, 10);
        let long = spread(5.0, 50);
        assert!(short > long, "short cv {short} vs long cv {long}");
    }

    #[test]
    fn no_cooldown_biases_energy_upward() {
        // Figure 12b: consecutive measurements without cooldown run hotter
        // and therefore measure more (leakage) energy.
        let gpu = GpuSpec::a100();
        let part = test_partition();
        let run_chain = |cooldown: f64| {
            let cfg = ProfilerConfig { cooldown_s: cooldown, ..Default::default() };
            let mut p = Profiler::new(gpu.clone(), cfg, 7);
            // Heat up with a few prior candidates, then measure.
            for _ in 0..3 {
                p.measure(&part, &sched());
            }
            p.measure(&part, &sched())
        };
        let cold = run_chain(8.0);
        let hot = run_chain(0.0);
        assert!(hot.temp_at_start_c > cold.temp_at_start_c + 1.0);
        assert!(hot.energy_j > cold.energy_j);
    }

    #[test]
    fn profiling_cost_accumulates() {
        let gpu = GpuSpec::a100();
        let mut p = Profiler::new(gpu, ProfilerConfig::default(), 2);
        let part = test_partition();
        p.measure(&part, &sched());
        p.measure(&part, &sched());
        // ~13 s per candidate (§5.3).
        assert!((p.total_cost_s - 26.0).abs() < 1.0, "cost {}", p.total_cost_s);
    }

    #[test]
    fn cached_profiler_measures_bit_identically() {
        let gpu = GpuSpec::a100();
        let part = test_partition();
        let cache = MeasureCache::new();
        let mut plain = Profiler::new(gpu.clone(), ProfilerConfig::default(), 3);
        let mut cached =
            Profiler::new(gpu.clone(), ProfilerConfig::default(), 3).with_cache(cache.clone());
        for _ in 0..4 {
            let a = plain.measure(&part, &sched());
            let b = cached.measure(&part, &sched());
            assert_eq!(a.time_s.to_bits(), b.time_s.to_bits());
            assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
            assert_eq!(a.dyn_j.to_bits(), b.dyn_j.to_bits());
        }
        assert!(cache.misses() > 0 && cache.len() > 0);
        // Replaying the same trajectory (same seed ⇒ same thermal path)
        // hits the cache and still reproduces the same measurement.
        let mut replay = Profiler::new(gpu, ProfilerConfig::default(), 3).with_cache(cache.clone());
        let h0 = cache.hits();
        let m = replay.measure(&part, &sched());
        assert!(cache.hits() > h0, "replay did not hit the cache");
        assert!(m.time_s > 0.0);
    }

    #[test]
    fn true_eval_deterministic() {
        let gpu = GpuSpec::a100();
        let part = test_partition();
        let a = Profiler::true_eval(&gpu, &part, &sched());
        let b = Profiler::true_eval(&gpu, &part, &sched());
        assert_eq!(a.time_s, b.time_s);
        assert_eq!(a.energy_j, b.energy_j);
    }
}
