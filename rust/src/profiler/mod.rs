//! Thermally stable profiler (§5.3, studied in §6.7 / Figure 12).
//!
//! Profiling a candidate schedule = cooldown → warm-up → run the partition
//! repeatedly over a measurement window, reading the (NVML-like, 100 ms
//! quantized) energy counter at window boundaries. The die temperature
//! evolves across candidates: skipping the cooldown biases subsequent
//! measurements upward (leakage grows with temperature), and short windows
//! alias against the counter publication interval — both reproduced by the
//! meter/thermal substrates.

use crate::partition::Partition;
use crate::sim::exec::{execute_partition, Schedule};
use crate::sim::gpu::GpuSpec;
use crate::sim::meter::EnergyMeter;
use crate::sim::thermal::{ThermalModel, ThermalState};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct ProfilerConfig {
    /// Measurement window (paper: 5 s).
    pub window_s: f64,
    /// Cooldown between candidates (paper: 5 s).
    pub cooldown_s: f64,
    /// Warm-up before measuring (runs not counted).
    pub warmup_s: f64,
    /// Fixed per-candidate setup (init + configuration switching).
    pub setup_s: f64,
}

impl Default for ProfilerConfig {
    fn default() -> Self {
        // §5.3: ~13 s per candidate total (init + warm-up + 5 s window +
        // 5 s cooldown).
        ProfilerConfig { window_s: 5.0, cooldown_s: 5.0, warmup_s: 1.0, setup_s: 2.0 }
    }
}

/// One profiling measurement.
#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    /// Mean wall time per partition execution (s).
    pub time_s: f64,
    /// Mean measured total energy per execution (J).
    pub energy_j: f64,
    /// Dynamic component: total − P_static(ref)·time.
    pub dyn_j: f64,
    /// Simulated wall-clock cost of taking this measurement (s) — the MBO
    /// overhead accounting of §6.6 charges this.
    pub profiling_cost_s: f64,
    /// Die temperature when the measurement window started.
    pub temp_at_start_c: f64,
}

/// Stateful profiler: carries thermal state across candidates like a real
/// GPU does.
pub struct Profiler {
    pub gpu: GpuSpec,
    pub thermal: ThermalModel,
    pub state: ThermalState,
    pub config: ProfilerConfig,
    rng: Rng,
    /// The persistent NVML-like counter: like the real driver's, it
    /// integrates continuously (cooldowns and warm-ups included) and is
    /// published on its own 100 ms cadence — measurement windows start at
    /// an arbitrary phase of that cadence, which is exactly what makes
    /// short windows noisy (Figure 12a).
    meter: EnergyMeter,
    /// Total simulated profiling wall-clock (s).
    pub total_cost_s: f64,
}

impl Profiler {
    pub fn new(gpu: GpuSpec, config: ProfilerConfig, seed: u64) -> Self {
        let thermal = ThermalModel::default();
        let state = thermal.initial();
        let mut rng = Rng::new(seed);
        let mut meter = EnergyMeter::new();
        // Desynchronize the counter phase from the measurement windows.
        meter.advance(gpu.static_w, rng.f64() * 0.1);
        Profiler { gpu, thermal, state, config, rng, meter, total_cost_s: 0.0 }
    }

    /// Profile one candidate schedule on one partition.
    ///
    /// Perf note (§Perf in EXPERIMENTS.md): a 5 s window covers hundreds
    /// to thousands of partition executions, but the execution result is
    /// temperature-independent except for the *static* power term — so we
    /// run the executor ONCE and replay (dynamic power + temperature-
    /// dependent static power) through the meter/thermal loop per run.
    /// This is semantically identical to re-executing each run and makes
    /// `measure` ~50× cheaper, which dominates MBO wall time.
    pub fn measure(&mut self, part: &Partition, sched: &Schedule) -> Measurement {
        let cfg = self.config.clone();
        // 1. Cooldown (idle at static draw; the counter keeps running).
        self.meter.advance(self.gpu.static_power(self.state.temp_c), cfg.cooldown_s);
        self.thermal.cool(&mut self.state, self.gpu.static_w, cfg.cooldown_s);

        // One canonical execution: time and dynamic energy do not depend
        // on die temperature (only static power does).
        let r = execute_partition(
            &self.gpu,
            &part.comps,
            part.comm.as_ref(),
            sched,
            self.state.temp_c,
            Some(self.gpu.tdp_w),
        );
        let t_run = r.time_s.max(1e-9);
        let p_dyn = r.dyn_j / t_run;

        // 2. Warm-up runs (heat the die, not measured).
        let mut elapsed = 0.0;
        while elapsed < cfg.warmup_s {
            let p = p_dyn + self.gpu.static_power(self.state.temp_c);
            self.meter.advance(p, t_run);
            self.thermal.step(&mut self.state, p, t_run);
            elapsed += t_run;
        }
        let temp_at_start = self.state.temp_c;

        // 3. Measurement window: replay runs, the counter integrates.
        let start_reading = self.meter.read(&mut self.rng);
        let mut window_elapsed = 0.0;
        let mut runs = 0u64;
        while window_elapsed < cfg.window_s {
            let p = p_dyn + self.gpu.static_power(self.state.temp_c);
            self.meter.advance(p, t_run);
            self.thermal.step(&mut self.state, p, t_run);
            window_elapsed += t_run;
            runs += 1;
            if runs > 2_000_000 {
                break; // degenerate tiny partitions
            }
        }
        let end_reading = self.meter.read(&mut self.rng);
        let energy_j = (end_reading - start_reading).max(0.0) / runs as f64;
        let time_s = window_elapsed / runs as f64;
        let dyn_j = (energy_j - self.gpu.static_w * time_s).max(0.0);

        let cost = cfg.setup_s + cfg.cooldown_s + cfg.warmup_s + cfg.window_s;
        self.total_cost_s += cost;
        Measurement { time_s, energy_j, dyn_j, profiling_cost_s: cost, temp_at_start_c: temp_at_start }
    }

    /// Noise-free, reference-temperature evaluation — the ground truth the
    /// profiler tries to estimate. Used by tests and the exhaustive oracle.
    pub fn true_eval(gpu: &GpuSpec, part: &Partition, sched: &Schedule) -> Measurement {
        let r = execute_partition(
            gpu,
            &part.comps,
            part.comm.as_ref(),
            sched,
            gpu.ref_temp_c,
            Some(gpu.tdp_w),
        );
        Measurement {
            time_s: r.time_s,
            energy_j: r.total_j(),
            dyn_j: r.dyn_j,
            profiling_cost_s: 0.0,
            temp_at_start_c: gpu.ref_temp_c,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::exec::LaunchAt;
    use crate::sim::kernel::{Kernel, KernelKind};

    fn test_partition() -> Partition {
        Partition {
            ptype: "fwd/attn".into(),
            comps: vec![
                Kernel::comp("norm", KernelKind::Norm, 1e8, 8e8),
                Kernel::comp("linear1", KernelKind::Linear, 4e11, 2e9),
                Kernel::comp("linear2", KernelKind::Linear, 4e11, 2e9),
            ],
            comm: Some(Kernel::comm("ar", KernelKind::AllReduce, 4e8)),
            count: 28,
        }
    }

    fn sched() -> Schedule {
        Schedule { comm_sms: 12, launch: LaunchAt::WithComp(1), freq_mhz: 1410 }
    }

    #[test]
    fn measurement_close_to_truth_with_default_config() {
        let gpu = GpuSpec::a100();
        let mut p = Profiler::new(gpu.clone(), ProfilerConfig::default(), 1);
        let part = test_partition();
        let m = p.measure(&part, &sched());
        let truth = Profiler::true_eval(&gpu, &part, &sched());
        let time_err = (m.time_s - truth.time_s).abs() / truth.time_s;
        let energy_err = (m.energy_j - truth.energy_j).abs() / truth.energy_j;
        assert!(time_err < 0.02, "time err {time_err}");
        // Profiled energy runs hot (warm die > ref temp) but within a few %.
        assert!(energy_err < 0.08, "energy err {energy_err}");
    }

    #[test]
    fn short_window_noisier_than_long() {
        let gpu = GpuSpec::a100();
        let part = test_partition();
        let spread = |window: f64, seed_base: u64| {
            let vals: Vec<f64> = (0..8)
                .map(|i| {
                    let cfg = ProfilerConfig { window_s: window, ..Default::default() };
                    let mut p = Profiler::new(gpu.clone(), cfg, seed_base + i);
                    p.measure(&part, &sched()).energy_j
                })
                .collect();
            crate::util::stats::std_dev(&vals) / crate::util::stats::mean(&vals)
        };
        let short = spread(0.55, 10);
        let long = spread(5.0, 50);
        assert!(short > long, "short cv {short} vs long cv {long}");
    }

    #[test]
    fn no_cooldown_biases_energy_upward() {
        // Figure 12b: consecutive measurements without cooldown run hotter
        // and therefore measure more (leakage) energy.
        let gpu = GpuSpec::a100();
        let part = test_partition();
        let run_chain = |cooldown: f64| {
            let cfg = ProfilerConfig { cooldown_s: cooldown, ..Default::default() };
            let mut p = Profiler::new(gpu.clone(), cfg, 7);
            // Heat up with a few prior candidates, then measure.
            for _ in 0..3 {
                p.measure(&part, &sched());
            }
            p.measure(&part, &sched())
        };
        let cold = run_chain(8.0);
        let hot = run_chain(0.0);
        assert!(hot.temp_at_start_c > cold.temp_at_start_c + 1.0);
        assert!(hot.energy_j > cold.energy_j);
    }

    #[test]
    fn profiling_cost_accumulates() {
        let gpu = GpuSpec::a100();
        let mut p = Profiler::new(gpu, ProfilerConfig::default(), 2);
        let part = test_partition();
        p.measure(&part, &sched());
        p.measure(&part, &sched());
        // ~13 s per candidate (§5.3).
        assert!((p.total_cost_s - 26.0).abs() < 1.0, "cost {}", p.total_cost_s);
    }

    #[test]
    fn true_eval_deterministic() {
        let gpu = GpuSpec::a100();
        let part = test_partition();
        let a = Profiler::true_eval(&gpu, &part, &sched());
        let b = Profiler::true_eval(&gpu, &part, &sched());
        assert_eq!(a.time_s, b.time_s);
        assert_eq!(a.energy_j, b.energy_j);
    }
}
