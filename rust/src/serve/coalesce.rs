//! Request coalescing with typed poisoning: the cache behind
//! [`PlanService`](super::PlanService).
//!
//! The serving invariant is *exactly one optimization per distinct key,
//! ever*: the first requester for a key becomes the **owner** and computes;
//! every concurrent or later requester becomes a **waiter** on the same
//! slot and receives the owner's published value. Filled slots stay in the
//! map, so the value doubles as the positive/negative cache (deterministic
//! failures are publishable values like any other) and the hit/miss split
//! is a pure function of the request multiset.
//!
//! Poisoning is typed, not panicking. The owner holds a [`FillGuard`];
//! dropping it without [`FillGuard::fill`] (the owner unwound before
//! publishing) marks the slot [`Fill::Poisoned`] so waiters get a typed
//! answer instead of parking forever, and *removes* the key from the map —
//! an owner death is not a deterministic outcome, so it must never be
//! negatively cached. No path here propagates a `std` mutex poison: the
//! [`SyncMutex`] shims recover poison at the lock, and abnormal-owner
//! semantics live entirely in this module's slot state.
//!
//! Under `--features modelcheck` every mutex/condvar here is
//! scheduler-visible, so `tests/modelcheck.rs` can enumerate all bounded
//! interleavings of claim/fill/wait and prove the exactly-one-owner and
//! no-lost-wakeup properties rather than stress-testing for them.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::util::sync::{SyncCondvar, SyncMutex};

/// What a resolved slot holds, as observed by a waiter.
#[derive(Clone, Debug, PartialEq)]
pub enum Fill<V> {
    /// The owner published a value (which may itself encode a typed,
    /// deterministic error — those are cacheable results, not poison).
    Value(V),
    /// The owner was destroyed before publishing; the message says why.
    Poisoned(String),
}

enum SlotState<V> {
    Empty,
    Filled(V),
    Poisoned(String),
}

/// One coalescing cell. Waiters park on `cv` until the state leaves
/// `Empty`; the resolved state is immutable afterwards.
pub struct Slot<V> {
    state: SyncMutex<SlotState<V>>,
    cv: SyncCondvar,
}

impl<V: Clone> Slot<V> {
    fn new() -> Slot<V> {
        Slot { state: SyncMutex::new(SlotState::Empty), cv: SyncCondvar::new() }
    }

    /// Block until the owner resolves the slot, then return the outcome.
    pub fn wait(&self) -> Fill<V> {
        let mut g = self.state.lock();
        loop {
            match &*g {
                SlotState::Empty => g = self.cv.wait(g),
                SlotState::Filled(v) => return Fill::Value(v.clone()),
                SlotState::Poisoned(m) => return Fill::Poisoned(m.clone()),
            }
        }
    }

    /// Non-blocking read; `None` while unresolved.
    fn peek(&self) -> Option<Fill<V>> {
        match &*self.state.lock() {
            SlotState::Empty => None,
            SlotState::Filled(v) => Some(Fill::Value(v.clone())),
            SlotState::Poisoned(m) => Some(Fill::Poisoned(m.clone())),
        }
    }

    fn fill(&self, v: V) {
        *self.state.lock() = SlotState::Filled(v);
        self.cv.notify_all();
    }

    fn poison(&self, why: String) {
        let mut g = self.state.lock();
        // First resolution wins; a filled slot is never demoted.
        if matches!(&*g, SlotState::Empty) {
            *g = SlotState::Poisoned(why);
            self.cv.notify_all();
        }
    }
}

/// The owner's obligation to resolve its slot, exactly once.
///
/// [`fill`](FillGuard::fill) publishes a value to every waiter and leaves
/// the entry cached. Dropping the guard unfilled — only possible by
/// unwinding past it — poisons the slot (waiters get a typed
/// [`Fill::Poisoned`], which the service maps to `ErrorCode::Internal`)
/// and evicts the key so the next requester retries from scratch.
pub struct FillGuard<'a, V: Clone> {
    cache: &'a CoalescingCache<V>,
    key: String,
    slot: Arc<Slot<V>>,
    armed: bool,
}

impl<V: Clone> FillGuard<'_, V> {
    /// Publish `v`: waiters wake with [`Fill::Value`] and the entry stays
    /// cached for future requesters.
    pub fn fill(mut self, v: V) {
        self.armed = false;
        self.slot.fill(v);
    }
}

impl<V: Clone> Drop for FillGuard<'_, V> {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        // Evict before poisoning: once waiters can observe Poisoned, no
        // new requester may coalesce onto this slot. Only evict the slot
        // this guard owns — a successor for the same key must survive.
        let mut map = self.cache.slots.lock();
        if map.get(&self.key).is_some_and(|s| Arc::ptr_eq(s, &self.slot)) {
            map.remove(&self.key);
        }
        drop(map);
        self.slot.poison(format!("owner of '{}' died before publishing a result", self.key));
    }
}

/// Outcome of [`CoalescingCache::claim`].
pub enum Claim<'a, V: Clone> {
    /// First requester for the key: compute, then [`FillGuard::fill`].
    Owner(FillGuard<'a, V>),
    /// Someone else owns (or owned) the key: [`Slot::wait`] for their
    /// result. Resolves immediately when the slot is already filled.
    Waiter(Arc<Slot<V>>),
    /// The admission gate refused a new owner; nothing was inserted.
    Refused,
}

/// Keyed map of coalescing slots. `BTreeMap` keeps any debugging dump
/// deterministic (matching the crate-wide no-iteration-nondeterminism
/// rule).
pub struct CoalescingCache<V> {
    slots: SyncMutex<BTreeMap<String, Arc<Slot<V>>>>,
}

impl<V: Clone> CoalescingCache<V> {
    /// Empty cache.
    pub fn new() -> CoalescingCache<V> {
        CoalescingCache { slots: SyncMutex::new(BTreeMap::new()) }
    }

    /// Claim `key`. An existing slot (in-flight or resolved) yields
    /// [`Claim::Waiter`]. Otherwise `admit` is consulted *while the map
    /// lock is held* — so admission and insertion are one atomic
    /// decision — and a `true` verdict installs the caller as
    /// [`Claim::Owner`]; `false` yields [`Claim::Refused`] and the map
    /// is unchanged.
    pub fn claim(&self, key: &str, admit: impl FnOnce() -> bool) -> Claim<'_, V> {
        let mut map = self.slots.lock();
        if let Some(slot) = map.get(key) {
            return Claim::Waiter(Arc::clone(slot));
        }
        if !admit() {
            return Claim::Refused;
        }
        let slot = Arc::new(Slot::new());
        map.insert(key.to_string(), Arc::clone(&slot));
        Claim::Owner(FillGuard { cache: self, key: key.to_string(), slot, armed: true })
    }

    /// Resolved value for `key`, if the slot exists and has been filled.
    /// Never blocks; in-flight slots read as `None`.
    pub fn peek(&self, key: &str) -> Option<Fill<V>> {
        let slot = { self.slots.lock().get(key).map(Arc::clone) };
        slot.and_then(|s| s.peek())
    }

    /// Number of cached keys (in-flight slots included).
    pub fn len(&self) -> usize {
        self.slots.lock().len()
    }

    /// True when no key has ever been claimed (or all were evicted).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<V: Clone> Default for CoalescingCache<V> {
    fn default() -> Self {
        CoalescingCache::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::sync::spawn;

    fn own<'a>(c: &'a CoalescingCache<u32>, key: &str) -> FillGuard<'a, u32> {
        match c.claim(key, || true) {
            Claim::Owner(g) => g,
            _ => panic!("expected to own '{key}'"),
        }
    }

    #[test]
    fn owner_fills_then_later_claims_wait_resolved() {
        let c = CoalescingCache::new();
        own(&c, "k").fill(7);
        match c.claim("k", || panic!("resolved keys never consult admission")) {
            Claim::Waiter(s) => assert_eq!(s.wait(), Fill::Value(7)),
            _ => panic!("second claim must coalesce"),
        }
        assert_eq!(c.len(), 1);
        assert_eq!(c.peek("k"), Some(Fill::Value(7)));
    }

    #[test]
    fn refused_admission_inserts_nothing() {
        let c = CoalescingCache::<u32>::new();
        assert!(matches!(c.claim("k", || false), Claim::Refused));
        assert!(c.is_empty());
        // The key is still claimable afterwards.
        assert!(matches!(c.claim("k", || true), Claim::Owner(_)));
    }

    #[test]
    fn concurrent_waiter_gets_owner_value() {
        let c = Arc::new(CoalescingCache::new());
        let g = own(&c, "k");
        let c2 = Arc::clone(&c);
        let t = spawn(move || match c2.claim("k", || false) {
            Claim::Waiter(s) => s.wait(),
            _ => panic!("must coalesce onto the in-flight owner"),
        });
        g.fill(11);
        assert_eq!(t.join().unwrap(), Fill::Value(11));
    }

    #[test]
    fn dropped_guard_poisons_waiters_and_evicts_key() {
        let c = Arc::new(CoalescingCache::new());
        let g = own(&c, "k");
        let c2 = Arc::clone(&c);
        let t = spawn(move || match c2.claim("k", || false) {
            Claim::Waiter(s) => s.wait(),
            _ => panic!("must coalesce onto the in-flight owner"),
        });
        drop(g); // owner dies without publishing
        match t.join().unwrap() {
            Fill::Poisoned(m) => assert!(m.contains("died before publishing")),
            f => panic!("waiter must observe poison, got {f:?}"),
        }
        // Poison is not a cached outcome: the key is free again.
        assert!(c.is_empty());
        assert!(matches!(c.claim("k", || true), Claim::Owner(_)));
    }

    #[test]
    fn peek_never_blocks_on_inflight_slot() {
        let c = CoalescingCache::<u32>::new();
        let g = own(&c, "k");
        assert_eq!(c.peek("k"), None);
        assert_eq!(c.len(), 1);
        g.fill(3);
        assert_eq!(c.peek("k"), Some(Fill::Value(3)));
    }
}
