//! Plan-serving daemon (data-flow step ⑨): `kareus serve` / `kareus loadgen`.
//!
//! Every other surface in this crate is a one-shot CLI that cold-starts the
//! optimizer per invocation. Production plan traffic is recurring — the same
//! (job, target) pairs arrive again and again — so the natural deployment
//! shape is a long-lived process whose steady-state request path is a cache
//! hit over state the [`engine`](crate::engine) layer already knows how to
//! share. This module is that process:
//!
//! * **Protocol** — newline-delimited JSON over TCP, schema-tagged
//!   `kareus_serve` v1. Typed [`ServeRequest`] / [`ServeResponse`] structs
//!   round-trip byte-deterministically through [`crate::util::json`] (no
//!   serde; the crate's no-external-deps discipline holds on the wire too).
//! * **Service** — [`PlanService`] owns one shared [`EngineConfig`]
//!   (process-wide `MboCache` / `MeasureCache` behind the existing locking)
//!   plus a plan cache keyed by (job, target, seed). Known pairs are
//!   answered without touching the optimizer; unknown pairs run per-partition
//!   MBO inline under bounded admission — overflow gets a typed `busy`
//!   response, never a hang. Identical in-flight requests coalesce onto one
//!   optimization (see [`coalesce`]), so concurrent duplicates cost one
//!   miss total and the hit/miss split is deterministic under any
//!   scheduling; an owner that dies before publishing poisons its slot
//!   *typed* — waiters get `ErrorCode::Internal`, never a hang. All shared
//!   state sits on the [`crate::util::sync`] shims, so `tests/modelcheck.rs`
//!   verifies these properties over every bounded interleaving.
//! * **Server** — [`Server`] is a fixed accept/worker thread model over a
//!   persistent [`WorkerPool`] (spawn-per-call `parallel_map` is the wrong
//!   shape for a daemon). Graceful shutdown is a control request: the
//!   listener stops accepting, blocked readers are unblocked with a
//!   read-side socket shutdown (responses still flush), and the pool drains
//!   every in-flight request before the process exits.
//! * **Loadgen** — [`run_loadgen`] drives a server from a deterministic
//!   job-spec mix and emits a `kareus_loadgen` report (requests/sec,
//!   p50/p99 latency, hit rate). In deterministic mode every wall-clock
//!   field is nulled exactly like `sweep_json`, so double runs against a
//!   trace backend are byte-identical (`kareus check` verifies the report).
//!
//! Determinism contract: plans served over the wire are byte-identical to a
//! direct `run_system_with` + `Coordinator::select` call with the same spec
//! and seed (see `tests/serve.rs`). Logging goes through a caller-supplied
//! callback (stderr in `main`), keeping stdout pure for artifacts.

use std::io::{BufRead, BufReader, Write as IoWrite};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::time::Instant;

use crate::baselines::run_system_with;
use crate::cluster::parse_job_spec;
use crate::coordinator::{Coordinator, Target};
use crate::engine::EngineConfig;
use crate::mbo::StrategyKind;
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::pool::WorkerPool;
use crate::util::stats::{max, mean, min, percentile};
use crate::util::sync::{SyncAtomicBool, SyncAtomicU64, SyncAtomicUsize, SyncMutex};

use self::coalesce::{Claim, CoalescingCache, Fill};

pub mod coalesce;

/// Schema tag carried by every request and response.
pub const SERVE_SCHEMA: &str = "kareus_serve";
/// Protocol version; requests with any other version are rejected.
pub const SERVE_VERSION: u64 = 1;
/// Hard cap on one request line. Longer lines get a typed parse error and
/// the connection is closed (the remainder of the line is unread, so there
/// is no way to resynchronize the stream).
pub const MAX_REQUEST_LINE: usize = 64 * 1024;
/// Client-side cap on one response line (plans with many slots are big).
const MAX_RESPONSE_LINE: usize = 16 * 1024 * 1024;

// ---------------------------------------------------------------------------
// Target specs
// ---------------------------------------------------------------------------

/// Parse a target spec: `max` | `deadline:<s>` | `budget:<J>` |
/// `power-cap:<W>`. The numeric forms require a finite positive value.
pub fn parse_target(spec: &str) -> Result<Target, String> {
    fn positive(what: &str, v: &str) -> Result<f64, String> {
        match v.parse::<f64>() {
            Ok(x) if x.is_finite() && x > 0.0 => Ok(x),
            _ => Err(format!("{what} wants a finite positive number, got '{v}'")),
        }
    }
    if spec == "max" || spec == "max-throughput" {
        return Ok(Target::MaxThroughput);
    }
    match spec.split_once(':') {
        Some(("deadline", v)) => Ok(Target::Deadline(positive("deadline", v)?)),
        Some(("budget", v)) => Ok(Target::EnergyBudget(positive("budget", v)?)),
        Some(("power-cap", v)) | Some(("cap", v)) => Ok(Target::PowerCap(positive("cap", v)?)),
        _ => Err(format!(
            "bad target '{spec}' (max | deadline:<s> | budget:<J> | power-cap:<W>)"
        )),
    }
}

/// Canonical string form of a target — the inverse of [`parse_target`],
/// used for cache keys and response provenance so `deadline:1.50` and
/// `deadline:1.5` never alias as distinct cache entries.
pub fn target_spec(t: &Target) -> String {
    match t {
        Target::MaxThroughput => "max".to_string(),
        Target::Deadline(v) => format!("deadline:{v}"),
        Target::EnergyBudget(v) => format!("budget:{v}"),
        Target::PowerCap(v) => format!("power-cap:{v}"),
    }
}

// ---------------------------------------------------------------------------
// Typed protocol structs
// ---------------------------------------------------------------------------

/// One request line. `job` is the cluster job-spec grammar
/// (`gpu:model:par:system`); `target` is canonical (see [`target_spec`]);
/// `strategy` optionally overrides the server's search strategy for this
/// request (safe: the MBO cache key folds the strategy fingerprint).
#[derive(Clone, Debug, PartialEq)]
pub enum ServeRequest {
    Plan { job: String, target: String, seed: u64, strategy: Option<StrategyKind> },
    Stats { deterministic: bool },
    Shutdown,
}

impl ServeRequest {
    /// Byte-deterministic wire form (the envelope fields are always
    /// present, so a round-trip through [`ServeRequest::from_json`] is
    /// exact).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("serve", s(SERVE_SCHEMA)),
            ("version", num(SERVE_VERSION as f64)),
        ];
        match self {
            ServeRequest::Plan { job, target, seed, strategy } => {
                fields.push(("type", s("plan")));
                fields.push(("job", s(job)));
                fields.push(("target", s(target)));
                fields.push(("seed", num(*seed as f64)));
                fields.push((
                    "strategy",
                    match strategy {
                        Some(k) => s(k.name()),
                        None => Json::Null,
                    },
                ));
            }
            ServeRequest::Stats { deterministic } => {
                fields.push(("type", s("stats")));
                fields.push(("deterministic", Json::Bool(*deterministic)));
            }
            ServeRequest::Shutdown => fields.push(("type", s("shutdown"))),
        }
        obj(fields)
    }

    /// Decode and validate one parsed request. Every error message names
    /// the offending field; the server maps them to a typed `bad_request`.
    pub fn from_json(j: &Json) -> Result<ServeRequest, String> {
        let tag = j.get("serve").and_then(|v| v.as_str());
        if tag != Some(SERVE_SCHEMA) {
            return Err(format!("missing or wrong schema tag (want \"serve\":\"{SERVE_SCHEMA}\")"));
        }
        let version = j.get("version").and_then(|v| v.as_f64());
        if version != Some(SERVE_VERSION as f64) {
            return Err(format!("unsupported protocol version (want {SERVE_VERSION})"));
        }
        let rtype = j
            .get("type")
            .and_then(|v| v.as_str())
            .ok_or("missing request 'type' (plan | stats | shutdown)")?;
        match rtype {
            "plan" => {
                let job = j
                    .get("job")
                    .and_then(|v| v.as_str())
                    .ok_or("plan request missing 'job' (gpu:model:par:system)")?
                    .to_string();
                let target_raw = match j.get("target") {
                    None | Some(Json::Null) => "max",
                    Some(v) => v.as_str().ok_or("'target' must be a string")?,
                };
                // Canonicalize now so equivalent spellings share one
                // cache entry and one provenance string.
                let target = target_spec(&parse_target(target_raw)?);
                let seed = match j.get("seed") {
                    None | Some(Json::Null) => 2026,
                    Some(v) => {
                        let f = v.as_f64().ok_or("'seed' must be a non-negative integer")?;
                        if !(f.is_finite() && f >= 0.0 && f.fract() == 0.0) {
                            return Err("'seed' must be a non-negative integer".to_string());
                        }
                        f as u64
                    }
                };
                let strategy = match j.get("strategy") {
                    None | Some(Json::Null) => None,
                    Some(v) => {
                        let name = v.as_str().ok_or("'strategy' must be a string")?;
                        Some(StrategyKind::parse(name).ok_or_else(|| {
                            format!("unknown strategy '{name}' (mbo | exhaustive | random | halving)")
                        })?)
                    }
                };
                Ok(ServeRequest::Plan { job, target, seed, strategy })
            }
            "stats" => {
                let deterministic = match j.get("deterministic") {
                    None | Some(Json::Null) => false,
                    Some(v) => v.as_bool().ok_or("'deterministic' must be a boolean")?,
                };
                Ok(ServeRequest::Stats { deterministic })
            }
            "shutdown" => Ok(ServeRequest::Shutdown),
            other => Err(format!("unknown request type '{other}' (plan | stats | shutdown)")),
        }
    }
}

/// Typed error categories on the wire.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorCode {
    /// The request line was not valid JSON (or exceeded the line cap).
    Parse,
    /// Valid JSON, but not a valid request (schema/field errors, bad job
    /// spec, bad target).
    BadRequest,
    /// Miss-path admission was full; retry later.
    Busy,
    /// No frontier point satisfies the requested target.
    Infeasible,
    /// The server is draining and accepts no new work.
    ShuttingDown,
    /// The optimizer panicked; the panic text is in the message.
    Internal,
}

impl ErrorCode {
    pub fn as_str(&self) -> &'static str {
        match self {
            ErrorCode::Parse => "parse",
            ErrorCode::BadRequest => "bad_request",
            ErrorCode::Busy => "busy",
            ErrorCode::Infeasible => "infeasible",
            ErrorCode::ShuttingDown => "shutting_down",
            ErrorCode::Internal => "internal",
        }
    }

    pub fn parse_code(v: &str) -> Option<ErrorCode> {
        match v {
            "parse" => Some(ErrorCode::Parse),
            "bad_request" => Some(ErrorCode::BadRequest),
            "busy" => Some(ErrorCode::Busy),
            "infeasible" => Some(ErrorCode::Infeasible),
            "shutting_down" => Some(ErrorCode::ShuttingDown),
            "internal" => Some(ErrorCode::Internal),
            _ => None,
        }
    }
}

/// One response line. The envelope keys are always present (null when not
/// applicable) so the wire shape — and therefore the byte form — never
/// depends on which path produced the response.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeResponse {
    /// Mirrors the request type (`plan` | `stats` | `shutdown`); `error`
    /// for lines that never decoded into a request.
    pub rtype: String,
    /// `ok` | `busy` | `error`.
    pub status: String,
    /// Plan responses only: whether the plan cache answered.
    pub cache_hit: Option<bool>,
    /// Non-ok responses only.
    pub code: Option<ErrorCode>,
    pub message: Option<String>,
    /// Ok responses only: the typed result payload.
    pub result: Option<Json>,
}

impl ServeResponse {
    pub fn ok(rtype: &str, result: Json) -> ServeResponse {
        ServeResponse {
            rtype: rtype.to_string(),
            status: "ok".to_string(),
            cache_hit: None,
            code: None,
            message: None,
            result: Some(result),
        }
    }

    pub fn error(rtype: &str, code: ErrorCode, message: &str) -> ServeResponse {
        ServeResponse {
            rtype: rtype.to_string(),
            status: "error".to_string(),
            cache_hit: None,
            code: Some(code),
            message: Some(message.to_string()),
            result: None,
        }
    }

    pub fn busy(message: &str) -> ServeResponse {
        ServeResponse {
            rtype: "plan".to_string(),
            status: "busy".to_string(),
            cache_hit: None,
            code: Some(ErrorCode::Busy),
            message: Some(message.to_string()),
            result: None,
        }
    }

    pub fn is_ok(&self) -> bool {
        self.status == "ok"
    }

    /// Byte-deterministic wire form.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("serve", s(SERVE_SCHEMA)),
            ("version", num(SERVE_VERSION as f64)),
            ("type", s(&self.rtype)),
            ("status", s(&self.status)),
            (
                "cache_hit",
                match self.cache_hit {
                    Some(b) => Json::Bool(b),
                    None => Json::Null,
                },
            ),
            (
                "code",
                match self.code {
                    Some(c) => s(c.as_str()),
                    None => Json::Null,
                },
            ),
            (
                "message",
                match &self.message {
                    Some(m) => s(m),
                    None => Json::Null,
                },
            ),
            ("result", self.result.clone().unwrap_or(Json::Null)),
        ])
    }

    /// Decode one response line (the loadgen client and tests).
    pub fn from_json(j: &Json) -> Result<ServeResponse, String> {
        if j.get("serve").and_then(|v| v.as_str()) != Some(SERVE_SCHEMA) {
            return Err("response missing schema tag".to_string());
        }
        if j.get("version").and_then(|v| v.as_f64()) != Some(SERVE_VERSION as f64) {
            return Err("response has unsupported version".to_string());
        }
        let rtype =
            j.get("type").and_then(|v| v.as_str()).ok_or("response missing 'type'")?.to_string();
        let status = j
            .get("status")
            .and_then(|v| v.as_str())
            .ok_or("response missing 'status'")?
            .to_string();
        let cache_hit = match j.get("cache_hit") {
            None | Some(Json::Null) => None,
            Some(v) => Some(v.as_bool().ok_or("'cache_hit' must be a boolean")?),
        };
        let code = match j.get("code") {
            None | Some(Json::Null) => None,
            Some(v) => {
                let name = v.as_str().ok_or("'code' must be a string")?;
                Some(ErrorCode::parse_code(name).ok_or_else(|| format!("unknown code '{name}'"))?)
            }
        };
        let message = match j.get("message") {
            None | Some(Json::Null) => None,
            Some(v) => Some(v.as_str().ok_or("'message' must be a string")?.to_string()),
        };
        let result = match j.get("result") {
            None | Some(Json::Null) => None,
            Some(v) => Some(v.clone()),
        };
        Ok(ServeResponse { rtype, status, cache_hit, code, message, result })
    }
}

/// What the connection loop should do after writing a response.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Control {
    Continue,
    Shutdown,
}

// ---------------------------------------------------------------------------
// Wire reading
// ---------------------------------------------------------------------------

enum LineError {
    /// The line exceeded the cap; the payload is how many bytes were seen.
    Oversized(usize),
    Io,
}

/// Read one newline-terminated line, capped at `cap` bytes.
///
/// `Ok(None)` is clean EOF. A truncated final line (EOF with no newline) is
/// surfaced as a line so the parser can answer it with a typed error rather
/// than silently dropping bytes. A trailing `\r` is stripped. Invalid UTF-8
/// is replaced (the JSON parser then reports a typed error on the
/// replacement characters).
fn read_line_capped(r: &mut impl BufRead, cap: usize) -> Result<Option<String>, LineError> {
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let (found, used) = {
            let chunk = match r.fill_buf() {
                Ok(c) => c,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => return Err(LineError::Io),
            };
            if chunk.is_empty() {
                // EOF: surface a trailing partial line exactly once.
                if buf.is_empty() {
                    return Ok(None);
                }
                (true, 0)
            } else {
                match chunk.iter().position(|&b| b == b'\n') {
                    Some(i) => {
                        buf.extend_from_slice(&chunk[..i]);
                        (true, i + 1)
                    }
                    None => {
                        buf.extend_from_slice(chunk);
                        (false, chunk.len())
                    }
                }
            }
        };
        r.consume(used);
        if buf.len() > cap {
            return Err(LineError::Oversized(buf.len()));
        }
        if found {
            if buf.last() == Some(&b'\r') {
                buf.pop();
            }
            return Ok(Some(String::from_utf8_lossy(&buf).into_owned()));
        }
    }
}

// ---------------------------------------------------------------------------
// The plan service (transport-free core)
// ---------------------------------------------------------------------------

/// Workload shape shared by every request (matches the `kareus cluster`
/// defaults), plus the admission bound.
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    /// Concurrent miss-path optimizations admitted before requests get a
    /// typed `busy` response. Zero means every miss is refused (useful for
    /// testing the busy path deterministically).
    pub max_inflight: usize,
    pub microbatch: u32,
    pub seq_len: u32,
    pub n_microbatches: u32,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions { max_inflight: 2, microbatch: 8, seq_len: 4096, n_microbatches: 8 }
    }
}

#[derive(Default)]
struct Counters {
    requests: SyncAtomicU64,
    plans: SyncAtomicU64,
    hits: SyncAtomicU64,
    misses: SyncAtomicU64,
    busy: SyncAtomicU64,
    errors: SyncAtomicU64,
}

/// The transport-free request processor: caches, counters, admission, and
/// the optimizer entry point. [`Server`] feeds it lines from TCP;
/// `benches/hot_paths.rs` and unit tests feed it lines directly.
pub struct PlanService {
    engine: EngineConfig,
    opts: ServeOptions,
    /// Plan cache + coalescing map ([`coalesce::CoalescingCache`]), keyed
    /// `job|target|seed|strategy`. Filled slots double as negative cache
    /// for deterministic failures (infeasible targets), so the hit/miss
    /// split is a pure function of the request multiset. Abnormal owner
    /// death instead poisons the slot — waiters get a typed internal
    /// error, the key is evicted, and nothing false is cached.
    plans: CoalescingCache<Json>,
    counters: Counters,
    inflight: SyncAtomicUsize,
    shutting_down: SyncAtomicBool,
    started: Instant,
}

impl PlanService {
    pub fn new(engine: EngineConfig, opts: ServeOptions) -> PlanService {
        PlanService {
            engine,
            opts,
            plans: CoalescingCache::new(),
            counters: Counters::default(),
            inflight: SyncAtomicUsize::new(0),
            shutting_down: SyncAtomicBool::new(false),
            started: Instant::now(),
        }
    }

    pub fn is_shutting_down(&self) -> bool {
        self.shutting_down.load()
    }

    /// Total request lines processed (including unparseable ones).
    pub fn requests(&self) -> u64 {
        self.counters.requests.load()
    }

    /// Plan requests answered from the plan cache (including coalesced
    /// waiters — they never re-entered the optimizer).
    pub fn hits(&self) -> u64 {
        self.counters.hits.load()
    }

    /// Plan requests that ran the optimizer.
    pub fn misses(&self) -> u64 {
        self.counters.misses.load()
    }

    /// Count an oversized request line that never reached
    /// [`PlanService::process_line`].
    pub fn note_oversized(&self) {
        self.counters.requests.fetch_add(1);
        self.counters.errors.fetch_add(1);
    }

    /// Process one request line into one response. This is the entire
    /// per-request path; the TCP layer only moves bytes.
    pub fn process_line(&self, line: &str) -> (ServeResponse, Control) {
        self.counters.requests.fetch_add(1);
        let parsed = match Json::parse(line) {
            Ok(j) => j,
            Err(e) => {
                self.counters.errors.fetch_add(1);
                return (
                    ServeResponse::error("error", ErrorCode::Parse, &e.to_string()),
                    Control::Continue,
                );
            }
        };
        let req = match ServeRequest::from_json(&parsed) {
            Ok(r) => r,
            Err(m) => {
                self.counters.errors.fetch_add(1);
                return (
                    ServeResponse::error("error", ErrorCode::BadRequest, &m),
                    Control::Continue,
                );
            }
        };
        if self.is_shutting_down() {
            return (
                ServeResponse::error(
                    "error",
                    ErrorCode::ShuttingDown,
                    "server is draining; no new requests",
                ),
                Control::Continue,
            );
        }
        match req {
            ServeRequest::Plan { job, target, seed, strategy } => {
                (self.plan(&job, &target, seed, strategy), Control::Continue)
            }
            ServeRequest::Stats { deterministic } => {
                (ServeResponse::ok("stats", self.stats_json(deterministic)), Control::Continue)
            }
            ServeRequest::Shutdown => {
                self.shutting_down.store(true);
                (
                    ServeResponse::ok("shutdown", obj(vec![("draining", Json::Bool(true))])),
                    Control::Shutdown,
                )
            }
        }
    }

    fn plan(
        &self,
        job: &str,
        target: &str,
        seed: u64,
        strategy: Option<StrategyKind>,
    ) -> ServeResponse {
        self.counters.plans.fetch_add(1);
        let strat_name = strategy.map(|k| k.name()).unwrap_or("");
        let key = format!("{job}|{target}|{seed}|{strat_name}");
        let guard = match self.plans.claim(&key, || self.admit()) {
            Claim::Refused => {
                self.counters.busy.fetch_add(1);
                return ServeResponse::busy(&format!(
                    "server at max in-flight optimizations ({}); retry later",
                    self.opts.max_inflight
                ));
            }
            Claim::Waiter(slot) => return self.waiter_response(slot.wait()),
            Claim::Owner(guard) => guard,
        };
        self.counters.misses.fetch_add(1);
        // The optimizer panicking (e.g. a trace replay miss) must not
        // strand coalesced waiters or kill the worker: catch, convert to
        // a typed internal error, and cache it — the panic is
        // deterministic for the same request. If even this path unwinds,
        // the dropped FillGuard poisons the slot and waiters still get a
        // typed internal error.
        let computed =
            catch_unwind(AssertUnwindSafe(|| self.compute(job, target, seed, strategy)));
        self.inflight.fetch_sub(1);
        let payload = match computed {
            Ok(Ok(result)) => obj(vec![("ok", result)]),
            Ok(Err((code, message))) => Self::err_payload(code, &message),
            Err(panic) => {
                let text = panic
                    .downcast_ref::<String>()
                    .map(|t| t.as_str())
                    .or_else(|| panic.downcast_ref::<&str>().copied())
                    .unwrap_or("optimizer panicked");
                Self::err_payload(ErrorCode::Internal, text)
            }
        };
        guard.fill(payload.clone());
        if payload.get("ok").is_none() {
            self.counters.errors.fetch_add(1);
        }
        Self::respond_from_payload(&payload, false)
    }

    /// What a coalesced waiter answers with once its slot resolves. A
    /// published payload is a cache hit (ok or typed deterministic
    /// error alike); a poisoned slot — the owner died before publishing —
    /// becomes a typed internal error rather than a hang or a panic, and
    /// is never presented as a cache hit (the key was evicted, so a
    /// retry recomputes).
    fn waiter_response(&self, fill: Fill<Json>) -> ServeResponse {
        match fill {
            Fill::Value(payload) => {
                self.counters.hits.fetch_add(1);
                Self::respond_from_payload(&payload, true)
            }
            Fill::Poisoned(why) => {
                self.counters.errors.fetch_add(1);
                let mut resp = ServeResponse::error("plan", ErrorCode::Internal, &why);
                resp.cache_hit = Some(false);
                resp
            }
        }
    }

    fn err_payload(code: ErrorCode, message: &str) -> Json {
        obj(vec![(
            "err",
            obj(vec![("code", s(code.as_str())), ("message", s(message))]),
        )])
    }

    fn respond_from_payload(payload: &Json, hit: bool) -> ServeResponse {
        if let Some(result) = payload.get("ok") {
            let mut resp = ServeResponse::ok("plan", result.clone());
            resp.cache_hit = Some(hit);
            return resp;
        }
        let e = payload.get("err");
        let code = e
            .and_then(|v| v.get("code"))
            .and_then(|v| v.as_str())
            .and_then(ErrorCode::parse_code)
            .unwrap_or(ErrorCode::Internal);
        let message = e
            .and_then(|v| v.get("message"))
            .and_then(|v| v.as_str())
            .unwrap_or("corrupt cached payload");
        let mut resp = ServeResponse::error("plan", code, message);
        resp.cache_hit = Some(hit);
        resp
    }

    /// Admission: lock-free permit under `max_inflight`.
    fn admit(&self) -> bool {
        let mut cur = self.inflight.load();
        loop {
            if cur >= self.opts.max_inflight {
                return false;
            }
            match self.inflight.compare_exchange(cur, cur + 1) {
                Ok(_) => return true,
                Err(now) => cur = now,
            }
        }
    }

    /// The miss path: the same pipeline a direct `kareus optimize` /
    /// `cluster` invocation runs, so served plans are byte-identical to a
    /// direct engine call by construction.
    fn compute(
        &self,
        job: &str,
        target: &str,
        seed: u64,
        strategy: Option<StrategyKind>,
    ) -> Result<Json, (ErrorCode, String)> {
        let parsed = parse_job_spec(
            job,
            self.opts.microbatch,
            self.opts.seq_len,
            self.opts.n_microbatches,
            seed,
        )
        .map_err(|e| (ErrorCode::BadRequest, format!("bad job spec '{job}': {e}")))?;
        let t = parse_target(target).map_err(|m| (ErrorCode::BadRequest, m))?;
        let sc = parsed.scenario;
        let engine = match strategy {
            Some(k) => self.engine.clone().with_strategy(k),
            None => self.engine.clone(),
        };
        let result = run_system_with(&sc.gpu, &sc.cfg, sc.system, sc.seed, &engine);
        let coord = Coordinator::new(sc.gpu.clone(), sc.cfg).with_engine(engine.clone());
        let dep = coord.select(&result, t).ok_or_else(|| {
            (
                ErrorCode::Infeasible,
                format!("no frontier point satisfies target '{target}' for job '{job}'"),
            )
        })?;
        Ok(obj(vec![
            ("job", s(job)),
            ("target", s(target)),
            ("seed", num(seed as f64)),
            ("system", s(result.system.name())),
            ("workload", s(&coord.cfg.label())),
            (
                "frontier",
                arr(result
                    .frontier
                    .points()
                    .iter()
                    .map(|p| arr(vec![num(p.time), num(p.energy)]))
                    .collect()),
            ),
            ("deployment", dep.to_json()),
            ("mbo_profiling_s", num(result.mbo_profiling_s)),
            ("backend", s(engine.backend.name())),
            ("strategy", s(engine.strategy.name())),
        ]))
    }

    /// The `stats` result payload. Wall-clock and scheduling-sensitive
    /// values (uptime, engine cache tallies that depend on worker
    /// interleaving) are nulled in deterministic mode, exactly like
    /// `sweep_json`.
    pub fn stats_json(&self, deterministic: bool) -> Json {
        let unstable = |v: f64| if deterministic { Json::Null } else { num(v) };
        obj(vec![
            ("uptime_s", unstable(self.started.elapsed().as_secs_f64())),
            ("requests", num(self.requests() as f64)),
            ("plans", num(self.counters.plans.load() as f64)),
            ("hits", num(self.hits() as f64)),
            ("misses", num(self.misses() as f64)),
            ("busy", num(self.counters.busy.load() as f64)),
            ("errors", num(self.counters.errors.load() as f64)),
            ("plan_cache_entries", num(self.plans.len() as f64)),
            (
                "engine",
                obj(vec![
                    ("backend", s(self.engine.backend.name())),
                    ("strategy", s(self.engine.strategy.name())),
                    ("threads", num(self.engine.worker_threads() as f64)),
                    ("mbo_entries", num(self.engine.mbo_cache.len() as f64)),
                    ("mbo_hits", unstable(self.engine.mbo_cache.hits() as f64)),
                    ("mbo_misses", unstable(self.engine.mbo_cache.misses() as f64)),
                    ("exec_entries", num(self.engine.measure_cache.len() as f64)),
                    ("exec_hits", unstable(self.engine.measure_cache.hits() as f64)),
                    ("exec_misses", unstable(self.engine.measure_cache.misses() as f64)),
                ]),
            ),
            ("max_inflight", num(self.opts.max_inflight as f64)),
            ("shutting_down", Json::Bool(self.is_shutting_down())),
        ])
    }
}

// ---------------------------------------------------------------------------
// The TCP server
// ---------------------------------------------------------------------------

/// Server configuration (`kareus serve` flags).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind address, e.g. `127.0.0.1:4500` (`:0` picks an ephemeral port;
    /// the bound address is logged and available via
    /// [`Server::local_addr`]).
    pub addr: String,
    /// Connection worker threads; 0 ⇒ `util::pool::default_threads()`.
    pub threads: usize,
    pub opts: ServeOptions,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { addr: "127.0.0.1:4500".to_string(), threads: 0, opts: ServeOptions::default() }
    }
}

/// Read-half registry: one entry per live connection, so graceful shutdown
/// can unblock readers (`Shutdown::Read` — responses still flush) without
/// aborting in-flight work.
#[derive(Default)]
struct ConnRegistry {
    conns: SyncMutex<std::collections::BTreeMap<u64, TcpStream>>,
    next: SyncAtomicU64,
}

impl ConnRegistry {
    fn insert(&self, stream: TcpStream) -> u64 {
        let id = self.next.fetch_add(1);
        self.conns.lock().insert(id, stream);
        id
    }

    fn remove(&self, id: u64) {
        self.conns.lock().remove(&id);
    }

    fn trip(&self) {
        for stream in self.conns.lock().values() {
            let _ = stream.shutdown(Shutdown::Read);
        }
    }
}

type LogFn = Arc<dyn Fn(&str) + Send + Sync>;

/// The accept loop + worker pool around one [`PlanService`].
pub struct Server {
    service: Arc<PlanService>,
    listener: TcpListener,
    threads: usize,
    log: LogFn,
}

impl Server {
    /// Bind the listener. `log` receives human-readable progress lines
    /// (`main` routes them to stderr; artifacts own stdout).
    pub fn bind(
        engine: EngineConfig,
        cfg: &ServeConfig,
        log: impl Fn(&str) + Send + Sync + 'static,
    ) -> std::io::Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)?;
        let threads =
            if cfg.threads == 0 { crate::util::pool::default_threads() } else { cfg.threads };
        Ok(Server {
            service: Arc::new(PlanService::new(engine, cfg.opts)),
            listener,
            threads,
            log: Arc::new(log),
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.listener.local_addr().expect("listener has a local address")
    }

    /// The underlying service (tests and benches introspect counters).
    pub fn service(&self) -> Arc<PlanService> {
        Arc::clone(&self.service)
    }

    /// Accept connections until a `shutdown` request arrives, then drain:
    /// stop accepting, unblock every parked reader, and join the pool
    /// (queued and in-flight requests all complete first).
    pub fn run(self) -> std::io::Result<()> {
        let addr = self.local_addr();
        (self.log)(&format!(
            "kareus serve: listening on {addr} ({} workers, max {} in-flight optimizations)",
            self.threads, self.service.opts.max_inflight
        ));
        let registry = Arc::new(ConnRegistry::default());
        let pool = WorkerPool::new(self.threads);
        for conn in self.listener.incoming() {
            if self.service.is_shutting_down() {
                break;
            }
            match conn {
                Ok(stream) => {
                    let service = Arc::clone(&self.service);
                    let registry = Arc::clone(&registry);
                    let log = Arc::clone(&self.log);
                    pool.execute(move || handle_conn(service, registry, stream, addr, log));
                }
                Err(e) => (self.log)(&format!("kareus serve: accept error: {e}")),
            }
        }
        drop(pool); // join workers: drains queued + in-flight requests
        (self.log)(&format!(
            "kareus serve: drained ({} requests, {} hits, {} misses)",
            self.service.requests(),
            self.service.hits(),
            self.service.misses()
        ));
        Ok(())
    }
}

/// One connection's lifetime on a pool worker. Panic containment lives in
/// [`PlanService::plan`]; everything here is I/O.
fn handle_conn(
    service: Arc<PlanService>,
    registry: Arc<ConnRegistry>,
    stream: TcpStream,
    listen_addr: SocketAddr,
    log: LogFn,
) {
    let (read_half, reader_src) = match (stream.try_clone(), stream.try_clone()) {
        (Ok(a), Ok(b)) => (a, b),
        _ => {
            log("kareus serve: failed to clone connection handles");
            return;
        }
    };
    // Register *before* the shutdown check: a connection registered before
    // the registry trip gets unblocked by it; one registered after sees
    // the flag here. Either way no reader parks forever.
    let id = registry.insert(read_half);
    let mut writer = stream;
    if service.is_shutting_down() {
        let resp = ServeResponse::error(
            "error",
            ErrorCode::ShuttingDown,
            "server is draining; no new requests",
        );
        let _ = write_response(&mut writer, &resp);
        registry.remove(id);
        return;
    }
    let mut reader = BufReader::new(reader_src);
    loop {
        match read_line_capped(&mut reader, MAX_REQUEST_LINE) {
            Ok(None) => break,
            Ok(Some(line)) => {
                let (resp, control) = service.process_line(&line);
                if write_response(&mut writer, &resp).is_err() {
                    break;
                }
                if control == Control::Shutdown {
                    // Drain sequence: the flag is already set (inside
                    // process_line). Unblock parked readers — their write
                    // halves stay open so in-flight responses still land —
                    // then poke the listener so the accept loop observes
                    // the flag.
                    registry.trip();
                    let _ = TcpStream::connect(listen_addr);
                    break;
                }
            }
            Err(LineError::Oversized(n)) => {
                service.note_oversized();
                let resp = ServeResponse::error(
                    "error",
                    ErrorCode::Parse,
                    &format!("request line of {n}+ bytes exceeds the {MAX_REQUEST_LINE}-byte cap"),
                );
                let _ = write_response(&mut writer, &resp);
                break; // the rest of the oversized line is unread: no resync
            }
            Err(LineError::Io) => break,
        }
    }
    registry.remove(id);
}

fn write_response(w: &mut TcpStream, resp: &ServeResponse) -> std::io::Result<()> {
    // Plan payloads are finite by construction; if one ever is not, send a
    // typed internal error instead of a corrupt line.
    let mut line = match resp.to_json().try_dump() {
        Ok(l) => l,
        Err(e) => {
            ServeResponse::error(&resp.rtype, ErrorCode::Internal, &e.to_string())
                .to_json()
                .dump()
        }
    };
    line.push('\n');
    w.write_all(line.as_bytes())?;
    w.flush()
}

// ---------------------------------------------------------------------------
// Loadgen client
// ---------------------------------------------------------------------------

/// `kareus loadgen` configuration.
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    pub addr: String,
    pub requests: usize,
    pub concurrency: usize,
    /// Round-robin job mix: request *i* asks for `jobs[i % jobs.len()]`.
    pub jobs: Vec<String>,
    pub target: String,
    pub seed: u64,
    /// Null every wall-clock field in the report (byte-identical double
    /// runs against a deterministic backend).
    pub deterministic: bool,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            addr: "127.0.0.1:4500".to_string(),
            requests: 16,
            concurrency: 4,
            jobs: vec!["a100:qwen1.7b:tp8pp2:megatron".to_string()],
            target: "max".to_string(),
            seed: 2026,
            deterministic: false,
        }
    }
}

#[derive(Default)]
struct WorkerTally {
    ok: u64,
    errors: u64,
    busy: u64,
    hits: u64,
    misses: u64,
    latencies_ms: Vec<f64>,
}

/// Drive a server and emit the `kareus_loadgen` v1 report.
///
/// Requests are assigned deterministically: worker *w* of *C* opens one
/// connection and sends requests `w, w+C, w+2C, …` in order, request *i*
/// targeting `jobs[i % jobs.len()]`. Counters are therefore a pure function
/// of the request multiset (the server coalesces identical in-flight
/// requests), which is what makes the deterministic-mode report
/// byte-reproducible.
pub fn run_loadgen(cfg: &LoadgenConfig) -> Result<Json, String> {
    if cfg.requests == 0 {
        return Err("--requests must be >= 1".to_string());
    }
    if cfg.jobs.is_empty() {
        return Err("--jobs must name at least one job spec".to_string());
    }
    for job in &cfg.jobs {
        parse_job_spec(job, 8, 4096, 8, cfg.seed)
            .map_err(|e| format!("bad job spec '{job}': {e}"))?;
    }
    let target = target_spec(&parse_target(&cfg.target)?);
    let concurrency = cfg.concurrency.clamp(1, cfg.requests);

    // Pre-serialize every request line, then split by worker.
    let lines: Vec<String> = (0..cfg.requests)
        .map(|i| {
            let req = ServeRequest::Plan {
                job: cfg.jobs[i % cfg.jobs.len()].clone(),
                target: target.clone(),
                seed: cfg.seed,
                strategy: None,
            };
            req.to_json().dump()
        })
        .collect();
    let batches: Vec<(String, Vec<String>)> = (0..concurrency)
        .map(|w| {
            let mine = lines.iter().skip(w).step_by(concurrency).cloned().collect();
            (cfg.addr.clone(), mine)
        })
        .collect();

    let started = Instant::now();
    let pool = WorkerPool::new(concurrency);
    let outcomes: Vec<Result<WorkerTally, String>> =
        pool.map(batches, |(addr, mine)| run_worker(&addr, &mine));
    let wall_s = started.elapsed().as_secs_f64();

    let mut tally = WorkerTally::default();
    for out in outcomes {
        let w = out?;
        tally.ok += w.ok;
        tally.errors += w.errors;
        tally.busy += w.busy;
        tally.hits += w.hits;
        tally.misses += w.misses;
        tally.latencies_ms.extend(w.latencies_ms);
    }

    let wall = |v: f64| if cfg.deterministic { Json::Null } else { num(v) };
    let cache_answered = tally.hits + tally.misses;
    Ok(obj(vec![
        ("report", s("kareus_loadgen")),
        ("version", num(1.0)),
        // The address usually carries an ephemeral port; it is wall-ish
        // provenance, nulled in deterministic mode like the timings.
        ("addr", if cfg.deterministic { Json::Null } else { s(&cfg.addr) }),
        ("requests", num(cfg.requests as f64)),
        ("concurrency", num(concurrency as f64)),
        ("jobs", arr(cfg.jobs.iter().map(|j| s(j)).collect())),
        ("target", s(&target)),
        ("seed", num(cfg.seed as f64)),
        ("ok", num(tally.ok as f64)),
        ("errors", num(tally.errors as f64)),
        ("busy", num(tally.busy as f64)),
        ("hits", num(tally.hits as f64)),
        ("misses", num(tally.misses as f64)),
        (
            "hit_rate",
            if cache_answered > 0 {
                num(tally.hits as f64 / cache_answered as f64)
            } else {
                Json::Null
            },
        ),
        (
            "latency",
            obj(vec![
                ("p50_ms", wall(percentile(&tally.latencies_ms, 50.0))),
                ("p99_ms", wall(percentile(&tally.latencies_ms, 99.0))),
                ("mean_ms", wall(mean(&tally.latencies_ms))),
                ("min_ms", wall(min(&tally.latencies_ms))),
                ("max_ms", wall(max(&tally.latencies_ms))),
            ]),
        ),
        ("requests_per_s", wall(cfg.requests as f64 / wall_s.max(1e-9))),
        ("wall_s", wall(wall_s)),
    ]))
}

fn run_worker(addr: &str, lines: &[String]) -> Result<WorkerTally, String> {
    let stream =
        TcpStream::connect(addr).map_err(|e| format!("loadgen: connect {addr}: {e}"))?;
    let mut writer = stream.try_clone().map_err(|e| format!("loadgen: clone: {e}"))?;
    let mut reader = BufReader::new(stream);
    let mut tally = WorkerTally::default();
    for line in lines {
        let t0 = Instant::now();
        writer
            .write_all(format!("{line}\n").as_bytes())
            .and_then(|_| writer.flush())
            .map_err(|e| format!("loadgen: send: {e}"))?;
        let reply = match read_line_capped(&mut reader, MAX_RESPONSE_LINE) {
            Ok(Some(l)) => l,
            Ok(None) => return Err("loadgen: server closed the connection mid-run".to_string()),
            Err(LineError::Oversized(n)) => {
                return Err(format!("loadgen: response of {n}+ bytes exceeds the client cap"))
            }
            Err(LineError::Io) => return Err("loadgen: read error".to_string()),
        };
        tally.latencies_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        let resp = Json::parse(&reply)
            .map_err(|e| format!("loadgen: bad response line: {e}"))
            .and_then(|j| ServeResponse::from_json(&j).map_err(|m| format!("loadgen: {m}")))?;
        match resp.status.as_str() {
            "ok" => {
                tally.ok += 1;
                match resp.cache_hit {
                    Some(true) => tally.hits += 1,
                    Some(false) => tally.misses += 1,
                    // An ok plan response always carries cache_hit; a
                    // missing flag is a malformed server and counts as an
                    // error so the report can never overstate the hit rate.
                    None => {
                        tally.ok -= 1;
                        tally.errors += 1;
                    }
                }
            }
            "busy" => tally.busy += 1,
            _ => tally.errors += 1,
        }
    }
    Ok(tally)
}

/// Send one `shutdown` control request (used by `kareus loadgen
/// --shutdown` so CI can stop a background server deterministically).
pub fn send_shutdown(addr: &str) -> Result<(), String> {
    let stream = TcpStream::connect(addr).map_err(|e| format!("shutdown: connect {addr}: {e}"))?;
    let mut writer = stream.try_clone().map_err(|e| format!("shutdown: clone: {e}"))?;
    let line = ServeRequest::Shutdown.to_json().dump();
    writer
        .write_all(format!("{line}\n").as_bytes())
        .and_then(|_| writer.flush())
        .map_err(|e| format!("shutdown: send: {e}"))?;
    let mut reader = BufReader::new(stream);
    match read_line_capped(&mut reader, MAX_RESPONSE_LINE) {
        Ok(Some(reply)) => {
            let j = Json::parse(&reply).map_err(|e| format!("shutdown: bad response: {e}"))?;
            let resp = ServeResponse::from_json(&j).map_err(|m| format!("shutdown: {m}"))?;
            if resp.is_ok() {
                Ok(())
            } else {
                Err(format!(
                    "shutdown refused: {}",
                    resp.message.unwrap_or_else(|| "unknown error".to_string())
                ))
            }
        }
        Ok(None) => Err("shutdown: server closed without responding".to_string()),
        Err(_) => Err("shutdown: read error".to_string()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn target_specs_roundtrip_canonically() {
        for spec in ["max", "deadline:1.5", "budget:3000", "power-cap:280"] {
            let t = parse_target(spec).unwrap();
            assert_eq!(target_spec(&t), spec);
        }
        // Aliases and float spellings canonicalize.
        assert_eq!(target_spec(&parse_target("max-throughput").unwrap()), "max");
        assert_eq!(target_spec(&parse_target("cap:280").unwrap()), "power-cap:280");
        assert_eq!(target_spec(&parse_target("deadline:1.50").unwrap()), "deadline:1.5");
        for bad in ["", "deadline", "deadline:", "deadline:-1", "deadline:inf", "cap:0", "x:1"] {
            assert!(parse_target(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn requests_roundtrip_byte_deterministically() {
        let reqs = vec![
            ServeRequest::Plan {
                job: "a100:qwen1.7b:tp8pp2:kareus".to_string(),
                target: "deadline:1.5".to_string(),
                seed: 7,
                strategy: Some(StrategyKind::Random),
            },
            ServeRequest::Plan {
                job: "v100:llama3b:tp8pp2:megatron".to_string(),
                target: "max".to_string(),
                seed: 2026,
                strategy: None,
            },
            ServeRequest::Stats { deterministic: true },
            ServeRequest::Shutdown,
        ];
        for req in reqs {
            let dump = req.to_json().dump();
            let back = ServeRequest::from_json(&Json::parse(&dump).unwrap()).unwrap();
            assert_eq!(back, req);
            assert_eq!(back.to_json().dump(), dump, "wire form must be byte-stable");
        }
    }

    #[test]
    fn request_decoding_rejects_bad_fields() {
        let cases = [
            (r#"{"type":"plan","job":"a"}"#, "schema tag"),
            (r#"{"serve":"kareus_serve","version":2,"type":"plan"}"#, "version"),
            (r#"{"serve":"kareus_serve","version":1}"#, "type"),
            (r#"{"serve":"kareus_serve","version":1,"type":"nope"}"#, "unknown request type"),
            (r#"{"serve":"kareus_serve","version":1,"type":"plan"}"#, "job"),
            (
                r#"{"serve":"kareus_serve","version":1,"type":"plan","job":"a:b:c:d","target":"x"}"#,
                "bad target",
            ),
            (
                r#"{"serve":"kareus_serve","version":1,"type":"plan","job":"a:b:c:d","seed":-1}"#,
                "seed",
            ),
            (
                r#"{"serve":"kareus_serve","version":1,"type":"plan","job":"a:b:c:d","seed":1.5}"#,
                "seed",
            ),
            (
                r#"{"serve":"kareus_serve","version":1,"type":"plan","job":"a:b:c:d","strategy":"bogus"}"#,
                "strategy",
            ),
        ];
        for (line, needle) in cases {
            let err = ServeRequest::from_json(&Json::parse(line).unwrap()).unwrap_err();
            assert!(err.contains(needle), "{line}: {err:?} missing {needle:?}");
        }
    }

    #[test]
    fn responses_roundtrip() {
        let mut ok = ServeResponse::ok("plan", obj(vec![("x", num(1.0))]));
        ok.cache_hit = Some(true);
        let cases = vec![
            ok,
            ServeResponse::busy("full"),
            ServeResponse::error("error", ErrorCode::Parse, "json error at byte 0: bad"),
        ];
        for resp in cases {
            let dump = resp.to_json().dump();
            let back = ServeResponse::from_json(&Json::parse(&dump).unwrap()).unwrap();
            assert_eq!(back, resp);
            assert_eq!(back.to_json().dump(), dump);
        }
    }

    #[test]
    fn service_answers_repeat_plans_from_cache() {
        let svc = PlanService::new(EngineConfig::sequential(), ServeOptions::default());
        let line = ServeRequest::Plan {
            job: "a100:qwen1.7b:tp8pp2:megatron".to_string(),
            target: "max".to_string(),
            seed: 11,
            strategy: None,
        }
        .to_json()
        .dump();
        let (first, _) = svc.process_line(&line);
        assert!(first.is_ok(), "{first:?}");
        assert_eq!(first.cache_hit, Some(false));
        let exec_misses = svc.engine.measure_cache.misses();
        let (second, _) = svc.process_line(&line);
        assert!(second.is_ok());
        assert_eq!(second.cache_hit, Some(true));
        // The fast path never touched the engine: no new measurements.
        assert_eq!(svc.engine.measure_cache.misses(), exec_misses);
        assert_eq!((svc.hits(), svc.misses()), (1, 1));
        // Identical plan bytes from both paths.
        assert_eq!(first.result.unwrap().dump(), second.result.unwrap().dump());
    }

    #[test]
    fn service_maps_wire_garbage_to_typed_errors() {
        let svc = PlanService::new(EngineConfig::sequential(), ServeOptions::default());
        for line in ["", "not json", "{\"serve\":", "[1,2,3]", "{\"serve\":\"x\",\"version\":1}"] {
            let (resp, control) = svc.process_line(line);
            assert_eq!(control, Control::Continue);
            assert_eq!(resp.status, "error", "{line:?}");
            assert!(
                matches!(resp.code, Some(ErrorCode::Parse) | Some(ErrorCode::BadRequest)),
                "{line:?} → {:?}",
                resp.code
            );
            assert!(resp.message.is_some());
        }
        assert_eq!(svc.requests(), 5);
        // Unparseable lines never enter the plan path.
        assert_eq!((svc.hits(), svc.misses()), (0, 0));
    }

    #[test]
    fn zero_admission_yields_typed_busy() {
        let opts = ServeOptions { max_inflight: 0, ..ServeOptions::default() };
        let svc = PlanService::new(EngineConfig::sequential(), opts);
        let line = ServeRequest::Plan {
            job: "a100:qwen1.7b:tp8pp2:megatron".to_string(),
            target: "max".to_string(),
            seed: 1,
            strategy: None,
        }
        .to_json()
        .dump();
        let (resp, _) = svc.process_line(&line);
        assert_eq!(resp.status, "busy");
        assert_eq!(resp.code, Some(ErrorCode::Busy));
        assert!(resp.message.unwrap().contains("in-flight"));
    }

    #[test]
    fn infeasible_targets_are_typed_and_negatively_cached() {
        let svc = PlanService::new(EngineConfig::sequential(), ServeOptions::default());
        let line = ServeRequest::Plan {
            job: "a100:qwen1.7b:tp8pp2:megatron".to_string(),
            // No schedule finishes an iteration in a nanosecond.
            target: "deadline:1e-9".to_string(),
            seed: 3,
            strategy: None,
        }
        .to_json()
        .dump();
        let (first, _) = svc.process_line(&line);
        assert_eq!(first.status, "error");
        assert_eq!(first.code, Some(ErrorCode::Infeasible));
        assert_eq!(first.cache_hit, Some(false));
        let (second, _) = svc.process_line(&line);
        assert_eq!(second.code, Some(ErrorCode::Infeasible));
        assert_eq!(second.cache_hit, Some(true), "deterministic failures are cached too");
        assert_eq!((svc.hits(), svc.misses()), (1, 1));
    }

    #[test]
    fn poisoned_slot_answers_waiters_with_typed_internal_error() {
        let svc = PlanService::new(EngineConfig::sequential(), ServeOptions::default());
        // Become the owner for a key exactly as `plan()` would, then die
        // without publishing (the only way: unwind past the FillGuard).
        // A waiter that coalesced before the death must get a typed
        // internal error — never a hang, a panic, or a false cache hit.
        let key = "a100:qwen1.7b:tp8pp2:megatron|max|5|";
        let guard = match svc.plans.claim(key, || true) {
            Claim::Owner(g) => g,
            _ => panic!("fresh key must be ownable"),
        };
        let slot = match svc.plans.claim(key, || false) {
            Claim::Waiter(s) => s,
            _ => panic!("second claim must coalesce onto the owner"),
        };
        drop(guard);
        let errors_before = svc.counters.errors.load();
        let resp = svc.waiter_response(slot.wait());
        assert_eq!(resp.status, "error");
        assert_eq!(resp.code, Some(ErrorCode::Internal));
        assert_eq!(resp.cache_hit, Some(false));
        assert!(resp.message.unwrap().contains("died before publishing"));
        assert_eq!(svc.counters.errors.load(), errors_before + 1);
        // Poison is not negatively cached: the key is free to retry.
        assert!(matches!(svc.plans.claim(key, || true), Claim::Owner(_)));
        assert_eq!((svc.hits(), svc.misses()), (0, 0));
    }

    #[test]
    fn stats_deterministic_mode_nulls_wall_fields() {
        let svc = PlanService::new(EngineConfig::sequential(), ServeOptions::default());
        let stats = svc.stats_json(true);
        assert_eq!(stats.get("uptime_s"), Some(&Json::Null));
        assert_eq!(stats.get("engine").unwrap().get("exec_hits"), Some(&Json::Null));
        assert!(stats.get("requests").unwrap().as_f64().is_some());
        let live = svc.stats_json(false);
        assert!(live.get("uptime_s").unwrap().as_f64().unwrap() >= 0.0);
    }

    #[test]
    fn shutdown_request_flips_the_flag_and_control() {
        let svc = PlanService::new(EngineConfig::sequential(), ServeOptions::default());
        let line = ServeRequest::Shutdown.to_json().dump();
        let (resp, control) = svc.process_line(&line);
        assert!(resp.is_ok());
        assert_eq!(control, Control::Shutdown);
        assert!(svc.is_shutting_down());
        // Later requests get the typed shutting_down error.
        let (resp, control) = svc.process_line(&ServeRequest::Shutdown.to_json().dump());
        assert_eq!(control, Control::Continue);
        assert_eq!(resp.code, Some(ErrorCode::ShuttingDown));
    }

    #[test]
    fn read_line_capped_handles_truncation_and_caps() {
        use std::io::Cursor;
        // Normal lines, CRLF, and a truncated trailing line all surface.
        let mut r = Cursor::new(b"{\"a\":1}\r\n{\"b\":2}\ntail-no-newline".to_vec());
        assert_eq!(read_line_capped(&mut r, 1024).ok().flatten().unwrap(), "{\"a\":1}");
        assert_eq!(read_line_capped(&mut r, 1024).ok().flatten().unwrap(), "{\"b\":2}");
        assert_eq!(read_line_capped(&mut r, 1024).ok().flatten().unwrap(), "tail-no-newline");
        assert!(read_line_capped(&mut r, 1024).ok().flatten().is_none(), "then clean EOF");
        // The cap fires even when the line never ends.
        let mut r = Cursor::new(vec![b'x'; 4096]);
        assert!(matches!(read_line_capped(&mut r, 128), Err(LineError::Oversized(_))));
    }
}
