//! Microbatch frontier construction (§4.4, Algorithm 2).
//!
//! A microbatch is a sequence of partition instances. For each GPU
//! frequency (uniform within the microbatch — frequency switching costs
//! milliseconds, §4.4 design decision 1), Kareus enumerates the Cartesian
//! product of per-*type* schedule configurations (design decision 2: all
//! instances of a type share one configuration), sums time and energy
//! across instances plus non-partition components, adds the
//! sequential-execution candidate (§4.5 execution-model switching), and
//! prunes to the Pareto frontier.
//!
//! Every execution in this module flows through a [`Measurer`] — an
//! [`ExecutionBackend`](crate::backend::ExecutionBackend) plus optional
//! shared [`MeasureCache`](crate::profiler::MeasureCache) — so the whole
//! layer is measurement-source agnostic (simulator, trace replay, future
//! hardware backends).

use std::collections::BTreeMap;

use crate::backend::{kernels_fp, Measurer};
use crate::engine::{EngineConfig, MboCache};
use crate::frontier::{Frontier, Point};
use crate::mbo::MboResult;
use crate::partition::Partition;
use crate::profiler::Profiler;
use crate::sim::exec::{KernelFreqs, LaunchAt, Schedule};
use crate::sim::gpu::GpuSpec;
use crate::sim::kernel::Kernel;
use crate::workload::MicrobatchWork;

/// The deployed configuration of one microbatch.
#[derive(Clone, Debug, PartialEq)]
pub struct MicrobatchPlan {
    pub freq_mhz: u32,
    /// Per-partition-type (SM allocation, launch timing); empty when
    /// sequential.
    pub configs: BTreeMap<String, Schedule>,
    /// §4.5: fall back to the sequential execution model.
    pub sequential: bool,
}

/// One feasible microbatch operating point.
#[derive(Clone, Debug)]
pub struct MbPoint {
    pub time_s: f64,
    pub total_j: f64,
    pub dyn_j: f64,
    pub plan: MicrobatchPlan,
}

impl MbPoint {
    pub fn static_j(&self) -> f64 {
        self.total_j - self.dyn_j
    }
}

/// A microbatch frontier: Pareto points plus the full plan list (frontier
/// tags index into `points`).
#[derive(Clone, Debug)]
pub struct MbFrontier {
    pub points: Vec<MbPoint>,
    pub frontier: Frontier,
}

impl MbFrontier {
    pub fn from_points(points: Vec<MbPoint>) -> Self {
        let f = Frontier::from_points(
            points.iter().enumerate().map(|(i, p)| Point::new(p.time_s, p.total_j, i)).collect(),
        );
        MbFrontier { points, frontier: f }
    }

    /// Frontier points in ascending time, with their plans.
    pub fn pareto(&self) -> Vec<&MbPoint> {
        self.frontier.points().iter().map(|p| &self.points[p.tag]).collect()
    }
}

/// Caller-hoisted measurement fingerprints for one microbatch: the
/// combined GPU+partition fingerprint per partition plus the fingerprint
/// of the non-partition extras. Hot loops (the Cartesian product,
/// per-frequency sweeps) hash the GPU spec and every kernel once instead
/// of on each probe.
#[derive(Clone, Debug)]
pub struct MbFps {
    /// `combine_fp(gpu, partition)`, parallel to the partition slice.
    pub parts: Vec<u64>,
    /// [`kernels_fp`] of the extras.
    pub extra: u64,
}

/// Hoist all fingerprints for `(gpu, partitions, extra)`.
pub fn microbatch_fps(gpu: &GpuSpec, partitions: &[Partition], extra: &[Kernel]) -> MbFps {
    let gpu_fp = gpu.fingerprint();
    MbFps {
        parts: partitions
            .iter()
            .map(|p| crate::profiler::combine_fp(gpu_fp, p.fingerprint()))
            .collect(),
        extra: kernels_fp(gpu_fp, extra, None),
    }
}

/// Evaluate one overlapped microbatch: partitions executed sequentially,
/// each overlapping its comm with the paired nanobatch's computation
/// (Figure 5, rows 2–3), plus non-partition extras and the trailing
/// drain comm of the last nanobatch (exposed by construction).
pub fn eval_overlapped_microbatch(
    gpu: &GpuSpec,
    partitions: &[Partition],
    configs: &BTreeMap<String, Schedule>,
    freq_mhz: u32,
    extra: &[Kernel],
    m: Measurer<'_>,
) -> MbPoint {
    eval_overlapped_microbatch_fp(gpu, partitions, None, configs, freq_mhz, extra, m)
}

/// Hot-path variant of [`eval_overlapped_microbatch`]: `fps` are the
/// caller-precomputed [`microbatch_fps`] (pass them whenever the call
/// sits inside a loop; when `None` they are hashed on the spot).
#[allow(clippy::too_many_arguments)]
pub fn eval_overlapped_microbatch_fp(
    gpu: &GpuSpec,
    partitions: &[Partition],
    fps: Option<&MbFps>,
    configs: &BTreeMap<String, Schedule>,
    freq_mhz: u32,
    extra: &[Kernel],
    m: Measurer<'_>,
) -> MbPoint {
    let computed;
    let fps = match fps {
        Some(f) => f,
        None => {
            computed = microbatch_fps(gpu, partitions, extra);
            &computed
        }
    };
    let mut time = 0.0;
    let mut total = 0.0;
    let mut dynamic = 0.0;
    let mut last_comm: Option<(&Kernel, u32)> = None;
    for (i, part) in partitions.iter().enumerate() {
        let mut sched = *configs
            .get(&part.ptype)
            .unwrap_or(&Schedule::uniform(12, LaunchAt::WithComp(0), freq_mhz));
        sched.freq_mhz = freq_mhz;
        // Per-class assignments keep their memory frequency but re-pin the
        // compute class to the sweep frequency (no-op for Uniform).
        sched.kernel_freqs = sched.kernel_freqs.rebased(freq_mhz);
        // A partition's execution depends only on its own schedule, so the
        // Cartesian product over other types re-measures identical
        // (partition, schedule) pairs constantly — the shared cache
        // collapses those to one backend probe each.
        let r = m.exec(
            fps.parts[i],
            gpu,
            &part.comps,
            part.comm.as_ref(),
            &sched,
            gpu.ref_temp_c,
            Some(gpu.tdp_w),
        );
        time += part.count as f64 * r.time_s;
        total += part.count as f64 * r.total_j();
        dynamic += part.count as f64 * r.dyn_j;
        if let Some(c) = &part.comm {
            last_comm = Some((c, sched.comm_sms));
        }
    }
    // Drain: the final segment's comm has no following computation to
    // overlap with — it runs exposed once per microbatch.
    if let Some((c, sms)) = last_comm {
        let bw = gpu.comm_bw(sms.max(1));
        let t = c.comm_bytes / bw;
        time += t;
        let p_dyn = gpu.comm_power(bw) + gpu.mem_power(2.0 * bw);
        total += (gpu.static_power(gpu.ref_temp_c) + p_dyn) * t;
        dynamic += p_dyn * t;
    }
    // Non-partition components run sequentially at the same frequency.
    let (te, je, de) = eval_extra(gpu, fps.extra, extra, freq_mhz, m);
    time += te;
    total += je;
    dynamic += de;
    MbPoint {
        time_s: time,
        total_j: total,
        dyn_j: dynamic,
        plan: MicrobatchPlan { freq_mhz, configs: configs.clone(), sequential: false },
    }
}

fn eval_extra(
    gpu: &GpuSpec,
    extra_fp: u64,
    extra: &[Kernel],
    freq_mhz: u32,
    m: Measurer<'_>,
) -> (f64, f64, f64) {
    if extra.is_empty() {
        return (0.0, 0.0, 0.0);
    }
    let r = m.exec(
        extra_fp,
        gpu,
        extra,
        None,
        &Schedule::sequential(freq_mhz),
        gpu.ref_temp_c,
        Some(gpu.tdp_w),
    );
    (r.time_s, r.total_j(), r.dyn_j)
}

/// Caller-hoisted fingerprints for the sequential execution model: one
/// entry per segment plus the extras' fp. Frequency-invariant, so
/// per-frequency sweeps hash the GPU spec and every kernel once.
#[derive(Clone, Debug)]
pub struct SeqFps {
    /// [`kernels_fp`] per segment, parallel to `work.segments`.
    pub segments: Vec<u64>,
    /// [`kernels_fp`] of the extras.
    pub extra: u64,
}

/// Hoist all fingerprints for the sequential model of `work` on `gpu`.
pub fn sequential_fps(gpu: &GpuSpec, work: &MicrobatchWork) -> SeqFps {
    let gpu_fp = gpu.fingerprint();
    SeqFps {
        segments: work
            .segments
            .iter()
            .map(|seg| kernels_fp(gpu_fp, &seg.comps, seg.comm.as_ref()))
            .collect(),
        extra: kernels_fp(gpu_fp, &work.extra, None),
    }
}

/// Evaluate the sequential execution model for one microbatch (§4.5;
/// Megatron-LM's model, Figure 2a): each segment's computation then its
/// comm, unsplit microbatch.
pub fn eval_sequential_microbatch(
    gpu: &GpuSpec,
    work: &MicrobatchWork,
    freq_mhz: u32,
    m: Measurer<'_>,
) -> MbPoint {
    eval_sequential_microbatch_fp(gpu, work, None, freq_mhz, m)
}

/// Hot-path variant of [`eval_sequential_microbatch`]: `fps` are the
/// caller-precomputed [`sequential_fps`] (pass them whenever the call
/// sits inside a per-frequency loop; when `None` they are hashed on the
/// spot).
pub fn eval_sequential_microbatch_fp(
    gpu: &GpuSpec,
    work: &MicrobatchWork,
    fps: Option<&SeqFps>,
    freq_mhz: u32,
    m: Measurer<'_>,
) -> MbPoint {
    let computed;
    let fps = match fps {
        Some(f) => f,
        None => {
            computed = sequential_fps(gpu, work);
            &computed
        }
    };
    let mut time = 0.0;
    let mut total = 0.0;
    let mut dynamic = 0.0;
    for (i, seg) in work.segments.iter().enumerate() {
        let r = m.exec(
            fps.segments[i],
            gpu,
            &seg.comps,
            seg.comm.as_ref(),
            &Schedule::sequential(freq_mhz),
            gpu.ref_temp_c,
            Some(gpu.tdp_w),
        );
        time += r.time_s;
        total += r.total_j();
        dynamic += r.dyn_j;
    }
    let (te, je, de) = eval_extra(gpu, fps.extra, &work.extra, freq_mhz, m);
    time += te;
    total += je;
    dynamic += de;
    MbPoint {
        time_s: time,
        total_j: total,
        dyn_j: dynamic,
        plan: MicrobatchPlan { freq_mhz, configs: BTreeMap::new(), sequential: true },
    }
}

/// Algorithm 2: build the microbatch frontier from per-partition MBO
/// results. `seq_work` is the unsplit microbatch (sequential-model
/// candidates are profiled per frequency and merged, §4.5).
pub fn microbatch_frontier(
    gpu: &GpuSpec,
    partitions: &[Partition],
    mbo: &BTreeMap<String, MboResult>,
    extra: &[Kernel],
    seq_work: Option<&MicrobatchWork>,
    m: Measurer<'_>,
) -> MbFrontier {
    // Distinct (sms, launch, kernel freqs) configs that appear on each
    // type's partition frontier — the schedule vocabulary the Cartesian
    // product ranges over. The kernel-frequency component is `Uniform`
    // throughout at partition granularity, so the vocabulary (and the
    // resulting frontier) is unchanged from the pre-kernel-DVFS layout.
    let mut type_configs: Vec<(String, Vec<(u32, LaunchAt, KernelFreqs)>)> = Vec::new();
    for part in partitions {
        if part.comm.is_none() {
            continue;
        }
        let Some(res) = mbo.get(&part.ptype) else { continue };
        let mut cfgs: Vec<(u32, LaunchAt, KernelFreqs)> = Vec::new();
        for p in res.frontier.points() {
            let s = res.evaluated[p.tag].sched;
            if !cfgs.contains(&(s.comm_sms, s.launch, s.kernel_freqs)) {
                cfgs.push((s.comm_sms, s.launch, s.kernel_freqs));
            }
        }
        if cfgs.is_empty() {
            cfgs.push((12, LaunchAt::WithComp(0), KernelFreqs::Uniform));
        }
        cfgs.truncate(8); // keep enumeration tractable
        // Always include nanobatching's default configuration so Kareus's
        // frontier dominates Nanobatching+Perseus by construction (the MBO
        // may not have kept it if it never landed on a partition frontier).
        let default_cfg = (
            crate::baselines::NANO_DEFAULT_SMS,
            crate::baselines::NANO_DEFAULT_LAUNCH,
            KernelFreqs::Uniform,
        );
        if !cfgs.contains(&default_cfg) {
            cfgs.push(default_cfg);
        }
        type_configs.push((part.ptype.clone(), cfgs));
    }

    let mut points: Vec<MbPoint> = Vec::new();
    // Fingerprints are invariant across the whole product — hash once.
    let fps = microbatch_fps(gpu, partitions, extra);
    let seq_fps = seq_work.map(|w| sequential_fps(gpu, w));

    // A partition's execution depends only on its own schedule, so its
    // (time, total, dyn) contribution is a function of (partition, config,
    // frequency) alone. Instead of materializing every combination as a
    // cloned schedule map and re-walking all partitions per combination,
    // measure each partition once per config per frequency and enumerate
    // the product with an index odometer (last type varies fastest — the
    // original nesting order), summing the memoized contributions in
    // partition order so every float lands in the same addition sequence
    // as the direct per-combo evaluation.
    //
    // `slot[i]`: this partition's entry in the type vocabulary. rposition
    // mirrors the map-overwrite semantics the combo maps had (a later
    // duplicate ptype entry wins).
    let slot: Vec<Option<usize>> = partitions
        .iter()
        .map(|p| type_configs.iter().rposition(|(t, _)| *t == p.ptype))
        .collect();
    let drain_part = partitions.iter().rposition(|p| p.comm.is_some());
    for &f in &gpu.search_freqs() {
        // Per-(type, config) schedules at this frequency.
        let scheds: Vec<Vec<Schedule>> = type_configs
            .iter()
            .map(|(_, cfgs)| {
                cfgs.iter()
                    .map(|&(sms, launch, kf)| Schedule {
                        comm_sms: sms,
                        launch,
                        freq_mhz: f,
                        kernel_freqs: kf.rebased(f),
                    })
                    .collect()
            })
            .collect();
        let default_sched = Schedule::uniform(12, LaunchAt::WithComp(0), f);
        let exec_part = |i: usize, sched: &Schedule| -> (f64, f64, f64) {
            let part = &partitions[i];
            let r = m.exec(
                fps.parts[i],
                gpu,
                &part.comps,
                part.comm.as_ref(),
                sched,
                gpu.ref_temp_c,
                Some(gpu.tdp_w),
            );
            (
                part.count as f64 * r.time_s,
                part.count as f64 * r.total_j(),
                part.count as f64 * r.dyn_j,
            )
        };
        // contrib[i]: one entry per config of partition i's type; a single
        // default-schedule entry for partitions outside the vocabulary.
        let contrib: Vec<Vec<(f64, f64, f64)>> = (0..partitions.len())
            .map(|i| match slot[i] {
                Some(j) => scheds[j].iter().map(|s| exec_part(i, s)).collect(),
                None => vec![exec_part(i, &default_sched)],
            })
            .collect();
        // Drain of the last comm partition, per applicable config (it
        // depends only on the comm kernel and that config's SM count).
        let drain_for = |c: &Kernel, sms: u32| -> (f64, f64, f64) {
            let bw = gpu.comm_bw(sms.max(1));
            let t = c.comm_bytes / bw;
            let p_dyn = gpu.comm_power(bw) + gpu.mem_power(2.0 * bw);
            (t, (gpu.static_power(gpu.ref_temp_c) + p_dyn) * t, p_dyn * t)
        };
        let drains: Option<(Option<usize>, Vec<(f64, f64, f64)>)> = drain_part.map(|i| {
            let c = partitions[i].comm.as_ref().unwrap();
            match slot[i] {
                Some(j) => (Some(j), scheds[j].iter().map(|s| drain_for(c, s.comm_sms)).collect()),
                None => (None, vec![drain_for(c, default_sched.comm_sms)]),
            }
        });
        // Non-partition extras: identical for every combination.
        let (te, je, de) = eval_extra(gpu, fps.extra, extra, f, m);

        let n_types = type_configs.len();
        let mut idx = vec![0usize; n_types];
        let mut done = false;
        while !done {
            let mut time = 0.0;
            let mut total = 0.0;
            let mut dynamic = 0.0;
            for (i, c) in contrib.iter().enumerate() {
                let (t, tot, dy) = match slot[i] {
                    Some(j) => c[idx[j]],
                    None => c[0],
                };
                time += t;
                total += tot;
                dynamic += dy;
            }
            if let Some((dslot, dvals)) = &drains {
                let (t, tot, dy) = match dslot {
                    Some(j) => dvals[idx[*j]],
                    None => dvals[0],
                };
                time += t;
                total += tot;
                dynamic += dy;
            }
            time += te;
            total += je;
            dynamic += de;
            let mut configs = BTreeMap::new();
            for (j, (ptype, _)) in type_configs.iter().enumerate() {
                configs.insert(ptype.clone(), scheds[j][idx[j]]);
            }
            points.push(MbPoint {
                time_s: time,
                total_j: total,
                dyn_j: dynamic,
                plan: MicrobatchPlan { freq_mhz: f, configs, sequential: false },
            });
            done = true;
            for k in (0..n_types).rev() {
                idx[k] += 1;
                if idx[k] < type_configs[k].1.len() {
                    done = false;
                    break;
                }
                idx[k] = 0;
            }
        }
        if let Some(w) = seq_work {
            points.push(eval_sequential_microbatch_fp(gpu, w, seq_fps.as_ref(), f, m));
        }
    }
    MbFrontier::from_points(points)
}

/// Run full MBO on every partition type with default engine settings
/// (simulator backend, auto thread count, fresh caches).
pub fn optimize_all_partitions(
    profiler_seed: u64,
    gpu: &GpuSpec,
    partitions: &[Partition],
    comm_group: u32,
) -> BTreeMap<String, MboResult> {
    let engine = EngineConfig::default();
    optimize_all_partitions_with(profiler_seed, gpu, partitions, comm_group, &engine)
}

/// The parallel multi-partition optimization engine (§5.1, §6.6): each
/// partition's search runs on its own worker with its own `Profiler` —
/// exactly the paper's model, where every partition is profiled on a
/// separate GPU, so thermal state is per-(partition, GPU) and *never*
/// shared across concurrent optimizations. Every profiler measures
/// through the engine's
/// [`ExecutionBackend`](crate::backend::ExecutionBackend), and every
/// search dispatches through the engine's
/// [`StrategyKind`](crate::mbo::StrategyKind) — multi-pass MBO by
/// default, exhaustive / random / successive-halving on request.
///
/// Determinism: each partition's seed derives only from `profiler_seed`
/// and the partition type, never from worker identity or scheduling order,
/// so results are byte-identical across any thread count. Warm caches are
/// bit-exact replays (see `tests/engine.rs`); the cache key folds the
/// strategy fingerprint, so strategies never alias each other's entries.
pub fn optimize_all_partitions_with(
    profiler_seed: u64,
    gpu: &GpuSpec,
    partitions: &[Partition],
    comm_group: u32,
    engine: &EngineConfig,
) -> BTreeMap<String, MboResult> {
    use crate::mbo::{optimize_partition_with_granularity, MboParams};
    use crate::profiler::ProfilerConfig;
    let backend_fp = engine.backend.fingerprint();
    let strategy_fp = engine.strategy.fingerprint();
    // Fail fast on an invalid user-settable strategy config (halving
    // hyperparameters): one clean typed panic here, instead of N worker
    // panics re-thrown by the pool as an opaque "worker panicked".
    if let Err(e) = engine.strategy.validate() {
        panic!("invalid '{}' strategy: {e}", engine.strategy.name());
    }
    // The pool runs `'static` jobs, so the closure owns its context: the
    // engine clone is cheap (Arc-backed caches/backend) and shares cache
    // state with the caller's engine by construction.
    let gpu_owned = gpu.clone();
    let engine_owned = engine.clone();
    let results: Vec<(String, MboResult)> = crate::util::pool::parallel_map(
        partitions.to_vec(),
        engine.worker_threads(),
        move |part| {
            let gpu = &gpu_owned;
            let engine = &engine_owned;
            // Deterministic per-partition seed (type-keyed, thread-free).
            let seed = profiler_seed ^ crate::util::hash::fnv1a_str(&part.ptype);
            let mut params = MboParams::for_class(part.size_class());
            params.seed = seed;
            let prof_cfg = ProfilerConfig::default();
            let key = MboCache::key(
                backend_fp,
                strategy_fp,
                gpu,
                &part,
                comm_group,
                &params,
                &prof_cfg,
                engine.freq_granularity,
            );
            if let Some(r) = engine.mbo_cache.get(key) {
                return (part.ptype.clone(), r);
            }
            // Strategy configs come from the engine (user-settable for
            // halving); surface the typed validation error verbatim
            // instead of a generic expect message.
            let strategy = match engine.strategy.build(params) {
                Ok(s) => s,
                Err(e) => panic!("invalid '{}' strategy: {e}", engine.strategy.name()),
            };
            let mut prof = Profiler::new(gpu.clone(), prof_cfg, seed)
                .with_cache(engine.measure_cache.clone())
                .with_backend(engine.backend.clone());
            let r = optimize_partition_with_granularity(
                strategy.as_ref(),
                &mut prof,
                &part,
                comm_group,
                engine.freq_granularity,
            );
            engine.mbo_cache.put(key, r.clone());
            (part.ptype.clone(), r)
        },
    );
    results.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{Measurer, SIM};
    use crate::partition::detect_partitions;
    use crate::profiler::MeasureCache;
    use crate::workload::{
        build_nanobatch_pass, build_pass, Dir, ModelSpec, Parallelism, TrainConfig,
    };

    fn cfg() -> TrainConfig {
        TrainConfig {
            model: ModelSpec::qwen3_1_7b(),
            par: Parallelism::new(8, 1, 2),
            microbatch: 8,
            seq_len: 4096,
            n_microbatches: 8,
            dtype_bytes: 2,
        }
    }

    #[test]
    fn sequential_single_freq_is_one_point() {
        let g = GpuSpec::a100();
        let c = cfg();
        let w = build_pass(&c, c.tokens_per_gpu(), Dir::Fwd, false, false);
        let p = eval_sequential_microbatch(&g, &w, 1410, Measurer::sim());
        assert!(p.time_s > 0.0 && p.total_j > 0.0);
        assert!(p.dyn_j < p.total_j);
        assert!(p.plan.sequential);
    }

    #[test]
    fn overlap_microbatch_beats_sequential_at_max_freq() {
        let g = GpuSpec::a100();
        let c = cfg();
        let seq_w = build_pass(&c, c.tokens_per_gpu(), Dir::Fwd, false, false);
        let nano_w = build_nanobatch_pass(&c, Dir::Fwd, false, false);
        let parts = detect_partitions(&g, &nano_w, true);
        let mut configs = BTreeMap::new();
        for p in &parts {
            configs.insert(
                p.ptype.clone(),
                Schedule::uniform(12, LaunchAt::WithComp(1), 1410),
            );
        }
        let ovl =
            eval_overlapped_microbatch(&g, &parts, &configs, 1410, &nano_w.extra, Measurer::sim());
        let seq = eval_sequential_microbatch(&g, &seq_w, 1410, Measurer::sim());
        assert!(ovl.time_s < seq.time_s, "ovl {} seq {}", ovl.time_s, seq.time_s);
    }

    #[test]
    fn frontier_contains_multiple_freqs() {
        let g = GpuSpec::a100();
        let c = cfg();
        let nano_w = build_nanobatch_pass(&c, Dir::Fwd, false, false);
        let parts = detect_partitions(&g, &nano_w, true);
        let mbo = optimize_all_partitions(7, &g, &parts, c.par.tp * c.par.cp);
        let seq_w = build_pass(&c, c.tokens_per_gpu(), Dir::Fwd, false, false);
        let mbf =
            microbatch_frontier(&g, &parts, &mbo, &nano_w.extra, Some(&seq_w), Measurer::sim());
        assert!(mbf.frontier.len() >= 5, "frontier len {}", mbf.frontier.len());
        let freqs: std::collections::BTreeSet<u32> =
            mbf.pareto().iter().map(|p| p.plan.freq_mhz).collect();
        assert!(freqs.len() >= 3, "only freqs {freqs:?} on frontier");
    }

    #[test]
    fn frontier_points_match_direct_eval_bitwise() {
        // The memoized odometer product must reproduce the direct
        // per-combination evaluation bit-for-bit for every emitted plan.
        let g = GpuSpec::a100();
        let c = cfg();
        let nano_w = build_nanobatch_pass(&c, Dir::Fwd, false, false);
        let parts = detect_partitions(&g, &nano_w, true);
        let mbo = optimize_all_partitions(7, &g, &parts, c.par.tp * c.par.cp);
        let seq_w = build_pass(&c, c.tokens_per_gpu(), Dir::Fwd, false, false);
        let mbf =
            microbatch_frontier(&g, &parts, &mbo, &nano_w.extra, Some(&seq_w), Measurer::sim());
        let overlapped: Vec<&MbPoint> = mbf.points.iter().filter(|p| !p.plan.sequential).collect();
        assert!(!overlapped.is_empty());
        // Sampled across the product (full re-evaluation would double the
        // test's simulator work for no extra coverage).
        let step = (overlapped.len() / 25).max(1);
        for p in overlapped.iter().step_by(step) {
            let direct = eval_overlapped_microbatch_fp(
                &g,
                &parts,
                None,
                &p.plan.configs,
                p.plan.freq_mhz,
                &nano_w.extra,
                Measurer::sim(),
            );
            assert_eq!(p.time_s.to_bits(), direct.time_s.to_bits());
            assert_eq!(p.total_j.to_bits(), direct.total_j.to_bits());
            assert_eq!(p.dyn_j.to_bits(), direct.dyn_j.to_bits());
        }
    }

    #[test]
    fn execution_model_switching_on_tiny_workloads() {
        // §4.5: when per-microbatch work is small, splitting into
        // nanobatches lowers arithmetic intensity and sequential execution
        // can win; the merged frontier must pick whichever is better and
        // never be worse than sequential-only.
        let g = GpuSpec::a100();
        let mut c = cfg();
        c.microbatch = 1;
        c.seq_len = 512; // tiny per-microbatch work
        let nano_w = build_nanobatch_pass(&c, Dir::Fwd, false, false);
        let parts = detect_partitions(&g, &nano_w, true);
        let mbo = optimize_all_partitions(13, &g, &parts, c.par.tp * c.par.cp);
        let seq_w = build_pass(&c, c.tokens_per_gpu(), Dir::Fwd, false, false);
        let mbf =
            microbatch_frontier(&g, &parts, &mbo, &nano_w.extra, Some(&seq_w), Measurer::sim());
        // Frontier min-time must be <= the best sequential point.
        let best_seq = (0..18)
            .map(|i| eval_sequential_microbatch(&g, &seq_w, 900 + 30 * i, Measurer::sim()).time_s)
            .fold(f64::INFINITY, f64::min);
        let ft = mbf.frontier.min_time().unwrap().time;
        assert!(ft <= best_seq * (1.0 + 1e-9), "frontier {ft} vs seq {best_seq}");
        // And sequential candidates are actually present in the point set.
        assert!(mbf.points.iter().any(|p| p.plan.sequential));
    }

    #[test]
    fn cached_evaluation_is_bit_identical() {
        let g = GpuSpec::a100();
        let c = cfg();
        let nano_w = build_nanobatch_pass(&c, Dir::Fwd, false, false);
        let parts = detect_partitions(&g, &nano_w, true);
        let mut configs = BTreeMap::new();
        for p in &parts {
            configs.insert(
                p.ptype.clone(),
                Schedule::uniform(12, LaunchAt::WithComp(1), 1410),
            );
        }
        let cache = MeasureCache::new();
        let cached = Measurer::new(&SIM, Some(&cache));
        let plain =
            eval_overlapped_microbatch(&g, &parts, &configs, 1410, &nano_w.extra, Measurer::sim());
        let cold = eval_overlapped_microbatch(&g, &parts, &configs, 1410, &nano_w.extra, cached);
        let warm = eval_overlapped_microbatch(&g, &parts, &configs, 1410, &nano_w.extra, cached);
        for p in [&cold, &warm] {
            assert_eq!(plain.time_s.to_bits(), p.time_s.to_bits());
            assert_eq!(plain.total_j.to_bits(), p.total_j.to_bits());
            assert_eq!(plain.dyn_j.to_bits(), p.dyn_j.to_bits());
        }
        assert!(cache.hits() > 0, "warm pass never hit the cache");
    }

    #[test]
    fn microbatch_energy_decomposes() {
        let g = GpuSpec::a100();
        let c = cfg();
        let w = build_pass(&c, c.tokens_per_gpu(), Dir::Fwd, true, true);
        let p = eval_sequential_microbatch(&g, &w, 1200, Measurer::sim());
        assert!(p.static_j() > 0.0);
        assert!((p.static_j() + p.dyn_j - p.total_j).abs() < 1e-9 * p.total_j);
    }
}
