//! Column-major (SoA) feature storage for the GBDT surrogate.
//!
//! Split search walks one feature across many rows; row-of-rows storage
//! (`&[Vec<f64>]`) turns every such walk into a pointer chase through
//! scattered heap allocations. A [`Matrix`] holds the same values
//! column-contiguous, so each feature streams linearly through cache.
//! Training-set index structures (value groups, scratch) live with the
//! tree builder, not here.

/// Dense column-major feature matrix: the value at (row `r`, column `c`)
/// lives at `cols[c * n_rows + r]`.
#[derive(Clone, Debug)]
pub struct Matrix {
    n_rows: usize,
    n_cols: usize,
    cols: Vec<f64>,
}

impl Matrix {
    /// Transpose row-major samples (all rows the same non-zero width).
    pub fn from_rows(x: &[Vec<f64>]) -> Matrix {
        assert!(!x.is_empty());
        let n_rows = x.len();
        let n_cols = x[0].len();
        assert!(n_cols > 0);
        let mut cols = vec![0.0; n_rows * n_cols];
        for (r, row) in x.iter().enumerate() {
            assert_eq!(row.len(), n_cols, "ragged row {r}");
            for (c, &v) in row.iter().enumerate() {
                cols[c * n_rows + r] = v;
            }
        }
        Matrix { n_rows, n_cols, cols }
    }

    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    pub fn n_cols(&self) -> usize {
        self.n_cols
    }

    /// One feature, contiguous across all rows.
    pub fn col(&self, c: usize) -> &[f64] {
        &self.cols[c * self.n_rows..(c + 1) * self.n_rows]
    }

    /// Value at (row, column).
    pub fn at(&self, r: usize, c: usize) -> f64 {
        self.cols[c * self.n_rows + r]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transpose_roundtrip() {
        let rows = vec![vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]];
        let m = Matrix::from_rows(&rows);
        assert_eq!((m.n_rows(), m.n_cols()), (2, 3));
        assert_eq!(m.col(0), &[1.0, 4.0]);
        assert_eq!(m.col(2), &[3.0, 6.0]);
        for (r, row) in rows.iter().enumerate() {
            for (c, &v) in row.iter().enumerate() {
                assert_eq!(m.at(r, c), v);
            }
        }
    }

    #[test]
    #[should_panic]
    fn ragged_rows_panic() {
        Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0]]);
    }
}
