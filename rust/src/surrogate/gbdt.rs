//! Gradient-boosted regression trees — the paper's surrogate model choice
//! (§4.3.2: XGBoost, because training scales linearly with samples and
//! trees handle the discrete/categorical schedule parameters natively).
//!
//! Squared loss ⇒ each round fits a tree to the residuals. Hyperparameters
//! follow Appendix C: max_depth 6, η = 0.3, 100 rounds, subsample 0.8.
//!
//! Training runs on the column-major SoA path ([`Gbdt::fit`] transposes
//! once, then every round reuses the same [`SplitIndex`] and scratch
//! buffers); [`Gbdt::fit_reference`] keeps the original row-major
//! implementation alive for the differential suite, which pins the two
//! bitwise-equal.

use super::matrix::Matrix;
use super::tree::{FitScratch, SplitIndex, Tree, TreeParams};
use crate::util::rng::Rng;

/// Ensemble sizes up to this use a stack accumulator in
/// [`Ensemble::predict`]; larger ensembles fall back to a heap buffer.
const STACK_MEMBERS: usize = 16;

#[derive(Clone, Debug)]
pub struct GbdtParams {
    pub n_rounds: usize,
    pub learning_rate: f64,
    pub max_depth: usize,
    pub min_samples_leaf: usize,
    pub lambda: f64,
    /// Row subsample fraction per round (stochastic gradient boosting).
    pub subsample: f64,
    pub seed: u64,
}

impl Default for GbdtParams {
    fn default() -> Self {
        // Appendix C settings.
        GbdtParams {
            n_rounds: 100,
            learning_rate: 0.3,
            max_depth: 6,
            min_samples_leaf: 2,
            lambda: 1.0,
            subsample: 1.0,
            seed: 0,
        }
    }
}

#[derive(Clone, Debug)]
pub struct Gbdt {
    base: f64,
    trees: Vec<Tree>,
    learning_rate: f64,
}

impl Gbdt {
    pub fn fit(x: &[Vec<f64>], y: &[f64], p: &GbdtParams) -> Gbdt {
        assert_eq!(x.len(), y.len());
        assert!(!x.is_empty());
        Self::fit_matrix(&Matrix::from_rows(x), y, p)
    }

    /// SoA boosting loop: the training-set sort index is built once and
    /// shared by all rounds; index/sort/count buffers are reused; the
    /// full-index vector is built once instead of per round when
    /// `subsample == 1.0`.
    pub fn fit_matrix(m: &Matrix, y: &[f64], p: &GbdtParams) -> Gbdt {
        assert_eq!(m.n_rows(), y.len());
        let n = m.n_rows();
        let base = y.iter().sum::<f64>() / n as f64;
        let mut pred: Vec<f64> = vec![base; n];
        let mut trees = Vec::with_capacity(p.n_rounds);
        let tp = TreeParams {
            max_depth: p.max_depth,
            min_samples_leaf: p.min_samples_leaf,
            lambda: p.lambda,
        };
        let gi = SplitIndex::build(m);
        let mut scratch = FitScratch::default();
        let mut rng = Rng::new(p.seed);
        let mut residual = vec![0.0f64; n];
        let full: Vec<u32> = (0..n as u32).collect();
        let mut sampled: Vec<u32> = Vec::new();
        for _ in 0..p.n_rounds {
            for i in 0..n {
                residual[i] = y[i] - pred[i];
            }
            let idx: &[u32] = if p.subsample < 1.0 {
                let k = ((n as f64 * p.subsample).round() as usize).clamp(1, n);
                sampled.clear();
                sampled.extend(rng.sample_indices(n, k).into_iter().map(|i| i as u32));
                &sampled
            } else {
                &full
            };
            let tree = Tree::fit_soa(m, &residual, idx, &tp, &gi, &mut scratch);
            for i in 0..n {
                pred[i] += p.learning_rate * tree.predict_row(m, i);
            }
            trees.push(tree);
        }
        Gbdt { base, trees, learning_rate: p.learning_rate }
    }

    /// Original row-major boosting loop, kept solely so the differential
    /// suite can pin `fit` ≡ `fit_reference` bitwise. Not a hot path.
    pub fn fit_reference(x: &[Vec<f64>], y: &[f64], p: &GbdtParams) -> Gbdt {
        assert_eq!(x.len(), y.len());
        assert!(!x.is_empty());
        let n = x.len();
        let base = y.iter().sum::<f64>() / n as f64;
        let mut pred: Vec<f64> = vec![base; n];
        let mut trees = Vec::with_capacity(p.n_rounds);
        let tp = TreeParams {
            max_depth: p.max_depth,
            min_samples_leaf: p.min_samples_leaf,
            lambda: p.lambda,
        };
        let mut rng = Rng::new(p.seed);
        let mut residual = vec![0.0f64; n];
        for _ in 0..p.n_rounds {
            for i in 0..n {
                residual[i] = y[i] - pred[i];
            }
            let idx: Vec<usize> = if p.subsample < 1.0 {
                let k = ((n as f64 * p.subsample).round() as usize).clamp(1, n);
                rng.sample_indices(n, k)
            } else {
                (0..n).collect()
            };
            let tree = Tree::fit(x, &residual, &idx, &tp);
            for i in 0..n {
                pred[i] += p.learning_rate * tree.predict(&x[i]);
            }
            trees.push(tree);
        }
        Gbdt { base, trees, learning_rate: p.learning_rate }
    }

    pub fn predict(&self, row: &[f64]) -> f64 {
        let mut v = self.base;
        for t in &self.trees {
            v += self.learning_rate * t.predict(row);
        }
        v
    }

    /// Batched [`predict`](Self::predict) into a caller-owned slice.
    /// Tree-outer accumulation (every output gets tree t's contribution
    /// before any output gets tree t+1's) keeps each element's addition
    /// sequence identical to `predict`, so results are bitwise-equal —
    /// while each tree's nodes stay hot in cache across all rows.
    pub fn predict_into(&self, rows: &[Vec<f64>], out: &mut [f64]) {
        assert_eq!(rows.len(), out.len());
        for v in out.iter_mut() {
            *v = self.base;
        }
        for t in &self.trees {
            for (row, v) in rows.iter().zip(out.iter_mut()) {
                *v += self.learning_rate * t.predict(row);
            }
        }
    }

    /// [`predict_into`](Self::predict_into) with the output vector
    /// cleared and sized for the caller.
    pub fn predict_batch(&self, rows: &[Vec<f64>], out: &mut Vec<f64>) {
        out.clear();
        out.resize(rows.len(), 0.0);
        self.predict_into(rows, out);
    }

    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }
}

/// Bootstrap ensemble for uncertainty quantification (§4.3.2 "exploration
/// with uncertainty"): M models trained on resampled datasets; the
/// per-candidate std dev of their predictions proxies predictive
/// uncertainty. Appendix C: M = 5, bootstrap fraction 0.8.
#[derive(Clone, Debug)]
pub struct Ensemble {
    pub members: Vec<Gbdt>,
}

#[derive(Clone, Debug)]
pub struct EnsembleParams {
    pub size: usize,
    pub bootstrap_fraction: f64,
    pub gbdt: GbdtParams,
}

impl Default for EnsembleParams {
    fn default() -> Self {
        EnsembleParams { size: 5, bootstrap_fraction: 0.8, gbdt: GbdtParams::default() }
    }
}

impl Ensemble {
    pub fn fit(x: &[Vec<f64>], y: &[f64], p: &EnsembleParams) -> Ensemble {
        let n = x.len();
        let k = ((n as f64 * p.bootstrap_fraction).round() as usize).clamp(1, n);
        let mut members = Vec::with_capacity(p.size);
        let mut rng = Rng::new(p.gbdt.seed ^ 0xB007);
        for m in 0..p.size {
            // Bootstrap: sample k rows with replacement.
            let mut xs = Vec::with_capacity(k);
            let mut ys = Vec::with_capacity(k);
            for _ in 0..k {
                let i = rng.below(n);
                xs.push(x[i].clone());
                ys.push(y[i]);
            }
            let mut gp = p.gbdt.clone();
            gp.seed = p.gbdt.seed.wrapping_add(m as u64 + 1);
            members.push(Gbdt::fit(&xs, &ys, &gp));
        }
        Ensemble { members }
    }

    /// (mean, std) across ensemble members. Member predictions accumulate
    /// in a stack buffer (heap fallback only past [`STACK_MEMBERS`]).
    pub fn predict(&self, row: &[f64]) -> (f64, f64) {
        let k = self.members.len();
        let mut stack = [0.0f64; STACK_MEMBERS];
        let mut heap: Vec<f64>;
        let preds: &mut [f64] = if k <= STACK_MEMBERS {
            &mut stack[..k]
        } else {
            heap = vec![0.0; k];
            &mut heap
        };
        for (slot, m) in preds.iter_mut().zip(&self.members) {
            *slot = m.predict(row);
        }
        (crate::util::stats::mean(preds), crate::util::stats::std_dev(preds))
    }

    /// Batched [`predict`](Self::predict): one pass per member over all
    /// rows (member-major, so each member's trees stay cache-hot), then a
    /// per-row gather in member order — the same value sequence
    /// `predict` feeds to mean/std_dev, hence bitwise-equal.
    pub fn predict_batch(&self, rows: &[Vec<f64>], out: &mut Vec<(f64, f64)>) {
        let n = rows.len();
        let k = self.members.len();
        let mut preds = vec![0.0f64; k * n];
        for (m, model) in self.members.iter().enumerate() {
            model.predict_into(rows, &mut preds[m * n..(m + 1) * n]);
        }
        out.clear();
        out.reserve(n);
        let mut stack = [0.0f64; STACK_MEMBERS];
        let mut heap = vec![0.0f64; if k > STACK_MEMBERS { k } else { 0 }];
        for r in 0..n {
            let buf: &mut [f64] =
                if k <= STACK_MEMBERS { &mut stack[..k] } else { &mut heap };
            for m in 0..k {
                buf[m] = preds[m * n + r];
            }
            out.push((crate::util::stats::mean(buf), crate::util::stats::std_dev(buf)));
        }
    }
}

/// R² on a held-out set — used by MBO diagnostics and tests.
pub fn r_squared(model: &Gbdt, x: &[Vec<f64>], y: &[f64]) -> f64 {
    let mean_y = y.iter().sum::<f64>() / y.len() as f64;
    let ss_tot: f64 = y.iter().map(|v| (v - mean_y) * (v - mean_y)).sum();
    let ss_res: f64 =
        x.iter().zip(y).map(|(xi, yi)| (yi - model.predict(xi)).powi(2)).sum();
    if ss_tot == 0.0 {
        1.0
    } else {
        1.0 - ss_res / ss_tot
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A smooth function of schedule-like features:
    /// time(freq, sms, timing) with interactions.
    fn synth(n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let f = rng.range_f64(900.0, 1410.0);
            let s = (rng.below(10) * 3 + 3) as f64;
            let t = rng.below(9) as f64;
            let time = 1000.0 / f + 0.3 * (s - 12.0).abs() + 0.5 * (t - 4.0).powi(2) / (f / 1000.0);
            x.push(vec![f, s, t]);
            y.push(time);
        }
        (x, y)
    }

    #[test]
    fn learns_schedule_like_function() {
        let (x, y) = synth(400, 1);
        let (xt, yt) = synth(100, 2);
        let model = Gbdt::fit(&x, &y, &GbdtParams::default());
        let r2 = r_squared(&model, &xt, &yt);
        assert!(r2 > 0.9, "r2 = {r2}");
    }

    #[test]
    fn boosting_improves_over_single_tree() {
        let (x, y) = synth(300, 3);
        let (xt, yt) = synth(100, 4);
        let one_params = GbdtParams { n_rounds: 1, learning_rate: 1.0, ..Default::default() };
        let one = Gbdt::fit(&x, &y, &one_params);
        let many = Gbdt::fit(&x, &y, &GbdtParams::default());
        assert!(r_squared(&many, &xt, &yt) > r_squared(&one, &xt, &yt));
    }

    #[test]
    fn deterministic_given_seed() {
        let (x, y) = synth(100, 5);
        let a = Gbdt::fit(&x, &y, &GbdtParams { subsample: 0.8, seed: 42, ..Default::default() });
        let b = Gbdt::fit(&x, &y, &GbdtParams { subsample: 0.8, seed: 42, ..Default::default() });
        for xi in x.iter().take(20) {
            assert_eq!(a.predict(xi), b.predict(xi));
        }
    }

    #[test]
    fn soa_fit_matches_reference_bitwise() {
        let (x, y) = synth(150, 9);
        for p in [
            GbdtParams::default(),
            GbdtParams { subsample: 0.8, seed: 42, ..Default::default() },
        ] {
            let soa = Gbdt::fit(&x, &y, &p);
            let r = Gbdt::fit_reference(&x, &y, &p);
            assert_eq!(soa.base.to_bits(), r.base.to_bits());
            assert_eq!(soa.trees.len(), r.trees.len());
            for (ta, tb) in soa.trees.iter().zip(&r.trees) {
                assert_eq!(ta.nodes, tb.nodes);
            }
            for xi in &x {
                assert_eq!(soa.predict(xi).to_bits(), r.predict(xi).to_bits());
            }
        }
    }

    #[test]
    fn predict_batch_matches_per_row_bitwise() {
        let (x, y) = synth(150, 10);
        let model = Gbdt::fit(&x, &y, &GbdtParams::default());
        let mut batch = Vec::new();
        model.predict_batch(&x, &mut batch);
        assert_eq!(batch.len(), x.len());
        for (xi, b) in x.iter().zip(&batch) {
            assert_eq!(model.predict(xi).to_bits(), b.to_bits());
        }
    }

    #[test]
    fn ensemble_predict_batch_matches_per_row_bitwise() {
        let (x, y) = synth(120, 11);
        let ens = Ensemble::fit(&x, &y, &EnsembleParams::default());
        let mut batch = Vec::new();
        ens.predict_batch(&x, &mut batch);
        assert_eq!(batch.len(), x.len());
        for (xi, &(bm, bs)) in x.iter().zip(&batch) {
            let (m, s) = ens.predict(xi);
            assert_eq!(m.to_bits(), bm.to_bits());
            assert_eq!(s.to_bits(), bs.to_bits());
        }
    }

    #[test]
    fn ensemble_uncertainty_higher_off_data() {
        let (x, y) = synth(200, 6);
        let ens = Ensemble::fit(&x, &y, &EnsembleParams::default());
        // In-distribution point vs far-extrapolation point.
        let (_, s_in) = ens.predict(&[1100.0, 12.0, 4.0]);
        let (_, s_out) = ens.predict(&[5000.0, 300.0, 50.0]);
        // Not guaranteed pointwise, but holds for this seed/shape; the
        // property MBO relies on is only that disagreement is non-negative
        // and usually larger away from data.
        assert!(s_in >= 0.0 && s_out >= 0.0);
    }

    #[test]
    fn ensemble_mean_tracks_target() {
        let (x, y) = synth(300, 7);
        let ens = Ensemble::fit(&x, &y, &EnsembleParams::default());
        let mut err = 0.0;
        for (xi, yi) in x.iter().zip(&y).take(50) {
            let (m, _) = ens.predict(xi);
            err += (m - yi).abs() / yi.abs().max(1e-9);
        }
        assert!(err / 50.0 < 0.1, "mean rel err {}", err / 50.0);
    }

    #[test]
    fn handles_single_point() {
        let model = Gbdt::fit(&[vec![1.0, 2.0]], &[5.0], &GbdtParams::default());
        assert!((model.predict(&[1.0, 2.0]) - 5.0).abs() < 0.5);
    }
}
