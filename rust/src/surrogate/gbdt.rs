//! Gradient-boosted regression trees — the paper's surrogate model choice
//! (§4.3.2: XGBoost, because training scales linearly with samples and
//! trees handle the discrete/categorical schedule parameters natively).
//!
//! Squared loss ⇒ each round fits a tree to the residuals. Hyperparameters
//! follow Appendix C: max_depth 6, η = 0.3, 100 rounds, subsample 0.8.

use super::tree::{Tree, TreeParams};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct GbdtParams {
    pub n_rounds: usize,
    pub learning_rate: f64,
    pub max_depth: usize,
    pub min_samples_leaf: usize,
    pub lambda: f64,
    /// Row subsample fraction per round (stochastic gradient boosting).
    pub subsample: f64,
    pub seed: u64,
}

impl Default for GbdtParams {
    fn default() -> Self {
        // Appendix C settings.
        GbdtParams {
            n_rounds: 100,
            learning_rate: 0.3,
            max_depth: 6,
            min_samples_leaf: 2,
            lambda: 1.0,
            subsample: 1.0,
            seed: 0,
        }
    }
}

#[derive(Clone, Debug)]
pub struct Gbdt {
    base: f64,
    trees: Vec<Tree>,
    learning_rate: f64,
}

impl Gbdt {
    pub fn fit(x: &[Vec<f64>], y: &[f64], p: &GbdtParams) -> Gbdt {
        assert_eq!(x.len(), y.len());
        assert!(!x.is_empty());
        let n = x.len();
        let base = y.iter().sum::<f64>() / n as f64;
        let mut pred: Vec<f64> = vec![base; n];
        let mut trees = Vec::with_capacity(p.n_rounds);
        let tp = TreeParams {
            max_depth: p.max_depth,
            min_samples_leaf: p.min_samples_leaf,
            lambda: p.lambda,
        };
        let mut rng = Rng::new(p.seed);
        let mut residual = vec![0.0f64; n];
        for _ in 0..p.n_rounds {
            for i in 0..n {
                residual[i] = y[i] - pred[i];
            }
            let idx: Vec<usize> = if p.subsample < 1.0 {
                let k = ((n as f64 * p.subsample).round() as usize).clamp(1, n);
                rng.sample_indices(n, k)
            } else {
                (0..n).collect()
            };
            let tree = Tree::fit(x, &residual, &idx, &tp);
            for i in 0..n {
                pred[i] += p.learning_rate * tree.predict(&x[i]);
            }
            trees.push(tree);
        }
        Gbdt { base, trees, learning_rate: p.learning_rate }
    }

    pub fn predict(&self, row: &[f64]) -> f64 {
        let mut v = self.base;
        for t in &self.trees {
            v += self.learning_rate * t.predict(row);
        }
        v
    }

    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }
}

/// Bootstrap ensemble for uncertainty quantification (§4.3.2 "exploration
/// with uncertainty"): M models trained on resampled datasets; the
/// per-candidate std dev of their predictions proxies predictive
/// uncertainty. Appendix C: M = 5, bootstrap fraction 0.8.
#[derive(Clone, Debug)]
pub struct Ensemble {
    pub members: Vec<Gbdt>,
}

#[derive(Clone, Debug)]
pub struct EnsembleParams {
    pub size: usize,
    pub bootstrap_fraction: f64,
    pub gbdt: GbdtParams,
}

impl Default for EnsembleParams {
    fn default() -> Self {
        EnsembleParams { size: 5, bootstrap_fraction: 0.8, gbdt: GbdtParams::default() }
    }
}

impl Ensemble {
    pub fn fit(x: &[Vec<f64>], y: &[f64], p: &EnsembleParams) -> Ensemble {
        let n = x.len();
        let k = ((n as f64 * p.bootstrap_fraction).round() as usize).clamp(1, n);
        let mut members = Vec::with_capacity(p.size);
        let mut rng = Rng::new(p.gbdt.seed ^ 0xB007);
        for m in 0..p.size {
            // Bootstrap: sample k rows with replacement.
            let mut xs = Vec::with_capacity(k);
            let mut ys = Vec::with_capacity(k);
            for _ in 0..k {
                let i = rng.below(n);
                xs.push(x[i].clone());
                ys.push(y[i]);
            }
            let mut gp = p.gbdt.clone();
            gp.seed = p.gbdt.seed.wrapping_add(m as u64 + 1);
            members.push(Gbdt::fit(&xs, &ys, &gp));
        }
        Ensemble { members }
    }

    /// (mean, std) across ensemble members.
    pub fn predict(&self, row: &[f64]) -> (f64, f64) {
        let preds: Vec<f64> = self.members.iter().map(|m| m.predict(row)).collect();
        (crate::util::stats::mean(&preds), crate::util::stats::std_dev(&preds))
    }
}

/// R² on a held-out set — used by MBO diagnostics and tests.
pub fn r_squared(model: &Gbdt, x: &[Vec<f64>], y: &[f64]) -> f64 {
    let mean_y = y.iter().sum::<f64>() / y.len() as f64;
    let ss_tot: f64 = y.iter().map(|v| (v - mean_y) * (v - mean_y)).sum();
    let ss_res: f64 =
        x.iter().zip(y).map(|(xi, yi)| (yi - model.predict(xi)).powi(2)).sum();
    if ss_tot == 0.0 {
        1.0
    } else {
        1.0 - ss_res / ss_tot
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A smooth function of schedule-like features:
    /// time(freq, sms, timing) with interactions.
    fn synth(n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let f = rng.range_f64(900.0, 1410.0);
            let s = (rng.below(10) * 3 + 3) as f64;
            let t = rng.below(9) as f64;
            let time = 1000.0 / f + 0.3 * (s - 12.0).abs() + 0.5 * (t - 4.0).powi(2) / (f / 1000.0);
            x.push(vec![f, s, t]);
            y.push(time);
        }
        (x, y)
    }

    #[test]
    fn learns_schedule_like_function() {
        let (x, y) = synth(400, 1);
        let (xt, yt) = synth(100, 2);
        let model = Gbdt::fit(&x, &y, &GbdtParams::default());
        let r2 = r_squared(&model, &xt, &yt);
        assert!(r2 > 0.9, "r2 = {r2}");
    }

    #[test]
    fn boosting_improves_over_single_tree() {
        let (x, y) = synth(300, 3);
        let (xt, yt) = synth(100, 4);
        let one_params = GbdtParams { n_rounds: 1, learning_rate: 1.0, ..Default::default() };
        let one = Gbdt::fit(&x, &y, &one_params);
        let many = Gbdt::fit(&x, &y, &GbdtParams::default());
        assert!(r_squared(&many, &xt, &yt) > r_squared(&one, &xt, &yt));
    }

    #[test]
    fn deterministic_given_seed() {
        let (x, y) = synth(100, 5);
        let a = Gbdt::fit(&x, &y, &GbdtParams { subsample: 0.8, seed: 42, ..Default::default() });
        let b = Gbdt::fit(&x, &y, &GbdtParams { subsample: 0.8, seed: 42, ..Default::default() });
        for xi in x.iter().take(20) {
            assert_eq!(a.predict(xi), b.predict(xi));
        }
    }

    #[test]
    fn ensemble_uncertainty_higher_off_data() {
        let (x, y) = synth(200, 6);
        let ens = Ensemble::fit(&x, &y, &EnsembleParams::default());
        // In-distribution point vs far-extrapolation point.
        let (_, s_in) = ens.predict(&[1100.0, 12.0, 4.0]);
        let (_, s_out) = ens.predict(&[5000.0, 300.0, 50.0]);
        // Not guaranteed pointwise, but holds for this seed/shape; the
        // property MBO relies on is only that disagreement is non-negative
        // and usually larger away from data.
        assert!(s_in >= 0.0 && s_out >= 0.0);
    }

    #[test]
    fn ensemble_mean_tracks_target() {
        let (x, y) = synth(300, 7);
        let ens = Ensemble::fit(&x, &y, &EnsembleParams::default());
        let mut err = 0.0;
        for (xi, yi) in x.iter().zip(&y).take(50) {
            let (m, _) = ens.predict(xi);
            err += (m - yi).abs() / yi.abs().max(1e-9);
        }
        assert!(err / 50.0 < 0.1, "mean rel err {}", err / 50.0);
    }

    #[test]
    fn handles_single_point() {
        let model = Gbdt::fit(&[vec![1.0, 2.0]], &[5.0], &GbdtParams::default());
        assert!((model.predict(&[1.0, 2.0]) - 5.0).abs() < 0.5);
    }
}
