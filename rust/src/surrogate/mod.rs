//! Surrogate models for MBO (§4.3.2): gradient-boosted regression trees
//! (XGBoost-like) built from scratch, plus bootstrap ensembles for the
//! uncertainty acquisition pass.

pub mod gbdt;
pub mod tree;

pub use gbdt::{r_squared, Ensemble, EnsembleParams, Gbdt, GbdtParams};
