//! Surrogate models for MBO (§4.3.2): gradient-boosted regression trees
//! (XGBoost-like) built from scratch, plus bootstrap ensembles for the
//! uncertainty acquisition pass. Training and batched prediction run over
//! column-major [`matrix::Matrix`] storage.

pub mod gbdt;
pub mod matrix;
pub mod tree;

pub use gbdt::{r_squared, Ensemble, EnsembleParams, Gbdt, GbdtParams};
pub use matrix::Matrix;
