//! Regression tree (CART, squared loss) — the weak learner for the GBDT
//! surrogate models. Exact greedy splits: the MBO feature space is tiny
//! (3–4 dimensions: frequency, SM allocation, launch timing, optionally
//! memory frequency; Appendix C), so sorting-based exact search is both
//! simplest and fastest.
//!
//! Two build paths share one packed [`FlatNode`] layout:
//!
//! * [`Tree::fit`] — the row-major reference implementation, kept
//!   verbatim for the differential parity suite;
//! * [`Tree::fit_soa`] — the hot path over a column-major
//!   [`Matrix`] with a precomputed [`SplitIndex`]. Per (node, feature)
//!   it runs one stable counting sort by value group — O(m + k) — in
//!   place of the reference's O(m log m) comparison sort, with all
//!   buffers reused through a [`FitScratch`].
//!
//! Parity is load-bearing and holds *by construction*: rows with equal
//! feature values share a dense group id and ids increase with the value,
//! so a stable counting sort by group id yields exactly the permutation a
//! stable comparison sort by value would — including tie order, which the
//! in-place Lomuto partition scrambles on the right child and the prefix
//! sums (non-associative f64 adds) depend on. `tests/surrogate_parity.rs`
//! and the in-module tests pin the two paths bitwise-equal.

use super::matrix::Matrix;

/// Sentinel feature id marking a leaf node.
const LEAF: u32 = u32::MAX;

/// Packed flat tree node, walked by index (no enum dispatch, no per-node
/// pointer chasing): internal nodes hold (feature, threshold, left,
/// right); a leaf stores its prediction in `threshold` with
/// `feature == LEAF`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FlatNode {
    pub feature: u32,
    pub threshold: f64,
    pub left: u32,
    pub right: u32,
}

impl FlatNode {
    fn leaf(value: f64) -> FlatNode {
        FlatNode { feature: LEAF, threshold: value, left: 0, right: 0 }
    }

    pub fn is_leaf(&self) -> bool {
        self.feature == LEAF
    }
}

#[derive(Clone, Debug)]
pub struct Tree {
    pub nodes: Vec<FlatNode>,
}

pub struct TreeParams {
    pub max_depth: usize,
    pub min_samples_leaf: usize,
    /// L2 regularization on leaf values (XGBoost's lambda).
    pub lambda: f64,
}

impl Default for TreeParams {
    fn default() -> Self {
        TreeParams { max_depth: 6, min_samples_leaf: 2, lambda: 1.0 }
    }
}

/// Per-feature dense value-group ids, computed once per training set and
/// shared by every node, tree, and boosting round of one fit. Rows with
/// equal feature values share a group id and ids increase with the value,
/// which is exactly what lets [`Tree::fit_soa`]'s stable counting sort
/// reproduce a stable comparison sort by value bit-for-bit.
pub struct SplitIndex {
    /// `groups[f][row]` = dense rank of the row's value in column `f`.
    groups: Vec<Vec<u32>>,
    /// Distinct values per feature (the counting-sort key range).
    n_groups: Vec<u32>,
}

impl SplitIndex {
    pub fn build(m: &Matrix) -> SplitIndex {
        let n = m.n_rows();
        let mut groups = Vec::with_capacity(m.n_cols());
        let mut n_groups = Vec::with_capacity(m.n_cols());
        let mut order: Vec<u32> = (0..n as u32).collect();
        for f in 0..m.n_cols() {
            let col = m.col(f);
            order.sort_by(|&a, &b| col[a as usize].partial_cmp(&col[b as usize]).unwrap());
            let mut g = vec![0u32; n];
            let mut gid = 0u32;
            for (w, &i) in order.iter().enumerate() {
                if w > 0 && col[i as usize] != col[order[w - 1] as usize] {
                    gid += 1;
                }
                g[i as usize] = gid;
            }
            groups.push(g);
            n_groups.push(gid + 1);
        }
        SplitIndex { groups, n_groups }
    }
}

/// Reusable buffers for [`Tree::fit_soa`], shared across nodes, trees,
/// and boosting rounds of one `Gbdt::fit` (the reference path allocates
/// an index copy per tree and a sort buffer per node).
#[derive(Default)]
pub struct FitScratch {
    idx: Vec<u32>,
    order: Vec<u32>,
    order2: Vec<u32>,
    counts: Vec<u32>,
}

impl Tree {
    /// Fit on rows `idx` of `(x, y)`. `x` is row-major: x[i] is sample i.
    /// Reference implementation — [`Tree::fit_soa`] is the hot path and
    /// must reproduce this byte-for-byte.
    pub fn fit(x: &[Vec<f64>], y: &[f64], idx: &[usize], p: &TreeParams) -> Tree {
        assert!(!idx.is_empty());
        let mut nodes = Vec::new();
        let mut idx = idx.to_vec();
        build(x, y, &mut idx, 0, p, &mut nodes);
        Tree { nodes }
    }

    /// SoA fast path: the same tree, built from the column-major matrix
    /// with the precomputed group index and reusable scratch.
    pub fn fit_soa(
        m: &Matrix,
        y: &[f64],
        idx: &[u32],
        p: &TreeParams,
        gi: &SplitIndex,
        scratch: &mut FitScratch,
    ) -> Tree {
        assert!(!idx.is_empty());
        let mut nodes = Vec::new();
        scratch.idx.clear();
        scratch.idx.extend_from_slice(idx);
        let mut idx = std::mem::take(&mut scratch.idx);
        build_soa(m, y, &mut idx, 0, p, gi, scratch, &mut nodes);
        scratch.idx = idx;
        Tree { nodes }
    }

    pub fn predict(&self, row: &[f64]) -> f64 {
        let mut i = 0usize;
        loop {
            let n = self.nodes[i];
            if n.feature == LEAF {
                return n.threshold;
            }
            i = if row[n.feature as usize] <= n.threshold {
                n.left as usize
            } else {
                n.right as usize
            };
        }
    }

    /// [`predict`](Self::predict) over row `r` of a column-major matrix
    /// (no row gather, no allocation).
    pub fn predict_row(&self, m: &Matrix, r: usize) -> f64 {
        let mut i = 0usize;
        loop {
            let n = self.nodes[i];
            if n.feature == LEAF {
                return n.threshold;
            }
            i = if m.at(r, n.feature as usize) <= n.threshold {
                n.left as usize
            } else {
                n.right as usize
            };
        }
    }

    pub fn depth(&self) -> usize {
        fn d(nodes: &[FlatNode], i: usize) -> usize {
            let n = nodes[i];
            if n.feature == LEAF {
                1
            } else {
                1 + d(nodes, n.left as usize).max(d(nodes, n.right as usize))
            }
        }
        d(&self.nodes, 0)
    }
}

/// Recursively build the subtree over `idx[..]`; returns node index.
fn build(
    x: &[Vec<f64>],
    y: &[f64],
    idx: &mut [usize],
    depth: usize,
    p: &TreeParams,
    nodes: &mut Vec<FlatNode>,
) -> usize {
    let sum: f64 = idx.iter().map(|&i| y[i]).sum();
    let n = idx.len() as f64;
    // Regularized leaf value (sum / (n + lambda), XGBoost-style shrinkage).
    let leaf_value = sum / (n + p.lambda);

    if depth >= p.max_depth || idx.len() < 2 * p.min_samples_leaf {
        nodes.push(FlatNode::leaf(leaf_value));
        return nodes.len() - 1;
    }

    match best_split(x, y, idx, p) {
        None => {
            nodes.push(FlatNode::leaf(leaf_value));
            nodes.len() - 1
        }
        Some((feature, threshold)) => {
            // Partition idx in place.
            let mut lo = 0usize;
            for i in 0..idx.len() {
                if x[idx[i]][feature] <= threshold {
                    idx.swap(i, lo);
                    lo += 1;
                }
            }
            debug_assert!(lo > 0 && lo < idx.len());
            let me = nodes.len();
            nodes.push(FlatNode::leaf(0.0)); // placeholder
            let (l_idx, r_idx) = idx.split_at_mut(lo);
            let left = build(x, y, l_idx, depth + 1, p, nodes) as u32;
            let right = build(x, y, r_idx, depth + 1, p, nodes) as u32;
            nodes[me] = FlatNode { feature: feature as u32, threshold, left, right };
            me
        }
    }
}

/// Exact greedy best split by variance reduction (squared loss gain).
fn best_split(x: &[Vec<f64>], y: &[f64], idx: &[usize], p: &TreeParams) -> Option<(usize, f64)> {
    let n_features = x[0].len();
    let total_sum: f64 = idx.iter().map(|&i| y[i]).sum();
    let n = idx.len() as f64;
    let parent_score = total_sum * total_sum / (n + p.lambda);

    let mut best: Option<(f64, usize, f64)> = None; // (gain, feat, thr)
    let mut order: Vec<usize> = idx.to_vec();
    for f in 0..n_features {
        order.sort_by(|&a, &b| x[a][f].partial_cmp(&x[b][f]).unwrap());
        let mut left_sum = 0.0;
        let mut left_n = 0.0;
        for w in 0..order.len() - 1 {
            let i = order[w];
            left_sum += y[i];
            left_n += 1.0;
            // Can't split between equal feature values.
            if x[order[w]][f] == x[order[w + 1]][f] {
                continue;
            }
            let right_sum = total_sum - left_sum;
            let right_n = n - left_n;
            if (left_n as usize) < p.min_samples_leaf || (right_n as usize) < p.min_samples_leaf {
                continue;
            }
            let score = left_sum * left_sum / (left_n + p.lambda)
                + right_sum * right_sum / (right_n + p.lambda);
            let gain = score - parent_score;
            if gain > 1e-12 && best.map(|(g, _, _)| gain > g).unwrap_or(true) {
                let thr = 0.5 * (x[order[w]][f] + x[order[w + 1]][f]);
                best = Some((gain, f, thr));
            }
        }
    }
    best.map(|(_, f, t)| (f, t))
}

/// SoA twin of [`build`]; identical recursion, partition, and leaf sums.
#[allow(clippy::too_many_arguments)]
fn build_soa(
    m: &Matrix,
    y: &[f64],
    idx: &mut [u32],
    depth: usize,
    p: &TreeParams,
    gi: &SplitIndex,
    scratch: &mut FitScratch,
    nodes: &mut Vec<FlatNode>,
) -> usize {
    let sum: f64 = idx.iter().map(|&i| y[i as usize]).sum();
    let n = idx.len() as f64;
    let leaf_value = sum / (n + p.lambda);

    if depth >= p.max_depth || idx.len() < 2 * p.min_samples_leaf {
        nodes.push(FlatNode::leaf(leaf_value));
        return nodes.len() - 1;
    }

    match best_split_soa(m, y, idx, p, gi, scratch) {
        None => {
            nodes.push(FlatNode::leaf(leaf_value));
            nodes.len() - 1
        }
        Some((feature, threshold)) => {
            let col = m.col(feature);
            let mut lo = 0usize;
            for i in 0..idx.len() {
                if col[idx[i] as usize] <= threshold {
                    idx.swap(i, lo);
                    lo += 1;
                }
            }
            debug_assert!(lo > 0 && lo < idx.len());
            let me = nodes.len();
            nodes.push(FlatNode::leaf(0.0)); // placeholder
            let (l_idx, r_idx) = idx.split_at_mut(lo);
            let left = build_soa(m, y, l_idx, depth + 1, p, gi, scratch, nodes) as u32;
            let right = build_soa(m, y, r_idx, depth + 1, p, gi, scratch, nodes) as u32;
            nodes[me] = FlatNode { feature: feature as u32, threshold, left, right };
            me
        }
    }
}

/// SoA twin of [`best_split`]: one stable counting sort by value group
/// per feature instead of a comparison sort. The reference re-sorts ONE
/// buffer feature after feature, so tie order under feature `f` follows
/// the feature `f-1` ordering — the counting sorts here read and replace
/// the same carried buffer to replicate that exactly.
fn best_split_soa(
    m: &Matrix,
    y: &[f64],
    idx: &[u32],
    p: &TreeParams,
    gi: &SplitIndex,
    scratch: &mut FitScratch,
) -> Option<(usize, f64)> {
    let total_sum: f64 = idx.iter().map(|&i| y[i as usize]).sum();
    let n = idx.len() as f64;
    let parent_score = total_sum * total_sum / (n + p.lambda);

    let mut best: Option<(f64, usize, f64)> = None; // (gain, feat, thr)
    scratch.order.clear();
    scratch.order.extend_from_slice(idx);
    for f in 0..m.n_cols() {
        let col = m.col(f);
        let grp = &gi.groups[f];
        let k = gi.n_groups[f] as usize;
        // Stable counting sort of `order` by value group into `order2`.
        scratch.counts.clear();
        scratch.counts.resize(k, 0);
        for &i in &scratch.order {
            scratch.counts[grp[i as usize] as usize] += 1;
        }
        let mut acc = 0u32;
        for c in scratch.counts.iter_mut() {
            let here = *c;
            *c = acc;
            acc += here;
        }
        scratch.order2.resize(scratch.order.len(), 0);
        for &i in &scratch.order {
            let slot = &mut scratch.counts[grp[i as usize] as usize];
            scratch.order2[*slot as usize] = i;
            *slot += 1;
        }
        std::mem::swap(&mut scratch.order, &mut scratch.order2);

        let order = &scratch.order;
        let mut left_sum = 0.0;
        let mut left_n = 0.0;
        for w in 0..order.len() - 1 {
            let i = order[w] as usize;
            left_sum += y[i];
            left_n += 1.0;
            // Can't split between equal feature values.
            if col[i] == col[order[w + 1] as usize] {
                continue;
            }
            let right_sum = total_sum - left_sum;
            let right_n = n - left_n;
            if (left_n as usize) < p.min_samples_leaf || (right_n as usize) < p.min_samples_leaf {
                continue;
            }
            let score = left_sum * left_sum / (left_n + p.lambda)
                + right_sum * right_sum / (right_n + p.lambda);
            let gain = score - parent_score;
            if gain > 1e-12 && best.map(|(g, _, _)| gain > g).unwrap_or(true) {
                let thr = 0.5 * (col[i] + col[order[w + 1] as usize]);
                best = Some((gain, f, thr));
            }
        }
    }
    best.map(|(_, f, t)| (f, t))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid_2d() -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..20 {
            for j in 0..20 {
                x.push(vec![i as f64, j as f64]);
                y.push(if i < 10 { 1.0 } else { 5.0 } + if j < 5 { 0.0 } else { 2.0 });
            }
        }
        (x, y)
    }

    fn fit_soa_of(x: &[Vec<f64>], y: &[f64], idx: &[usize], p: &TreeParams) -> Tree {
        let m = Matrix::from_rows(x);
        let gi = SplitIndex::build(&m);
        let mut scratch = FitScratch::default();
        let idx32: Vec<u32> = idx.iter().map(|&i| i as u32).collect();
        Tree::fit_soa(&m, y, &idx32, p, &gi, &mut scratch)
    }

    #[test]
    fn fits_step_function_exactly() {
        let (x, y) = grid_2d();
        let idx: Vec<usize> = (0..x.len()).collect();
        let t = Tree::fit(&x, &y, &idx, &TreeParams { lambda: 0.0, ..Default::default() });
        for (xi, yi) in x.iter().zip(&y) {
            assert!((t.predict(xi) - yi).abs() < 1e-9, "{:?} -> {}", xi, yi);
        }
    }

    #[test]
    fn respects_max_depth() {
        let (x, y) = grid_2d();
        let idx: Vec<usize> = (0..x.len()).collect();
        let t = Tree::fit(&x, &y, &idx, &TreeParams { max_depth: 2, ..Default::default() });
        assert!(t.depth() <= 3); // root + 2 levels
    }

    #[test]
    fn constant_target_single_leaf() {
        let x: Vec<Vec<f64>> = (0..10).map(|i| vec![i as f64]).collect();
        let y = vec![3.0; 10];
        let t = Tree::fit(&x, &y, &(0..10).collect::<Vec<_>>(), &TreeParams::default());
        assert_eq!(t.nodes.len(), 1);
    }

    #[test]
    fn single_sample() {
        let x = vec![vec![1.0]];
        let y = vec![7.0];
        let t = Tree::fit(&x, &y, &[0], &TreeParams { lambda: 0.0, ..Default::default() });
        assert!((t.predict(&[1.0]) - 7.0).abs() < 1e-9);
    }

    #[test]
    fn duplicate_feature_values_no_split() {
        let x = vec![vec![1.0], vec![1.0], vec![1.0], vec![1.0]];
        let y = vec![0.0, 1.0, 2.0, 3.0];
        let t = Tree::fit(&x, &y, &[0, 1, 2, 3], &TreeParams { lambda: 0.0, ..Default::default() });
        assert_eq!(t.nodes.len(), 1); // cannot split identical features
        assert!((t.predict(&[1.0]) - 1.5).abs() < 1e-9);
    }

    /// The load-bearing contract: the SoA path reproduces the reference
    /// node-for-node, bit-for-bit — on a grid dense with duplicate
    /// feature values (every tie-handling branch exercised) and from a
    /// scrambled index set (non-trivial tie order).
    #[test]
    fn soa_matches_reference_bitwise() {
        let (x, y) = grid_2d();
        let mut idx: Vec<usize> = (0..x.len()).collect();
        // Deterministic scramble so idx order ≠ row order.
        idx.reverse();
        idx.swap(3, 177);
        idx.swap(40, 202);
        for p in [
            TreeParams::default(),
            TreeParams { max_depth: 3, ..Default::default() },
            TreeParams { lambda: 0.0, min_samples_leaf: 1, ..Default::default() },
        ] {
            let a = Tree::fit(&x, &y, &idx, &p);
            let b = fit_soa_of(&x, &y, &idx, &p);
            assert_eq!(a.nodes.len(), b.nodes.len());
            for (na, nb) in a.nodes.iter().zip(&b.nodes) {
                assert_eq!(na.feature, nb.feature);
                assert_eq!(na.left, nb.left);
                assert_eq!(na.right, nb.right);
                assert_eq!(na.threshold.to_bits(), nb.threshold.to_bits());
            }
        }
    }

    #[test]
    fn soa_predict_row_matches_predict() {
        let (x, y) = grid_2d();
        let idx: Vec<usize> = (0..x.len()).collect();
        let m = Matrix::from_rows(&x);
        let t = fit_soa_of(&x, &y, &idx, &TreeParams::default());
        for (r, xi) in x.iter().enumerate() {
            assert_eq!(t.predict(xi).to_bits(), t.predict_row(&m, r).to_bits());
        }
    }

    #[test]
    fn split_index_groups_are_dense_and_ordered() {
        let m = Matrix::from_rows(&[vec![3.0], vec![1.0], vec![3.0], vec![2.0]]);
        let gi = SplitIndex::build(&m);
        assert_eq!(gi.n_groups, vec![3]);
        assert_eq!(gi.groups[0], vec![2, 0, 2, 1]);
    }
}
