//! Regression tree (CART, squared loss) — the weak learner for the GBDT
//! surrogate models. Exact greedy splits: the MBO feature space is tiny
//! (3 dimensions: frequency, SM allocation, launch timing; Appendix C),
//! so sorting-based exact search is both simplest and fastest.

/// Flattened tree: internal nodes hold (feature, threshold, left, right);
/// leaves hold a prediction value.
#[derive(Clone, Debug)]
pub enum Node {
    Split { feature: usize, threshold: f64, left: usize, right: usize },
    Leaf { value: f64 },
}

#[derive(Clone, Debug)]
pub struct Tree {
    pub nodes: Vec<Node>,
}

pub struct TreeParams {
    pub max_depth: usize,
    pub min_samples_leaf: usize,
    /// L2 regularization on leaf values (XGBoost's lambda).
    pub lambda: f64,
}

impl Default for TreeParams {
    fn default() -> Self {
        TreeParams { max_depth: 6, min_samples_leaf: 2, lambda: 1.0 }
    }
}

impl Tree {
    /// Fit on rows `idx` of `(x, y)`. `x` is row-major: x[i] is sample i.
    pub fn fit(x: &[Vec<f64>], y: &[f64], idx: &[usize], p: &TreeParams) -> Tree {
        assert!(!idx.is_empty());
        let mut nodes = Vec::new();
        let mut idx = idx.to_vec();
        build(x, y, &mut idx, 0, p, &mut nodes);
        Tree { nodes }
    }

    pub fn predict(&self, row: &[f64]) -> f64 {
        let mut i = 0usize;
        loop {
            match &self.nodes[i] {
                Node::Leaf { value } => return *value,
                Node::Split { feature, threshold, left, right } => {
                    i = if row[*feature] <= *threshold { *left } else { *right };
                }
            }
        }
    }

    pub fn depth(&self) -> usize {
        fn d(nodes: &[Node], i: usize) -> usize {
            match &nodes[i] {
                Node::Leaf { .. } => 1,
                Node::Split { left, right, .. } => 1 + d(nodes, *left).max(d(nodes, *right)),
            }
        }
        d(&self.nodes, 0)
    }
}

/// Recursively build the subtree over `idx[..]`; returns node index.
fn build(
    x: &[Vec<f64>],
    y: &[f64],
    idx: &mut [usize],
    depth: usize,
    p: &TreeParams,
    nodes: &mut Vec<Node>,
) -> usize {
    let sum: f64 = idx.iter().map(|&i| y[i]).sum();
    let n = idx.len() as f64;
    // Regularized leaf value (sum / (n + lambda), XGBoost-style shrinkage).
    let leaf_value = sum / (n + p.lambda);

    if depth >= p.max_depth || idx.len() < 2 * p.min_samples_leaf {
        nodes.push(Node::Leaf { value: leaf_value });
        return nodes.len() - 1;
    }

    match best_split(x, y, idx, p) {
        None => {
            nodes.push(Node::Leaf { value: leaf_value });
            nodes.len() - 1
        }
        Some((feature, threshold)) => {
            // Partition idx in place.
            let mut lo = 0usize;
            for i in 0..idx.len() {
                if x[idx[i]][feature] <= threshold {
                    idx.swap(i, lo);
                    lo += 1;
                }
            }
            debug_assert!(lo > 0 && lo < idx.len());
            let me = nodes.len();
            nodes.push(Node::Leaf { value: 0.0 }); // placeholder
            let (l_idx, r_idx) = idx.split_at_mut(lo);
            let left = build(x, y, l_idx, depth + 1, p, nodes);
            let right = build(x, y, r_idx, depth + 1, p, nodes);
            nodes[me] = Node::Split { feature, threshold, left, right };
            me
        }
    }
}

/// Exact greedy best split by variance reduction (squared loss gain).
fn best_split(x: &[Vec<f64>], y: &[f64], idx: &[usize], p: &TreeParams) -> Option<(usize, f64)> {
    let n_features = x[0].len();
    let total_sum: f64 = idx.iter().map(|&i| y[i]).sum();
    let n = idx.len() as f64;
    let parent_score = total_sum * total_sum / (n + p.lambda);

    let mut best: Option<(f64, usize, f64)> = None; // (gain, feat, thr)
    let mut order: Vec<usize> = idx.to_vec();
    for f in 0..n_features {
        order.sort_by(|&a, &b| x[a][f].partial_cmp(&x[b][f]).unwrap());
        let mut left_sum = 0.0;
        let mut left_n = 0.0;
        for w in 0..order.len() - 1 {
            let i = order[w];
            left_sum += y[i];
            left_n += 1.0;
            // Can't split between equal feature values.
            if x[order[w]][f] == x[order[w + 1]][f] {
                continue;
            }
            let right_sum = total_sum - left_sum;
            let right_n = n - left_n;
            if (left_n as usize) < p.min_samples_leaf || (right_n as usize) < p.min_samples_leaf {
                continue;
            }
            let score = left_sum * left_sum / (left_n + p.lambda)
                + right_sum * right_sum / (right_n + p.lambda);
            let gain = score - parent_score;
            if gain > 1e-12 && best.map(|(g, _, _)| gain > g).unwrap_or(true) {
                let thr = 0.5 * (x[order[w]][f] + x[order[w + 1]][f]);
                best = Some((gain, f, thr));
            }
        }
    }
    best.map(|(_, f, t)| (f, t))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid_2d() -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..20 {
            for j in 0..20 {
                x.push(vec![i as f64, j as f64]);
                y.push(if i < 10 { 1.0 } else { 5.0 } + if j < 5 { 0.0 } else { 2.0 });
            }
        }
        (x, y)
    }

    #[test]
    fn fits_step_function_exactly() {
        let (x, y) = grid_2d();
        let idx: Vec<usize> = (0..x.len()).collect();
        let t = Tree::fit(&x, &y, &idx, &TreeParams { lambda: 0.0, ..Default::default() });
        for (xi, yi) in x.iter().zip(&y) {
            assert!((t.predict(xi) - yi).abs() < 1e-9, "{:?} -> {}", xi, yi);
        }
    }

    #[test]
    fn respects_max_depth() {
        let (x, y) = grid_2d();
        let idx: Vec<usize> = (0..x.len()).collect();
        let t = Tree::fit(&x, &y, &idx, &TreeParams { max_depth: 2, ..Default::default() });
        assert!(t.depth() <= 3); // root + 2 levels
    }

    #[test]
    fn constant_target_single_leaf() {
        let x: Vec<Vec<f64>> = (0..10).map(|i| vec![i as f64]).collect();
        let y = vec![3.0; 10];
        let t = Tree::fit(&x, &y, &(0..10).collect::<Vec<_>>(), &TreeParams::default());
        assert_eq!(t.nodes.len(), 1);
    }

    #[test]
    fn single_sample() {
        let x = vec![vec![1.0]];
        let y = vec![7.0];
        let t = Tree::fit(&x, &y, &[0], &TreeParams { lambda: 0.0, ..Default::default() });
        assert!((t.predict(&[1.0]) - 7.0).abs() < 1e-9);
    }

    #[test]
    fn duplicate_feature_values_no_split() {
        let x = vec![vec![1.0], vec![1.0], vec![1.0], vec![1.0]];
        let y = vec![0.0, 1.0, 2.0, 3.0];
        let t = Tree::fit(&x, &y, &[0, 1, 2, 3], &TreeParams { lambda: 0.0, ..Default::default() });
        assert_eq!(t.nodes.len(), 1); // cannot split identical features
        assert!((t.predict(&[1.0]) - 1.5).abs() < 1e-9);
    }
}
