//! End-to-end trainer: drives real numerical training through the AOT
//! train-step artifact while the Kareus-selected execution schedule drives
//! the simulated time/energy accounting per step.
//!
//! This is the integration point that proves all three layers compose:
//! L1 Pallas kernels (inside the artifact's HLO), L2 JAX model (the
//! artifact), L3 Rust coordination (this module + the optimizer stack).

use anyhow::{anyhow, Result};

use crate::runtime::Runtime;
use crate::util::rng::Rng;

/// Synthetic-but-learnable token stream: next = (cur·31 + 17) mod V with
/// a random start per row (mirrors python/tests/test_model.py). The model
/// can drive loss toward 0; pure-random tokens would plateau at ln(V).
pub fn synthetic_tokens(rng: &mut Rng, batch: usize, seq_plus1: usize, vocab: usize) -> Vec<i32> {
    let mut out = vec![0i32; batch * seq_plus1];
    for b in 0..batch {
        let mut tok = rng.below(vocab) as i64;
        out[b * seq_plus1] = tok as i32;
        for t in 1..seq_plus1 {
            tok = (tok * 31 + 17) % vocab as i64;
            out[b * seq_plus1 + t] = tok as i32;
        }
    }
    out
}

/// Per-step record of the training run.
#[derive(Clone, Copy, Debug)]
pub struct StepLog {
    pub step: u32,
    pub loss: f32,
    pub wall_s: f64,
    /// Simulated iteration time/energy of the deployed schedule.
    pub sim_time_s: f64,
    pub sim_energy_j: f64,
}

/// Simulated accounting plugged in by the coordinator, derived from the
/// typed deployment plan: iteration (time, energy) plus the deployed
/// frequency span of the schedule the run executes under.
#[derive(Clone, Copy, Debug)]
pub struct ScheduleAccounting {
    pub label: &'static str,
    pub iter_time_s: f64,
    pub iter_energy_j: f64,
    /// (min, max) deployed core frequency across the plan's slots
    /// (`(0, 0)` when no slot information is available).
    pub freq_span_mhz: (u32, u32),
}

pub struct Trainer {
    pub runtime: Runtime,
    pub config_name: String,
    state: Vec<xla::Literal>,
    step_counter: xla::Literal,
    rng: Rng,
    batch: usize,
    seq_plus1: usize,
    vocab: usize,
}

impl Trainer {
    /// Initialize parameters on-device via the `init_<cfg>` artifact and
    /// zero optimizer moments.
    pub fn new(mut runtime: Runtime, config_name: &str, seed: u64) -> Result<Trainer> {
        let info = runtime
            .manifest
            .configs
            .get(config_name)
            .ok_or_else(|| anyhow!("unknown config {config_name} in manifest"))?
            .clone();
        let init_name = format!("init_{config_name}");
        let seed_lit = xla::Literal::scalar(seed as u32);
        let params = runtime.execute(&init_name, &[seed_lit])?;

        // Optimizer state: zeros shaped like the parameters.
        let mut state = Vec::with_capacity(3 * params.len());
        let zeros: Vec<xla::Literal> = params
            .iter()
            .map(|p| {
                let shape = p.array_shape().expect("param shape");
                let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
                xla::Literal::create_from_shape(xla::PrimitiveType::F32, &dims)
            })
            .collect();
        let zeros2: Vec<xla::Literal> = zeros
            .iter()
            .map(|z| {
                let dims: Vec<usize> =
                    z.array_shape().unwrap().dims().iter().map(|&d| d as usize).collect();
                xla::Literal::create_from_shape(xla::PrimitiveType::F32, &dims)
            })
            .collect();
        state.extend(params);
        state.extend(zeros);
        state.extend(zeros2);

        Ok(Trainer {
            runtime,
            config_name: config_name.to_string(),
            state,
            step_counter: xla::Literal::scalar(0i32),
            rng: Rng::new(seed ^ 0xDA7A),
            batch: info.batch,
            seq_plus1: info.seq_len + 1,
            vocab: info.vocab,
        })
    }

    pub fn n_state(&self) -> usize {
        self.state.len()
    }

    /// Run one training step; returns the loss.
    pub fn step(&mut self) -> Result<f32> {
        let toks = synthetic_tokens(&mut self.rng, self.batch, self.seq_plus1, self.vocab);
        let tok_lit = xla::Literal::vec1(&toks)
            .reshape(&[self.batch as i64, self.seq_plus1 as i64])
            .map_err(|e| anyhow!("reshape tokens: {e:?}"))?;

        let mut args: Vec<xla::Literal> = Vec::with_capacity(self.state.len() + 2);
        args.append(&mut self.state);
        args.push(std::mem::replace(&mut self.step_counter, xla::Literal::scalar(0i32)));
        args.push(tok_lit);

        let step_name = format!("train_step_{}", self.config_name);
        let mut outs = self.runtime.execute(&step_name, &args)?;
        // outputs: [loss, state..., step]
        let loss = outs[0].get_first_element::<f32>().map_err(|e| anyhow!("loss: {e:?}"))?;
        self.step_counter = outs.pop().ok_or_else(|| anyhow!("missing step output"))?;
        self.state = outs.split_off(1);
        Ok(loss)
    }

    /// Train for `steps` steps with schedule-driven energy accounting.
    pub fn train(
        &mut self,
        steps: u32,
        accounting: &ScheduleAccounting,
        log_every: u32,
    ) -> Result<Vec<StepLog>> {
        let mut logs = Vec::new();
        for s in 0..steps {
            let t0 = std::time::Instant::now();
            let loss = self.step()?;
            let wall = t0.elapsed().as_secs_f64();
            if s % log_every.max(1) == 0 || s + 1 == steps {
                let log = StepLog {
                    step: s,
                    loss,
                    wall_s: wall,
                    sim_time_s: accounting.iter_time_s,
                    sim_energy_j: accounting.iter_energy_j,
                };
                // Progress goes to stderr: stdout is reserved for artifact
                // JSON across every subcommand (srclint: stdout rule).
                eprintln!(
                    "step {:4}  loss {:.4}  wall {:.2}s  | sched[{}] iter {:.3}s {:.0}J {}-{} MHz",
                    s,
                    loss,
                    wall,
                    accounting.label,
                    accounting.iter_time_s,
                    accounting.iter_energy_j,
                    accounting.freq_span_mhz.0,
                    accounting.freq_span_mhz.1
                );
                logs.push(log);
            }
        }
        Ok(logs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_tokens_in_range_and_learnable() {
        let mut rng = Rng::new(0);
        let toks = synthetic_tokens(&mut rng, 4, 65, 64);
        assert_eq!(toks.len(), 4 * 65);
        assert!(toks.iter().all(|&t| (0..64).contains(&t)));
        // Deterministic transition: same current token -> same next token.
        for b in 0..4 {
            for t in 0..64 {
                let cur = toks[b * 65 + t] as i64;
                let next = toks[b * 65 + t + 1] as i64;
                assert_eq!(next, (cur * 31 + 17) % 64);
            }
        }
    }

    #[test]
    fn e2e_tiny_training_loss_decreases() {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::new(&dir).unwrap();
        let mut tr = Trainer::new(rt, "tiny", 0).unwrap();
        let acct = ScheduleAccounting {
            label: "test",
            iter_time_s: 0.0,
            iter_energy_j: 0.0,
            freq_span_mhz: (1410, 1410),
        };
        let logs = tr.train(30, &acct, 100).unwrap();
        let first = logs.first().unwrap().loss;
        let last = logs.last().unwrap().loss;
        assert!(
            last < first * 0.7,
            "no convergence: {first} -> {last}"
        );
    }
}
