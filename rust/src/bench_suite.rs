//! The `kareus bench` suite: one [`BenchEntry`] per optimizer-stack hot
//! path, over fixed synthetic inputs so every counter is derivable by
//! hand. Counters describe the *work shape* (rows, kernels, slots, cache
//! hits) and are identical on every run; wall-clock stats come from
//! [`bench_quiet`] and are nulled in deterministic mode, where each
//! workload runs exactly once untimed. CI diffs two deterministic runs
//! byte-for-byte and validates the artifact with `kareus check`
//! (K080–K082).

use std::collections::BTreeMap;
use std::hint::black_box;

use crate::backend::SimBackend;
use crate::compose::{MbFrontier, MbPoint, MicrobatchPlan};
use crate::mbo::space;
use crate::partition::Partition;
use crate::pipeline::{greedy_fill, simulate_1f1b, StageMenu};
use crate::profiler::{combine_fp, MeasureCache};
use crate::sim::exec::{execute_partition, KernelFreqs, LaunchAt, Schedule};
use crate::sim::gpu::GpuSpec;
use crate::sim::kernel::{Kernel, KernelKind};
use crate::surrogate::{Ensemble, EnsembleParams, Gbdt, GbdtParams};
use crate::util::bench::{bench_quiet, wall_time, BenchEntry, BenchReport};
use crate::util::pool::WorkerPool;
use crate::util::rng::Rng;

/// Synthetic attention-like partition: three computation kernels plus an
/// AllReduce (the standard fixture shape used across the test suite).
fn bench_partition() -> Partition {
    Partition {
        ptype: "bench/attn".into(),
        comps: vec![
            Kernel::comp("norm", KernelKind::Norm, 1e8, 8e8),
            Kernel::comp("linear1", KernelKind::Linear, 4e11, 2e9),
            Kernel::comp("linear2", KernelKind::Linear, 4e11, 2e9),
        ],
        comm: Some(Kernel::comm("ar", KernelKind::AllReduce, 4e8)),
        count: 28,
    }
}

/// Compute → memory → compute kernel sequence: under a per-class
/// schedule the executor must charge exactly two frequency transitions.
fn per_class_partition() -> Partition {
    Partition {
        ptype: "bench/kdvfs".into(),
        comps: vec![
            Kernel::comp("linear1", KernelKind::Linear, 3e11, 1e9),
            Kernel::comp("fused", KernelKind::Grouped, 2e11, 2e9),
            Kernel::comp("linear2", KernelKind::Linear, 3e11, 1e9),
        ],
        comm: None,
        count: 28,
    }
}

/// Same schedule-like synthetic regression set the surrogate tests use:
/// 150 rows × 3 features, fixed seed.
fn synth_dataset() -> (Vec<Vec<f64>>, Vec<f64>) {
    let mut rng = Rng::new(1);
    let mut x = Vec::new();
    let mut y = Vec::new();
    for _ in 0..150 {
        let f = rng.range_f64(900.0, 1410.0);
        let s = (rng.below(10) * 3 + 3) as f64;
        let t = rng.below(9) as f64;
        let time = 1000.0 / f + 0.3 * (s - 12.0).abs() + 0.5 * (t - 4.0).powi(2) / (f / 1000.0);
        x.push(vec![f, s, t]);
        y.push(time);
    }
    (x, y)
}

/// An 18-point stage menu (both directions identical) for the 1F1B
/// entries — same shape as the `hot_paths` bench target.
fn bench_menus(n_stages: usize) -> Vec<StageMenu> {
    let mk = || {
        let f = MbFrontier::from_points(
            (0..18)
                .map(|i| MbPoint {
                    time_s: 0.1 + 0.004 * i as f64,
                    total_j: 60.0 - 1.2 * i as f64,
                    dyn_j: 40.0 - i as f64,
                    plan: MicrobatchPlan {
                        freq_mhz: 1410,
                        configs: Default::default(),
                        sequential: true,
                    },
                })
                .collect(),
        );
        StageMenu::from_frontiers(&f, &f)
    };
    (0..n_stages).map(|_| mk()).collect()
}

fn counters(pairs: &[(&str, u64)]) -> BTreeMap<String, u64> {
    pairs.iter().map(|&(k, v)| (k.to_string(), v)).collect()
}

fn push_entry<F: FnMut()>(
    entries: &mut BTreeMap<String, BenchEntry>,
    deterministic: bool,
    name: &str,
    budget_s: f64,
    c: BTreeMap<String, u64>,
    f: F,
) {
    let e = if deterministic {
        // The workload already ran exactly once while deriving counters.
        BenchEntry::deterministic(c)
    } else {
        BenchEntry::timed(&bench_quiet(name, budget_s, f), c)
    };
    entries.insert(name.to_string(), e);
}

/// Run the whole suite. `budget_scale` multiplies every entry's timing
/// budget (ignored in deterministic mode, where nothing is timed).
pub fn run(deterministic: bool, budget_scale: f64) -> BenchReport {
    let (report, wall) = wall_time(|| run_entries(deterministic, budget_scale));
    BenchReport {
        deterministic,
        entries: report,
        wall_s: if deterministic { None } else { Some(wall) },
    }
}

fn run_entries(deterministic: bool, budget_scale: f64) -> BTreeMap<String, BenchEntry> {
    let mut entries = BTreeMap::new();
    let scale = |s: f64| s * budget_scale;
    let gpu = GpuSpec::a100();
    let part = bench_partition();

    // 1. The schedule executor — 10^5–10^6 calls per MBO sweep.
    let ovl = Schedule::uniform(12, LaunchAt::WithComp(1), 1200);
    let r = execute_partition(&gpu, &part.comps, part.comm.as_ref(), &ovl, 30.0, Some(gpu.tdp_w));
    push_entry(
        &mut entries,
        deterministic,
        "exec_overlapped",
        scale(0.2),
        counters(&[
            ("kernels", part.comps.len() as u64),
            ("freq_transitions", r.freq_transitions as u64),
        ]),
        || {
            black_box(execute_partition(
                &gpu,
                &part.comps,
                part.comm.as_ref(),
                &ovl,
                30.0,
                Some(gpu.tdp_w),
            ));
        },
    );

    let seq = Schedule::sequential(1200);
    let r = execute_partition(&gpu, &part.comps, part.comm.as_ref(), &seq, 30.0, Some(gpu.tdp_w));
    push_entry(
        &mut entries,
        deterministic,
        "exec_sequential",
        scale(0.2),
        counters(&[
            ("kernels", part.comps.len() as u64),
            ("freq_transitions", r.freq_transitions as u64),
        ]),
        || {
            black_box(execute_partition(
                &gpu,
                &part.comps,
                part.comm.as_ref(),
                &seq,
                30.0,
                Some(gpu.tdp_w),
            ));
        },
    );

    let kd = per_class_partition();
    let split = Schedule {
        comm_sms: 0,
        launch: LaunchAt::Sequential,
        freq_mhz: 1410,
        kernel_freqs: KernelFreqs::PerClass { compute_mhz: 1410, memory_mhz: 1110 },
    };
    let r = execute_partition(&gpu, &kd.comps, None, &split, 30.0, Some(gpu.tdp_w));
    push_entry(
        &mut entries,
        deterministic,
        "exec_per_class",
        scale(0.2),
        counters(&[
            ("kernels", kd.comps.len() as u64),
            ("freq_transitions", r.freq_transitions as u64),
        ]),
        || {
            black_box(execute_partition(&gpu, &kd.comps, None, &split, 30.0, Some(gpu.tdp_w)));
        },
    );

    // 2. Candidate-space enumeration (no-comm partition: one candidate
    //    per search frequency).
    let space_len = space::candidate_space(&gpu, &kd, 8).len();
    push_entry(
        &mut entries,
        deterministic,
        "candidate_space",
        scale(0.1),
        counters(&[("candidates", space_len as u64)]),
        || {
            black_box(space::candidate_space(&gpu, &kd, 8));
        },
    );

    // 3. Surrogate: SoA training and batched prediction.
    let (x, y) = synth_dataset();
    let params = GbdtParams::default();
    let model = Gbdt::fit(&x, &y, &params);
    push_entry(
        &mut entries,
        deterministic,
        "surrogate_fit",
        scale(0.5),
        counters(&[
            ("rows", x.len() as u64),
            ("features", x[0].len() as u64),
            ("rounds", params.n_rounds as u64),
            ("trees", model.n_trees() as u64),
        ]),
        || {
            black_box(Gbdt::fit(&x, &y, &params));
        },
    );

    let mut batch = Vec::new();
    model.predict_batch(&x, &mut batch);
    push_entry(
        &mut entries,
        deterministic,
        "surrogate_predict_batch",
        scale(0.2),
        counters(&[("rows", x.len() as u64), ("trees", model.n_trees() as u64)]),
        || {
            model.predict_batch(&x, &mut batch);
            black_box(&batch);
        },
    );

    let ep = EnsembleParams::default();
    let ens = Ensemble::fit(&x, &y, &ep);
    let mut ens_batch = Vec::new();
    ens.predict_batch(&x, &mut ens_batch);
    push_entry(
        &mut entries,
        deterministic,
        "ensemble_predict_batch",
        scale(0.2),
        counters(&[("rows", x.len() as u64), ("members", ens.members.len() as u64)]),
        || {
            ens.predict_batch(&x, &mut ens_batch);
            black_box(&ens_batch);
        },
    );

    // 4. 1F1B simulation + Perseus greedy fill.
    let (n_stages, n_mb) = (2usize, 8usize);
    let menus = bench_menus(n_stages);
    let choice = vec![vec![0usize; 2 * n_mb]; n_stages];
    black_box(simulate_1f1b(&menus, &choice, n_mb));
    push_entry(
        &mut entries,
        deterministic,
        "simulate_1f1b",
        scale(0.2),
        counters(&[
            ("stages", n_stages as u64),
            ("microbatches", n_mb as u64),
            ("tasks", (n_stages * 2 * n_mb) as u64),
        ]),
        || {
            black_box(simulate_1f1b(&menus, &choice, n_mb));
        },
    );

    black_box(greedy_fill(&menus, n_mb, 90.0, 2.0));
    push_entry(
        &mut entries,
        deterministic,
        "greedy_fill",
        scale(0.5),
        counters(&[
            ("stages", n_stages as u64),
            ("microbatches", n_mb as u64),
            ("slots", (n_stages * 2 * n_mb) as u64),
        ]),
        || {
            black_box(greedy_fill(&menus, n_mb, 90.0, 2.0));
        },
    );

    // 5. Measurement cache: same canonical execution probed twice —
    //    exactly one miss then one hit per fresh cache.
    let backend = SimBackend;
    let fp = combine_fp(gpu.fingerprint(), part.fingerprint());
    let probe_twice = || {
        let cache = MeasureCache::new();
        for _ in 0..2 {
            black_box(cache.exec(
                &backend,
                fp,
                &gpu,
                &part.comps,
                part.comm.as_ref(),
                &ovl,
                30.0,
                Some(gpu.tdp_w),
            ));
        }
        cache
    };
    let cache = probe_twice();
    push_entry(
        &mut entries,
        deterministic,
        "measure_cache",
        scale(0.2),
        counters(&[("hits", cache.hits()), ("misses", cache.misses())]),
        || {
            black_box(probe_twice());
        },
    );

    // 6. Chunked pool dispatch: 64 items in chunks of 8 on 2 workers.
    let pool = WorkerPool::new(2);
    let items: Vec<u64> = (0..64).collect();
    let out = pool.map_chunked(items.clone(), 8, |v| v * v);
    push_entry(
        &mut entries,
        deterministic,
        "pool_map_chunked",
        scale(0.2),
        counters(&[
            ("items", out.len() as u64),
            ("chunks", out.len().div_ceil(8) as u64),
            ("threads", pool.size() as u64),
        ]),
        move || {
            black_box(pool.map_chunked(items.clone(), 8, |v| v * v));
        },
    );

    entries
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_run_is_byte_identical() {
        let a = run(true, 0.0);
        let b = run(true, 0.0);
        assert_eq!(a.to_json().try_dump().unwrap(), b.to_json().try_dump().unwrap());
        assert!(a.deterministic && a.wall_s.is_none());
        for (name, e) in &a.entries {
            assert!(e.iters.is_none(), "{name} timed in deterministic mode");
            assert!(e.min_ns.is_none() && e.median_ns.is_none() && e.mean_ns.is_none());
            assert!(!e.counters.is_empty(), "{name} has no counters");
        }
    }

    #[test]
    fn counters_match_structure() {
        let rep = run(true, 0.0);
        let c = |name: &str, key: &str| rep.entries[name].counters[key];
        assert_eq!(c("exec_overlapped", "kernels"), 3);
        assert_eq!(c("exec_overlapped", "freq_transitions"), 0);
        assert_eq!(c("exec_sequential", "freq_transitions"), 0);
        assert_eq!(c("exec_per_class", "freq_transitions"), 2);
        assert_eq!(
            c("candidate_space", "candidates"),
            GpuSpec::a100().search_freqs().len() as u64
        );
        assert_eq!(c("surrogate_fit", "rows"), 150);
        assert_eq!(c("surrogate_fit", "trees"), 100);
        assert_eq!(c("ensemble_predict_batch", "members"), 5);
        assert_eq!(c("simulate_1f1b", "tasks"), 32);
        assert_eq!(c("greedy_fill", "slots"), 32);
        assert_eq!(c("measure_cache", "hits"), 1);
        assert_eq!(c("measure_cache", "misses"), 1);
        assert_eq!(c("pool_map_chunked", "items"), 64);
        assert_eq!(c("pool_map_chunked", "chunks"), 8);
    }

    #[test]
    fn timed_run_populates_wall_fields() {
        let rep = run(false, 0.01);
        assert!(!rep.deterministic);
        assert!(rep.wall_s.unwrap() > 0.0);
        for (name, e) in &rep.entries {
            assert!(e.iters.unwrap() >= 3, "{name}");
            assert!(e.min_ns.unwrap() > 0.0, "{name}");
        }
    }
}
