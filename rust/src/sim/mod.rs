//! GPU execution & energy simulator — the substitute for the paper's
//! 16×A100 testbed (see DESIGN.md §1 for the substitution argument).

pub mod exec;
pub mod gpu;
pub mod kernel;
pub mod meter;
pub mod thermal;

pub use exec::{
    execute_partition, execute_partition_with, ExecResult, ExecScratch, LaunchAt, Schedule,
};
pub use gpu::GpuSpec;
pub use kernel::{Kernel, KernelKind};
