//! Kernel resource-demand model.
//!
//! A kernel is characterized by the *work* it performs: FLOPs, HBM bytes
//! moved, and interconnect bytes (for communication kernels). §3.1 of the
//! paper: total work is schedule-invariant; schedules change *when/where*
//! it runs and therefore time and static energy.

/// Operator classes appearing in the paper's figures (Figure 3, Figure 10).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KernelKind {
    Norm,
    Linear,
    Rope,
    FlashAttention,
    Activation,
    BiasDropoutAdd,
    Embedding,
    GradAccum,
    AllReduce,
    AllGather,
    ReduceScatter,
    SendRecv,
    /// Short memory-bound computations grouped into one logical op (§4.5).
    Grouped,
}

/// Coarse kernel classes for per-class frequency assignment (kernel-level
/// DVFS). The class partitions [`KernelKind`] by which resource dominates
/// the kernel's roofline at training shapes: `Compute` kernels ride the
/// FLOP ceiling (frequency-sensitive in both time and energy), `Memory`
/// kernels ride the HBM ceiling (frequency lowers only dynamic compute
/// energy), and `Comm` kernels ride the interconnect (core frequency is
/// irrelevant to both time and power).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KernelClass {
    Compute,
    Memory,
    Comm,
}

impl KernelKind {
    pub fn is_comm(self) -> bool {
        matches!(
            self,
            KernelKind::AllReduce
                | KernelKind::AllGather
                | KernelKind::ReduceScatter
                | KernelKind::SendRecv
        )
    }

    /// The [`KernelClass`] a kernel of this kind belongs to. Static by
    /// kind (not by shape): the per-class frequency axis needs a stable
    /// partition of the kernel stream that deployment can reproduce
    /// without re-deriving rooflines.
    pub fn class(self) -> KernelClass {
        match self {
            KernelKind::Linear | KernelKind::FlashAttention => KernelClass::Compute,
            KernelKind::Norm
            | KernelKind::Rope
            | KernelKind::Activation
            | KernelKind::BiasDropoutAdd
            | KernelKind::Embedding
            | KernelKind::GradAccum
            | KernelKind::Grouped => KernelClass::Memory,
            KernelKind::AllReduce
            | KernelKind::AllGather
            | KernelKind::ReduceScatter
            | KernelKind::SendRecv => KernelClass::Comm,
        }
    }
}

/// One kernel's total resource demand.
#[derive(Clone, Debug)]
pub struct Kernel {
    pub name: String,
    pub kind: KernelKind,
    /// Floating-point operations (0 for pure comm).
    pub flops: f64,
    /// HBM traffic in bytes (reads + writes). Communication kernels also
    /// touch HBM: ring collectives read and write each chunk.
    pub bytes: f64,
    /// Interconnect traffic in bytes (0 for computation kernels).
    pub comm_bytes: f64,
}

impl Kernel {
    pub fn comp(name: impl Into<String>, kind: KernelKind, flops: f64, bytes: f64) -> Self {
        debug_assert!(!kind.is_comm());
        Kernel { name: name.into(), kind, flops, bytes, comm_bytes: 0.0 }
    }

    pub fn comm(name: impl Into<String>, kind: KernelKind, comm_bytes: f64) -> Self {
        debug_assert!(kind.is_comm());
        // Ring collectives stream every chunk through HBM once in and once
        // out; model HBM traffic as 2× the wire traffic.
        Kernel { name: name.into(), kind, flops: 0.0, bytes: 2.0 * comm_bytes, comm_bytes }
    }

    pub fn is_comm(&self) -> bool {
        self.kind.is_comm()
    }

    /// Arithmetic intensity (FLOPs per HBM byte). The roofline ridge point
    /// at frequency f sits at n_sms·c·f / mem_bw; kernels below it are
    /// memory-bound (§3.2.2).
    pub fn intensity(&self) -> f64 {
        if self.bytes <= 0.0 {
            f64::INFINITY
        } else {
            self.flops / self.bytes
        }
    }

    /// Whether this kernel is memory-bound on `gpu` at frequency `f_mhz`
    /// when given `sms` SMs.
    pub fn memory_bound(&self, gpu: &super::gpu::GpuSpec, sms: u32, f_mhz: u32) -> bool {
        if self.is_comm() {
            return true;
        }
        let t_comp = self.flops / gpu.flop_rate(sms, f_mhz);
        let t_mem = self.bytes / gpu.mem_bw;
        t_mem > t_comp
    }

    /// Merge consecutive short memory-bound kernels into one logical op
    /// (§4.5 "short consecutive memory-bound computations").
    pub fn group(kernels: &[Kernel]) -> Kernel {
        assert!(!kernels.is_empty());
        Kernel {
            name: kernels.iter().map(|k| k.name.as_str()).collect::<Vec<_>>().join("+"),
            kind: if kernels.len() == 1 { kernels[0].kind } else { KernelKind::Grouped },
            flops: kernels.iter().map(|k| k.flops).sum(),
            bytes: kernels.iter().map(|k| k.bytes).sum(),
            comm_bytes: kernels.iter().map(|k| k.comm_bytes).sum(),
        }
    }

    /// Fuse consecutive communication kernels into one (§4.5 "multiple
    /// communication kernels" — e.g. per-layer AllGathers under context
    /// parallelism share one SM allocation).
    pub fn fuse_comm(kernels: &[Kernel]) -> Kernel {
        assert!(kernels.iter().all(|k| k.is_comm()));
        let total: f64 = kernels.iter().map(|k| k.comm_bytes).sum();
        let mut k = Kernel::comm(
            kernels.iter().map(|k| k.name.as_str()).collect::<Vec<_>>().join("+"),
            kernels[0].kind,
            total,
        );
        if kernels.len() > 1 {
            // Fusing removes per-kernel launch overhead; nothing else changes.
            k.name.push_str("(fused)");
        }
        k
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::gpu::GpuSpec;

    #[test]
    fn class_partitions_every_kind() {
        // comm kinds are exactly the Comm class; compute-heavy GEMM-like
        // kinds are Compute; everything else is Memory.
        for k in [
            KernelKind::Norm,
            KernelKind::Linear,
            KernelKind::Rope,
            KernelKind::FlashAttention,
            KernelKind::Activation,
            KernelKind::BiasDropoutAdd,
            KernelKind::Embedding,
            KernelKind::GradAccum,
            KernelKind::AllReduce,
            KernelKind::AllGather,
            KernelKind::ReduceScatter,
            KernelKind::SendRecv,
            KernelKind::Grouped,
        ] {
            assert_eq!(k.class() == KernelClass::Comm, k.is_comm(), "{k:?}");
        }
        assert_eq!(KernelKind::Linear.class(), KernelClass::Compute);
        assert_eq!(KernelKind::FlashAttention.class(), KernelClass::Compute);
        assert_eq!(KernelKind::Norm.class(), KernelClass::Memory);
        assert_eq!(KernelKind::Grouped.class(), KernelClass::Memory);
    }

    #[test]
    fn comm_has_hbm_traffic() {
        let k = Kernel::comm("ar", KernelKind::AllReduce, 1e9);
        assert_eq!(k.bytes, 2e9);
        assert!(k.is_comm());
    }

    #[test]
    fn memory_bound_classification() {
        let g = GpuSpec::a100();
        // Norm-like kernel: tiny flops, large bytes -> memory bound.
        let norm = Kernel::comp("norm", KernelKind::Norm, 1e8, 1e9);
        assert!(norm.memory_bound(&g, g.n_sms, 1410));
        // Big matmul: compute bound at f_max with all SMs.
        let mm = Kernel::comp("mm", KernelKind::Linear, 1e12, 1e9);
        assert!(!mm.memory_bound(&g, g.n_sms, 1410));
    }

    #[test]
    fn lower_freq_shifts_toward_compute_bound() {
        // §3.2.3: reducing frequency lowers the compute ceiling only, so a
        // kernel that was memory-bound at f_max can become compute-bound.
        let g = GpuSpec::a100();
        let k = Kernel::comp("border", KernelKind::Linear, 2.2e11, 1.5e9);
        assert!(k.memory_bound(&g, g.n_sms, 1410));
        assert!(!k.memory_bound(&g, g.n_sms, 900));
    }

    #[test]
    fn group_sums_work() {
        let a = Kernel::comp("bda", KernelKind::BiasDropoutAdd, 1e6, 4e8);
        let b = Kernel::comp("norm", KernelKind::Norm, 2e6, 6e8);
        let gr = Kernel::group(&[a, b]);
        assert_eq!(gr.kind, KernelKind::Grouped);
        assert_eq!(gr.flops, 3e6);
        assert_eq!(gr.bytes, 1e9);
    }

    #[test]
    fn fuse_comm_sums_volume() {
        let a = Kernel::comm("ag_k", KernelKind::AllGather, 1e8);
        let b = Kernel::comm("ag_v", KernelKind::AllGather, 1e8);
        let f = Kernel::fuse_comm(&[a, b]);
        assert_eq!(f.comm_bytes, 2e8);
    }
}
