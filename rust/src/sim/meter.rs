//! NVML-like energy meter.
//!
//! §5.3: NVML's energy counter updates roughly every 100 ms, so
//! millisecond-scale measurements alias badly; the paper therefore
//! measures over multi-second windows. We model a counter that
//! integrates true power but is only *published* at a fixed sampling
//! interval, plus small sensor noise — reproducing Figure 12a's
//! high-variance short-window behaviour.

use crate::util::rng::Rng;

pub const NVML_SAMPLE_INTERVAL_S: f64 = 0.1;

#[derive(Clone, Debug)]
pub struct EnergyMeter {
    /// True accumulated energy (J).
    true_energy_j: f64,
    /// Energy value at the last counter publication.
    published_j: f64,
    /// Energy accumulated during the last published interval (sets the
    /// scale of per-reading sensor noise — the counter is a lifetime
    /// accumulator, so noise must NOT scale with the lifetime total).
    last_interval_j: f64,
    /// Time since the last publication.
    since_publish_s: f64,
    /// Per-reading sensor noise as a fraction of one sampling interval's
    /// energy (std dev).
    pub noise_interval_frac: f64,
}

impl EnergyMeter {
    pub fn new() -> Self {
        EnergyMeter {
            true_energy_j: 0.0,
            published_j: 0.0,
            last_interval_j: 0.0,
            since_publish_s: 0.0,
            noise_interval_frac: 0.15,
        }
    }

    /// Integrate constant power `p_w` for `dt_s`, publishing the counter at
    /// every 100 ms boundary crossed.
    pub fn advance(&mut self, p_w: f64, dt_s: f64) {
        let mut remaining = dt_s;
        while remaining > 0.0 {
            let to_boundary = NVML_SAMPLE_INTERVAL_S - self.since_publish_s;
            let step = remaining.min(to_boundary);
            self.true_energy_j += p_w * step;
            self.since_publish_s += step;
            remaining -= step;
            if self.since_publish_s >= NVML_SAMPLE_INTERVAL_S - 1e-12 {
                self.last_interval_j = self.true_energy_j - self.published_j;
                self.published_j = self.true_energy_j;
                self.since_publish_s = 0.0;
            }
        }
    }

    /// Read the counter as a driver would: the last *published* value plus
    /// interval-scale sensor noise. Short windows therefore see stale,
    /// aliased values.
    pub fn read(&self, rng: &mut Rng) -> f64 {
        self.published_j + self.noise_interval_frac * self.last_interval_j * rng.normal()
    }

    /// Ground truth (for tests and oracle comparisons).
    pub fn true_energy(&self) -> f64 {
        self.true_energy_j
    }
}

impl Default for EnergyMeter {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn integrates_power() {
        let mut m = EnergyMeter::new();
        m.advance(100.0, 2.0);
        assert!((m.true_energy() - 200.0).abs() < 1e-9);
    }

    #[test]
    fn publication_quantized() {
        let mut m = EnergyMeter::new();
        m.advance(100.0, 0.05); // below one sampling interval
        let mut rng = Rng::new(0);
        // Nothing published yet: reading is (noisy) zero.
        assert!(m.read(&mut rng).abs() < 1.0);
        m.advance(100.0, 0.06); // crosses the 100 ms boundary
        assert!(m.read(&mut rng) > 9.0);
    }

    #[test]
    fn long_window_accurate() {
        let mut m = EnergyMeter::new();
        m.advance(250.0, 5.0);
        let mut rng = Rng::new(1);
        let r = m.read(&mut rng);
        assert!((r - 1250.0).abs() / 1250.0 < 0.02, "read {r}");
    }

    #[test]
    fn short_window_relative_error_larger() {
        // Relative quantization error shrinks with window length.
        let err_for = |window: f64| {
            let mut m = EnergyMeter::new();
            m.advance(300.0, 0.033); // desynchronize from the boundary
            let start = m.published_j;
            m.advance(300.0, window);
            let end = m.published_j;
            let measured = end - start;
            let truth = 300.0 * window;
            (measured - truth).abs() / truth
        };
        // Windows that are not multiples of the 100 ms publication
        // interval see the staleness; relative error shrinks with window.
        assert!(err_for(0.55) > err_for(5.05));
    }
}
