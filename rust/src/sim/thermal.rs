//! First-order thermal model of the GPU + server cooling.
//!
//! §5.3 / §6.7: power draw is temperature-dependent; without cooldown
//! between profiling candidates, earlier measurements heat the die and
//! bias later ones. We model the die as a single thermal RC node:
//!     τ · dT/dt = (T_amb + θ·P) − T
//! where θ is the junction-to-ambient thermal resistance and τ the time
//! constant. With P≈400 W and θ≈0.09 K/W the steady state is ≈61 °C over
//! a 25 °C ambient — typical for an SXM A100 under load.

#[derive(Clone, Debug)]
pub struct ThermalModel {
    pub ambient_c: f64,
    /// Thermal resistance, K/W.
    pub theta_k_per_w: f64,
    /// Time constant, seconds (heat-up and cool-down).
    pub tau_s: f64,
}

impl Default for ThermalModel {
    fn default() -> Self {
        // τ calibrated to §5.3: a 5 s idle cooldown reliably brings the
        // die from load temperature back below ~32 °C.
        ThermalModel { ambient_c: 25.0, theta_k_per_w: 0.09, tau_s: 5.0 }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct ThermalState {
    pub temp_c: f64,
}

impl ThermalModel {
    pub fn initial(&self) -> ThermalState {
        ThermalState { temp_c: self.ambient_c }
    }

    /// Advance the die temperature under constant power `p_w` for `dt_s`
    /// (closed-form exponential step of the RC equation).
    pub fn step(&self, state: &mut ThermalState, p_w: f64, dt_s: f64) {
        let t_ss = self.ambient_c + self.theta_k_per_w * p_w;
        let a = (-dt_s / self.tau_s).exp();
        state.temp_c = t_ss + (state.temp_c - t_ss) * a;
    }

    /// Idle cooldown for `dt_s` (power = idle static draw).
    pub fn cool(&self, state: &mut ThermalState, idle_power_w: f64, dt_s: f64) {
        self.step(state, idle_power_w, dt_s);
    }

    pub fn steady_state_c(&self, p_w: f64) -> f64 {
        self.ambient_c + self.theta_k_per_w * p_w
    }

    /// Die-temperature trace of a cold start warming under constant power
    /// `p_w`: `n` samples, one after each `dt_s` step. The pinned default
    /// trace (320 W, 0.5 s steps) is shared between the tests here and
    /// the [`DriftMonitor`](crate::runtime::DriftMonitor) thermal-drift
    /// tests — the runtime's leakage-growth detection is exercised
    /// against exactly this warm-up curve.
    pub fn warmup_trace(&self, p_w: f64, dt_s: f64, n: usize) -> Vec<f64> {
        let mut s = self.initial();
        (0..n)
            .map(|_| {
                self.step(&mut s, p_w, dt_s);
                s.temp_c
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warms_toward_steady_state() {
        let m = ThermalModel::default();
        let mut s = m.initial();
        for _ in 0..100 {
            m.step(&mut s, 400.0, 1.0);
        }
        assert!((s.temp_c - m.steady_state_c(400.0)).abs() < 0.5);
    }

    #[test]
    fn cools_toward_ambient_plus_idle() {
        let m = ThermalModel::default();
        let mut s = ThermalState { temp_c: 70.0 };
        for _ in 0..200 {
            m.cool(&mut s, 85.0, 1.0);
        }
        assert!((s.temp_c - m.steady_state_c(85.0)).abs() < 0.5);
    }

    #[test]
    fn five_second_cooldown_approaches_idle_steady_state() {
        // The paper's environment: a 5 s cooldown reliably returns the GPU
        // to its idle temperature regime (§5.3, "below 32 °C" on their
        // servers). With our calibration the idle steady state is ~32.7 °C
        // (25 °C ambient + θ·85 W); 5 s must close most of the gap.
        let m = ThermalModel::default();
        let mut s = m.initial();
        m.step(&mut s, 350.0, 3.0); // short hot burst
        let hot = s.temp_c;
        m.cool(&mut s, 85.0, 5.0);
        let idle_ss = m.steady_state_c(85.0);
        assert!(s.temp_c < idle_ss + 3.0, "temp {} (idle ss {idle_ss})", s.temp_c);
        assert!(s.temp_c < hot - 0.6 * (hot - idle_ss), "cooled too little: {hot} -> {}", s.temp_c);
    }

    #[test]
    fn monotone_in_power() {
        let m = ThermalModel::default();
        let mut a = m.initial();
        let mut b = m.initial();
        m.step(&mut a, 200.0, 10.0);
        m.step(&mut b, 400.0, 10.0);
        assert!(b.temp_c > a.temp_c);
    }

    #[test]
    fn steady_state_convergence_is_exponential() {
        // The closed-form RC step must contract the distance to steady
        // state by exactly e^(−dt/τ) per step, from any starting point —
        // the convergence-rate contract the warm-up trace tests pin.
        let m = ThermalModel::default();
        let t_ss = m.steady_state_c(320.0);
        let a = (-0.5 / m.tau_s).exp();
        for start in [25.0, 40.0, 80.0] {
            let mut s = ThermalState { temp_c: start };
            let mut err = (start - t_ss).abs();
            for _ in 0..30 {
                m.step(&mut s, 320.0, 0.5);
                let next_err = (s.temp_c - t_ss).abs();
                assert!(
                    (next_err - err * a).abs() < 1e-9 * err.max(1e-9),
                    "contraction drifted from e^(-dt/tau): {next_err} vs {}",
                    err * a
                );
                err = next_err;
            }
            assert!(err < 0.1, "30 × 0.5 s must converge near steady state (err {err})");
        }
    }

    #[test]
    fn static_power_monotone_along_warmup() {
        // The coupling the replanning runtime exploits: as the die warms,
        // the GPU's temperature-dependent static power never decreases,
        // and strictly increases once past the leakage reference point.
        let m = ThermalModel::default();
        let gpu = crate::sim::gpu::GpuSpec::a100();
        let trace = m.warmup_trace(320.0, 0.5, 40);
        let mut prev_p = gpu.static_power(m.ambient_c);
        for &t in &trace {
            let p = gpu.static_power(t);
            assert!(p >= prev_p - 1e-12, "static power dipped while warming: {prev_p} -> {p}");
            if t > gpu.ref_temp_c {
                assert!(p > gpu.static_w, "above ref temp leakage must exceed P0");
            }
            prev_p = p;
        }
        // The warm trace ends well above the reference temperature, so the
        // leakage excess the DriftMonitor watches is actually present.
        assert!(*trace.last().unwrap() > gpu.ref_temp_c + 10.0);
        assert!(gpu.static_power(*trace.last().unwrap()) > 1.1 * gpu.static_w);
    }

    #[test]
    fn pinned_warmup_trace() {
        // The exact trace the runtime's thermal-drift tests replay
        // (320 W, 0.5 s steps from a cold start): T_k = T_ss − ΔT·a^k
        // with T_ss = 53.8 °C, ΔT = 28.8 K, a = e^(−0.1).
        let m = ThermalModel::default();
        let trace = m.warmup_trace(320.0, 0.5, 40);
        assert_eq!(trace.len(), 40);
        let t_ss = m.steady_state_c(320.0);
        assert!((t_ss - 53.8).abs() < 1e-12);
        let a = (-0.1f64).exp();
        for (k, &t) in trace.iter().enumerate() {
            let expected = t_ss - 28.8 * a.powi(k as i32 + 1);
            assert!((t - expected).abs() < 1e-9, "step {k}: {t} vs {expected}");
        }
        // Spot-pin a few absolute values so the curve can't silently move.
        assert!((trace[0] - 27.740_682_360_564_4).abs() < 1e-6);
        assert!((trace[4] - 36.331_917_000_276_2).abs() < 1e-6);
        assert!((trace[19] - 49.902_343_842_785_6).abs() < 1e-6);
    }
}
