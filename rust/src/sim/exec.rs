//! Two-stream execution-schedule simulator.
//!
//! Executes one *partition* (§4.2): a sequence of computation kernels on
//! the compute stream, plus (optionally) one communication kernel on the
//! comm stream with no data dependencies on them. The schedule controls
//! the three factors of §3.2:
//!   1. SM allocation of the communication kernel,
//!   2. launch timing (which computation kernel the comm launches with, or
//!      fully sequential execution),
//!   3. GPU frequency.
//!
//! The simulation is piecewise: between events (kernel completions, comm
//! launch), resource shares are constant; HBM bandwidth is split
//! demand-proportionally between the active compute kernel and the
//! communication kernel (this reproduces §3.2.2's Norm-vs-AllReduce
//! contention), compute throughput scales with SMs × frequency while
//! memory and link throughput are frequency-invariant (§3.2.3), and power
//! above the board limit triggers oscillating frequency throttling whose
//! Jensen penalty makes fluctuating frequency cost more dynamic energy
//! than its average (Appendix A).

use super::gpu::GpuSpec;
use super::kernel::{Kernel, KernelClass};

/// Fixed kernel-launch latency (CUDA launch + stream bookkeeping).
pub const LAUNCH_OVERHEAD_S: f64 = 3e-6;

/// When the communication kernel launches relative to the computation
/// sequence.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LaunchAt {
    /// Sequential execution model (Megatron-LM, Figure 2a): comm runs
    /// alone after all computation, with enough SMs to saturate the link.
    Sequential,
    /// Partitioned overlap: comm launches when computation kernel `i`
    /// starts (Figure 3's "launched together with Linear1/Norm/RoPE").
    WithComp(usize),
}

/// Per-kernel-class frequency assignment layered on a schedule's base
/// frequency (kernel-level DVFS). `Uniform` reproduces the partition-level
/// model bit-for-bit; `PerClass` gives the compute and memory kernel
/// classes ([`KernelClass`]) their own frequencies, and the executor
/// charges an explicit transition cost whenever the active frequency
/// changes between adjacent kernels. Comm kernels have no frequency of
/// their own — core frequency affects neither link nor HBM throughput —
/// so comm-only segments hold whatever frequency is already active and
/// never force a transition.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KernelFreqs {
    /// One frequency (`Schedule::freq_mhz`) for the whole partition.
    Uniform,
    /// Per-class frequencies. Invariant: `compute_mhz` equals the
    /// schedule's base `freq_mhz` (the base frequency *is* the
    /// compute-class frequency, so every layer keyed on `freq_mhz`
    /// remains meaningful for per-class schedules).
    PerClass { compute_mhz: u32, memory_mhz: u32 },
}

impl KernelFreqs {
    /// Re-base on a new compute/base frequency (the microbatch frequency
    /// sweep re-pins schedules per sweep frequency). The memory-class
    /// frequency, chosen for the kernels' energy profile, is kept.
    pub fn rebased(self, freq_mhz: u32) -> KernelFreqs {
        match self {
            KernelFreqs::Uniform => KernelFreqs::Uniform,
            KernelFreqs::PerClass { memory_mhz, .. } => {
                KernelFreqs::PerClass { compute_mhz: freq_mhz, memory_mhz }
            }
        }
    }
}

/// A complete execution schedule for one partition (the MBO decision
/// variables, §4.1). `Eq + Hash` so schedules can key the shared
/// measurement cache (all fields are integral).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Schedule {
    pub comm_sms: u32,
    pub launch: LaunchAt,
    pub freq_mhz: u32,
    /// Kernel-level frequency assignment; `Uniform` is the partition-level
    /// model (one frequency everywhere, zero transitions).
    pub kernel_freqs: KernelFreqs,
}

impl Schedule {
    pub fn sequential(freq_mhz: u32) -> Self {
        Schedule {
            comm_sms: 0,
            launch: LaunchAt::Sequential,
            freq_mhz,
            kernel_freqs: KernelFreqs::Uniform,
        }
    }

    /// Uniform-frequency overlapped schedule (the partition-level shape).
    pub fn uniform(comm_sms: u32, launch: LaunchAt, freq_mhz: u32) -> Self {
        Schedule { comm_sms, launch, freq_mhz, kernel_freqs: KernelFreqs::Uniform }
    }

    /// The frequency driving a kernel of `class` under this schedule. Comm
    /// kernels are frequency-invariant; they report the compute-class/base
    /// frequency so callers always receive a valid grid point.
    pub fn freq_for(&self, class: KernelClass) -> u32 {
        match self.kernel_freqs {
            KernelFreqs::Uniform => self.freq_mhz,
            KernelFreqs::PerClass { compute_mhz, memory_mhz } => match class {
                KernelClass::Compute | KernelClass::Comm => compute_mhz,
                KernelClass::Memory => memory_mhz,
            },
        }
    }
}

/// Simulation output for one partition execution.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExecResult {
    pub time_s: f64,
    pub dyn_j: f64,
    pub static_j: f64,
    /// Time during which the comm kernel ran with no computation active
    /// ("exposed communication", §3.2.1) — SMs idle, static power wasted.
    pub exposed_comm_s: f64,
    /// Work-averaged effective core frequency (≠ requested when throttled).
    pub avg_freq_mhz: f64,
    pub throttled: bool,
    pub peak_power_w: f64,
    /// Core-frequency transitions charged during this execution (always 0
    /// for [`KernelFreqs::Uniform`] schedules).
    pub freq_transitions: u32,
}

impl ExecResult {
    pub fn total_j(&self) -> f64 {
        self.dyn_j + self.static_j
    }
}

/// Reusable per-call state for the executor. Holds each computation
/// kernel's resolved frequency so the event loop indexes a flat array
/// instead of re-dispatching `Schedule::freq_for` (class match + freq
/// match) every segment — and so the resolution buffer is allocated once
/// per scratch, not once per the 10⁵–10⁶ `execute_partition` calls a
/// sweep makes.
#[derive(Default)]
pub struct ExecScratch {
    freqs: Vec<u32>,
}

/// Execute one partition under `sched` at die temperature `temp_c`.
///
/// `power_limit` of `None` disables throttling (used by unit tests);
/// normally pass `Some(gpu.tdp_w)`. Convenience wrapper over
/// [`execute_partition_with`] using a thread-local [`ExecScratch`].
pub fn execute_partition(
    gpu: &GpuSpec,
    comps: &[Kernel],
    comm: Option<&Kernel>,
    sched: &Schedule,
    temp_c: f64,
    power_limit: Option<f64>,
) -> ExecResult {
    thread_local! {
        static SCRATCH: std::cell::RefCell<ExecScratch> =
            std::cell::RefCell::new(ExecScratch::default());
    }
    SCRATCH.with(|s| {
        execute_partition_with(gpu, comps, comm, sched, temp_c, power_limit, &mut s.borrow_mut())
    })
}

/// [`execute_partition`] with a caller-owned scratch. Results are
/// independent of the scratch's prior contents (pinned bitwise by the
/// differential suite).
#[allow(clippy::too_many_arguments)]
pub fn execute_partition_with(
    gpu: &GpuSpec,
    comps: &[Kernel],
    comm: Option<&Kernel>,
    sched: &Schedule,
    temp_c: f64,
    power_limit: Option<f64>,
    scratch: &mut ExecScratch,
) -> ExecResult {
    debug_assert!(
        sched.freq_mhz >= gpu.f_min_mhz && sched.freq_mhz <= gpu.f_max_mhz,
        "schedule frequency {} MHz outside {}'s [{}, {}] MHz range",
        sched.freq_mhz,
        gpu.name,
        gpu.f_min_mhz,
        gpu.f_max_mhz
    );
    debug_assert!(
        comm.is_none() || sched.comm_sms < gpu.n_sms,
        "{} comm SMs oversubscribes {} ({} SMs)",
        sched.comm_sms,
        gpu.name,
        gpu.n_sms
    );
    if let KernelFreqs::PerClass { compute_mhz, memory_mhz } = sched.kernel_freqs {
        debug_assert!(
            compute_mhz == sched.freq_mhz,
            "per-class compute frequency {compute_mhz} MHz must equal the base {} MHz",
            sched.freq_mhz
        );
        debug_assert!(
            memory_mhz >= gpu.f_min_mhz && memory_mhz <= gpu.f_max_mhz,
            "memory-class frequency {} MHz outside {}'s [{}, {}] MHz range",
            memory_mhz,
            gpu.name,
            gpu.f_min_mhz,
            gpu.f_max_mhz
        );
    }
    // Resolve every computation kernel's frequency once; both executors
    // then read `freqs[i]` instead of dispatching per segment.
    scratch.freqs.clear();
    scratch.freqs.extend(comps.iter().map(|k| sched.freq_for(k.kind.class())));
    match sched.launch {
        LaunchAt::Sequential => {
            execute_sequential(gpu, comps, comm, sched, &scratch.freqs, temp_c, power_limit)
        }
        LaunchAt::WithComp(launch_idx) => execute_overlapped(
            gpu,
            comps,
            comm,
            sched,
            &scratch.freqs,
            launch_idx,
            temp_c,
            power_limit,
        ),
    }
}

#[allow(clippy::too_many_arguments)]
fn execute_sequential(
    gpu: &GpuSpec,
    comps: &[Kernel],
    comm: Option<&Kernel>,
    sched: &Schedule,
    freqs: &[u32],
    temp_c: f64,
    power_limit: Option<f64>,
) -> ExecResult {
    let mut res = ExecResult { avg_freq_mhz: sched.freq_mhz as f64, ..Default::default() };
    let p_static = gpu.static_power(temp_c);
    let mut freq_time_weighted = 0.0;
    let mut cur_freq = sched.freq_mhz;

    for (i, k) in comps.iter().enumerate() {
        let f_k = freqs[i];
        if f_k != cur_freq {
            charge_transition(gpu, p_static, f_k, &mut res, &mut freq_time_weighted);
            cur_freq = f_k;
        }
        let fw = &mut freq_time_weighted;
        run_solo_comp(gpu, k, gpu.n_sms, f_k, p_static, power_limit, &mut res, fw);
    }
    if let Some(c) = comm {
        // NCCL-style default kernel: saturates the link when run alone.
        // Frequency-invariant, so it holds `cur_freq` (no transition).
        let link = gpu.link_bw.min(gpu.mem_bw / 2.0);
        let t = c.comm_bytes / link + LAUNCH_OVERHEAD_S;
        let p_dyn = gpu.comm_power(link) + gpu.mem_power(2.0 * link);
        res.time_s += t;
        res.dyn_j += p_dyn * t;
        res.static_j += p_static * t;
        res.exposed_comm_s += t;
        res.peak_power_w = res.peak_power_w.max(p_static + p_dyn);
        freq_time_weighted += cur_freq as f64 * t;
    }
    if res.time_s > 0.0 {
        res.avg_freq_mhz = freq_time_weighted / res.time_s;
    }
    res
}

/// Charge one core-frequency transition: both streams stall for the
/// switch latency (static power keeps burning) and the PLL/voltage-
/// regulator overhead lands on the dynamic bill.
fn charge_transition(
    gpu: &GpuSpec,
    p_static: f64,
    new_freq_mhz: u32,
    res: &mut ExecResult,
    freq_time_weighted: &mut f64,
) {
    res.time_s += gpu.freq_switch_s;
    res.static_j += p_static * gpu.freq_switch_s;
    res.dyn_j += gpu.freq_switch_j;
    res.freq_transitions += 1;
    *freq_time_weighted += new_freq_mhz as f64 * gpu.freq_switch_s;
}

/// Run one computation kernel alone (no comm contention).
#[allow(clippy::too_many_arguments)]
fn run_solo_comp(
    gpu: &GpuSpec,
    k: &Kernel,
    sms: u32,
    freq_mhz: u32,
    p_static: f64,
    power_limit: Option<f64>,
    res: &mut ExecResult,
    freq_time_weighted: &mut f64,
) {
    let seg = segment_rates(gpu, Some((k, sms, 1.0)), None, freq_mhz, p_static, power_limit);
    let t = 1.0 / seg.comp_rate + LAUNCH_OVERHEAD_S;
    res.time_s += t;
    res.dyn_j += seg.p_dyn * (t - LAUNCH_OVERHEAD_S) + p_static * 0.0;
    res.static_j += p_static * t;
    res.peak_power_w = res.peak_power_w.max(p_static + seg.p_dyn);
    res.throttled |= seg.throttled;
    *freq_time_weighted += seg.eff_freq_mhz * t;
}

#[allow(clippy::too_many_arguments)]
fn execute_overlapped(
    gpu: &GpuSpec,
    comps: &[Kernel],
    comm: Option<&Kernel>,
    sched: &Schedule,
    freqs: &[u32],
    launch_idx: usize,
    temp_c: f64,
    power_limit: Option<f64>,
) -> ExecResult {
    let launch_idx = launch_idx.min(comps.len().saturating_sub(1));
    let p_static = gpu.static_power(temp_c);
    let mut res = ExecResult { avg_freq_mhz: sched.freq_mhz as f64, ..Default::default() };
    let mut freq_time_weighted = 0.0;

    let mut comp_idx = 0usize;
    let mut comp_left = 1.0f64; // fraction of current comp kernel remaining
    let mut comm_left: f64 = if comm.is_some() { 1.0 } else { 0.0 };
    let mut comm_launched = comm.is_none();
    // Active core frequency; per-class schedules re-clock it as the
    // compute stream moves between kernel classes (comm-only segments are
    // frequency-invariant and hold it).
    let mut cur_freq = sched.freq_mhz;
    // Launch overheads are serial on each stream; fold them in up front.
    let overhead = comps.len() as f64 * LAUNCH_OVERHEAD_S;
    res.time_s += overhead;
    res.static_j += p_static * overhead;

    let mut guard = 0usize;
    while comp_idx < comps.len() || comm_left > 1e-12 {
        guard += 1;
        assert!(guard < 10_000, "simulator failed to converge");

        if !comm_launched && comp_idx >= launch_idx {
            comm_launched = true;
        }
        let comm_active = comm_launched && comm_left > 1e-12;
        let comp_active = comp_idx < comps.len();

        if comp_active {
            let f_k = freqs[comp_idx];
            if f_k != cur_freq {
                charge_transition(gpu, p_static, f_k, &mut res, &mut freq_time_weighted);
                cur_freq = f_k;
            }
        }
        let comp_sms =
            if comm_active { gpu.n_sms.saturating_sub(sched.comm_sms) } else { gpu.n_sms };
        let comp_arg =
            if comp_active { Some((&comps[comp_idx], comp_sms, comp_left)) } else { None };
        let comm_arg = if comm_active {
            Some((comm.unwrap(), sched.comm_sms, comm_left))
        } else {
            None
        };
        let seg = segment_rates(gpu, comp_arg, comm_arg, cur_freq, p_static, power_limit);

        // Time until the earliest completion among active kernels.
        let mut dt = f64::INFINITY;
        if comp_active {
            dt = dt.min(comp_left / seg.comp_rate);
        }
        if comm_active {
            dt = dt.min(comm_left / seg.comm_rate);
        }
        debug_assert!(dt.is_finite() && dt > 0.0, "dt = {dt}");

        res.time_s += dt;
        res.dyn_j += seg.p_dyn * dt;
        res.static_j += p_static * dt;
        res.peak_power_w = res.peak_power_w.max(p_static + seg.p_dyn);
        res.throttled |= seg.throttled;
        freq_time_weighted += seg.eff_freq_mhz * dt;
        if comm_active && !comp_active {
            res.exposed_comm_s += dt;
        }

        if comp_active {
            comp_left -= seg.comp_rate * dt;
            if comp_left <= 1e-9 {
                comp_idx += 1;
                comp_left = 1.0;
            }
        }
        if comm_active {
            comm_left -= seg.comm_rate * dt;
            if comm_left <= 1e-9 {
                comm_left = 0.0;
            }
        }
    }
    if res.time_s > 0.0 {
        res.avg_freq_mhz =
            (freq_time_weighted + sched.freq_mhz as f64 * overhead) / res.time_s;
    }
    res
}

/// Constant-rate segment: resource shares and power for the active kernel
/// set. Rates are fractions of each kernel completed per second.
struct SegmentRates {
    comp_rate: f64,
    comm_rate: f64,
    p_dyn: f64,
    eff_freq_mhz: f64,
    throttled: bool,
}

fn segment_rates(
    gpu: &GpuSpec,
    comp: Option<(&Kernel, u32, f64)>,
    comm: Option<(&Kernel, u32, f64)>,
    freq_mhz: u32,
    p_static: f64,
    power_limit: Option<f64>,
) -> SegmentRates {
    let rates_at = |f_mhz: f64| -> (f64, f64, f64, f64, f64) {
        // HBM demand of each consumer (bytes/s it could absorb).
        let (mut d_comp, mut flop_cap) = (0.0, 0.0);
        if let Some((k, sms, _)) = comp {
            flop_cap = sms as f64 * gpu.flops_per_sm_per_cycle * f_mhz * 1e6;
            d_comp = if k.flops > 0.0 {
                (k.bytes * flop_cap / k.flops).min(gpu.mem_bw)
            } else {
                gpu.mem_bw
            };
        }
        let mut d_comm = 0.0;
        let mut link_cap = 0.0;
        if let Some((k, sms, _)) = comm {
            link_cap = gpu.comm_bw(sms);
            // HBM traffic rate needed to sustain the link rate.
            d_comm = (k.bytes / k.comm_bytes.max(1.0)) * link_cap;
        }
        // Demand-proportional HBM sharing when oversubscribed.
        let total_d = d_comp + d_comm;
        let scale = if total_d > gpu.mem_bw { gpu.mem_bw / total_d } else { 1.0 };
        let m_comp = d_comp * scale;
        let m_comm = d_comm * scale;

        // Per-kernel completion rates (fraction/s).
        let comp_rate = comp
            .map(|(k, _, _)| {
                let r_flop = if k.flops > 0.0 { flop_cap / k.flops } else { f64::INFINITY };
                let r_mem = if k.bytes > 0.0 { m_comp / k.bytes } else { f64::INFINITY };
                r_flop.min(r_mem)
            })
            .unwrap_or(0.0);
        let comm_rate = comm
            .map(|(k, _, _)| {
                let r_link = link_cap / k.comm_bytes.max(1.0);
                let r_mem = if k.bytes > 0.0 { m_comm / k.bytes } else { f64::INFINITY };
                r_link.min(r_mem)
            })
            .unwrap_or(0.0);

        // Achieved resource rates -> dynamic power.
        let flop_rate = comp.map(|(k, _, _)| comp_rate * k.flops).unwrap_or(0.0);
        let mem_rate = comp.map(|(k, _, _)| comp_rate * k.bytes).unwrap_or(0.0)
            + comm.map(|(k, _, _)| comm_rate * k.bytes).unwrap_or(0.0);
        let link_rate = comm.map(|(k, _, _)| comm_rate * k.comm_bytes).unwrap_or(0.0);
        let fr = f_mhz * 1e6 / gpu.f_max_hz();
        let peak_flops = gpu.n_sms as f64 * gpu.flops_per_sm_per_cycle * f_mhz * 1e6;
        let p_comp = if peak_flops > 0.0 {
            gpu.comp_w_max * fr * fr * fr * (flop_rate / peak_flops).min(1.0)
        } else {
            0.0
        };
        let p_dyn = p_comp + gpu.mem_power(mem_rate) + gpu.comm_power(link_rate);
        (comp_rate, comm_rate, p_dyn, flop_rate, p_comp)
    };

    let f_req = freq_mhz as f64;
    let (comp_rate, comm_rate, p_dyn, _flop_rate, p_comp) = rates_at(f_req);

    let limit = power_limit.unwrap_or(f64::INFINITY);
    if p_static + p_dyn <= limit || p_comp <= 0.0 {
        return SegmentRates { comp_rate, comm_rate, p_dyn, eff_freq_mhz: f_req, throttled: false };
    }

    // Throttling: the power controller oscillates the clock so that average
    // power ≈ limit. Find the balance frequency by bisection on the *true*
    // rates function (utilization shifts as kernels move between memory-
    // and compute-bound regimes, so a constant-utilization f³ solve is not
    // monotone). The oscillation is modeled as a 50/50 duty cycle between
    // f_req and f_lo mirrored around f_bal: time follows the *average*
    // frequency; dynamic compute energy follows the f³ *mixture*, which by
    // Jensen's inequality exceeds running constantly at f_bal (Appendix A)
    // — the effect Kareus exploits in the §6.2.1 case study.
    let mut lo = gpu.f_min_mhz as f64;
    let mut hi = f_req;
    for _ in 0..24 {
        let mid = 0.5 * (lo + hi);
        let (_, _, p_mid, _, _) = rates_at(mid);
        if p_static + p_mid > limit {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    let f_bal = lo;
    let f_lo = (2.0 * f_bal - f_req).max(gpu.f_min_mhz as f64);
    let (comp_rate_b, comm_rate_b, p_dyn_bal, _fr_b, p_comp_bal) = rates_at(f_bal);
    // Jensen penalty on the compute component of dynamic power.
    let mix = if f_bal > 0.0 {
        0.5 * (f_req / f_bal).powi(3) + 0.5 * (f_lo / f_bal).powi(3)
    } else {
        1.0
    };
    let p_dyn_throttled = (p_dyn_bal - p_comp_bal) + p_comp_bal * mix.max(1.0);
    SegmentRates {
        comp_rate: comp_rate_b,
        comm_rate: comm_rate_b,
        p_dyn: p_dyn_throttled,
        eff_freq_mhz: f_bal,
        throttled: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::kernel::KernelKind;

    fn gpu() -> GpuSpec {
        GpuSpec::a100()
    }

    fn linear(flops: f64) -> Kernel {
        Kernel::comp("linear", KernelKind::Linear, flops, flops / 300.0)
    }
    fn norm(bytes: f64) -> Kernel {
        Kernel::comp("norm", KernelKind::Norm, bytes / 8.0, bytes)
    }
    fn allreduce(bytes: f64) -> Kernel {
        Kernel::comm("ar", KernelKind::AllReduce, bytes)
    }

    #[test]
    fn sequential_time_is_sum() {
        let g = gpu();
        let comps = vec![linear(1e11), linear(2e11)];
        let comm = allreduce(1e8);
        let r = execute_partition(&g, &comps, Some(&comm), &Schedule::sequential(1410), 30.0, None);
        let t_comp = 3e11 / g.flop_rate(g.n_sms, 1410);
        let t_comm = 1e8 / g.link_bw;
        assert!((r.time_s - (t_comp + t_comm)).abs() / r.time_s < 0.05, "{}", r.time_s);
        assert!(r.exposed_comm_s > 0.0);
    }

    #[test]
    fn overlap_beats_sequential() {
        // A long compute kernel fully hides a small comm kernel.
        let g = gpu();
        let comps = vec![linear(5e11)];
        let comm = allreduce(1e8);
        let seq_sched = Schedule::sequential(1410);
        let seq = execute_partition(&g, &comps, Some(&comm), &seq_sched, 30.0, None);
        let ovl = execute_partition(
            &g,
            &comps,
            Some(&comm),
            &Schedule::uniform(8, LaunchAt::WithComp(0), 1410),
            30.0,
            None,
        );
        assert!(ovl.time_s < seq.time_s, "ovl {} seq {}", ovl.time_s, seq.time_s);
        assert!(ovl.total_j() < seq.total_j());
        assert_eq!(ovl.exposed_comm_s, 0.0);
    }

    #[test]
    fn sm_allocation_sweet_spot() {
        // §3.2.1 / Figure 3(a)-(c): few SMs expose comm; many SMs slow
        // compute. A middle allocation minimizes time.
        let g = gpu();
        let comps = vec![linear(5e11), linear(5e11)];
        let comm = allreduce(3e8);
        let time_at = |sms: u32| {
            execute_partition(
                &g,
                &comps,
                Some(&comm),
                &Schedule::uniform(sms, LaunchAt::WithComp(0), 1410),
                30.0,
                None,
            )
            .time_s
        };
        let t2 = time_at(2);
        let t12 = time_at(12);
        let t90 = time_at(90);
        assert!(t12 < t2, "mid {t12} vs few {t2}");
        assert!(t12 < t90, "mid {t12} vs many {t90}");
    }

    #[test]
    fn comm_with_membound_kernel_contends() {
        // §3.2.2: a comm kernel overlapped with a memory-bound kernel
        // (Norm) contends for HBM bandwidth and prolongs both, whereas
        // overlapping with a compute-bound Linear (giving up a few SMs)
        // costs almost nothing.
        let g = gpu();
        let comm = allreduce(3e8);
        let sched = Schedule::uniform(12, LaunchAt::WithComp(0), 1410);

        // Norm + comm: both memory-bound -> contention prolongs the pair
        // beyond the longer of the two run solo.
        let norm_k = norm(4e9);
        let t_norm_solo =
            execute_partition(&g, &[norm_k.clone()], None, &sched, 30.0, None).time_s;
        let t_comm_solo =
            execute_partition(&g, &[], Some(&comm), &sched, 30.0, None).time_s;
        let t_norm_ovl =
            execute_partition(&g, &[norm_k.clone()], Some(&comm), &sched, 30.0, None).time_s;
        assert!(
            t_norm_ovl > 1.05 * t_norm_solo.max(t_comm_solo),
            "ovl {t_norm_ovl} vs solo {t_norm_solo}/{t_comm_solo}"
        );

        // Linear + comm: near-perfect overlap (no bandwidth contention).
        let lin = linear(6e11);
        let t_lin_solo = execute_partition(&g, &[lin.clone()], None, &sched, 30.0, None).time_s;
        let t_lin_ovl =
            execute_partition(&g, &[lin.clone()], Some(&comm), &sched, 30.0, None).time_s;
        assert!(
            t_lin_ovl < 1.15 * t_lin_solo.max(t_comm_solo),
            "ovl {t_lin_ovl} vs solo {t_lin_solo}/{t_comm_solo}"
        );
        assert!(t_lin_ovl < 0.8 * (t_lin_solo + t_comm_solo));
    }

    #[test]
    fn lower_freq_cuts_dynamic_energy() {
        let g = gpu();
        let comps = vec![linear(5e11)];
        let hi = execute_partition(&g, &comps, None, &Schedule::sequential(1410), 30.0, None);
        let lo = execute_partition(&g, &comps, None, &Schedule::sequential(1110), 30.0, None);
        assert!(lo.dyn_j < hi.dyn_j);
        assert!(lo.time_s > hi.time_s);
        assert!(lo.static_j > hi.static_j); // longer run -> more static
    }

    #[test]
    fn dynamic_energy_schedule_invariant_at_fixed_freq() {
        // §3.1: at the same frequency, dynamic energy is (largely) constant
        // across schedules; static energy varies with time.
        let g = gpu();
        let comps = vec![linear(3e11), norm(1e9), linear(3e11)];
        let comm = allreduce(5e8);
        let mk = |sms, at| {
            execute_partition(
                &g,
                &comps,
                Some(&comm),
                &Schedule::uniform(sms, LaunchAt::WithComp(at), 1410),
                30.0,
                None,
            )
        };
        let a = mk(4, 0);
        let b = mk(20, 2);
        let rel = (a.dyn_j - b.dyn_j).abs() / a.dyn_j;
        assert!(rel < 0.02, "dyn energy varied {rel}");
        assert!((a.static_j - b.static_j).abs() > 0.0);
    }

    #[test]
    fn throttling_penalizes_fluctuation() {
        // Heavy overlap at max frequency exceeds TDP -> throttled with a
        // Jensen penalty; requesting the balance frequency directly is
        // cheaper at ~equal time (§6.2.1 case study).
        let g = gpu();
        let comps = vec![linear(8e11)];
        let comm = allreduce(2e9);
        let hot = execute_partition(
            &g,
            &comps,
            Some(&comm),
            &Schedule::uniform(24, LaunchAt::WithComp(0), 1410),
            60.0,
            Some(g.tdp_w),
        );
        assert!(hot.throttled);
        assert!(hot.avg_freq_mhz < 1409.0, "avg {}", hot.avg_freq_mhz);
        let steady_freq = (hot.avg_freq_mhz / 15.0).round() as u32 * 15;
        let steady = execute_partition(
            &g,
            &comps,
            Some(&comm),
            &Schedule::uniform(24, LaunchAt::WithComp(0), steady_freq),
            60.0,
            Some(g.tdp_w),
        );
        assert!(steady.time_s <= hot.time_s * 1.02);
        assert!(steady.dyn_j < hot.dyn_j, "steady {} hot {}", steady.dyn_j, hot.dyn_j);
    }

    #[test]
    fn exposed_comm_accounted() {
        let g = gpu();
        let comps = vec![linear(1e10)];
        let comm = allreduce(5e9); // huge comm, tiny compute
        let r = execute_partition(
            &g,
            &comps,
            Some(&comm),
            &Schedule::uniform(30, LaunchAt::WithComp(0), 1410),
            30.0,
            None,
        );
        assert!(r.exposed_comm_s > 0.5 * r.time_s);
    }

    #[test]
    fn higher_temp_increases_static_energy() {
        let g = gpu();
        let comps = vec![linear(3e11)];
        let cold = execute_partition(&g, &comps, None, &Schedule::sequential(1410), 30.0, None);
        let hot = execute_partition(&g, &comps, None, &Schedule::sequential(1410), 75.0, None);
        assert!(hot.static_j > cold.static_j);
        assert!((hot.time_s - cold.time_s).abs() < 1e-9);
    }

    #[test]
    fn no_comm_partition_works() {
        let g = gpu();
        let comps = vec![linear(1e11), norm(1e9)];
        let r = execute_partition(
            &g,
            &comps,
            None,
            &Schedule::uniform(0, LaunchAt::WithComp(0), 1410),
            30.0,
            None,
        );
        assert!(r.time_s > 0.0);
        assert_eq!(r.exposed_comm_s, 0.0);
    }

    #[test]
    fn late_launch_can_expose_comm() {
        let g = gpu();
        let comps = vec![linear(4e11), linear(4e11)];
        let comm = allreduce(2e9);
        let early = execute_partition(
            &g,
            &comps,
            Some(&comm),
            &Schedule::uniform(12, LaunchAt::WithComp(0), 1410),
            30.0,
            None,
        );
        let late = execute_partition(
            &g,
            &comps,
            Some(&comm),
            &Schedule::uniform(12, LaunchAt::WithComp(1), 1410),
            30.0,
            None,
        );
        assert!(late.exposed_comm_s >= early.exposed_comm_s);
    }

    /// Memory-bound kernel with intensity ~100 FLOP/B: below the A100
    /// roofline ridge (~200 at 1410 MHz, ~128 at 900 MHz), so its time is
    /// HBM-limited at both frequencies while its compute power is large
    /// enough for per-class downclocking to matter.
    fn fused_membound(bytes: f64) -> Kernel {
        Kernel::comp("fused", KernelKind::Grouped, 100.0 * bytes, bytes)
    }

    fn per_class(comm_sms: u32, launch: LaunchAt, compute: u32, memory: u32) -> Schedule {
        Schedule {
            comm_sms,
            launch,
            freq_mhz: compute,
            kernel_freqs: KernelFreqs::PerClass { compute_mhz: compute, memory_mhz: memory },
        }
    }

    fn assert_bitwise_eq(a: &ExecResult, b: &ExecResult) {
        assert_eq!(a.time_s.to_bits(), b.time_s.to_bits(), "time {} vs {}", a.time_s, b.time_s);
        assert_eq!(a.dyn_j.to_bits(), b.dyn_j.to_bits(), "dyn {} vs {}", a.dyn_j, b.dyn_j);
        assert_eq!(a.static_j.to_bits(), b.static_j.to_bits());
        assert_eq!(a.exposed_comm_s.to_bits(), b.exposed_comm_s.to_bits());
        assert_eq!(a.avg_freq_mhz.to_bits(), b.avg_freq_mhz.to_bits());
        assert_eq!(a.peak_power_w.to_bits(), b.peak_power_w.to_bits());
        assert_eq!(a.throttled, b.throttled);
        assert_eq!(a.freq_transitions, b.freq_transitions);
    }

    #[test]
    fn per_class_diagonal_matches_uniform_bitwise() {
        // PerClass{f, f} never transitions, so even with nonzero switch
        // costs it must reproduce the Uniform arithmetic bit-for-bit.
        let g = gpu();
        let comps = vec![linear(3e11), norm(2e9), linear(4e11)];
        let comm = allreduce(5e8);
        for (launch, sms) in
            [(LaunchAt::Sequential, 0), (LaunchAt::WithComp(0), 12), (LaunchAt::WithComp(2), 24)]
        {
            for f in [900, 1110, 1410] {
                let uni = Schedule::uniform(sms, launch, f);
                let diag = per_class(sms, launch, f, f);
                let a = execute_partition(&g, &comps, Some(&comm), &uni, 40.0, Some(g.tdp_w));
                let b = execute_partition(&g, &comps, Some(&comm), &diag, 40.0, Some(g.tdp_w));
                assert_bitwise_eq(&a, &b);
                assert_eq!(a.freq_transitions, 0);
            }
        }
    }

    #[test]
    fn transitions_charged_iff_class_frequency_changes() {
        let g = gpu();
        // compute -> memory -> compute: two class boundaries where the
        // active frequency changes (the stream starts at the base/compute
        // frequency, so the first Linear is free).
        let comps = vec![linear(3e11), fused_membound(2e9), linear(3e11)];
        let split = per_class(0, LaunchAt::Sequential, 1410, 1110);
        let r = execute_partition(&g, &comps, None, &split, 30.0, None);
        assert_eq!(r.freq_transitions, 2);

        // Memory kernels adjacent to each other share a frequency: still 2.
        let comps2 = vec![linear(3e11), fused_membound(2e9), norm(1e9), linear(3e11)];
        let r2 = execute_partition(&g, &comps2, None, &split, 30.0, None);
        assert_eq!(r2.freq_transitions, 2);

        // Same per-class assignment on an all-memory partition: one switch
        // on entry, none after.
        let comps3 = vec![fused_membound(2e9), norm(1e9)];
        let r3 = execute_partition(&g, &comps3, None, &split, 30.0, None);
        assert_eq!(r3.freq_transitions, 1);
    }

    #[test]
    fn transition_cost_grows_energy_and_time() {
        let g = gpu();
        let comps = vec![linear(3e11), fused_membound(2e9), linear(3e11)];
        let split = per_class(0, LaunchAt::Sequential, 1410, 1110);
        let mut free = g.clone();
        free.freq_switch_s = 0.0;
        free.freq_switch_j = 0.0;
        let paid = execute_partition(&g, &comps, None, &split, 30.0, None);
        let free_r = execute_partition(&free, &comps, None, &split, 30.0, None);
        assert_eq!(paid.freq_transitions, 2);
        assert_eq!(free_r.freq_transitions, 2);
        let dt = paid.time_s - free_r.time_s;
        assert!((dt - 2.0 * g.freq_switch_s).abs() < 1e-12, "latency {dt}");
        assert!(paid.total_j() > free_r.total_j());
    }

    #[test]
    fn total_energy_linear_in_switch_energy_penalty() {
        // dyn_j grows by exactly n_transitions * delta when only the
        // per-transition energy penalty changes.
        let comps = vec![linear(3e11), fused_membound(2e9), linear(3e11)];
        let split = per_class(0, LaunchAt::Sequential, 1410, 1110);
        let at = |j: f64| {
            let mut g = gpu();
            g.freq_switch_j = j;
            execute_partition(&g, &comps, None, &split, 30.0, None)
        };
        let (a, b, c) = (at(0.0), at(5e-3), at(5e-2));
        assert!(a.total_j() <= b.total_j() && b.total_j() <= c.total_j());
        let n = a.freq_transitions as f64;
        assert!(n > 0.0);
        assert!((b.dyn_j - a.dyn_j - n * 5e-3).abs() < 1e-9);
        assert!((c.dyn_j - a.dyn_j - n * 5e-2).abs() < 1e-9);
    }

    #[test]
    fn membound_downclock_saves_energy_at_near_equal_time() {
        // The kernel-level DVFS win: a memory-bound kernel's time is
        // HBM-limited (frequency-invariant) while its compute dynamic
        // power scales ~f^2 — downclocking only the memory class trades a
        // single transition for a large dynamic-energy cut.
        let g = gpu();
        let comps = vec![linear(9e11), fused_membound(1.2e10)];
        let uni = Schedule::uniform(0, LaunchAt::Sequential, 1410);
        let split = per_class(0, LaunchAt::Sequential, 1410, 900);
        let base = execute_partition(&g, &comps, None, &uni, 30.0, None);
        let kdvfs = execute_partition(&g, &comps, None, &split, 30.0, None);
        assert_eq!(kdvfs.freq_transitions, 1);
        // Time grows only by the single switch latency.
        let dt = kdvfs.time_s - base.time_s;
        assert!((dt - g.freq_switch_s).abs() < 1e-9, "dt {dt}");
        // Energy drops by far more than the switch penalty costs.
        assert!(
            kdvfs.total_j() < base.total_j() - 0.3,
            "kdvfs {} base {}",
            kdvfs.total_j(),
            base.total_j()
        );
    }

    #[test]
    fn comm_only_segments_hold_frequency() {
        // A trailing comm kernel after a memory-class kernel must not
        // charge a transition back to the base frequency: core frequency
        // is irrelevant to link and HBM throughput.
        let g = gpu();
        let comps = vec![linear(3e11), fused_membound(4e9)];
        let comm = allreduce(2e9);
        let split = per_class(12, LaunchAt::WithComp(1), 1410, 900);
        let r = execute_partition(&g, &comps, Some(&comm), &split, 30.0, None);
        assert_eq!(r.freq_transitions, 1);
        assert!(r.exposed_comm_s > 0.0);
    }

    #[test]
    fn scratch_reuse_matches_fresh_bitwise() {
        // One dirty scratch carried across schedules of every shape
        // (sequential / overlapped / per-class / throttled) must produce
        // the same bits as a fresh scratch per call.
        let g = gpu();
        let comps = vec![linear(3e11), fused_membound(2e9), norm(1e9), linear(8e11)];
        let comm = allreduce(2e9);
        let scheds = [
            Schedule::sequential(1410),
            Schedule::uniform(12, LaunchAt::WithComp(0), 1410),
            Schedule::uniform(24, LaunchAt::WithComp(3), 1110),
            per_class(0, LaunchAt::Sequential, 1410, 900),
            per_class(12, LaunchAt::WithComp(1), 1410, 1110),
        ];
        let mut reused = ExecScratch::default();
        for sched in &scheds {
            for (comm_arg, limit) in
                [(Some(&comm), None), (Some(&comm), Some(g.tdp_w)), (None, None)]
            {
                let a = execute_partition_with(
                    &g,
                    &comps,
                    comm_arg,
                    sched,
                    40.0,
                    limit,
                    &mut reused,
                );
                let b = execute_partition_with(
                    &g,
                    &comps,
                    comm_arg,
                    sched,
                    40.0,
                    limit,
                    &mut ExecScratch::default(),
                );
                let c = execute_partition(&g, &comps, comm_arg, sched, 40.0, limit);
                assert_bitwise_eq(&a, &b);
                assert_bitwise_eq(&a, &c);
            }
        }
    }
}
