//! Two-stream execution-schedule simulator.
//!
//! Executes one *partition* (§4.2): a sequence of computation kernels on
//! the compute stream, plus (optionally) one communication kernel on the
//! comm stream with no data dependencies on them. The schedule controls
//! the three factors of §3.2:
//!   1. SM allocation of the communication kernel,
//!   2. launch timing (which computation kernel the comm launches with, or
//!      fully sequential execution),
//!   3. GPU frequency.
//!
//! The simulation is piecewise: between events (kernel completions, comm
//! launch), resource shares are constant; HBM bandwidth is split
//! demand-proportionally between the active compute kernel and the
//! communication kernel (this reproduces §3.2.2's Norm-vs-AllReduce
//! contention), compute throughput scales with SMs × frequency while
//! memory and link throughput are frequency-invariant (§3.2.3), and power
//! above the board limit triggers oscillating frequency throttling whose
//! Jensen penalty makes fluctuating frequency cost more dynamic energy
//! than its average (Appendix A).

use super::gpu::GpuSpec;
use super::kernel::Kernel;

/// Fixed kernel-launch latency (CUDA launch + stream bookkeeping).
pub const LAUNCH_OVERHEAD_S: f64 = 3e-6;

/// When the communication kernel launches relative to the computation
/// sequence.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LaunchAt {
    /// Sequential execution model (Megatron-LM, Figure 2a): comm runs
    /// alone after all computation, with enough SMs to saturate the link.
    Sequential,
    /// Partitioned overlap: comm launches when computation kernel `i`
    /// starts (Figure 3's "launched together with Linear1/Norm/RoPE").
    WithComp(usize),
}

/// A complete execution schedule for one partition (the MBO decision
/// variables, §4.1). `Eq + Hash` so schedules can key the shared
/// measurement cache (all fields are integral).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Schedule {
    pub comm_sms: u32,
    pub launch: LaunchAt,
    pub freq_mhz: u32,
}

impl Schedule {
    pub fn sequential(freq_mhz: u32) -> Self {
        Schedule { comm_sms: 0, launch: LaunchAt::Sequential, freq_mhz }
    }
}

/// Simulation output for one partition execution.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExecResult {
    pub time_s: f64,
    pub dyn_j: f64,
    pub static_j: f64,
    /// Time during which the comm kernel ran with no computation active
    /// ("exposed communication", §3.2.1) — SMs idle, static power wasted.
    pub exposed_comm_s: f64,
    /// Work-averaged effective core frequency (≠ requested when throttled).
    pub avg_freq_mhz: f64,
    pub throttled: bool,
    pub peak_power_w: f64,
}

impl ExecResult {
    pub fn total_j(&self) -> f64 {
        self.dyn_j + self.static_j
    }
}

/// Execute one partition under `sched` at die temperature `temp_c`.
///
/// `power_limit` of `None` disables throttling (used by unit tests);
/// normally pass `Some(gpu.tdp_w)`.
pub fn execute_partition(
    gpu: &GpuSpec,
    comps: &[Kernel],
    comm: Option<&Kernel>,
    sched: &Schedule,
    temp_c: f64,
    power_limit: Option<f64>,
) -> ExecResult {
    debug_assert!(
        sched.freq_mhz >= gpu.f_min_mhz && sched.freq_mhz <= gpu.f_max_mhz,
        "schedule frequency {} MHz outside {}'s [{}, {}] MHz range",
        sched.freq_mhz,
        gpu.name,
        gpu.f_min_mhz,
        gpu.f_max_mhz
    );
    debug_assert!(
        comm.is_none() || sched.comm_sms < gpu.n_sms,
        "{} comm SMs oversubscribes {} ({} SMs)",
        sched.comm_sms,
        gpu.name,
        gpu.n_sms
    );
    match sched.launch {
        LaunchAt::Sequential => {
            execute_sequential(gpu, comps, comm, sched.freq_mhz, temp_c, power_limit)
        }
        LaunchAt::WithComp(launch_idx) => {
            execute_overlapped(gpu, comps, comm, sched, launch_idx, temp_c, power_limit)
        }
    }
}

fn execute_sequential(
    gpu: &GpuSpec,
    comps: &[Kernel],
    comm: Option<&Kernel>,
    freq_mhz: u32,
    temp_c: f64,
    power_limit: Option<f64>,
) -> ExecResult {
    let mut res = ExecResult { avg_freq_mhz: freq_mhz as f64, ..Default::default() };
    let p_static = gpu.static_power(temp_c);
    let mut freq_time_weighted = 0.0;

    for k in comps {
        let fw = &mut freq_time_weighted;
        run_solo_comp(gpu, k, gpu.n_sms, freq_mhz, p_static, power_limit, &mut res, fw);
    }
    if let Some(c) = comm {
        // NCCL-style default kernel: saturates the link when run alone.
        let link = gpu.link_bw.min(gpu.mem_bw / 2.0);
        let t = c.comm_bytes / link + LAUNCH_OVERHEAD_S;
        let p_dyn = gpu.comm_power(link) + gpu.mem_power(2.0 * link);
        res.time_s += t;
        res.dyn_j += p_dyn * t;
        res.static_j += p_static * t;
        res.exposed_comm_s += t;
        res.peak_power_w = res.peak_power_w.max(p_static + p_dyn);
        freq_time_weighted += freq_mhz as f64 * t;
    }
    if res.time_s > 0.0 {
        res.avg_freq_mhz = freq_time_weighted / res.time_s;
    }
    res
}

/// Run one computation kernel alone (no comm contention).
#[allow(clippy::too_many_arguments)]
fn run_solo_comp(
    gpu: &GpuSpec,
    k: &Kernel,
    sms: u32,
    freq_mhz: u32,
    p_static: f64,
    power_limit: Option<f64>,
    res: &mut ExecResult,
    freq_time_weighted: &mut f64,
) {
    let seg = segment_rates(gpu, Some((k, sms, 1.0)), None, freq_mhz, p_static, power_limit);
    let t = 1.0 / seg.comp_rate + LAUNCH_OVERHEAD_S;
    res.time_s += t;
    res.dyn_j += seg.p_dyn * (t - LAUNCH_OVERHEAD_S) + p_static * 0.0;
    res.static_j += p_static * t;
    res.peak_power_w = res.peak_power_w.max(p_static + seg.p_dyn);
    res.throttled |= seg.throttled;
    *freq_time_weighted += seg.eff_freq_mhz * t;
}

#[allow(clippy::too_many_arguments)]
fn execute_overlapped(
    gpu: &GpuSpec,
    comps: &[Kernel],
    comm: Option<&Kernel>,
    sched: &Schedule,
    launch_idx: usize,
    temp_c: f64,
    power_limit: Option<f64>,
) -> ExecResult {
    let launch_idx = launch_idx.min(comps.len().saturating_sub(1));
    let p_static = gpu.static_power(temp_c);
    let mut res = ExecResult { avg_freq_mhz: sched.freq_mhz as f64, ..Default::default() };
    let mut freq_time_weighted = 0.0;

    let mut comp_idx = 0usize;
    let mut comp_left = 1.0f64; // fraction of current comp kernel remaining
    let mut comm_left: f64 = if comm.is_some() { 1.0 } else { 0.0 };
    let mut comm_launched = comm.is_none();
    // Launch overheads are serial on each stream; fold them in up front.
    let overhead = comps.len() as f64 * LAUNCH_OVERHEAD_S;
    res.time_s += overhead;
    res.static_j += p_static * overhead;

    let mut guard = 0usize;
    while comp_idx < comps.len() || comm_left > 1e-12 {
        guard += 1;
        assert!(guard < 10_000, "simulator failed to converge");

        if !comm_launched && comp_idx >= launch_idx {
            comm_launched = true;
        }
        let comm_active = comm_launched && comm_left > 1e-12;
        let comp_active = comp_idx < comps.len();

        let comp_sms =
            if comm_active { gpu.n_sms.saturating_sub(sched.comm_sms) } else { gpu.n_sms };
        let comp_arg =
            if comp_active { Some((&comps[comp_idx], comp_sms, comp_left)) } else { None };
        let comm_arg = if comm_active {
            Some((comm.unwrap(), sched.comm_sms, comm_left))
        } else {
            None
        };
        let seg = segment_rates(gpu, comp_arg, comm_arg, sched.freq_mhz, p_static, power_limit);

        // Time until the earliest completion among active kernels.
        let mut dt = f64::INFINITY;
        if comp_active {
            dt = dt.min(comp_left / seg.comp_rate);
        }
        if comm_active {
            dt = dt.min(comm_left / seg.comm_rate);
        }
        debug_assert!(dt.is_finite() && dt > 0.0, "dt = {dt}");

        res.time_s += dt;
        res.dyn_j += seg.p_dyn * dt;
        res.static_j += p_static * dt;
        res.peak_power_w = res.peak_power_w.max(p_static + seg.p_dyn);
        res.throttled |= seg.throttled;
        freq_time_weighted += seg.eff_freq_mhz * dt;
        if comm_active && !comp_active {
            res.exposed_comm_s += dt;
        }

        if comp_active {
            comp_left -= seg.comp_rate * dt;
            if comp_left <= 1e-9 {
                comp_idx += 1;
                comp_left = 1.0;
            }
        }
        if comm_active {
            comm_left -= seg.comm_rate * dt;
            if comm_left <= 1e-9 {
                comm_left = 0.0;
            }
        }
    }
    if res.time_s > 0.0 {
        res.avg_freq_mhz =
            (freq_time_weighted + sched.freq_mhz as f64 * overhead) / res.time_s;
    }
    res
}

/// Constant-rate segment: resource shares and power for the active kernel
/// set. Rates are fractions of each kernel completed per second.
struct SegmentRates {
    comp_rate: f64,
    comm_rate: f64,
    p_dyn: f64,
    eff_freq_mhz: f64,
    throttled: bool,
}

fn segment_rates(
    gpu: &GpuSpec,
    comp: Option<(&Kernel, u32, f64)>,
    comm: Option<(&Kernel, u32, f64)>,
    freq_mhz: u32,
    p_static: f64,
    power_limit: Option<f64>,
) -> SegmentRates {
    let rates_at = |f_mhz: f64| -> (f64, f64, f64, f64, f64) {
        // HBM demand of each consumer (bytes/s it could absorb).
        let (mut d_comp, mut flop_cap) = (0.0, 0.0);
        if let Some((k, sms, _)) = comp {
            flop_cap = sms as f64 * gpu.flops_per_sm_per_cycle * f_mhz * 1e6;
            d_comp = if k.flops > 0.0 {
                (k.bytes * flop_cap / k.flops).min(gpu.mem_bw)
            } else {
                gpu.mem_bw
            };
        }
        let mut d_comm = 0.0;
        let mut link_cap = 0.0;
        if let Some((k, sms, _)) = comm {
            link_cap = gpu.comm_bw(sms);
            // HBM traffic rate needed to sustain the link rate.
            d_comm = (k.bytes / k.comm_bytes.max(1.0)) * link_cap;
        }
        // Demand-proportional HBM sharing when oversubscribed.
        let total_d = d_comp + d_comm;
        let scale = if total_d > gpu.mem_bw { gpu.mem_bw / total_d } else { 1.0 };
        let m_comp = d_comp * scale;
        let m_comm = d_comm * scale;

        // Per-kernel completion rates (fraction/s).
        let comp_rate = comp
            .map(|(k, _, _)| {
                let r_flop = if k.flops > 0.0 { flop_cap / k.flops } else { f64::INFINITY };
                let r_mem = if k.bytes > 0.0 { m_comp / k.bytes } else { f64::INFINITY };
                r_flop.min(r_mem)
            })
            .unwrap_or(0.0);
        let comm_rate = comm
            .map(|(k, _, _)| {
                let r_link = link_cap / k.comm_bytes.max(1.0);
                let r_mem = if k.bytes > 0.0 { m_comm / k.bytes } else { f64::INFINITY };
                r_link.min(r_mem)
            })
            .unwrap_or(0.0);

        // Achieved resource rates -> dynamic power.
        let flop_rate = comp.map(|(k, _, _)| comp_rate * k.flops).unwrap_or(0.0);
        let mem_rate = comp.map(|(k, _, _)| comp_rate * k.bytes).unwrap_or(0.0)
            + comm.map(|(k, _, _)| comm_rate * k.bytes).unwrap_or(0.0);
        let link_rate = comm.map(|(k, _, _)| comm_rate * k.comm_bytes).unwrap_or(0.0);
        let fr = f_mhz * 1e6 / gpu.f_max_hz();
        let peak_flops = gpu.n_sms as f64 * gpu.flops_per_sm_per_cycle * f_mhz * 1e6;
        let p_comp = if peak_flops > 0.0 {
            gpu.comp_w_max * fr * fr * fr * (flop_rate / peak_flops).min(1.0)
        } else {
            0.0
        };
        let p_dyn = p_comp + gpu.mem_power(mem_rate) + gpu.comm_power(link_rate);
        (comp_rate, comm_rate, p_dyn, flop_rate, p_comp)
    };

    let f_req = freq_mhz as f64;
    let (comp_rate, comm_rate, p_dyn, _flop_rate, p_comp) = rates_at(f_req);

    let limit = power_limit.unwrap_or(f64::INFINITY);
    if p_static + p_dyn <= limit || p_comp <= 0.0 {
        return SegmentRates { comp_rate, comm_rate, p_dyn, eff_freq_mhz: f_req, throttled: false };
    }

    // Throttling: the power controller oscillates the clock so that average
    // power ≈ limit. Find the balance frequency by bisection on the *true*
    // rates function (utilization shifts as kernels move between memory-
    // and compute-bound regimes, so a constant-utilization f³ solve is not
    // monotone). The oscillation is modeled as a 50/50 duty cycle between
    // f_req and f_lo mirrored around f_bal: time follows the *average*
    // frequency; dynamic compute energy follows the f³ *mixture*, which by
    // Jensen's inequality exceeds running constantly at f_bal (Appendix A)
    // — the effect Kareus exploits in the §6.2.1 case study.
    let mut lo = gpu.f_min_mhz as f64;
    let mut hi = f_req;
    for _ in 0..24 {
        let mid = 0.5 * (lo + hi);
        let (_, _, p_mid, _, _) = rates_at(mid);
        if p_static + p_mid > limit {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    let f_bal = lo;
    let f_lo = (2.0 * f_bal - f_req).max(gpu.f_min_mhz as f64);
    let (comp_rate_b, comm_rate_b, p_dyn_bal, _fr_b, p_comp_bal) = rates_at(f_bal);
    // Jensen penalty on the compute component of dynamic power.
    let mix = if f_bal > 0.0 {
        0.5 * (f_req / f_bal).powi(3) + 0.5 * (f_lo / f_bal).powi(3)
    } else {
        1.0
    };
    let p_dyn_throttled = (p_dyn_bal - p_comp_bal) + p_comp_bal * mix.max(1.0);
    SegmentRates {
        comp_rate: comp_rate_b,
        comm_rate: comm_rate_b,
        p_dyn: p_dyn_throttled,
        eff_freq_mhz: f_bal,
        throttled: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::kernel::KernelKind;

    fn gpu() -> GpuSpec {
        GpuSpec::a100()
    }

    fn linear(flops: f64) -> Kernel {
        Kernel::comp("linear", KernelKind::Linear, flops, flops / 300.0)
    }
    fn norm(bytes: f64) -> Kernel {
        Kernel::comp("norm", KernelKind::Norm, bytes / 8.0, bytes)
    }
    fn allreduce(bytes: f64) -> Kernel {
        Kernel::comm("ar", KernelKind::AllReduce, bytes)
    }

    #[test]
    fn sequential_time_is_sum() {
        let g = gpu();
        let comps = vec![linear(1e11), linear(2e11)];
        let comm = allreduce(1e8);
        let r = execute_partition(&g, &comps, Some(&comm), &Schedule::sequential(1410), 30.0, None);
        let t_comp = 3e11 / g.flop_rate(g.n_sms, 1410);
        let t_comm = 1e8 / g.link_bw;
        assert!((r.time_s - (t_comp + t_comm)).abs() / r.time_s < 0.05, "{}", r.time_s);
        assert!(r.exposed_comm_s > 0.0);
    }

    #[test]
    fn overlap_beats_sequential() {
        // A long compute kernel fully hides a small comm kernel.
        let g = gpu();
        let comps = vec![linear(5e11)];
        let comm = allreduce(1e8);
        let seq_sched = Schedule::sequential(1410);
        let seq = execute_partition(&g, &comps, Some(&comm), &seq_sched, 30.0, None);
        let ovl = execute_partition(
            &g,
            &comps,
            Some(&comm),
            &Schedule { comm_sms: 8, launch: LaunchAt::WithComp(0), freq_mhz: 1410 },
            30.0,
            None,
        );
        assert!(ovl.time_s < seq.time_s, "ovl {} seq {}", ovl.time_s, seq.time_s);
        assert!(ovl.total_j() < seq.total_j());
        assert_eq!(ovl.exposed_comm_s, 0.0);
    }

    #[test]
    fn sm_allocation_sweet_spot() {
        // §3.2.1 / Figure 3(a)-(c): few SMs expose comm; many SMs slow
        // compute. A middle allocation minimizes time.
        let g = gpu();
        let comps = vec![linear(5e11), linear(5e11)];
        let comm = allreduce(3e8);
        let time_at = |sms: u32| {
            execute_partition(
                &g,
                &comps,
                Some(&comm),
                &Schedule { comm_sms: sms, launch: LaunchAt::WithComp(0), freq_mhz: 1410 },
                30.0,
                None,
            )
            .time_s
        };
        let t2 = time_at(2);
        let t12 = time_at(12);
        let t90 = time_at(90);
        assert!(t12 < t2, "mid {t12} vs few {t2}");
        assert!(t12 < t90, "mid {t12} vs many {t90}");
    }

    #[test]
    fn comm_with_membound_kernel_contends() {
        // §3.2.2: a comm kernel overlapped with a memory-bound kernel
        // (Norm) contends for HBM bandwidth and prolongs both, whereas
        // overlapping with a compute-bound Linear (giving up a few SMs)
        // costs almost nothing.
        let g = gpu();
        let comm = allreduce(3e8);
        let sched = Schedule { comm_sms: 12, launch: LaunchAt::WithComp(0), freq_mhz: 1410 };

        // Norm + comm: both memory-bound -> contention prolongs the pair
        // beyond the longer of the two run solo.
        let norm_k = norm(4e9);
        let t_norm_solo =
            execute_partition(&g, &[norm_k.clone()], None, &sched, 30.0, None).time_s;
        let t_comm_solo =
            execute_partition(&g, &[], Some(&comm), &sched, 30.0, None).time_s;
        let t_norm_ovl =
            execute_partition(&g, &[norm_k.clone()], Some(&comm), &sched, 30.0, None).time_s;
        assert!(
            t_norm_ovl > 1.05 * t_norm_solo.max(t_comm_solo),
            "ovl {t_norm_ovl} vs solo {t_norm_solo}/{t_comm_solo}"
        );

        // Linear + comm: near-perfect overlap (no bandwidth contention).
        let lin = linear(6e11);
        let t_lin_solo = execute_partition(&g, &[lin.clone()], None, &sched, 30.0, None).time_s;
        let t_lin_ovl =
            execute_partition(&g, &[lin.clone()], Some(&comm), &sched, 30.0, None).time_s;
        assert!(
            t_lin_ovl < 1.15 * t_lin_solo.max(t_comm_solo),
            "ovl {t_lin_ovl} vs solo {t_lin_solo}/{t_comm_solo}"
        );
        assert!(t_lin_ovl < 0.8 * (t_lin_solo + t_comm_solo));
    }

    #[test]
    fn lower_freq_cuts_dynamic_energy() {
        let g = gpu();
        let comps = vec![linear(5e11)];
        let hi = execute_partition(&g, &comps, None, &Schedule::sequential(1410), 30.0, None);
        let lo = execute_partition(&g, &comps, None, &Schedule::sequential(1110), 30.0, None);
        assert!(lo.dyn_j < hi.dyn_j);
        assert!(lo.time_s > hi.time_s);
        assert!(lo.static_j > hi.static_j); // longer run -> more static
    }

    #[test]
    fn dynamic_energy_schedule_invariant_at_fixed_freq() {
        // §3.1: at the same frequency, dynamic energy is (largely) constant
        // across schedules; static energy varies with time.
        let g = gpu();
        let comps = vec![linear(3e11), norm(1e9), linear(3e11)];
        let comm = allreduce(5e8);
        let mk = |sms, at| {
            execute_partition(
                &g,
                &comps,
                Some(&comm),
                &Schedule { comm_sms: sms, launch: LaunchAt::WithComp(at), freq_mhz: 1410 },
                30.0,
                None,
            )
        };
        let a = mk(4, 0);
        let b = mk(20, 2);
        let rel = (a.dyn_j - b.dyn_j).abs() / a.dyn_j;
        assert!(rel < 0.02, "dyn energy varied {rel}");
        assert!((a.static_j - b.static_j).abs() > 0.0);
    }

    #[test]
    fn throttling_penalizes_fluctuation() {
        // Heavy overlap at max frequency exceeds TDP -> throttled with a
        // Jensen penalty; requesting the balance frequency directly is
        // cheaper at ~equal time (§6.2.1 case study).
        let g = gpu();
        let comps = vec![linear(8e11)];
        let comm = allreduce(2e9);
        let hot = execute_partition(
            &g,
            &comps,
            Some(&comm),
            &Schedule { comm_sms: 24, launch: LaunchAt::WithComp(0), freq_mhz: 1410 },
            60.0,
            Some(g.tdp_w),
        );
        assert!(hot.throttled);
        assert!(hot.avg_freq_mhz < 1409.0, "avg {}", hot.avg_freq_mhz);
        let steady_freq = (hot.avg_freq_mhz / 15.0).round() as u32 * 15;
        let steady = execute_partition(
            &g,
            &comps,
            Some(&comm),
            &Schedule { comm_sms: 24, launch: LaunchAt::WithComp(0), freq_mhz: steady_freq },
            60.0,
            Some(g.tdp_w),
        );
        assert!(steady.time_s <= hot.time_s * 1.02);
        assert!(steady.dyn_j < hot.dyn_j, "steady {} hot {}", steady.dyn_j, hot.dyn_j);
    }

    #[test]
    fn exposed_comm_accounted() {
        let g = gpu();
        let comps = vec![linear(1e10)];
        let comm = allreduce(5e9); // huge comm, tiny compute
        let r = execute_partition(
            &g,
            &comps,
            Some(&comm),
            &Schedule { comm_sms: 30, launch: LaunchAt::WithComp(0), freq_mhz: 1410 },
            30.0,
            None,
        );
        assert!(r.exposed_comm_s > 0.5 * r.time_s);
    }

    #[test]
    fn higher_temp_increases_static_energy() {
        let g = gpu();
        let comps = vec![linear(3e11)];
        let cold = execute_partition(&g, &comps, None, &Schedule::sequential(1410), 30.0, None);
        let hot = execute_partition(&g, &comps, None, &Schedule::sequential(1410), 75.0, None);
        assert!(hot.static_j > cold.static_j);
        assert!((hot.time_s - cold.time_s).abs() < 1e-9);
    }

    #[test]
    fn no_comm_partition_works() {
        let g = gpu();
        let comps = vec![linear(1e11), norm(1e9)];
        let r = execute_partition(
            &g,
            &comps,
            None,
            &Schedule { comm_sms: 0, launch: LaunchAt::WithComp(0), freq_mhz: 1410 },
            30.0,
            None,
        );
        assert!(r.time_s > 0.0);
        assert_eq!(r.exposed_comm_s, 0.0);
    }

    #[test]
    fn late_launch_can_expose_comm() {
        let g = gpu();
        let comps = vec![linear(4e11), linear(4e11)];
        let comm = allreduce(2e9);
        let early = execute_partition(
            &g,
            &comps,
            Some(&comm),
            &Schedule { comm_sms: 12, launch: LaunchAt::WithComp(0), freq_mhz: 1410 },
            30.0,
            None,
        );
        let late = execute_partition(
            &g,
            &comps,
            Some(&comm),
            &Schedule { comm_sms: 12, launch: LaunchAt::WithComp(1), freq_mhz: 1410 },
            30.0,
            None,
        );
        assert!(late.exposed_comm_s >= early.exposed_comm_s);
    }
}
