//! GPU hardware specification and power model.
//!
//! The paper's power model (§2.1, Appendix A): total power = static power
//! (all parts of the chip, always, temperature-dependent leakage) + dynamic
//! power (∝ activity; compute dynamic power ∝ V²f ≈ f³ since voltage scales
//! ~linearly with frequency on NVIDIA parts). Memory and interconnect
//! throughput are frequency-invariant (§3.2.3 footnote 5: lowering core
//! frequency lowers the roofline's compute ceiling only).

/// Hardware spec. Defaults model an NVIDIA A100-SXM4-40GB, the paper's
/// testbed GPU (§6.1), with power split calibrated so that a fully busy
/// GPU at f_max draws ≈ TDP.
#[derive(Clone, Debug)]
pub struct GpuSpec {
    pub name: &'static str,
    /// Streaming multiprocessors available for allocation.
    pub n_sms: u32,
    /// FLOPs per SM per cycle (bf16/fp16 tensor-core path).
    /// 108 SMs × 2048 × 1.41 GHz ≈ 312 TFLOP/s, the A100's tensor peak.
    pub flops_per_sm_per_cycle: f64,
    /// HBM bandwidth, bytes/s (frequency-invariant).
    pub mem_bw: f64,
    /// Effective collective-communication bandwidth per GPU, bytes/s
    /// (NVSwitch intra-node; the workload builder scales volumes so this
    /// single figure suffices).
    pub link_bw: f64,
    /// Link bytes/s contributed by each SM allocated to a communication
    /// kernel (MSCCL++ grid-size model). With 12 GB/s per SM, ~25 SMs
    /// saturate the link — matching the paper's observation that >30 SMs
    /// never helps (Appendix B).
    pub sm_copy_bw: f64,
    /// Supported core frequencies, MHz.
    pub f_min_mhz: u32,
    pub f_max_mhz: u32,
    pub f_stride_mhz: u32,
    /// Static power at reference temperature (P0 "ready" state draw, §2.3
    /// footnote 4), watts.
    pub static_w: f64,
    /// Leakage temperature coefficient: static power multiplier per kelvin
    /// above the reference temperature.
    pub leak_per_k: f64,
    pub ref_temp_c: f64,
    /// Dynamic power of fully-active compute at f_max, watts.
    pub comp_w_max: f64,
    /// Dynamic power of fully-saturated HBM, watts.
    pub mem_w_max: f64,
    /// Dynamic power of a fully-saturated interconnect, watts.
    pub comm_w_max: f64,
    /// Board power limit; sustained draw above this triggers frequency
    /// throttling (§6.2.1 case study).
    pub tdp_w: f64,
    /// Latency of one core-frequency transition, seconds. Kernel-level
    /// DVFS re-clocks mid-partition; each switch stalls both streams for
    /// this long (driver clock-lock reprogramming, tens of microseconds
    /// on locked-clock NVIDIA parts).
    pub freq_switch_s: f64,
    /// Energy overhead of one core-frequency transition, joules (PLL
    /// relock + voltage-regulator settling), on top of the static power
    /// burned during `freq_switch_s`.
    pub freq_switch_j: f64,
}

impl GpuSpec {
    pub fn a100() -> Self {
        GpuSpec {
            name: "A100-SXM4-40GB",
            n_sms: 108,
            flops_per_sm_per_cycle: 2048.0,
            mem_bw: 1.555e12,
            link_bw: 300e9,
            sm_copy_bw: 12e9,
            f_min_mhz: 210,
            f_max_mhz: 1410,
            f_stride_mhz: 15,
            static_w: 90.0,
            leak_per_k: 0.008,
            ref_temp_c: 30.0,
            comp_w_max: 300.0,
            mem_w_max: 90.0,
            comm_w_max: 15.0,
            tdp_w: 400.0,
            freq_switch_s: 50e-6,
            freq_switch_j: 5e-3,
        }
    }

    /// NVIDIA H100-SXM5-80GB: the sweep engine's "next-gen" scenario.
    /// 132 SMs × 4096 × 1.83 GHz ≈ 990 TFLOP/s bf16 dense; HBM3 at
    /// 3.35 TB/s; NVLink4 at 450 GB/s per GPU. Power split calibrated the
    /// same way as the A100's: fully-overlapped max-frequency work exceeds
    /// the 700 W board limit, a typical training mix does not.
    pub fn h100() -> Self {
        GpuSpec {
            name: "H100-SXM5-80GB",
            n_sms: 132,
            flops_per_sm_per_cycle: 4096.0,
            mem_bw: 3.35e12,
            link_bw: 450e9,
            sm_copy_bw: 18e9,
            f_min_mhz: 210,
            f_max_mhz: 1830,
            f_stride_mhz: 15,
            static_w: 120.0,
            leak_per_k: 0.008,
            ref_temp_c: 30.0,
            comp_w_max: 520.0,
            mem_w_max: 110.0,
            comm_w_max: 25.0,
            tdp_w: 700.0,
            freq_switch_s: 40e-6,
            freq_switch_j: 6e-3,
        }
    }

    /// NVIDIA V100-SXM2-32GB: the sweep engine's "legacy" scenario.
    /// 80 SMs × 1024 × 1.53 GHz ≈ 125 TFLOP/s fp16; HBM2 at 0.9 TB/s;
    /// NVLink2 at 150 GB/s per GPU.
    pub fn v100() -> Self {
        GpuSpec {
            name: "V100-SXM2-32GB",
            n_sms: 80,
            flops_per_sm_per_cycle: 1024.0,
            mem_bw: 0.9e12,
            link_bw: 150e9,
            sm_copy_bw: 7.5e9,
            f_min_mhz: 135,
            f_max_mhz: 1530,
            f_stride_mhz: 15,
            static_w: 70.0,
            leak_per_k: 0.008,
            ref_temp_c: 30.0,
            comp_w_max: 180.0,
            mem_w_max: 60.0,
            comm_w_max: 12.0,
            tdp_w: 300.0,
            freq_switch_s: 60e-6,
            freq_switch_j: 4e-3,
        }
    }

    /// Look a spec up by short name (CLI sweep matrices).
    pub fn by_name(name: &str) -> Option<GpuSpec> {
        match name.to_ascii_lowercase().as_str() {
            "a100" => Some(GpuSpec::a100()),
            "h100" => Some(GpuSpec::h100()),
            "v100" => Some(GpuSpec::v100()),
            _ => None,
        }
    }

    /// Stable fingerprint over every physical parameter — part of the
    /// shared measurement-cache key, so two specs that differ in any field
    /// never alias. Exhaustive destructuring (no `..`) makes adding a
    /// field a compile error here rather than a silent stale-cache-hit.
    pub fn fingerprint(&self) -> u64 {
        let GpuSpec {
            name,
            n_sms,
            flops_per_sm_per_cycle,
            mem_bw,
            link_bw,
            sm_copy_bw,
            f_min_mhz,
            f_max_mhz,
            f_stride_mhz,
            static_w,
            leak_per_k,
            ref_temp_c,
            comp_w_max,
            mem_w_max,
            comm_w_max,
            tdp_w,
            freq_switch_s,
            freq_switch_j,
        } = self;
        let mut h = crate::util::hash::Fnv64::new();
        h.write_str(name)
            .write_u64(*n_sms as u64)
            .write_f64(*flops_per_sm_per_cycle)
            .write_f64(*mem_bw)
            .write_f64(*link_bw)
            .write_f64(*sm_copy_bw)
            .write_u64(*f_min_mhz as u64)
            .write_u64(*f_max_mhz as u64)
            .write_u64(*f_stride_mhz as u64)
            .write_f64(*static_w)
            .write_f64(*leak_per_k)
            .write_f64(*ref_temp_c)
            .write_f64(*comp_w_max)
            .write_f64(*mem_w_max)
            .write_f64(*comm_w_max)
            .write_f64(*tdp_w)
            .write_f64(*freq_switch_s)
            .write_f64(*freq_switch_j);
        h.finish()
    }

    #[inline]
    pub fn f_max_hz(&self) -> f64 {
        self.f_max_mhz as f64 * 1e6
    }

    /// Peak FLOP/s with `sms` SMs at `f_mhz`.
    #[inline]
    pub fn flop_rate(&self, sms: u32, f_mhz: u32) -> f64 {
        sms as f64 * self.flops_per_sm_per_cycle * f_mhz as f64 * 1e6
    }

    /// Effective link bandwidth for a communication kernel given its SM
    /// allocation (frequency-invariant).
    #[inline]
    pub fn comm_bw(&self, sms: u32) -> f64 {
        (sms as f64 * self.sm_copy_bw).min(self.link_bw)
    }

    /// Static power at a given die temperature (leakage grows with temp).
    #[inline]
    pub fn static_power(&self, temp_c: f64) -> f64 {
        self.static_w * (1.0 + self.leak_per_k * (temp_c - self.ref_temp_c).max(0.0))
    }

    /// Instantaneous dynamic compute power given the achieved FLOP rate and
    /// frequency: P = comp_w_max · (f/f_max)³ · utilization, where
    /// utilization is the achieved fraction of peak *at that frequency*.
    #[inline]
    pub fn comp_power(&self, flop_rate_achieved: f64, f_mhz: u32) -> f64 {
        let peak = self.flop_rate(self.n_sms, f_mhz);
        if peak <= 0.0 {
            return 0.0;
        }
        let util = (flop_rate_achieved / peak).min(1.0);
        let fr = f_mhz as f64 * 1e6 / self.f_max_hz();
        self.comp_w_max * fr * fr * fr * util
    }

    /// Instantaneous dynamic memory power given achieved HBM traffic rate.
    #[inline]
    pub fn mem_power(&self, mem_rate: f64) -> f64 {
        self.mem_w_max * (mem_rate / self.mem_bw).min(1.0)
    }

    /// Instantaneous dynamic interconnect power.
    #[inline]
    pub fn comm_power(&self, link_rate: f64) -> f64 {
        self.comm_w_max * (link_rate / self.link_bw).min(1.0)
    }

    /// All supported frequencies (hardware stride).
    pub fn all_freqs(&self) -> Vec<u32> {
        (self.f_min_mhz..=self.f_max_mhz).step_by(self.f_stride_mhz as usize).collect()
    }

    /// The MBO search range from Appendix C: 900–1410 MHz at 30 MHz stride
    /// (below ~900 MHz total energy rises again — footnote 11).
    pub fn search_freqs(&self) -> Vec<u32> {
        let lo = 900.max(self.f_min_mhz);
        (lo..=self.f_max_mhz).step_by(2 * self.f_stride_mhz as usize).collect()
    }

    /// Memory-class frequency axis for kernel-level DVFS: the Appendix C
    /// search range plus deeper steps below its 900 MHz floor. The floor
    /// exists because below it *runtime* stretches faster than per-flop
    /// energy falls (footnote 11) — but memory-bound kernels' time is
    /// HBM-limited and frequency-invariant, so for the memory class lower
    /// frequencies keep cutting dynamic compute energy (∝ f²) at zero time
    /// cost until transition overheads dominate. Every entry sits on the
    /// hardware grid (`f_min + k·f_stride`).
    pub fn memory_class_freqs(&self) -> Vec<u32> {
        let floor = 900.max(self.f_min_mhz);
        let mut out: Vec<u32> =
            (self.f_min_mhz..floor).step_by(8 * self.f_stride_mhz as usize).collect();
        out.extend(self.search_freqs());
        out
    }

    /// Dynamic energy per FLOP at frequency f (∝ f², see Appendix A):
    /// power/rate = comp_w_max·(f/fmax)³ / (n_sms·c·f).
    #[inline]
    pub fn energy_per_flop(&self, f_mhz: u32) -> f64 {
        let fr = f_mhz as f64 * 1e6 / self.f_max_hz();
        self.comp_w_max * fr * fr * fr / self.flop_rate(self.n_sms, f_mhz)
    }

    /// Dynamic energy per HBM byte (frequency-invariant).
    #[inline]
    pub fn energy_per_byte(&self) -> f64 {
        self.mem_w_max / self.mem_bw
    }

    /// Dynamic energy per communicated byte.
    #[inline]
    pub fn energy_per_comm_byte(&self) -> f64 {
        self.comm_w_max / self.link_bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a100_tensor_peak() {
        let g = GpuSpec::a100();
        let peak = g.flop_rate(g.n_sms, g.f_max_mhz);
        assert!((peak - 312e12).abs() / 312e12 < 0.01, "peak {peak}");
    }

    #[test]
    fn unconstrained_full_load_exceeds_tdp() {
        // Sustained fully-overlapped max-frequency work must exceed the
        // board limit — that is *why* throttling exists (§6.2.1); real
        // A100s downclock under sustained dense GEMM + comm overlap.
        let g = GpuSpec::a100();
        let p = g.static_power(60.0)
            + g.comp_power(g.flop_rate(g.n_sms, g.f_max_mhz), g.f_max_mhz)
            + g.mem_power(g.mem_bw)
            + g.comm_power(g.link_bw);
        assert!(p > g.tdp_w, "p = {p}");
        // A typical training mix (70% compute util, 50% HBM) fits in TDP.
        let typical = g.static_power(55.0)
            + g.comp_power(0.70 * g.flop_rate(g.n_sms, g.f_max_mhz), g.f_max_mhz)
            + g.mem_power(0.5 * g.mem_bw);
        assert!(typical < g.tdp_w, "typical = {typical}");
    }

    #[test]
    fn energy_per_flop_scales_superlinearly() {
        // e(f) ∝ f²: halving frequency should quarter per-flop energy.
        let g = GpuSpec::a100();
        let hi = g.energy_per_flop(1410);
        let lo = g.energy_per_flop(705);
        assert!((hi / lo - 4.0).abs() < 0.05, "ratio {}", hi / lo);
    }

    #[test]
    fn comm_bw_saturates() {
        let g = GpuSpec::a100();
        assert!(g.comm_bw(2) < g.link_bw);
        assert_eq!(g.comm_bw(25), g.link_bw);
        assert_eq!(g.comm_bw(80), g.link_bw);
    }

    #[test]
    fn static_power_grows_with_temp() {
        let g = GpuSpec::a100();
        assert!(g.static_power(70.0) > g.static_power(30.0));
        assert_eq!(g.static_power(20.0), g.static_w); // clamped below ref
    }

    #[test]
    fn by_name_roundtrip() {
        for n in ["a100", "h100", "v100", "A100"] {
            let g = GpuSpec::by_name(n).unwrap();
            assert!(g.name.to_ascii_lowercase().starts_with(&n.to_ascii_lowercase()[..4]));
        }
        assert!(GpuSpec::by_name("tpu").is_none());
    }

    #[test]
    fn newer_parts_power_model_consistent() {
        for g in [GpuSpec::h100(), GpuSpec::v100()] {
            // Unconstrained full load exceeds TDP (throttling exists)…
            let full = g.static_power(60.0)
                + g.comp_power(g.flop_rate(g.n_sms, g.f_max_mhz), g.f_max_mhz)
                + g.mem_power(g.mem_bw)
                + g.comm_power(g.link_bw);
            assert!(full > g.tdp_w, "{}: full {full}", g.name);
            // …while a typical training mix fits.
            let typical = g.static_power(55.0)
                + g.comp_power(0.70 * g.flop_rate(g.n_sms, g.f_max_mhz), g.f_max_mhz)
                + g.mem_power(0.5 * g.mem_bw);
            assert!(typical < g.tdp_w, "{}: typical {typical}", g.name);
            // Search range is non-empty and ends at f_max.
            let s = g.search_freqs();
            assert!(s.len() >= 10, "{}: {} freqs", g.name, s.len());
            assert_eq!(*s.last().unwrap(), g.f_max_mhz);
        }
    }

    #[test]
    fn fingerprints_distinguish_specs() {
        let a = GpuSpec::a100().fingerprint();
        let h = GpuSpec::h100().fingerprint();
        let v = GpuSpec::v100().fingerprint();
        assert!(a != h && h != v && a != v);
        assert_eq!(a, GpuSpec::a100().fingerprint());
        let mut tweaked = GpuSpec::a100();
        tweaked.static_w += 1.0;
        assert_ne!(a, tweaked.fingerprint());
        // The frequency-transition cost model is part of the identity.
        let mut sw = GpuSpec::a100();
        sw.freq_switch_s *= 2.0;
        assert_ne!(a, sw.fingerprint());
        let mut sj = GpuSpec::a100();
        sj.freq_switch_j += 1e-3;
        assert_ne!(a, sj.fingerprint());
    }

    #[test]
    fn switch_costs_are_small_but_positive() {
        for g in [GpuSpec::a100(), GpuSpec::h100(), GpuSpec::v100()] {
            assert!(g.freq_switch_s > 0.0 && g.freq_switch_s < 1e-3, "{}", g.name);
            assert!(g.freq_switch_j > 0.0 && g.freq_switch_j < 0.1, "{}", g.name);
        }
    }

    #[test]
    fn freq_lists() {
        let g = GpuSpec::a100();
        let all = g.all_freqs();
        assert_eq!(all.first(), Some(&210));
        assert_eq!(all.last(), Some(&1410));
        assert_eq!(all.len(), 81);
        let search = g.search_freqs();
        assert_eq!(search.first(), Some(&900));
        assert_eq!(search.len(), 18);
    }

    #[test]
    fn memory_class_freqs_extend_search_range_downward() {
        for g in [GpuSpec::a100(), GpuSpec::h100(), GpuSpec::v100()] {
            let m = g.memory_class_freqs();
            let s = g.search_freqs();
            // Superset of the search range, sorted, on the hardware grid.
            assert!(m.len() > s.len(), "{}", g.name);
            assert!(m.windows(2).all(|w| w[0] < w[1]), "{}", g.name);
            for f in &m {
                assert!(*f >= g.f_min_mhz && *f <= g.f_max_mhz);
                assert_eq!((f - g.f_min_mhz) % g.f_stride_mhz, 0, "{}: {f}", g.name);
            }
            assert!(m.ends_with(&s), "{}: search range must be the tail", g.name);
            assert_eq!(m[0], g.f_min_mhz);
        }
    }
}
