//! Candidate search space for one partition (§4.1 decision variables,
//! Appendix C ranges).

use crate::partition::Partition;
use crate::sim::exec::{KernelFreqs, LaunchAt, Schedule};
use crate::sim::gpu::GpuSpec;

/// Frequency-assignment granularity of the candidate space (the
/// kernel-level DVFS axis).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum FreqGranularity {
    /// One uniform frequency per partition schedule (the paper's model;
    /// every emitted schedule is [`KernelFreqs::Uniform`]).
    #[default]
    Partition,
    /// Per-kernel-class frequencies: the compute class sweeps the search
    /// range as before while the memory class independently sweeps
    /// [`GpuSpec::memory_class_freqs`]; every emitted schedule is
    /// [`KernelFreqs::PerClass`].
    KernelClass,
}

impl FreqGranularity {
    pub fn as_str(&self) -> &'static str {
        match self {
            FreqGranularity::Partition => "partition",
            FreqGranularity::KernelClass => "kernel",
        }
    }

    pub fn parse(spec: &str) -> Option<FreqGranularity> {
        match spec {
            "partition" => Some(FreqGranularity::Partition),
            "kernel" | "kernel-class" => Some(FreqGranularity::KernelClass),
            _ => None,
        }
    }
}

/// Enumerate the candidate schedules for a partition.
///
/// * Frequency: 900–1410 MHz at 30 MHz stride (App. C).
/// * SM allocation: comm group < 4 GPUs → 1..=20 step 1;
///   group ≥ 4 → 3..=30 step 3 (App. C).
/// * Launch timing: any computation kernel index whose remaining compute
///   can possibly cover the communication; options that *always* expose
///   communication (e.g. launching with the last Linear2, Figure 3a) are
///   excluded (App. C).
pub fn candidate_space(gpu: &GpuSpec, part: &Partition, comm_group: u32) -> Vec<Schedule> {
    candidate_space_with(gpu, part, comm_group, FreqGranularity::Partition)
}

/// [`candidate_space`] with an explicit frequency granularity.
/// `Partition` reproduces the legacy space exactly (same schedules, same
/// order); `KernelClass` multiplies in a memory-class frequency axis, so
/// the census arithmetic becomes |freqs| × |mem freqs| × |SMs| × |timings|.
pub fn candidate_space_with(
    gpu: &GpuSpec,
    part: &Partition,
    comm_group: u32,
    granularity: FreqGranularity,
) -> Vec<Schedule> {
    let freqs = gpu.search_freqs();
    let sms = sm_allocations(comm_group);
    let timings = launch_timings(gpu, part);
    let mem_freqs: Vec<u32> = match granularity {
        FreqGranularity::Partition => Vec::new(),
        FreqGranularity::KernelClass => gpu.memory_class_freqs(),
    };
    let kf_options = |f: u32| -> Vec<KernelFreqs> {
        if mem_freqs.is_empty() {
            vec![KernelFreqs::Uniform]
        } else {
            mem_freqs
                .iter()
                .map(|&m| KernelFreqs::PerClass { compute_mhz: f, memory_mhz: m })
                .collect()
        }
    };
    let per_freq = mem_freqs.len().max(1);
    let mut out = Vec::with_capacity(freqs.len() * per_freq * sms.len() * timings.len());
    for &f in &freqs {
        for kf in kf_options(f) {
            if part.comm.is_none() {
                // No communication: only the frequency axes matter.
                out.push(Schedule {
                    comm_sms: 0,
                    launch: LaunchAt::WithComp(0),
                    freq_mhz: f,
                    kernel_freqs: kf,
                });
                continue;
            }
            for &s in &sms {
                for &t in &timings {
                    out.push(Schedule {
                        comm_sms: s,
                        launch: LaunchAt::WithComp(t),
                        freq_mhz: f,
                        kernel_freqs: kf,
                    });
                }
            }
        }
    }
    out
}

pub fn sm_allocations(comm_group: u32) -> Vec<u32> {
    if comm_group < 4 {
        (1..=20).collect()
    } else {
        (1..=10).map(|i| 3 * i).collect()
    }
}

/// Launch-timing options: computation kernel indices, pruned of positions
/// from which the communication can never finish before the computation
/// stream does (always-exposed; App. C).
pub fn launch_timings(gpu: &GpuSpec, part: &Partition) -> Vec<usize> {
    let Some(comm) = &part.comm else { return vec![0] };
    // Fastest possible comm: full search-range SM allocation.
    let t_comm_min = comm.comm_bytes / gpu.comm_bw(30);
    let mut out = Vec::new();
    for i in 0..part.comps.len() {
        // Compute time from kernel i to the end at f_max with all SMs —
        // the loosest bound on how much overlap room remains.
        let t_rest: f64 = part.comps[i..]
            .iter()
            .map(|k| {
                (k.flops / gpu.flop_rate(gpu.n_sms, gpu.f_max_mhz)).max(k.bytes / gpu.mem_bw)
            })
            .sum();
        if t_rest >= t_comm_min || i == 0 {
            out.push(i);
        }
    }
    out
}

/// Feature vector for the surrogate models: [freq, sms, launch index] for
/// uniform-frequency schedules, plus the memory-class frequency as a 4th
/// feature for per-class schedules. Any one candidate space is homogeneous
/// in [`KernelFreqs`] variant, so feature width is uniform per space.
pub fn features(s: &Schedule) -> Vec<f64> {
    let launch = match s.launch {
        LaunchAt::Sequential => -1.0,
        LaunchAt::WithComp(i) => i as f64,
    };
    match s.kernel_freqs {
        KernelFreqs::Uniform => vec![s.freq_mhz as f64, s.comm_sms as f64, launch],
        KernelFreqs::PerClass { memory_mhz, .. } => {
            vec![s.freq_mhz as f64, s.comm_sms as f64, launch, memory_mhz as f64]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::kernel::{Kernel, KernelKind};

    fn part(comm_bytes: f64) -> Partition {
        Partition {
            ptype: "fwd/attn".into(),
            comps: vec![
                Kernel::comp("norm", KernelKind::Norm, 1e8, 8e8),
                Kernel::comp("linear1", KernelKind::Linear, 4e11, 2e9),
                Kernel::comp("flash", KernelKind::FlashAttention, 2e11, 1e9),
                Kernel::comp("linear2", KernelKind::Linear, 4e11, 2e9),
            ],
            comm: Some(Kernel::comm("ar", KernelKind::AllReduce, comm_bytes)),
            count: 28,
        }
    }

    #[test]
    fn space_size_matches_appendix_c_shape() {
        let g = GpuSpec::a100();
        let p = part(4e8);
        let space = candidate_space(&g, &p, 8);
        // 18 freqs × 10 SM choices × ≤4 timings.
        assert!(space.len() <= 18 * 10 * 4);
        assert!(space.len() >= 18 * 10 * 2, "len {}", space.len());
    }

    #[test]
    fn small_group_fine_grained_sms() {
        assert_eq!(sm_allocations(2), (1..=20).collect::<Vec<u32>>());
        assert_eq!(sm_allocations(8), vec![3, 6, 9, 12, 15, 18, 21, 24, 27, 30]);
    }

    #[test]
    fn always_exposed_timings_pruned() {
        let g = GpuSpec::a100();
        // Huge comm: only early launch indices can cover it.
        let p = part(6e9);
        let timings = launch_timings(&g, &p);
        assert!(timings.contains(&0));
        assert!(!timings.contains(&3), "launching at the last kernel always exposes: {timings:?}");
    }

    #[test]
    fn no_comm_partition_single_knob() {
        let g = GpuSpec::a100();
        let mut p = part(1e8);
        p.comm = None;
        let space = candidate_space(&g, &p, 8);
        assert_eq!(space.len(), g.search_freqs().len());
    }

    #[test]
    fn features_roundtrip() {
        let s = Schedule::uniform(12, LaunchAt::WithComp(2), 1200);
        assert_eq!(features(&s), vec![1200.0, 12.0, 2.0]);
        // Per-class schedules expose the memory frequency as a 4th feature.
        let k = Schedule {
            kernel_freqs: KernelFreqs::PerClass { compute_mhz: 1200, memory_mhz: 690 },
            ..s
        };
        assert_eq!(features(&k), vec![1200.0, 12.0, 2.0, 690.0]);
    }

    #[test]
    fn no_comm_partition_exactly_one_candidate_per_frequency() {
        // Without communication the only knob is frequency: the space must
        // contain each search frequency exactly once, with the SM/launch
        // fields pinned to their neutral values.
        let g = GpuSpec::a100();
        let mut p = part(1e8);
        p.comm = None;
        let space = candidate_space(&g, &p, 8);
        let freqs = g.search_freqs();
        assert_eq!(space.len(), freqs.len());
        for (s, &f) in space.iter().zip(freqs.iter()) {
            assert_eq!(s.freq_mhz, f);
            assert_eq!(s.comm_sms, 0);
            assert_eq!(s.launch, LaunchAt::WithComp(0));
        }
    }

    #[test]
    fn comm_group_boundary_switches_sm_ranges_at_four() {
        // Appendix C: groups below 4 GPUs search 1..=20 step 1; groups of
        // 4 and above search 3..=30 step 3. The boundary sits exactly at
        // comm_group == 4.
        assert_eq!(sm_allocations(3), (1..=20).collect::<Vec<u32>>());
        assert_eq!(sm_allocations(4), (1..=10).map(|i| 3 * i).collect::<Vec<u32>>());
        assert_eq!(sm_allocations(3).len(), 20);
        assert_eq!(sm_allocations(4).len(), 10);
        // The boundary is visible in the candidate space itself.
        let g = GpuSpec::a100();
        let p = part(4e8);
        let timings = launch_timings(&g, &p).len();
        let small = candidate_space(&g, &p, 3);
        let large = candidate_space(&g, &p, 4);
        assert_eq!(small.len(), g.search_freqs().len() * 20 * timings);
        assert_eq!(large.len(), g.search_freqs().len() * 10 * timings);
    }

    #[test]
    fn candidate_counts_match_census_arithmetic() {
        // The enumerated space must be the exact product the Appendix B
        // census arithmetic predicts: |freqs| × |SM choices| × |timings|.
        let g = GpuSpec::a100();
        let p = part(4e8);
        let freqs = g.search_freqs().len();
        for group in [2u32, 8] {
            let expected = freqs * sm_allocations(group).len() * launch_timings(&g, &p).len();
            assert_eq!(candidate_space(&g, &p, group).len(), expected);
        }
        // And the census's own product identity holds for its shape.
        let c = crate::mbo::exhaustive::census(9, 13.0, 16);
        assert_eq!(c.total, c.n_freqs * c.n_sms * c.n_groupings);
    }

    #[test]
    fn partition_granularity_is_the_legacy_space() {
        let g = GpuSpec::a100();
        let p = part(4e8);
        let legacy = candidate_space(&g, &p, 8);
        let explicit = candidate_space_with(&g, &p, 8, FreqGranularity::Partition);
        assert_eq!(legacy, explicit);
        assert!(legacy.iter().all(|s| s.kernel_freqs == KernelFreqs::Uniform));
    }

    #[test]
    fn kernel_class_space_is_the_full_product() {
        let g = GpuSpec::a100();
        let p = part(4e8);
        let space = candidate_space_with(&g, &p, 8, FreqGranularity::KernelClass);
        let expected = g.search_freqs().len()
            * g.memory_class_freqs().len()
            * sm_allocations(8).len()
            * launch_timings(&g, &p).len();
        assert_eq!(space.len(), expected);
        // Homogeneously per-class, base frequency == compute frequency, and
        // every frequency on the hardware grid.
        for s in &space {
            match s.kernel_freqs {
                KernelFreqs::PerClass { compute_mhz, memory_mhz } => {
                    assert_eq!(compute_mhz, s.freq_mhz);
                    assert_eq!((memory_mhz - g.f_min_mhz) % g.f_stride_mhz, 0);
                    assert!(memory_mhz >= g.f_min_mhz && memory_mhz <= g.f_max_mhz);
                }
                KernelFreqs::Uniform => panic!("kernel-class space emitted a Uniform schedule"),
            }
        }
        // No-comm partitions keep one candidate per frequency *pair*.
        let mut nc = part(1e8);
        nc.comm = None;
        let nc_space = candidate_space_with(&g, &nc, 8, FreqGranularity::KernelClass);
        assert_eq!(nc_space.len(), g.search_freqs().len() * g.memory_class_freqs().len());
    }

    #[test]
    fn kernel_class_space_contains_every_uniform_point() {
        // For each search frequency f the pair (compute=f, memory=f) is in
        // the space; it executes bit-identically to Uniform{f}, so the
        // kernel-level frontier can never be worse than partition-level.
        let g = GpuSpec::a100();
        let p = part(4e8);
        let space = candidate_space_with(&g, &p, 8, FreqGranularity::KernelClass);
        for &f in &g.search_freqs() {
            let diag = KernelFreqs::PerClass { compute_mhz: f, memory_mhz: f };
            assert!(
                space.iter().any(|s| s.kernel_freqs == diag),
                "missing diagonal pair at {f} MHz"
            );
        }
    }

    #[test]
    fn granularity_names_roundtrip() {
        for gr in [FreqGranularity::Partition, FreqGranularity::KernelClass] {
            assert_eq!(FreqGranularity::parse(gr.as_str()), Some(gr));
        }
        assert_eq!(FreqGranularity::parse("kernel-class"), Some(FreqGranularity::KernelClass));
        assert_eq!(FreqGranularity::parse("per-kernel"), None);
        assert_eq!(FreqGranularity::default(), FreqGranularity::Partition);
    }
}
