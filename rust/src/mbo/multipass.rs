//! The paper's multi-pass MBO (§4.3, Algorithm 1) as a
//! [`SearchStrategy`].
//!
//! Two GBDT surrogates (time, dynamic energy), three hypervolume-
//! improvement exploitation passes (total / dynamic / static energy) that
//! expand the frontier in complementary directions (Figure 7), plus one
//! bootstrap-ensemble uncertainty exploration pass. Hyperparameters follow
//! Appendix C (sample sizes by partition size class, pass proportions
//! 0.4/0.2/0.2/0.2, stopping on relative HV improvement via the shared
//! [`EvalBudget`]).
//!
//! Parity is load-bearing: for identical hyperparameters and seeds this
//! strategy reproduces the pre-refactor monolithic `optimize_partition`
//! byte-for-byte — same RNG stream, same evaluation order, same frontier
//! bits — which `tests/strategy.rs` and the engine cache tests enforce.

use crate::surrogate::{Ensemble, EnsembleParams, Gbdt, GbdtParams};
use crate::util::hash::fnv1a_str;
use crate::util::rng::Rng;

use super::strategy::SearchStrategy;
use super::{space, EvalBudget, EvalContext, MboParams, MboParamsError, MboResult, Pass};

/// Multi-pass MBO over a partition's joint (frequency × SM × launch
/// timing) space.
pub struct MultiPassMbo {
    params: MboParams,
}

impl MultiPassMbo {
    /// Validates the hyperparameters up front ([`MboParams::validate`]):
    /// pass fractions summing past 1.0 or a zero batch/initial-design size
    /// are configuration bugs, not search settings.
    pub fn new(params: MboParams) -> Result<Self, MboParamsError> {
        params.validate()?;
        Ok(MultiPassMbo { params })
    }

    pub fn params(&self) -> &MboParams {
        &self.params
    }
}

impl SearchStrategy for MultiPassMbo {
    fn name(&self) -> &'static str {
        "mbo"
    }

    fn fingerprint(&self) -> u64 {
        fnv1a_str(self.name())
    }

    fn optimize(&self, ctx: &mut EvalContext<'_>) -> MboResult {
        let params = &self.params;
        ctx.set_budget(EvalBudget::from_params(params));
        let n = ctx.n_candidates();
        let mut rng = Rng::new(params.seed ^ 0x5eed);

        // --- Initial random design ------------------------------------
        // A warm-started context already carries measurements: only the
        // *remaining* initial-design quota is sampled, and candidates the
        // prior search measured are never re-measured. Cold contexts take
        // the exact pre-refactor path (same RNG stream, same order), so
        // byte-parity with the monolith is preserved.
        let n_init = params.n_init.min(n).saturating_sub(ctx.measured());
        for idx in rng.sample_indices(n, n_init) {
            if !ctx.is_chosen(idx) {
                ctx.measure(idx, Pass::Init);
            }
        }
        let exhausted = ctx.measured() >= n;

        if !exhausted {
            for _batch in 0..params.b_max {
                let t0 = std::time::Instant::now();
                // ---- Train surrogates on D ---------------------------
                let x: Vec<Vec<f64>> =
                    ctx.evaluated().iter().map(|e| space::features(&e.sched)).collect();
                let y_t: Vec<f64> = ctx.evaluated().iter().map(|e| e.m.time_s).collect();
                let y_e: Vec<f64> = ctx.evaluated().iter().map(|e| e.m.dyn_j).collect();
                let gp = GbdtParams { seed: params.seed, subsample: 1.0, ..Default::default() };
                let t_hat = Gbdt::fit(&x, &y_t, &gp);
                let e_hat = Gbdt::fit(&x, &y_e, &gp);
                let ens_p = EnsembleParams {
                    size: params.ensemble_size,
                    bootstrap_fraction: params.bootstrap_fraction,
                    gbdt: GbdtParams {
                        seed: params.seed ^ 0xE45,
                        subsample: 0.8,
                        ..Default::default()
                    },
                };
                let t_ens = Ensemble::fit(&x, &y_t, &ens_p);
                let e_ens = Ensemble::fit(&x, &y_e, &ens_p);

                // ---- Current frontiers on each objective plane --------
                // Maintained incrementally by the context's planes; the
                // references all follow Appendix C's 1.1× rule.
                let p_static = ctx.gpu().static_w;
                let (r_tot, r_dyn, r_stat) = ctx.planes().references();

                // ---- Score all unevaluated candidates -----------------
                // One batched call per model over all remaining candidates
                // (tree-outer, cache-hot) instead of four model walks per
                // candidate; bitwise-equal to per-row predict by the
                // surrogate's batching contract. The normalizing sums are
                // loop-invariant and hoisted (the per-candidate recompute
                // produced the identical value each iteration).
                let mut rem: Vec<usize> = Vec::new();
                let mut feats: Vec<Vec<f64>> = Vec::new();
                for (idx, s) in ctx.space().iter().enumerate() {
                    if !ctx.is_chosen(idx) {
                        rem.push(idx);
                        feats.push(space::features(s));
                    }
                }
                let (mut th_all, mut eh_all) = (Vec::new(), Vec::new());
                t_hat.predict_batch(&feats, &mut th_all);
                e_hat.predict_batch(&feats, &mut eh_all);
                let (mut ts_all, mut es_all) = (Vec::new(), Vec::new());
                t_ens.predict_batch(&feats, &mut ts_all);
                e_ens.predict_batch(&feats, &mut es_all);
                let sum_t = y_t.iter().sum::<f64>().max(1e-12);
                let sum_e = y_e.iter().sum::<f64>().max(1e-12);
                // (idx, hvi_tot, hvi_dyn, hvi_stat, unc) per candidate.
                let mut cand: Vec<(usize, f64, f64, f64, f64)> =
                    Vec::with_capacity(rem.len());
                {
                    let planes = ctx.planes();
                    for (c, &idx) in rem.iter().enumerate() {
                        let th = th_all[c].max(1e-9);
                        let eh = eh_all[c].max(0.0);
                        let hvi_tot = planes.f_tot.hvi((th, th * p_static + eh), r_tot);
                        let hvi_dyn = planes.f_dyn.hvi((th, eh), r_dyn);
                        let hvi_stat = planes.f_stat.hvi((th, th * p_static), r_stat);
                        let (_, st) = ts_all[c];
                        let (_, se) = es_all[c];
                        // Sum of per-objective std deviations (§4.3.2).
                        let unc = st / sum_t * y_t.len() as f64
                            + se / sum_e * y_e.len() as f64;
                        cand.push((idx, hvi_tot, hvi_dyn, hvi_stat, unc));
                    }
                }
                ctx.charge_surrogate(t0.elapsed().as_secs_f64());
                if cand.is_empty() {
                    break;
                }

                // ---- Multi-pass candidate selection -------------------
                let k = params.batch_k.min(cand.len());
                let k1 = ((k as f64 * params.pass_fracs[0]).round() as usize).max(1);
                let k2 = ((k as f64 * params.pass_fracs[1]).round() as usize).max(1);
                let k3 = ((k as f64 * params.pass_fracs[2]).round() as usize).max(1);
                let mut picked: Vec<(usize, Pass)> = Vec::new();
                let mut taken = vec![false; n];
                let top_by = |key: usize,
                              count: usize,
                              pass: Pass,
                              picked: &mut Vec<(usize, Pass)>,
                              taken: &mut Vec<bool>| {
                    let mut order: Vec<&(usize, f64, f64, f64, f64)> =
                        cand.iter().filter(|c| !taken[c.0]).collect();
                    order.sort_by(|a, b| {
                        let va = [a.1, a.2, a.3, a.4][key];
                        let vb = [b.1, b.2, b.3, b.4][key];
                        vb.partial_cmp(&va).unwrap()
                    });
                    for c in order.into_iter().take(count) {
                        taken[c.0] = true;
                        picked.push((c.0, pass));
                    }
                };
                top_by(0, k1, Pass::Total, &mut picked, &mut taken);
                top_by(1, k2, Pass::Dynamic, &mut picked, &mut taken);
                top_by(2, k3, Pass::Static, &mut picked, &mut taken);
                let rest = k.saturating_sub(picked.len());
                top_by(3, rest, Pass::Uncertainty, &mut picked, &mut taken);

                // ---- Evaluate the batch -------------------------------
                for (idx, pass) in picked {
                    ctx.measure(idx, pass);
                }

                // ---- Stopping: relative HV improvement ----------------
                // The total-energy plane already reflects the new batch;
                // its reference tracks the worst coordinates seen so far.
                ctx.record_hv();
                if ctx.hv_converged() {
                    break;
                }
            }
        }

        ctx.finish()
    }
}
