//! Exhaustive search oracle + Appendix B solution-space census.
//!
//! The paper motivates MBO by the size of the joint space (85,050
//! candidates ≈ 4,912 GPU·h of thermally-stable profiling on an A100).
//! Exhaustive evaluation is only feasible against the *simulator's*
//! noise-free oracle (`Profiler::true_eval`), which is exactly what we use
//! it for: ground truth in tests and the §6.6-style comparison.

use crate::frontier::{Frontier, Point};
use crate::partition::Partition;
use crate::profiler::Profiler;
use crate::sim::gpu::GpuSpec;

use super::{space, MboResult};

/// Evaluate every candidate with the noise-free oracle; return the true
/// frontier on the (time, total energy) plane.
pub fn exhaustive_frontier(gpu: &GpuSpec, part: &Partition, comm_group: u32) -> Frontier {
    exhaustive_frontier_with(gpu, part, comm_group, space::FreqGranularity::Partition)
}

/// [`exhaustive_frontier`] over the candidate space of an explicit
/// frequency granularity — the ground truth the kernel-dvfs ablation
/// compares per-class against partition-level frontiers with.
pub fn exhaustive_frontier_with(
    gpu: &GpuSpec,
    part: &Partition,
    comm_group: u32,
    granularity: space::FreqGranularity,
) -> Frontier {
    let cands = space::candidate_space_with(gpu, part, comm_group, granularity);
    let pts: Vec<Point> = cands
        .iter()
        .enumerate()
        .map(|(i, s)| {
            let m = Profiler::true_eval(gpu, part, s);
            Point::new(m.time_s, m.energy_j, i)
        })
        .collect();
    Frontier::from_points(pts)
}

/// Noise-free re-evaluation of a search result's frontier schedules —
/// the fair quality view for oracle comparisons (measured values carry
/// load-temperature leakage and counter noise that the oracle does not).
/// One definition shared by the strategy ablation (`paper --exp
/// strategies`) and the quality bounds in `tests/strategy.rs`, so the
/// published table and the CI guarantee measure the same quantity.
pub fn true_frontier(gpu: &GpuSpec, part: &Partition, r: &MboResult) -> Frontier {
    Frontier::from_points(
        r.frontier
            .points()
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let m = Profiler::true_eval(gpu, part, &r.evaluated[p.tag].sched);
                Point::new(m.time_s, m.energy_j, i)
            })
            .collect(),
    )
}

/// Appendix B census of the *global* (un-partitioned) solution space for a
/// typical transformer block on an A100.
#[derive(Clone, Copy, Debug)]
pub struct SpaceCensus {
    pub n_freqs: usize,
    pub n_sms: usize,
    pub n_groupings: usize,
    pub total: usize,
    /// Profiling cost at ~13 s/candidate + measurement repetition —
    /// the paper quotes up to 4,912 GPU-hours.
    pub profiling_gpu_hours: f64,
}

/// The paper's arithmetic: 35 frequencies (900–1410 @ 15 MHz) × 30 SM
/// choices × 81 launch-timing groupings = 85,050 candidates; at ~13 s and
/// 16 GPUs profiling in lockstep the quoted exhaustive cost follows.
pub fn census(n_comp_ops: usize, seconds_per_candidate: f64, n_gpus: u32) -> SpaceCensus {
    let n_freqs = (1410 - 900) / 15 + 1; // 35
    let n_sms = 30;
    // (start, overlap length) pairs with length capped at n_comp_ops.
    let n_groupings = n_comp_ops * n_comp_ops; // 81 for 9 ops
    let total = n_freqs as usize * n_sms * n_groupings;
    SpaceCensus {
        n_freqs: n_freqs as usize,
        n_sms,
        n_groupings,
        total,
        profiling_gpu_hours: total as f64 * seconds_per_candidate * n_gpus as f64 / 3600.0,
    }
}

/// Appendix B launch-timing DP: Pareto frontier over interleavings of two
/// dependency-free operation sequences where the communication may overlap
/// a contiguous computation subsequence. Operations are (time, energy)
/// atoms; `overlap(i, j..j+k)` costs are supplied by the caller (here: the
/// simulator). Counts subproblems as a byproduct.
pub fn count_dp_subproblems(n_comp: usize, cap: usize) -> usize {
    // Overlapped patterns: start × capped length; plus the non-overlapped
    // sequential placements of the comm (before/between/after each comp).
    let overlapped: usize = n_comp * cap.min(n_comp);
    let sequential = n_comp + 1;
    overlapped + sequential
}

/// The Appendix B recurrence instantiated over our execution model, for a
/// fixed (frequency, SM allocation).
///
/// In the paper's runtime, an overlap pattern is (start, length) — the
/// comm kernel can be *held* to span a chosen subsequence. In our
/// event-driven executor the comm runs to completion once launched, so
/// the (start, length) family collapses to the launch start; the
/// remaining distinct plans are:
///   · overlapped: launch together with computation kernel i (n plans),
///   · sequential: run the comm solo inserted at position p — before,
///     between, or after the computations (n+1 plans).
/// Returns the Pareto frontier over all 2n+1 plans; tags index the plan
/// list (0..n = overlap starts, n..2n+1 = insertions).
pub fn launch_timing_frontier(
    gpu: &GpuSpec,
    part: &Partition,
    freq_mhz: u32,
    comm_sms: u32,
) -> Frontier {
    use crate::sim::exec::{execute_partition, LaunchAt, Schedule};
    let n = part.comps.len();
    let limit = Some(gpu.tdp_w);
    let temp = gpu.ref_temp_c;
    let mut pts: Vec<Point> = Vec::new();
    // Overlapped starts.
    for i in 0..n {
        let s = Schedule::uniform(comm_sms, LaunchAt::WithComp(i), freq_mhz);
        let r = execute_partition(gpu, &part.comps, part.comm.as_ref(), &s, temp, limit);
        pts.push(Point::new(r.time_s, r.total_j(), i));
    }
    // Sequential insertions: prefix solo + comm solo (at its SM-limited
    // rate) + suffix solo. Position is irrelevant to totals in our model
    // (no inter-kernel state), but enumerate for fidelity to the DP.
    for p in 0..=n {
        let s = Schedule::uniform(comm_sms, LaunchAt::WithComp(0), freq_mhz);
        let prefix = execute_partition(gpu, &part.comps[..p], None, &s, temp, limit);
        let comm = execute_partition(gpu, &[], part.comm.as_ref(), &s, temp, limit);
        let suffix = execute_partition(gpu, &part.comps[p..], None, &s, temp, limit);
        pts.push(Point::new(
            prefix.time_s + comm.time_s + suffix.time_s,
            prefix.total_j() + comm.total_j() + suffix.total_j(),
            n + p,
        ));
    }
    Frontier::from_points(pts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::kernel::{Kernel, KernelKind};

    #[test]
    fn census_matches_paper_numbers() {
        let c = census(9, 13.0, 16);
        assert_eq!(c.n_freqs, 35);
        assert_eq!(c.n_groupings, 81);
        assert_eq!(c.total, 85_050);
        // Paper: "up to 4,912 GPU-hours".
        let rel_err = (c.profiling_gpu_hours - 4912.0).abs() / 4912.0;
        assert!(rel_err < 0.01, "{}", c.profiling_gpu_hours);
    }

    #[test]
    fn dp_subproblem_count() {
        // 9 comps, cap 9: 81 overlapped + 10 sequential = 91 (App. B).
        assert_eq!(count_dp_subproblems(9, 9), 91);
    }

    #[test]
    fn dp_launch_frontier_covers_overlap_and_sequential() {
        let gpu = GpuSpec::a100();
        let part = Partition {
            ptype: "t".into(),
            comps: vec![
                Kernel::comp("norm", KernelKind::Norm, 1e8, 4e9),
                Kernel::comp("lin1", KernelKind::Linear, 5e11, 2e9),
                Kernel::comp("lin2", KernelKind::Linear, 5e11, 2e9),
            ],
            comm: Some(Kernel::comm("ar", KernelKind::AllReduce, 4e8)),
            count: 1,
        };
        let f = launch_timing_frontier(&gpu, &part, 1410, 12);
        assert!(!f.is_empty());
        // Each plan tag must be one of the 2n+1 DP subproblems.
        let n = part.comps.len();
        for p in f.points() {
            assert!(p.tag < 2 * n + 1);
        }
        // With a hideable comm, some overlapped plan must dominate every
        // sequential insertion (overlap saves the exposed comm time).
        let best = f.min_time().unwrap();
        assert!(best.tag < n, "best plan should be overlapped, got tag {}", best.tag);
    }

    #[test]
    fn exhaustive_frontier_nonempty_and_valid() {
        let gpu = GpuSpec::a100();
        let part = Partition {
            ptype: "t".into(),
            comps: vec![
                Kernel::comp("n", KernelKind::Norm, 1e8, 8e8),
                Kernel::comp("l", KernelKind::Linear, 5e11, 2e9),
            ],
            comm: Some(Kernel::comm("ar", KernelKind::AllReduce, 4e8)),
            count: 1,
        };
        let f = exhaustive_frontier(&gpu, &part, 8);
        assert!(f.len() >= 3);
        // Frontier must be strictly decreasing in energy as time grows.
        for w in f.points().windows(2) {
            assert!(w[1].time > w[0].time && w[1].energy < w[0].energy);
        }
    }
}
