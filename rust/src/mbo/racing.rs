//! Racing and baseline search strategies.
//!
//! [`RandomSearch`] is the ablation baseline: uniform sampling at the
//! multi-pass MBO's measurement budget. [`SuccessiveHalving`] is the
//! racing strategy (Hyperband-style): screen the *whole* candidate space
//! with cheap low-repetition probes — short measurement windows that alias
//! against the energy counter's 100 ms cadence, so they are noisy but ~an
//! order of magnitude cheaper — then shrink the survivor pool by `eta` per
//! round at increasing fidelity, and finally re-measure the survivors at
//! full fidelity. Survivor selection peels Pareto layers of the probed
//! (time, energy) values, so the racer preserves the whole time–energy
//! trade-off rather than a single scalar objective. The result: near-
//! oracle frontiers for strictly fewer simulated profiling seconds than
//! the multi-pass MBO spends (enforced by `tests/strategy.rs`).

use crate::frontier::{Frontier, Point};
use crate::util::hash::{fnv1a_str, Fnv64};
use crate::util::rng::Rng;

use super::strategy::SearchStrategy;
use super::{EvalBudget, EvalContext, MboParams, MboParamsError, MboResult, Pass};

/// Uniform random search at the MBO's measurement budget (`n_init +
/// b_max · batch_k` full-fidelity measurements) — the reference row every
/// model-based strategy must beat.
pub struct RandomSearch {
    params: MboParams,
}

impl RandomSearch {
    pub fn new(params: MboParams) -> Result<Self, MboParamsError> {
        params.validate()?;
        Ok(RandomSearch { params })
    }
}

impl SearchStrategy for RandomSearch {
    fn name(&self) -> &'static str {
        "random"
    }

    fn fingerprint(&self) -> u64 {
        fnv1a_str(self.name())
    }

    fn optimize(&self, ctx: &mut EvalContext<'_>) -> MboResult {
        ctx.set_budget(EvalBudget::from_params(&self.params));
        let n = ctx.n_candidates();
        // The sample size already caps at the budget ceiling, so the loop
        // needs no per-iteration exhaustion check.
        let k = ctx.budget().max_measurements.min(n);
        let mut rng = Rng::new(self.params.seed ^ 0x52_414e_44);
        for idx in rng.sample_indices(n, k) {
            // Skip candidates a warm-started context already measured.
            if !ctx.is_chosen(idx) {
                ctx.measure(idx, Pass::Init);
            }
        }
        ctx.record_hv();
        ctx.finish()
    }
}

/// Successive-halving hyperparameters. Part of the strategy identity: the
/// engine folds them into cache keys via [`HalvingParams::fingerprint`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HalvingParams {
    /// Survivor-pool reduction factor per round (≥ 2).
    pub eta: usize,
    /// Fidelity of the first screening round, as a fraction of the full
    /// profiling schedule (window, warm-up, cooldown, setup all scale).
    /// Screening fidelities are capped at 1/2 — full fidelity is reserved
    /// for the survivor re-measurement.
    pub base_fidelity: f64,
    /// Survivors re-measured at full fidelity at the end.
    pub survivors: usize,
}

impl Default for HalvingParams {
    /// Defaults sized so that on a typical 360-candidate partition space
    /// the racer spends ~1,150 simulated profiling seconds against the
    /// multi-pass MBO's ≥ 1,250: screen everything at 1/12 fidelity
    /// (~0.4 s windows, ~10% energy noise — survivable at a 6× keep
    /// ratio), re-screen the survivors at 1/2 fidelity (~2% noise), then
    /// measure the final 28 at full fidelity.
    fn default() -> Self {
        HalvingParams { eta: 6, base_fidelity: 1.0 / 12.0, survivors: 28 }
    }
}

impl HalvingParams {
    pub fn validate(&self) -> Result<(), MboParamsError> {
        if self.eta < 2 {
            return Err(MboParamsError::BadHalving("eta must be >= 2"));
        }
        if !(self.base_fidelity > 0.0 && self.base_fidelity <= 1.0) {
            return Err(MboParamsError::BadHalving("base_fidelity must be in (0, 1]"));
        }
        if self.survivors == 0 {
            return Err(MboParamsError::BadHalving("survivors must be >= 1"));
        }
        Ok(())
    }

    pub fn fingerprint(&self) -> u64 {
        let HalvingParams { eta, base_fidelity, survivors } = self;
        let mut h = Fnv64::new();
        h.write_str("halving")
            .write_u64(*eta as u64)
            .write_f64(*base_fidelity)
            .write_u64(*survivors as u64);
        h.finish()
    }
}

/// Successive-halving racer over the candidate space.
pub struct SuccessiveHalving {
    params: MboParams,
    halving: HalvingParams,
}

impl SuccessiveHalving {
    pub fn new(params: MboParams, halving: HalvingParams) -> Result<Self, MboParamsError> {
        params.validate()?;
        halving.validate()?;
        Ok(SuccessiveHalving { params, halving })
    }
}

impl SearchStrategy for SuccessiveHalving {
    fn name(&self) -> &'static str {
        "halving"
    }

    fn fingerprint(&self) -> u64 {
        self.halving.fingerprint()
    }

    fn optimize(&self, ctx: &mut EvalContext<'_>) -> MboResult {
        let hp = self.halving;
        // The full-fidelity bill is the survivor pool; probes are charged
        // separately by the context. HV convergence follows the MBO's rule
        // should a caller record intermediate trajectories.
        ctx.set_budget(EvalBudget {
            max_measurements: usize::MAX,
            r_window: self.params.r_window,
            eps: self.params.eps,
        });
        let n = ctx.n_candidates();
        let mut alive: Vec<usize> = (0..n).collect();
        if n > hp.survivors {
            let mut fidelity = hp.base_fidelity.min(MAX_SCREEN_FIDELITY);
            // The ladder keeps peeling until the pool fits the survivor
            // quota: `keep` strictly shrinks the pool while it exceeds
            // `survivors`, so the loop terminates, and the final
            // full-fidelity pass never measures more than `survivors`
            // candidates regardless of the (eta, base_fidelity) geometry.
            while alive.len() > hp.survivors {
                let probed: Vec<(usize, f64, f64)> = alive
                    .iter()
                    .map(|&idx| {
                        let m = ctx.probe(idx, fidelity);
                        (idx, m.time_s, m.energy_j)
                    })
                    .collect();
                let keep = (alive.len() / hp.eta).max(hp.survivors);
                alive = pareto_survivors(&probed, keep);
                alive.sort_unstable();
                fidelity = (fidelity * hp.eta as f64).min(MAX_SCREEN_FIDELITY);
            }
        }
        for idx in alive {
            // Dedup via the chosen-candidate bitmap: when the survivor
            // pool underflows the quota (or the context was warm-started
            // from a prior search), a survivor may already carry a
            // full-fidelity measurement — re-measuring would double-bill
            // the profiling budget and duplicate the evaluation history.
            if !ctx.is_chosen(idx) {
                ctx.measure(idx, Pass::Racing);
            }
        }
        ctx.record_hv();
        ctx.finish()
    }
}

/// Screening probes never exceed half the full profiling schedule: full
/// fidelity is reserved for the survivor re-measurement, so a screening
/// round can never cost as much as simply measuring its pool outright.
const MAX_SCREEN_FIDELITY: f64 = 0.5;

/// Keep the `keep` best probed candidates by peeling Pareto layers of the
/// (time, energy) values: the non-dominated set, then the non-dominated
/// set of what remains, and so on — so survivors cover the whole frontier
/// shape instead of one corner. Deterministic for a fixed probe set.
fn pareto_survivors(probed: &[(usize, f64, f64)], keep: usize) -> Vec<usize> {
    let mut remaining: Vec<(usize, f64, f64)> = probed.to_vec();
    let mut out: Vec<usize> = Vec::new();
    while out.len() < keep && !remaining.is_empty() {
        let layer = Frontier::from_points(
            remaining
                .iter()
                .enumerate()
                .map(|(pos, &(_, t, e))| Point::new(t, e, pos))
                .collect(),
        );
        if layer.is_empty() {
            break; // non-finite probes only; nothing rankable remains
        }
        for p in layer.points() {
            if out.len() >= keep {
                break;
            }
            out.push(remaining[p.tag].0);
        }
        // Drop the whole layer (taken or not) before the next peel.
        let mut positions: Vec<usize> = layer.points().iter().map(|p| p.tag).collect();
        positions.sort_unstable_by(|a, b| b.cmp(a));
        for pos in positions {
            remaining.swap_remove(pos);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pareto_peeling_orders_layers() {
        // Layer 1: (1,10), (2,5), (3,1); layer 2: (2,12), (3,6); rest worse.
        let probed = vec![
            (100, 1.0, 10.0),
            (101, 2.0, 5.0),
            (102, 3.0, 1.0),
            (103, 2.0, 12.0),
            (104, 3.0, 6.0),
            (105, 4.0, 13.0),
        ];
        assert_eq!(pareto_survivors(&probed, 3), vec![100, 101, 102]);
        let five = pareto_survivors(&probed, 5);
        assert_eq!(five.len(), 5);
        assert!(five.contains(&103) && five.contains(&104));
        assert!(!five.contains(&105));
        // Asking for more than exists returns everything.
        assert_eq!(pareto_survivors(&probed, 99).len(), 6);
    }

    #[test]
    fn halving_params_validate() {
        assert!(HalvingParams::default().validate().is_ok());
        assert!(HalvingParams { eta: 1, ..Default::default() }.validate().is_err());
        assert!(HalvingParams { base_fidelity: 0.0, ..Default::default() }.validate().is_err());
        assert!(HalvingParams { base_fidelity: 1.5, ..Default::default() }.validate().is_err());
        assert!(HalvingParams { survivors: 0, ..Default::default() }.validate().is_err());
    }
}
