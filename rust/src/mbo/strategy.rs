//! The pluggable search-strategy seam of the optimization layer.
//!
//! A [`SearchStrategy`] decides *which* candidates to evaluate (and at
//! what fidelity) for one partition; everything else — candidate space,
//! incremental objective planes, dedup, budget, cost accounting — lives in
//! the shared [`EvalContext`]. The paper's multi-pass MBO
//! ([`MultiPassMbo`](super::MultiPassMbo)), the exhaustive oracle
//! ([`ExhaustiveStrategy`]), a random-search baseline
//! ([`RandomSearch`](super::RandomSearch)), and a successive-halving racer
//! ([`SuccessiveHalving`](super::SuccessiveHalving)) all implement the
//! same trait, so the engine, the CLI, and the paper ablations can swap
//! and compare them freely.

use crate::partition::Partition;
use crate::profiler::Profiler;
use crate::util::hash::fnv1a_str;

use super::racing::{RandomSearch, SuccessiveHalving};
use super::{
    EvalBudget, EvalContext, HalvingParams, MboParams, MboParamsError, MboResult, MultiPassMbo,
    Pass,
};

/// A per-partition candidate-search policy over a shared [`EvalContext`].
///
/// Implementations must be deterministic functions of their construction
/// parameters and the context's profiler seed: the engine memoizes whole
/// results by (strategy fingerprint, partition, hyperparameters, seed),
/// so a cache hit must be a bit-identical replay.
pub trait SearchStrategy: Send + Sync {
    /// Short stable identifier (CLI value, table rows, cache diagnostics).
    fn name(&self) -> &'static str;

    /// Folded into [`MboCache`](crate::engine::MboCache) keys so results
    /// from different strategies never alias. Must cover the strategy
    /// identity plus any hyperparameters *not* already part of
    /// [`MboParams`] (which the cache key folds separately).
    fn fingerprint(&self) -> u64;

    /// Run the search to completion, returning the packaged result
    /// (usually via [`EvalContext::finish`]).
    fn optimize(&self, ctx: &mut EvalContext<'_>) -> MboResult;
}

/// Run `strategy` on one (partition, comm group) through a fresh
/// [`EvalContext`] on `profiler` — the one entry point every layer above
/// the trait dispatches through.
pub fn optimize_partition_with(
    strategy: &dyn SearchStrategy,
    profiler: &mut Profiler,
    part: &Partition,
    comm_group: u32,
) -> MboResult {
    let mut ctx = EvalContext::new(profiler, part, comm_group);
    strategy.optimize(&mut ctx)
}

/// [`optimize_partition_with`] over an explicit frequency granularity:
/// the context enumerates the (possibly per-kernel-class) candidate space
/// and the strategy runs unchanged over it.
pub fn optimize_partition_with_granularity(
    strategy: &dyn SearchStrategy,
    profiler: &mut Profiler,
    part: &Partition,
    comm_group: u32,
    granularity: crate::mbo::space::FreqGranularity,
) -> MboResult {
    let mut ctx = EvalContext::new_with(profiler, part, comm_group, granularity);
    strategy.optimize(&mut ctx)
}

/// Warm-start entry point: run `strategy` on a context pre-seeded from a
/// `prior` result over the same (partition, comm group) — previously
/// measured candidates are replayed into the planes and the dedup bitmap
/// without re-measuring (see [`EvalContext::warm_start`]), so the search
/// *continues* instead of restarting and the returned result bills only
/// the new measurements. This is how the online replanning runtime
/// refreshes per-partition frontiers without paying a cold
/// re-optimization (`tests/runtime.rs` asserts the billing gap).
pub fn optimize_partition_warm(
    strategy: &dyn SearchStrategy,
    profiler: &mut Profiler,
    part: &Partition,
    comm_group: u32,
    prior: &MboResult,
) -> MboResult {
    let mut ctx = EvalContext::new(profiler, part, comm_group);
    ctx.warm_start(prior);
    strategy.optimize(&mut ctx)
}

/// The strategy configuration an
/// [`EngineConfig`](crate::engine::EngineConfig) carries: a cheap,
/// copyable selector that builds a concrete [`SearchStrategy`] once the
/// per-partition [`MboParams`] are resolved (size class + derived seed).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StrategyKind {
    /// The paper's multi-pass MBO (§4.3, Algorithm 1) — the default.
    MultiPass,
    /// Full-fidelity measurement of every candidate (the oracle).
    Exhaustive,
    /// Uniform random sampling at the MBO's measurement budget.
    Random,
    /// Successive-halving racing: cheap screening, full re-measurement of
    /// survivors.
    Halving(HalvingParams),
}

impl StrategyKind {
    /// Parse a CLI value (`mbo | exhaustive | random | halving`).
    pub fn parse(s: &str) -> Option<StrategyKind> {
        match s {
            "mbo" | "multipass" => Some(StrategyKind::MultiPass),
            "exhaustive" | "oracle" => Some(StrategyKind::Exhaustive),
            "random" => Some(StrategyKind::Random),
            "halving" | "racing" => Some(StrategyKind::Halving(HalvingParams::default())),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            StrategyKind::MultiPass => "mbo",
            StrategyKind::Exhaustive => "exhaustive",
            StrategyKind::Random => "random",
            StrategyKind::Halving(_) => "halving",
        }
    }

    /// Validate the strategy-specific, partition-independent
    /// configuration (today: [`HalvingParams`]). Lets the engine fail
    /// fast with one clean typed error before fanning work out to
    /// parallel workers; per-partition [`MboParams`] are validated again
    /// by [`build`](Self::build).
    pub fn validate(&self) -> Result<(), MboParamsError> {
        match self {
            StrategyKind::Halving(hp) => hp.validate(),
            _ => Ok(()),
        }
    }

    /// The fingerprint the built strategy will report — exposed on the
    /// kind so the engine can fold it into cache keys without building a
    /// strategy first.
    pub fn fingerprint(&self) -> u64 {
        match self {
            StrategyKind::Halving(hp) => hp.fingerprint(),
            _ => fnv1a_str(self.name()),
        }
    }

    /// Build the concrete strategy for one partition's resolved
    /// hyperparameters. Validates `params` ([`MboParams::validate`]) for
    /// every strategy that consumes them.
    pub fn build(&self, params: MboParams) -> Result<Box<dyn SearchStrategy>, MboParamsError> {
        Ok(match self {
            StrategyKind::MultiPass => Box::new(MultiPassMbo::new(params)?),
            StrategyKind::Exhaustive => Box::new(ExhaustiveStrategy),
            StrategyKind::Random => Box::new(RandomSearch::new(params)?),
            StrategyKind::Halving(hp) => Box::new(SuccessiveHalving::new(params, *hp)?),
        })
    }
}

/// The exhaustive oracle as a strategy: measure every candidate at full
/// fidelity. Only feasible against the simulator (Appendix B prices the
/// real thing at thousands of GPU-hours), which is exactly its role —
/// the ground-truth row of the strategy ablation table.
pub struct ExhaustiveStrategy;

impl SearchStrategy for ExhaustiveStrategy {
    fn name(&self) -> &'static str {
        "exhaustive"
    }

    fn fingerprint(&self) -> u64 {
        fnv1a_str(self.name())
    }

    fn optimize(&self, ctx: &mut EvalContext<'_>) -> MboResult {
        ctx.set_budget(EvalBudget::unbounded());
        for idx in 0..ctx.n_candidates() {
            // Warm-started contexts already carry some measurements; the
            // oracle completes the coverage without duplicating them.
            if !ctx.is_chosen(idx) {
                ctx.measure(idx, Pass::Init);
            }
        }
        ctx.record_hv();
        ctx.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parsing_roundtrip() {
        for spec in ["mbo", "exhaustive", "random", "halving"] {
            let kind = StrategyKind::parse(spec).expect(spec);
            assert_eq!(kind.name(), spec);
        }
        assert_eq!(StrategyKind::parse("multipass"), Some(StrategyKind::MultiPass));
        assert_eq!(StrategyKind::parse("racing"), StrategyKind::parse("halving"));
        assert!(StrategyKind::parse("zzz").is_none());
    }

    #[test]
    fn fingerprints_never_alias() {
        let kinds = [
            StrategyKind::MultiPass,
            StrategyKind::Exhaustive,
            StrategyKind::Random,
            StrategyKind::Halving(HalvingParams::default()),
        ];
        for (i, a) in kinds.iter().enumerate() {
            for b in &kinds[i + 1..] {
                assert_ne!(a.fingerprint(), b.fingerprint(), "{a:?} vs {b:?}");
            }
        }
        // Halving hyperparameters are part of the identity.
        let tuned = HalvingParams { eta: 8, ..Default::default() };
        assert_ne!(
            StrategyKind::Halving(tuned).fingerprint(),
            StrategyKind::Halving(HalvingParams::default()).fingerprint()
        );
    }

    #[test]
    fn kind_matches_built_strategy() {
        for kind in [
            StrategyKind::MultiPass,
            StrategyKind::Exhaustive,
            StrategyKind::Random,
            StrategyKind::Halving(HalvingParams::default()),
        ] {
            let params = MboParams::for_class(crate::partition::SizeClass::Small);
            let s = kind.build(params).expect("defaults validate");
            assert_eq!(s.name(), kind.name());
            assert_eq!(s.fingerprint(), kind.fingerprint());
        }
    }
}
