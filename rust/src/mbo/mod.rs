//! Per-partition schedule optimization (§4.3, Algorithm 1) behind a
//! pluggable [`SearchStrategy`] layer.
//!
//! The module is split along the strategy seam:
//!
//! * [`space`] — the joint (frequency × SM × launch-timing) candidate
//!   space (§4.1, Appendix C ranges);
//! * [`context`] — the shared [`EvalContext`] every strategy drives: the
//!   candidate space, the three incremental objective [`Planes`], the
//!   dedup bitmap, the profiling/surrogate cost accounting, and the
//!   first-class [`EvalBudget`] stopping rules;
//! * [`strategy`] — the [`SearchStrategy`] trait, the engine-facing
//!   [`StrategyKind`] selector, and the [`ExhaustiveStrategy`] oracle;
//! * [`multipass`] — the paper's multi-pass MBO ([`MultiPassMbo`]), the
//!   default strategy (byte-identical to the pre-refactor monolith);
//! * [`racing`] — [`RandomSearch`] (ablation baseline) and
//!   [`SuccessiveHalving`] (cheap screening + full re-measurement of
//!   survivors);
//! * [`exhaustive`] — the noise-free oracle frontier and the Appendix B
//!   census (test/report machinery, distinct from [`ExhaustiveStrategy`],
//!   which measures through the profiler like every other strategy).
//!
//! Every strategy is measurement-source agnostic: candidates are profiled
//! through the [`Profiler`], whose canonical executions flow through its
//! configured [`ExecutionBackend`](crate::backend::ExecutionBackend) —
//! simulator by default, trace record/replay (or a future hardware
//! backend) without any change here.

pub mod context;
pub mod exhaustive;
pub mod multipass;
pub mod racing;
pub mod space;
pub mod strategy;

pub use context::{EvalBudget, EvalContext, Planes};
pub use multipass::MultiPassMbo;
pub use racing::{HalvingParams, RandomSearch, SuccessiveHalving};
pub use strategy::{
    optimize_partition_warm, optimize_partition_with, optimize_partition_with_granularity,
    ExhaustiveStrategy, SearchStrategy, StrategyKind,
};

use crate::frontier::Frontier;
use crate::partition::{Partition, SizeClass};
use crate::profiler::{Measurement, Profiler};
use crate::sim::exec::Schedule;

/// Which selection pass discovered a candidate (§6.6 attribution stats).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Pass {
    Init,
    Total,
    Dynamic,
    Static,
    Uncertainty,
    /// Survivor of a racing strategy's screening rounds.
    Racing,
}

#[derive(Clone, Debug)]
pub struct Evaluated {
    pub sched: Schedule,
    pub m: Measurement,
    pub pass: Pass,
}

#[derive(Clone, Debug)]
pub struct MboParams {
    pub n_init: usize,
    pub b_max: usize,
    pub batch_k: usize,
    /// Fractions of each batch from (total, dynamic, static) HVI passes;
    /// the remainder goes to the uncertainty pass.
    pub pass_fracs: [f64; 3],
    pub ensemble_size: usize,
    pub bootstrap_fraction: f64,
    /// Stopping: moving average of relative HV improvement over the last
    /// `r_window` batches below `eps`.
    pub r_window: usize,
    pub eps: f64,
    pub seed: u64,
}

/// A rejected [`MboParams`] / [`HalvingParams`] configuration. Raised at
/// *strategy construction* ([`MultiPassMbo::new`] and friends), because
/// the failure modes are silent at run time: pass fractions summing past
/// 1.0 underflow the uncertainty pass's share, and a zero batch or
/// initial-design size loops without progress.
#[derive(Clone, Debug, PartialEq)]
pub enum MboParamsError {
    /// `pass_fracs` must be non-negative and finite.
    BadPassFrac { index: usize, value: f64 },
    /// `pass_fracs` must sum to at most 1.0 (the remainder funds the
    /// uncertainty pass).
    PassFracsExceedOne { sum: f64 },
    /// `n_init == 0`: the surrogates would train on an empty design.
    ZeroInit,
    /// `batch_k == 0`: every batch would select nothing useful.
    ZeroBatchK,
    /// `ensemble_size == 0`: uncertainty estimates would be NaN.
    ZeroEnsemble,
    /// `bootstrap_fraction` must lie in (0, 1].
    BadBootstrapFraction { value: f64 },
    /// `r_window == 0`: the stopping rule would divide by zero.
    ZeroWindow,
    /// `eps` must be finite.
    BadEps { value: f64 },
    /// Invalid [`HalvingParams`] (racing strategy).
    BadHalving(&'static str),
}

impl std::fmt::Display for MboParamsError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MboParamsError::BadPassFrac { index, value } => {
                write!(f, "pass_fracs[{index}] = {value} must be finite and >= 0")
            }
            MboParamsError::PassFracsExceedOne { sum } => {
                write!(f, "pass_fracs sum to {sum} > 1.0 (uncertainty share underflows)")
            }
            MboParamsError::ZeroInit => write!(f, "n_init must be >= 1"),
            MboParamsError::ZeroBatchK => write!(f, "batch_k must be >= 1"),
            MboParamsError::ZeroEnsemble => write!(f, "ensemble_size must be >= 1"),
            MboParamsError::BadBootstrapFraction { value } => {
                write!(f, "bootstrap_fraction = {value} must be in (0, 1]")
            }
            MboParamsError::ZeroWindow => write!(f, "r_window must be >= 1"),
            MboParamsError::BadEps { value } => write!(f, "eps = {value} must be finite"),
            MboParamsError::BadHalving(reason) => write!(f, "halving params: {reason}"),
        }
    }
}

impl std::error::Error for MboParamsError {}

impl MboParams {
    /// Appendix C settings by partition size class.
    pub fn for_class(class: SizeClass) -> Self {
        let (n_init, b_max, batch_k) = match class {
            SizeClass::Small => (36, 3, 16),
            SizeClass::Medium => (48, 4, 16),
            SizeClass::Large => (96, 4, 32),
        };
        MboParams {
            n_init,
            b_max,
            batch_k,
            pass_fracs: [0.4, 0.2, 0.2],
            ensemble_size: 5,
            bootstrap_fraction: 0.8,
            r_window: 2,
            eps: 1e-3,
            seed: 0,
        }
    }

    /// Reject configurations whose failure modes are silent at run time
    /// (see [`MboParamsError`]). Called by every strategy constructor.
    pub fn validate(&self) -> Result<(), MboParamsError> {
        for (index, &value) in self.pass_fracs.iter().enumerate() {
            if !value.is_finite() || value < 0.0 {
                return Err(MboParamsError::BadPassFrac { index, value });
            }
        }
        let sum: f64 = self.pass_fracs.iter().sum();
        if sum > 1.0 {
            return Err(MboParamsError::PassFracsExceedOne { sum });
        }
        if self.n_init == 0 {
            return Err(MboParamsError::ZeroInit);
        }
        if self.batch_k == 0 {
            return Err(MboParamsError::ZeroBatchK);
        }
        if self.ensemble_size == 0 {
            return Err(MboParamsError::ZeroEnsemble);
        }
        if !(self.bootstrap_fraction > 0.0 && self.bootstrap_fraction <= 1.0) {
            return Err(MboParamsError::BadBootstrapFraction { value: self.bootstrap_fraction });
        }
        if self.r_window == 0 {
            return Err(MboParamsError::ZeroWindow);
        }
        if !self.eps.is_finite() {
            return Err(MboParamsError::BadEps { value: self.eps });
        }
        Ok(())
    }
}

#[derive(Clone, Debug)]
pub struct MboResult {
    pub evaluated: Vec<Evaluated>,
    /// Frontier on the (time, measured total energy) plane; tags index
    /// into `evaluated`.
    pub frontier: Frontier,
    /// Size of the full candidate space.
    pub n_candidates: usize,
    /// Dominated-HV trajectory after each batch (total-energy plane).
    pub hv_history: Vec<f64>,
    /// Simulated profiling wall-clock charged to this partition (s) —
    /// full-fidelity measurements plus any low-fidelity screening probes.
    pub profiling_cost_s: f64,
    /// Real wall-clock spent in surrogate training + acquisition (s).
    pub surrogate_cost_s: f64,
}

impl MboResult {
    /// Per-pass share of frontier points (§6.6).
    pub fn pass_contributions(&self) -> Vec<(Pass, usize)> {
        let mut counts = vec![
            (Pass::Init, 0),
            (Pass::Total, 0),
            (Pass::Dynamic, 0),
            (Pass::Static, 0),
            (Pass::Uncertainty, 0),
            (Pass::Racing, 0),
        ];
        for p in self.frontier.points() {
            let pass = self.evaluated[p.tag].pass;
            for (k, v) in counts.iter_mut() {
                if *k == pass {
                    *v += 1;
                }
            }
        }
        counts
    }
}

/// Algorithm 1: multi-pass MBO for one partition — the pre-refactor entry
/// point, now a thin wrapper over [`MultiPassMbo`] through the strategy
/// seam (byte-identical results for identical `params`).
///
/// Panics on invalid `params`; construct a [`MultiPassMbo`] directly to
/// handle [`MboParamsError`] instead.
pub fn optimize_partition(
    profiler: &mut Profiler,
    part: &Partition,
    comm_group: u32,
    params: &MboParams,
) -> MboResult {
    let strategy = MultiPassMbo::new(params.clone()).expect("invalid MboParams");
    optimize_partition_with(&strategy, profiler, part, comm_group)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontier::Point;
    use crate::profiler::ProfilerConfig;
    use crate::sim::gpu::GpuSpec;
    use crate::sim::kernel::{Kernel, KernelKind};

    fn test_partition() -> Partition {
        Partition {
            ptype: "fwd/attn".into(),
            comps: vec![
                Kernel::comp("Norm", KernelKind::Norm, 1e8, 8e8),
                Kernel::comp("Linear1", KernelKind::Linear, 5e11, 2.5e9),
                Kernel::comp("Flash", KernelKind::FlashAttention, 3e11, 1e9),
                Kernel::comp("Linear2", KernelKind::Linear, 5e11, 2.5e9),
            ],
            comm: Some(Kernel::comm("AR", KernelKind::AllReduce, 5e8)),
            count: 28,
        }
    }

    fn run_mbo(seed: u64) -> MboResult {
        let gpu = GpuSpec::a100();
        let mut prof = Profiler::new(gpu, ProfilerConfig::default(), seed);
        let part = test_partition();
        let mut params = MboParams::for_class(part.size_class());
        params.seed = seed;
        optimize_partition(&mut prof, &part, 8, &params)
    }

    #[test]
    fn produces_nonempty_frontier() {
        let r = run_mbo(1);
        assert!(r.frontier.len() >= 3, "frontier {:?}", r.frontier.len());
        assert!(r.evaluated.len() >= 96);
        assert!(r.n_candidates > 200);
    }

    #[test]
    fn frontier_near_exhaustive_oracle() {
        let r = run_mbo(2);
        let gpu = GpuSpec::a100();
        let part = test_partition();
        let oracle = exhaustive::exhaustive_frontier(&gpu, &part, 8);
        // Fair comparison: re-evaluate the schedules MBO selected with the
        // noise-free oracle (measured values carry load-temperature
        // leakage and counter noise that the oracle does not).
        let mbo_true = exhaustive::true_frontier(&gpu, &part, &r);
        let mut all: Vec<Point> = oracle.points().to_vec();
        all.extend(mbo_true.points().iter().copied());
        let rref = Frontier::reference_of(&all);
        let hv_mbo = mbo_true.hypervolume(rref);
        let hv_oracle = oracle.hypervolume(rref);
        assert!(
            hv_mbo >= 0.93 * hv_oracle,
            "MBO hv {hv_mbo} vs oracle {hv_oracle} ({})",
            hv_mbo / hv_oracle
        );
    }

    #[test]
    fn multiple_passes_contribute() {
        let r = run_mbo(3);
        let contrib = r.pass_contributions();
        let non_init: usize = contrib
            .iter()
            .filter(|(p, _)| *p != Pass::Init)
            .map(|(_, c)| *c)
            .sum();
        assert!(non_init > 0, "non-init passes contributed nothing: {contrib:?}");
    }

    #[test]
    fn profiling_dominates_overhead() {
        // §6.6: thermally stable profiling is ~97% of MBO overhead.
        let r = run_mbo(4);
        assert!(r.profiling_cost_s > 50.0 * r.surrogate_cost_s.max(1e-3));
    }

    #[test]
    fn hv_history_monotone() {
        let r = run_mbo(5);
        for w in r.hv_history.windows(2) {
            assert!(w[1] >= w[0] - 1e-9);
        }
    }

    #[test]
    fn deterministic_across_runs() {
        let a = run_mbo(8);
        let b = run_mbo(8);
        let key = |r: &MboResult| -> Vec<(u64, u64, usize)> {
            r.frontier
                .points()
                .iter()
                .map(|p| (p.time.to_bits(), p.energy.to_bits(), p.tag))
                .collect()
        };
        assert_eq!(key(&a), key(&b));
        let hv = |r: &MboResult| -> Vec<u64> { r.hv_history.iter().map(|h| h.to_bits()).collect() };
        assert_eq!(hv(&a), hv(&b));
        assert_eq!(a.evaluated.len(), b.evaluated.len());
    }

    #[test]
    fn wrapper_and_trait_path_are_byte_identical() {
        // The load-bearing parity constraint of the strategy refactor: the
        // legacy entry point and explicit trait dispatch must produce the
        // same bits.
        let gpu = GpuSpec::a100();
        let part = test_partition();
        let mut params = MboParams::for_class(part.size_class());
        params.seed = 11;
        let mut prof_a = Profiler::new(gpu.clone(), ProfilerConfig::default(), 11);
        let a = optimize_partition(&mut prof_a, &part, 8, &params);
        let strategy = MultiPassMbo::new(params).expect("valid");
        let mut prof_b = Profiler::new(gpu, ProfilerConfig::default(), 11);
        let b = optimize_partition_with(&strategy, &mut prof_b, &part, 8);
        let bits = |r: &MboResult| -> Vec<(u64, u64, usize)> {
            r.frontier
                .points()
                .iter()
                .map(|p| (p.time.to_bits(), p.energy.to_bits(), p.tag))
                .collect()
        };
        assert_eq!(bits(&a), bits(&b));
        assert_eq!(a.evaluated.len(), b.evaluated.len());
        assert_eq!(a.profiling_cost_s.to_bits(), b.profiling_cost_s.to_bits());
    }

    #[test]
    fn no_comm_partition_small_space() {
        let gpu = GpuSpec::a100();
        let mut prof = Profiler::new(gpu, ProfilerConfig::default(), 6);
        let mut part = test_partition();
        part.comm = None;
        let params = MboParams::for_class(part.size_class());
        let r = optimize_partition(&mut prof, &part, 8, &params);
        assert_eq!(r.n_candidates, 18);
        assert!(r.evaluated.len() <= 18 + 1);
    }

    #[test]
    fn validate_rejects_silent_misconfigurations() {
        let ok = MboParams::for_class(SizeClass::Small);
        assert!(ok.validate().is_ok());

        let mut p = ok.clone();
        p.pass_fracs = [0.6, 0.3, 0.3];
        assert!(matches!(p.validate(), Err(MboParamsError::PassFracsExceedOne { .. })));

        let mut p = ok.clone();
        p.pass_fracs = [0.4, -0.1, 0.2];
        assert!(matches!(p.validate(), Err(MboParamsError::BadPassFrac { index: 1, .. })));

        let mut p = ok.clone();
        p.n_init = 0;
        assert_eq!(p.validate(), Err(MboParamsError::ZeroInit));

        let mut p = ok.clone();
        p.batch_k = 0;
        assert_eq!(p.validate(), Err(MboParamsError::ZeroBatchK));

        let mut p = ok.clone();
        p.bootstrap_fraction = 0.0;
        assert!(matches!(p.validate(), Err(MboParamsError::BadBootstrapFraction { .. })));

        let mut p = ok.clone();
        p.r_window = 0;
        assert_eq!(p.validate(), Err(MboParamsError::ZeroWindow));

        // Strategy constructors surface the same typed error.
        let mut p = ok;
        p.pass_fracs = [0.9, 0.9, 0.9];
        assert!(MultiPassMbo::new(p.clone()).is_err());
        assert!(RandomSearch::new(p.clone()).is_err());
        assert!(SuccessiveHalving::new(p, HalvingParams::default()).is_err());
    }
}
