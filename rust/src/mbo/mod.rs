//! Multi-pass multi-objective Bayesian optimization (§4.3, Algorithm 1).
//!
//! Two GBDT surrogates (time, dynamic energy), three hypervolume-
//! improvement exploitation passes (total / dynamic / static energy) that
//! expand the frontier in complementary directions (Figure 7), plus one
//! bootstrap-ensemble uncertainty exploration pass. Hyperparameters follow
//! Appendix C (sample sizes by partition size class, pass proportions
//! 0.4/0.2/0.2/0.2, stopping on relative HV improvement).
//!
//! The optimizer is measurement-source agnostic: every candidate is
//! profiled through the [`Profiler`], whose canonical executions flow
//! through its configured
//! [`ExecutionBackend`](crate::backend::ExecutionBackend) — simulator by
//! default, trace record/replay (or a future hardware backend) without
//! any change here.

pub mod exhaustive;
pub mod space;

use crate::frontier::{Frontier, Point};
use crate::partition::{Partition, SizeClass};
use crate::profiler::{Measurement, Profiler};
use crate::sim::exec::Schedule;
use crate::surrogate::{Ensemble, EnsembleParams, Gbdt, GbdtParams};
use crate::util::rng::Rng;

/// Which selection pass discovered a candidate (§6.6 attribution stats).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Pass {
    Init,
    Total,
    Dynamic,
    Static,
    Uncertainty,
}

#[derive(Clone, Debug)]
pub struct Evaluated {
    pub sched: Schedule,
    pub m: Measurement,
    pub pass: Pass,
}

#[derive(Clone, Debug)]
pub struct MboParams {
    pub n_init: usize,
    pub b_max: usize,
    pub batch_k: usize,
    /// Fractions of each batch from (total, dynamic, static) HVI passes;
    /// the remainder goes to the uncertainty pass.
    pub pass_fracs: [f64; 3],
    pub ensemble_size: usize,
    pub bootstrap_fraction: f64,
    /// Stopping: moving average of relative HV improvement over the last
    /// `r_window` batches below `eps`.
    pub r_window: usize,
    pub eps: f64,
    pub seed: u64,
}

impl MboParams {
    /// Appendix C settings by partition size class.
    pub fn for_class(class: SizeClass) -> Self {
        let (n_init, b_max, batch_k) = match class {
            SizeClass::Small => (36, 3, 16),
            SizeClass::Medium => (48, 4, 16),
            SizeClass::Large => (96, 4, 32),
        };
        MboParams {
            n_init,
            b_max,
            batch_k,
            pass_fracs: [0.4, 0.2, 0.2],
            ensemble_size: 5,
            bootstrap_fraction: 0.8,
            r_window: 2,
            eps: 1e-3,
            seed: 0,
        }
    }
}

#[derive(Clone, Debug)]
pub struct MboResult {
    pub evaluated: Vec<Evaluated>,
    /// Frontier on the (time, measured total energy) plane; tags index
    /// into `evaluated`.
    pub frontier: Frontier,
    /// Size of the full candidate space.
    pub n_candidates: usize,
    /// Dominated-HV trajectory after each batch (total-energy plane).
    pub hv_history: Vec<f64>,
    /// Simulated profiling wall-clock charged to this partition (s).
    pub profiling_cost_s: f64,
    /// Real wall-clock spent in surrogate training + acquisition (s).
    pub surrogate_cost_s: f64,
}

impl MboResult {
    /// Per-pass share of frontier points (§6.6).
    pub fn pass_contributions(&self) -> Vec<(Pass, usize)> {
        let mut counts = vec![
            (Pass::Init, 0),
            (Pass::Total, 0),
            (Pass::Dynamic, 0),
            (Pass::Static, 0),
            (Pass::Uncertainty, 0),
        ];
        for p in self.frontier.points() {
            let pass = self.evaluated[p.tag].pass;
            for (k, v) in counts.iter_mut() {
                if *k == pass {
                    *v += 1;
                }
            }
        }
        counts
    }
}

/// The three objective planes of §4.3 (total / dynamic / static energy vs
/// time), maintained *incrementally*: every measurement is inserted into
/// each plane's frontier as it lands, and the worst observed coordinates
/// are tracked alongside, so the batch loop never rebuilds a frontier (or
/// its reference point) from the full evaluation history.
struct Planes {
    f_tot: Frontier,
    f_dyn: Frontier,
    f_stat: Frontier,
    p_static: f64,
    t_max: f64,
    e_tot_max: f64,
    e_dyn_max: f64,
}

impl Planes {
    fn new(p_static: f64) -> Self {
        Planes {
            f_tot: Frontier::new(),
            f_dyn: Frontier::new(),
            f_stat: Frontier::new(),
            p_static,
            t_max: f64::NEG_INFINITY,
            e_tot_max: f64::NEG_INFINITY,
            e_dyn_max: f64::NEG_INFINITY,
        }
    }

    /// Fold measurement `i` into all three planes.
    fn observe(&mut self, i: usize, m: &Measurement) {
        self.f_tot.insert(Point::new(m.time_s, m.energy_j, i));
        self.f_dyn.insert(Point::new(m.time_s, m.dyn_j, i));
        self.f_stat.insert(Point::new(m.time_s, m.time_s * self.p_static, i));
        self.t_max = self.t_max.max(m.time_s);
        self.e_tot_max = self.e_tot_max.max(m.energy_j);
        self.e_dyn_max = self.e_dyn_max.max(m.dyn_j);
    }

    /// Reference points for (total, dynamic, static), all derived through
    /// the one canonical `Frontier::reference_of` rule (Appendix C: 1.1 ×
    /// the worst observed coordinates). On the static plane energy is
    /// time × P_static, so its worst energy is exactly `t_max · P_static`.
    fn references(&self) -> ((f64, f64), (f64, f64), (f64, f64)) {
        let of = |e_max: f64| Frontier::reference_of(&[Point::new(self.t_max, e_max, 0)]);
        (of(self.e_tot_max), of(self.e_dyn_max), of(self.t_max * self.p_static))
    }
}

/// Algorithm 1: multi-pass MBO for one partition.
pub fn optimize_partition(
    profiler: &mut Profiler,
    part: &Partition,
    comm_group: u32,
    params: &MboParams,
) -> MboResult {
    let gpu = profiler.gpu.clone();
    let space = space::candidate_space(&gpu, part, comm_group);
    let n = space.len();
    let mut rng = Rng::new(params.seed ^ 0x5eed);
    let mut evaluated: Vec<Evaluated> = Vec::new();
    let mut chosen = vec![false; n];
    let mut surrogate_cost = 0.0f64;
    let mut planes = Planes::new(gpu.static_w);
    // Hoisted: the cache probe inside every measurement keys on this.
    let part_fp = part.fingerprint();

    let eval = |idx: usize,
                    pass: Pass,
                    profiler: &mut Profiler,
                    evaluated: &mut Vec<Evaluated>,
                    chosen: &mut Vec<bool>,
                    planes: &mut Planes| {
        chosen[idx] = true;
        let m = profiler.measure_fp(part, part_fp, &space[idx]);
        planes.observe(evaluated.len(), &m);
        evaluated.push(Evaluated { sched: space[idx], m, pass });
    };

    // --- Initial random design ------------------------------------------
    let n_init = params.n_init.min(n);
    for idx in rng.sample_indices(n, n_init) {
        eval(idx, Pass::Init, profiler, &mut evaluated, &mut chosen, &mut planes);
    }

    let mut hv_history: Vec<f64> = Vec::new();
    let exhausted = n_init >= n;

    if !exhausted {
        for _batch in 0..params.b_max {
            let t0 = std::time::Instant::now();
            // ---- Train surrogates on D --------------------------------
            let x: Vec<Vec<f64>> = evaluated.iter().map(|e| space::features(&e.sched)).collect();
            let y_t: Vec<f64> = evaluated.iter().map(|e| e.m.time_s).collect();
            let y_e: Vec<f64> = evaluated.iter().map(|e| e.m.dyn_j).collect();
            let gp = GbdtParams { seed: params.seed, subsample: 1.0, ..Default::default() };
            let t_hat = Gbdt::fit(&x, &y_t, &gp);
            let e_hat = Gbdt::fit(&x, &y_e, &gp);
            let ens_p = EnsembleParams {
                size: params.ensemble_size,
                bootstrap_fraction: params.bootstrap_fraction,
                gbdt: GbdtParams {
                    seed: params.seed ^ 0xE45,
                    subsample: 0.8,
                    ..Default::default()
                },
            };
            let t_ens = Ensemble::fit(&x, &y_t, &ens_p);
            let e_ens = Ensemble::fit(&x, &y_e, &ens_p);

            // ---- Current frontiers on each objective plane -------------
            // Maintained incrementally by `planes` as measurements land;
            // the references all follow Appendix C's 1.1× rule.
            let p_static = gpu.static_w;
            let (r_tot, r_dyn, r_stat) = planes.references();

            // ---- Score all unevaluated candidates ----------------------
            // (idx, hvi_tot, hvi_dyn, hvi_stat, unc) per candidate.
            let mut cand: Vec<(usize, f64, f64, f64, f64)> = Vec::new();
            for (idx, s) in space.iter().enumerate() {
                if chosen[idx] {
                    continue;
                }
                let feats = space::features(s);
                let th = t_hat.predict(&feats).max(1e-9);
                let eh = e_hat.predict(&feats).max(0.0);
                let hvi_tot = planes.f_tot.hvi((th, th * p_static + eh), r_tot);
                let hvi_dyn = planes.f_dyn.hvi((th, eh), r_dyn);
                let hvi_stat = planes.f_stat.hvi((th, th * p_static), r_stat);
                let (_, st) = t_ens.predict(&feats);
                let (_, se) = e_ens.predict(&feats);
                // Sum of per-objective std deviations (§4.3.2).
                let unc = st / y_t.iter().sum::<f64>().max(1e-12) * y_t.len() as f64
                    + se / y_e.iter().sum::<f64>().max(1e-12) * y_e.len() as f64;
                cand.push((idx, hvi_tot, hvi_dyn, hvi_stat, unc));
            }
            surrogate_cost += t0.elapsed().as_secs_f64();
            if cand.is_empty() {
                break;
            }

            // ---- Multi-pass candidate selection ------------------------
            let k = params.batch_k.min(cand.len());
            let k1 = ((k as f64 * params.pass_fracs[0]).round() as usize).max(1);
            let k2 = ((k as f64 * params.pass_fracs[1]).round() as usize).max(1);
            let k3 = ((k as f64 * params.pass_fracs[2]).round() as usize).max(1);
            let mut picked: Vec<(usize, Pass)> = Vec::new();
            let mut taken = vec![false; n];
            let top_by = |key: usize,
                          count: usize,
                          pass: Pass,
                          picked: &mut Vec<(usize, Pass)>,
                          taken: &mut Vec<bool>| {
                let mut order: Vec<&(usize, f64, f64, f64, f64)> =
                    cand.iter().filter(|c| !taken[c.0]).collect();
                order.sort_by(|a, b| {
                    let va = [a.1, a.2, a.3, a.4][key];
                    let vb = [b.1, b.2, b.3, b.4][key];
                    vb.partial_cmp(&va).unwrap()
                });
                for c in order.into_iter().take(count) {
                    taken[c.0] = true;
                    picked.push((c.0, pass));
                }
            };
            top_by(0, k1, Pass::Total, &mut picked, &mut taken);
            top_by(1, k2, Pass::Dynamic, &mut picked, &mut taken);
            top_by(2, k3, Pass::Static, &mut picked, &mut taken);
            let rest = k.saturating_sub(picked.len());
            top_by(3, rest, Pass::Uncertainty, &mut picked, &mut taken);

            // ---- Evaluate the batch ------------------------------------
            for (idx, pass) in picked {
                eval(idx, pass, profiler, &mut evaluated, &mut chosen, &mut planes);
            }

            // ---- Stopping: relative HV improvement ---------------------
            // The total-energy plane already reflects the new batch; its
            // reference tracks the worst coordinates seen so far.
            let (r_now, _, _) = planes.references();
            let hv = planes.f_tot.hypervolume(r_now);
            hv_history.push(hv);
            if hv_history.len() > params.r_window {
                let w = params.r_window;
                let prev = hv_history[hv_history.len() - 1 - w];
                let delta = (hv - prev) / prev.max(1e-12) / w as f64;
                if delta < params.eps {
                    break;
                }
            }
        }
    }

    // The total-energy plane *is* the result frontier — built once,
    // incrementally, instead of a final from_points rebuild.
    let frontier = planes.f_tot;
    let profiling_cost_s = evaluated.iter().map(|e| e.m.profiling_cost_s).sum();
    MboResult {
        evaluated,
        frontier,
        n_candidates: n,
        hv_history,
        profiling_cost_s,
        surrogate_cost_s: surrogate_cost,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::ProfilerConfig;
    use crate::sim::gpu::GpuSpec;
    use crate::sim::kernel::{Kernel, KernelKind};

    fn test_partition() -> Partition {
        Partition {
            ptype: "fwd/attn".into(),
            comps: vec![
                Kernel::comp("Norm", KernelKind::Norm, 1e8, 8e8),
                Kernel::comp("Linear1", KernelKind::Linear, 5e11, 2.5e9),
                Kernel::comp("Flash", KernelKind::FlashAttention, 3e11, 1e9),
                Kernel::comp("Linear2", KernelKind::Linear, 5e11, 2.5e9),
            ],
            comm: Some(Kernel::comm("AR", KernelKind::AllReduce, 5e8)),
            count: 28,
        }
    }

    fn run_mbo(seed: u64) -> MboResult {
        let gpu = GpuSpec::a100();
        let mut prof = Profiler::new(gpu, ProfilerConfig::default(), seed);
        let part = test_partition();
        let mut params = MboParams::for_class(part.size_class());
        params.seed = seed;
        optimize_partition(&mut prof, &part, 8, &params)
    }

    #[test]
    fn produces_nonempty_frontier() {
        let r = run_mbo(1);
        assert!(r.frontier.len() >= 3, "frontier {:?}", r.frontier.len());
        assert!(r.evaluated.len() >= 96);
        assert!(r.n_candidates > 200);
    }

    #[test]
    fn frontier_near_exhaustive_oracle() {
        let r = run_mbo(2);
        let gpu = GpuSpec::a100();
        let part = test_partition();
        let oracle = exhaustive::exhaustive_frontier(&gpu, &part, 8);
        // Fair comparison: re-evaluate the schedules MBO selected with the
        // noise-free oracle (measured values carry load-temperature
        // leakage and counter noise that the oracle does not).
        let mbo_true = Frontier::from_points(
            r.frontier
                .points()
                .iter()
                .enumerate()
                .map(|(i, p)| {
                    let m = crate::profiler::Profiler::true_eval(
                        &gpu,
                        &part,
                        &r.evaluated[p.tag].sched,
                    );
                    Point::new(m.time_s, m.energy_j, i)
                })
                .collect(),
        );
        let mut all: Vec<Point> = oracle.points().to_vec();
        all.extend(mbo_true.points().iter().copied());
        let rref = Frontier::reference_of(&all);
        let hv_mbo = mbo_true.hypervolume(rref);
        let hv_oracle = oracle.hypervolume(rref);
        assert!(
            hv_mbo >= 0.93 * hv_oracle,
            "MBO hv {hv_mbo} vs oracle {hv_oracle} ({})",
            hv_mbo / hv_oracle
        );
    }

    #[test]
    fn multiple_passes_contribute() {
        let r = run_mbo(3);
        let contrib = r.pass_contributions();
        let non_init: usize = contrib
            .iter()
            .filter(|(p, _)| *p != Pass::Init)
            .map(|(_, c)| *c)
            .sum();
        assert!(non_init > 0, "non-init passes contributed nothing: {contrib:?}");
    }

    #[test]
    fn profiling_dominates_overhead() {
        // §6.6: thermally stable profiling is ~97% of MBO overhead.
        let r = run_mbo(4);
        assert!(r.profiling_cost_s > 50.0 * r.surrogate_cost_s.max(1e-3));
    }

    #[test]
    fn hv_history_monotone() {
        let r = run_mbo(5);
        for w in r.hv_history.windows(2) {
            assert!(w[1] >= w[0] - 1e-9);
        }
    }

    #[test]
    fn deterministic_across_runs() {
        let a = run_mbo(8);
        let b = run_mbo(8);
        let key = |r: &MboResult| -> Vec<(u64, u64, usize)> {
            r.frontier
                .points()
                .iter()
                .map(|p| (p.time.to_bits(), p.energy.to_bits(), p.tag))
                .collect()
        };
        assert_eq!(key(&a), key(&b));
        let hv = |r: &MboResult| -> Vec<u64> { r.hv_history.iter().map(|h| h.to_bits()).collect() };
        assert_eq!(hv(&a), hv(&b));
        assert_eq!(a.evaluated.len(), b.evaluated.len());
    }

    #[test]
    fn no_comm_partition_small_space() {
        let gpu = GpuSpec::a100();
        let mut prof = Profiler::new(gpu, ProfilerConfig::default(), 6);
        let mut part = test_partition();
        part.comm = None;
        let params = MboParams::for_class(part.size_class());
        let r = optimize_partition(&mut prof, &part, 8, &params);
        assert_eq!(r.n_candidates, 18);
        assert!(r.evaluated.len() <= 18 + 1);
    }
}
