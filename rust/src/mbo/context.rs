//! Shared evaluation state for pluggable search strategies.
//!
//! Every [`SearchStrategy`](super::SearchStrategy) optimizes one partition
//! through an [`EvalContext`]: it owns the candidate space, the three
//! incremental objective [`Planes`], the chosen-candidate bitmap, the
//! evaluation history, and the profiling/surrogate cost accounting — so a
//! strategy only decides *which* candidate to evaluate next (and at what
//! fidelity), never how measurements are taken, deduplicated, or folded
//! into the result frontier. The [`EvalBudget`] makes the stopping rules
//! (measurement ceiling + Appendix C relative-HV convergence) first-class
//! instead of burying them in a batch loop.

use crate::frontier::{Frontier, Point};
use crate::partition::Partition;
use crate::profiler::{Measurement, Profiler, ProfilerConfig};
use crate::sim::exec::Schedule;
use crate::sim::gpu::GpuSpec;

use super::{space, Evaluated, MboParams, MboResult, Pass};

/// The three objective planes of §4.3 (total / dynamic / static energy vs
/// time), maintained *incrementally*: every measurement is inserted into
/// each plane's frontier as it lands, and the worst observed coordinates
/// are tracked alongside, so strategies never rebuild a frontier (or its
/// reference point) from the full evaluation history.
#[derive(Clone, Debug)]
pub struct Planes {
    pub f_tot: Frontier,
    pub f_dyn: Frontier,
    pub f_stat: Frontier,
    pub p_static: f64,
    pub t_max: f64,
    pub e_tot_max: f64,
    pub e_dyn_max: f64,
}

impl Planes {
    pub fn new(p_static: f64) -> Self {
        Planes {
            f_tot: Frontier::new(),
            f_dyn: Frontier::new(),
            f_stat: Frontier::new(),
            p_static,
            t_max: f64::NEG_INFINITY,
            e_tot_max: f64::NEG_INFINITY,
            e_dyn_max: f64::NEG_INFINITY,
        }
    }

    /// Fold measurement `i` into all three planes.
    pub fn observe(&mut self, i: usize, m: &Measurement) {
        self.f_tot.insert(Point::new(m.time_s, m.energy_j, i));
        self.f_dyn.insert(Point::new(m.time_s, m.dyn_j, i));
        self.f_stat.insert(Point::new(m.time_s, m.time_s * self.p_static, i));
        self.t_max = self.t_max.max(m.time_s);
        self.e_tot_max = self.e_tot_max.max(m.energy_j);
        self.e_dyn_max = self.e_dyn_max.max(m.dyn_j);
    }

    /// Reference points for (total, dynamic, static), all derived through
    /// the one canonical `Frontier::reference_of` rule (Appendix C: 1.1 ×
    /// the worst observed coordinates). On the static plane energy is
    /// time × P_static, so its worst energy is exactly `t_max · P_static`.
    pub fn references(&self) -> ((f64, f64), (f64, f64), (f64, f64)) {
        let of = |e_max: f64| Frontier::reference_of(&[Point::new(self.t_max, e_max, 0)]);
        (of(self.e_tot_max), of(self.e_dyn_max), of(self.t_max * self.p_static))
    }
}

/// First-class evaluation budget: a measurement ceiling plus the
/// Appendix C stopping rule (moving average of relative HV improvement
/// over the last `r_window` recorded batches below `eps`). Previously
/// buried in the multi-pass batch loop; now every strategy consults the
/// same object.
///
/// The ceiling is *consulted, not enforced*: strategies query
/// [`exhausted`](Self::exhausted)/[`remaining`](Self::remaining) and
/// decide when to stop, while [`EvalContext::measure`] never drops a
/// requested measurement. Enforcing the cap inside `measure` would
/// silently change byte-level trajectories for hyperparameters whose own
/// arithmetic can legitimately overshoot it (e.g. extreme `pass_fracs`
/// in the multi-pass batch selection) — and bit-parity with the
/// specification is this layer's load-bearing contract.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EvalBudget {
    /// Ceiling on full-fidelity measurements that budget-driven
    /// strategies consult (`usize::MAX` = unbounded).
    pub max_measurements: usize,
    /// HV-convergence window (batches); `usize::MAX` disables the rule.
    pub r_window: usize,
    /// Relative HV-improvement threshold.
    pub eps: f64,
}

impl EvalBudget {
    /// No ceiling, no convergence rule (the exhaustive oracle's budget).
    pub fn unbounded() -> Self {
        EvalBudget { max_measurements: usize::MAX, r_window: usize::MAX, eps: 0.0 }
    }

    /// The budget implied by a set of MBO hyperparameters: at most the
    /// initial design plus `b_max` full batches, stopping early on HV
    /// convergence.
    pub fn from_params(p: &MboParams) -> Self {
        EvalBudget {
            max_measurements: p.n_init.saturating_add(p.b_max.saturating_mul(p.batch_k)),
            r_window: p.r_window,
            eps: p.eps,
        }
    }

    pub fn exhausted(&self, used: usize) -> bool {
        used >= self.max_measurements
    }

    pub fn remaining(&self, used: usize) -> usize {
        self.max_measurements.saturating_sub(used)
    }

    /// Appendix C stopping: true once the moving average of relative HV
    /// improvement over the last `r_window` entries of `hist` drops below
    /// `eps`. Needs more than `r_window` recorded batches to trigger.
    pub fn hv_converged(&self, hist: &[f64]) -> bool {
        if self.r_window == 0 || hist.len() <= self.r_window {
            return false;
        }
        let w = self.r_window;
        let hv = hist[hist.len() - 1];
        let prev = hist[hist.len() - 1 - w];
        let delta = (hv - prev) / prev.max(1e-12) / w as f64;
        delta < self.eps
    }
}

/// Per-partition evaluation state shared by every search strategy: the
/// candidate space, the incremental objective planes, the dedup bitmap,
/// the evaluation history, and the cost accounting. Strategies interact
/// with it through [`measure`](Self::measure) (full-fidelity, lands in the
/// result) and [`probe`](Self::probe) (cheap screening, charged to the
/// profiling bill but kept out of the result frontier).
pub struct EvalContext<'a> {
    profiler: &'a mut Profiler,
    part: &'a Partition,
    comm_group: u32,
    space: Vec<Schedule>,
    planes: Planes,
    evaluated: Vec<Evaluated>,
    chosen: Vec<bool>,
    part_fp: u64,
    budget: EvalBudget,
    hv_history: Vec<f64>,
    surrogate_cost_s: f64,
    /// Profiling seconds charged by low-fidelity probes (not represented
    /// in `evaluated`, but still real measurement time §6.6 must count).
    probe_cost_s: f64,
    /// Profiling seconds carried in by [`warm_start`](Self::warm_start):
    /// already billed by the prior search, subtracted again in
    /// [`finish`](Self::finish) so a warm continuation bills only *new*
    /// work.
    warm_cost_s: f64,
}

impl<'a> EvalContext<'a> {
    /// Build the context for one (partition, comm group) on a profiler:
    /// enumerates the candidate space and hoists the partition fingerprint
    /// so strategies never rehash kernels per probe.
    pub fn new(profiler: &'a mut Profiler, part: &'a Partition, comm_group: u32) -> Self {
        Self::new_with(profiler, part, comm_group, space::FreqGranularity::Partition)
    }

    /// [`new`](Self::new) over the candidate space of an explicit
    /// frequency granularity. Strategies are granularity-agnostic: the
    /// space is just larger and [`space::features`] wider for
    /// `KernelClass`, so the incremental planes, dedup bitmap, and budget
    /// machinery are reused unchanged.
    pub fn new_with(
        profiler: &'a mut Profiler,
        part: &'a Partition,
        comm_group: u32,
        granularity: space::FreqGranularity,
    ) -> Self {
        let space = space::candidate_space_with(&profiler.gpu, part, comm_group, granularity);
        let n = space.len();
        let planes = Planes::new(profiler.gpu.static_w);
        let part_fp = part.fingerprint();
        EvalContext {
            profiler,
            part,
            comm_group,
            space,
            planes,
            evaluated: Vec::new(),
            chosen: vec![false; n],
            part_fp,
            budget: EvalBudget::unbounded(),
            hv_history: Vec::new(),
            surrogate_cost_s: 0.0,
            probe_cost_s: 0.0,
            warm_cost_s: 0.0,
        }
    }

    /// Warm-start this context from a prior search result over the same
    /// (partition, comm group): every previously measured candidate is
    /// replayed into the planes, the dedup bitmap, and the evaluation
    /// history — without re-measuring and without re-billing its
    /// profiling cost — and the HV trajectory carries over. A strategy
    /// run afterwards *continues* the search (e.g.
    /// [`MultiPassMbo`](crate::mbo::MultiPassMbo) skips the
    /// already-covered initial design), which is what makes an online
    /// replan bill measurably fewer measurements than a cold
    /// re-optimization.
    ///
    /// Prior evaluations whose schedule is absent from this context's
    /// candidate space (the space geometry changed) are skipped. Returns
    /// the number of carried-over measurements.
    pub fn warm_start(&mut self, prior: &MboResult) -> usize {
        use std::collections::HashMap;
        let index: HashMap<Schedule, usize> =
            self.space.iter().enumerate().map(|(i, s)| (*s, i)).collect();
        let mut carried = 0usize;
        for e in &prior.evaluated {
            let Some(&idx) = index.get(&e.sched) else { continue };
            if self.chosen[idx] {
                continue;
            }
            self.chosen[idx] = true;
            self.planes.observe(self.evaluated.len(), &e.m);
            self.evaluated.push(e.clone());
            self.warm_cost_s += e.m.profiling_cost_s;
            carried += 1;
        }
        self.hv_history = prior.hv_history.clone();
        carried
    }

    pub fn gpu(&self) -> &GpuSpec {
        &self.profiler.gpu
    }

    pub fn part(&self) -> &Partition {
        self.part
    }

    pub fn comm_group(&self) -> u32 {
        self.comm_group
    }

    /// The enumerated candidate schedules (immutable for the whole run).
    pub fn space(&self) -> &[Schedule] {
        &self.space
    }

    pub fn n_candidates(&self) -> usize {
        self.space.len()
    }

    /// True once candidate `idx` has been measured at full fidelity.
    pub fn is_chosen(&self, idx: usize) -> bool {
        self.chosen[idx]
    }

    /// Full-fidelity measurements taken so far.
    pub fn measured(&self) -> usize {
        self.evaluated.len()
    }

    pub fn evaluated(&self) -> &[Evaluated] {
        &self.evaluated
    }

    pub fn planes(&self) -> &Planes {
        &self.planes
    }

    pub fn budget(&self) -> EvalBudget {
        self.budget
    }

    pub fn set_budget(&mut self, budget: EvalBudget) {
        self.budget = budget;
    }

    pub fn hv_history(&self) -> &[f64] {
        &self.hv_history
    }

    /// Measure candidate `idx` at full fidelity: marks it chosen, folds the
    /// measurement into all three planes, and appends it to the evaluation
    /// history that the result frontier tags index into.
    pub fn measure(&mut self, idx: usize, pass: Pass) -> Measurement {
        self.chosen[idx] = true;
        let m = self.profiler.measure_fp(self.part, self.part_fp, &self.space[idx]);
        self.planes.observe(self.evaluated.len(), &m);
        self.evaluated.push(Evaluated { sched: self.space[idx], m, pass });
        m
    }

    /// Cheap screening measurement of candidate `idx` at a fraction of the
    /// full profiling schedule (window, warm-up, cooldown, and setup all
    /// scaled by `fidelity`). Shorter windows alias against the energy
    /// counter's 100 ms publication cadence (Figure 12a), so probes are
    /// noisy by construction — racing strategies screen with them and
    /// re-measure survivors through [`measure`](Self::measure). The probe
    /// is charged to the profiling bill but never enters `evaluated`, the
    /// planes, or the dedup bitmap.
    pub fn probe(&mut self, idx: usize, fidelity: f64) -> Measurement {
        let full = self.profiler.config.clone();
        let f = fidelity.clamp(0.01, 1.0);
        self.profiler.config = ProfilerConfig {
            window_s: full.window_s * f,
            cooldown_s: full.cooldown_s * f,
            warmup_s: full.warmup_s * f,
            setup_s: full.setup_s * f,
        };
        let m = self.profiler.measure_fp(self.part, self.part_fp, &self.space[idx]);
        self.profiler.config = full;
        self.probe_cost_s += m.profiling_cost_s;
        m
    }

    /// Real wall-clock spent in surrogate training + acquisition.
    pub fn charge_surrogate(&mut self, seconds: f64) {
        self.surrogate_cost_s += seconds;
    }

    /// Record the current dominated HV of the total-energy plane (w.r.t.
    /// the Appendix C reference over the worst observed coordinates) into
    /// the trajectory; returns the recorded value.
    pub fn record_hv(&mut self) -> f64 {
        let (r_now, _, _) = self.planes.references();
        let hv = self.planes.f_tot.hypervolume(r_now);
        self.hv_history.push(hv);
        hv
    }

    /// True once the budget's HV-convergence rule fires on the recorded
    /// trajectory.
    pub fn hv_converged(&self) -> bool {
        self.budget.hv_converged(&self.hv_history)
    }

    /// Package the accumulated state into an [`MboResult`]. The
    /// total-energy plane *is* the result frontier — built incrementally,
    /// never rebuilt from the history. Warm-started measurements appear
    /// in the history/frontier but their (already billed) profiling cost
    /// is excluded, so `profiling_cost_s` charges only this run's work.
    pub fn finish(&mut self) -> MboResult {
        let evaluated = std::mem::take(&mut self.evaluated);
        let frontier = std::mem::take(&mut self.planes.f_tot);
        let profiling_cost_s = evaluated.iter().map(|e| e.m.profiling_cost_s).sum::<f64>()
            - self.warm_cost_s
            + self.probe_cost_s;
        MboResult {
            evaluated,
            frontier,
            n_candidates: self.space.len(),
            hv_history: std::mem::take(&mut self.hv_history),
            profiling_cost_s,
            surrogate_cost_s: self.surrogate_cost_s,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::kernel::{Kernel, KernelKind};

    fn part() -> Partition {
        Partition {
            ptype: "fwd/attn".into(),
            comps: vec![
                Kernel::comp("norm", KernelKind::Norm, 1e8, 8e8),
                Kernel::comp("linear", KernelKind::Linear, 5e11, 2.5e9),
            ],
            comm: Some(Kernel::comm("ar", KernelKind::AllReduce, 5e8)),
            count: 28,
        }
    }

    #[test]
    fn budget_rules() {
        let b = EvalBudget { max_measurements: 10, r_window: 2, eps: 1e-3 };
        assert!(!b.exhausted(9));
        assert!(b.exhausted(10));
        assert_eq!(b.remaining(4), 6);
        // Convergence needs more than r_window entries.
        assert!(!b.hv_converged(&[1.0, 1.0]));
        assert!(b.hv_converged(&[1.0, 1.0, 1.0]));
        assert!(!b.hv_converged(&[1.0, 2.0, 4.0]));
        // Unbounded budgets never stop.
        let u = EvalBudget::unbounded();
        assert!(!u.exhausted(usize::MAX - 1));
        assert!(!u.hv_converged(&[1.0; 64]));
    }

    #[test]
    fn probe_charges_less_than_measure() {
        let gpu = GpuSpec::a100();
        let mut prof = Profiler::new(gpu, ProfilerConfig::default(), 1);
        let p = part();
        let mut ctx = EvalContext::new(&mut prof, &p, 8);
        let cheap = ctx.probe(0, 1.0 / 16.0);
        let full = ctx.measure(0, Pass::Init);
        assert!(cheap.profiling_cost_s < full.profiling_cost_s / 10.0);
        assert!(cheap.time_s > 0.0 && cheap.energy_j > 0.0);
        // Probes stay out of the evaluation history but on the bill.
        assert_eq!(ctx.measured(), 1);
        let r = ctx.finish();
        let full_only: f64 = r.evaluated.iter().map(|e| e.m.profiling_cost_s).sum();
        assert!(r.profiling_cost_s > full_only);
    }

    #[test]
    fn measure_is_deduplicated_and_observed() {
        let gpu = GpuSpec::a100();
        let mut prof = Profiler::new(gpu, ProfilerConfig::default(), 2);
        let p = part();
        let mut ctx = EvalContext::new(&mut prof, &p, 8);
        assert!(!ctx.is_chosen(3));
        ctx.measure(3, Pass::Init);
        assert!(ctx.is_chosen(3));
        assert_eq!(ctx.planes().f_tot.len(), 1);
        let hv0 = ctx.record_hv();
        assert!(hv0 >= 0.0);
        let r = ctx.finish();
        assert_eq!(r.evaluated.len(), 1);
        assert_eq!(r.n_candidates, ctx_space_len(&p));
    }

    fn ctx_space_len(p: &Partition) -> usize {
        space::candidate_space(&GpuSpec::a100(), p, 8).len()
    }

    #[test]
    fn warm_start_replays_without_rebilling() {
        let gpu = GpuSpec::a100();
        let p = part();
        // Prior search: three full-fidelity measurements.
        let mut prof_a = Profiler::new(gpu.clone(), ProfilerConfig::default(), 9);
        let mut ctx_a = EvalContext::new(&mut prof_a, &p, 8);
        for idx in [0, 5, 9] {
            ctx_a.measure(idx, Pass::Init);
        }
        ctx_a.record_hv();
        let prior = ctx_a.finish();
        assert!(prior.profiling_cost_s > 0.0);

        // Warm continuation: the prior's candidates are chosen, observed,
        // and in the history — but their cost is not billed again.
        let mut prof_b = Profiler::new(gpu, ProfilerConfig::default(), 10);
        let mut ctx_b = EvalContext::new(&mut prof_b, &p, 8);
        let carried = ctx_b.warm_start(&prior);
        assert_eq!(carried, 3);
        assert_eq!(ctx_b.measured(), 3);
        assert!(ctx_b.is_chosen(0) && ctx_b.is_chosen(5) && ctx_b.is_chosen(9));
        assert!(!ctx_b.is_chosen(1));
        assert_eq!(ctx_b.hv_history().len(), prior.hv_history.len());
        // Re-seeding the same prior is idempotent (dedup bitmap).
        assert_eq!(ctx_b.warm_start(&prior), 0);

        // One new measurement: only it is billed.
        let m = ctx_b.measure(1, Pass::Total);
        let r = ctx_b.finish();
        assert_eq!(r.evaluated.len(), 4);
        assert!(
            (r.profiling_cost_s - m.profiling_cost_s).abs() < 1e-9,
            "warm continuation billed {} but only {} is new",
            r.profiling_cost_s,
            m.profiling_cost_s
        );
        // The carried measurements still shape the frontier planes.
        assert!(!r.frontier.is_empty());
    }
}
