//! Kareus reproduction library.
pub mod backend;
pub mod baselines;
pub mod cli;
pub mod coordinator;
pub mod engine;
pub mod plan;
pub mod runtime;
pub mod trainer;
pub mod paper;
pub mod compose;
pub mod frontier;
pub mod pipeline;
pub mod mbo;
pub mod partition;
pub mod profiler;
pub mod sim;
pub mod surrogate;
pub mod workload;
pub mod util;

pub fn hello() -> &'static str { "kareus" }
