//! Kareus reproduction library — joint reduction of dynamic and static
//! energy in large model training, grown into a multi-scenario,
//! multi-backend optimization engine with a cluster-level power-cap
//! scheduler on top.
//!
//! The end-to-end data flow (see `ARCHITECTURE.md` for the full map):
//!
//! 1. **profile** — [`workload`] builds kernel sequences, [`partition`]
//!    detects computation–communication partitions, [`profiler`] measures
//!    them thermally stably through an [`backend::ExecutionBackend`].
//! 2. **optimize** — [`mbo`] searches each partition's joint schedule
//!    space through a pluggable [`mbo::SearchStrategy`] (multi-pass
//!    multi-objective Bayesian optimization by default; successive-halving
//!    racing, random search, and the exhaustive oracle as alternatives;
//!    [`surrogate`] provides the GBDT ensemble), fanned out and memoized
//!    by [`engine`].
//! 3. **compose** — [`compose`] builds microbatch frontiers, [`pipeline`]
//!    composes them into the 1F1B iteration frontier ([`frontier`] holds
//!    the Pareto machinery); [`baselines`] wraps the whole pipeline per
//!    system under comparison.
//! 4. **select + deploy** — [`coordinator`] picks an operating point for
//!    a target (deadline / energy budget / power cap / max throughput)
//!    and deploys the typed [`plan::FrequencyPlan`] through
//!    [`runtime::pjrt`] / [`trainer`].
//! 5. **schedule the cluster** — [`cluster`] allocates a datacenter
//!    power-cap timeline across N jobs by re-selecting along their
//!    retained frontiers (no re-optimization).
//! 6. **replan online** — [`runtime`] steps training iterations under
//!    time-varying conditions (thermal leakage, stragglers, cap changes),
//!    a [`runtime::DriftMonitor`] flags stale plans, and replans run
//!    incrementally: cap boundaries re-select along retained frontiers,
//!    drift triggers warm-start from the engine's caches; every change is
//!    a typed [`plan::PlanRevision`].
//! 7. **verify** — [`check`] statically verifies every emitted artifact
//!    (plans, cluster plans, revision logs, traces, sweeps, load-test
//!    reports) against the invariants above, as the `kareus check`
//!    subcommand and as debug-mode assertions at the construction seams.
//! 8. **serve** — [`serve`] wraps the whole stack in a long-running
//!    plan-serving daemon (`kareus serve`): concurrent clients get plans
//!    over newline-delimited JSON, answered from the process-wide warm
//!    caches when possible; `kareus loadgen` load-tests it
//!    deterministically.
//! 9. **model-check the concurrency** — every concurrency-bearing module
//!    builds on the [`util::sync`] shims (plain `std::sync` in normal
//!    builds); under `--features modelcheck` the `modelcheck` explorer
//!    drives them through every bounded interleaving, detecting
//!    deadlock, lost wakeups, and double locks, and emits failing
//!    schedules as replayable JSON fixtures.
//!
//! [`paper`] regenerates the evaluation tables/figures, [`sim`] is the
//! default measurement source (GPU power model + two-stream executor),
//! and [`util`] holds the offline substrates (JSON, RNG, stats, hashing,
//! thread pool, sync shims).

pub mod backend;
pub mod baselines;
pub mod bench_suite;
pub mod check;
pub mod cli;
pub mod cluster;
pub mod compose;
pub mod coordinator;
pub mod engine;
pub mod frontier;
pub mod mbo;
#[cfg(feature = "modelcheck")]
pub mod modelcheck;
pub mod paper;
pub mod partition;
pub mod pipeline;
pub mod plan;
pub mod profiler;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod surrogate;
pub mod trainer;
pub mod util;
pub mod workload;

pub fn hello() -> &'static str {
    "kareus"
}
