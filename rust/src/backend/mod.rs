//! Execution backends: the measurement seam between the optimization
//! pipeline and whatever actually runs a partition.
//!
//! Kareus's pipeline (profile → per-partition MBO → compose → select →
//! deploy) is backend-agnostic: every layer only needs *some* source of
//! `(schedule, partition) → ExecResult` measurements. This module makes
//! that seam explicit:
//!
//! * [`ExecutionBackend`] — the trait. The low-level entry point is
//!   [`measure_kernels`](ExecutionBackend::measure_kernels) (raw kernel
//!   lists plus a caller-hoisted fingerprint, used by the hot paths); the
//!   convenience [`measure`](ExecutionBackend::measure) wraps it for a
//!   whole [`Partition`]. Backends also expose a [`fingerprint`]
//!   (so memoization layers never alias results from different
//!   measurement sources) and a [`caps`](ExecutionBackend::caps)
//!   capability descriptor.
//! * [`SimBackend`] — the two-stream simulator (`sim::exec`), the default
//!   everywhere and the reference for bit-exactness tests.
//! * [`TraceBackend`] — records measurements to / replays them from a
//!   JSON trace file. Record mode wraps the simulator and captures every
//!   measurement it serves; replay mode answers **only** from the trace
//!   (the simulator is structurally unreachable), which makes recorded
//!   sweeps byte-reproducible offline and is the template for future
//!   hardware-measured (PJRT/NVML) backends.
//! * [`Measurer`] — a backend plus an optional shared
//!   [`MeasureCache`](crate::profiler::MeasureCache), threaded through
//!   the microbatch-evaluation layers in place of raw simulator calls.
//!
//! The memoization contract is unchanged from the cache-only design:
//! every backend must be a pure function of
//! `(fingerprint, schedule, temperature, power limit)` for a fixed
//! backend identity, so replaying a cached/traced result is bit-identical
//! to recomputing it.
//!
//! ## Trace file schema (version 1)
//!
//! ```jsonc
//! {
//!   "trace": "kareus_exec_trace",
//!   "version": 1,
//!   "entries": {
//!     // key = <fp as hex>|<comm_sms>:<launch>:<freq_mhz>|<temp f64 bits>|<limit f64 bits>
//!     "0f3a..|12:c1:1410|4043..|ffff..": {
//!       "time_s": 0.0123, "dyn_j": 3.1, "static_j": 0.9,
//!       "exposed_comm_s": 0.0, "avg_freq_mhz": 1410,
//!       "throttled": false, "peak_power_w": 401.2
//!     }
//!   }
//! }
//! ```
//!
//! `launch` is `seq` (sequential execution model) or `c<i>` (launched
//! with computation kernel `i`); schedules carrying a per-kernel-class
//! frequency split extend the frequency field to
//! `<freq_mhz>m<memory_mhz>` (uniform schedules keep the bare
//! `<freq_mhz>`, so legacy traces replay unchanged). Entries whose
//! execution charged frequency transitions carry an extra
//! `freq_transitions` count; zero-transition entries omit it. Floats are
//! written with Rust's shortest round-trip formatting, so a decoded
//! [`ExecResult`] is bit-identical to the recorded one. Entries live in a
//! `BTreeMap`, so a saved trace is byte-deterministic for a given set of
//! measurements.
//!
//! [`fingerprint`]: ExecutionBackend::fingerprint

use std::collections::BTreeMap;
use std::io;
use std::path::{Path, PathBuf};
use crate::util::sync::{SyncAtomicU64, SyncMutex};

use crate::partition::Partition;
use crate::profiler::MeasureCache;
use crate::sim::exec::{execute_partition, ExecResult, KernelFreqs, LaunchAt, Schedule};
use crate::sim::gpu::GpuSpec;
use crate::sim::kernel::Kernel;
use crate::util::hash::Fnv64;
use crate::util::json::{num, obj, s, Json};

/// What a backend can and cannot do. Pipeline layers use this to decide,
/// e.g., whether asking for a never-seen schedule can possibly succeed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BackendCaps {
    /// Repeating a measurement returns bit-identical results.
    pub deterministic: bool,
    /// The backend can produce *fresh* measurements (simulator, hardware).
    /// `false` for replay-only backends: a measurement absent from their
    /// store is unanswerable.
    pub live: bool,
}

/// The measurement source behind the optimization pipeline.
pub trait ExecutionBackend: Send + Sync {
    /// Measure one canonical partition execution given raw kernel lists.
    ///
    /// `fp` is the caller-hoisted combined GPU+kernels fingerprint (see
    /// [`combine_fp`](crate::profiler::combine_fp) / [`kernels_fp`]): the
    /// backend-independent identity of the physical work, used by trace
    /// keys and shared caches. Hot loops compute it once per (GPU,
    /// partition), not per probe.
    #[allow(clippy::too_many_arguments)]
    fn measure_kernels(
        &self,
        gpu: &GpuSpec,
        fp: u64,
        comps: &[Kernel],
        comm: Option<&Kernel>,
        sched: &Schedule,
        temp_c: f64,
        power_limit: Option<f64>,
    ) -> ExecResult;

    /// Measure one whole [`Partition`] under `sched` at die temperature
    /// `temp_c` (the convenience entry point named in the coordinator's
    /// phase ① design).
    fn measure(
        &self,
        gpu: &GpuSpec,
        part: &Partition,
        sched: &Schedule,
        temp_c: f64,
        power_limit: Option<f64>,
    ) -> ExecResult {
        let fp = crate::profiler::combine_fp(gpu.fingerprint(), part.fingerprint());
        self.measure_kernels(gpu, fp, &part.comps, part.comm.as_ref(), sched, temp_c, power_limit)
    }

    /// Stable identity of this measurement source. Folded into the MBO
    /// memoization key so results measured by different backends (or
    /// different traces) never alias.
    fn fingerprint(&self) -> u64;

    /// Short display name (`sim`, `trace`).
    fn name(&self) -> &'static str;

    /// Capability descriptor.
    fn caps(&self) -> BackendCaps {
        BackendCaps { deterministic: true, live: true }
    }
}

/// Fingerprint of a raw kernel list on one GPU — the ad-hoc counterpart
/// of [`Partition::fingerprint`] for work that is not a partition
/// (non-partition extras, sequential-model segments). Hashes exactly the
/// physical resource demands, mirroring the partition rule.
pub fn kernels_fp(gpu_fp: u64, comps: &[Kernel], comm: Option<&Kernel>) -> u64 {
    let mut h = Fnv64::new();
    h.write_str("kernels").write_u64(gpu_fp).write_u64(comps.len() as u64);
    let write_kernel = |h: &mut Fnv64, k: &Kernel| {
        // `name` is a label; execution depends only on the resources.
        let Kernel { name: _, kind, flops, bytes, comm_bytes } = k;
        h.write_u64(*kind as u64).write_f64(*flops).write_f64(*bytes).write_f64(*comm_bytes);
    };
    for k in comps {
        write_kernel(&mut h, k);
    }
    match comm {
        Some(c) => {
            h.write_u64(1);
            write_kernel(&mut h, c);
        }
        None => {
            h.write_u64(0);
        }
    }
    h.finish()
}

// ---------------------------------------------------------------------
// SimBackend
// ---------------------------------------------------------------------

/// The two-stream execution-schedule simulator (`sim::exec`) as a
/// backend: live, deterministic, and the bit-exactness reference every
/// other backend is tested against.
#[derive(Clone, Copy, Debug, Default)]
pub struct SimBackend;

/// The process-wide simulator backend instance ([`SimBackend`] is a unit
/// struct; one static serves every [`Measurer::sim`]).
pub static SIM: SimBackend = SimBackend;

/// Precomputed: the cache key path probes
/// [`ExecutionBackend::fingerprint`] per measurement, so the simulator's
/// must not re-hash its tag string every time.
const SIM_FINGERPRINT: u64 = crate::util::hash::fnv1a_const("kareus_backend:sim:v1");

impl ExecutionBackend for SimBackend {
    fn measure_kernels(
        &self,
        gpu: &GpuSpec,
        _fp: u64,
        comps: &[Kernel],
        comm: Option<&Kernel>,
        sched: &Schedule,
        temp_c: f64,
        power_limit: Option<f64>,
    ) -> ExecResult {
        execute_partition(gpu, comps, comm, sched, temp_c, power_limit)
    }

    fn fingerprint(&self) -> u64 {
        SIM_FINGERPRINT
    }

    fn name(&self) -> &'static str {
        "sim"
    }
}

// ---------------------------------------------------------------------
// TraceBackend
// ---------------------------------------------------------------------

/// Trace-file schema tag.
pub const TRACE_SCHEMA: &str = "kareus_exec_trace";
/// Trace-file schema version.
pub const TRACE_VERSION: u64 = 1;

/// Records measurements to / replays them from a JSON trace file.
///
/// * **Record mode** ([`TraceBackend::record`]): wraps the simulator,
///   captures every measurement it serves; [`save`](TraceBackend::save)
///   writes the byte-deterministic trace file.
/// * **Replay mode** ([`TraceBackend::replay`]): loads the file and
///   answers exclusively from it. There is no simulator fallback — a
///   missing entry panics with the offending key, because it means the
///   trace was recorded for a different scenario/seed and silently
///   recomputing would defeat the point of offline replay.
pub struct TraceBackend {
    path: PathBuf,
    replay: bool,
    /// Precomputed [`ExecutionBackend::fingerprint`] (the cache key path
    /// is hot; don't rehash the path string per probe). Mode-independent,
    /// so a record run and its replay share one identity.
    fp: u64,
    entries: SyncMutex<BTreeMap<String, ExecResult>>,
    recorded: SyncAtomicU64,
    replayed: SyncAtomicU64,
}

fn trace_fp(path: &Path) -> u64 {
    let mut h = Fnv64::new();
    h.write_str("kareus_backend:trace:v1").write_str(&path.to_string_lossy());
    h.finish()
}

/// Canonical trace key of one measurement: combined fingerprint, the
/// schedule, and the exact (bit-level) temperature and power limit.
pub fn trace_key(fp: u64, sched: &Schedule, temp_c: f64, power_limit: Option<f64>) -> String {
    let launch = match sched.launch {
        LaunchAt::Sequential => "seq".to_string(),
        LaunchAt::WithComp(i) => format!("c{i}"),
    };
    let freq = match sched.kernel_freqs {
        KernelFreqs::Uniform => format!("{}", sched.freq_mhz),
        KernelFreqs::PerClass { memory_mhz, .. } => {
            format!("{}m{}", sched.freq_mhz, memory_mhz)
        }
    };
    format!(
        "{:016x}|{}:{}:{}|{:016x}|{:016x}",
        fp,
        sched.comm_sms,
        launch,
        freq,
        temp_c.to_bits(),
        power_limit.map_or(u64::MAX, f64::to_bits)
    )
}

/// Serialize one [`ExecResult`] (floats keep Rust's shortest round-trip
/// formatting, so decoding restores the exact bits).
pub fn exec_result_to_json(r: &ExecResult) -> Json {
    let mut fields = vec![
        ("time_s", num(r.time_s)),
        ("dyn_j", num(r.dyn_j)),
        ("static_j", num(r.static_j)),
        ("exposed_comm_s", num(r.exposed_comm_s)),
        ("avg_freq_mhz", num(r.avg_freq_mhz)),
        ("throttled", Json::Bool(r.throttled)),
        ("peak_power_w", num(r.peak_power_w)),
    ];
    // Only executions that actually switched frequency mid-partition
    // carry the count; everything else keeps the legacy byte layout.
    if r.freq_transitions > 0 {
        fields.push(("freq_transitions", num(r.freq_transitions as f64)));
    }
    obj(fields)
}

/// Decode one [`ExecResult`]; errors name the missing/ill-typed field.
pub fn exec_result_from_json(j: &Json) -> Result<ExecResult, String> {
    let f = |k: &str| {
        j.get(k).and_then(|v| v.as_f64()).ok_or_else(|| format!("trace entry missing '{k}'"))
    };
    Ok(ExecResult {
        time_s: f("time_s")?,
        dyn_j: f("dyn_j")?,
        static_j: f("static_j")?,
        exposed_comm_s: f("exposed_comm_s")?,
        avg_freq_mhz: f("avg_freq_mhz")?,
        throttled: j
            .get("throttled")
            .and_then(|v| v.as_bool())
            .ok_or("trace entry missing 'throttled'")?,
        peak_power_w: f("peak_power_w")?,
        freq_transitions: j
            .get("freq_transitions")
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0) as u32,
    })
}

impl TraceBackend {
    /// Fresh recording trace that will be saved to `path`.
    pub fn record(path: impl Into<PathBuf>) -> Self {
        let path = path.into();
        let fp = trace_fp(&path);
        TraceBackend {
            path,
            replay: false,
            fp,
            entries: SyncMutex::new(BTreeMap::new()),
            recorded: SyncAtomicU64::new(0),
            replayed: SyncAtomicU64::new(0),
        }
    }

    /// Load `path` for replay; the simulator is unreachable from the
    /// returned backend.
    pub fn replay(path: impl Into<PathBuf>) -> io::Result<Self> {
        let path = path.into();
        let text = std::fs::read_to_string(&path)?;
        let bad = |msg: String| io::Error::new(io::ErrorKind::InvalidData, msg);
        let json = Json::parse(&text).map_err(|e| bad(format!("{}: {e}", path.display())))?;
        if json.get("trace").and_then(|v| v.as_str()) != Some(TRACE_SCHEMA) {
            return Err(bad(format!("{}: not a {TRACE_SCHEMA} file", path.display())));
        }
        let version = json.get("version").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
        if version != TRACE_VERSION {
            return Err(bad(format!(
                "{}: unsupported trace version {version} (want {TRACE_VERSION})",
                path.display()
            )));
        }
        // The typed decode below accepts anything field-shaped; in debug
        // builds run the full static verifier so a corrupted trace fails
        // here, not as a silently-wrong measurement downstream.
        #[cfg(debug_assertions)]
        crate::check::assert_no_errors(
            &format!("TraceBackend::replay({})", path.display()),
            &crate::check::check_trace_json(&json),
        );
        let mut entries = BTreeMap::new();
        let obj = json
            .get("entries")
            .and_then(|v| v.as_obj())
            .ok_or_else(|| bad(format!("{}: missing 'entries' object", path.display())))?;
        for (k, v) in obj {
            let r = exec_result_from_json(v)
                .map_err(|e| bad(format!("{}: entry '{k}': {e}", path.display())))?;
            entries.insert(k.clone(), r);
        }
        let fp = trace_fp(&path);
        Ok(TraceBackend {
            path,
            replay: true,
            fp,
            entries: SyncMutex::new(entries),
            recorded: SyncAtomicU64::new(0),
            replayed: SyncAtomicU64::new(0),
        })
    }

    /// Replay if `path` exists, otherwise start recording to it — the CLI
    /// semantics of `--backend trace:<path>` (first run records, second
    /// replays).
    pub fn open(path: impl Into<PathBuf>) -> io::Result<Self> {
        let path = path.into();
        if path.exists() {
            Self::replay(path)
        } else {
            Ok(Self::record(path))
        }
    }

    pub fn is_replay(&self) -> bool {
        self.replay
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Distinct measurements currently in the trace.
    pub fn len(&self) -> usize {
        self.entries.lock().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Measurements served while recording (≥ [`len`](Self::len): repeated
    /// keys overwrite in place).
    pub fn recorded(&self) -> u64 {
        self.recorded.load()
    }

    /// Measurements answered from the trace in replay mode.
    pub fn replayed(&self) -> u64 {
        self.replayed.load()
    }

    /// The whole trace as JSON (record or replay mode alike).
    pub fn to_json(&self) -> Json {
        let entries: BTreeMap<String, Json> = self
            .entries
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), exec_result_to_json(v)))
            .collect();
        obj(vec![
            ("trace", s(TRACE_SCHEMA)),
            ("version", num(TRACE_VERSION as f64)),
            ("entries", Json::Obj(entries)),
        ])
    }

    /// Write the trace to its path (byte-deterministic: `BTreeMap` order).
    /// A non-finite measurement is refused rather than written as
    /// invalid JSON that no replay could load.
    pub fn save(&self) -> io::Result<()> {
        let text = self
            .to_json()
            .try_dump()
            .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e.to_string()))?;
        std::fs::write(&self.path, text)
    }
}

impl ExecutionBackend for TraceBackend {
    fn measure_kernels(
        &self,
        gpu: &GpuSpec,
        fp: u64,
        comps: &[Kernel],
        comm: Option<&Kernel>,
        sched: &Schedule,
        temp_c: f64,
        power_limit: Option<f64>,
    ) -> ExecResult {
        let key = trace_key(fp, sched, temp_c, power_limit);
        if self.replay {
            let hit = self.entries.lock().get(&key).copied();
            match hit {
                Some(r) => {
                    self.replayed.fetch_add(1);
                    r
                }
                None => panic!(
                    "trace replay miss for key {key} in {}: the trace was recorded for a \
                     different scenario/seed — re-record it",
                    self.path.display()
                ),
            }
        } else {
            let r = execute_partition(gpu, comps, comm, sched, temp_c, power_limit);
            self.recorded.fetch_add(1);
            self.entries.lock().insert(key, r);
            r
        }
    }

    fn fingerprint(&self) -> u64 {
        // Record and replay of the *same* trace share a fingerprint, so a
        // record run and its replay produce identical memoization keys.
        self.fp
    }

    fn name(&self) -> &'static str {
        "trace"
    }

    fn caps(&self) -> BackendCaps {
        BackendCaps { deterministic: true, live: !self.replay }
    }
}

// ---------------------------------------------------------------------
// Measurer: backend + optional shared cache
// ---------------------------------------------------------------------

/// A backend plus an optional shared [`MeasureCache`], threaded through
/// the microbatch-evaluation layers. The cache sits *above* the backend:
/// a hit never reaches it, a miss consults it exactly once.
#[derive(Clone, Copy)]
pub struct Measurer<'a> {
    pub backend: &'a dyn ExecutionBackend,
    pub cache: Option<&'a MeasureCache>,
}

impl<'a> Measurer<'a> {
    pub fn new(backend: &'a dyn ExecutionBackend, cache: Option<&'a MeasureCache>) -> Self {
        Measurer { backend, cache }
    }

    /// Plain simulator, no cache — the default for tests and one-off
    /// evaluations.
    pub fn sim() -> Measurer<'static> {
        Measurer { backend: &SIM, cache: None }
    }

    /// Cache-or-measure one canonical execution (see
    /// [`MeasureCache::exec_opt`]).
    #[allow(clippy::too_many_arguments)]
    pub fn exec(
        &self,
        fp: u64,
        gpu: &GpuSpec,
        comps: &[Kernel],
        comm: Option<&Kernel>,
        sched: &Schedule,
        temp_c: f64,
        power_limit: Option<f64>,
    ) -> ExecResult {
        MeasureCache::exec_opt(
            self.backend,
            self.cache,
            fp,
            gpu,
            comps,
            comm,
            sched,
            temp_c,
            power_limit,
        )
    }
}

// ---------------------------------------------------------------------
// CLI backend specs
// ---------------------------------------------------------------------

/// Parsed `--backend` CLI value.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum BackendSpec {
    /// The in-process simulator (default).
    Sim,
    /// Trace file: replay it if it exists, record into it otherwise.
    Trace(PathBuf),
}

/// Parse a `--backend` value: `sim` or `trace:<path>`.
pub fn parse_backend_spec(spec: &str) -> Result<BackendSpec, String> {
    if spec == "sim" {
        return Ok(BackendSpec::Sim);
    }
    if let Some(path) = spec.strip_prefix("trace:") {
        if path.is_empty() {
            return Err("backend 'trace:' needs a file path (trace:<path>)".to_string());
        }
        return Ok(BackendSpec::Trace(PathBuf::from(path)));
    }
    Err(format!("unknown backend '{spec}' (sim | trace:<path>)"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::kernel::KernelKind;

    fn gpu() -> GpuSpec {
        GpuSpec::a100()
    }

    fn part() -> Partition {
        Partition {
            ptype: "fwd/attn".into(),
            comps: vec![
                Kernel::comp("norm", KernelKind::Norm, 1e8, 8e8),
                Kernel::comp("linear", KernelKind::Linear, 4e11, 2e9),
            ],
            comm: Some(Kernel::comm("ar", KernelKind::AllReduce, 4e8)),
            count: 28,
        }
    }

    fn sched() -> Schedule {
        Schedule::uniform(12, LaunchAt::WithComp(1), 1410)
    }

    fn tmp_path(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("kareus_{tag}_{}.json", std::process::id()))
    }

    #[test]
    fn sim_backend_matches_direct_simulator() {
        let g = gpu();
        let p = part();
        let direct =
            execute_partition(&g, &p.comps, p.comm.as_ref(), &sched(), 30.0, Some(g.tdp_w));
        let via = SIM.measure(&g, &p, &sched(), 30.0, Some(g.tdp_w));
        assert_eq!(direct.time_s.to_bits(), via.time_s.to_bits());
        assert_eq!(direct.dyn_j.to_bits(), via.dyn_j.to_bits());
        assert_eq!(direct.static_j.to_bits(), via.static_j.to_bits());
        assert!(SIM.caps().live && SIM.caps().deterministic);
        assert_eq!(SIM.name(), "sim");
    }

    #[test]
    fn trace_records_and_replays_bit_identically() {
        let path = tmp_path("trace_roundtrip");
        let _ = std::fs::remove_file(&path);
        let g = gpu();
        let p = part();

        let rec = TraceBackend::record(&path);
        assert!(!rec.is_replay() && rec.caps().live);
        let a = rec.measure(&g, &p, &sched(), 30.0, Some(g.tdp_w));
        let b = rec.measure(&g, &p, &Schedule::sequential(1200), 42.5, None);
        assert_eq!(rec.recorded(), 2);
        assert_eq!(rec.len(), 2);
        rec.save().unwrap();

        let rep = TraceBackend::open(&path).unwrap();
        assert!(rep.is_replay() && !rep.caps().live);
        let a2 = rep.measure(&g, &p, &sched(), 30.0, Some(g.tdp_w));
        let b2 = rep.measure(&g, &p, &Schedule::sequential(1200), 42.5, None);
        assert_eq!(rep.replayed(), 2);
        for (x, y) in [(a, a2), (b, b2)] {
            assert_eq!(x.time_s.to_bits(), y.time_s.to_bits());
            assert_eq!(x.dyn_j.to_bits(), y.dyn_j.to_bits());
            assert_eq!(x.static_j.to_bits(), y.static_j.to_bits());
            assert_eq!(x.exposed_comm_s.to_bits(), y.exposed_comm_s.to_bits());
            assert_eq!(x.avg_freq_mhz.to_bits(), y.avg_freq_mhz.to_bits());
            assert_eq!(x.throttled, y.throttled);
            assert_eq!(x.peak_power_w.to_bits(), y.peak_power_w.to_bits());
        }
        // Record and replay of the same path share an identity.
        assert_eq!(TraceBackend::record(&path).fingerprint(), rep.fingerprint());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    #[should_panic(expected = "trace replay miss")]
    fn trace_replay_miss_panics_instead_of_simulating() {
        let path = tmp_path("trace_miss");
        let _ = std::fs::remove_file(&path);
        let rec = TraceBackend::record(&path);
        let g = gpu();
        let p = part();
        rec.measure(&g, &p, &sched(), 30.0, Some(g.tdp_w));
        rec.save().unwrap();
        let rep = TraceBackend::replay(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        // Different temperature → different key → must not fall back to sim.
        rep.measure(&g, &p, &sched(), 31.0, Some(g.tdp_w));
    }

    #[test]
    fn trace_rejects_malformed_files() {
        let path = tmp_path("trace_bad");
        std::fs::write(&path, "{\"trace\":\"something_else\",\"version\":1,\"entries\":{}}")
            .unwrap();
        assert!(TraceBackend::replay(&path).is_err());
        std::fs::write(&path, "not json").unwrap();
        assert!(TraceBackend::replay(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn exec_result_json_roundtrip_is_exact() {
        let mut r = ExecResult {
            time_s: 0.12345678901234567,
            dyn_j: 3.1e2,
            static_j: 0.1 + 0.2, // deliberately non-representable sum
            exposed_comm_s: 0.0,
            avg_freq_mhz: 1403.7218374,
            throttled: true,
            peak_power_w: 401.25,
            freq_transitions: 0,
        };
        let dumped = exec_result_to_json(&r).dump();
        // Zero transitions keep the legacy byte layout.
        assert!(!dumped.contains("freq_transitions"), "{dumped}");
        let back = exec_result_from_json(&Json::parse(&dumped).unwrap()).unwrap();
        assert_eq!(r.time_s.to_bits(), back.time_s.to_bits());
        assert_eq!(r.static_j.to_bits(), back.static_j.to_bits());
        assert_eq!(r.avg_freq_mhz.to_bits(), back.avg_freq_mhz.to_bits());
        assert_eq!(r.throttled, back.throttled);
        assert_eq!(back.freq_transitions, 0);

        r.freq_transitions = 3;
        let dumped = exec_result_to_json(&r).dump();
        assert!(dumped.contains("freq_transitions"), "{dumped}");
        let back = exec_result_from_json(&Json::parse(&dumped).unwrap()).unwrap();
        assert_eq!(back.freq_transitions, 3);
    }

    #[test]
    fn trace_key_encodes_kernel_frequency_split() {
        let uni = trace_key(7, &sched(), 30.0, None);
        assert!(uni.contains("|12:c1:1410|"), "{uni}");
        let mut split = sched();
        split.kernel_freqs = KernelFreqs::PerClass { compute_mhz: 1410, memory_mhz: 900 };
        let per = trace_key(7, &split, 30.0, None);
        assert!(per.contains("|12:c1:1410m900|"), "{per}");
        assert_ne!(uni, per, "per-class split must never alias the uniform key");
    }

    #[test]
    fn kernels_fp_distinguishes_work() {
        let p = part();
        let a = kernels_fp(1, &p.comps, p.comm.as_ref());
        let b = kernels_fp(1, &p.comps, None);
        let c = kernels_fp(2, &p.comps, p.comm.as_ref());
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_eq!(a, kernels_fp(1, &p.comps, p.comm.as_ref()));
    }

    #[test]
    fn backend_spec_parsing() {
        assert_eq!(parse_backend_spec("sim").unwrap(), BackendSpec::Sim);
        assert_eq!(
            parse_backend_spec("trace:/tmp/t.json").unwrap(),
            BackendSpec::Trace(PathBuf::from("/tmp/t.json"))
        );
        assert!(parse_backend_spec("trace:").is_err());
        assert!(parse_backend_spec("hardware").is_err());
    }

    #[test]
    fn backend_fingerprints_never_alias() {
        let t = TraceBackend::record("/tmp/a.json");
        let u = TraceBackend::record("/tmp/b.json");
        assert_ne!(SIM.fingerprint(), t.fingerprint());
        assert_ne!(t.fingerprint(), u.fingerprint());
        // The compile-time sim fingerprint tracks the runtime FNV-1a.
        assert_eq!(SIM.fingerprint(), crate::util::hash::fnv1a_str("kareus_backend:sim:v1"));
    }

    #[test]
    fn shared_cache_never_aliases_across_backends() {
        // Cloning an EngineConfig shares the MeasureCache while
        // `with_backend` swaps the measurement source — a probe through a
        // different backend must miss (and reach that backend), never
        // replay another source's entry.
        let g = gpu();
        let p = part();
        let cache = MeasureCache::new();
        let fp = kernels_fp(g.fingerprint(), &p.comps, p.comm.as_ref());
        let a = MeasureCache::exec_opt(
            &SIM, Some(&cache), fp, &g, &p.comps, p.comm.as_ref(), &sched(), 30.0, Some(g.tdp_w),
        );
        let t = TraceBackend::record(tmp_path("alias"));
        let m0 = cache.misses();
        let b = MeasureCache::exec_opt(
            &t, Some(&cache), fp, &g, &p.comps, p.comm.as_ref(), &sched(), 30.0, Some(g.tdp_w),
        );
        assert_eq!(cache.misses(), m0 + 1, "trace probe aliased the sim-warmed cache entry");
        assert_eq!(t.recorded(), 1, "the trace backend never saw the measurement");
        // Identical physics either way — only the cache identity differs.
        assert_eq!(a.time_s.to_bits(), b.time_s.to_bits());
    }
}
