//! Workload model: transformer architectures, parallelism, and the
//! kernel-sequence builder that substitutes for profiling real
//! Megatron-LM layers (DESIGN.md §1).

pub mod builder;
pub mod models;

pub use builder::{build_nanobatch_pass, build_pass, Dir, MicrobatchWork, Segment};
pub use models::{ModelSpec, Parallelism, TrainConfig};
