//! Model architecture presets for the paper's workloads (§6.1):
//! Llama 3.2 3B and Qwen 3 1.7B on the testbed, Llama 3.3 70B in
//! large-scale emulation.

/// Transformer architecture description (decoder-only, GQA, SwiGLU).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ModelSpec {
    pub name: &'static str,
    pub n_layers: u32,
    pub d_model: u32,
    pub n_heads: u32,
    pub n_kv_heads: u32,
    pub d_ff: u32,
    pub vocab: u32,
}

impl ModelSpec {
    pub fn llama32_3b() -> Self {
        ModelSpec {
            name: "Llama 3.2 3B",
            n_layers: 28,
            d_model: 3072,
            n_heads: 24,
            n_kv_heads: 8,
            d_ff: 8192,
            vocab: 128_256,
        }
    }

    pub fn qwen3_1_7b() -> Self {
        ModelSpec {
            name: "Qwen 3 1.7B",
            n_layers: 28,
            d_model: 2048,
            n_heads: 16,
            n_kv_heads: 8,
            d_ff: 6144,
            vocab: 151_936,
        }
    }

    pub fn llama33_70b() -> Self {
        ModelSpec {
            name: "Llama 3.3 70B",
            n_layers: 80,
            d_model: 8192,
            n_heads: 64,
            n_kv_heads: 8,
            d_ff: 28_672,
            vocab: 128_256,
        }
    }

    pub fn head_dim(&self) -> u32 {
        self.d_model / self.n_heads
    }

    /// Approximate parameter count (embeddings + blocks).
    pub fn n_params(&self) -> f64 {
        let d = self.d_model as f64;
        let ff = self.d_ff as f64;
        let kv = (self.n_kv_heads as f64 / self.n_heads as f64) * d;
        let per_layer = d * d      // wq
            + 2.0 * d * kv         // wk, wv
            + d * d                // wo
            + 3.0 * d * ff         // gate, up, down
            + 2.0 * d; // norms
        self.n_layers as f64 * per_layer + 2.0 * (self.vocab as f64 * d)
    }
}

/// Multi-GPU parallelization (§6.1): tensor, context, pipeline.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Parallelism {
    pub tp: u32,
    pub cp: u32,
    pub pp: u32,
}

impl Parallelism {
    pub fn new(tp: u32, cp: u32, pp: u32) -> Self {
        assert!(tp >= 1 && cp >= 1 && pp >= 1);
        Parallelism { tp, cp, pp }
    }

    pub fn gpus(&self) -> u32 {
        self.tp * self.cp * self.pp
    }

    pub fn label(&self) -> String {
        let mut s = String::new();
        if self.cp > 1 {
            s.push_str(&format!("CP{}", self.cp));
        }
        s.push_str(&format!("TP{}", self.tp));
        s
    }
}

/// One training workload row (the paper's Table 3 rows).
#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    pub model: ModelSpec,
    pub par: Parallelism,
    pub microbatch: u32,
    pub seq_len: u32,
    pub n_microbatches: u32,
    /// Activation/weight element size in bytes (bf16 = 2).
    pub dtype_bytes: u32,
}

impl TrainConfig {
    /// Tokens processed per microbatch on one (TP, CP)-sharded GPU.
    /// Context parallelism splits the sequence across CP ranks.
    pub fn tokens_per_gpu(&self) -> f64 {
        self.microbatch as f64 * self.seq_len as f64 / self.par.cp as f64
    }

    /// Layers resident on one pipeline stage (balanced split, §6.1).
    pub fn layers_per_stage(&self) -> u32 {
        self.model.n_layers.div_ceil(self.par.pp)
    }

    pub fn label(&self) -> String {
        format!(
            "{} {} µb{} seq{}K",
            self.model.name,
            self.par.label(),
            self.microbatch,
            self.seq_len / 1024
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_roughly_match_names() {
        let l3b = ModelSpec::llama32_3b().n_params() / 1e9;
        assert!((2.0..4.5).contains(&l3b), "llama3b {l3b}B");
        let q17 = ModelSpec::qwen3_1_7b().n_params() / 1e9;
        assert!((1.2..2.5).contains(&q17), "qwen {q17}B");
        let l70 = ModelSpec::llama33_70b().n_params() / 1e9;
        assert!((60.0..80.0).contains(&l70), "llama70 {l70}B");
    }

    #[test]
    fn parallelism_gpu_count() {
        assert_eq!(Parallelism::new(4, 2, 2).gpus(), 16);
        assert_eq!(Parallelism::new(8, 1, 2).label(), "TP8");
        assert_eq!(Parallelism::new(4, 2, 2).label(), "CP2TP4");
    }

    #[test]
    fn tokens_split_by_cp() {
        let cfg = TrainConfig {
            model: ModelSpec::qwen3_1_7b(),
            par: Parallelism::new(4, 2, 2),
            microbatch: 16,
            seq_len: 4096,
            n_microbatches: 8,
            dtype_bytes: 2,
        };
        assert_eq!(cfg.tokens_per_gpu(), 16.0 * 4096.0 / 2.0);
        assert_eq!(cfg.layers_per_stage(), 14);
    }
}
