//! Kernel-sequence builder: expands (model × parallelism × microbatch ×
//! seq-len) into the per-GPU kernel streams the optimizer schedules.
//!
//! This is the substitute for profiling real Megatron-LM layers: the
//! optimizer only ever sees kernels with FLOP/byte/comm-volume demands,
//! and the builder derives those from the architecture exactly as the
//! paper's Figure 3/Figure 10 describe (Norm, QKV Linear, RoPE,
//! FlashAttention, projection/MLP Linears, activation, AllReduce for TP,
//! AllGather for CP).
//!
//! MXU/tensor-core efficiency: dense kernels never achieve peak; we fold
//! an achieved-efficiency derate into the FLOP demand (time right; power
//! slightly conservative — stalled pipelines still draw near-active
//! power). Megatron-LM's measured 99 TFLOP/s/GPU (Table 1) emerges from
//! this derate plus exposed communication.

use crate::sim::kernel::{Kernel, KernelKind};

use super::models::TrainConfig;

/// Achieved fraction of tensor peak per kernel class.
pub const EFF_LINEAR: f64 = 0.62;
pub const EFF_FLASH: f64 = 0.42;
pub const EFF_EMBED: f64 = 0.30;

/// A forward or backward pass direction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dir {
    Fwd,
    Bwd,
}

/// One schedulable segment: a computation sequence ending in (optionally)
/// one communication kernel. Two segments per transformer layer:
/// Attention→AllReduce and MLP→AllReduce (Figure 5, second row).
#[derive(Clone, Debug)]
pub struct Segment {
    /// Segment type label ("attn" / "mlp"), used for partition typing.
    pub stype: &'static str,
    pub comps: Vec<Kernel>,
    pub comm: Option<Kernel>,
}

impl Segment {
    pub fn total_flops(&self) -> f64 {
        self.comps.iter().map(|k| k.flops).sum()
    }
    pub fn total_bytes(&self) -> f64 {
        self.comps.iter().map(|k| k.bytes).sum::<f64>()
            + self.comm.as_ref().map(|c| c.bytes).unwrap_or(0.0)
    }
    pub fn comm_bytes(&self) -> f64 {
        self.comm.as_ref().map(|c| c.comm_bytes).unwrap_or(0.0)
    }
}

/// The kernel stream of one microbatch (or nanobatch) on one GPU of one
/// pipeline stage: `layers_per_stage` repetitions of [attn, mlp] segments,
/// plus non-segment work (embedding / head / optimizer slice).
#[derive(Clone, Debug)]
pub struct MicrobatchWork {
    pub dir: Dir,
    pub segments: Vec<Segment>,
    /// Computation outside partitions (embedding lookup, final norm+head
    /// on the last stage, gradient-accumulation on bwd): executed
    /// sequentially, scheduled only by frequency.
    pub extra: Vec<Kernel>,
}

/// Build the forward kernel stream for `tokens` tokens (a full microbatch
/// or one nanobatch) on one GPU.
pub fn build_pass(
    cfg: &TrainConfig,
    tokens: f64,
    dir: Dir,
    first_stage: bool,
    last_stage: bool,
) -> MicrobatchWork {
    let m = &cfg.model;
    let b = cfg.dtype_bytes as f64;
    let tp = cfg.par.tp as f64;
    let cp = cfg.par.cp as f64;
    let d = m.d_model as f64;
    let d_ff = m.d_ff as f64;
    let hd = m.head_dim() as f64;
    let kv_d = m.n_kv_heads as f64 * hd;
    // Backward with activation checkpointing (§6.1): recompute forward,
    // then backprop (dgrad + wgrad) => ~3× forward FLOPs, ~2.5× bytes.
    let (fmul, bmul) = match dir {
        Dir::Fwd => (1.0, 1.0),
        Dir::Bwd => (3.0, 2.5),
    };

    let mut segments = Vec::new();
    for _ in 0..cfg.layers_per_stage() {
        // ---------------- Attention segment ----------------
        // Norm carries the residual-add + dropout traffic of the block
        // boundary (read x, read residual, write sum, read for norm,
        // write normed ≈ 5 activation passes) — this is what makes Norm a
        // substantial memory-bound kernel in Figure 3.
        let mut comps = Vec::new();
        comps.push(Kernel::comp(
            "Norm",
            KernelKind::Norm,
            fmul * 6.0 * tokens * d,
            bmul * 5.0 * tokens * d * b,
        ));
        // Fused QKV projection (columns sharded by TP).
        let qkv_cols = (d + 2.0 * kv_d) / tp;
        comps.push(Kernel::comp(
            "LinearQKV",
            KernelKind::Linear,
            fmul * 2.0 * tokens * d * qkv_cols / EFF_LINEAR,
            bmul * b * (tokens * d + tokens * qkv_cols + d * qkv_cols),
        ));
        comps.push(Kernel::comp(
            "RoPE",
            KernelKind::Rope,
            fmul * 6.0 * tokens * (d + kv_d) / tp,
            bmul * 2.0 * tokens * (d + kv_d) / tp * b,
        ));
        // Context parallelism: AllGather K/V across CP ranks before
        // attention (Llama 3 scheme, §4.5/§6.1). Fused K+V gather.
        let cp_comm = if cfg.par.cp > 1 {
            let kv_bytes = 2.0 * tokens * kv_d / tp * b; // local K+V shard
            Some(Kernel::comm("AllGatherKV", KernelKind::AllGather, kv_bytes * (cp - 1.0)))
        } else {
            None
        };
        // FlashAttention: queries = local tokens; keys = the full
        // per-sample sequence (nanobatching splits the *batch* dimension,
        // so attention span is unchanged; under CP the AllGather restores
        // the full key sequence). Causal halves the scores.
        let kv_tokens = cfg.seq_len as f64;
        comps.push(Kernel::comp(
            "FlashAttention",
            KernelKind::FlashAttention,
            fmul * 0.5 * 4.0 * tokens * kv_tokens * hd * (m.n_heads as f64 / tp) / EFF_FLASH,
            bmul * 3.0 * tokens * d / tp * b,
        ));
        comps.push(Kernel::comp(
            "LinearProj",
            KernelKind::Linear,
            fmul * 2.0 * tokens * (d / tp) * d / EFF_LINEAR,
            bmul * b * (tokens * d / tp + tokens * d + d * d / tp),
        ));
        // TP AllReduce of the attention output (ring volume).
        let ar_bytes = tokens * d * b * 2.0 * (tp - 1.0) / tp;
        let attn_comm = if cfg.par.tp > 1 {
            Some(Kernel::comm("AllReduce", KernelKind::AllReduce, ar_bytes))
        } else {
            None
        };
        // The CP AllGather is fused with the TP AllReduce of the previous
        // segment when both exist (§4.5 "multiple communication kernels");
        // we attach it as the segment's comm if TP comm is absent.
        let comm = match (attn_comm, cp_comm) {
            (Some(ar), Some(ag)) => Some(Kernel::fuse_comm(&[ar, ag])),
            (Some(ar), None) => Some(ar),
            (None, Some(ag)) => Some(ag),
            (None, None) => None,
        };
        segments.push(Segment { stype: "attn", comps, comm });

        // ---------------- MLP segment ----------------
        let mut comps = Vec::new();
        comps.push(Kernel::comp(
            "Norm",
            KernelKind::Norm,
            fmul * 6.0 * tokens * d,
            bmul * 5.0 * tokens * d * b,
        ));
        comps.push(Kernel::comp(
            "LinearGateUp",
            KernelKind::Linear,
            fmul * 2.0 * tokens * d * (2.0 * d_ff / tp) / EFF_LINEAR,
            bmul * b * (tokens * d + 2.0 * tokens * d_ff / tp + 2.0 * d * d_ff / tp),
        ));
        comps.push(Kernel::comp(
            "Activation",
            KernelKind::Activation,
            fmul * 8.0 * tokens * d_ff / tp,
            bmul * 3.0 * tokens * d_ff / tp * b,
        ));
        comps.push(Kernel::comp(
            "LinearDown",
            KernelKind::Linear,
            fmul * 2.0 * tokens * (d_ff / tp) * d / EFF_LINEAR,
            bmul * b * (tokens * d_ff / tp + tokens * d + d * d_ff / tp),
        ));
        let mlp_comm = if cfg.par.tp > 1 {
            Some(Kernel::comm(
                "AllReduce",
                KernelKind::AllReduce,
                tokens * d * b * 2.0 * (tp - 1.0) / tp,
            ))
        } else {
            None
        };
        segments.push(Segment { stype: "mlp", comps, comm: mlp_comm });
    }

    // ---------------- Non-segment components ----------------
    let mut extra = Vec::new();
    if first_stage {
        extra.push(Kernel::comp(
            "Embedding",
            KernelKind::Embedding,
            0.0,
            bmul * tokens * d * b * 2.0,
        ));
    }
    if last_stage {
        extra.push(Kernel::comp(
            "FinalNorm",
            KernelKind::Norm,
            fmul * 4.0 * tokens * d,
            bmul * 2.0 * tokens * d * b,
        ));
        extra.push(Kernel::comp(
            "LMHead",
            KernelKind::Linear,
            fmul * 2.0 * tokens * d * (m.vocab as f64 / tp) / EFF_EMBED,
            bmul * b * (tokens * d + tokens * m.vocab as f64 / tp),
        ));
    }
    if dir == Dir::Bwd {
        // Per-pass weight-gradient accumulation traffic (fp32 grads).
        let weight_elems_per_stage = (12.0 * d * d + 3.0 * d * d_ff).max(1.0) / tp
            * cfg.layers_per_stage() as f64
            / 3.0; // only a slice is touched per nanobatch in steady state
        extra.push(Kernel::comp(
            "GradAccum",
            KernelKind::GradAccum,
            weight_elems_per_stage,
            3.0 * 4.0 * weight_elems_per_stage,
        ));
    }

    MicrobatchWork { dir, segments, extra }
}

/// Nanobatching (§2.2): split one microbatch into two equal nanobatches.
/// The returned work is for ONE nanobatch; callers pair the comm of one
/// nanobatch with the computation of the other. Extra memory traffic and
/// gradient accumulation make total dynamic work slightly higher than the
/// unsplit microbatch (Table 1's "slightly higher dynamic energy").
pub const NANOBATCH_BYTES_OVERHEAD: f64 = 1.05;

pub fn build_nanobatch_pass(
    cfg: &TrainConfig,
    dir: Dir,
    first_stage: bool,
    last_stage: bool,
) -> MicrobatchWork {
    let tokens = cfg.tokens_per_gpu() / 2.0;
    let mut work = build_pass(cfg, tokens, dir, first_stage, last_stage);
    for seg in &mut work.segments {
        for k in &mut seg.comps {
            k.bytes *= NANOBATCH_BYTES_OVERHEAD;
        }
    }
    work
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::models::{ModelSpec, Parallelism};

    fn cfg() -> TrainConfig {
        TrainConfig {
            model: ModelSpec::qwen3_1_7b(),
            par: Parallelism::new(8, 1, 2),
            microbatch: 8,
            seq_len: 4096,
            n_microbatches: 8,
            dtype_bytes: 2,
        }
    }

    #[test]
    fn two_segments_per_layer() {
        let w = build_pass(&cfg(), cfg().tokens_per_gpu(), Dir::Fwd, true, false);
        assert_eq!(w.segments.len() as u32, 2 * cfg().layers_per_stage());
        assert_eq!(w.segments[0].stype, "attn");
        assert_eq!(w.segments[1].stype, "mlp");
    }

    #[test]
    fn tp_produces_allreduce() {
        let w = build_pass(&cfg(), 1000.0, Dir::Fwd, false, false);
        for seg in &w.segments {
            let c = seg.comm.as_ref().expect("TP>1 must emit comm");
            assert!(c.is_comm());
            assert!(c.comm_bytes > 0.0);
        }
    }

    #[test]
    fn tp1_has_no_comm() {
        let mut c = cfg();
        c.par = Parallelism::new(1, 1, 2);
        let w = build_pass(&c, 1000.0, Dir::Fwd, false, false);
        assert!(w.segments.iter().all(|s| s.comm.is_none()));
    }

    #[test]
    fn cp_fuses_allgather_into_attn_comm() {
        let mut c = cfg();
        c.par = Parallelism::new(4, 2, 2);
        let w = build_pass(&c, c.tokens_per_gpu(), Dir::Fwd, false, false);
        let attn = &w.segments[0];
        let mlp = &w.segments[1];
        assert!(attn.comm_bytes() > mlp.comm_bytes(), "fused CP+TP comm is larger");
    }

    #[test]
    fn bwd_has_more_flops_than_fwd() {
        let c = cfg();
        let f = build_pass(&c, 1000.0, Dir::Fwd, false, false);
        let b = build_pass(&c, 1000.0, Dir::Bwd, false, false);
        assert!(b.segments[0].total_flops() > 2.0 * f.segments[0].total_flops());
    }

    #[test]
    fn flop_count_matches_analytic_estimate() {
        // fwd FLOPs/token/layer ≈ 2·(params/layer)/tp + attention; sanity
        // check we are within 2× of 6ND/3-style accounting.
        let c = cfg();
        let tokens = 1000.0;
        let w = build_pass(&c, tokens, Dir::Fwd, false, false);
        let per_layer: f64 = (w.segments[0].total_flops() + w.segments[1].total_flops())
            * EFF_LINEAR; // undo derate roughly
        let d = c.model.d_model as f64;
        let ff = c.model.d_ff as f64;
        let analytic = 2.0 * tokens * (2.3 * d * d + 3.0 * d * ff) / c.par.tp as f64;
        let ratio = per_layer / analytic;
        assert!((0.5..2.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn nanobatch_half_tokens_extra_bytes() {
        let c = cfg();
        let full = build_pass(&c, c.tokens_per_gpu(), Dir::Fwd, false, false);
        let nano = build_nanobatch_pass(&c, Dir::Fwd, false, false);
        let f0 = full.segments[0].total_flops();
        let n0 = nano.segments[0].total_flops();
        assert!((n0 / f0 - 0.5).abs() < 0.05, "flops ratio {}", n0 / f0);
        // Dynamic-work overhead: 2 nanobatches move more bytes than 1 µb.
        let fb: f64 = full.segments.iter().map(|s| s.total_bytes()).sum();
        let nb: f64 = nano.segments.iter().map(|s| s.total_bytes()).sum();
        assert!(2.0 * nb > fb * 1.01);
    }

    #[test]
    fn stage_roles_add_extra_kernels() {
        let c = cfg();
        let first = build_pass(&c, 1000.0, Dir::Fwd, true, false);
        let mid = build_pass(&c, 1000.0, Dir::Fwd, false, false);
        let last = build_pass(&c, 1000.0, Dir::Fwd, false, true);
        assert!(first.extra.len() > mid.extra.len());
        assert!(last.extra.iter().any(|k| k.name == "LMHead"));
    }

    #[test]
    fn comm_scales_with_tp_ring_factor() {
        let mut c2 = cfg();
        c2.par = Parallelism::new(2, 1, 2);
        let mut c8 = cfg();
        c8.par = Parallelism::new(8, 1, 2);
        let w2 = build_pass(&c2, 1000.0, Dir::Fwd, false, false);
        let w8 = build_pass(&c8, 1000.0, Dir::Fwd, false, false);
        let r = w8.segments[1].comm_bytes() / w2.segments[1].comm_bytes();
        // ring factor 2(tp-1)/tp: (2·7/8)/(2·1/2) = 1.75
        assert!((r - 1.75).abs() < 0.01, "ratio {r}");
    }
}
