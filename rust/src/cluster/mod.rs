//! Cluster-scale power-cap scheduling over per-job time–energy frontiers.
//!
//! Kareus produces, per training job, a Pareto frontier of iteration-level
//! (time, energy) operating points plus the typed
//! [`FrequencyPlan`](crate::plan::FrequencyPlan) behind each point (§4.1:
//! deadlines, energy budgets, changing environments). This module is the
//! layer above the single job: a datacenter runs N such jobs under a
//! *shared* power cap (demand charges, peak shaving, brownout response —
//! the Perseus / energy-aware cluster-scheduling line of work), and the
//! cap has to be split across jobs so the cluster loses as little
//! aggregate throughput as possible.
//!
//! * [`JobMenu`] — one job's frontier reduced to the scheduler's view:
//!   ascending-time operating points, each with the job's cluster-wide
//!   average power draw (per-GPU energy/time × GPUs × replicas).
//! * [`allocate`] — marginal-cost water-filling. Every job starts at its
//!   max-throughput point; while the cap is exceeded, the scheduler takes
//!   the single move (one job, one step down its frontier) that loses the
//!   least throughput per watt freed — equalizing the marginal trade
//!   dJ/dP across jobs at convergence, where J is aggregate weighted
//!   throughput — and a final refill pass spends leftover headroom on the
//!   highest-value up-moves. A cap below the cluster-wide minimum power
//!   pins every job at its minimum-power point and flags the slice
//!   infeasible (no panic).
//! * [`PowerCapSchedule`] — a piecewise-constant cap timeline (a constant
//!   cap is the one-segment special case). The planner re-allocates at
//!   every cap boundary by **re-selecting** along the retained frontiers
//!   and stage menus — no MBO re-run.
//! * [`ClusterPlan`] — the typed result: per cap segment, per job, the
//!   selected frontier point and its deployable
//!   [`FrequencyPlan`](crate::plan::FrequencyPlan). Serde-free JSON
//!   round-trip via [`util::json`](crate::util::json); the dump is
//!   byte-deterministic for fixed inputs (no wall-clock or cache
//!   statistics in the schema).
//!
//! The uniform-split reference policy lives in
//! [`baselines::uniform_cap_allocation`](crate::baselines::uniform_cap_allocation);
//! `kareus paper --exp cluster` compares the two.
//!
//! ## `ClusterPlan` JSON schema (version 1)
//!
//! ```jsonc
//! {
//!   "plan": "kareus_cluster",
//!   "version": 1,
//!   "cap_schedule": [{"start_s": 0, "cap_w": 40000}, ...],
//!   "jobs": [
//!     {
//!       "label": "a100:qwen1.7b:tp8pp2:m+p",
//!       "gpu": "A100-SXM4-40GB", "model": "Qwen 3 1.7B",
//!       "parallelism": "tp8cp1pp2", "system": "Megatron-LM+Perseus",
//!       "replicas": 1, "n_gpus": 16, "tokens_per_iter": 262144,
//!       "skipped": false,
//!       // ascending time: [iter_time_s, per-GPU iter_energy_j, cluster power_w]
//!       "menu": [[0.523, 2841.0, 86918.7], ...]
//!     }
//!   ],
//!   "slices": [
//!     {
//!       "start_s": 0, "cap_w": 40000, "feasible": true,
//!       "total_power_w": 39214.0, "tokens_per_s": 1.61e6,
//!       "assignments": [
//!         {"job": 0, "point": 3, "iter_time_s": 0.61, "iter_energy_j": 2390.0,
//!          "power_w": 12672.1, "plan": { /* FrequencyPlan, see kareus::plan */ }}
//!       ]
//!     }
//!   ]
//! }
//! ```

use crate::baselines::{run_system_with, SystemResult};
use crate::engine::{parse_model, parse_parallelism, parse_system, EngineConfig, Scenario};
use crate::frontier::Frontier;
use crate::plan::FrequencyPlan;
use crate::sim::gpu::GpuSpec;
use crate::util::json::{arr, num, obj, s, Json};
use crate::workload::TrainConfig;

// ---------------------------------------------------------------------------
// Cap schedule
// ---------------------------------------------------------------------------

/// One segment of the datacenter power-cap timeline: from `start_s`
/// (seconds since the schedule origin) until the next segment starts (the
/// last segment extends indefinitely), the cluster may draw at most
/// `cap_w` watts.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CapSegment {
    pub start_s: f64,
    pub cap_w: f64,
}

/// A piecewise-constant datacenter power cap over wall-clock time.
/// Segments are validated to start at 0 and strictly ascend, with finite
/// positive caps; a constant cap is the one-segment special case.
#[derive(Clone, Debug, PartialEq)]
pub struct PowerCapSchedule {
    segments: Vec<CapSegment>,
}

impl PowerCapSchedule {
    /// A constant cap (one segment from t = 0).
    pub fn constant(cap_w: f64) -> Self {
        Self::piecewise(vec![CapSegment { start_s: 0.0, cap_w }])
            .expect("constant cap must be finite and positive")
    }

    /// Validate and build a piecewise schedule. The first segment must
    /// start at 0, starts must strictly ascend, and every cap must be a
    /// finite positive wattage.
    pub fn piecewise(segments: Vec<CapSegment>) -> Result<Self, String> {
        if segments.is_empty() {
            return Err("cap schedule needs at least one segment".to_string());
        }
        if segments[0].start_s != 0.0 {
            return Err(format!(
                "first cap segment must start at 0 s (got {} s)",
                segments[0].start_s
            ));
        }
        for w in segments.windows(2) {
            if w[1].start_s <= w[0].start_s {
                return Err(format!(
                    "cap segment starts must strictly ascend ({} s then {} s)",
                    w[0].start_s, w[1].start_s
                ));
            }
        }
        for seg in &segments {
            if !seg.cap_w.is_finite() || seg.cap_w <= 0.0 || !seg.start_s.is_finite() {
                return Err(format!(
                    "cap segment ({} s, {} W) must have finite start and positive finite cap",
                    seg.start_s, seg.cap_w
                ));
            }
        }
        Ok(PowerCapSchedule { segments })
    }

    /// Parse the CLI cap-schedule format: either a plain wattage
    /// (`"40000"` — constant cap) or comma-separated `start:watts` pairs
    /// (`"0:40000,3600:25000"`).
    pub fn parse(spec: &str) -> Result<Self, String> {
        let mut segments = Vec::new();
        for item in spec.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            let (start, cap) = match item.split_once(':') {
                Some((a, b)) => (a, b),
                None => ("0", item),
            };
            let start_s: f64 =
                start.trim().parse().map_err(|_| format!("bad segment start '{start}'"))?;
            let cap_w: f64 = cap.trim().parse().map_err(|_| format!("bad cap wattage '{cap}'"))?;
            segments.push(CapSegment { start_s, cap_w });
        }
        Self::piecewise(segments)
    }

    pub fn segments(&self) -> &[CapSegment] {
        &self.segments
    }

    /// The cap in force at time `t_s` (clamped to the first segment for
    /// negative times).
    pub fn cap_at(&self, t_s: f64) -> f64 {
        let mut cap = self.segments[0].cap_w;
        for seg in &self.segments {
            if seg.start_s <= t_s {
                cap = seg.cap_w;
            } else {
                break;
            }
        }
        cap
    }

    pub fn to_json(&self) -> Json {
        arr(self
            .segments
            .iter()
            .map(|seg| obj(vec![("start_s", num(seg.start_s)), ("cap_w", num(seg.cap_w))]))
            .collect())
    }

    pub fn from_json(j: &Json) -> Result<Self, String> {
        let segs = j.as_arr().ok_or("cap_schedule must be an array")?;
        let mut segments = Vec::with_capacity(segs.len());
        for sj in segs {
            let get = |k: &str| {
                sj.get(k)
                    .and_then(|v| v.as_f64())
                    .ok_or_else(|| format!("cap segment missing '{k}'"))
            };
            segments.push(CapSegment { start_s: get("start_s")?, cap_w: get("cap_w")? });
        }
        Self::piecewise(segments)
    }
}

// ---------------------------------------------------------------------------
// Jobs and menus
// ---------------------------------------------------------------------------

/// One training job competing for the shared cap: a sweep-engine
/// [`Scenario`] (GPU × model × parallelism × system × seed) plus a number
/// of data-parallel pipeline replicas that share its operating point.
#[derive(Clone, Debug)]
pub struct ClusterJob {
    /// Display/JSON label; defaults to the job-spec string or scenario
    /// label.
    pub label: String,
    pub scenario: Scenario,
    /// Data-parallel replicas of the pipeline (≥ 1). Power and throughput
    /// both scale linearly with replicas.
    pub replicas: u32,
}

impl ClusterJob {
    pub fn new(scenario: Scenario) -> Self {
        ClusterJob { label: scenario.label(), scenario, replicas: 1 }
    }

    pub fn with_replicas(mut self, replicas: u32) -> Self {
        assert!(replicas >= 1, "a job needs at least one pipeline replica");
        self.replicas = replicas;
        self
    }

    /// Total GPUs the job occupies (one pipeline × replicas).
    pub fn n_gpus(&self) -> u32 {
        self.scenario.cfg.par.gpus() * self.replicas
    }

    /// Tokens one pipeline processes per iteration.
    pub fn tokens_per_iter(&self) -> f64 {
        let c = &self.scenario.cfg;
        c.microbatch as f64 * c.seq_len as f64 * c.n_microbatches as f64
    }
}

/// Parse a CLI job spec `gpu:model:par:system[:replicas]`, e.g.
/// `a100:qwen1.7b:tp8pp2:m+p` or `v100:llama3b:cp2tp4pp2:kareus:4`.
/// The microbatching settings and seed are shared across the job list.
pub fn parse_job_spec(
    spec: &str,
    microbatch: u32,
    seq_len: u32,
    n_microbatches: u32,
    seed: u64,
) -> Result<ClusterJob, String> {
    let fields: Vec<&str> = spec.split(':').collect();
    if fields.len() < 4 || fields.len() > 5 {
        return Err("expected gpu:model:par:system[:replicas]".to_string());
    }
    let gpu = GpuSpec::by_name(fields[0])
        .ok_or_else(|| format!("unknown gpu '{}' (a100 | h100 | v100)", fields[0]))?;
    let model = parse_model(fields[1])
        .ok_or_else(|| format!("unknown model '{}' (qwen1.7b | llama3b | llama70b)", fields[1]))?;
    let par = parse_parallelism(fields[2])
        .ok_or_else(|| format!("bad parallelism '{}' (e.g. tp8pp2)", fields[2]))?;
    let system =
        parse_system(fields[3]).ok_or_else(|| format!("unknown system '{}'", fields[3]))?;
    let replicas: u32 = match fields.get(4) {
        Some(r) => match r.parse() {
            Ok(n) if n >= 1 => n,
            _ => return Err(format!("bad replica count '{r}'")),
        },
        None => 1,
    };
    let cfg = TrainConfig { model, par, microbatch, seq_len, n_microbatches, dtype_bytes: 2 };
    let scenario = Scenario { gpu, cfg, system, seed };
    Ok(ClusterJob { label: spec.to_string(), scenario, replicas })
}

/// One operating point as the cluster scheduler sees it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MenuPoint {
    /// Iteration time at this point (s).
    pub iter_time_s: f64,
    /// Per-GPU iteration energy (J) — same unit as the sweep/frontier
    /// JSON schemas.
    pub iter_energy_j: f64,
    /// Cluster-wide average draw of the whole job at this point (W):
    /// per-GPU energy/time × GPUs per pipeline × replicas.
    pub power_w: f64,
}

/// One job's frontier reduced to the scheduler's menu: points in
/// ascending iteration time (thus, on a real Pareto frontier, strictly
/// descending power), plus the job's throughput weight.
#[derive(Clone, Debug, PartialEq)]
pub struct JobMenu {
    /// Tokens the whole job (all replicas) processes per iteration; the
    /// job's throughput at point `k` is `weight / points[k].iter_time_s`.
    pub weight: f64,
    pub points: Vec<MenuPoint>,
}

impl JobMenu {
    /// Build the menu from an iteration frontier. `tokens_per_iter` is
    /// per pipeline; replicas scale both weight and power.
    pub fn from_frontier(
        frontier: &Frontier,
        n_gpus: u32,
        replicas: u32,
        tokens_per_iter: f64,
    ) -> JobMenu {
        let scale = n_gpus as f64 * replicas as f64;
        let points = frontier
            .points()
            .iter()
            .map(|p| MenuPoint {
                iter_time_s: p.time,
                iter_energy_j: p.energy,
                power_w: p.avg_power_w() * scale,
            })
            .collect();
        JobMenu { weight: tokens_per_iter * replicas as f64, points }
    }

    /// Job throughput (tokens/s) at menu point `k`.
    pub fn tokens_per_s(&self, k: usize) -> f64 {
        self.weight / self.points[k].iter_time_s
    }

    /// Index of the minimum-power point (last point on a real frontier).
    pub fn min_power_point(&self) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (k, p) in self.points.iter().enumerate() {
            if best.is_none_or(|b| p.power_w < self.points[b].power_w) {
                best = Some(k);
            }
        }
        best
    }
}

// ---------------------------------------------------------------------------
// Allocation
// ---------------------------------------------------------------------------

/// A selection of one menu point per job under one cap value.
#[derive(Clone, Debug, PartialEq)]
pub struct Allocation {
    /// Per job: selected menu index, or `None` for jobs with an empty
    /// menu (skipped).
    pub selection: Vec<Option<usize>>,
    /// False when the policy could not respect its cap. For [`allocate`]
    /// that means the cap sits below the cluster-wide minimum power and
    /// every job is pinned at its minimum-power point; for the uniform
    /// baseline it means some job's minimum power exceeds its equal
    /// share (only those jobs are pinned).
    pub feasible: bool,
    /// Total cluster draw of the selection (W).
    pub total_power_w: f64,
    /// Aggregate throughput of the selection (tokens/s).
    pub tokens_per_s: f64,
}

impl Allocation {
    /// Finalize a raw per-job selection into an [`Allocation`] (computes
    /// the power and throughput aggregates).
    pub fn from_selection(
        menus: &[JobMenu],
        selection: Vec<Option<usize>>,
        feasible: bool,
    ) -> Allocation {
        let total_power_w = total_power(menus, &selection);
        let tokens_per_s = menus
            .iter()
            .zip(&selection)
            .map(|(m, sel)| sel.map_or(0.0, |k| m.tokens_per_s(k)))
            .sum();
        Allocation { selection, feasible, total_power_w, tokens_per_s }
    }
}

fn total_power(menus: &[JobMenu], selection: &[Option<usize>]) -> f64 {
    menus
        .iter()
        .zip(selection)
        .map(|(m, sel)| sel.map_or(0.0, |k| m.points[k].power_w))
        .sum()
}

/// Relative tolerance applied to cap comparisons so float noise at the
/// boundary never flips a verdict.
fn cap_slack(cap_w: f64) -> f64 {
    cap_w * 1e-9
}

/// Marginal-cost water-filling under one cap value.
///
/// Phase 1 (drain): all jobs start at their max-throughput (index 0)
/// point; while total power exceeds the cap, apply the down-move with the
/// smallest throughput loss per watt freed (ties: lowest job index). If
/// every job saturates before the cap holds, the cap is below the
/// cluster-wide minimum — every job is pinned at its minimum-power point
/// and the result is flagged infeasible.
///
/// Phase 2 (refill): the last drain move can overshoot; spend remaining
/// headroom on the up-moves with the highest throughput gain per watt
/// that still fit under the cap.
///
/// Jobs with empty menus are skipped (`selection[j] == None`). Fully
/// deterministic: ties break on job order, and no scheduling or timing
/// state enters the result.
pub fn allocate(menus: &[JobMenu], cap_w: f64) -> Allocation {
    let slack = cap_slack(cap_w);
    let mut sel: Vec<Option<usize>> =
        menus.iter().map(|m| if m.points.is_empty() { None } else { Some(0) }).collect();

    // Phase 1: drain until the cap holds.
    let feasible = loop {
        if total_power(menus, &sel) <= cap_w + slack {
            break true;
        }
        let mut best: Option<(f64, usize)> = None;
        for (j, m) in menus.iter().enumerate() {
            let Some(k) = sel[j] else { continue };
            if k + 1 >= m.points.len() {
                continue;
            }
            let dp = m.points[k].power_w - m.points[k + 1].power_w;
            if dp <= 0.0 {
                continue; // frees no power; never useful for draining
            }
            let loss =
                m.weight * (1.0 / m.points[k].iter_time_s - 1.0 / m.points[k + 1].iter_time_s);
            let rate = loss / dp;
            if best.is_none_or(|(r, _)| rate < r) {
                best = Some((rate, j));
            }
        }
        match best {
            Some((_, j)) => sel[j] = sel[j].map(|k| k + 1),
            None => {
                // Saturated above the cap: pin every job at min power.
                for (j, m) in menus.iter().enumerate() {
                    if sel[j].is_some() {
                        sel[j] = m.min_power_point();
                    }
                }
                break false;
            }
        }
    };

    // Phase 2: refill leftover headroom with the highest-value up-moves.
    if feasible {
        loop {
            let headroom = cap_w + slack - total_power(menus, &sel);
            let mut best: Option<(f64, usize)> = None;
            for (j, m) in menus.iter().enumerate() {
                let Some(k) = sel[j] else { continue };
                if k == 0 {
                    continue;
                }
                let dp = m.points[k - 1].power_w - m.points[k].power_w;
                if dp > headroom {
                    continue;
                }
                let gain =
                    m.weight * (1.0 / m.points[k - 1].iter_time_s - 1.0 / m.points[k].iter_time_s);
                if gain <= 0.0 {
                    continue;
                }
                let value = if dp > 0.0 { gain / dp } else { f64::INFINITY };
                if best.is_none_or(|(v, _)| value > v) {
                    best = Some((value, j));
                }
            }
            match best {
                Some((_, j)) => sel[j] = sel[j].map(|k| k - 1),
                None => break,
            }
        }
    }

    Allocation::from_selection(menus, sel, feasible)
}

// ---------------------------------------------------------------------------
// Cluster planning (frontier retention + re-selection per cap segment)
// ---------------------------------------------------------------------------

/// A job with its retained optimization output: the iteration frontier
/// plus the stage menus/plans needed to materialize any frontier point
/// into a typed [`FrequencyPlan`] — the state that makes cap-change
/// re-adaptation a pure re-selection (no MBO re-run).
#[derive(Clone, Debug)]
pub struct JobFrontier {
    pub job: ClusterJob,
    pub result: SystemResult,
}

/// Run every job through the frontier pipeline on the shared engine
/// (sequentially across jobs; each job already fans its partitions across
/// the engine's workers). `progress` receives one line per job.
pub fn optimize_jobs(
    jobs: &[ClusterJob],
    engine: &EngineConfig,
    mut progress: impl FnMut(&str),
) -> Vec<JobFrontier> {
    let total = jobs.len();
    jobs.iter()
        .enumerate()
        .map(|(i, job)| {
            progress(&format!("[{}/{}] {}", i + 1, total, job.label));
            let sc = &job.scenario;
            let result = run_system_with(&sc.gpu, &sc.cfg, sc.system, sc.seed, engine);
            progress(&format!(
                "        {} frontier points (min iter {:.4}s, {:.1} kW at max throughput)",
                result.frontier.len(),
                result.frontier.min_time().map(|p| p.time).unwrap_or(f64::NAN),
                result
                    .frontier
                    .min_time()
                    .map(|p| p.avg_power_w() * job.n_gpus() as f64 / 1e3)
                    .unwrap_or(f64::NAN),
            ));
            JobFrontier { job: job.clone(), result }
        })
        .collect()
}

/// The cluster's feasible demand range over a menu set: (peak, floor) =
/// (sum of max-throughput draws, sum of minimum-power draws) in watts.
/// Caps at or above `peak` never bind; caps below `floor` are infeasible.
/// Empty menus contribute nothing to either bound.
pub fn demand_range(menus: &[JobMenu]) -> (f64, f64) {
    let peak = menus.iter().map(|m| m.points.first().map_or(0.0, |p| p.power_w)).sum();
    let floor = menus
        .iter()
        .map(|m| m.min_power_point().map_or(0.0, |k| m.points[k].power_w))
        .sum();
    (peak, floor)
}

/// The scheduler's menu for one optimized job.
pub fn job_menu(f: &JobFrontier) -> JobMenu {
    JobMenu::from_frontier(
        &f.result.frontier,
        f.job.scenario.cfg.par.gpus(),
        f.job.replicas,
        f.job.tokens_per_iter(),
    )
}

/// Serializable job record inside a [`ClusterPlan`].
#[derive(Clone, Debug, PartialEq)]
pub struct JobDescriptor {
    pub label: String,
    pub gpu: String,
    pub model: String,
    pub parallelism: String,
    pub system: String,
    pub replicas: u32,
    /// GPUs per pipeline (multiply by `replicas` for the job total).
    pub n_gpus: u32,
    /// Tokens one pipeline processes per iteration.
    pub tokens_per_iter: f64,
    /// True iff the job's frontier was empty — it takes part in no slice.
    pub skipped: bool,
    /// The retained menu (ascending iteration time).
    pub menu: Vec<MenuPoint>,
}

/// One job's selected operating point within a cap segment.
#[derive(Clone, Debug, PartialEq)]
pub struct JobAssignment {
    /// Index into [`ClusterPlan::jobs`].
    pub job: usize,
    /// Index into that job's menu (and frontier).
    pub point: usize,
    pub iter_time_s: f64,
    /// Per-GPU iteration energy (J).
    pub iter_energy_j: f64,
    /// Cluster draw of the whole job at this point (W).
    pub power_w: f64,
    /// The deployable per-slot plan behind the selected point.
    pub plan: FrequencyPlan,
}

/// The allocation for one cap segment.
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterSlice {
    pub start_s: f64,
    pub cap_w: f64,
    /// False iff the cap sits below the cluster minimum (jobs pinned at
    /// min power).
    pub feasible: bool,
    pub total_power_w: f64,
    pub tokens_per_s: f64,
    pub assignments: Vec<JobAssignment>,
}

/// The typed cluster deployment plan: the cap schedule, the per-job
/// frontier menus, and one allocation slice per cap segment. JSON
/// round-trips bit-exactly via [`ClusterPlan::to_json`] /
/// [`ClusterPlan::from_json`].
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterPlan {
    pub schedule: PowerCapSchedule,
    pub jobs: Vec<JobDescriptor>,
    pub slices: Vec<ClusterSlice>,
}

/// Allocate every cap segment over the retained job frontiers. Jobs with
/// empty frontiers are skipped with a `warn` line instead of a panic;
/// each segment's selection is materialized into typed per-job
/// [`FrequencyPlan`]s by re-indexing the retained stage menus.
pub fn plan_cluster(
    fronts: &[JobFrontier],
    schedule: &PowerCapSchedule,
    mut warn: impl FnMut(&str),
) -> ClusterPlan {
    let menus: Vec<JobMenu> = fronts.iter().map(job_menu).collect();
    for (f, m) in fronts.iter().zip(&menus) {
        if m.points.is_empty() {
            warn(&format!(
                "job '{}': empty frontier — skipped (no feasible operating point)",
                f.job.label
            ));
        }
    }
    let jobs: Vec<JobDescriptor> = fronts
        .iter()
        .zip(&menus)
        .map(|(f, m)| {
            let sc = &f.job.scenario;
            JobDescriptor {
                label: f.job.label.clone(),
                gpu: sc.gpu.name.to_string(),
                model: sc.cfg.model.name.to_string(),
                parallelism: format!("tp{}cp{}pp{}", sc.cfg.par.tp, sc.cfg.par.cp, sc.cfg.par.pp),
                system: sc.system.name().to_string(),
                replicas: f.job.replicas,
                n_gpus: sc.cfg.par.gpus(),
                tokens_per_iter: f.job.tokens_per_iter(),
                skipped: m.points.is_empty(),
                menu: m.points.clone(),
            }
        })
        .collect();
    let slices = schedule
        .segments()
        .iter()
        .map(|seg| {
            let a = allocate(&menus, seg.cap_w);
            let assignments = a
                .selection
                .iter()
                .enumerate()
                .filter_map(|(j, sel)| {
                    let k = (*sel)?;
                    let res = &fronts[j].result;
                    let point = res.frontier.points()[k];
                    Some(JobAssignment {
                        job: j,
                        point: k,
                        iter_time_s: point.time,
                        iter_energy_j: point.energy,
                        power_w: menus[j].points[k].power_w,
                        plan: FrequencyPlan::from_iteration(&res.menus, &res.plans[point.tag]),
                    })
                })
                .collect();
            ClusterSlice {
                start_s: seg.start_s,
                cap_w: seg.cap_w,
                feasible: a.feasible,
                total_power_w: a.total_power_w,
                tokens_per_s: a.tokens_per_s,
                assignments,
            }
        })
        .collect();
    let plan = ClusterPlan { schedule: schedule.clone(), jobs, slices };
    #[cfg(debug_assertions)]
    crate::check::assert_no_errors("plan_cluster", &crate::check::check_cluster_json(&plan.to_json()));
    plan
}

impl ClusterPlan {
    /// True iff every slice's cap sits at or above the cluster minimum.
    pub fn feasible(&self) -> bool {
        self.slices.iter().all(|sl| sl.feasible)
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("plan", s("kareus_cluster")),
            ("version", num(1.0)),
            ("cap_schedule", self.schedule.to_json()),
            ("jobs", arr(self.jobs.iter().map(job_to_json).collect())),
            ("slices", arr(self.slices.iter().map(slice_to_json).collect())),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ClusterPlan, String> {
        if j.get("plan").and_then(|v| v.as_str()) != Some("kareus_cluster") {
            return Err("not a kareus_cluster plan".to_string());
        }
        let schedule = PowerCapSchedule::from_json(
            j.get("cap_schedule").ok_or("plan missing 'cap_schedule'")?,
        )?;
        let jobs = j
            .get("jobs")
            .and_then(|v| v.as_arr())
            .ok_or("plan missing 'jobs'")?
            .iter()
            .map(job_from_json)
            .collect::<Result<Vec<_>, _>>()?;
        let slices = j
            .get("slices")
            .and_then(|v| v.as_arr())
            .ok_or("plan missing 'slices'")?
            .iter()
            .map(slice_from_json)
            .collect::<Result<Vec<_>, _>>()?;
        Ok(ClusterPlan { schedule, jobs, slices })
    }
}

fn menu_point_to_json(p: &MenuPoint) -> Json {
    arr(vec![num(p.iter_time_s), num(p.iter_energy_j), num(p.power_w)])
}

fn menu_point_from_json(j: &Json) -> Result<MenuPoint, String> {
    let a = j.as_arr().ok_or("menu point must be a [time, energy, power] triple")?;
    if a.len() != 3 {
        return Err(format!("menu point has {} fields, expected 3", a.len()));
    }
    let get = |i: usize| a[i].as_f64().ok_or_else(|| format!("menu point field {i} not a number"));
    Ok(MenuPoint { iter_time_s: get(0)?, iter_energy_j: get(1)?, power_w: get(2)? })
}

fn job_to_json(d: &JobDescriptor) -> Json {
    obj(vec![
        ("label", s(&d.label)),
        ("gpu", s(&d.gpu)),
        ("model", s(&d.model)),
        ("parallelism", s(&d.parallelism)),
        ("system", s(&d.system)),
        ("replicas", num(d.replicas as f64)),
        ("n_gpus", num(d.n_gpus as f64)),
        ("tokens_per_iter", num(d.tokens_per_iter)),
        ("skipped", Json::Bool(d.skipped)),
        ("menu", arr(d.menu.iter().map(menu_point_to_json).collect())),
    ])
}

fn job_from_json(j: &Json) -> Result<JobDescriptor, String> {
    let get_str = |k: &str| {
        j.get(k)
            .and_then(|v| v.as_str())
            .map(str::to_string)
            .ok_or_else(|| format!("job missing '{k}'"))
    };
    let get_u32 = |k: &str| {
        j.get(k)
            .and_then(|v| v.as_f64())
            .map(|n| n as u32)
            .ok_or_else(|| format!("job missing '{k}'"))
    };
    let menu = j
        .get("menu")
        .and_then(|v| v.as_arr())
        .ok_or("job missing 'menu'")?
        .iter()
        .map(menu_point_from_json)
        .collect::<Result<Vec<_>, _>>()?;
    Ok(JobDescriptor {
        label: get_str("label")?,
        gpu: get_str("gpu")?,
        model: get_str("model")?,
        parallelism: get_str("parallelism")?,
        system: get_str("system")?,
        replicas: get_u32("replicas")?,
        n_gpus: get_u32("n_gpus")?,
        tokens_per_iter: j
            .get("tokens_per_iter")
            .and_then(|v| v.as_f64())
            .ok_or("job missing 'tokens_per_iter'")?,
        skipped: j.get("skipped").and_then(|v| v.as_bool()).ok_or("job missing 'skipped'")?,
        menu,
    })
}

fn assignment_to_json(a: &JobAssignment) -> Json {
    obj(vec![
        ("job", num(a.job as f64)),
        ("point", num(a.point as f64)),
        ("iter_time_s", num(a.iter_time_s)),
        ("iter_energy_j", num(a.iter_energy_j)),
        ("power_w", num(a.power_w)),
        ("plan", a.plan.to_json()),
    ])
}

fn assignment_from_json(j: &Json) -> Result<JobAssignment, String> {
    let get_f64 = |k: &str| {
        j.get(k).and_then(|v| v.as_f64()).ok_or_else(|| format!("assignment missing '{k}'"))
    };
    Ok(JobAssignment {
        job: get_f64("job")? as usize,
        point: get_f64("point")? as usize,
        iter_time_s: get_f64("iter_time_s")?,
        iter_energy_j: get_f64("iter_energy_j")?,
        power_w: get_f64("power_w")?,
        plan: FrequencyPlan::from_json(j.get("plan").ok_or("assignment missing 'plan'")?)?,
    })
}

fn slice_to_json(sl: &ClusterSlice) -> Json {
    obj(vec![
        ("start_s", num(sl.start_s)),
        ("cap_w", num(sl.cap_w)),
        ("feasible", Json::Bool(sl.feasible)),
        ("total_power_w", num(sl.total_power_w)),
        ("tokens_per_s", num(sl.tokens_per_s)),
        ("assignments", arr(sl.assignments.iter().map(assignment_to_json).collect())),
    ])
}

fn slice_from_json(j: &Json) -> Result<ClusterSlice, String> {
    let get_f64 = |k: &str| {
        j.get(k).and_then(|v| v.as_f64()).ok_or_else(|| format!("slice missing '{k}'"))
    };
    let assignments = j
        .get("assignments")
        .and_then(|v| v.as_arr())
        .ok_or("slice missing 'assignments'")?
        .iter()
        .map(assignment_from_json)
        .collect::<Result<Vec<_>, _>>()?;
    Ok(ClusterSlice {
        start_s: get_f64("start_s")?,
        cap_w: get_f64("cap_w")?,
        feasible: j.get("feasible").and_then(|v| v.as_bool()).ok_or("slice missing 'feasible'")?,
        total_power_w: get_f64("total_power_w")?,
        tokens_per_s: get_f64("tokens_per_s")?,
        assignments,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::uniform_cap_allocation;
    use crate::frontier::Point;

    /// A synthetic menu: (time, power) pairs with energy = power × time.
    fn menu(weight: f64, pts: &[(f64, f64)]) -> JobMenu {
        JobMenu {
            weight,
            points: pts
                .iter()
                .map(|&(t, p)| MenuPoint { iter_time_s: t, iter_energy_j: p * t, power_w: p })
                .collect(),
        }
    }

    #[test]
    fn schedule_validation() {
        assert!(PowerCapSchedule::piecewise(vec![]).is_err());
        let not_zero = vec![CapSegment { start_s: 5.0, cap_w: 10.0 }];
        assert!(PowerCapSchedule::piecewise(not_zero).is_err());
        let descending = vec![
            CapSegment { start_s: 0.0, cap_w: 10.0 },
            CapSegment { start_s: 10.0, cap_w: 8.0 },
            CapSegment { start_s: 10.0, cap_w: 6.0 },
        ];
        assert!(PowerCapSchedule::piecewise(descending).is_err());
        let bad_cap = vec![CapSegment { start_s: 0.0, cap_w: -3.0 }];
        assert!(PowerCapSchedule::piecewise(bad_cap).is_err());
        let ok = PowerCapSchedule::piecewise(vec![
            CapSegment { start_s: 0.0, cap_w: 10.0 },
            CapSegment { start_s: 60.0, cap_w: 5.0 },
        ])
        .unwrap();
        assert_eq!(ok.cap_at(0.0), 10.0);
        assert_eq!(ok.cap_at(59.9), 10.0);
        assert_eq!(ok.cap_at(60.0), 5.0);
        assert_eq!(ok.cap_at(1e9), 5.0);
    }

    #[test]
    fn schedule_parse_and_roundtrip() {
        let constant = PowerCapSchedule::parse("40000").unwrap();
        assert_eq!(constant.segments().len(), 1);
        assert_eq!(constant.cap_at(1234.0), 40000.0);
        let pw = PowerCapSchedule::parse("0:40000, 3600:25000").unwrap();
        assert_eq!(pw.segments().len(), 2);
        assert_eq!(pw.cap_at(3600.0), 25000.0);
        assert!(PowerCapSchedule::parse("").is_err());
        assert!(PowerCapSchedule::parse("abc").is_err());
        assert!(PowerCapSchedule::parse("0:1,0:2").is_err());
        let back = PowerCapSchedule::from_json(&Json::parse(&pw.to_json().dump()).unwrap());
        assert_eq!(back.unwrap(), pw);
    }

    #[test]
    fn menu_from_frontier_descending_power() {
        let f = Frontier::from_points(vec![
            Point::new(1.0, 500.0, 0),
            Point::new(1.5, 400.0, 1),
            Point::new(2.0, 360.0, 2),
        ]);
        let m = JobMenu::from_frontier(&f, 16, 2, 1000.0);
        assert_eq!(m.points.len(), 3);
        assert_eq!(m.weight, 2000.0);
        // power = energy/time × 32 GPUs.
        assert!((m.points[0].power_w - 500.0 * 32.0).abs() < 1e-9);
        for w in m.points.windows(2) {
            assert!(w[1].power_w < w[0].power_w, "power must descend along the menu");
        }
        assert_eq!(m.min_power_point(), Some(2));
        // Demand range: peak = fastest point's draw, floor = min-power draw.
        let (peak, floor) = demand_range(&[m.clone()]);
        assert_eq!(peak, m.points[0].power_w);
        assert_eq!(floor, m.points[2].power_w);
        assert_eq!(demand_range(&[]), (0.0, 0.0));
    }

    #[test]
    fn loose_cap_keeps_max_throughput() {
        let menus = vec![menu(1.0, &[(1.0, 100.0), (2.0, 40.0)]), menu(1.0, &[(1.0, 80.0)])];
        let a = allocate(&menus, 1000.0);
        assert!(a.feasible);
        assert_eq!(a.selection, vec![Some(0), Some(0)]);
        assert!((a.total_power_w - 180.0).abs() < 1e-9);
        assert!((a.tokens_per_s - 2.0).abs() < 1e-12);
    }

    #[test]
    fn binding_cap_drains_cheapest_job_first() {
        // Job A: cheap slowdown (tiny throughput loss per watt); job B:
        // expensive. The drain must slow A, not B.
        let menus = vec![
            menu(1.0, &[(1.0, 100.0), (1.05, 40.0)]),
            menu(1.0, &[(1.0, 100.0), (3.0, 40.0)]),
        ];
        let a = allocate(&menus, 150.0);
        assert!(a.feasible);
        assert_eq!(a.selection, vec![Some(1), Some(0)]);
        assert!(a.total_power_w <= 150.0 + 1e-6);
    }

    #[test]
    fn cap_below_cluster_minimum_is_flagged_not_panicked() {
        let menus = vec![
            menu(1.0, &[(1.0, 100.0), (2.0, 60.0)]),
            menu(1.0, &[(1.0, 90.0), (2.0, 50.0)]),
        ];
        // Cluster minimum is 110 W; a 100 W cap is infeasible.
        let a = allocate(&menus, 100.0);
        assert!(!a.feasible);
        assert_eq!(a.selection, vec![Some(1), Some(1)]);
        assert!((a.total_power_w - 110.0).abs() < 1e-9);
    }

    #[test]
    fn single_job_gets_the_whole_cap() {
        let menus = vec![menu(1.0, &[(1.0, 100.0), (1.5, 70.0), (2.0, 50.0)])];
        let a = allocate(&menus, 75.0);
        assert!(a.feasible);
        // Fastest point under 75 W is the 70 W one.
        assert_eq!(a.selection, vec![Some(1)]);
    }

    #[test]
    fn empty_menu_job_is_skipped() {
        let menus = vec![menu(1.0, &[]), menu(1.0, &[(1.0, 50.0)])];
        let a = allocate(&menus, 60.0);
        assert!(a.feasible);
        assert_eq!(a.selection, vec![None, Some(0)]);
        assert!((a.total_power_w - 50.0).abs() < 1e-9);
        assert!((a.tokens_per_s - 1.0).abs() < 1e-12);
        // All menus empty: a valid, empty, feasible allocation.
        let none = allocate(&[menu(1.0, &[])], 10.0);
        assert!(none.feasible);
        assert_eq!(none.selection, vec![None]);
        assert_eq!(none.total_power_w, 0.0);
    }

    #[test]
    fn refill_spends_overshoot_headroom() {
        // Job A has three cheap 10 W steps (rates ≈ 0.004–0.005/W); job
        // B's single step is pricier (0.5/60 ≈ 0.008/W) but big. Under a
        // 130 W cap the drain walks A all the way down (200→170 W), then
        // B's step overshoots to 110 W — and the refill pass must spend
        // the 20 W of headroom walking A two steps back up to exactly
        // 130 W.
        let menus = vec![
            menu(1.0, &[(1.0, 100.0), (1.05, 90.0), (1.10, 80.0), (1.15, 70.0)]),
            menu(1.0, &[(1.0, 100.0), (2.0, 40.0)]),
        ];
        let a = allocate(&menus, 130.0);
        assert!(a.feasible);
        assert!(a.total_power_w <= 130.0 + 1e-6);
        assert_eq!(a.selection, vec![Some(1), Some(1)], "headroom left unspent");
        assert!((a.total_power_w - 130.0).abs() < 1e-6);
        assert!((a.tokens_per_s - (1.0 / 1.05 + 0.5)).abs() < 1e-9);
    }

    #[test]
    fn water_filling_beats_uniform_split_on_heterogeneous_jobs() {
        // Job A can barely save power; job B saves a lot cheaply. A
        // uniform split starves A while B wastes headroom.
        let menus = vec![
            menu(1.0, &[(1.0, 90.0), (1.1, 70.0)]),
            menu(1.0, &[(1.0, 50.0), (1.05, 20.0)]),
        ];
        let cap = 120.0;
        let wf = allocate(&menus, cap);
        let uni = uniform_cap_allocation(&menus, cap);
        assert!(wf.feasible);
        assert!(wf.total_power_w <= cap + 1e-6);
        assert!(
            wf.tokens_per_s >= uni.tokens_per_s - 1e-12,
            "water-filling {} below uniform {}",
            wf.tokens_per_s,
            uni.tokens_per_s
        );
        // And strictly better here: uniform pins A at 70 W (share 60 is
        // below A's 90 W fast point), while water-filling runs A fast.
        assert!(wf.tokens_per_s > uni.tokens_per_s);
    }

    #[test]
    fn uniform_baseline_flags_oversized_jobs() {
        let menus = vec![menu(1.0, &[(1.0, 100.0)]), menu(1.0, &[(1.0, 10.0)])];
        // Share is 30 W; job A cannot fit even at min power.
        let uni = uniform_cap_allocation(&menus, 60.0);
        assert!(!uni.feasible);
        assert_eq!(uni.selection, vec![Some(0), Some(0)]);
    }

    #[test]
    fn job_spec_parsing() {
        let j = parse_job_spec("a100:qwen1.7b:tp8pp2:m+p", 8, 4096, 8, 7).unwrap();
        assert_eq!(j.label, "a100:qwen1.7b:tp8pp2:m+p");
        assert_eq!(j.replicas, 1);
        assert_eq!(j.n_gpus(), 16);
        assert_eq!(j.tokens_per_iter(), 8.0 * 4096.0 * 8.0);
        assert_eq!(j.scenario.seed, 7);
        let r = parse_job_spec("v100:llama3b:cp2tp4pp2:kareus:4", 8, 4096, 8, 7).unwrap();
        assert_eq!(r.replicas, 4);
        assert_eq!(r.n_gpus(), 64);
        for bad in [
            "a100:qwen1.7b:tp8pp2",            // missing system
            "tpu:qwen1.7b:tp8pp2:m+p",         // unknown gpu
            "a100:gpt99:tp8pp2:m+p",           // unknown model
            "a100:qwen1.7b:xx:m+p",            // bad parallelism
            "a100:qwen1.7b:tp8pp2:zzz",        // unknown system
            "a100:qwen1.7b:tp8pp2:m+p:0",      // zero replicas
            "a100:qwen1.7b:tp8pp2:m+p:2:more", // trailing garbage
        ] {
            assert!(parse_job_spec(bad, 8, 4096, 8, 7).is_err(), "{bad} should fail");
        }
    }
}
