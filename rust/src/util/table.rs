//! Aligned ASCII table printing for the paper-reproduction harness output.

pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                out.push_str(&format!("| {:w$} ", c, w = widths[i]));
            }
            out.push_str("|\n");
        };
        line(&mut out, &self.headers);
        for i in 0..ncols {
            out.push_str(&format!("|{}", "-".repeat(widths[i] + 2)));
        }
        out.push_str("|\n");
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a percentage like the paper tables ("12.3", "-0.5").
pub fn pct(x: f64) -> String {
    format!("{:.1}", x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["a", "long_header"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["333333".into(), "4".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
    }

    #[test]
    #[should_panic]
    fn rejects_bad_width() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
