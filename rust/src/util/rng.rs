//! Deterministic PRNG (xoshiro256**) — no external crates are available in
//! this environment, so randomness for MBO sampling, bootstrap resampling,
//! synthetic corpora, and the simulator's measurement noise lives here.

/// xoshiro256** by Blackman & Vigna; seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the full state.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's nearly-divisionless bounded sampling.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Derive an independent stream (for per-thread / per-partition use).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(2);
        for n in [1usize, 2, 7, 100] {
            for _ in 0..1000 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn below_roughly_uniform() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "{c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(6);
        let idx = r.sample_indices(20, 10);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 10);
    }

    #[test]
    fn fork_independent() {
        let mut r = Rng::new(7);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
