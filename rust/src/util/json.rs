//! Minimal JSON parser/serializer (no serde available offline).
//!
//! Used for artifacts/manifest.json, experiment result dumps, and the
//! schedule-plan files the coordinator deploys. Supports the full JSON
//! grammar except extreme float edge cases; numbers parse as f64.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

/// Error from [`Json::try_dump`]: the document contains a non-finite
/// number, which has no JSON representation.
#[derive(Debug, PartialEq)]
pub struct EmitError {
    /// Dotted path to the offending value (e.g. `scenarios[2].wall_s`).
    pub path: String,
    /// The offending value (NaN or ±inf).
    pub value: f64,
}

impl fmt::Display for EmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let at = if self.path.is_empty() { "the document root" } else { self.path.as_str() };
        write!(f, "cannot emit non-finite number {} at {at}", self.value)
    }
}

impl std::error::Error for EmitError {}

/// Maximum container nesting the parser accepts. The parser is recursive
/// descent, so unbounded nesting is a stack overflow — an abort, not a
/// typed error — and a ~64 KiB wire request of `[[[[…` would reach tens of
/// thousands of levels. 512 is far beyond any artifact this crate emits
/// while keeping worst-case stack use trivially small.
pub const MAX_PARSE_DEPTH: usize = 512;

impl Json {
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: input.as_bytes(), i: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Serialize compactly. NaN/Inf would serialize as the non-JSON
    /// tokens `NaN`/`inf`; artifact emitters go through [`Json::try_dump`]
    /// so that becomes a reportable error instead of a corrupt file.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Serialize compactly, rejecting non-finite numbers with a typed
    /// error that names the offending path.
    pub fn try_dump(&self) -> Result<String, EmitError> {
        if let Some(e) = self.find_nonfinite("") {
            return Err(e);
        }
        Ok(self.dump())
    }

    /// Depth-first search for the first non-finite number (document order,
    /// so the reported path is deterministic).
    fn find_nonfinite(&self, at: &str) -> Option<EmitError> {
        match self {
            Json::Num(n) if !n.is_finite() => Some(EmitError { path: at.to_string(), value: *n }),
            Json::Arr(a) => a
                .iter()
                .enumerate()
                .find_map(|(i, v)| v.find_nonfinite(&format!("{at}[{i}]"))),
            Json::Obj(m) => m.iter().find_map(|(k, v)| {
                let child = if at.is_empty() { k.clone() } else { format!("{at}.{k}") };
                v.find_nonfinite(&child)
            }),
            _ => None,
        }
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn num(n: f64) -> Json {
    debug_assert!(n.is_finite(), "num({n}): non-finite numbers have no JSON representation");
    Json::Num(n)
}
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}
pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    /// Guard one level of container nesting; pairs with `descend_end`.
    fn descend(&mut self) -> Result<(), JsonError> {
        if self.depth >= MAX_PARSE_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        self.depth += 1;
        Ok(())
    }

    fn descend_end(&mut self) {
        self.depth -= 1;
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.descend()?;
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            self.descend_end();
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    self.descend_end();
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.descend()?;
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            self.descend_end();
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    self.descend_end();
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let start = self.i;
                    let len = utf8_len(self.b[start]);
                    let end = (start + len).min(self.b.len());
                    out.push_str(
                        std::str::from_utf8(&self.b[start..end]).map_err(|_| self.err("bad utf8"))?,
                    );
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        match std::str::from_utf8(&self.b[start..self.i]).ok().and_then(|s| s.parse::<f64>().ok())
        {
            // str::parse overflows literals like 1e999 to inf; valid JSON
            // has no non-finite numbers, so reject rather than smuggle
            // them into a document that could never round-trip.
            Some(n) if n.is_finite() => Ok(Json::Num(n)),
            Some(_) => Err(self.err("number out of range")),
            None => Err(self.err("bad number")),
        }
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b < 0xE0 {
        2
    } else if b < 0xF0 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a":[1,2.5,-3e2],"b":"hi\nthere","c":true,"d":null,"e":{}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 3, "s": "x", "a": [1,2], "b": false}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("s").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(v.get("b").unwrap().as_bool(), Some(false));
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn nested() {
        let v = Json::parse(r#"[{"x":{"y":[[1]]}}]"#).unwrap();
        let inner = v.as_arr().unwrap()[0].get("x").unwrap().get("y").unwrap();
        assert_eq!(inner.as_arr().unwrap()[0].as_arr().unwrap()[0].as_f64(), Some(1.0));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
        assert!(Json::parse("[1] x").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn float_formats() {
        for (src, want) in [("0.5", 0.5), ("-1.25e2", -125.0), ("1E-3", 0.001), ("42", 42.0)] {
            assert_eq!(Json::parse(src).unwrap().as_f64(), Some(want), "{src}");
        }
    }

    #[test]
    fn dump_escapes_control_chars() {
        let v = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(Json::parse(&v.dump()).unwrap(), v);
    }

    #[test]
    fn escape_sequences_roundtrip() {
        let v = Json::parse(r#""Aé\t\r\n\b\f\/\\\"""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé\t\r\n\u{0008}\u{000C}/\\\""));
        // NUL and other C0 controls survive a dump/parse cycle as \uXXXX.
        let nul = Json::Str("a\u{0000}b\u{0001}".to_string());
        assert_eq!(Json::parse(&nul.dump()).unwrap(), nul);
        // Lone surrogate escapes cannot be a char; the parser substitutes
        // U+FFFD rather than erroring (matching from_utf8_lossy).
        assert_eq!(Json::parse(r#""\ud800""#).unwrap().as_str(), Some("\u{FFFD}"));
    }

    fn nested_arrays(depth: usize) -> String {
        let mut src = String::new();
        src.push_str(&"[".repeat(depth));
        src.push('1');
        src.push_str(&"]".repeat(depth));
        src
    }

    #[test]
    fn deep_nesting_roundtrips() {
        let depth = MAX_PARSE_DEPTH;
        let src = nested_arrays(depth);
        let v = Json::parse(&src).unwrap();
        assert_eq!(v.dump(), src);
        let mut inner = &v;
        for _ in 0..depth {
            inner = &inner.as_arr().unwrap()[0];
        }
        assert_eq!(inner.as_f64(), Some(1.0));
    }

    #[test]
    fn excessive_nesting_is_a_typed_error_not_an_overflow() {
        // One past the cap errors; a wire-sized bomb (64 KiB of '[') must
        // come back as a typed JsonError, not blow the worker stack.
        let err = Json::parse(&nested_arrays(MAX_PARSE_DEPTH + 1)).unwrap_err();
        assert!(err.msg.contains("nesting too deep"), "{err}");
        let bomb = "[".repeat(1 << 16);
        let err = Json::parse(&bomb).unwrap_err();
        assert!(err.msg.contains("nesting too deep"), "{err}");
        // Mixed object/array nesting counts against the same cap.
        let mut src = String::new();
        for _ in 0..MAX_PARSE_DEPTH {
            src.push_str("{\"a\":[");
        }
        let err = Json::parse(&src).unwrap_err();
        assert!(err.msg.contains("nesting too deep"), "{err}");
    }

    #[test]
    fn truncated_documents_are_typed_errors() {
        // Every prefix of a valid document must fail cleanly — the serve
        // wire can hand the parser a request line cut anywhere.
        let full = r#"{"serve":"kareus_serve","version":1,"job":["a",1.5,null]}"#;
        for cut in 1..full.len() {
            let prefix = &full[..cut]; // all-ASCII, every cut is a char boundary
            assert!(Json::parse(prefix).is_err(), "prefix {prefix:?} parsed");
        }
    }

    #[test]
    fn parser_rejects_nonfinite_literals() {
        // Overflowing exponents would become inf through str::parse.
        assert!(Json::parse("1e999").is_err());
        assert!(Json::parse("-1e999").is_err());
        assert!(Json::parse("[1, 1e999]").is_err());
        // JSON has no NaN/Infinity tokens at all.
        assert!(Json::parse("NaN").is_err());
        assert!(Json::parse("Infinity").is_err());
        // Large-but-finite still parses.
        assert_eq!(Json::parse("1e308").unwrap().as_f64(), Some(1e308));
    }

    #[test]
    fn try_dump_rejects_nonfinite_with_path() {
        let doc = Json::Obj(
            [
                ("ok".to_string(), Json::Num(1.0)),
                (
                    "scenarios".to_string(),
                    Json::Arr(vec![Json::Num(2.0), Json::Num(f64::NAN)]),
                ),
            ]
            .into_iter()
            .collect(),
        );
        let err = doc.try_dump().unwrap_err();
        assert_eq!(err.path, "scenarios[1]");
        assert!(err.value.is_nan());
        assert!(err.to_string().contains("scenarios[1]"));
        assert_eq!(Json::Num(f64::INFINITY).try_dump().unwrap_err().path, "");
        // Finite documents pass through identically to dump().
        let fine = Json::parse(r#"{"a":[1,2.5],"b":"x"}"#).unwrap();
        assert_eq!(fine.try_dump().unwrap(), fine.dump());
    }
}
