//! Worker threads: a persistent [`WorkerPool`] plus a one-shot
//! [`parallel_map`] wrapper (no rayon offline).
//!
//! MBO runs per-partition optimizations in parallel (the paper runs them in
//! parallel across GPUs, Section 6.6); emulation sweeps use it too. The
//! plan-serving daemon ([`crate::serve`]) keeps one pool alive for its whole
//! lifetime and feeds it connection handlers, so the pool outlives any
//! single batch of work — jobs are `'static` and travel through a channel.

use std::sync::Arc;

use crate::util::sync::{channel, spawn, SyncJoinHandle, SyncMutex, SyncSender};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed-size pool of persistent worker threads.
///
/// Jobs are closures sent over a shared queue; workers pop in FIFO order.
/// Dropping the pool (or calling [`WorkerPool::shutdown`]) closes the queue,
/// lets every already-queued job run to completion, and joins the workers —
/// the drain semantics the daemon's graceful shutdown relies on.
///
/// A job that panics kills its worker thread (the panic is not forwarded to
/// other queued jobs); long-lived callers that must survive bad jobs should
/// catch panics inside the job itself, as the serve connection handler does.
pub struct WorkerPool {
    tx: Option<SyncSender<Job>>,
    workers: Vec<SyncJoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `n_threads.max(1)` persistent workers.
    pub fn new(n_threads: usize) -> WorkerPool {
        let n = n_threads.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(SyncMutex::new(rx));
        let workers = (0..n)
            .map(|_| {
                let rx = Arc::clone(&rx);
                spawn(move || loop {
                    // The receiver lock is held while blocked on recv(),
                    // which is fine: exactly one idle worker waits at a
                    // time, takes the next job, and releases the lock
                    // before running it.
                    let job = rx.lock().recv();
                    match job {
                        Ok(job) => job(),
                        // Queue closed and drained: the pool is shutting down.
                        Err(_) => break,
                    }
                })
            })
            .collect();
        WorkerPool { tx: Some(tx), workers }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Queue one fire-and-forget job.
    ///
    /// Panics if called after [`WorkerPool::shutdown`], or if every worker
    /// has died to a panicking job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(job))
            .expect("all workers exited");
    }

    /// Run `f` over `items` on this pool, preserving input order.
    ///
    /// Blocks until every item is done. Each result travels back tagged
    /// with its index, so worker scheduling never leaks into the output
    /// order. Panics if a worker dies mid-batch (its result can then never
    /// arrive).
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        self.map_chunked(items, 1, f)
    }

    /// [`map`](Self::map) with one queued job per contiguous chunk of up
    /// to `chunk` items instead of one per item, so large batches of
    /// cheap work pay channel-send and boxing costs per chunk, not per
    /// item. Chunks are reassembled in input order; `chunk == 1` is
    /// exactly `map`.
    pub fn map_chunked<T, R, F>(&self, items: Vec<T>, chunk: usize, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        let chunk = chunk.max(1);
        let f = Arc::new(f);
        let (done_tx, done_rx) = channel::<(usize, Vec<R>)>();
        let mut iter = items.into_iter();
        let mut n_chunks = 0usize;
        loop {
            let batch: Vec<T> = iter.by_ref().take(chunk).collect();
            if batch.is_empty() {
                break;
            }
            let f = Arc::clone(&f);
            let done = done_tx.clone();
            let ci = n_chunks;
            self.execute(move || {
                // A send error means the collector gave up (caller
                // panicked); drop the results on the floor.
                let _ = done.send((ci, batch.into_iter().map(|t| f(t)).collect()));
            });
            n_chunks += 1;
        }
        drop(done_tx);
        let mut slots: Vec<Option<Vec<R>>> = (0..n_chunks).map(|_| None).collect();
        for _ in 0..n_chunks {
            let (i, r) = done_rx.recv().expect("worker panicked");
            debug_assert!(slots[i].is_none(), "chunk {i} produced twice");
            slots[i] = Some(r);
        }
        let mut out = Vec::with_capacity(n);
        for s in slots {
            out.extend(s.expect("missing chunk"));
        }
        out
    }

    /// Close the queue and join every worker. Already-queued jobs run to
    /// completion first; new [`WorkerPool::execute`] calls panic. Called
    /// automatically on drop.
    pub fn shutdown(&mut self) {
        // Dropping the sender makes each worker's recv() fail once the
        // queue drains, so this is a drain-then-join, not an abort.
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            // A worker that died to a panicking job already reported it;
            // don't double-panic while unwinding.
            let _ = w.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Run `f` over `items` on up to `n_threads` threads, preserving order.
///
/// Thin wrapper over [`WorkerPool`]: stands up a pool for the call and
/// drops it (join + drain) on return. One-shot batch work (per-partition
/// MBO fan-out, sweeps) goes through here; anything long-lived should hold
/// its own `WorkerPool`. With one thread or at most one item the work runs
/// inline on the caller with no pool at all, which keeps
/// `EngineConfig::sequential()` literally single-threaded.
pub fn parallel_map<T, R, F>(items: Vec<T>, n_threads: usize, f: F) -> Vec<R>
where
    T: Send + 'static,
    R: Send + 'static,
    F: Fn(T) -> R + Send + Sync + 'static,
{
    let n_threads = n_threads.max(1);
    if n_threads == 1 || items.len() <= 1 {
        return items.into_iter().map(f).collect();
    }
    let threads = n_threads.min(items.len());
    // 4× job oversubscription balances skewed per-item cost; heavy
    // small-batch work (per-partition MBO) still gets one item per job.
    let chunk = (items.len() / (threads * 4)).max(1);
    WorkerPool::new(threads).map_chunked(items, chunk, f)
}

/// Default parallelism: available cores, capped.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::sync::SyncAtomicUsize;

    #[test]
    fn preserves_order() {
        let out = parallel_map((0..100).collect::<Vec<_>>(), 8, |x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let out = parallel_map(vec![1, 2, 3], 1, |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty() {
        let out: Vec<i32> = parallel_map(Vec::<i32>::new(), 4, |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn more_threads_than_items() {
        let out = parallel_map(vec![10, 20], 16, |x| x + 1);
        assert_eq!(out, vec![11, 21]);
    }

    #[test]
    fn order_preserved_under_skewed_work() {
        // Early items sleep; later items finish first on other workers, so
        // any result-path ordering bug would scramble the output.
        let out = parallel_map((0..32).collect::<Vec<_>>(), 8, |x| {
            if x < 8 {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            x * x
        });
        assert_eq!(out, (0..32).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn pool_is_reusable_across_batches() {
        // The daemon's shape: one pool, many independent waves of work.
        let pool = WorkerPool::new(4);
        assert_eq!(pool.size(), 4);
        for round in 0..3 {
            let out = pool.map((0..20).collect::<Vec<_>>(), move |x| x + round);
            assert_eq!(out, (0..20).map(|x| x + round).collect::<Vec<_>>());
        }
    }

    #[test]
    fn pool_map_preserves_order_under_skew() {
        let pool = WorkerPool::new(8);
        let out = pool.map((0..32).collect::<Vec<_>>(), |x| {
            if x < 8 {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            x * x
        });
        assert_eq!(out, (0..32).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn map_chunked_matches_sequential_for_any_chunk() {
        let expect: Vec<i32> = (0..100).map(|x| x * 3 + 1).collect();
        let pool = WorkerPool::new(4);
        for chunk in [1, 2, 7, 33, 100, 1000] {
            let out = pool.map_chunked((0..100).collect::<Vec<_>>(), chunk, |x| x * 3 + 1);
            assert_eq!(out, expect, "chunk {chunk}");
        }
    }

    #[test]
    fn map_chunked_order_preserved_under_skew() {
        let pool = WorkerPool::new(8);
        let out = pool.map_chunked((0..64).collect::<Vec<_>>(), 5, |x| {
            if x < 10 {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            x * x
        });
        assert_eq!(out, (0..64).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn shutdown_drains_queued_jobs() {
        // Queue far more jobs than workers, then shut down immediately:
        // every queued job must still run (drain, not abort).
        let ran = Arc::new(SyncAtomicUsize::new(0));
        let mut pool = WorkerPool::new(2);
        for _ in 0..64 {
            let ran = Arc::clone(&ran);
            pool.execute(move || {
                ran.fetch_add(1);
            });
        }
        pool.shutdown();
        assert_eq!(ran.load(), 64);
    }

    #[test]
    fn drop_joins_workers() {
        let ran = Arc::new(SyncAtomicUsize::new(0));
        {
            let pool = WorkerPool::new(3);
            for _ in 0..9 {
                let ran = Arc::clone(&ran);
                pool.execute(move || {
                    ran.fetch_add(1);
                });
            }
        } // drop ⇒ drain + join
        assert_eq!(ran.load(), 9);
    }

    #[test]
    fn pool_floor_is_one_worker() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.size(), 1);
        assert_eq!(pool.map(vec![1, 2, 3], |x| x * 10), vec![10, 20, 30]);
    }
}
