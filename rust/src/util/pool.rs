//! Tiny scoped parallel-map over OS threads (no rayon offline).
//!
//! MBO runs per-partition optimizations in parallel (the paper runs them in
//! parallel across GPUs, Section 6.6); emulation sweeps use it too.

/// Run `f` over `items` on up to `n_threads` threads, preserving order.
pub fn parallel_map<T, R, F>(items: Vec<T>, n_threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n_threads = n_threads.max(1);
    if n_threads == 1 || items.len() <= 1 {
        return items.into_iter().map(f).collect();
    }
    let n = items.len();
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let work: Vec<(usize, T)> = items.into_iter().enumerate().collect();
    let queue = std::sync::Mutex::new(work);
    let slots_mtx = std::sync::Mutex::new(&mut slots);

    std::thread::scope(|scope| {
        for _ in 0..n_threads.min(n) {
            scope.spawn(|| loop {
                let job = { queue.lock().unwrap().pop() };
                match job {
                    Some((i, item)) => {
                        let r = f(item);
                        slots_mtx.lock().unwrap()[i] = Some(r);
                    }
                    None => break,
                }
            });
        }
    });
    slots.into_iter().map(|s| s.expect("worker panicked")).collect()
}

/// Default parallelism: available cores, capped.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let out = parallel_map((0..100).collect::<Vec<_>>(), 8, |x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let out = parallel_map(vec![1, 2, 3], 1, |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty() {
        let out: Vec<i32> = parallel_map(Vec::<i32>::new(), 4, |x| x);
        assert!(out.is_empty());
    }
}
