//! Tiny scoped parallel-map over OS threads (no rayon offline).
//!
//! MBO runs per-partition optimizations in parallel (the paper runs them in
//! parallel across GPUs, Section 6.6); emulation sweeps use it too.

/// Run `f` over `items` on up to `n_threads` threads, preserving order.
///
/// Work is handed out through a shared iterator in ascending index order;
/// each worker accumulates `(index, result)` pairs privately and the
/// results are merged after all workers join, so the result path takes no
/// locks and workers never contend on a shared output buffer.
pub fn parallel_map<T, R, F>(items: Vec<T>, n_threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n_threads = n_threads.max(1);
    if n_threads == 1 || items.len() <= 1 {
        return items.into_iter().map(f).collect();
    }
    let n = items.len();
    let queue = std::sync::Mutex::new(items.into_iter().enumerate());

    let per_worker: Vec<Vec<(usize, R)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n_threads.min(n))
            .map(|_| {
                scope.spawn(|| {
                    let mut local: Vec<(usize, R)> = Vec::new();
                    loop {
                        // Hold the queue lock only for the pop, never while
                        // running `f`.
                        let job = queue.lock().unwrap().next();
                        match job {
                            Some((i, item)) => local.push((i, f(item))),
                            None => break,
                        }
                    }
                    local
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    });

    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    for (i, r) in per_worker.into_iter().flatten() {
        debug_assert!(slots[i].is_none(), "index {i} produced twice");
        slots[i] = Some(r);
    }
    slots.into_iter().map(|s| s.expect("missing result")).collect()
}

/// Default parallelism: available cores, capped.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let out = parallel_map((0..100).collect::<Vec<_>>(), 8, |x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let out = parallel_map(vec![1, 2, 3], 1, |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty() {
        let out: Vec<i32> = parallel_map(Vec::<i32>::new(), 4, |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn more_threads_than_items() {
        let out = parallel_map(vec![10, 20], 16, |x| x + 1);
        assert_eq!(out, vec![11, 21]);
    }

    #[test]
    fn order_preserved_under_skewed_work() {
        // Early items sleep; later items finish first on other workers, so
        // any result-path ordering bug would scramble the output.
        let out = parallel_map((0..32).collect::<Vec<_>>(), 8, |x| {
            if x < 8 {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            x * x
        });
        assert_eq!(out, (0..32).map(|x| x * x).collect::<Vec<_>>());
    }
}
