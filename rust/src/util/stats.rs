//! Small statistics helpers shared by the profiler, MBO, and bench harness.

/// Arithmetic mean. Returns 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile (nearest-rank, p in [0, 100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Min/max ignoring NaNs.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 50.0) - 50.0).abs() <= 1.0);
    }
}
