//! Offline substrates: JSON, PRNG, stats, hashing, thread pool, table
//! printing.

pub mod bench;
pub mod hash;
pub mod json;
pub mod pool;
pub mod rng;
pub mod stats;
pub mod sync;
pub mod table;
