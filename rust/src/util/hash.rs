//! FNV-1a hashing for stable, deterministic fingerprints (partition
//! identity, measurement-cache keys, MBO memoization). `std`'s hashers are
//! randomly seeded per process, which would break cross-run determinism.

/// Incremental 64-bit FNV-1a hasher.
#[derive(Clone, Copy, Debug)]
pub struct Fnv64(u64);

pub const FNV_OFFSET: u64 = 0xcbf29ce484222325;
pub const FNV_PRIME: u64 = 0x100000001b3;

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv64 {
    pub fn new() -> Self {
        Fnv64(FNV_OFFSET)
    }

    #[inline]
    pub fn write_bytes(&mut self, bytes: &[u8]) -> &mut Self {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(FNV_PRIME);
        }
        self
    }

    #[inline]
    pub fn write_u64(&mut self, v: u64) -> &mut Self {
        self.write_bytes(&v.to_le_bytes())
    }

    /// Hash an f64 by bit pattern (exact: distinguishes -0.0/0.0, NaNs).
    #[inline]
    pub fn write_f64(&mut self, v: f64) -> &mut Self {
        self.write_u64(v.to_bits())
    }

    #[inline]
    pub fn write_str(&mut self, s: &str) -> &mut Self {
        // Length prefix prevents concatenation ambiguity ("ab","c" vs "a","bc").
        self.write_u64(s.len() as u64).write_bytes(s.as_bytes())
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

/// One-shot FNV-1a over a string (the partition-type seed hash). No
/// length framing — bit-compatible with the textbook byte-stream FNV-1a.
pub fn fnv1a_str(s: &str) -> u64 {
    let mut h = Fnv64::new();
    h.write_bytes(s.as_bytes());
    h.finish()
}

/// Compile-time [`fnv1a_str`] for `const` fingerprints (e.g. the sim
/// backend identity probed on every measurement-cache key). Must stay
/// bit-compatible with the runtime path — enforced by a unit test here
/// and by `backend::tests::backend_fingerprints_never_alias`.
pub const fn fnv1a_const(s: &str) -> u64 {
    let bytes = s.as_bytes();
    let mut h = FNV_OFFSET;
    let mut i = 0;
    while i < bytes.len() {
        h ^= bytes[i] as u64;
        h = h.wrapping_mul(FNV_PRIME);
        i += 1;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_sensitive() {
        let mut a = Fnv64::new();
        a.write_u64(1);
        let mut b = Fnv64::new();
        b.write_u64(1);
        assert_eq!(a.finish(), b.finish());
        let mut c = Fnv64::new();
        c.write_u64(2);
        assert_ne!(a.finish(), c.finish());
        // f64 hashing is bit-exact: -0.0 and 0.0 differ.
        let mut d = Fnv64::new();
        d.write_f64(0.0);
        let mut e = Fnv64::new();
        e.write_f64(-0.0);
        assert_ne!(d.finish(), e.finish());
    }

    #[test]
    fn string_framing_unambiguous() {
        let mut a = Fnv64::new();
        a.write_str("ab").write_str("c");
        let mut b = Fnv64::new();
        b.write_str("a").write_str("bc");
        assert_ne!(a.finish(), b.finish());
    }

    #[test]
    fn one_shot_matches_known_vector() {
        // FNV-1a("a") = 0xaf63dc4c8601ec8c.
        assert_eq!(fnv1a_str("a"), 0xaf63dc4c8601ec8c);
    }

    #[test]
    fn const_fnv1a_matches_runtime() {
        const H: u64 = fnv1a_const("kareus_backend:sim:v1");
        assert_eq!(H, fnv1a_str("kareus_backend:sim:v1"));
        assert_eq!(fnv1a_const("a"), 0xaf63dc4c8601ec8c);
    }
}
