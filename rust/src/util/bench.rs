//! Minimal bench harness (criterion is unavailable offline): warmup +
//! timed iterations, reporting min/median/mean. Used by the `[[bench]]`
//! targets (harness = false).

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub min_ns: f64,
    pub median_ns: f64,
    pub mean_ns: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:40} {:>6} iters   min {:>12}   median {:>12}   mean {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.min_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns)
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Run `f` repeatedly for ~`budget_s` seconds (after 10% warmup); prevent
/// the compiler from optimizing the result away via `std::hint::black_box`
/// inside the closure.
pub fn bench<F: FnMut()>(name: &str, budget_s: f64, mut f: F) -> BenchResult {
    // Warmup + calibration.
    let t0 = Instant::now();
    f();
    let one = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((budget_s / one).ceil() as usize).clamp(3, 10_000);
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let res = BenchResult {
        name: name.to_string(),
        iters,
        min_ns: samples[0],
        median_ns: samples[samples.len() / 2],
        mean_ns: samples.iter().sum::<f64>() / samples.len() as f64,
    };
    println!("{}", res.report());
    res
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut x = 0u64;
        let r = bench("noop-ish", 0.01, || {
            x = std::hint::black_box(x.wrapping_add(1));
        });
        assert!(r.iters >= 3);
        assert!(r.min_ns <= r.median_ns && r.median_ns <= r.mean_ns * 2.0);
    }

    #[test]
    fn formats() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5e4).contains("µs"));
        assert!(fmt_ns(5e7).contains("ms"));
        assert!(fmt_ns(5e9).contains("s"));
    }
}
