//! Minimal bench harness (criterion is unavailable offline): warmup +
//! timed iterations, reporting min/median/mean. Used by the `[[bench]]`
//! targets (harness = false) and by `kareus bench`, whose
//! [`BenchReport`] JSON artifact separates deterministic work counters
//! from wall-clock fields so CI can diff reports byte-for-byte.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::util::json::{num, obj, s, Json};

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub min_ns: f64,
    pub median_ns: f64,
    pub mean_ns: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:40} {:>6} iters   min {:>12}   median {:>12}   mean {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.min_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns)
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Run `f` repeatedly for ~`budget_s` seconds (after 10% warmup); prevent
/// the compiler from optimizing the result away via `std::hint::black_box`
/// inside the closure.
pub fn bench<F: FnMut()>(name: &str, budget_s: f64, f: F) -> BenchResult {
    let res = bench_quiet(name, budget_s, f);
    println!("{}", res.report());
    res
}

/// [`bench`] without the stdout report line — for callers ( `kareus
/// bench`) that own the output channel.
pub fn bench_quiet<F: FnMut()>(name: &str, budget_s: f64, mut f: F) -> BenchResult {
    // Warmup + calibration.
    let t0 = Instant::now();
    f();
    let one = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((budget_s / one).ceil() as usize).clamp(3, 10_000);
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    BenchResult {
        name: name.to_string(),
        iters,
        min_ns: samples[0],
        median_ns: samples[samples.len() / 2],
        mean_ns: samples.iter().sum::<f64>() / samples.len() as f64,
    }
}

/// Wall-clock a closure: `(result, elapsed_s)`. The suite and `kareus
/// bench` time through this so wall-clock access stays confined to this
/// module (the determinism source lint pins the allowlist).
pub fn wall_time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// One `kareus bench` suite entry: deterministic work counters (always
/// populated — evaluations run, cache hits, kernels walked) plus
/// wall-clock stats that are `None` in `--deterministic` mode, where the
/// workload runs exactly once untimed.
#[derive(Clone, Debug)]
pub struct BenchEntry {
    pub counters: BTreeMap<String, u64>,
    pub iters: Option<usize>,
    pub min_ns: Option<f64>,
    pub median_ns: Option<f64>,
    pub mean_ns: Option<f64>,
}

impl BenchEntry {
    /// Counter-only entry (deterministic mode: every wall field null).
    pub fn deterministic(counters: BTreeMap<String, u64>) -> BenchEntry {
        BenchEntry { counters, iters: None, min_ns: None, median_ns: None, mean_ns: None }
    }

    /// Timed entry from a harness result.
    pub fn timed(r: &BenchResult, counters: BTreeMap<String, u64>) -> BenchEntry {
        BenchEntry {
            counters,
            iters: Some(r.iters),
            min_ns: Some(r.min_ns),
            median_ns: Some(r.median_ns),
            mean_ns: Some(r.mean_ns),
        }
    }
}

/// The `kareus bench` artifact (tag `"bench": "kareus_bench"`, validated
/// by `kareus check` as K080–K082). In deterministic mode all wall
/// fields — per-entry stats and `wall_s` — are null and the document is
/// byte-identical across runs.
#[derive(Clone, Debug)]
pub struct BenchReport {
    pub deterministic: bool,
    pub entries: BTreeMap<String, BenchEntry>,
    pub wall_s: Option<f64>,
}

impl BenchReport {
    pub fn to_json(&self) -> Json {
        let wall = |v: Option<f64>| v.map(num).unwrap_or(Json::Null);
        let mut entries = BTreeMap::new();
        for (name, e) in &self.entries {
            let mut counters = BTreeMap::new();
            for (k, v) in &e.counters {
                counters.insert(k.clone(), num(*v as f64));
            }
            entries.insert(
                name.clone(),
                obj(vec![
                    ("counters", Json::Obj(counters)),
                    ("iters", e.iters.map(|i| num(i as f64)).unwrap_or(Json::Null)),
                    ("min_ns", wall(e.min_ns)),
                    ("median_ns", wall(e.median_ns)),
                    ("mean_ns", wall(e.mean_ns)),
                ]),
            );
        }
        obj(vec![
            ("bench", s("kareus_bench")),
            ("version", num(1.0)),
            ("deterministic", Json::Bool(self.deterministic)),
            ("entries", Json::Obj(entries)),
            ("wall_s", wall(self.wall_s)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut x = 0u64;
        let r = bench("noop-ish", 0.01, || {
            x = std::hint::black_box(x.wrapping_add(1));
        });
        assert!(r.iters >= 3);
        assert!(r.min_ns <= r.median_ns && r.median_ns <= r.mean_ns * 2.0);
    }

    #[test]
    fn deterministic_report_nulls_every_wall_field() {
        let mut counters = BTreeMap::new();
        counters.insert("evals".to_string(), 7u64);
        let mut entries = BTreeMap::new();
        entries.insert("x".to_string(), BenchEntry::deterministic(counters));
        let rep = BenchReport { deterministic: true, entries, wall_s: None };
        let j = rep.to_json();
        assert_eq!(j.get("bench").and_then(|b| b.as_str()), Some("kareus_bench"));
        assert_eq!(j.get("deterministic").and_then(|b| b.as_bool()), Some(true));
        assert!(matches!(j.get("wall_s"), Some(Json::Null)));
        let e = j.get("entries").unwrap().get("x").unwrap();
        for field in ["iters", "min_ns", "median_ns", "mean_ns"] {
            assert!(matches!(e.get(field), Some(Json::Null)), "{field}");
        }
        assert_eq!(
            e.get("counters").unwrap().get("evals").unwrap().as_f64(),
            Some(7.0)
        );
        // Deterministic reports must round-trip dump → parse.
        let text = rep.to_json().try_dump().unwrap();
        assert_eq!(Json::parse(&text).unwrap().dump(), text);
    }

    #[test]
    fn timed_report_populates_wall_fields() {
        let mut x = 0u64;
        let r = bench_quiet("q", 0.005, || {
            x = std::hint::black_box(x.wrapping_add(1));
        });
        let mut entries = BTreeMap::new();
        entries.insert("q".to_string(), BenchEntry::timed(&r, BTreeMap::new()));
        let rep = BenchReport { deterministic: false, entries, wall_s: Some(0.25) };
        let j = rep.to_json();
        let e = j.get("entries").unwrap().get("q").unwrap();
        assert!(e.get("min_ns").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(e.get("iters").unwrap().as_usize(), Some(r.iters));
        assert_eq!(j.get("wall_s").and_then(|w| w.as_f64()), Some(0.25));
    }

    #[test]
    fn formats() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5e4).contains("µs"));
        assert!(fmt_ns(5e7).contains("ms"));
        assert!(fmt_ns(5e9).contains("s"));
    }
}
